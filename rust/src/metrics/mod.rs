//! Metric helpers: Gop/s, Top/s/W, area efficiency, and the table
//! formatting used by the `figure` harness.

/// Performance in Gop/s from ops executed over cycles at `freq_mhz`.
pub fn gops(ops: u64, cycles: u64, freq_mhz: f64) -> f64 {
    if cycles == 0 {
        return 0.0;
    }
    ops as f64 / cycles as f64 * freq_mhz * 1.0e6 / 1.0e9
}

/// Energy efficiency in Gop/s/W given performance and power.
pub fn gops_per_w(gops: f64, power_mw: f64) -> f64 {
    gops / (power_mw * 1.0e-3)
}

/// Area efficiency in Gop/s/mm².
pub fn gops_per_mm2(gops: f64, area_mm2: f64) -> f64 {
    gops / area_mm2
}

/// Energy per operation in femtojoules.
pub fn fj_per_op(power_mw: f64, gops: f64) -> f64 {
    if gops == 0.0 {
        return f64::INFINITY;
    }
    // mW / Gop/s = 1e-3 J / 1e9 op = pJ/op; x1000 => fJ/op
    power_mw / gops * 1.0e3
}

/// Per-layer cost split of the plan-driven inference path: one-time
/// plan compilation (setup — weight packing, geometry resolution,
/// requant staging) vs per-image activation streaming (compute), with
/// the activation-packing share of compute broken out (pack — the
/// serial fraction the pool's banded packing attacks; `pack_us` is
/// *included* in `compute_us`). The throughput bench serializes these
/// into `BENCH_*.json` so the trajectory is recorded per commit.
#[derive(Debug, Clone)]
pub struct LayerSplit {
    pub name: String,
    pub setup_us: f64,
    /// Activation-packing wall time within `compute_us` (0 for
    /// elementwise and reference-staged layers).
    pub pack_us: f64,
    pub compute_us: f64,
}

/// Render the setup/pack/compute table (one row per layer + a totals
/// row).
pub fn render_setup_compute(rows: &[LayerSplit]) -> String {
    let mut body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.name.clone(),
                format!("{:.1}", r.setup_us),
                format!("{:.1}", r.pack_us),
                format!("{:.1}", r.compute_us),
            ]
        })
        .collect();
    let (setup, pack, compute) =
        rows.iter().fold((0.0, 0.0, 0.0), |(s, p, c), r| {
            (s + r.setup_us, p + r.pack_us, c + r.compute_us)
        });
    body.push(vec![
        "TOTAL".into(),
        format!("{setup:.1}"),
        format!("{pack:.1}"),
        format!("{compute:.1}"),
    ]);
    render_table(&["layer", "setup us", "pack us", "compute us"], &body)
}

/// Pretty-print a table: header + rows of equal length.
pub fn render_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = header.len();
    let mut width = vec![0usize; ncol];
    for (i, h) in header.iter().enumerate() {
        width[i] = h.len();
    }
    for r in rows {
        assert_eq!(r.len(), ncol, "ragged table row");
        for (i, c) in r.iter().enumerate() {
            width[i] = width[i].max(c.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: Vec<String>, width: &[usize]| -> String {
        let mut line = String::new();
        for (i, c) in cells.iter().enumerate() {
            if i > 0 {
                line.push_str("  ");
            }
            line.push_str(&format!("{:<w$}", c, w = width[i]));
        }
        line.trim_end().to_string()
    };
    out.push_str(&fmt_row(
        header.iter().map(|s| s.to_string()).collect(),
        &width,
    ));
    out.push('\n');
    out.push_str(&"-".repeat(width.iter().sum::<usize>() + 2 * (ncol - 1)));
    out.push('\n');
    for r in rows {
        out.push_str(&fmt_row(r.clone(), &width));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gops_math() {
        // 663552 ops in 528 cycles at 420 MHz ~ 528 Gop/s
        let g = gops(663_552, 528, 420.0);
        assert!((g - 527.8).abs() < 1.0);
        assert!((gops_per_w(100.0, 200.0) - 500.0).abs() < 1e-9);
        assert!((gops_per_mm2(91.0, 2.42) - 37.6).abs() < 0.1);
    }

    #[test]
    fn energy_per_op() {
        // 100 mW at 100 Gop/s = 1 pJ/op = 1000 fJ/op
        assert!((fj_per_op(100.0, 100.0) - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn table_renders_aligned() {
        let t = render_table(
            &["a", "bbb"],
            &[vec!["1".into(), "2".into()],
              vec!["333".into(), "4".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("a"));
        assert!(lines[2].starts_with("1"));
    }
}
