//! Deterministic parameter generation for functional inference.
//!
//! Weight *values* are random (the paper's latency/energy results do not
//! depend on learned values — DESIGN.md substitution table); shapes,
//! ranges and normquant parameters follow the layer signature exactly.

use crate::dnn::{Layer, LayerOp};
use crate::util::Rng;

/// Quantized parameters of one conv/linear layer.
#[derive(Debug, Clone)]
pub struct LayerParams {
    /// Weights: conv3x3 (Kout, Kin, 3, 3); conv1x1/linear (Kout, Kin).
    pub w: Vec<i32>,
    pub scale: Vec<i32>,
    pub bias: Vec<i32>,
}

/// Generate parameters for `layer` from a seeded RNG.
pub fn random_layer_params(layer: &Layer, rng: &mut Rng) -> LayerParams {
    let half = 1i32 << (layer.w_bits - 1);
    let n_w = match layer.op {
        LayerOp::Conv3x3 => layer.cout * layer.cin * 9,
        LayerOp::Conv1x1 | LayerOp::Linear => layer.cout * layer.cin,
        _ => 0,
    };
    LayerParams {
        w: (0..n_w).map(|_| rng.range_i32(-half, half)).collect(),
        scale: (0..layer.cout).map(|_| rng.range_i32(1, 16)).collect(),
        bias: (0..layer.cout)
            .map(|_| rng.range_i32(-(1 << 10), 1 << 10))
            .collect(),
    }
}

/// A synthetic CIFAR-like image: (32, 32, 3) with values in the stem's
/// input range.
pub fn random_image(i_bits: usize, rng: &mut Rng) -> Vec<i32> {
    (0..32 * 32 * 3)
        .map(|_| rng.range_i32(0, 1 << i_bits))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::{resnet20_layers, PrecisionConfig};

    #[test]
    fn params_respect_ranges() {
        let mut rng = Rng::new(1);
        for l in resnet20_layers(PrecisionConfig::Mixed) {
            if !l.op.on_rbe() {
                continue;
            }
            let p = random_layer_params(&l, &mut rng);
            let half = 1i32 << (l.w_bits - 1);
            assert!(p.w.iter().all(|&v| (-half..half).contains(&v)));
            assert_eq!(p.scale.len(), l.cout);
            assert!(p.scale.iter().all(|&s| s >= 1));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let l = &resnet20_layers(PrecisionConfig::Uniform8)[0];
        let a = random_layer_params(l, &mut Rng::new(7));
        let b = random_layer_params(l, &mut Rng::new(7));
        assert_eq!(a.w, b.w);
    }
}
