//! Deployment handles: the network-agnostic serving API.
//!
//! `Coordinator::deploy(&NetworkSpec)` resolves a deployment **once** —
//! layers built from the `dnn` registry, manifest validated, and (on the
//! native backend) the immutable [`NetworkPlan`] compiled into the
//! runtime's bounded, LRU-evicting plan cache. The returned
//! [`Deployment`] then serves [`Deployment::infer`],
//! [`Deployment::infer_batch`], [`Deployment::infer_latency`]
//! (single-image latency mode: conv layers tile-split across the worker
//! pool) and [`Deployment::profile`] as pure activation streaming: no
//! layer rebuilding, no weight re-derivation, no cache-key plumbing per
//! call.
//!
//! The handle borrows the coordinator, so any number of deployments
//! (tenants) can coexist over one shared runtime; the plan cache evicts
//! least-recently-used deployments once its byte budget is exceeded and
//! a re-deployed evictee rebuilds bit-identically from its spec.
//!
//! Serving parallelism is **one** path: [`Deployment::infer_scheduled`]
//! streams jobs onto the process-wide work-stealing runtime
//! ([`crate::runtime::global`] — workers provisioned once per process,
//! shared by every tenant) and a [`Schedule`] decides what the jobs
//! are: whole-image shards, per-layer packing bands + conv tiles, or
//! the hybrid of both. `infer_batch` and `infer_latency` are thin
//! presets over it, with bitwise-identical outputs. The PR-5 scoped
//! per-call pool survives as the `Owned` A/B path:
//! [`Deployment::infer_scheduled_on`] picks per call, `MARSELLUS_EXEC`
//! picks the process default, and both produce bit-identical logits.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{ensure, Result};

use crate::dnn::{Layer, NetworkSpec};
use crate::mapping::NetworkReport;
use crate::metrics::LayerSplit;
use crate::power::OperatingPoint;
use crate::runtime::{
    global, BackendKind, ExecCtx, ExecPool, ExecRuntime, NetworkPlan,
    PoolTelemetry,
};
use crate::util::Rng;

use super::infer::{ConvExec, Coordinator, InferenceResult};

/// Which parallelism shape [`Deployment::infer_scheduled`] applies.
/// Every mode is bitwise identical to a sequential per-image walk; they
/// differ only in how the pool's workers are fed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScheduleMode {
    /// Pick per call: `Latency` for a single image, `Hybrid` otherwise.
    Auto,
    /// Images across workers only — the throughput preset behind
    /// [`Deployment::infer_batch`].
    Batch,
    /// Conv tiles and packing bands within each image, images in
    /// sequence — the single-image preset behind
    /// [`Deployment::infer_latency`].
    Latency,
    /// Whole-image shards for the pool-aligned bulk of the batch, then
    /// the small remainder tiled within-image over the same pool — the
    /// mid-size-batch regime neither pure mode covers.
    Hybrid,
}

impl std::str::FromStr for ScheduleMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(ScheduleMode::Auto),
            "batch" => Ok(ScheduleMode::Batch),
            "latency" => Ok(ScheduleMode::Latency),
            "hybrid" => Ok(ScheduleMode::Hybrid),
            other => anyhow::bail!(
                "unknown schedule {other:?} (known: auto, batch, latency, \
                 hybrid)"
            ),
        }
    }
}

/// A serving schedule: worker count plus parallelism shape. The worker
/// count includes the calling thread and is clamped to 2x the machine's
/// cores by the pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Schedule {
    /// Total workers (the calling thread counts).
    pub threads: usize,
    /// Parallelism shape — see [`ScheduleMode`].
    pub mode: ScheduleMode,
}

impl Schedule {
    /// Let the scheduler pick the shape per call.
    pub fn auto(threads: usize) -> Self {
        Self { threads, mode: ScheduleMode::Auto }
    }

    /// Images across workers (throughput preset).
    pub fn batch(threads: usize) -> Self {
        Self { threads, mode: ScheduleMode::Batch }
    }

    /// Tiles within each image (single-image latency preset).
    pub fn latency(threads: usize) -> Self {
        Self { threads, mode: ScheduleMode::Latency }
    }

    /// Image shards + tiled remainder over one pool.
    pub fn hybrid(threads: usize) -> Self {
        Self { threads, mode: ScheduleMode::Hybrid }
    }
}

pub use crate::runtime::HYBRID_TILE_SPEEDUP_CAP;

/// A deployed network: spec resolved, layers staged, plan compiled.
///
/// Cheap to hold, `Sync`, and read-only — batch workers share it across
/// threads. Results are bitwise independent of batch size and worker
/// count (`infer(op, &img)` equals the same image inside any batch at
/// any thread count).
pub struct Deployment<'c> {
    coord: &'c Coordinator,
    spec: NetworkSpec,
    layers: Vec<Layer>,
    /// Compiled plan (native backend); `None` on backends that execute
    /// per-call artifacts.
    plan: Option<Arc<NetworkPlan>>,
    /// Seed-derived weights for the per-call path (non-native backends).
    params: Option<
        std::collections::HashMap<String, super::params::LayerParams>,
    >,
    /// Last scheduler report, memoized by operating point: the report is
    /// a pure function of (layers, op), so re-serving the same DVFS
    /// set-point costs one comparison instead of a scheduler walk.
    report: Mutex<Option<(OperatingPoint, Arc<NetworkReport>)>>,
    /// Whether the stale-tuning warning ([`Self::hybrid_cutover_for`])
    /// already fired — once per deployment, not per call.
    cutover_warned: AtomicBool,
}

impl<'c> Deployment<'c> {
    pub(super) fn new(
        coord: &'c Coordinator,
        spec: NetworkSpec,
        layers: Vec<Layer>,
        plan: Option<Arc<NetworkPlan>>,
        params: Option<
            std::collections::HashMap<String, super::params::LayerParams>,
        >,
    ) -> Self {
        Self {
            coord,
            spec,
            layers,
            plan,
            params,
            report: Mutex::new(None),
            cutover_warned: AtomicBool::new(false),
        }
    }

    /// The deployment identity this handle serves.
    pub fn spec(&self) -> &NetworkSpec {
        &self.spec
    }

    /// The resolved layer schedule.
    pub fn layers(&self) -> &[Layer] {
        &self.layers
    }

    /// The autotuned configuration this deployment serves from, if it
    /// was deployed through `Coordinator::deploy_tuned` (or the
    /// `MARSELLUS_TUNE` environment opt-in).
    pub fn tuned(&self) -> Option<&crate::runtime::TunedConfig> {
        self.plan.as_ref()?.tuned()
    }

    /// The hybrid batch/tile cutover in force: the measured one when
    /// this deployment carries a tuned configuration with a real
    /// tile-vs-sequential measurement, the fixed
    /// [`HYBRID_TILE_SPEEDUP_CAP`] otherwise.
    pub fn hybrid_cutover(&self) -> usize {
        self.tuned()
            .map(|t| t.hybrid_cutover())
            .unwrap_or(HYBRID_TILE_SPEEDUP_CAP)
    }

    /// [`Self::hybrid_cutover`] guarded against stale tunings: the
    /// tuned cutover was *measured* at [`TunedConfig::threads`] workers
    /// (`crate::runtime::TunedConfig`), so a serving call running at a
    /// different width would silently apply a measurement from a
    /// machine shape it never saw. Detect the divergence, warn once
    /// per deployment, and fall back to the fixed heuristic cap — the
    /// same behavior as an untuned deployment.
    ///
    /// [`TunedConfig::threads`]: crate::runtime::TunedConfig::threads
    pub fn hybrid_cutover_for(&self, live_threads: usize) -> usize {
        let live = live_threads.max(1);
        match self.tuned() {
            Some(t) if t.threads != live => {
                if !self.cutover_warned.swap(true, Ordering::Relaxed) {
                    eprintln!(
                        "warning: {}: serving at {live} threads but tuned \
                         at {} — stale split measurements; using the \
                         heuristic hybrid cutover ({HYBRID_TILE_SPEEDUP_CAP}) \
                         instead (re-tune at the serving width to clear \
                         this)",
                        self.spec, t.threads
                    );
                }
                HYBRID_TILE_SPEEDUP_CAP
            }
            Some(t) => t.hybrid_cutover(),
            None => HYBRID_TILE_SPEEDUP_CAP,
        }
    }

    /// (side, channels) of the unpadded input plane the network
    /// consumes, taken from its first layer.
    pub fn input_dims(&self) -> (usize, usize) {
        let first = &self.layers[0];
        (first.h, first.cin)
    }

    /// Input activation precision (bits) of the first layer.
    pub fn input_bits(&self) -> usize {
        self.layers[0].i_bits
    }

    /// A random input plane with the deployment's exact shape and
    /// precision — what `random_image` was for ResNet-20, for any
    /// registry network.
    pub fn random_input(&self, rng: &mut Rng) -> Vec<i32> {
        let (h, c) = self.input_dims();
        let hi = 1 << self.input_bits();
        (0..h * h * c).map(|_| rng.range_i32(0, hi)).collect()
    }

    /// Latency/energy report at an operating point (memoized per op).
    pub fn report(&self, op: &OperatingPoint) -> Result<Arc<NetworkReport>> {
        let mut memo = self.report.lock().unwrap();
        if let Some((cached_op, rep)) = memo.as_ref() {
            if cached_op == op {
                return Ok(rep.clone());
            }
        }
        let rep =
            Arc::new(self.coord.scheduler.network_report(&self.layers, op)?);
        *memo = Some((*op, rep.clone()));
        Ok(rep)
    }

    /// Run one input through the deployment: activation streaming only.
    pub fn infer(
        &self,
        op: &OperatingPoint,
        image: &[i32],
    ) -> Result<InferenceResult> {
        let report = self.report(op)?;
        let logits = self.run_one(image)?;
        Ok(InferenceResult {
            logits,
            report: (*report).clone(),
            cross_checked: 0,
        })
    }

    /// [`Self::infer`] with in-flight cross-checking: the named layers
    /// are re-computed with the Rust bit-serial datapath model and
    /// compared bit-exactly (expensive; pick small layers). Forces the
    /// per-call backend path — comparing the plan (which *is* the
    /// functional model) against itself would be vacuous.
    pub fn infer_cross_checked(
        &self,
        op: &OperatingPoint,
        image: &[i32],
        cross_check_layers: &[&str],
    ) -> Result<InferenceResult> {
        // A name that matches no cross-checkable conv layer must fail
        // loudly: silently checking nothing would report success for a
        // verification that never ran (e.g. a typo in `--check`).
        for name in cross_check_layers {
            ensure!(
                self.layers.iter().any(|l| l.name == *name
                    && matches!(
                        l.op,
                        crate::dnn::LayerOp::Conv3x3
                            | crate::dnn::LayerOp::Conv1x1
                    )),
                "{}: cross-check layer {name:?} matches no conv layer \
                 (cross-checkable: {})",
                self.spec,
                self.layers
                    .iter()
                    .filter(|l| matches!(
                        l.op,
                        crate::dnn::LayerOp::Conv3x3
                            | crate::dnn::LayerOp::Conv1x1
                    ))
                    .map(|l| l.name.as_str())
                    .collect::<Vec<_>>()
                    .join(", ")
            );
        }
        let report = self.report(op)?;
        let params = self.params_for_per_call();
        let (logits, cross_checked) = self.coord.run_network(
            &self.layers,
            params.as_ref(),
            image,
            cross_check_layers,
        )?;
        Ok(InferenceResult {
            logits,
            report: (*report).clone(),
            cross_checked,
        })
    }

    /// Per-layer setup/pack/compute split on one input: plan-compile
    /// cost (amortized over the deployment) vs activation-streaming
    /// cost (paid per inference), with the activation-packing share of
    /// compute broken out. Requires the plan path (native backend).
    pub fn profile(&self, image: &[i32]) -> Result<Vec<LayerSplit>> {
        self.profile_scheduled(image, 1).map(|(split, _)| split)
    }

    /// [`Self::profile`] over `threads` workers, additionally returning
    /// worker telemetry — how many threads *this call* spawned and how
    /// many per-layer jobs they served. Runs on the process default
    /// runtime ([`ExecRuntime::from_env`]); on the global runtime
    /// `spawned_threads` is 0 (workers pre-exist the call), which is
    /// the recovered provisioning overhead `marsellus infer --profile`
    /// prints.
    pub fn profile_scheduled(
        &self,
        image: &[i32],
        threads: usize,
    ) -> Result<(Vec<LayerSplit>, PoolTelemetry)> {
        self.profile_scheduled_on(image, threads, ExecRuntime::from_env())
    }

    /// [`Self::profile_scheduled`] with an explicit runtime choice —
    /// the telemetry A/B: `Owned` provisions a scoped pool for the call
    /// and reports its spawns (`width - 1`) and jobs; `Global` streams
    /// onto the pre-existing process runtime and reports zero spawns
    /// plus the jobs this call added to it.
    pub fn profile_scheduled_on(
        &self,
        image: &[i32],
        threads: usize,
        rt: ExecRuntime,
    ) -> Result<(Vec<LayerSplit>, PoolTelemetry)> {
        let plan = self.plan.as_ref().ok_or_else(|| {
            anyhow::anyhow!(
                "{}: profiling needs the plan path (native backend)",
                self.spec
            )
        })?;
        let mut split = Vec::with_capacity(plan.steps().len());
        let telemetry = if threads <= 1 {
            self.coord.run_network_exec(
                plan,
                image,
                Some(&mut split),
                ConvExec::Ctx(ExecCtx::Seq),
            )?;
            PoolTelemetry::sequential()
        } else {
            match rt {
                ExecRuntime::Owned => {
                    ExecPool::with(threads, |pool| -> Result<_> {
                        self.coord.run_network_exec(
                            plan,
                            image,
                            Some(&mut split),
                            ConvExec::Ctx(ExecCtx::Owned(pool)),
                        )?;
                        Ok(pool.telemetry())
                    })?
                }
                ExecRuntime::Global => {
                    let ctx = ExecCtx::Global(threads);
                    let before = global().telemetry();
                    self.coord.run_network_exec(
                        plan,
                        image,
                        Some(&mut split),
                        ConvExec::Ctx(ctx),
                    )?;
                    let after = global().telemetry();
                    PoolTelemetry {
                        width: ctx.width(),
                        // the whole point of the global runtime: a
                        // serving call provisions no threads
                        spawned_threads: after
                            .spawned_threads
                            .saturating_sub(before.spawned_threads),
                        jobs: after.jobs.saturating_sub(before.jobs),
                    }
                }
            }
        };
        Ok((split, telemetry))
    }

    /// [`Self::infer`] in **latency mode**: one image, with every conv
    /// layer's activation packing (row bands) and `(output-row, k_out)`
    /// range (tiles) split across a persistent pool of `threads`
    /// workers provisioned once for the whole layer walk. Requires the
    /// plan path (native backend). A thin preset over
    /// [`Self::infer_scheduled`] ([`Schedule::latency`]).
    ///
    /// Logits are bitwise identical to [`Self::infer`] at every worker
    /// count — tiling only changes which worker computes which disjoint
    /// output element. Use [`Self::infer_batch`] when *throughput* over
    /// many queued images matters (data-parallel over images, near-ideal
    /// scaling); use this when one image's wall-clock latency matters
    /// (tile-parallel inside the image, scaling bounded by the
    /// elementwise serial fraction).
    pub fn infer_latency(
        &self,
        op: &OperatingPoint,
        image: &[i32],
        threads: usize,
    ) -> Result<InferenceResult> {
        self.infer_latency_opts(op, image, threads, true)
    }

    /// [`Self::infer_latency`] with an explicit pool choice. `pooled =
    /// false` runs the **legacy** pre-pool tiler (`ConvPlan::run_tiled`:
    /// a fresh scoped-thread set spawned and joined per conv layer) —
    /// kept callable so benches can measure the recovered spawn
    /// overhead; both choices are bitwise identical.
    pub fn infer_latency_opts(
        &self,
        op: &OperatingPoint,
        image: &[i32],
        threads: usize,
        pooled: bool,
    ) -> Result<InferenceResult> {
        if pooled {
            self.infer_latency_on(op, image, threads, ExecRuntime::from_env())
        } else {
            let plan = self.plan.as_ref().ok_or_else(|| {
                anyhow::anyhow!(
                    "{}: latency mode needs the plan path (native backend)",
                    self.spec
                )
            })?;
            let report = self.report(op)?;
            let logits = self.coord.run_network_exec(
                plan,
                image,
                None,
                ConvExec::Respawn(threads),
            )?;
            Ok(InferenceResult {
                logits,
                report: (*report).clone(),
                cross_checked: 0,
            })
        }
    }

    /// [`Self::infer_latency`] with an explicit runtime choice — the
    /// Owned-vs-Global A/B for the single-image tiling path.
    pub fn infer_latency_on(
        &self,
        op: &OperatingPoint,
        image: &[i32],
        threads: usize,
        rt: ExecRuntime,
    ) -> Result<InferenceResult> {
        let plan = self.plan.as_ref().ok_or_else(|| {
            anyhow::anyhow!(
                "{}: latency mode needs the plan path (native backend)",
                self.spec
            )
        })?;
        let report = self.report(op)?;
        let logits =
            self.coord.run_network_planned(plan, image, None, threads, rt)?;
        Ok(InferenceResult {
            logits,
            report: (*report).clone(),
            cross_checked: 0,
        })
    }

    /// Run a batch of inputs in parallel over an intra-batch worker pool
    /// of `threads` workers sharing this deployment (the backend, its
    /// caches and the compiled plan are `Send + Sync` and shared
    /// read-only). A thin preset over [`Self::infer_scheduled`]
    /// ([`Schedule::batch`]: images across workers, no intra-image
    /// tiling).
    ///
    /// The batch is N requests against this one deployed model. Results
    /// come back in input order and are bitwise independent of
    /// `threads`: `infer_batch(op, &[img], 1)` and the same image inside
    /// an 8-wide batch produce identical logits.
    pub fn infer_batch(
        &self,
        op: &OperatingPoint,
        images: &[Vec<i32>],
        threads: usize,
    ) -> Result<Vec<InferenceResult>> {
        self.infer_batch_opts(op, images, threads, self.plan.is_some())
    }

    /// [`Self::infer_batch`] with an explicit execution-path choice.
    /// `use_plans = false` forces the per-call (pre-plan) backend path —
    /// the PJRT route, kept callable on native so benches and parity
    /// tests can compare both paths on one deployment. `use_plans =
    /// true` requires the native backend: plans execute the in-process
    /// functional models, and silently bypassing a non-native backend
    /// would misattribute its results.
    pub fn infer_batch_opts(
        &self,
        op: &OperatingPoint,
        images: &[Vec<i32>],
        threads: usize,
        use_plans: bool,
    ) -> Result<Vec<InferenceResult>> {
        self.infer_scheduled_opts(
            op,
            images,
            Schedule::batch(threads),
            use_plans,
            ExecRuntime::from_env(),
        )
    }

    /// Run a batch of inputs under an explicit [`Schedule`] — the one
    /// serving path every preset (`infer_batch`, `infer_latency`,
    /// `Auto`) narrows to. The schedule's jobs stream onto the
    /// process-wide work-stealing runtime (no threads are provisioned
    /// by the call): whole-image shards ([`ScheduleMode::Batch`]),
    /// per-layer packing bands + conv tiles ([`ScheduleMode::Latency`]),
    /// or shards for the worker-aligned bulk of the batch and tiles for
    /// the remainder ([`ScheduleMode::Hybrid`]).
    ///
    /// Results come back in input order and are bitwise identical to a
    /// sequential per-image walk for every `(batch, threads, mode)`
    /// combination — scheduling only moves work between workers, never
    /// changes arithmetic. `MARSELLUS_EXEC=owned` opts the process back
    /// into PR-5 scoped per-call pools ([`Self::infer_scheduled_on`]
    /// picks per call); logits are bitwise identical either way.
    pub fn infer_scheduled(
        &self,
        op: &OperatingPoint,
        images: &[Vec<i32>],
        sched: Schedule,
    ) -> Result<Vec<InferenceResult>> {
        self.infer_scheduled_on(op, images, sched, ExecRuntime::from_env())
    }

    /// [`Self::infer_scheduled`] with an explicit runtime choice — the
    /// Owned-vs-Global A/B: `Owned` provisions a scoped [`ExecPool`]
    /// for the call (the PR-5 behavior, kept for measurement and parity
    /// tests), `Global` streams onto the shared process runtime.
    pub fn infer_scheduled_on(
        &self,
        op: &OperatingPoint,
        images: &[Vec<i32>],
        sched: Schedule,
        rt: ExecRuntime,
    ) -> Result<Vec<InferenceResult>> {
        self.infer_scheduled_opts(
            op,
            images,
            sched,
            self.plan.is_some(),
            rt,
        )
    }

    fn infer_scheduled_opts(
        &self,
        op: &OperatingPoint,
        images: &[Vec<i32>],
        sched: Schedule,
        use_plans: bool,
        rt: ExecRuntime,
    ) -> Result<Vec<InferenceResult>> {
        ensure!(
            !use_plans || self.coord.runtime.kind() == BackendKind::Native,
            "plan-driven execution requires the native backend (current \
             backend: {})",
            self.coord.runtime.kind().as_str()
        );
        ensure!(
            !use_plans || self.plan.is_some(),
            "{}: deployment holds no compiled plan",
            self.spec
        );
        let n = images.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let report = self.report(op)?;
        let logits = if use_plans {
            let plan = self.plan.as_deref().expect("ensured above");
            self.run_scheduled_planned(plan, images, sched, rt)
        } else {
            // the per-call path executes whole artifacts — only the
            // image axis can parallelize
            ensure!(
                matches!(
                    sched.mode,
                    ScheduleMode::Auto | ScheduleMode::Batch
                ),
                "{}: the {:?} schedule tiles within images, which needs \
                 the plan path",
                self.spec,
                sched.mode
            );
            self.run_batch_per_call(images, sched.threads, rt)
        };
        logits
            .into_iter()
            .map(|l| {
                Ok(InferenceResult {
                    logits: l?,
                    report: (*report).clone(),
                    cross_checked: 0,
                })
            })
            .collect()
    }

    /// The plan-path scheduler body: resolve the schedule, pick the
    /// execution context (`rt`), feed it the schedule's jobs, return
    /// per-image results in input order.
    fn run_scheduled_planned(
        &self,
        plan: &NetworkPlan,
        images: &[Vec<i32>],
        sched: Schedule,
        rt: ExecRuntime,
    ) -> Vec<Result<Vec<i32>>> {
        let n = images.len();
        let threads = sched.threads.max(1);
        let mode = match sched.mode {
            ScheduleMode::Auto if n == 1 => ScheduleMode::Latency,
            ScheduleMode::Auto => ScheduleMode::Hybrid,
            m => m,
        };
        if threads == 1 {
            return images
                .iter()
                .map(|img| {
                    self.coord.run_network_exec(
                        plan,
                        img,
                        None,
                        ConvExec::Ctx(ExecCtx::Seq),
                    )
                })
                .collect();
        }
        // image shards never benefit from more workers than images
        let lanes = if mode == ScheduleMode::Batch {
            threads.min(n)
        } else {
            threads
        };
        match rt {
            ExecRuntime::Owned => ExecPool::with(lanes, |pool| {
                self.drive_schedule(plan, images, mode, ExecCtx::Owned(pool))
            }),
            ExecRuntime::Global => {
                self.drive_schedule(plan, images, mode, ExecCtx::Global(lanes))
            }
        }
    }

    /// Feed one resolved schedule's jobs to one execution context —
    /// shared verbatim by the `Owned` and `Global` arms, which is what
    /// makes their bitwise parity structural rather than maintained.
    fn drive_schedule<'env>(
        &'env self,
        plan: &'env NetworkPlan,
        images: &'env [Vec<i32>],
        mode: ScheduleMode,
        ctx: ExecCtx<'env>,
    ) -> Vec<Result<Vec<i32>>> {
        let n = images.len();
        let slots: Arc<Vec<Mutex<Option<Result<Vec<i32>>>>>> =
            Arc::new((0..n).map(|_| Mutex::new(None)).collect());
        // whole-image shards: workers pull image indices off the job
        // queue and run the sequential walk per image
        let shard_range = |lo: usize, hi: usize| {
            if lo >= hi {
                return;
            }
            let slots = slots.clone();
            ctx.scatter(
                hi - lo,
                Arc::new(move |i| {
                    let idx = lo + i;
                    *slots[idx].lock().unwrap() =
                        Some(self.coord.run_network_exec(
                            plan,
                            &images[idx],
                            None,
                            ConvExec::Ctx(ExecCtx::Seq),
                        ));
                }),
            );
        };
        // tiled images: the caller walks each image's layers, fanning
        // every layer's bands + tiles over the same workers
        let tile_range = |lo: usize, hi: usize| {
            for idx in lo..hi {
                *slots[idx].lock().unwrap() =
                    Some(self.coord.run_network_exec(
                        plan,
                        &images[idx],
                        None,
                        ConvExec::Ctx(ctx),
                    ));
            }
        };
        match mode {
            ScheduleMode::Batch => shard_range(0, n),
            ScheduleMode::Latency => tile_range(0, n),
            ScheduleMode::Hybrid => {
                let w = ctx.width();
                let rem = if n >= w { n % w } else { n };
                // tiling a remainder image across the workers is worth
                // ~cutover concurrent shards: the measured value on
                // tuned deployments (guarded against width divergence),
                // the fixed cap otherwise
                let tiled =
                    if rem > 0 && rem < w.min(self.hybrid_cutover_for(w)) {
                        rem
                    } else {
                        0
                    };
                shard_range(0, n - tiled);
                tile_range(n - tiled, n);
            }
            ScheduleMode::Auto => unreachable!("resolved by caller"),
        }
        Self::take_slots(&slots)
    }

    /// The per-call (pre-plan) batch body: image shards only, over the
    /// same context mechanism — the PJRT route parallelizes across
    /// images on the shared runtime too.
    fn run_batch_per_call(
        &self,
        images: &[Vec<i32>],
        threads: usize,
        rt: ExecRuntime,
    ) -> Vec<Result<Vec<i32>>> {
        let n = images.len();
        // Per-network state was prepared ONCE at deploy time; per-batch
        // work is only streaming images through it.
        let params = self.params_for_per_call();
        let run_one = |img: &[i32]| -> Result<Vec<i32>> {
            self.coord
                .run_network(&self.layers, params.as_ref(), img, &[])
                .map(|(l, _)| l)
        };
        let threads = threads.clamp(1, n);
        if threads == 1 {
            return images.iter().map(|img| run_one(img)).collect();
        }
        let slots: Arc<Vec<Mutex<Option<Result<Vec<i32>>>>>> =
            Arc::new((0..n).map(|_| Mutex::new(None)).collect());
        let task: Arc<dyn Fn(usize) + Send + Sync + '_> = {
            let task_slots = slots.clone();
            let run_one = &run_one;
            Arc::new(move |i: usize| {
                *task_slots[i].lock().unwrap() =
                    Some(run_one(images[i].as_slice()));
            })
        };
        match rt {
            ExecRuntime::Owned => {
                ExecPool::with(threads, |pool| {
                    ExecCtx::Owned(pool).scatter(n, task.clone())
                });
            }
            ExecRuntime::Global => {
                ExecCtx::Global(threads).scatter(n, task)
            }
        }
        Self::take_slots(&slots)
    }

    /// One input through whichever staged path this deployment holds
    /// (deploy guarantees exactly one of plan/params is populated).
    fn run_one(&self, image: &[i32]) -> Result<Vec<i32>> {
        match &self.plan {
            Some(plan) => self.coord.run_network_planned(
                plan,
                image,
                None,
                1,
                ExecRuntime::Global,
            ),
            None => self
                .coord
                .run_network(
                    &self.layers,
                    self.params_for_per_call().as_ref(),
                    image,
                    &[],
                )
                .map(|(l, _)| l),
        }
    }

    /// Drain per-image result slots in input order. Every slot is
    /// filled by construction — every context's `scatter` is a barrier.
    fn take_slots(
        slots: &[Mutex<Option<Result<Vec<i32>>>>],
    ) -> Vec<Result<Vec<i32>>> {
        slots
            .iter()
            .enumerate()
            .map(|(i, s)| {
                s.lock().unwrap().take().unwrap_or_else(|| {
                    panic!("batch slot {i} never filled")
                })
            })
            .collect()
    }

    /// Seed-derived weights for the per-call path: the staged map when
    /// this deployment was built without a plan, re-derived (cheap,
    /// deterministic) when the per-call path is explicitly requested on
    /// a plan deployment.
    fn params_for_per_call(
        &self,
    ) -> std::borrow::Cow<
        '_,
        std::collections::HashMap<String, super::params::LayerParams>,
    > {
        match &self.params {
            Some(p) => std::borrow::Cow::Borrowed(p),
            None => std::borrow::Cow::Owned(Coordinator::network_params(
                &self.layers,
                self.spec.seed,
            )),
        }
    }
}
