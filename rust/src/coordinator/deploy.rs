//! Deployment handles: the network-agnostic serving API.
//!
//! `Coordinator::deploy(&NetworkSpec)` resolves a deployment **once** —
//! layers built from the `dnn` registry, manifest validated, and (on the
//! native backend) the immutable [`NetworkPlan`] compiled into the
//! runtime's bounded, LRU-evicting plan cache. The returned
//! [`Deployment`] then serves [`Deployment::infer`],
//! [`Deployment::infer_batch`], [`Deployment::infer_latency`]
//! (single-image latency mode: conv layers tile-split across the worker
//! pool) and [`Deployment::profile`] as pure activation streaming: no
//! layer rebuilding, no weight re-derivation, no cache-key plumbing per
//! call.
//!
//! The handle borrows the coordinator, so any number of deployments
//! (tenants) can coexist over one shared runtime; the plan cache evicts
//! least-recently-used deployments once its byte budget is exceeded and
//! a re-deployed evictee rebuilds bit-identically from its spec.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{ensure, Result};

use crate::dnn::{Layer, NetworkSpec};
use crate::mapping::NetworkReport;
use crate::metrics::LayerSplit;
use crate::power::OperatingPoint;
use crate::runtime::{BackendKind, NetworkPlan};
use crate::util::Rng;

use super::infer::{Coordinator, InferenceResult};

/// A deployed network: spec resolved, layers staged, plan compiled.
///
/// Cheap to hold, `Sync`, and read-only — batch workers share it across
/// threads. Results are bitwise independent of batch size and worker
/// count (`infer(op, &img)` equals the same image inside any batch at
/// any thread count).
pub struct Deployment<'c> {
    coord: &'c Coordinator,
    spec: NetworkSpec,
    layers: Vec<Layer>,
    /// Compiled plan (native backend); `None` on backends that execute
    /// per-call artifacts.
    plan: Option<Arc<NetworkPlan>>,
    /// Seed-derived weights for the per-call path (non-native backends).
    params: Option<
        std::collections::HashMap<String, super::params::LayerParams>,
    >,
    /// Last scheduler report, memoized by operating point: the report is
    /// a pure function of (layers, op), so re-serving the same DVFS
    /// set-point costs one comparison instead of a scheduler walk.
    report: Mutex<Option<(OperatingPoint, Arc<NetworkReport>)>>,
}

impl<'c> Deployment<'c> {
    pub(super) fn new(
        coord: &'c Coordinator,
        spec: NetworkSpec,
        layers: Vec<Layer>,
        plan: Option<Arc<NetworkPlan>>,
        params: Option<
            std::collections::HashMap<String, super::params::LayerParams>,
        >,
    ) -> Self {
        Self {
            coord,
            spec,
            layers,
            plan,
            params,
            report: Mutex::new(None),
        }
    }

    /// The deployment identity this handle serves.
    pub fn spec(&self) -> &NetworkSpec {
        &self.spec
    }

    /// The resolved layer schedule.
    pub fn layers(&self) -> &[Layer] {
        &self.layers
    }

    /// (side, channels) of the unpadded input plane the network
    /// consumes, taken from its first layer.
    pub fn input_dims(&self) -> (usize, usize) {
        let first = &self.layers[0];
        (first.h, first.cin)
    }

    /// Input activation precision (bits) of the first layer.
    pub fn input_bits(&self) -> usize {
        self.layers[0].i_bits
    }

    /// A random input plane with the deployment's exact shape and
    /// precision — what `random_image` was for ResNet-20, for any
    /// registry network.
    pub fn random_input(&self, rng: &mut Rng) -> Vec<i32> {
        let (h, c) = self.input_dims();
        let hi = 1 << self.input_bits();
        (0..h * h * c).map(|_| rng.range_i32(0, hi)).collect()
    }

    /// Latency/energy report at an operating point (memoized per op).
    pub fn report(&self, op: &OperatingPoint) -> Result<Arc<NetworkReport>> {
        let mut memo = self.report.lock().unwrap();
        if let Some((cached_op, rep)) = memo.as_ref() {
            if cached_op == op {
                return Ok(rep.clone());
            }
        }
        let rep =
            Arc::new(self.coord.scheduler.network_report(&self.layers, op)?);
        *memo = Some((*op, rep.clone()));
        Ok(rep)
    }

    /// Run one input through the deployment: activation streaming only.
    pub fn infer(
        &self,
        op: &OperatingPoint,
        image: &[i32],
    ) -> Result<InferenceResult> {
        let report = self.report(op)?;
        let logits = self.run_one(image)?;
        Ok(InferenceResult {
            logits,
            report: (*report).clone(),
            cross_checked: 0,
        })
    }

    /// [`Self::infer`] with in-flight cross-checking: the named layers
    /// are re-computed with the Rust bit-serial datapath model and
    /// compared bit-exactly (expensive; pick small layers). Forces the
    /// per-call backend path — comparing the plan (which *is* the
    /// functional model) against itself would be vacuous.
    pub fn infer_cross_checked(
        &self,
        op: &OperatingPoint,
        image: &[i32],
        cross_check_layers: &[&str],
    ) -> Result<InferenceResult> {
        // A name that matches no cross-checkable conv layer must fail
        // loudly: silently checking nothing would report success for a
        // verification that never ran (e.g. a typo in `--check`).
        for name in cross_check_layers {
            ensure!(
                self.layers.iter().any(|l| l.name == *name
                    && matches!(
                        l.op,
                        crate::dnn::LayerOp::Conv3x3
                            | crate::dnn::LayerOp::Conv1x1
                    )),
                "{}: cross-check layer {name:?} matches no conv layer \
                 (cross-checkable: {})",
                self.spec,
                self.layers
                    .iter()
                    .filter(|l| matches!(
                        l.op,
                        crate::dnn::LayerOp::Conv3x3
                            | crate::dnn::LayerOp::Conv1x1
                    ))
                    .map(|l| l.name.as_str())
                    .collect::<Vec<_>>()
                    .join(", ")
            );
        }
        let report = self.report(op)?;
        let params = self.params_for_per_call();
        let (logits, cross_checked) = self.coord.run_network(
            &self.layers,
            params.as_ref(),
            image,
            cross_check_layers,
        )?;
        Ok(InferenceResult {
            logits,
            report: (*report).clone(),
            cross_checked,
        })
    }

    /// Per-layer setup-vs-compute split on one input: plan-compile cost
    /// (amortized over the deployment) vs activation-streaming cost
    /// (paid per inference). Requires the plan path (native backend).
    pub fn profile(&self, image: &[i32]) -> Result<Vec<LayerSplit>> {
        let plan = self.plan.as_ref().ok_or_else(|| {
            anyhow::anyhow!(
                "{}: profiling needs the plan path (native backend)",
                self.spec
            )
        })?;
        let mut split = Vec::with_capacity(plan.steps().len());
        let _ =
            self.coord.run_network_planned(plan, image, Some(&mut split), 1)?;
        Ok(split)
    }

    /// [`Self::infer`] in **latency mode**: one image, with every conv
    /// layer's `(output-row, k_out)` range split across `threads`
    /// workers of an intra-image tile pool (`ConvPlan::run_tiled`) over
    /// the shared immutable plan. Requires the plan path (native
    /// backend).
    ///
    /// Logits are bitwise identical to [`Self::infer`] at every worker
    /// count — tiling only changes which worker computes which disjoint
    /// output element. Use [`Self::infer_batch`] when *throughput* over
    /// many queued images matters (data-parallel over images, near-ideal
    /// scaling); use this when one image's wall-clock latency matters
    /// (tile-parallel inside the image, scaling bounded by packing /
    /// elementwise serial fractions).
    pub fn infer_latency(
        &self,
        op: &OperatingPoint,
        image: &[i32],
        threads: usize,
    ) -> Result<InferenceResult> {
        let plan = self.plan.as_ref().ok_or_else(|| {
            anyhow::anyhow!(
                "{}: latency mode needs the plan path (native backend)",
                self.spec
            )
        })?;
        let report = self.report(op)?;
        let logits =
            self.coord.run_network_planned(plan, image, None, threads)?;
        Ok(InferenceResult {
            logits,
            report: (*report).clone(),
            cross_checked: 0,
        })
    }

    /// Run a batch of inputs in parallel over an intra-batch worker pool
    /// of `threads` scoped threads sharing this deployment (the backend,
    /// its caches and the compiled plan are `Send + Sync` and shared
    /// read-only).
    ///
    /// The batch is N requests against this one deployed model. Results
    /// come back in input order and are bitwise independent of
    /// `threads`: `infer_batch(op, &[img], 1)` and the same image inside
    /// an 8-wide batch produce identical logits.
    pub fn infer_batch(
        &self,
        op: &OperatingPoint,
        images: &[Vec<i32>],
        threads: usize,
    ) -> Result<Vec<InferenceResult>> {
        self.infer_batch_opts(op, images, threads, self.plan.is_some())
    }

    /// [`Self::infer_batch`] with an explicit execution-path choice.
    /// `use_plans = false` forces the per-call (pre-plan) backend path —
    /// the PJRT route, kept callable on native so benches and parity
    /// tests can compare both paths on one deployment. `use_plans =
    /// true` requires the native backend: plans execute the in-process
    /// functional models, and silently bypassing a non-native backend
    /// would misattribute its results.
    pub fn infer_batch_opts(
        &self,
        op: &OperatingPoint,
        images: &[Vec<i32>],
        threads: usize,
        use_plans: bool,
    ) -> Result<Vec<InferenceResult>> {
        ensure!(
            !use_plans || self.coord.runtime.kind() == BackendKind::Native,
            "plan-driven execution requires the native backend (current \
             backend: {})",
            self.coord.runtime.kind().as_str()
        );
        ensure!(
            !use_plans || self.plan.is_some(),
            "{}: deployment holds no compiled plan",
            self.spec
        );
        let n = images.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let report = self.report(op)?;
        // Per-network state was prepared ONCE at deploy time; the only
        // per-batch choice is which staged operands to stream through.
        let params = if use_plans {
            None
        } else {
            Some(self.params_for_per_call())
        };
        let plan = if use_plans { self.plan.as_deref() } else { None };
        let run_one = |img: &[i32]| -> Result<Vec<i32>> {
            match (plan, &params) {
                (Some(p), _) => {
                    self.coord.run_network_planned(p, img, None, 1)
                }
                (None, Some(pr)) => self
                    .coord
                    .run_network(&self.layers, pr.as_ref(), img, &[])
                    .map(|(l, _)| l),
                (None, None) => unreachable!(),
            }
        };

        let threads = threads.clamp(1, n);
        let logits: Vec<Option<Result<Vec<i32>>>> = if threads == 1 {
            images.iter().map(|img| Some(run_one(img.as_slice()))).collect()
        } else {
            // Worker pool: threads pull the next image index from an
            // atomic queue, so stragglers don't idle the rest of the
            // pool. Output order (and every bit of every result) is
            // independent of the interleaving.
            let slots: Vec<Mutex<Option<Result<Vec<i32>>>>> =
                (0..n).map(|_| Mutex::new(None)).collect();
            let next = AtomicUsize::new(0);
            std::thread::scope(|s| {
                for _ in 0..threads {
                    let (slots, next, run_one) = (&slots, &next, &run_one);
                    s.spawn(move || loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        *slots[i].lock().unwrap() =
                            Some(run_one(images[i].as_slice()));
                    });
                }
            });
            slots.into_iter().map(|slot| slot.into_inner().unwrap()).collect()
        };
        logits
            .into_iter()
            .enumerate()
            .map(|(i, slot)| {
                let l = slot
                    .unwrap_or_else(|| panic!("batch slot {i} never filled"))?;
                Ok(InferenceResult {
                    logits: l,
                    report: (*report).clone(),
                    cross_checked: 0,
                })
            })
            .collect()
    }

    /// One input through whichever staged path this deployment holds
    /// (deploy guarantees exactly one of plan/params is populated).
    fn run_one(&self, image: &[i32]) -> Result<Vec<i32>> {
        match &self.plan {
            Some(plan) => {
                self.coord.run_network_planned(plan, image, None, 1)
            }
            None => self
                .coord
                .run_network(
                    &self.layers,
                    self.params_for_per_call().as_ref(),
                    image,
                    &[],
                )
                .map(|(l, _)| l),
        }
    }

    /// Seed-derived weights for the per-call path: the staged map when
    /// this deployment was built without a plan, re-derived (cheap,
    /// deterministic) when the per-call path is explicitly requested on
    /// a plan deployment.
    fn params_for_per_call(
        &self,
    ) -> std::borrow::Cow<
        '_,
        std::collections::HashMap<String, super::params::LayerParams>,
    > {
        match &self.params {
            Some(p) => std::borrow::Cow::Borrowed(p),
            None => std::borrow::Cow::Owned(Coordinator::network_params(
                &self.layers,
                self.spec.seed,
            )),
        }
    }
}
