//! The leader: ties the PJRT runtime (functional numerics), the DORY
//! scheduler (timing/energy), the RBE functional model (cross-checking)
//! and the ABB machinery into end-to-end flows.
//!
//! Python never appears here — the artifacts were AOT-compiled at build
//! time and the coordinator only loads/executes them through PJRT.

mod infer;
mod params;

pub use infer::{InferenceResult, Coordinator};
pub use params::{random_image, random_layer_params, LayerParams};
