//! The leader: ties the execution runtime (functional numerics via the
//! native or PJRT backend), the DORY scheduler (timing/energy), the RBE
//! functional model (cross-checking) and the ABB machinery into
//! end-to-end flows.
//!
//! Serving is deployment-handle based: [`Coordinator::deploy`] resolves
//! a `dnn::NetworkSpec` once into a [`Deployment`], after which
//! `infer`/`infer_batch`/`profile` are pure activation streaming.
//! Batches fan out onto the process-wide work-stealing runtime
//! (`runtime::global`) by default; an owned scoped pool remains as an
//! A/B path (`MARSELLUS_EXEC=owned`, [`Deployment::infer_scheduled_on`]).
//!
//! Python never appears here — layer numerics come either from the
//! in-tree native backend or from artifacts AOT-compiled at build time;
//! either way the coordinator only loads/executes them through the
//! `runtime` abstraction.

mod deploy;
mod infer;
mod params;

pub use deploy::{
    Deployment, Schedule, ScheduleMode, HYBRID_TILE_SPEEDUP_CAP,
};
pub use infer::{Coordinator, InferenceResult};
pub use params::{random_image, random_layer_params, LayerParams};
