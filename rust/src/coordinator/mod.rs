//! The leader: ties the execution runtime (functional numerics via the
//! native or PJRT backend), the DORY scheduler (timing/energy), the RBE
//! functional model (cross-checking) and the ABB machinery into
//! end-to-end flows.
//!
//! Python never appears here — layer numerics come either from the
//! in-tree native backend or from artifacts AOT-compiled at build time;
//! either way the coordinator only loads/executes them through the
//! `runtime` abstraction. Batches fan out over scoped threads sharing
//! one runtime ([`Coordinator::infer_batch`]).

mod infer;
mod params;

pub use infer::{Coordinator, InferenceResult};
pub use params::{random_image, random_layer_params, LayerParams};
