//! End-to-end quantized inference through the execution backend.
//!
//! The coordinator walks the layer schedule in execution order, feeding
//! each layer's executable (functional result, bit-exact vs. the Pallas
//! kernels regardless of backend) while the DORY scheduler produces the
//! per-layer latency/energy from the cycle models — the functional/timing
//! split of DESIGN.md. Residual bookkeeping (block inputs, downsample
//! shortcuts) mirrors `model.resnet20_forward`.
//!
//! Batch serving: [`Coordinator::infer_batch`] fans a batch of images out
//! over scoped worker threads sharing one `Runtime` (backends are
//! `Send + Sync`, and the compile cache lives behind the backend), the
//! first step toward the ROADMAP's heavy-traffic serving story.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::dnn::{resnet20_layers, Layer, LayerOp, Manifest, PrecisionConfig};
use crate::mapping::{NetworkReport, Scheduler};
use crate::power::OperatingPoint;
use crate::rbe::functional::{conv_bitserial, trim_input, NormQuant};
use crate::rbe::{RbeJob, RbeMode};
use crate::runtime::{Runtime, TensorArg};
use crate::util::Rng;

use super::params::{random_layer_params, LayerParams};

/// Result of one inference.
#[derive(Debug, Clone)]
pub struct InferenceResult {
    pub logits: Vec<i32>,
    pub report: NetworkReport,
    /// Layers whose backend output was cross-checked against the Rust
    /// bit-serial RBE model.
    pub cross_checked: usize,
}

/// The system leader.
pub struct Coordinator {
    pub runtime: Runtime,
    pub manifest: Manifest,
    pub scheduler: Scheduler,
}

impl Coordinator {
    /// Coordinator over the environment-selected backend
    /// (`MARSELLUS_BACKEND`, default native). Works without `make
    /// artifacts`: the manifest falls back to the built-in layer zoo.
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let runtime = Runtime::from_env(artifacts_dir)?;
        Self::with_runtime(runtime)
    }

    /// Coordinator over an explicitly constructed runtime/backend.
    pub fn with_runtime(runtime: Runtime) -> Result<Self> {
        let manifest = Manifest::load_or_builtin(runtime.artifacts_dir())
            .context("loading artifact manifest")?;
        Ok(Self { runtime, manifest, scheduler: Scheduler::default() })
    }

    /// Zero-pad (H, W, C) by one pixel on each spatial side.
    fn pad1(x: &[i32], h: usize, w: usize, c: usize) -> Vec<i32> {
        let (hp, wp) = (h + 2, w + 2);
        let mut out = vec![0i32; hp * wp * c];
        for y in 0..h {
            let src = y * w * c;
            let dst = ((y + 1) * wp + 1) * c;
            out[dst..dst + w * c].copy_from_slice(&x[src..src + w * c]);
        }
        out
    }

    fn exec_layer(&self, l: &Layer, inputs: &[TensorArg]) -> Result<Vec<i32>> {
        let exe = self
            .runtime
            .load(&l.artifact())
            .with_context(|| format!("layer {}", l.name))?;
        let outs = exe.execute_i32(inputs)?;
        Ok(outs.into_iter().next().unwrap())
    }

    /// Deterministic per-layer parameters for the deployed network: the
    /// weights are a function of `seed` alone, shared by every image of
    /// a batch.
    fn network_params(layers: &[Layer], seed: u64) -> HashMap<String, LayerParams> {
        let mut rng = Rng::new(seed);
        layers
            .iter()
            .filter(|l| l.op.on_rbe())
            .map(|l| (l.name.clone(), random_layer_params(l, &mut rng)))
            .collect()
    }

    /// Run ResNet-20 end to end. `cross_check_layers` names layers whose
    /// backend output is re-computed with the Rust bit-serial model and
    /// compared bit-exactly (expensive; pick small layers).
    pub fn infer_resnet20(
        &self,
        config: PrecisionConfig,
        op: &OperatingPoint,
        image: &[i32],
        seed: u64,
        cross_check_layers: &[&str],
    ) -> Result<InferenceResult> {
        let layers = resnet20_layers(config);
        self.manifest.validate_network(config)?;
        let params = Self::network_params(&layers, seed);
        let (logits, cross_checked) =
            self.run_network(&layers, &params, image, cross_check_layers)?;
        let report = self.scheduler.network_report(&layers, op)?;
        Ok(InferenceResult { logits, report, cross_checked })
    }

    /// Walk the layer schedule for one image against prepared weights.
    fn run_network(
        &self,
        layers: &[Layer],
        params: &HashMap<String, LayerParams>,
        image: &[i32],
        cross_check_layers: &[&str],
    ) -> Result<(Vec<i32>, usize)> {
        let mut cur = image.to_vec();
        let mut cur_hw = (32usize, 3usize); // (h, channels)
        let mut block_in: Vec<i32> = cur.clone();
        let mut down_out: Vec<i32> = Vec::new();
        let mut cross_checked = 0usize;

        for l in layers {
            match l.op {
                LayerOp::Conv3x3 => {
                    if l.name.ends_with(".conv0") {
                        block_in = cur.clone();
                    }
                    let p = &params[&l.name];
                    let padded = Self::pad1(&cur, l.h, l.h, l.cin);
                    let hp = l.h + 2;
                    let args = vec![
                        TensorArg::new(padded.clone(), vec![hp, hp, l.cin]),
                        TensorArg::new(
                            p.w.clone(),
                            vec![l.cout, l.cin, 3, 3],
                        ),
                        TensorArg::scalar_vec(p.scale.clone()),
                        TensorArg::scalar_vec(p.bias.clone()),
                    ];
                    let out = self.exec_layer(l, &args)?;
                    if cross_check_layers.contains(&l.name.as_str()) {
                        self.cross_check(l, &padded, p, &out)?;
                        cross_checked += 1;
                    }
                    cur = out;
                    cur_hw = (l.h_out(), l.cout);
                }
                LayerOp::Conv1x1 => {
                    let p = &params[&l.name];
                    let args = vec![
                        TensorArg::new(
                            block_in.clone(),
                            vec![l.h, l.h, l.cin],
                        ),
                        TensorArg::new(p.w.clone(), vec![l.cout, l.cin]),
                        TensorArg::scalar_vec(p.scale.clone()),
                        TensorArg::scalar_vec(p.bias.clone()),
                    ];
                    down_out = self.exec_layer(l, &args)?;
                    if cross_check_layers.contains(&l.name.as_str()) {
                        self.cross_check(l, &block_in, p, &down_out)?;
                        cross_checked += 1;
                    }
                }
                LayerOp::Add => {
                    let short = match l.residual_of.as_deref() {
                        Some("input") => &block_in,
                        _ => &down_out,
                    };
                    let dims = vec![l.h, l.h, l.cin];
                    let args = vec![
                        TensorArg::new(cur.clone(), dims.clone()),
                        TensorArg::new(short.clone(), dims),
                    ];
                    cur = self.exec_layer(l, &args)?;
                }
                LayerOp::AvgPool => {
                    let args = vec![TensorArg::new(
                        cur.clone(),
                        vec![l.h, l.h, l.cin],
                    )];
                    cur = self.exec_layer(l, &args)?;
                    cur_hw = (1, l.cout);
                }
                LayerOp::Linear => {
                    let p = &params[&l.name];
                    let args = vec![
                        TensorArg::new(cur.clone(), vec![l.cin]),
                        TensorArg::new(p.w.clone(), vec![l.cout, l.cin]),
                        TensorArg::scalar_vec(p.scale.clone()),
                        TensorArg::scalar_vec(p.bias.clone()),
                    ];
                    cur = self.exec_layer(l, &args)?;
                }
            }
        }
        let _ = cur_hw;
        Ok((cur, cross_checked))
    }

    /// Run a batch of images through ResNet-20 in parallel over
    /// `threads` scoped worker threads sharing this coordinator (the
    /// backend and its compile cache are `Send + Sync`).
    ///
    /// All images share the same `seed`, i.e. the same network weights —
    /// the batch is N requests against one deployed model. Results come
    /// back in input order and are bitwise independent of `threads`:
    /// `infer_batch(.., &[img], .., 1)` and the same image inside an
    /// 8-wide batch produce identical logits.
    pub fn infer_batch(
        &self,
        config: PrecisionConfig,
        op: &OperatingPoint,
        images: &[Vec<i32>],
        seed: u64,
        threads: usize,
    ) -> Result<Vec<InferenceResult>> {
        let n = images.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        // Per-network state is prepared ONCE for the whole batch: the
        // layer schedule, the seed-derived weights and the timing/energy
        // report are image-independent and shared read-only by workers.
        let layers = resnet20_layers(config);
        self.manifest.validate_network(config)?;
        let params = Self::network_params(&layers, seed);
        let report = self.scheduler.network_report(&layers, op)?;

        let threads = threads.clamp(1, n);
        let mut logits: Vec<Option<Result<Vec<i32>>>> = Vec::new();
        if threads == 1 {
            for img in images {
                logits.push(Some(
                    self.run_network(&layers, &params, img, &[])
                        .map(|(l, _)| l),
                ));
            }
        } else {
            let slots: Vec<Mutex<Option<Result<Vec<i32>>>>> =
                (0..n).map(|_| Mutex::new(None)).collect();
            std::thread::scope(|s| {
                for t in 0..threads {
                    let (slots, layers, params) = (&slots, &layers, &params);
                    s.spawn(move || {
                        let mut i = t;
                        while i < n {
                            let r = self
                                .run_network(layers, params, &images[i], &[])
                                .map(|(l, _)| l);
                            *slots[i].lock().unwrap() = Some(r);
                            i += threads;
                        }
                    });
                }
            });
            logits = slots
                .into_iter()
                .map(|slot| slot.into_inner().unwrap())
                .collect();
        }
        logits
            .into_iter()
            .enumerate()
            .map(|(i, slot)| {
                let l = slot
                    .unwrap_or_else(|| panic!("batch slot {i} never filled"))?;
                Ok(InferenceResult {
                    logits: l,
                    report: report.clone(),
                    cross_checked: 0,
                })
            })
            .collect()
    }

    /// Re-compute a conv layer with the Rust bit-serial datapath model
    /// and compare bit-exactly with the backend output.
    fn cross_check(
        &self,
        l: &Layer,
        input: &[i32],
        p: &LayerParams,
        backend_out: &[i32],
    ) -> Result<()> {
        let h = l.h_out();
        let job = match l.op {
            LayerOp::Conv3x3 => RbeJob {
                mode: RbeMode::Conv3x3,
                h_out: h,
                w_out: h,
                k_in: l.cin,
                k_out: l.cout,
                stride: l.stride,
                w_bits: l.w_bits,
                i_bits: l.i_bits,
                o_bits: l.o_bits,
            },
            LayerOp::Conv1x1 => RbeJob {
                mode: RbeMode::Conv1x1,
                h_out: h,
                w_out: h,
                k_in: l.cin,
                k_out: l.cout,
                stride: l.stride,
                w_bits: l.w_bits,
                i_bits: l.i_bits,
                o_bits: l.o_bits,
            },
            _ => anyhow::bail!("cross-check supports conv layers"),
        };
        let nq = NormQuant {
            scale: p.scale.clone(),
            bias: p.bias.clone(),
            shift: l.shift,
        };
        // The backend takes the layer's full input plane; the datapath
        // model wants exactly the strided extent ((h_out-1)*stride + k).
        let full = if l.op == LayerOp::Conv3x3 { l.h + 2 } else { l.h };
        let input = trim_input(input, full, job.h_in(), l.cin);
        let ours = conv_bitserial(&job, &input, &p.w, &nq)?;
        anyhow::ensure!(
            ours == backend_out,
            "bit-serial model and {} backend disagree on layer {}",
            self.runtime.kind().as_str(),
            l.name
        );
        Ok(())
    }
}
