//! End-to-end quantized inference through the AOT artifacts.
//!
//! The coordinator walks the layer schedule in execution order, feeding
//! each layer's PJRT executable (functional result, bit-exact vs. the
//! Pallas kernels) while the DORY scheduler produces the per-layer
//! latency/energy from the cycle models — the functional/timing split of
//! DESIGN.md. Residual bookkeeping (block inputs, downsample shortcuts)
//! mirrors `model.resnet20_forward`.

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::dnn::{resnet20_layers, Layer, LayerOp, Manifest, PrecisionConfig};
use crate::mapping::{NetworkReport, Scheduler};
use crate::power::OperatingPoint;
use crate::rbe::functional::{conv_bitserial, NormQuant};
use crate::rbe::{RbeJob, RbeMode};
use crate::runtime::{Runtime, TensorArg};
use crate::util::Rng;

use super::params::{random_layer_params, LayerParams};

/// Result of one inference.
#[derive(Debug, Clone)]
pub struct InferenceResult {
    pub logits: Vec<i32>,
    pub report: NetworkReport,
    /// Layers whose artifact output was cross-checked against the Rust
    /// bit-serial RBE model.
    pub cross_checked: usize,
}

/// The system leader.
pub struct Coordinator {
    pub runtime: Runtime,
    pub manifest: Manifest,
    pub scheduler: Scheduler,
}

impl Coordinator {
    pub fn new(artifacts_dir: &str) -> Result<Self> {
        let runtime = Runtime::cpu(artifacts_dir)?;
        let manifest =
            Manifest::load(std::path::Path::new(artifacts_dir))
                .context("loading manifest.tsv (run `make artifacts`)")?;
        Ok(Self { runtime, manifest, scheduler: Scheduler::default() })
    }

    /// Zero-pad (H, W, C) by one pixel on each spatial side.
    fn pad1(x: &[i32], h: usize, w: usize, c: usize) -> Vec<i32> {
        let (hp, wp) = (h + 2, w + 2);
        let mut out = vec![0i32; hp * wp * c];
        for y in 0..h {
            let src = y * w * c;
            let dst = ((y + 1) * wp + 1) * c;
            out[dst..dst + w * c].copy_from_slice(&x[src..src + w * c]);
        }
        out
    }

    fn exec_layer(
        &self,
        l: &Layer,
        inputs: &[TensorArg],
    ) -> Result<Vec<i32>> {
        let exe = self
            .runtime
            .load(&l.artifact())
            .with_context(|| format!("layer {}", l.name))?;
        let outs = exe.execute_i32(inputs)?;
        Ok(outs.into_iter().next().unwrap())
    }

    /// Run ResNet-20 end to end. `cross_check_layers` names layers whose
    /// artifact output is re-computed with the Rust bit-serial model and
    /// compared bit-exactly (expensive; pick small layers).
    pub fn infer_resnet20(
        &self,
        config: PrecisionConfig,
        op: &OperatingPoint,
        image: &[i32],
        seed: u64,
        cross_check_layers: &[&str],
    ) -> Result<InferenceResult> {
        let layers = resnet20_layers(config);
        self.manifest.validate_network(config)?;
        let mut rng = Rng::new(seed);
        let params: HashMap<String, LayerParams> = layers
            .iter()
            .filter(|l| l.op.on_rbe())
            .map(|l| (l.name.clone(), random_layer_params(l, &mut rng)))
            .collect();

        let mut cur = image.to_vec();
        let mut cur_hw = (32usize, 3usize); // (h, channels)
        let mut block_in: Vec<i32> = cur.clone();
        let mut down_out: Vec<i32> = Vec::new();
        let mut cross_checked = 0usize;

        for l in &layers {
            match l.op {
                LayerOp::Conv3x3 => {
                    if l.name.ends_with(".conv0") {
                        block_in = cur.clone();
                    }
                    let p = &params[&l.name];
                    let padded = Self::pad1(&cur, l.h, l.h, l.cin);
                    let hp = l.h + 2;
                    let args = vec![
                        TensorArg::new(padded.clone(), vec![hp, hp, l.cin]),
                        TensorArg::new(
                            p.w.clone(),
                            vec![l.cout, l.cin, 3, 3],
                        ),
                        TensorArg::scalar_vec(p.scale.clone()),
                        TensorArg::scalar_vec(p.bias.clone()),
                    ];
                    let out = self.exec_layer(l, &args)?;
                    if cross_check_layers.contains(&l.name.as_str()) {
                        self.cross_check(l, &padded, p, &out)?;
                        cross_checked += 1;
                    }
                    cur = out;
                    cur_hw = (l.h_out(), l.cout);
                }
                LayerOp::Conv1x1 => {
                    let p = &params[&l.name];
                    let args = vec![
                        TensorArg::new(
                            block_in.clone(),
                            vec![l.h, l.h, l.cin],
                        ),
                        TensorArg::new(p.w.clone(), vec![l.cout, l.cin]),
                        TensorArg::scalar_vec(p.scale.clone()),
                        TensorArg::scalar_vec(p.bias.clone()),
                    ];
                    down_out = self.exec_layer(l, &args)?;
                    if cross_check_layers.contains(&l.name.as_str()) {
                        self.cross_check(l, &block_in, p, &down_out)?;
                        cross_checked += 1;
                    }
                }
                LayerOp::Add => {
                    let short = match l.residual_of.as_deref() {
                        Some("input") => &block_in,
                        _ => &down_out,
                    };
                    let dims = vec![l.h, l.h, l.cin];
                    let args = vec![
                        TensorArg::new(cur.clone(), dims.clone()),
                        TensorArg::new(short.clone(), dims),
                    ];
                    cur = self.exec_layer(l, &args)?;
                }
                LayerOp::AvgPool => {
                    let args = vec![TensorArg::new(
                        cur.clone(),
                        vec![l.h, l.h, l.cin],
                    )];
                    cur = self.exec_layer(l, &args)?;
                    cur_hw = (1, l.cout);
                }
                LayerOp::Linear => {
                    let p = &params[&l.name];
                    let args = vec![
                        TensorArg::new(cur.clone(), vec![l.cin]),
                        TensorArg::new(p.w.clone(), vec![l.cout, l.cin]),
                        TensorArg::scalar_vec(p.scale.clone()),
                        TensorArg::scalar_vec(p.bias.clone()),
                    ];
                    cur = self.exec_layer(l, &args)?;
                }
            }
        }
        let _ = cur_hw;
        let report = self.scheduler.network_report(&layers, op)?;
        Ok(InferenceResult { logits: cur, report, cross_checked })
    }

    /// Re-compute a conv layer with the Rust bit-serial datapath model
    /// and compare bit-exactly with the artifact output.
    fn cross_check(
        &self,
        l: &Layer,
        input: &[i32],
        p: &LayerParams,
        artifact_out: &[i32],
    ) -> Result<()> {
        let h = l.h_out();
        let job = match l.op {
            LayerOp::Conv3x3 => RbeJob {
                mode: RbeMode::Conv3x3,
                h_out: h,
                w_out: h,
                k_in: l.cin,
                k_out: l.cout,
                stride: l.stride,
                w_bits: l.w_bits,
                i_bits: l.i_bits,
                o_bits: l.o_bits,
            },
            LayerOp::Conv1x1 => RbeJob {
                mode: RbeMode::Conv1x1,
                h_out: h,
                w_out: h,
                k_in: l.cin,
                k_out: l.cout,
                stride: l.stride,
                w_bits: l.w_bits,
                i_bits: l.i_bits,
                o_bits: l.o_bits,
            },
            _ => anyhow::bail!("cross-check supports conv layers"),
        };
        let nq = NormQuant {
            scale: p.scale.clone(),
            bias: p.bias.clone(),
            shift: l.shift,
        };
        // The artifacts take the layer's full input plane; the datapath
        // model wants exactly the strided extent ((h_out-1)*stride + k).
        let need = job.h_in();
        let full = if l.op == LayerOp::Conv3x3 { l.h + 2 } else { l.h };
        let trimmed: Vec<i32>;
        let input = if need == full {
            input
        } else {
            let c = l.cin;
            let mut v = Vec::with_capacity(need * need * c);
            for r in 0..need {
                v.extend_from_slice(
                    &input[r * full * c..(r * full + need) * c],
                );
            }
            trimmed = v;
            &trimmed
        };
        let ours = conv_bitserial(&job, input, &p.w, &nq)?;
        anyhow::ensure!(
            ours == artifact_out,
            "bit-serial model and PJRT artifact disagree on layer {}",
            l.name
        );
        Ok(())
    }
}
