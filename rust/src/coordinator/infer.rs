//! End-to-end quantized inference through the execution backend.
//!
//! The coordinator walks a layer schedule in execution order, feeding
//! each layer's executable (functional result, bit-exact vs. the Pallas
//! kernels regardless of backend) while the DORY scheduler produces the
//! per-layer latency/energy from the cycle models — the functional/timing
//! split of DESIGN.md. Residual bookkeeping (block inputs, downsample
//! shortcuts) mirrors `model.resnet20_forward` and generalizes to every
//! registry network built from the same block grammar.
//!
//! Serving goes through deployment handles ([`super::deploy`]):
//! `Coordinator::deploy(spec)` resolves a `dnn::NetworkSpec` once —
//! layers built, manifest validated, [`NetworkPlan`] compiled into the
//! runtime's bounded plan cache — and the returned `Deployment` streams
//! activations per inference with no per-call network plumbing. Worker
//! fan-out flows through [`ExecCtx`]: the process-wide work-stealing
//! runtime by default, a caller-scoped [`ExecPool`] on the `Owned` A/B
//! path, inline for single-lane calls.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::dnn::{Layer, LayerOp, Manifest, NetworkSpec};
use crate::mapping::Scheduler;
use crate::metrics::LayerSplit;
use crate::rbe::functional::{
    add_requant, avgpool, conv_bitserial, trim_input, NormQuant,
    PlaneWidth,
};
use crate::rbe::{RbeJob, RbeMode};
use crate::runtime::{
    machine_fingerprint, BackendKind, ConvPlan, ConvRun, ExecCtx,
    ExecPool, ExecRuntime, LayerPlan, LayerTune, NetworkPlan, PlanStep,
    Runtime, SplitFactors, TensorArg, TuneOptions, TunedConfig,
    BAND_FACTOR_CANDIDATES, LATENCY_TILE_MIN_MACS,
    TILE_FACTOR_CANDIDATES,
};
use crate::util::Rng;

use super::deploy::Deployment;
use super::params::{random_layer_params, LayerParams};

/// Result of one inference.
#[derive(Debug, Clone)]
pub struct InferenceResult {
    pub logits: Vec<i32>,
    pub report: crate::mapping::NetworkReport,
    /// Layers whose backend output was cross-checked against the Rust
    /// bit-serial RBE model.
    pub cross_checked: usize,
}

/// How conv layers of a planned walk fan out — the execution half of a
/// schedule. Every variant is bitwise identical; they differ only in
/// wall clock and in how worker threads are provisioned.
#[derive(Clone, Copy)]
pub(super) enum ConvExec<'env> {
    /// Per-layer jobs (packing bands + conv tiles) on an execution
    /// context: inline ([`ExecCtx::Seq`] — also the per-image shard
    /// mode of the batch/hybrid scheduler, where parallelism lives
    /// across images), a caller-scoped pool ([`ExecCtx::Owned`]), or
    /// the process-wide runtime ([`ExecCtx::Global`]).
    Ctx(ExecCtx<'env>),
    /// The legacy pre-pool tiler: a fresh scoped-thread set spawned and
    /// joined per conv layer. Kept for A/B benches of the recovered
    /// spawn overhead.
    Respawn(usize),
}

/// Salt decorrelating the autotuner's probe image from any seed a
/// caller is likely to use for real inputs.
const TUNE_PROBE_SALT: u64 = 0x7E57_AB1E;

/// The system leader.
pub struct Coordinator {
    pub runtime: Runtime,
    pub manifest: Manifest,
    pub scheduler: Scheduler,
}

impl Coordinator {
    /// Coordinator over the environment-selected backend
    /// (`MARSELLUS_BACKEND`, default native). Works without `make
    /// artifacts`: the manifest falls back to the built-in layer zoo.
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let runtime = Runtime::from_env(artifacts_dir)?;
        Self::with_runtime(runtime)
    }

    /// Coordinator over an explicitly constructed runtime/backend.
    pub fn with_runtime(runtime: Runtime) -> Result<Self> {
        let manifest = Manifest::load_or_builtin(runtime.artifacts_dir())
            .context("loading artifact manifest")?;
        Ok(Self { runtime, manifest, scheduler: Scheduler::default() })
    }

    /// Zero-pad (H, W, C) by one pixel on each spatial side.
    pub(super) fn pad1(x: &[i32], h: usize, w: usize, c: usize) -> Vec<i32> {
        let (hp, wp) = (h + 2, w + 2);
        let mut out = vec![0i32; hp * wp * c];
        for y in 0..h {
            let src = y * w * c;
            let dst = ((y + 1) * wp + 1) * c;
            out[dst..dst + w * c].copy_from_slice(&x[src..src + w * c]);
        }
        out
    }

    fn exec_layer(&self, l: &Layer, inputs: &[TensorArg]) -> Result<Vec<i32>> {
        let exe = self
            .runtime
            .load(&l.artifact())
            .with_context(|| format!("layer {}", l.name))?;
        let outs = exe.execute_i32(inputs)?;
        Ok(outs.into_iter().next().unwrap())
    }

    /// Deterministic per-layer parameters for the deployed network: the
    /// weights are a function of `seed` alone, shared by every image of
    /// a batch.
    pub(super) fn network_params(
        layers: &[Layer],
        seed: u64,
    ) -> HashMap<String, LayerParams> {
        let mut rng = Rng::new(seed);
        layers
            .iter()
            .filter(|l| l.op.on_rbe())
            .map(|l| (l.name.clone(), random_layer_params(l, &mut rng)))
            .collect()
    }

    /// Resolve a [`NetworkSpec`] **once** into a served [`Deployment`]
    /// handle: layers built from the `dnn` registry, manifest validated,
    /// and — on the native backend — the [`NetworkPlan`] compiled into
    /// (or fetched from) the runtime's bounded plan cache. After
    /// `deploy`, `Deployment::{infer, infer_batch, profile}` are pure
    /// activation streaming with no per-call network plumbing.
    pub fn deploy(&self, spec: &NetworkSpec) -> Result<Deployment<'_>> {
        if self.runtime.kind() == BackendKind::Native {
            // opt-in deploy-time autotuning (`MARSELLUS_TUNE=1`)
            if let Some(opts) = TuneOptions::from_env() {
                return self.deploy_tuned(spec, &opts);
            }
        }
        let layers = spec.layers()?;
        self.manifest
            .validate_layers(&layers)
            .with_context(|| format!("deploying {spec}"))?;
        let (plan, params) = if self.runtime.kind() == BackendKind::Native {
            (Some(self.plan_for_layers(spec, &layers)?), None)
        } else {
            (None, Some(Self::network_params(&layers, spec.seed)))
        };
        Ok(Deployment::new(self, spec.clone(), layers, plan, params))
    }

    /// [`Self::deploy`] with deploy-time autotuning: candidate
    /// (width × tile × band) kernel variants are micro-benchmarked per
    /// conv layer on this machine and the deployment serves from a plan
    /// compiled to the winners, with the hybrid batch/tile cutover
    /// derived from the measured tile-vs-sequential speedup. Tuning is
    /// paid once: a valid persisted config (`opts.persist_dir`, keyed
    /// by spec + [`machine_fingerprint`]) is reused, and the tuned plan
    /// enters the runtime's bounded plan cache like any other. Every
    /// candidate is constrained to configurations already proven
    /// bitwise identical — and re-checked against the heuristic plan's
    /// logits during measurement — so tuning changes speed, never
    /// logits. A trial budget of 0 deploys the exact heuristic
    /// configuration (useful as an A/B control). Native backend only.
    pub fn deploy_tuned(
        &self,
        spec: &NetworkSpec,
        opts: &TuneOptions,
    ) -> Result<Deployment<'_>> {
        ensure!(
            self.runtime.kind() == BackendKind::Native,
            "autotuning requires the native backend (layer plans); \
             backend is {}",
            self.runtime.kind().as_str()
        );
        let layers = spec.layers()?;
        self.manifest
            .validate_layers(&layers)
            .with_context(|| format!("deploying {spec}"))?;
        let fp = machine_fingerprint();
        // a resident plan only satisfies a tuned deploy when it carries
        // a config for THIS machine — and a measured one, unless the
        // caller explicitly asked for the heuristic control (trials 0)
        let accept = |p: &NetworkPlan| {
            p.tuned().is_some_and(|t| {
                t.fingerprint == fp && (t.trials > 0 || opts.trials == 0)
            })
        };
        let plan = match self.runtime.cached_network_plan(spec, &accept) {
            Some(plan) => plan,
            None => {
                let cfg = self.tuned_config(spec, &layers, opts, &fp)?;
                self.runtime.network_plan_replacing(spec, &accept, || {
                    self.build_plan_with(&layers, spec.seed, Some(&cfg))
                })?
            }
        };
        Ok(Deployment::new(self, spec.clone(), layers, Some(plan), None))
    }

    /// Resolve the tuned configuration for a deployment: trial budget 0
    /// short-circuits to the exact heuristic configuration; otherwise a
    /// valid persisted config for this spec + machine is reloaded, else
    /// the network is tuned now and the winner persisted.
    fn tuned_config(
        &self,
        spec: &NetworkSpec,
        layers: &[Layer],
        opts: &TuneOptions,
        fingerprint: &str,
    ) -> Result<TunedConfig> {
        if opts.trials == 0 {
            let plan = self.build_plan(layers, spec.seed)?;
            return Ok(Self::heuristic_config(
                spec,
                &plan,
                fingerprint,
                opts.threads.max(1),
            ));
        }
        let spec_key = spec.to_string();
        if let Some(dir) = &opts.persist_dir {
            if let Some(cfg) = TunedConfig::load(dir, &spec_key, fingerprint)?
            {
                return Ok(cfg);
            }
        }
        let cfg = self.tune_network(spec, layers, opts, fingerprint)?;
        if let Some(dir) = &opts.persist_dir {
            cfg.save(dir)?;
        }
        Ok(cfg)
    }

    /// The exact configuration the fixed heuristics pick — what a trial
    /// budget of 0 resolves to: every conv layer at its compiled width
    /// with unit split factors, nothing measured (so the hybrid cutover
    /// stays at the fixed cap).
    fn heuristic_config(
        spec: &NetworkSpec,
        plan: &NetworkPlan,
        fingerprint: &str,
        threads: usize,
    ) -> TunedConfig {
        let layers = plan
            .steps()
            .iter()
            .filter_map(|s| match &s.plan {
                LayerPlan::Conv(c) => Some(LayerTune::heuristic(
                    &s.layer.name,
                    c.plane_width(),
                )),
                _ => None,
            })
            .collect();
        TunedConfig {
            spec: spec.to_string(),
            fingerprint: fingerprint.to_string(),
            threads,
            trials: 0,
            tile_speedup: 0.0,
            layers,
        }
    }

    /// Micro-benchmark candidate (width × tile × band) variants for
    /// every conv layer of `spec` on this machine and return the
    /// winning configuration.
    ///
    /// Structure: a heuristic plan is built and walked once
    /// sequentially on a deterministic probe image, capturing each conv
    /// layer's exact input plane (so candidates are timed on real
    /// mid-network activations, not synthetic ones). Per measurable
    /// layer — at or above [`LATENCY_TILE_MIN_MACS`], where the workers
    /// engage — every width variant is compiled up front, then timed on
    /// the process-wide runtime (the same workers serving calls use):
    /// widths first at unit factors, then the split-
    /// factor grid on the winning width. The heuristic variant is timed
    /// first and wins ties (strict `<`), so measurement noise can never
    /// walk away from the default without evidence. Every candidate's
    /// first trial is asserted bitwise equal to the heuristic layer
    /// output, and the final tuned plan's whole-net logits (sequential
    /// and pooled) are asserted equal to the heuristic plan's — the
    /// pooled walk also yields the tile-vs-sequential speedup that
    /// becomes the measured hybrid cutover.
    fn tune_network(
        &self,
        spec: &NetworkSpec,
        layers: &[Layer],
        opts: &TuneOptions,
        fingerprint: &str,
    ) -> Result<TunedConfig> {
        let threads = opts.threads.max(1);
        let trials = opts.trials.max(1);
        let heuristic = self.build_plan(layers, spec.seed)?;
        // deterministic probe image from the entry layer's geometry
        let first = layers
            .iter()
            .find(|l| l.op.on_rbe())
            .context("network has no conv/linear layer to tune")?;
        let mut rng = Rng::new(spec.seed ^ TUNE_PROBE_SALT);
        let probe: Vec<i32> = (0..first.h * first.h * first.cin)
            .map(|_| rng.range_i32(0, 1 << first.i_bits))
            .collect();
        // one sequential reference walk, capturing every conv input
        let steps = heuristic.steps();
        let mut inputs: Vec<Option<Vec<i32>>> = vec![None; steps.len()];
        let mut capture = |idx: usize, x: &[i32]| {
            inputs[idx] = Some(x.to_vec());
        };
        let heuristic_logits = self.run_network_exec_obs(
            &heuristic,
            &probe,
            None,
            ConvExec::Ctx(ExecCtx::Seq),
            Some(&mut capture),
        )?;
        let params = Self::network_params(layers, spec.seed);
        let numerics = self.runtime.backend().plan_numerics();
        let mut tuned_layers = Vec::new();
        for (idx, step) in steps.iter().enumerate() {
            let LayerPlan::Conv(hc) = &step.plan else { continue };
            let l = &step.layer;
            if threads <= 1 || hc.job.macs() < LATENCY_TILE_MIN_MACS {
                // the pool never engages here: nothing to measure, the
                // heuristic pick is exact by construction
                tuned_layers
                    .push(LayerTune::heuristic(&l.name, hc.plane_width()));
                continue;
            }
            let x = inputs[idx]
                .as_ref()
                .with_context(|| format!("no captured input for {}", l.name))?;
            let reference = hc.run(x)?;
            // every width variant compiles up front; heuristic width
            // first, so index 0 is always the control
            let heur_width = hc.plane_width();
            let widths: Vec<Option<PlaneWidth>> = match heur_width {
                Some(hw) => std::iter::once(Some(hw))
                    .chain(
                        PlaneWidth::ALL
                            .into_iter()
                            .filter(|w| *w != hw)
                            .map(Some),
                    )
                    .collect(),
                None => vec![None],
            };
            let e = self.manifest.get(&l.artifact()).with_context(|| {
                format!("layer {} has no artifact {}", l.name, l.artifact())
            })?;
            let p = &params[&l.name];
            let mut variants: Vec<(Option<PlaneWidth>, ConvPlan)> =
                Vec::with_capacity(widths.len());
            for w in &widths {
                let pick = LayerTune {
                    layer: l.name.clone(),
                    width: *w,
                    factors: SplitFactors::UNIT,
                    tuned_us: 0.0,
                    heuristic_us: 0.0,
                };
                let plan = LayerPlan::compile_with(
                    e,
                    &p.w,
                    &p.scale,
                    &p.bias,
                    numerics,
                    Some(&pick),
                )
                .with_context(|| format!("variant plan for {}", l.name))?;
                let LayerPlan::Conv(c) = plan else {
                    bail!("layer {} variant is not a conv plan", l.name)
                };
                variants.push((*w, c));
            }
            // measured on the process-wide runtime — the same workers
            // (and the same stealing behavior) serving calls run on
            let ctx = ExecCtx::Global(threads);
            let mut time_variant =
                |vi: usize, f: SplitFactors| -> Result<f64> {
                    let c = &variants[vi].1;
                    let mut best = f64::INFINITY;
                    for trial in 0..trials {
                        let t0 = Instant::now();
                        let r = c.run_scheduled_factored(x, ctx, f)?;
                        let us = t0.elapsed().as_secs_f64() * 1e6;
                        if trial == 0 {
                            ensure!(
                                r.out == reference,
                                "layer {}: candidate {:?} tile x{} \
                                 band x{} diverged from the heuristic \
                                 output",
                                l.name,
                                variants[vi].0,
                                f.tile,
                                f.band
                            );
                        }
                        best = best.min(us);
                    }
                    Ok(best)
                };
            // stage 1: the width axis at unit factors; the
            // heuristic (index 0) is timed first and wins ties
            let heuristic_us = time_variant(0, SplitFactors::UNIT)?;
            let (mut best_vi, mut best_us) = (0usize, heuristic_us);
            for vi in 1..variants.len() {
                let us = time_variant(vi, SplitFactors::UNIT)?;
                if us < best_us {
                    (best_vi, best_us) = (vi, us);
                }
            }
            // stage 2: the split-factor grid on the winning width
            let mut best_f = SplitFactors::UNIT;
            for tf in TILE_FACTOR_CANDIDATES {
                for bf in BAND_FACTOR_CANDIDATES {
                    let f = SplitFactors { tile: tf, band: bf };
                    if f == SplitFactors::UNIT {
                        continue;
                    }
                    let us = time_variant(best_vi, f)?;
                    if us < best_us {
                        (best_f, best_us) = (f, us);
                    }
                }
            }
            tuned_layers.push(LayerTune {
                layer: l.name.clone(),
                width: variants[best_vi].1.plane_width(),
                factors: best_f,
                tuned_us: best_us,
                heuristic_us,
            });
        }
        let mut cfg = TunedConfig {
            spec: spec.to_string(),
            fingerprint: fingerprint.to_string(),
            threads,
            trials,
            tile_speedup: 0.0,
            layers: tuned_layers,
        };
        // whole-net gate on the assembled winner: the tuned plan's
        // sequential and pooled walks must reproduce the heuristic
        // logits exactly — and their timing ratio is the measured
        // tile-vs-sequential speedup behind the hybrid cutover
        let tuned_plan = self.build_plan_with(layers, spec.seed, Some(&cfg))?;
        let mut seq_us = f64::INFINITY;
        for _ in 0..trials {
            let t0 = Instant::now();
            let logits = self.run_network_exec(
                &tuned_plan,
                &probe,
                None,
                ConvExec::Ctx(ExecCtx::Seq),
            )?;
            seq_us = seq_us.min(t0.elapsed().as_secs_f64() * 1e6);
            ensure!(
                logits == heuristic_logits,
                "tuned sequential walk diverged from heuristic logits"
            );
        }
        let mut pool_us = f64::INFINITY;
        for _ in 0..trials {
            let t0 = Instant::now();
            let logits = self.run_network_exec(
                &tuned_plan,
                &probe,
                None,
                ConvExec::Ctx(ExecCtx::Global(threads)),
            )?;
            pool_us = pool_us.min(t0.elapsed().as_secs_f64() * 1e6);
            ensure!(
                logits == heuristic_logits,
                "tuned pooled walk diverged from heuristic logits"
            );
        }
        cfg.tile_speedup =
            if pool_us > 0.0 { seq_us / pool_us } else { 1.0 };
        Ok(cfg)
    }

    /// Fetch (or compile, once) the layer-plan pipeline for a deployment
    /// from the runtime's bounded plan cache. Prefer [`Self::deploy`];
    /// this is the load-time half on its own.
    pub fn plan_for(&self, spec: &NetworkSpec) -> Result<Arc<NetworkPlan>> {
        let layers = spec.layers()?;
        self.manifest
            .validate_layers(&layers)
            .with_context(|| format!("deploying {spec}"))?;
        self.plan_for_layers(spec, &layers)
    }

    fn plan_for_layers(
        &self,
        spec: &NetworkSpec,
        layers: &[Layer],
    ) -> Result<Arc<NetworkPlan>> {
        self.runtime
            .network_plan(spec, || self.build_plan(layers, spec.seed))
    }

    /// Compile every layer of the network once: weights packed into RBE
    /// bit-plane words, job geometry resolved, requant constants staged.
    fn build_plan(&self, layers: &[Layer], seed: u64) -> Result<NetworkPlan> {
        self.build_plan_with(layers, seed, None)
    }

    /// [`Self::build_plan`], compiling each conv layer to its pick from
    /// a tuned configuration when one is given; the config rides inside
    /// the returned plan (`NetworkPlan::tuned`) and joins its byte
    /// accounting.
    fn build_plan_with(
        &self,
        layers: &[Layer],
        seed: u64,
        tuned: Option<&TunedConfig>,
    ) -> Result<NetworkPlan> {
        let params = Self::network_params(layers, seed);
        let numerics = self.runtime.backend().plan_numerics();
        let empty = LayerParams {
            w: Vec::new(),
            scale: Vec::new(),
            bias: Vec::new(),
        };
        let mut steps = Vec::with_capacity(layers.len());
        for l in layers {
            let name = l.artifact();
            let e = self.manifest.get(&name).with_context(|| {
                format!("layer {} has no artifact {name}", l.name)
            })?;
            let p = if l.op.on_rbe() { &params[&l.name] } else { &empty };
            let pick = tuned.and_then(|c| c.layer(&l.name));
            let t0 = Instant::now();
            let plan = LayerPlan::compile_with(
                e, &p.w, &p.scale, &p.bias, numerics, pick,
            )
            .with_context(|| format!("planning layer {}", l.name))?;
            steps.push(PlanStep {
                layer: l.clone(),
                plan,
                setup_us: t0.elapsed().as_secs_f64() * 1e6,
            });
        }
        let mut plan = NetworkPlan::new(steps);
        if let Some(cfg) = tuned {
            plan.set_tuned(cfg.clone());
        }
        Ok(plan)
    }

    /// Walk the compiled plan for one image: activation streaming only.
    /// Residual bookkeeping mirrors [`Self::run_network`] exactly. When
    /// `profile` is given, per-layer compute time (and its
    /// activation-packing share) is recorded next to the plan-compile
    /// (setup) time. `exec` chooses how each conv layer fans out — an
    /// [`ExecCtx`] (inline, scoped pool or the process-wide runtime) or
    /// the legacy spawn-per-layer tiler; every choice is bitwise
    /// identical, and elementwise layers stay serial in all of them
    /// (they are memory bound and a fraction of a percent of the work).
    pub(super) fn run_network_exec(
        &self,
        plan: &NetworkPlan,
        image: &[i32],
        profile: Option<&mut Vec<LayerSplit>>,
        exec: ConvExec<'_>,
    ) -> Result<Vec<i32>> {
        self.run_network_exec_obs(plan, image, profile, exec, None)
    }

    /// [`Self::run_network_exec`] with an optional per-step observer:
    /// `observe(step_index, conv_input)` fires for every conv/linear
    /// step with the exact activation plane the layer receives (padded
    /// for 3×3, the block input for 1×1 shortcuts). The autotuner uses
    /// this to capture real mid-network operands for candidate timing
    /// without duplicating the residual bookkeeping below.
    pub(super) fn run_network_exec_obs(
        &self,
        plan: &NetworkPlan,
        image: &[i32],
        mut profile: Option<&mut Vec<LayerSplit>>,
        exec: ConvExec<'_>,
        mut observe: Option<&mut dyn FnMut(usize, &[i32])>,
    ) -> Result<Vec<i32>> {
        let run_conv = |c: &ConvPlan, x: &[i32]| -> Result<ConvRun> {
            match exec {
                ConvExec::Ctx(ctx) => c.run_scheduled(x, ctx),
                ConvExec::Respawn(threads) => c
                    .run_tiled(x, threads)
                    .map(|out| ConvRun { out, pack_us: 0.0 }),
            }
        };
        let mut cur = image.to_vec();
        let mut block_in: Vec<i32> = cur.clone();
        let mut down_out: Vec<i32> = Vec::new();
        for (idx, step) in plan.steps().iter().enumerate() {
            let l = &step.layer;
            let t0 = profile.is_some().then(Instant::now);
            let mut pack_us = 0.0;
            match (&step.plan, l.op) {
                (LayerPlan::Conv(c), LayerOp::Conv3x3) => {
                    if l.name.ends_with(".conv0") {
                        block_in = cur.clone();
                    }
                    let padded = Self::pad1(&cur, l.h, l.h, l.cin);
                    if let Some(obs) = observe.as_mut() {
                        obs(idx, &padded);
                    }
                    let r = run_conv(c, &padded)
                        .with_context(|| format!("layer {}", l.name))?;
                    pack_us = r.pack_us;
                    cur = r.out;
                }
                (LayerPlan::Conv(c), LayerOp::Conv1x1) => {
                    if let Some(obs) = observe.as_mut() {
                        obs(idx, &block_in);
                    }
                    let r = run_conv(c, &block_in)
                        .with_context(|| format!("layer {}", l.name))?;
                    pack_us = r.pack_us;
                    down_out = r.out;
                }
                (
                    LayerPlan::Conv(c),
                    LayerOp::Linear | LayerOp::LinearSigned,
                ) => {
                    if let Some(obs) = observe.as_mut() {
                        obs(idx, &cur);
                    }
                    let r = run_conv(c, &cur)
                        .with_context(|| format!("layer {}", l.name))?;
                    pack_us = r.pack_us;
                    cur = r.out;
                }
                (LayerPlan::Add { h, k, shift, o_bits }, _) => {
                    let short = match l.residual_of.as_deref() {
                        Some("input") => &block_in,
                        _ => &down_out,
                    };
                    ensure!(
                        cur.len() == *h * *h * *k,
                        "layer {}: residual input length {} != {}x{}x{}",
                        l.name,
                        cur.len(),
                        h,
                        h,
                        k
                    );
                    cur = add_requant(&cur, short, *shift, *o_bits)
                        .with_context(|| format!("layer {}", l.name))?;
                }
                (LayerPlan::AvgPool { h, k, shift }, _) => {
                    cur = avgpool(&cur, *h * *h, *k, *shift)
                        .with_context(|| format!("layer {}", l.name))?;
                }
                (_, op) => {
                    bail!("layer {}: plan does not match op {op:?}", l.name)
                }
            }
            if let (Some(prof), Some(t0)) = (profile.as_mut(), t0) {
                prof.push(LayerSplit {
                    name: l.name.clone(),
                    setup_us: step.setup_us,
                    pack_us,
                    compute_us: t0.elapsed().as_secs_f64() * 1e6,
                });
            }
        }
        Ok(cur)
    }

    /// [`Self::run_network_exec`] with the thread-count calling
    /// convention — the single-image **latency mode**: `tile_threads`
    /// lanes of per-layer jobs on the runtime `rt` picks (the
    /// process-wide workers by default; `Owned` provisions a scoped
    /// [`ExecPool`] for the walk, the PR-5 A/B behavior).
    pub(super) fn run_network_planned(
        &self,
        plan: &NetworkPlan,
        image: &[i32],
        profile: Option<&mut Vec<LayerSplit>>,
        tile_threads: usize,
        rt: ExecRuntime,
    ) -> Result<Vec<i32>> {
        match rt {
            _ if tile_threads <= 1 => self.run_network_exec(
                plan,
                image,
                profile,
                ConvExec::Ctx(ExecCtx::Seq),
            ),
            ExecRuntime::Global => self.run_network_exec(
                plan,
                image,
                profile,
                ConvExec::Ctx(ExecCtx::Global(tile_threads)),
            ),
            ExecRuntime::Owned => ExecPool::with(tile_threads, |pool| {
                self.run_network_exec(
                    plan,
                    image,
                    profile,
                    ConvExec::Ctx(ExecCtx::Owned(pool)),
                )
            }),
        }
    }

    /// Walk the layer schedule for one image against prepared weights.
    pub(super) fn run_network(
        &self,
        layers: &[Layer],
        params: &HashMap<String, LayerParams>,
        image: &[i32],
        cross_check_layers: &[&str],
    ) -> Result<(Vec<i32>, usize)> {
        let mut cur = image.to_vec();
        let mut cur_hw = (32usize, 3usize); // (h, channels)
        let mut block_in: Vec<i32> = cur.clone();
        let mut down_out: Vec<i32> = Vec::new();
        let mut cross_checked = 0usize;

        for l in layers {
            match l.op {
                LayerOp::Conv3x3 => {
                    if l.name.ends_with(".conv0") {
                        block_in = cur.clone();
                    }
                    let p = &params[&l.name];
                    let padded = Self::pad1(&cur, l.h, l.h, l.cin);
                    let hp = l.h + 2;
                    let args = vec![
                        TensorArg::new(padded.clone(), vec![hp, hp, l.cin]),
                        TensorArg::new(
                            p.w.clone(),
                            vec![l.cout, l.cin, 3, 3],
                        ),
                        TensorArg::scalar_vec(p.scale.clone()),
                        TensorArg::scalar_vec(p.bias.clone()),
                    ];
                    let out = self.exec_layer(l, &args)?;
                    if cross_check_layers.contains(&l.name.as_str()) {
                        self.cross_check(l, &padded, p, &out)?;
                        cross_checked += 1;
                    }
                    cur = out;
                    cur_hw = (l.h_out(), l.cout);
                }
                LayerOp::Conv1x1 => {
                    let p = &params[&l.name];
                    let args = vec![
                        TensorArg::new(
                            block_in.clone(),
                            vec![l.h, l.h, l.cin],
                        ),
                        TensorArg::new(p.w.clone(), vec![l.cout, l.cin]),
                        TensorArg::scalar_vec(p.scale.clone()),
                        TensorArg::scalar_vec(p.bias.clone()),
                    ];
                    down_out = self.exec_layer(l, &args)?;
                    if cross_check_layers.contains(&l.name.as_str()) {
                        self.cross_check(l, &block_in, p, &down_out)?;
                        cross_checked += 1;
                    }
                }
                LayerOp::Add => {
                    let short = match l.residual_of.as_deref() {
                        Some("input") => &block_in,
                        _ => &down_out,
                    };
                    let dims = vec![l.h, l.h, l.cin];
                    let args = vec![
                        TensorArg::new(cur.clone(), dims.clone()),
                        TensorArg::new(short.clone(), dims),
                    ];
                    cur = self.exec_layer(l, &args)?;
                }
                LayerOp::AvgPool => {
                    let args = vec![TensorArg::new(
                        cur.clone(),
                        vec![l.h, l.h, l.cin],
                    )];
                    cur = self.exec_layer(l, &args)?;
                    cur_hw = (1, l.cout);
                }
                LayerOp::Linear | LayerOp::LinearSigned => {
                    let p = &params[&l.name];
                    let args = vec![
                        TensorArg::new(cur.clone(), vec![l.cin]),
                        TensorArg::new(p.w.clone(), vec![l.cout, l.cin]),
                        TensorArg::scalar_vec(p.scale.clone()),
                        TensorArg::scalar_vec(p.bias.clone()),
                    ];
                    cur = self.exec_layer(l, &args)?;
                }
            }
        }
        let _ = cur_hw;
        Ok((cur, cross_checked))
    }

    /// Re-compute a conv layer with the Rust bit-serial datapath model
    /// and compare bit-exactly with the backend output.
    pub(super) fn cross_check(
        &self,
        l: &Layer,
        input: &[i32],
        p: &LayerParams,
        backend_out: &[i32],
    ) -> Result<()> {
        let h = l.h_out();
        let job = match l.op {
            LayerOp::Conv3x3 => RbeJob {
                mode: RbeMode::Conv3x3,
                h_out: h,
                w_out: h,
                k_in: l.cin,
                k_out: l.cout,
                stride: l.stride,
                w_bits: l.w_bits,
                i_bits: l.i_bits,
                o_bits: l.o_bits,
            },
            LayerOp::Conv1x1 => RbeJob {
                mode: RbeMode::Conv1x1,
                h_out: h,
                w_out: h,
                k_in: l.cin,
                k_out: l.cout,
                stride: l.stride,
                w_bits: l.w_bits,
                i_bits: l.i_bits,
                o_bits: l.o_bits,
            },
            _ => anyhow::bail!("cross-check supports conv layers"),
        };
        let nq = NormQuant::new(p.scale.clone(), p.bias.clone(), l.shift);
        // The backend takes the layer's full input plane; the datapath
        // model wants exactly the strided extent ((h_out-1)*stride + k).
        let full = if l.op == LayerOp::Conv3x3 { l.h + 2 } else { l.h };
        let input = trim_input(input, full, job.h_in(), l.cin);
        let ours = conv_bitserial(&job, &input, &p.w, &nq)?;
        anyhow::ensure!(
            ours == backend_out,
            "bit-serial model and {} backend disagree on layer {}",
            self.runtime.kind().as_str(),
            l.name
        );
        Ok(())
    }
}
