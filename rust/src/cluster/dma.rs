//! Cluster DMA engine (paper §II: 64-bit/cycle read + 64-bit/cycle write
//! between L2 and the TCDM, through the dual-clock AXI FIFOs).
//!
//! Functionally the DMA copies words between L2 and L1; for timing it
//! reports the cycle cost of a (possibly 2-D strided) transfer, which the
//! mapping layer overlaps with compute via double buffering (paper
//! Fig. 16).

use anyhow::{bail, Result};

use super::memmap::{L2_SIZE, TCDM_SIZE};
use super::tcdm::Tcdm;

/// One DMA job description (word granularity).
#[derive(Debug, Clone, Copy)]
pub struct DmaTransfer {
    /// Source word offset (in L2 for in-transfers, L1 for out-transfers).
    pub src_word: usize,
    /// Destination word offset.
    pub dst_word: usize,
    /// Contiguous words per line.
    pub line_words: usize,
    /// Number of lines (1 = 1-D transfer).
    pub lines: usize,
    /// Source stride between lines, in words.
    pub src_stride: usize,
    /// Destination stride between lines, in words.
    pub dst_stride: usize,
}

impl DmaTransfer {
    pub fn linear(src_word: usize, dst_word: usize, words: usize) -> Self {
        Self {
            src_word,
            dst_word,
            line_words: words,
            lines: 1,
            src_stride: 0,
            dst_stride: 0,
        }
    }

    pub fn total_words(&self) -> usize {
        self.line_words * self.lines
    }
}

/// Timing + functional model of the cluster DMA.
#[derive(Debug, Clone)]
pub struct DmaEngine {
    /// Payload bandwidth in bytes/cycle (paper: 64-bit/cycle each
    /// direction = 8 B/cycle).
    pub bytes_per_cycle: f64,
    /// Programming + arbitration overhead per job, cycles.
    pub setup_cycles: u64,
    /// Extra overhead per 2-D line (address regeneration).
    pub per_line_cycles: u64,
}

impl Default for DmaEngine {
    fn default() -> Self {
        Self { bytes_per_cycle: 8.0, setup_cycles: 20, per_line_cycles: 2 }
    }
}

impl DmaEngine {
    /// Cycle cost of a transfer (payload + setup + line overhead).
    pub fn cycles(&self, t: &DmaTransfer) -> u64 {
        let payload =
            ((t.total_words() * 4) as f64 / self.bytes_per_cycle).ceil() as u64;
        self.setup_cycles + payload + self.per_line_cycles * t.lines as u64
    }

    /// Cycle cost for a plain byte count (convenience for the tiler).
    pub fn cycles_for_bytes(&self, bytes: u64) -> u64 {
        self.setup_cycles + (bytes as f64 / self.bytes_per_cycle).ceil() as u64
    }

    /// Functionally copy L2 -> L1.
    pub fn run_in(&self, mem: &mut Tcdm, t: &DmaTransfer) -> Result<u64> {
        self.check(t, true)?;
        for l in 0..t.lines {
            let s = t.src_word + l * t.src_stride;
            let d = t.dst_word + l * t.dst_stride;
            for k in 0..t.line_words {
                mem.l1[d + k] = mem.l2[s + k];
            }
        }
        Ok(self.cycles(t))
    }

    /// Functionally copy L1 -> L2.
    pub fn run_out(&self, mem: &mut Tcdm, t: &DmaTransfer) -> Result<u64> {
        self.check(t, false)?;
        for l in 0..t.lines {
            let s = t.src_word + l * t.src_stride;
            let d = t.dst_word + l * t.dst_stride;
            for k in 0..t.line_words {
                mem.l2[d + k] = mem.l1[s + k];
            }
        }
        Ok(self.cycles(t))
    }

    fn check(&self, t: &DmaTransfer, inbound: bool) -> Result<()> {
        let l1_words = (TCDM_SIZE / 4) as usize;
        let l2_words = (L2_SIZE / 4) as usize;
        let (src_limit, dst_limit) = if inbound {
            (l2_words, l1_words)
        } else {
            (l1_words, l2_words)
        };
        let src_end =
            t.src_word + t.src_stride * t.lines.saturating_sub(1) + t.line_words;
        let dst_end =
            t.dst_word + t.dst_stride * t.lines.saturating_sub(1) + t.line_words;
        if src_end > src_limit || dst_end > dst_limit {
            bail!(
                "dma transfer out of range: src_end {src_end}/{src_limit} \
                 dst_end {dst_end}/{dst_limit}"
            );
        }
        Ok(())
    }
}

/// Analytical model of the SOC I/O DMA + external HyperRAM (L3) interface,
/// following the paper's own approach (§IV: "off-chip memory accesses are
/// modeled using an analytical model of I/O obtained from data of a
/// previous prototype" [Vega]).
#[derive(Debug, Clone)]
pub struct IoDma {
    /// Sustained HyperRAM bandwidth, bytes per microsecond (~400 MB/s for
    /// an 8-bit DDR HyperBus at 200 MHz, as in Vega).
    pub bytes_per_us: f64,
    /// Fixed per-transfer latency, microseconds.
    pub latency_us: f64,
}

impl Default for IoDma {
    fn default() -> Self {
        Self { bytes_per_us: 400.0, latency_us: 0.3 }
    }
}

impl IoDma {
    /// Wall-clock microseconds to move `bytes` between L3 and L2.
    pub fn us_for_bytes(&self, bytes: u64) -> f64 {
        self.latency_us + bytes as f64 / self.bytes_per_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_copy_roundtrip() {
        let mut mem = Tcdm::new();
        for i in 0..64 {
            mem.l2[i] = i as u32 * 3;
        }
        let dma = DmaEngine::default();
        let t = DmaTransfer::linear(0, 100, 64);
        let cyc = dma.run_in(&mut mem, &t).unwrap();
        assert_eq!(mem.l1[100..164], mem.l2[0..64]);
        // 64 words = 256 B at 8 B/cycle = 32 + setup 20 + 2
        assert_eq!(cyc, 54);
    }

    #[test]
    fn strided_2d() {
        let mut mem = Tcdm::new();
        for i in 0..100 {
            mem.l2[i] = i as u32;
        }
        let dma = DmaEngine::default();
        // 4 lines of 8 words with src stride 16 -> packs a (4,8) tile
        let t = DmaTransfer {
            src_word: 0,
            dst_word: 0,
            line_words: 8,
            lines: 4,
            src_stride: 16,
            dst_stride: 8,
        };
        dma.run_in(&mut mem, &t).unwrap();
        for l in 0..4 {
            for k in 0..8 {
                assert_eq!(mem.l1[l * 8 + k], (l * 16 + k) as u32);
            }
        }
    }

    #[test]
    fn out_of_range_rejected() {
        let mut mem = Tcdm::new();
        let dma = DmaEngine::default();
        let t = DmaTransfer::linear(0, (TCDM_SIZE / 4) as usize, 8);
        assert!(dma.run_in(&mut mem, &t).is_err());
    }

    #[test]
    fn hyperram_bandwidth() {
        let io = IoDma::default();
        // 4 KiB at 400 B/us = ~10.24 us + 0.3
        let us = io.us_for_bytes(4096);
        assert!((us - 10.54).abs() < 0.01);
    }
}
