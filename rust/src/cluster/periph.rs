//! Memory-mapped RBE peripheral (paper §II-B4): the cluster peripheral
//! interconnect exposes the accelerator's latch-based dual-context
//! register file, so RISC-V programs configure a job with plain stores,
//! commit it, and poll/wait for the completion event — exactly the
//! offload sequence of Fig. 4's "jobs offloaded" timeline.
//!
//! The peripheral is *timing-coupled*: a committed job occupies the
//! engine for the cycles predicted by [`RbeTiming`], during which the
//! RBE-IC steals TCDM bank slots from the LIC (the engine raises the
//! background-traffic probability), and the busy/event status registers
//! reflect engine time. Functional tensor work stays with the
//! layer-level models — the cores on the chip also never see RBE
//! internals, only TCDM contents and the event.

use anyhow::{bail, Result};

use crate::rbe::{RbeJob, RbeMode, RbeTiming};

/// Peripheral base address (cluster peripheral interconnect region).
pub const RBE_PERIPH_BASE: u32 = 0x1020_0000;
/// Peripheral window size in bytes.
pub const RBE_PERIPH_SIZE: u32 = 0x100;

/// Register map (word offsets from RBE_PERIPH_BASE).
pub mod regs {
    pub const MODE: u32 = 0; // 0 = 3x3, 1 = 1x1
    pub const H_OUT: u32 = 1;
    pub const W_OUT: u32 = 2;
    pub const K_IN: u32 = 3;
    pub const K_OUT: u32 = 4;
    pub const STRIDE: u32 = 5;
    pub const W_BITS: u32 = 6;
    pub const I_BITS: u32 = 7;
    pub const O_BITS: u32 = 8;
    /// Write 1 to enqueue the configured job. Reads back the number of
    /// free job contexts.
    pub const COMMIT: u32 = 9;
    /// 1 while the engine is running or jobs are pending.
    pub const STATUS_BUSY: u32 = 10;
    /// Completed-job counter (the event-unit line, readable).
    pub const EVT_COUNT: u32 = 11;
}

/// Fraction of TCDM banks the RBE-IC occupies per cycle while streaming.
pub const RBE_BANK_OCCUPANCY: f64 = 0.30;

#[derive(Debug, Clone, Copy, Default)]
struct Shadow {
    mode: u32,
    h_out: u32,
    w_out: u32,
    k_in: u32,
    k_out: u32,
    stride: u32,
    w_bits: u32,
    i_bits: u32,
    o_bits: u32,
}

impl Shadow {
    fn to_job(self) -> Result<RbeJob> {
        let job = RbeJob {
            mode: if self.mode == 0 {
                RbeMode::Conv3x3
            } else {
                RbeMode::Conv1x1
            },
            h_out: self.h_out as usize,
            w_out: self.w_out as usize,
            k_in: self.k_in as usize,
            k_out: self.k_out as usize,
            stride: self.stride as usize,
            w_bits: self.w_bits as usize,
            i_bits: self.i_bits as usize,
            o_bits: self.o_bits as usize,
        };
        job.validate()?;
        Ok(job)
    }
}

/// The peripheral: dual-context queue + engine occupancy tracking.
#[derive(Debug, Default)]
pub struct RbePeriph {
    shadow: Shadow,
    /// Enqueued jobs (≤ 2, hardware register-file contexts).
    pending: Vec<RbeJob>,
    /// Cycles left on the currently running job (0 = idle).
    running_left: u64,
    /// Total completed jobs (event counter).
    pub completed: u64,
    /// Total cycles the engine was busy (for utilization stats).
    pub busy_cycles: u64,
}

impl RbePeriph {
    pub fn new() -> Self {
        Self::default()
    }

    /// Is `addr` inside the peripheral window?
    pub fn owns(addr: u32) -> bool {
        (RBE_PERIPH_BASE..RBE_PERIPH_BASE + RBE_PERIPH_SIZE).contains(&addr)
    }

    pub fn busy(&self) -> bool {
        self.running_left > 0 || !self.pending.is_empty()
    }

    /// Advance the engine by one cluster cycle.
    pub fn tick(&mut self) {
        if self.running_left == 0 {
            if let Some(job) = self.pending.first().copied() {
                self.pending.remove(0);
                self.running_left = RbeTiming::cycles(&job);
            }
        }
        if self.running_left > 0 {
            self.running_left -= 1;
            self.busy_cycles += 1;
            if self.running_left == 0 {
                self.completed += 1; // event to the event unit
            }
        }
    }

    /// Peripheral load (1-cycle, no TCDM arbitration).
    pub fn load(&self, addr: u32) -> Result<u32> {
        let off = (addr - RBE_PERIPH_BASE) / 4;
        Ok(match off {
            regs::MODE => self.shadow.mode,
            regs::H_OUT => self.shadow.h_out,
            regs::W_OUT => self.shadow.w_out,
            regs::K_IN => self.shadow.k_in,
            regs::K_OUT => self.shadow.k_out,
            regs::STRIDE => self.shadow.stride,
            regs::W_BITS => self.shadow.w_bits,
            regs::I_BITS => self.shadow.i_bits,
            regs::O_BITS => self.shadow.o_bits,
            regs::COMMIT => {
                let in_flight = self.pending.len()
                    + (self.running_left > 0) as usize;
                2u32.saturating_sub(in_flight as u32)
            }
            regs::STATUS_BUSY => self.busy() as u32,
            regs::EVT_COUNT => self.completed as u32,
            _ => bail!("RBE periph: read of undefined register {off}"),
        })
    }

    /// Peripheral store.
    pub fn store(&mut self, addr: u32, value: u32) -> Result<()> {
        let off = (addr - RBE_PERIPH_BASE) / 4;
        match off {
            regs::MODE => self.shadow.mode = value,
            regs::H_OUT => self.shadow.h_out = value,
            regs::W_OUT => self.shadow.w_out = value,
            regs::K_IN => self.shadow.k_in = value,
            regs::K_OUT => self.shadow.k_out = value,
            regs::STRIDE => self.shadow.stride = value,
            regs::W_BITS => self.shadow.w_bits = value,
            regs::I_BITS => self.shadow.i_bits = value,
            regs::O_BITS => self.shadow.o_bits = value,
            regs::COMMIT => {
                if value != 0 {
                    let in_flight = self.pending.len()
                        + (self.running_left > 0) as usize;
                    if in_flight >= 2 {
                        bail!(
                            "RBE periph: commit with both job contexts busy \
                             (driver must wait for the free-context event)"
                        );
                    }
                    self.pending.push(self.shadow.to_job()?);
                }
            }
            regs::STATUS_BUSY | regs::EVT_COUNT => {
                bail!("RBE periph: write to read-only register {off}")
            }
            _ => bail!("RBE periph: write to undefined register {off}"),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn program_job(p: &mut RbePeriph) {
        for (r, v) in [
            (regs::MODE, 0u32),
            (regs::H_OUT, 3),
            (regs::W_OUT, 3),
            (regs::K_IN, 32),
            (regs::K_OUT, 32),
            (regs::STRIDE, 1),
            (regs::W_BITS, 2),
            (regs::I_BITS, 2),
            (regs::O_BITS, 2),
        ] {
            p.store(RBE_PERIPH_BASE + r * 4, v).unwrap();
        }
    }

    #[test]
    fn offload_runs_for_model_cycles() {
        let mut p = RbePeriph::new();
        program_job(&mut p);
        p.store(RBE_PERIPH_BASE + regs::COMMIT * 4, 1).unwrap();
        assert!(p.busy());
        let job = RbeJob::conv3x3(3, 3, 32, 32, 1, 2, 2, 2).unwrap();
        let expect = RbeTiming::cycles(&job);
        let mut n = 0;
        while p.busy() {
            p.tick();
            n += 1;
            assert!(n < 10 * expect, "runaway");
        }
        assert_eq!(n, expect);
        assert_eq!(
            p.load(RBE_PERIPH_BASE + regs::EVT_COUNT * 4).unwrap(),
            1
        );
    }

    #[test]
    fn dual_context_third_commit_fails() {
        let mut p = RbePeriph::new();
        program_job(&mut p);
        let commit = RBE_PERIPH_BASE + regs::COMMIT * 4;
        p.store(commit, 1).unwrap();
        p.store(commit, 1).unwrap();
        assert_eq!(p.load(commit).unwrap(), 0); // no free contexts
        assert!(p.store(commit, 1).is_err());
        // drain one job; a context frees up
        p.tick(); // starts job 1
        while p.completed == 0 {
            p.tick();
        }
        assert_eq!(p.load(commit).unwrap(), 1);
        p.store(commit, 1).unwrap();
    }

    #[test]
    fn invalid_job_rejected_at_commit() {
        let mut p = RbePeriph::new();
        program_job(&mut p);
        p.store(RBE_PERIPH_BASE + regs::W_BITS * 4, 11).unwrap();
        assert!(p
            .store(RBE_PERIPH_BASE + regs::COMMIT * 4, 1)
            .is_err());
    }

    #[test]
    fn readonly_and_undefined_registers() {
        let mut p = RbePeriph::new();
        assert!(p
            .store(RBE_PERIPH_BASE + regs::STATUS_BUSY * 4, 1)
            .is_err());
        assert!(p.load(RBE_PERIPH_BASE + 0x80).is_err());
        assert!(RbePeriph::owns(RBE_PERIPH_BASE));
        assert!(!RbePeriph::owns(RBE_PERIPH_BASE + RBE_PERIPH_SIZE));
    }
}
