//! Cluster address map (paper Fig. 1 values).

/// TCDM (L1) base address.
pub const TCDM_BASE: u32 = 0x1000_0000;
/// TCDM size: 128 KiB (paper §II).
pub const TCDM_SIZE: u32 = 128 * 1024;
/// Word-interleaved TCDM banks (paper §II: 32 banks).
pub const TCDM_BANKS: usize = 32;

/// SOC L2 base address.
pub const L2_BASE: u32 = 0x1C00_0000;
/// L2 size: 1 MiB (paper: 960 KiB interleaved + 64 KiB private).
pub const L2_SIZE: u32 = 1024 * 1024;

/// Address-space classification.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemMap {
    Tcdm { word: u32, bank: usize },
    L2 { word: u32 },
}

impl MemMap {
    #[inline]
    pub fn classify(addr: u32) -> Option<MemMap> {
        if (TCDM_BASE..TCDM_BASE + TCDM_SIZE).contains(&addr) {
            let word = (addr - TCDM_BASE) >> 2;
            Some(MemMap::Tcdm {
                word,
                bank: (word as usize) % TCDM_BANKS,
            })
        } else if (L2_BASE..L2_BASE + L2_SIZE).contains(&addr) {
            Some(MemMap::L2 { word: (addr - L2_BASE) >> 2 })
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_interleaving() {
        // consecutive words land in consecutive banks
        for i in 0..64u32 {
            match MemMap::classify(TCDM_BASE + i * 4) {
                Some(MemMap::Tcdm { word, bank }) => {
                    assert_eq!(word, i);
                    assert_eq!(bank, (i as usize) % TCDM_BANKS);
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn l2_and_unmapped() {
        assert!(matches!(
            MemMap::classify(L2_BASE + 8),
            Some(MemMap::L2 { word: 2 })
        ));
        assert_eq!(MemMap::classify(0xDEAD_0000), None);
        assert_eq!(MemMap::classify(TCDM_BASE + TCDM_SIZE), None);
    }
}
