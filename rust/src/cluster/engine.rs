//! Cycle-stepped cluster execution engine: core issue, LIC bank
//! arbitration, shared-FPU arbitration, event-unit barriers.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::memmap::{MemMap, TCDM_BANKS};
use super::periph::{RbePeriph, RBE_BANK_OCCUPANCY};
use super::tcdm::Tcdm;
use crate::core::MemSpace;
use crate::core::{Core, CoreStats, ExecOutcome};
use crate::isa::Program;
use crate::util::Rng;

/// Cluster configuration. Defaults model the Marsellus CLUSTER; the SOC
/// controller is the same engine with `cores = 1`, `fpus = 1` (its FPU is
/// private) — see [`ClusterConfig::soc_controller`].
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub cores: usize,
    /// Shared FPU slots per cycle (paper: 8 FPUs for 16 cores).
    pub fpus: usize,
    /// AXI access latency to L2, in cluster cycles.
    pub l2_latency: u32,
    /// Probability that a TCDM bank is occupied by RBE/DMA traffic in a
    /// given cycle (the bank-level mux between LIC and RBE-IC rotates
    /// round-robin, so from the cores' perspective contention appears as
    /// per-bank occupancy).
    pub background_traffic: f64,
    /// Seed of the background-traffic sampler. The default keeps the
    /// historical value for reproducibility; benches that iterate under
    /// contention should vary it per iteration, or every run replays the
    /// identical bank-conflict sequence and under-reports variance.
    pub traffic_seed: u64,
    /// Safety limit on simulated cycles.
    pub max_cycles: u64,
}

/// Historical fixed seed of the background-traffic sampler.
pub const DEFAULT_TRAFFIC_SEED: u64 = 0xC0FFEE;

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            cores: 16,
            fpus: 8,
            l2_latency: 8,
            background_traffic: 0.0,
            traffic_seed: DEFAULT_TRAFFIC_SEED,
            max_cycles: 500_000_000,
        }
    }
}

impl ClusterConfig {
    /// The SOC-domain RV32IMCFXpulp controller core (paper Fig. 1): single
    /// core, private FPU, directly attached L2 (no TCDM banking benefit —
    /// modelled as one core on the same engine with zero conflicts).
    pub fn soc_controller() -> Self {
        Self { cores: 1, fpus: 1, ..Self::default() }
    }
}

/// Aggregate results of one `run`.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    /// Wall-clock cycles until every core halted.
    pub cycles: u64,
    /// Sum over cores.
    pub total: CoreStats,
    /// Per-core counters.
    pub per_core: Vec<CoreStats>,
    /// Background-traffic RNG seed the run was sampled with (reported so
    /// contention experiments can record / vary it).
    pub traffic_seed: u64,
}

impl RunStats {
    /// Total MACs * 2 (multiply + add), the paper's "operations" metric.
    pub fn ops(&self) -> u64 {
        self.total.macs * 2
    }

    pub fn flops(&self) -> u64 {
        self.total.flops
    }

    /// ops/cycle across the whole cluster.
    pub fn ops_per_cycle(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.ops() as f64 / self.cycles as f64
        }
    }

    pub fn flops_per_cycle(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.total.flops as f64 / self.cycles as f64
        }
    }

    /// Mean per-core DOTP-unit utilization (active cores only).
    pub fn dotp_utilization(&self) -> f64 {
        let active: Vec<_> = self
            .per_core
            .iter()
            .filter(|c| c.dotp_instrs > 0)
            .collect();
        if active.is_empty() {
            return 0.0;
        }
        active.iter().map(|c| c.dotp_utilization()).sum::<f64>()
            / active.len() as f64
    }
}

/// Per-cycle arbitration buffers, kept across cycles to avoid allocating
/// in the simulation hot loop (see EXPERIMENTS.md §Perf).
#[derive(Default)]
struct Scratch {
    bank_req: Vec<Vec<usize>>,
    l2_req: Vec<usize>,
    fpu_req: Vec<usize>,
    granted: Vec<usize>,
    granted_mask: Vec<bool>,
}

/// The cluster: cores + memory + arbitration state.
pub struct Cluster {
    pub cfg: ClusterConfig,
    pub cores: Vec<Core>,
    pub mem: Tcdm,
    /// The memory-mapped RBE offload peripheral (§II-B4).
    pub rbe: RbePeriph,
    /// Round-robin priority pointer for bank arbitration (rotates each
    /// cycle, as in the LIC).
    rr: usize,
    rng: Rng,
    cycles: u64,
    scratch: Scratch,
}

impl Cluster {
    pub fn new(cfg: ClusterConfig) -> Self {
        Self {
            cores: Vec::new(),
            mem: Tcdm::new(),
            rbe: RbePeriph::new(),
            rr: 0,
            rng: Rng::new(cfg.traffic_seed),
            cycles: 0,
            scratch: Scratch {
                bank_req: vec![Vec::new(); TCDM_BANKS],
                ..Scratch::default()
            },
            cfg,
        }
    }

    /// Load the same program on all cores (SPMD, the PULP model). Resets
    /// the cycle counter; TCDM/L2 contents persist across loads.
    pub fn load_spmd(&mut self, prog: Program) {
        let prog = Arc::new(prog);
        self.cores = (0..self.cfg.cores)
            .map(|id| Core::new(id, prog.clone()))
            .collect();
        self.cycles = 0;
    }

    /// Load distinct programs per core.
    pub fn load_programs(&mut self, progs: Vec<Program>) {
        assert_eq!(progs.len(), self.cfg.cores);
        self.cores = progs
            .into_iter()
            .enumerate()
            .map(|(id, p)| Core::new(id, Arc::new(p)))
            .collect();
        self.cycles = 0;
    }

    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Run until all cores halt; returns aggregated statistics.
    pub fn run(&mut self) -> Result<RunStats> {
        while !self.all_halted() {
            self.step()?;
            if self.cycles >= self.cfg.max_cycles {
                bail!("cluster exceeded max_cycles {}", self.cfg.max_cycles);
            }
        }
        let mut total = CoreStats::default();
        let per_core: Vec<CoreStats> =
            self.cores.iter().map(|c| c.stats.clone()).collect();
        for s in &per_core {
            total.merge(s);
        }
        Ok(RunStats {
            cycles: self.cycles,
            total,
            per_core,
            traffic_seed: self.cfg.traffic_seed,
        })
    }

    fn all_halted(&self) -> bool {
        self.cores.iter().all(|c| c.halted)
    }

    /// One cluster cycle.
    pub fn step(&mut self) -> Result<()> {
        self.cycles += 1;
        let n = self.cores.len();

        // Phase 1: collect intents of issue-ready cores.
        // bank_req[b] = cores requesting bank b this cycle. Buffers are
        // reused across cycles (hot loop — no allocation).
        let mut sc = std::mem::take(&mut self.scratch);
        if sc.bank_req.len() != TCDM_BANKS {
            sc.bank_req = vec![Vec::new(); TCDM_BANKS];
        }
        for b in &mut sc.bank_req {
            b.clear();
        }
        sc.l2_req.clear();
        sc.fpu_req.clear();
        sc.granted.clear();
        let bank_req = &mut sc.bank_req;
        let l2_req = &mut sc.l2_req;
        let fpu_req = &mut sc.fpu_req;
        let granted = &mut sc.granted;
        let mut any_mem = false;
        for (i, core) in self.cores.iter_mut().enumerate() {
            if core.halted || core.at_barrier {
                continue;
            }
            core.stats.cycles += 1;
            if core.stall > 0 {
                continue;
            }
            let Some(instr) = core.fetch() else { continue };
            if instr.is_mem() {
                let req = core.mem_request().unwrap();
                if RbePeriph::owns(req.addr) {
                    // peripheral interconnect: no TCDM arbitration
                    granted.push(i);
                    continue;
                }
                match MemMap::classify(req.addr) {
                    Some(MemMap::Tcdm { bank, .. }) => {
                        bank_req[bank].push(i);
                        any_mem = true;
                    }
                    Some(MemMap::L2 { .. }) => l2_req.push(i),
                    None => bail!(
                        "core {i} pc {} unmapped address {:#010x}",
                        core.pc,
                        req.addr
                    ),
                }
            } else if instr.is_fpu() {
                fpu_req.push(i);
            } else {
                granted.push(i);
            }
        }

        // Phase 2: arbitrate.
        if any_mem {
            for (bank, reqs) in bank_req.iter().enumerate() {
                if reqs.is_empty() {
                    continue;
                }
                // RBE-IC / DMA occupancy steals this bank for a cycle.
                let bg = if self.rbe.busy() {
                    self.cfg.background_traffic.max(RBE_BANK_OCCUPANCY)
                } else {
                    self.cfg.background_traffic
                };
                let stolen = bg > 0.0 && self.rng.f64() < bg;
                if stolen {
                    for &c in reqs {
                        self.cores[c].stats.stall_conflict += 1;
                    }
                    continue;
                }
                // Round-robin winner: first requester at/after the pointer.
                let winner = *reqs
                    .iter()
                    .min_by_key(|&&c| {
                        (c + TCDM_BANKS * 2 - (self.rr + bank)) % n
                    })
                    .unwrap();
                granted.push(winner);
                for &c in reqs {
                    if c != winner {
                        self.cores[c].stats.stall_conflict += 1;
                    }
                }
            }
        }
        // L2: unlimited concurrency, fixed latency (AXI pipeline depth is
        // not the bottleneck for the workloads modelled).
        for &c in l2_req.iter() {
            let lat = self.cfg.l2_latency;
            self.cores[c].stall += lat;
            self.cores[c].stats.stall_l2 += lat as u64;
            granted.push(c);
        }
        // FPU slots: rotate priority with the same pointer.
        fpu_req.sort_unstable_by_key(|&c| (c + n - self.rr % n) % n);
        for (k, &c) in fpu_req.iter().enumerate() {
            if k < self.cfg.fpus {
                granted.push(c);
            } else {
                self.cores[c].stats.stall_fpu += 1;
            }
        }

        // Phase 3: execute granted cores; decrement stalls of the rest.
        sc.granted_mask.clear();
        sc.granted_mask.resize(n, false);
        let granted_mask = &mut sc.granted_mask;
        for &c in sc.granted.iter() {
            granted_mask[c] = true;
        }
        for i in 0..n {
            let core = &mut self.cores[i];
            if core.halted || core.at_barrier {
                continue;
            }
            if core.stall > 0 {
                core.stall -= 1;
                continue;
            }
            if !granted_mask[i] {
                continue; // lost arbitration; retries next cycle
            }
            let mut space = ClusterSpace {
                mem: &mut self.mem,
                periph: &mut self.rbe,
            };
            match core.exec(&mut space)? {
                ExecOutcome::BranchTaken => {
                    core.stall += 1;
                    core.stats.stall_branch += 1;
                }
                ExecOutcome::Barrier | ExecOutcome::Halted | ExecOutcome::Done => {}
            }
        }

        // Event unit: release the barrier once every live core reached it
        // (single pass; waiting cores account a stall cycle otherwise).
        let mut live = 0u32;
        let mut waiting = 0u32;
        for c in self.cores.iter() {
            if !c.halted {
                live += 1;
                waiting += c.at_barrier as u32;
            }
        }
        if waiting > 0 {
            if waiting == live {
                for c in self.cores.iter_mut().filter(|c| !c.halted) {
                    c.at_barrier = false;
                }
            } else {
                for c in self
                    .cores
                    .iter_mut()
                    .filter(|c| !c.halted && c.at_barrier)
                {
                    c.stats.stall_barrier += 1;
                }
            }
        }

        self.rbe.tick();
        self.rr = (self.rr + 1) % TCDM_BANKS.max(n);
        self.scratch = sc;
        Ok(())
    }
}

/// The cluster-visible address space: TCDM + L2 plus the RBE peripheral
/// window, dispatched per access.
struct ClusterSpace<'a> {
    mem: &'a mut Tcdm,
    periph: &'a mut RbePeriph,
}

impl MemSpace for ClusterSpace<'_> {
    #[inline]
    fn load(&mut self, addr: u32) -> Result<u32> {
        if RbePeriph::owns(addr) {
            self.periph.load(addr)
        } else {
            self.mem.load(addr)
        }
    }

    #[inline]
    fn store(&mut self, addr: u32, value: u32) -> Result<()> {
        if RbePeriph::owns(addr) {
            self.periph.store(addr, value)
        } else {
            self.mem.store(addr, value)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::memmap::TCDM_BASE;
    use crate::isa::{AluOp, Cond, Instr, IsaLevel, ProgramBuilder};

    /// Each core stores its id into TCDM[id], then barriers, then core 0
    /// sums everything.
    #[test]
    fn spmd_store_barrier_sum() {
        let mut b = ProgramBuilder::new("spmd", IsaLevel::Xpulp);
        let done = b.label();
        b.emit(Instr::CoreId { rd: 5 });
        b.emit(Instr::Li { rd: 6, imm: TCDM_BASE as i32 });
        b.emit(Instr::AluImm { op: AluOp::Sll, rd: 7, rs1: 5, imm: 2 });
        b.emit(Instr::Alu { op: AluOp::Add, rd: 6, rs1: 6, rs2: 7 });
        b.emit(Instr::Sw { rs: 5, base: 6, offset: 0, post_inc: 0 });
        b.emit(Instr::Barrier);
        // only core 0 proceeds to sum
        b.branch(Cond::Ne, 5, 0, done);
        b.emit(Instr::Li { rd: 10, imm: TCDM_BASE as i32 });
        b.emit(Instr::Li { rd: 11, imm: 0 });
        let (s, e) = (b.label(), b.label());
        b.emit(Instr::Li { rd: 12, imm: 16 });
        b.hw_loop(0, 12, s, e);
        b.bind(s);
        b.emit(Instr::Lw { rd: 13, base: 10, offset: 0, post_inc: 4 });
        b.emit(Instr::Alu { op: AluOp::Add, rd: 11, rs1: 11, rs2: 13 });
        b.bind(e);
        b.emit(Instr::Sw {
            rs: 11,
            base: 0,
            offset: (TCDM_BASE + 64) as i32,
            post_inc: 0,
        });
        b.bind(done);
        b.emit(Instr::Nop);

        let mut cl = Cluster::new(ClusterConfig::default());
        cl.load_spmd(b.build().unwrap());
        cl.run().unwrap();
        assert_eq!(cl.mem.l1[16], (0..16).sum::<u32>());
    }

    /// All 16 cores hammering the same bank must serialize (~16x slowdown),
    /// while hitting distinct banks stays parallel.
    #[test]
    fn bank_conflicts_serialize() {
        let mk = |same_bank: bool| {
            let mut b = ProgramBuilder::new("bk", IsaLevel::Xpulp);
            b.emit(Instr::CoreId { rd: 5 });
            // address = TCDM + (same ? 0 : id*4)
            if !same_bank {
                b.emit(Instr::AluImm { op: AluOp::Sll, rd: 5, rs1: 5, imm: 2 });
            } else {
                b.emit(Instr::Li { rd: 5, imm: 0 });
            }
            b.emit(Instr::AluImm {
                op: AluOp::Add,
                rd: 6,
                rs1: 5,
                imm: TCDM_BASE as i32,
            });
            let (s, e) = (b.label(), b.label());
            b.emit(Instr::Li { rd: 7, imm: 64 });
            b.hw_loop(0, 7, s, e);
            b.bind(s);
            b.emit(Instr::Lw { rd: 8, base: 6, offset: 0, post_inc: 0 });
            b.bind(e);
            b.emit(Instr::Nop);
            b.build().unwrap()
        };
        let run = |p| {
            let mut cl = Cluster::new(ClusterConfig::default());
            cl.load_spmd(p);
            cl.run().unwrap().cycles
        };
        let fast = run(mk(false));
        let slow = run(mk(true));
        assert!(
            slow as f64 > fast as f64 * 8.0,
            "conflict run {slow} should be >> conflict-free {fast}"
        );
    }

    /// FPU arbitration: 16 cores issuing back-to-back FP ops see ~2x
    /// slowdown (8 FPUs), 8 cores see none.
    #[test]
    fn fpu_contention() {
        let mk = || {
            let mut b = ProgramBuilder::new("fpu", IsaLevel::Xpulp);
            let (s, e) = (b.label(), b.label());
            b.emit(Instr::Li { rd: 7, imm: 256 });
            b.hw_loop(0, 7, s, e);
            b.bind(s);
            b.emit(Instr::FAlu {
                op: crate::isa::FOp::Madd,
                lanes: 1,
                fd: 1,
                fs1: 2,
                fs2: 3,
                fs3: 1,
            });
            b.bind(e);
            b.emit(Instr::Nop);
            b.build().unwrap()
        };
        let run = |cores| {
            let mut cfg = ClusterConfig::default();
            cfg.cores = cores;
            let mut cl = Cluster::new(cfg);
            cl.load_spmd(mk());
            cl.run().unwrap()
        };
        let r8 = run(8);
        let r16 = run(16);
        // 8 cores: no contention. 16 cores on 8 FPUs: ~half throughput.
        let thr8 = r8.total.flops as f64 / r8.cycles as f64;
        let thr16 = r16.total.flops as f64 / r16.cycles as f64;
        assert!((thr16 / thr8 - 1.0).abs() < 0.15, "thr8={thr8} thr16={thr16}");
        assert!(r16.total.stall_fpu > 0);
    }

    /// The background-traffic sampler is seeded from the config: same
    /// seed replays the identical bank-conflict sequence, different
    /// seeds restore run-to-run variance, and the seed is reported in
    /// the stats.
    #[test]
    fn traffic_seed_controls_contention_replay() {
        let mk = || {
            let mut b = ProgramBuilder::new("ts", IsaLevel::Xpulp);
            b.emit(Instr::Li { rd: 6, imm: TCDM_BASE as i32 });
            let (s, e) = (b.label(), b.label());
            b.emit(Instr::Li { rd: 7, imm: 256 });
            b.hw_loop(0, 7, s, e);
            b.bind(s);
            b.emit(Instr::Lw { rd: 8, base: 6, offset: 0, post_inc: 0 });
            b.bind(e);
            b.emit(Instr::Nop);
            b.build().unwrap()
        };
        let run = |seed: u64| {
            let mut cfg = ClusterConfig::default();
            cfg.cores = 4;
            cfg.background_traffic = 0.5;
            cfg.traffic_seed = seed;
            let mut cl = Cluster::new(cfg);
            cl.load_spmd(mk());
            cl.run().unwrap()
        };
        let a = run(DEFAULT_TRAFFIC_SEED);
        let b = run(DEFAULT_TRAFFIC_SEED);
        assert_eq!(a.cycles, b.cycles, "same seed must replay identically");
        assert_eq!(a.traffic_seed, DEFAULT_TRAFFIC_SEED);
        // at least one different seed must produce a different conflict
        // sequence (three tries make a coincidental collision negligible)
        let varied = [1u64, 2, 3]
            .iter()
            .map(|&s| run(s))
            .collect::<Vec<_>>();
        assert!(
            varied.iter().any(|r| r.cycles != a.cycles),
            "distinct seeds never changed the contention outcome"
        );
        assert_eq!(varied[0].traffic_seed, 1);
    }

    /// Background (RBE) traffic degrades core memory throughput.
    #[test]
    fn background_traffic_slows_cores() {
        let mk = || {
            let mut b = ProgramBuilder::new("bg", IsaLevel::Xpulp);
            b.emit(Instr::CoreId { rd: 5 });
            b.emit(Instr::AluImm { op: AluOp::Sll, rd: 5, rs1: 5, imm: 2 });
            b.emit(Instr::AluImm {
                op: AluOp::Add,
                rd: 6,
                rs1: 5,
                imm: TCDM_BASE as i32,
            });
            let (s, e) = (b.label(), b.label());
            b.emit(Instr::Li { rd: 7, imm: 512 });
            b.hw_loop(0, 7, s, e);
            b.bind(s);
            b.emit(Instr::Lw { rd: 8, base: 6, offset: 0, post_inc: 0 });
            b.bind(e);
            b.emit(Instr::Nop);
            b.build().unwrap()
        };
        let run = |bg| {
            let mut cfg = ClusterConfig::default();
            cfg.background_traffic = bg;
            let mut cl = Cluster::new(cfg);
            cl.load_spmd(mk());
            cl.run().unwrap().cycles
        };
        let free = run(0.0);
        let busy = run(0.5);
        assert!(busy as f64 > free as f64 * 1.5, "free={free} busy={busy}");
    }
}
