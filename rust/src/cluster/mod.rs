//! The Marsellus CLUSTER (paper §II, Fig. 1): 16 RV32IMFCXpulpnn cores, a
//! 32-bank word-interleaved 128 KiB TCDM behind the logarithmic
//! interconnect (LIC), 8 shared FPUs, the event unit (barriers), and the
//! cluster DMA.
//!
//! Execution is cycle-stepped: every cycle the engine collects the memory
//! and FPU intents of all ready cores, arbitrates TCDM banks (round-robin,
//! starvation-free — the paper's LIC) and FPU slots, then executes granted
//! cores. RBE traffic rides the separate RBE-IC branch and is modelled as
//! a per-bank background-occupancy probability while the accelerator runs
//! (`set_background_traffic`).

mod dma;
mod engine;
mod memmap;
pub mod periph;
mod tcdm;

pub use dma::{DmaEngine, DmaTransfer, IoDma};
pub use engine::{Cluster, ClusterConfig, RunStats, DEFAULT_TRAFFIC_SEED};
pub use memmap::{MemMap, L2_BASE, L2_SIZE, TCDM_BANKS, TCDM_BASE, TCDM_SIZE};
pub use periph::{RbePeriph, RBE_PERIPH_BASE};
pub use tcdm::Tcdm;
