//! TCDM storage + the combined word-addressed memory space (TCDM + L2).

use anyhow::{bail, Result};

use super::memmap::{MemMap, L2_SIZE, TCDM_SIZE};
use crate::core::MemSpace;

/// Backing storage for the cluster-visible address space. Functional only;
/// timing (bank conflicts, L2 latency) is handled by the engine.
pub struct Tcdm {
    pub l1: Vec<u32>,
    pub l2: Vec<u32>,
}

impl Default for Tcdm {
    fn default() -> Self {
        Self::new()
    }
}

impl Tcdm {
    pub fn new() -> Self {
        Self {
            l1: vec![0; (TCDM_SIZE / 4) as usize],
            l2: vec![0; (L2_SIZE / 4) as usize],
        }
    }

    /// Write a slice of words into TCDM at a word offset.
    pub fn write_l1(&mut self, word_off: usize, data: &[u32]) {
        self.l1[word_off..word_off + data.len()].copy_from_slice(data);
    }

    /// Read words out of TCDM.
    pub fn read_l1(&self, word_off: usize, len: usize) -> &[u32] {
        &self.l1[word_off..word_off + len]
    }

    /// Write a slice of words into L2 at a word offset.
    pub fn write_l2(&mut self, word_off: usize, data: &[u32]) {
        self.l2[word_off..word_off + data.len()].copy_from_slice(data);
    }

    pub fn read_l2(&self, word_off: usize, len: usize) -> &[u32] {
        &self.l2[word_off..word_off + len]
    }
}

impl MemSpace for Tcdm {
    #[inline]
    fn load(&mut self, addr: u32) -> Result<u32> {
        match MemMap::classify(addr) {
            Some(MemMap::Tcdm { word, .. }) => Ok(self.l1[word as usize]),
            Some(MemMap::L2 { word }) => Ok(self.l2[word as usize]),
            None => bail!("load from unmapped address {addr:#010x}"),
        }
    }

    #[inline]
    fn store(&mut self, addr: u32, value: u32) -> Result<()> {
        match MemMap::classify(addr) {
            Some(MemMap::Tcdm { word, .. }) => {
                self.l1[word as usize] = value;
                Ok(())
            }
            Some(MemMap::L2 { word }) => {
                self.l2[word as usize] = value;
                Ok(())
            }
            None => bail!("store to unmapped address {addr:#010x}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::memmap::{L2_BASE, TCDM_BASE};

    #[test]
    fn load_store_roundtrip() {
        let mut m = Tcdm::new();
        m.store(TCDM_BASE + 4, 0xABCD).unwrap();
        m.store(L2_BASE + 8, 0x1234).unwrap();
        assert_eq!(m.load(TCDM_BASE + 4).unwrap(), 0xABCD);
        assert_eq!(m.load(L2_BASE + 8).unwrap(), 0x1234);
    }

    #[test]
    fn unmapped_faults() {
        let mut m = Tcdm::new();
        assert!(m.load(0x0).is_err());
        assert!(m.store(0xFFFF_0000, 1).is_err());
    }
}
