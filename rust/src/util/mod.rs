//! Small in-tree utilities replacing external crates that are not vendored
//! in the build environment: a deterministic PRNG (for property-style
//! tests), a TSV table reader (artifact manifest contract), and a tiny
//! argument parser used by the CLI and examples.

mod args;
mod rng;
mod tsv;

pub use args::Args;
pub use rng::Rng;
pub use tsv::TsvTable;
