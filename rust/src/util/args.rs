//! Tiny `--flag value` argument parser for the CLI and examples.

use std::collections::HashMap;

use anyhow::{bail, Result};

/// Parsed command line: free-standing positionals plus `--key value` /
/// `--switch` options.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    opts: HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Self> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = match it.peek() {
                    Some(nxt) if !nxt.starts_with("--") => it.next().unwrap(),
                    _ => "true".to_string(),
                };
                if out.opts.insert(key.to_string(), val).is_some() {
                    bail!("duplicate option --{key}");
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.get(key).is_some()
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key}: {s:?} is not a number")),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key}: {s:?} is not an integer")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn positional_and_options() {
        let a = parse("figure fig13 --vdd 0.8 --verbose");
        assert_eq!(a.positional, vec!["figure", "fig13"]);
        assert_eq!(a.get("vdd"), Some("0.8"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_accessors() {
        let a = parse("--n 42 --x 0.5");
        assert_eq!(a.get_usize("n", 0).unwrap(), 42);
        assert_eq!(a.get_f64("x", 0.0).unwrap(), 0.5);
        assert_eq!(a.get_f64("missing", 1.25).unwrap(), 1.25);
    }

    #[test]
    fn duplicate_rejected() {
        assert!(Args::parse(["--a".into(), "1".into(), "--a".into(), "2".into()]).is_err());
    }
}
