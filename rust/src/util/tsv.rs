//! Minimal TSV table reader for `artifacts/manifest.tsv` (the contract
//! between `python/compile/aot.py` and the rust runtime — see aot.py).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// A parsed TSV file with a header row; rows are accessed by column name.
#[derive(Debug, Clone)]
pub struct TsvTable {
    header: Vec<String>,
    col: HashMap<String, usize>,
    rows: Vec<Vec<String>>,
}

impl TsvTable {
    pub fn parse(text: &str) -> Result<Self> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header: Vec<String> = lines
            .next()
            .context("empty tsv")?
            .split('\t')
            .map(|s| s.trim().to_string())
            .collect();
        let col = header
            .iter()
            .enumerate()
            .map(|(i, h)| (h.clone(), i))
            .collect();
        let mut rows = Vec::new();
        for (n, line) in lines.enumerate() {
            let row: Vec<String> =
                line.split('\t').map(|s| s.trim().to_string()).collect();
            if row.len() != header.len() {
                bail!("tsv row {} has {} fields, header has {}", n + 2,
                      row.len(), header.len());
            }
            rows.push(row);
        }
        Ok(Self { header, col, rows })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn header(&self) -> &[String] {
        &self.header
    }

    pub fn get(&self, row: usize, col: &str) -> Result<&str> {
        let c = *self
            .col
            .get(col)
            .with_context(|| format!("no column {col:?}"))?;
        Ok(self.rows[row][c].as_str())
    }

    pub fn get_usize(&self, row: usize, col: &str) -> Result<usize> {
        let s = self.get(row, col)?;
        s.parse()
            .with_context(|| format!("column {col:?} row {row}: {s:?} not an integer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let t = TsvTable::parse("a\tb\n1\tx\n2\ty\n").unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!(t.get(0, "a").unwrap(), "1");
        assert_eq!(t.get(1, "b").unwrap(), "y");
        assert_eq!(t.get_usize(1, "a").unwrap(), 2);
    }

    #[test]
    fn ragged_rows_rejected() {
        assert!(TsvTable::parse("a\tb\n1\n").is_err());
    }

    #[test]
    fn missing_column_is_error() {
        let t = TsvTable::parse("a\n1\n").unwrap();
        assert!(t.get(0, "zzz").is_err());
    }
}
