//! xoshiro256** PRNG — deterministic, seedable, dependency-free.
//!
//! Used by tests (property-style sweeps), workload generators and the ABB
//! pre-error sampler. Not cryptographic.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            z = z.wrapping_add(0x9E3779B97F4A7C15);
            let mut x = z;
            x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
            x ^ (x >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [lo, hi) (hi exclusive, lo < hi).
    pub fn range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        debug_assert!(lo < hi);
        let span = (hi as i64 - lo as i64) as u64;
        (lo as i64 + (self.next_u64() % span) as i64) as i32
    }

    /// Uniform usize in [0, n).
    pub fn index(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let v = r.range_i32(-8, 8);
            assert!((-8..8).contains(&v));
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(2);
        let mut acc = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            acc += v;
        }
        // Mean of U(0,1) ~ 0.5.
        assert!((acc / 10_000.0 - 0.5).abs() < 0.02);
    }
}
