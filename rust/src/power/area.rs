//! Area model (paper Figs. 6–8, Table II): static breakdowns of the
//! fabricated CLUSTER, used by the `figure fig7`/`fig8` harness and the
//! area-efficiency rows of Table II.

/// Total die area (mm²), including IPs out of scope.
pub const DIE_AREA_MM2: f64 = 18.7;
/// CLUSTER area (mm²) — the denominator of all area-efficiency numbers.
pub const CLUSTER_AREA_MM2: f64 = 2.42;
/// RBE post-synthesis complexity (kGE).
pub const RBE_KGE: f64 = 652.0;
/// One XpulpNN core (kGE), +17.5% over baseline RI5CY (paper §II-A2).
pub const CORE_KGE: f64 = 78.0;
/// ABB generator area (mm², paper §II-C).
pub const ABB_GEN_AREA_MM2: f64 = 0.039;

/// One named slice of an area breakdown.
#[derive(Debug, Clone)]
pub struct AreaItem {
    pub name: &'static str,
    /// Percentage of the parent total.
    pub pct: f64,
}

/// Fig. 7: CLUSTER area distribution. The paper states the 16 cores +
/// shared I$ take "almost half" and RBE "one fifth"; the remaining split
/// follows the figure.
pub fn cluster_area_breakdown() -> Vec<AreaItem> {
    vec![
        AreaItem { name: "RISC-V cores + I$", pct: 47.0 },
        AreaItem { name: "RBE", pct: 20.0 },
        AreaItem { name: "TCDM SRAM banks", pct: 21.0 },
        AreaItem { name: "interconnect (LIC + RBE-IC)", pct: 6.0 },
        AreaItem { name: "shared FPUs", pct: 3.5 },
        AreaItem { name: "DMA + event unit + periph", pct: 2.5 },
    ]
}

/// Fig. 8: RBE post-synthesis breakdown (652 kGE total, datapath 92.7%).
pub fn rbe_area_breakdown() -> Vec<AreaItem> {
    vec![
        AreaItem { name: "datapath (engine)", pct: 92.7 },
        AreaItem { name: "streamer", pct: 4.3 },
        AreaItem { name: "controller (FSM + uloop + regfile)", pct: 3.0 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdowns_sum_to_100() {
        for b in [cluster_area_breakdown(), rbe_area_breakdown()] {
            let s: f64 = b.iter().map(|i| i.pct).sum();
            assert!((s - 100.0).abs() < 0.5, "sum {s}");
        }
    }

    #[test]
    fn paper_statements_hold() {
        let b = cluster_area_breakdown();
        assert!(b[0].pct > 40.0 && b[0].pct < 50.0); // "almost half"
        assert!((b[1].pct - 20.0).abs() < 1.0); // "one fifth"
        let r = rbe_area_breakdown();
        assert!((r[0].pct - 92.7).abs() < 0.1);
        // datapath kGE = 605 per the paper
        assert!(((RBE_KGE * r[0].pct / 100.0) - 605.0).abs() < 2.0);
    }
}
