//! Power model: P = C_eff(workload) · V² · f + P_leak(V, FBB).
//!
//! Calibration (DESIGN.md §Calibration):
//! * Fig. 9 anchor: 123 mW total at 0.8 V / 420 MHz on the INT8 MAC&LOAD
//!   matmul, 94.6% dynamic / 5.4% leakage ⇒ L₀ = 6.64 mW;
//! * dynamic scaling check: (0.5/0.8)²·(100/420) = 1/10.75 — the paper
//!   measures 10.7× dynamic reduction ✓;
//! * leakage: 3.5× reduction from 0.8 V to 0.5 V ⇒ exponential slope
//!   λ = 0.3/ln(3.5) = 0.2395 V;
//! * FBB leakage penalty: m(FBB) = exp(V_FBB/σ); σ set so the 0.65 V +
//!   full-FBB point lands on the paper's −30%-of-nominal total power
//!   (Fig. 10) ⇒ m(0.9 V) ≈ 2.6, σ = 0.9419 V;
//! * per-workload C_eff back-solved from Fig. 15's measured
//!   (performance, efficiency) pairs — see [`Workload::ceff_nf`].

use super::vf::OperatingPoint;

/// Leakage at 0.8 V, no FBB (5.4% of the Fig. 9 123 mW anchor).
pub const LEAK_MW_AT_NOM: f64 = 6.64;
/// Exponential leakage slope vs V_DD.
pub const LEAK_LAMBDA_V: f64 = 0.2395;
/// Exponential leakage slope vs V_FBB.
pub const LEAK_SIGMA_V: f64 = 0.9419;

/// Cluster workload classes with calibrated effective switched
/// capacitance (nF, whole-CLUSTER including interconnect and memories).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Workload {
    /// Parallel INT8 matmul, baseline Xpulp kernel (Fig. 15 "MMUL"):
    /// 25.45 Gop/s @ 250 Gop/s/W at nominal ⇒ dyn 95.2 mW.
    MatmulXpulp8,
    /// MAC&LOAD matmul, any precision (Fig. 9 anchor kernel): the NN-RF
    /// keeps the DOTP unit at ~94% utilization, raising switched
    /// capacitance. Consistent across 8/4/2-bit per Fig. 15 (+51% eff at
    /// +67% perf ⇒ dyn ≈ 106 mW).
    MatmulMacLoad,
    /// 16-core FP32 DSP (FFT): FPU-bound; 36 GFLOPS/W @ 0.5 V anchor.
    FftFp32,
    /// Low-intensity data marshaling (Fig. 11 middle phase).
    Marshaling,
    /// RBE running with the cores idle/clock-gated. The effective C
    /// depends on BinConv duty (how many AND arrays toggle): calibrated
    /// at duty=1 from the 8×8-bit point (740 Gop/s/W @ 91 Gop/s) and at
    /// duty=0.5 from the 2×2-bit point (5.37 Top/s/W @ 569 Gop/s).
    Rbe { duty_pct: u8 },
    /// Clock-gated idle cluster.
    Idle,
}

impl Workload {
    /// Effective switched capacitance in nF.
    pub fn ceff_nf(&self) -> f64 {
        match self {
            Workload::MatmulXpulp8 => 0.354,
            Workload::MatmulMacLoad => 0.394,
            Workload::FftFp32 => 0.445,
            Workload::Marshaling => 0.20,
            Workload::Rbe { duty_pct } => {
                0.305 + 0.128 * (*duty_pct as f64 / 100.0)
            }
            Workload::Idle => 0.045,
        }
    }
}

/// The cluster power model.
#[derive(Debug, Clone, Default)]
pub struct PowerModel;

impl PowerModel {
    /// Dynamic power in mW. Units: nF · V² · MHz = 10⁻⁹·10⁶ W = mW, so
    /// the numeric product is already milliwatts (0.394 · 0.8² · 420 ≈
    /// 106 mW for the MAC&LOAD matmul).
    pub fn dynamic_mw(&self, w: Workload, op: &OperatingPoint) -> f64 {
        w.ceff_nf() * op.vdd * op.vdd * op.freq_mhz
    }

    /// Leakage power in mW.
    pub fn leakage_mw(&self, op: &OperatingPoint) -> f64 {
        LEAK_MW_AT_NOM
            * ((op.vdd - 0.8) / LEAK_LAMBDA_V).exp()
            * (op.fbb_v / LEAK_SIGMA_V).exp()
    }

    /// Total cluster power in mW.
    pub fn total_mw(&self, w: Workload, op: &OperatingPoint) -> f64 {
        self.dynamic_mw(w, op) + self.leakage_mw(op)
    }

    /// Energy in microjoules for `cycles` at the operating point.
    pub fn energy_uj(&self, w: Workload, op: &OperatingPoint, cycles: u64)
        -> f64 {
        let seconds = cycles as f64 / (op.freq_mhz * 1.0e6);
        self.total_mw(w, op) * 1.0e-3 * seconds * 1.0e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::power::vf::{fmax_mhz, FBB_MAX_V};

    fn op(vdd: f64, f: f64, fbb: f64) -> OperatingPoint {
        OperatingPoint { vdd, freq_mhz: f, fbb_v: fbb }
    }

    /// Fig. 9 anchor: INT8 MAC&LOAD matmul ~123 mW at 0.8 V / 420 MHz
    /// (we land within the paper's own Fig. 9 / Fig. 15 spread, ±15%).
    #[test]
    fn nominal_power_anchor() {
        let m = PowerModel;
        let p = m.total_mw(Workload::MatmulMacLoad, &op(0.8, 420.0, 0.0));
        assert!((p - 123.0).abs() / 123.0 < 0.15, "P = {p} mW");
    }

    /// Fig. 9: dynamic power drops 10.7×, leakage 3.5×, from 0.8 V/420 MHz
    /// to 0.5 V/100 MHz.
    #[test]
    fn voltage_scaling_ratios() {
        let m = PowerModel;
        let hi = op(0.8, 420.0, 0.0);
        let lo = op(0.5, 100.0, 0.0);
        let dyn_ratio = m.dynamic_mw(Workload::MatmulMacLoad, &hi)
            / m.dynamic_mw(Workload::MatmulMacLoad, &lo);
        let leak_ratio = m.leakage_mw(&hi) / m.leakage_mw(&lo);
        assert!((dyn_ratio - 10.7).abs() < 0.2, "dyn {dyn_ratio}");
        assert!((leak_ratio - 3.5).abs() < 0.1, "leak {leak_ratio}");
    }

    /// Fig. 10: at a fixed 400 MHz, dropping to 0.65 V with full FBB saves
    /// ~30% vs the 0.8 V nominal point and ~16% vs 0.74 V.
    #[test]
    fn abb_power_saving() {
        let m = PowerModel;
        let w = Workload::MatmulMacLoad;
        let p_nom = m.total_mw(w, &op(0.8, 400.0, 0.0));
        let p_074 = m.total_mw(w, &op(0.74, 400.0, 0.0));
        let p_abb = m.total_mw(w, &op(0.65, 400.0, FBB_MAX_V));
        let vs_nom = 1.0 - p_abb / p_nom;
        let vs_074 = 1.0 - p_abb / p_074;
        assert!((vs_nom - 0.30).abs() < 0.05, "vs nominal {vs_nom}");
        assert!((vs_074 - 0.16).abs() < 0.05, "vs 0.74V {vs_074}");
    }

    /// Fig. 15 MMUL baseline anchors: 250 Gop/s/W @ 25.45 Gop/s nominal;
    /// ~580 Gop/s/W @ 6.06 Gop/s at 0.5 V.
    #[test]
    fn mmul_efficiency_curve() {
        let m = PowerModel;
        let w = Workload::MatmulXpulp8;
        let p_hi = m.total_mw(w, &op(0.8, 420.0, 0.0));
        let eff_hi = 25.45 / (p_hi * 1e-3);
        assert!((eff_hi - 250.0).abs() / 250.0 < 0.05, "eff {eff_hi}");
        let p_lo = m.total_mw(w, &op(0.5, 100.0, 0.0));
        let eff_lo = 25.45 * (100.0 / 420.0) / (p_lo * 1e-3);
        assert!((eff_lo - 580.0).abs() / 580.0 < 0.06, "eff@0.5 {eff_lo}");
    }

    /// Fig. 15 RBE anchors: 8×8 → ~740 Gop/s/W at 91 Gop/s; 2×2 →
    /// ~5.37 Top/s/W at 569 Gop/s (nominal), 12.36 Top/s/W at 0.5 V.
    #[test]
    fn rbe_efficiency_anchors() {
        let m = PowerModel;
        let p88 = m.total_mw(Workload::Rbe { duty_pct: 100 },
                             &op(0.8, 420.0, 0.0));
        let eff88 = 91.0 / (p88 * 1e-3);
        assert!((eff88 - 740.0).abs() / 740.0 < 0.10, "8x8 {eff88}");
        let p22 = m.total_mw(Workload::Rbe { duty_pct: 50 },
                             &op(0.8, 420.0, 0.0));
        let eff22 = 569.0 / (p22 * 1e-3);
        assert!((eff22 / 1000.0 - 5.37).abs() / 5.37 < 0.10, "2x2 {eff22}");
        let p22lo = m.total_mw(Workload::Rbe { duty_pct: 50 },
                               &op(0.5, 100.0, 0.0));
        let eff22lo = 569.0 * (100.0 / 420.0) / (p22lo * 1e-3);
        assert!((eff22lo / 1000.0 - 12.36).abs() / 12.36 < 0.12,
                "2x2@0.5 {eff22lo}");
    }

    /// fmax sanity tie-in: power at the Fig. 9 sweep endpoints uses the
    /// measured frequencies.
    #[test]
    fn energy_accounting() {
        let m = PowerModel;
        let o = op(0.5, fmax_mhz(0.5, 0.0), 0.0);
        // 1 M cycles at 100 MHz = 10 ms at ~10.7 mW ≈ 107 uJ
        let e = m.energy_uj(Workload::MatmulMacLoad, &o, 1_000_000);
        assert!((e - 107.0).abs() < 15.0, "e = {e}");
    }
}
