//! Maximum-frequency model f_max(V_DD, V_FBB).
//!
//! The silicon measurement (Fig. 9) gives f_max at the sweep endpoints:
//! 420 MHz @ 0.8 V down to 100 MHz @ 0.5 V; Fig. 10 adds that 400 MHz is
//! sustained without ABB down to exactly 0.74 V. We interpolate a
//! monotone piecewise-cubic (PCHIP) through those measured anchors — the
//! same thing the paper's plotted curve is — with alpha-power-law shaped
//! intermediate points, and model forward body bias as an effective-voltage
//! shift: raising V_FBB lowers V_th, which to first order behaves like
//! extra headroom ΔV_eff = γ·V_FBB (γ = 0.1, so the full 0.9 V FBB range
//! buys 90 mV — exactly what lets 0.65 V + ABB hold the 400 MHz signoff
//! frequency, Fig. 10).

/// Signoff frequency of the CLUSTER at 0.8 V (paper §III-A).
pub const SIGNOFF_FREQ_MHZ: f64 = 400.0;
/// Nominal supply.
pub const VDD_NOM: f64 = 0.80;
/// Sweep bounds (Fig. 9).
pub const VDD_MIN: f64 = 0.50;
pub const VDD_MAX: f64 = 0.80;
/// Maximum forward-body-bias voltage of the ABB generator.
pub const FBB_MAX_V: f64 = 0.90;
/// Effective-voltage gain of FBB: ΔV_eff = γ · V_FBB.
pub const FBB_GAMMA: f64 = 0.10;

/// Measured/fitted anchors (V_eff, MHz). Points ≤ 0.8 V follow Fig. 9/10;
/// points above 0.8 V extend the curve into FBB-boosted territory
/// (calibrated so 0.8 V + full FBB reaches the paper's 470 MHz
/// overclocked operation, Fig. 11).
const ANCHORS: &[(f64, f64)] = &[
    (0.50, 100.0),
    (0.575, 168.0),
    (0.65, 250.0),
    (0.74, 400.0),
    (0.80, 420.0),
    (0.86, 452.0),
    (0.92, 490.0),
];

/// Monotone cubic (Fritsch–Carlson PCHIP) interpolation through ANCHORS;
/// clamps outside the table.
pub fn fmax_at_veff(veff: f64) -> f64 {
    let n = ANCHORS.len();
    if veff <= ANCHORS[0].0 {
        return ANCHORS[0].1;
    }
    if veff >= ANCHORS[n - 1].0 {
        return ANCHORS[n - 1].1;
    }
    // interval slopes
    let mut h = vec![0.0; n - 1];
    let mut d = vec![0.0; n - 1];
    for i in 0..n - 1 {
        h[i] = ANCHORS[i + 1].0 - ANCHORS[i].0;
        d[i] = (ANCHORS[i + 1].1 - ANCHORS[i].1) / h[i];
    }
    // Fritsch–Carlson tangents
    let mut m = vec![0.0; n];
    m[0] = d[0];
    m[n - 1] = d[n - 2];
    for i in 1..n - 1 {
        m[i] = if d[i - 1] * d[i] <= 0.0 {
            0.0
        } else {
            let (w1, w2) = (2.0 * h[i] + h[i - 1], h[i] + 2.0 * h[i - 1]);
            (w1 + w2) / (w1 / d[i - 1] + w2 / d[i])
        };
    }
    // locate interval
    let mut k = 0;
    while ANCHORS[k + 1].0 < veff {
        k += 1;
    }
    let t = (veff - ANCHORS[k].0) / h[k];
    let (y0, y1) = (ANCHORS[k].1, ANCHORS[k + 1].1);
    let (h00, h10) = (
        (1.0 + 2.0 * t) * (1.0 - t) * (1.0 - t),
        t * (1.0 - t) * (1.0 - t),
    );
    let (h01, h11) = ((3.0 - 2.0 * t) * t * t, t * t * (t - 1.0));
    h00 * y0 + h10 * h[k] * m[k] + h01 * y1 + h11 * h[k] * m[k + 1]
}

/// Maximum frequency at a supply voltage and forward-body-bias setting.
pub fn fmax_mhz(vdd: f64, fbb_v: f64) -> f64 {
    fmax_at_veff(vdd + FBB_GAMMA * fbb_v.clamp(0.0, FBB_MAX_V))
}

/// One (V, f, FBB) operating point of the cluster.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OperatingPoint {
    pub vdd: f64,
    pub freq_mhz: f64,
    pub fbb_v: f64,
}

impl OperatingPoint {
    /// The nominal 0.8 V point at the silicon's measured f_max.
    pub fn nominal() -> Self {
        Self { vdd: VDD_NOM, freq_mhz: fmax_mhz(VDD_NOM, 0.0), fbb_v: 0.0 }
    }

    /// Max-frequency point at a given supply (no ABB).
    pub fn at_vdd(vdd: f64) -> Self {
        Self { vdd, freq_mhz: fmax_mhz(vdd, 0.0), fbb_v: 0.0 }
    }

    /// Does this point meet timing (f <= f_max(V, FBB))?
    pub fn is_timing_clean(&self) -> bool {
        self.freq_mhz <= fmax_mhz(self.vdd, self.fbb_v) + 1e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchors_reproduced() {
        assert!((fmax_mhz(0.8, 0.0) - 420.0).abs() < 1.0);
        assert!((fmax_mhz(0.5, 0.0) - 100.0).abs() < 1.0);
        assert!((fmax_mhz(0.74, 0.0) - 400.0).abs() < 1.0);
    }

    #[test]
    fn monotone_in_vdd() {
        let mut prev = 0.0;
        let mut v = 0.48;
        while v < 0.95 {
            let f = fmax_mhz(v, 0.0);
            assert!(f >= prev - 1e-9, "non-monotone at {v}");
            prev = f;
            v += 0.005;
        }
    }

    /// Fig. 10: 400 MHz fails below 0.74 V without ABB, but holds at
    /// 0.65 V with full FBB.
    #[test]
    fn abb_rescues_400mhz_at_0v65() {
        assert!(fmax_mhz(0.73, 0.0) < 400.0);
        assert!(fmax_mhz(0.74, 0.0) >= 399.9);
        assert!(fmax_mhz(0.65, FBB_MAX_V) >= 399.9);
        assert!(fmax_mhz(0.65, 0.0) < 300.0);
    }

    /// Fig. 11: 470 MHz overclock at 0.8 V is reachable only with FBB.
    #[test]
    fn overclock_needs_fbb() {
        assert!(fmax_mhz(0.8, 0.0) < 470.0);
        assert!(fmax_mhz(0.8, FBB_MAX_V) >= 470.0);
    }

    /// ABB buys ~17.5%+ frequency at nominal voltage (paper: 470 vs 400).
    #[test]
    fn boost_magnitude() {
        let boost = fmax_mhz(0.8, FBB_MAX_V) / SIGNOFF_FREQ_MHZ;
        assert!(boost >= 1.17, "boost {boost}");
    }

    #[test]
    fn timing_clean_check() {
        assert!(OperatingPoint::nominal().is_timing_clean());
        let op = OperatingPoint { vdd: 0.7, freq_mhz: 400.0, fbb_v: 0.0 };
        assert!(!op.is_timing_clean());
        let op = OperatingPoint { vdd: 0.7, freq_mhz: 400.0, fbb_v: 0.9 };
        assert!(op.is_timing_clean());
    }
}
