//! Voltage / frequency / power / area models of the Marsellus CLUSTER,
//! calibrated against the paper's measured anchor points (§III-A Fig. 9,
//! §III-C Fig. 15, Figs. 7–8). See DESIGN.md §Calibration.

mod area;
mod energy;
mod vf;

pub use area::{cluster_area_breakdown, rbe_area_breakdown, AreaItem,
               CLUSTER_AREA_MM2, DIE_AREA_MM2, RBE_KGE};
pub use energy::{PowerModel, Workload};
pub use vf::{fmax_mhz, OperatingPoint, FBB_MAX_V, SIGNOFF_FREQ_MHZ,
             VDD_MAX, VDD_MIN, VDD_NOM};
