//! The network registry: every built-in network the deployment API can
//! serve, keyed by a stable string id.
//!
//! This generalizes the old hard-wired `resnet20_layers` call sites: a
//! [`NetworkSpec`] names a registry entry plus a [`PrecisionConfig`] and
//! a weight seed, and `Coordinator::deploy` resolves it *once* into a
//! served `Deployment` handle. Adding a network to the zoo is one table
//! row here — the manifest, the native backend and the plan compiler all
//! derive their entries from the registry
//! ([`crate::dnn::Manifest::builtin`]).

use std::fmt;

use anyhow::{anyhow, Result};

use super::layer::{shift_for, Layer, LayerOp, PrecisionConfig};
use super::resnet::{resnet18_layers_cfg, resnet20_layers};

/// One registered network: id, provenance note, and the layer builder.
pub struct NetworkDef {
    pub id: &'static str,
    pub description: &'static str,
    builder: fn(PrecisionConfig) -> Vec<Layer>,
}

impl NetworkDef {
    /// Build the layer schedule under a precision configuration.
    pub fn layers(&self, config: PrecisionConfig) -> Vec<Layer> {
        (self.builder)(config)
    }
}

/// All built-in networks, in registry order.
pub const NETWORKS: &[NetworkDef] = &[
    NetworkDef {
        id: "resnet20",
        description: "ResNet-20/CIFAR-10 (paper Figs. 17-18)",
        builder: resnet20_layers,
    },
    NetworkDef {
        id: "resnet18",
        description: "ResNet-18/ImageNet, folded 7x7 stem (Table II)",
        builder: resnet18_layers_cfg,
    },
    NetworkDef {
        id: "kws",
        description: "keyword-spotting CNN with a signed (no-ReLU) \
                      logits head",
        builder: kws_layers,
    },
];

/// Registry ids, in registry order.
pub fn network_ids() -> Vec<&'static str> {
    NETWORKS.iter().map(|n| n.id).collect()
}

/// Look a network up by id; the error names every known id.
pub fn network(id: &str) -> Result<&'static NetworkDef> {
    NETWORKS.iter().find(|n| n.id == id).ok_or_else(|| {
        anyhow!(
            "unknown network {id:?} (known: {})",
            network_ids().join(", ")
        )
    })
}

/// A deployable network identity: registry id + precision configuration
/// + weight seed. This is the plan-cache key — two specs differing in
/// any field are distinct deployments with distinct compiled plans.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct NetworkSpec {
    pub network: String,
    pub config: PrecisionConfig,
    pub seed: u64,
}

impl NetworkSpec {
    pub fn new(
        network: impl Into<String>,
        config: PrecisionConfig,
        seed: u64,
    ) -> Self {
        Self { network: network.into(), config, seed }
    }

    /// Resolve the layer schedule this spec deploys.
    pub fn layers(&self) -> Result<Vec<Layer>> {
        Ok(network(&self.network)?.layers(self.config))
    }
}

impl fmt::Display for NetworkSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}/{}/seed{}", self.network, self.config.as_str(), self.seed)
    }
}

/// A small keyword-spotting-style CNN whose head is a *signed*
/// (no-ReLU) linear layer — the zoo network that exercises
/// `NormQuant::apply_signed` end to end (ROADMAP "signed-output layers"
/// item). Body layers stay ReLU/unsigned like the rest of the zoo; only
/// the logits keep their sign, clipped to the two's-complement 8-bit
/// range.
pub fn kws_layers(config: PrecisionConfig) -> Vec<Layer> {
    // (w_bits, i_bits, o_bits) per stage, mirroring the HAWQ palette
    // style of `bits_of`.
    let (stem, body, head) = match config {
        PrecisionConfig::Uniform8 => ((8, 8, 8), (8, 8, 8), (8, 8)),
        PrecisionConfig::Mixed => ((8, 8, 4), (4, 4, 4), (4, 4)),
    };
    let conv = |name: &str, h, cin, cout, stride, b: (usize, usize, usize)| {
        Layer {
            op: LayerOp::Conv3x3,
            name: name.to_string(),
            h,
            cin,
            cout,
            stride,
            w_bits: b.0,
            i_bits: b.1,
            o_bits: b.2,
            shift: shift_for(cin, b.0, b.1, b.2, 9),
            residual_of: None,
        }
    };
    vec![
        // 16x16x8 input patch (8 MFCC-style channels)
        conv("stem", 16, 8, 16, 1, stem),
        conv("body", 16, 16, 16, 2, body),
        Layer {
            op: LayerOp::AvgPool,
            name: "avgpool".into(),
            h: 8,
            cin: 16,
            cout: 16,
            stride: 1,
            w_bits: 8,
            i_bits: 8,
            o_bits: 8,
            shift: 6, // 8x8 = 64 pixels
            residual_of: None,
        },
        Layer {
            op: LayerOp::LinearSigned,
            name: "head".into(),
            h: 0,
            cin: 16,
            cout: 12, // the 12 KWS classes
            stride: 1,
            w_bits: head.0,
            i_bits: head.1,
            o_bits: 8,
            shift: shift_for(16, head.0, head.1, 8, 1),
            residual_of: None,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_every_id() {
        assert_eq!(network_ids(), vec!["resnet20", "resnet18", "kws"]);
        for def in NETWORKS {
            for cfg in [PrecisionConfig::Uniform8, PrecisionConfig::Mixed] {
                let layers = def.layers(cfg);
                assert!(!layers.is_empty(), "{}", def.id);
                // every registered network ends in a head that reduces
                // to a class vector
                let last = layers.last().unwrap();
                assert!(matches!(
                    last.op,
                    LayerOp::Linear | LayerOp::LinearSigned
                ));
            }
        }
        let err = network("resnet50").unwrap_err().to_string();
        assert!(err.contains("resnet20") && err.contains("kws"), "{err}");
    }

    #[test]
    fn spec_round_trip() {
        let spec = NetworkSpec::new("kws", PrecisionConfig::Mixed, 7);
        assert_eq!(spec.to_string(), "kws/mixed/seed7");
        assert_eq!(spec.layers().unwrap(), kws_layers(PrecisionConfig::Mixed));
        assert!(NetworkSpec::new("nope", PrecisionConfig::Mixed, 0)
            .layers()
            .is_err());
    }

    #[test]
    fn kws_head_is_signed_and_shapes_chain() {
        for cfg in [PrecisionConfig::Uniform8, PrecisionConfig::Mixed] {
            let ls = kws_layers(cfg);
            assert_eq!(ls.len(), 4);
            assert!(ls.last().unwrap().op.signed_output());
            // stem 16x16 -> body s2 -> 8x8 -> avgpool -> 16 -> head 12
            assert_eq!(ls[0].h_out(), 16);
            assert_eq!(ls[1].h_out(), 8);
            assert_eq!(ls[2].h, ls[1].h_out());
            assert_eq!(ls[2].cin, ls[1].cout);
            assert_eq!(ls[3].cin, ls[2].cout);
            assert_eq!(ls[3].cout, 12);
            // avgpool output fits the head's input precision:
            // 64 pixels of (2^O - 1) summed then >> 6
            let body_max = (1i64 << ls[1].o_bits) - 1;
            assert!((64 * body_max) >> 6 < 1 << ls[3].i_bits);
        }
    }
}
