//! DNN layer zoo (paper §IV): the networks deployed on Marsellus and
//! their HAWQ mixed-precision configurations.
//!
//! Networks are registered in [`registry`] and addressed by a
//! [`NetworkSpec`] (registry id + [`PrecisionConfig`] + weight seed) —
//! the identity `Coordinator::deploy` resolves and the `Runtime` plan
//! cache is keyed by.
//!
//! [`resnet::resnet20_layers`] mirrors `python/compile/model.py`
//! **field-for-field** — layer names, shapes, precisions, normquant
//! shifts and artifact names must match, because the Python side lowers
//! one PJRT artifact per unique layer signature and the Rust coordinator
//! looks them up by the same derived name. `manifest.tsv` (written by
//! aot.py) is the contract for that subset ([`Manifest::aot_zoo`]);
//! [`manifest::Manifest`] validates it. The other registry networks
//! (ResNet-18, the signed-head KWS net) are Rust-builtin only.

pub mod layer;
pub mod manifest;
pub mod registry;
pub mod resnet;

pub use layer::{
    artifact_name, validate_signed_dataflow, Layer, LayerOp, PrecisionConfig,
};
pub use manifest::{Manifest, ManifestEntry};
pub use registry::{kws_layers, network, network_ids, NetworkDef, NetworkSpec};
pub use resnet::{
    quickstart_layer, resnet18_layers, resnet18_layers_cfg, resnet20_layers,
};
