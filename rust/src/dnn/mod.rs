//! DNN layer zoo (paper §IV): the networks deployed on Marsellus and
//! their HAWQ mixed-precision configurations.
//!
//! [`resnet::resnet20_layers`] mirrors `python/compile/model.py`
//! **field-for-field** — layer names, shapes, precisions, normquant
//! shifts and artifact names must match, because the Python side lowers
//! one PJRT artifact per unique layer signature and the Rust coordinator
//! looks them up by the same derived name. `manifest.tsv` (written by
//! aot.py) is the contract; [`manifest::Manifest`] validates it.

pub mod layer;
pub mod manifest;
pub mod resnet;

pub use layer::{artifact_name, Layer, LayerOp, PrecisionConfig};
pub use manifest::{Manifest, ManifestEntry};
pub use resnet::{quickstart_layer, resnet18_layers, resnet20_layers};
