//! Layer descriptors, mirroring `python/compile/model.py::LayerSpec`.

/// Operator kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerOp {
    Conv3x3,
    Conv1x1,
    Add,
    AvgPool,
    Linear,
    /// Fully connected head with a *signed* (no-ReLU) output range:
    /// identical arithmetic to [`LayerOp::Linear`] but requantized with
    /// `NormQuant::apply_signed` (two's-complement clip instead of the
    /// ReLU `[0, 2^O - 1]` clip). Only valid as a network head — every
    /// other layer consumes unsigned activations.
    LinearSigned,
}

impl LayerOp {
    pub fn as_str(&self) -> &'static str {
        match self {
            LayerOp::Conv3x3 => "conv3x3",
            LayerOp::Conv1x1 => "conv1x1",
            LayerOp::Add => "add",
            LayerOp::AvgPool => "avgpool",
            LayerOp::Linear => "linear",
            LayerOp::LinearSigned => "linears",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "conv3x3" => LayerOp::Conv3x3,
            "conv1x1" => LayerOp::Conv1x1,
            "add" => LayerOp::Add,
            "avgpool" => LayerOp::AvgPool,
            "linear" => LayerOp::Linear,
            "linears" => LayerOp::LinearSigned,
            _ => return None,
        })
    }

    /// Does this operator run on RBE (vs the RISC-V cores)?
    pub fn on_rbe(&self) -> bool {
        matches!(
            self,
            LayerOp::Conv3x3
                | LayerOp::Conv1x1
                | LayerOp::Linear
                | LayerOp::LinearSigned
        )
    }

    /// Does this operator produce signed (no-ReLU) outputs?
    pub fn signed_output(&self) -> bool {
        matches!(self, LayerOp::LinearSigned)
    }
}

/// Network precision configuration (paper §IV).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PrecisionConfig {
    /// Everything 8-bit.
    Uniform8,
    /// Representative HAWQ assignment: weights {2,3,6,8}, acts {4,8}.
    Mixed,
}

impl PrecisionConfig {
    pub fn as_str(&self) -> &'static str {
        match self {
            PrecisionConfig::Uniform8 => "uniform8",
            PrecisionConfig::Mixed => "mixed",
        }
    }
}

/// One schedulable layer. `h` is the *unpadded* input spatial size.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Layer {
    pub op: LayerOp,
    pub name: String,
    pub h: usize,
    pub cin: usize,
    pub cout: usize,
    pub stride: usize,
    pub w_bits: usize,
    pub i_bits: usize,
    pub o_bits: usize,
    pub shift: u32,
    /// For `Add`: name of the shortcut source ("input" = block entry).
    pub residual_of: Option<String>,
}

impl Layer {
    pub fn h_out(&self) -> usize {
        if self.h == 0 {
            0
        } else {
            (self.h + self.stride - 1) / self.stride
        }
    }

    /// MACs of this layer (conv/linear only; elementwise ops report 0).
    pub fn macs(&self) -> u64 {
        match self.op {
            LayerOp::Conv3x3 => {
                (self.h_out() * self.h_out() * self.cout * self.cin * 9)
                    as u64
            }
            LayerOp::Conv1x1 => {
                (self.h_out() * self.h_out() * self.cout * self.cin) as u64
            }
            LayerOp::Linear | LayerOp::LinearSigned => {
                (self.cin * self.cout) as u64
            }
            _ => 0,
        }
    }

    pub fn ops(&self) -> u64 {
        self.macs() * 2
    }

    /// Elements produced.
    pub fn out_elems(&self) -> usize {
        match self.op {
            LayerOp::AvgPool | LayerOp::Linear | LayerOp::LinearSigned => {
                self.cout
            }
            _ => self.h_out() * self.h_out() * self.cout,
        }
    }

    pub fn artifact(&self) -> String {
        artifact_name(self)
    }
}

/// Stable artifact naming shared with `python/compile/model.py`.
pub fn artifact_name(l: &Layer) -> String {
    match l.op {
        LayerOp::Conv3x3 | LayerOp::Conv1x1 => format!(
            "{}_h{}_ci{}_co{}_s{}_w{}i{}o{}",
            l.op.as_str(),
            l.h,
            l.cin,
            l.cout,
            l.stride,
            l.w_bits,
            l.i_bits,
            l.o_bits
        ),
        LayerOp::Add => {
            format!("add_h{}_k{}_o{}_sh{}", l.h, l.cin, l.o_bits, l.shift)
        }
        LayerOp::AvgPool => format!("avgpool_h{}_k{}", l.h, l.cin),
        LayerOp::Linear => format!(
            "linear_ci{}_co{}_w{}i{}o{}",
            l.cin, l.cout, l.w_bits, l.i_bits, l.o_bits
        ),
        // distinct prefix: a signed head must never collide with an
        // unsigned linear layer of the same signature in the zoo map
        LayerOp::LinearSigned => format!(
            "linears_ci{}_co{}_w{}i{}o{}",
            l.cin, l.cout, l.w_bits, l.i_bits, l.o_bits
        ),
    }
}

/// Reject schedules that route *signed* activations into the unsigned
/// bit-serial kernels: every conv/linear/add/avgpool kernel packs (or
/// clips) its input as unsigned bit-planes, so a signed-output layer
/// ([`LayerOp::signed_output`]) is only valid as the network head —
/// anything downstream of one would silently pack the two's-complement
/// high bits as magnitude. This is the plan-compile-time (structural)
/// half of the guard; `rbe::functional` additionally rejects negative
/// activation *values* at the kernel boundary.
pub fn validate_signed_dataflow(layers: &[Layer]) -> anyhow::Result<()> {
    for (i, l) in layers.iter().enumerate() {
        if l.op.signed_output() && i + 1 != layers.len() {
            anyhow::bail!(
                "layer {} ({}) produces signed activations but is not the \
                 network head: downstream layer {} would pack them as \
                 unsigned bit-planes (mid-network signed activations are \
                 not supported)",
                l.name,
                l.op.as_str(),
                layers[i + 1].name
            );
        }
    }
    Ok(())
}

/// Mirror of `model._shift_for` (must stay numerically identical): a
/// variance-based shift so random-weight activations stay spread over the
/// O-bit range through the whole network (see the python docstring).
pub fn shift_for(
    cin: usize,
    w_bits: usize,
    i_bits: usize,
    o_bits: usize,
    taps: usize,
) -> u32 {
    let x = 0.5 * ((cin * taps).max(1) as f64).log2()
        + w_bits as f64
        + i_bits as f64
        + 0.42
        - o_bits as f64;
    ((x + 0.5).trunc() as i64).max(0) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_names_match_python_convention() {
        let l = Layer {
            op: LayerOp::Conv3x3,
            name: "stem".into(),
            h: 32,
            cin: 3,
            cout: 16,
            stride: 1,
            w_bits: 8,
            i_bits: 8,
            o_bits: 8,
            shift: 0,
            residual_of: None,
        };
        assert_eq!(l.artifact(), "conv3x3_h32_ci3_co16_s1_w8i8o8");
    }

    #[test]
    fn signed_head_has_distinct_artifact_name() {
        let mk = |op| Layer {
            op,
            name: "fc".into(),
            h: 0,
            cin: 64,
            cout: 10,
            stride: 1,
            w_bits: 8,
            i_bits: 8,
            o_bits: 8,
            shift: 7,
            residual_of: None,
        };
        let unsigned = mk(LayerOp::Linear);
        let signed = mk(LayerOp::LinearSigned);
        assert_eq!(signed.artifact(), "linears_ci64_co10_w8i8o8");
        assert_ne!(signed.artifact(), unsigned.artifact());
        assert!(signed.op.signed_output() && !unsigned.op.signed_output());
        assert!(signed.op.on_rbe());
        assert_eq!(signed.macs(), unsigned.macs());
        assert_eq!(LayerOp::parse("linears"), Some(LayerOp::LinearSigned));
    }

    /// Regression (ISSUE 4 satellite): a signed-output layer anywhere
    /// but the network head must be a loud plan-compile error, never a
    /// schedule that silently packs two's-complement bits as unsigned
    /// magnitudes downstream.
    #[test]
    fn mid_network_signed_activations_rejected_structurally() {
        let conv = Layer {
            op: LayerOp::Conv3x3,
            name: "body.conv0".into(),
            h: 8,
            cin: 16,
            cout: 16,
            stride: 1,
            w_bits: 4,
            i_bits: 4,
            o_bits: 4,
            shift: 8,
            residual_of: None,
        };
        let head = Layer {
            op: LayerOp::LinearSigned,
            name: "head.fc".into(),
            h: 0,
            cin: 16,
            cout: 10,
            stride: 1,
            w_bits: 8,
            i_bits: 8,
            o_bits: 8,
            shift: 7,
            residual_of: None,
        };
        // signed head last: valid
        validate_signed_dataflow(&[conv.clone(), head.clone()]).unwrap();
        // signed layer feeding a conv: structural error naming both ends
        let mid = Layer { name: "mid.fc".into(), ..head };
        let err = validate_signed_dataflow(&[mid, conv.clone()])
            .unwrap_err()
            .to_string();
        assert!(
            err.contains("mid.fc")
                && err.contains("body.conv0")
                && err.contains("signed"),
            "unhelpful error: {err:?}"
        );
    }

    #[test]
    fn shift_matches_python_formula() {
        // stem uniform8: 0.5*log2(27)+8+8+0.42-8 = 10.80 -> 11 (round)
        assert_eq!(shift_for(3, 8, 8, 8, 9), 11);
        // fc mixed: 0.5*log2(64)+8+4+0.42-8 = 7.42 -> 7
        assert_eq!(shift_for(64, 8, 4, 8, 1), 7);
        // stage1 mixed: 0.5*log2(144)+6+4+0.42-4 = 10.0 -> 10
        assert_eq!(shift_for(16, 6, 4, 4, 9), 10);
    }

    #[test]
    fn mac_counts() {
        let l = Layer {
            op: LayerOp::Conv3x3,
            name: "x".into(),
            h: 16,
            cin: 32,
            cout: 64,
            stride: 2,
            w_bits: 4,
            i_bits: 4,
            o_bits: 4,
            shift: 0,
            residual_of: None,
        };
        assert_eq!(l.h_out(), 8);
        assert_eq!(l.macs(), 8 * 8 * 64 * 32 * 9);
    }
}
