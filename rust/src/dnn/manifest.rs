//! The artifact manifest: the build-time contract between
//! `python/compile/aot.py` and the Rust runtime.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Result};

use super::layer::{Layer, LayerOp, PrecisionConfig};
use super::resnet::resnet20_layers;
use crate::util::TsvTable;

/// One manifest row (mirrors aot.manifest_entry minus arg shapes, which
/// the Rust side re-derives from the layer signature).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestEntry {
    pub name: String,
    pub op: LayerOp,
    pub h: usize,
    pub cin: usize,
    pub cout: usize,
    pub stride: usize,
    pub w_bits: usize,
    pub i_bits: usize,
    pub o_bits: usize,
    pub shift: u32,
}

/// Parsed `manifest.tsv`.
#[derive(Debug, Clone)]
pub struct Manifest {
    entries: HashMap<String, ManifestEntry>,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let t = TsvTable::load(&artifacts_dir.join("manifest.tsv"))?;
        let mut entries = HashMap::new();
        for r in 0..t.len() {
            let name = t.get(r, "name")?.to_string();
            let op = LayerOp::parse(t.get(r, "op")?)
                .ok_or_else(|| anyhow::anyhow!("bad op row {r}"))?;
            let e = ManifestEntry {
                name: name.clone(),
                op,
                h: t.get_usize(r, "h")?,
                cin: t.get_usize(r, "cin")?,
                cout: t.get_usize(r, "cout")?,
                stride: t.get_usize(r, "stride")?,
                w_bits: t.get_usize(r, "w_bits")?,
                i_bits: t.get_usize(r, "i_bits")?,
                o_bits: t.get_usize(r, "o_bits")?,
                shift: t.get_usize(r, "shift")? as u32,
            };
            entries.insert(name, e);
        }
        Ok(Self { entries })
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.get(name)
    }

    /// Check that every layer of the given network config has a manifest
    /// entry with matching signature (the python/rust zoo must agree).
    pub fn validate_network(&self, config: PrecisionConfig) -> Result<()> {
        for l in resnet20_layers(config) {
            let name = l.artifact();
            let Some(e) = self.entries.get(&name) else {
                bail!("layer {} has no artifact {name}", l.name);
            };
            if !entry_matches(e, &l) {
                bail!(
                    "artifact {name} signature mismatch: manifest {e:?} vs \
                     layer {l:?}"
                );
            }
        }
        Ok(())
    }
}

fn entry_matches(e: &ManifestEntry, l: &Layer) -> bool {
    e.op == l.op
        && e.h == l.h
        && e.cin == l.cin
        && e.cout == l.cout
        && e.stride == l.stride
        && (e.w_bits, e.i_bits, e.o_bits) == (l.w_bits, l.i_bits, l.o_bits)
        && e.shift == l.shift
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn manifest_loads_and_covers_both_configs() {
        let dir = artifacts_dir();
        if !dir.join("manifest.tsv").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.len() >= 20, "{} artifacts", m.len());
        m.validate_network(PrecisionConfig::Uniform8).unwrap();
        m.validate_network(PrecisionConfig::Mixed).unwrap();
    }
}
