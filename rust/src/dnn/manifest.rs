//! The artifact manifest: the build-time contract between
//! `python/compile/aot.py` and the Rust runtime.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Result};

use super::layer::{Layer, LayerOp, PrecisionConfig};
use super::registry::NETWORKS;
use super::resnet::{quickstart_layer, resnet20_layers};
use crate::rbe::RbeJob;
use crate::util::TsvTable;

/// One manifest row (mirrors aot.manifest_entry minus arg shapes, which
/// the Rust side re-derives from the layer signature).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestEntry {
    pub name: String,
    pub op: LayerOp,
    pub h: usize,
    pub cin: usize,
    pub cout: usize,
    pub stride: usize,
    pub w_bits: usize,
    pub i_bits: usize,
    pub o_bits: usize,
    pub shift: u32,
}

impl ManifestEntry {
    /// Side length of the activation plane the artifact receives:
    /// conv3x3 artifacts take the zero-padded plane (pad = 1/side),
    /// linear layers a single pixel (their `h` is 0 by convention),
    /// everything else the layer's own spatial size.
    pub fn full_side(&self) -> usize {
        match self.op {
            LayerOp::Conv3x3 => self.h + 2,
            LayerOp::Linear | LayerOp::LinearSigned => 1,
            _ => self.h,
        }
    }

    /// Resolve the RBE job geometry this conv/linear artifact executes:
    /// valid conv over the padded plane (3×3), strided gather of the
    /// full plane (1×1), single-pixel 1×1 (linear). The plan compiler
    /// and the per-call native path both derive geometry here, so they
    /// cannot drift.
    pub fn rbe_job(&self) -> Result<RbeJob> {
        match self.op {
            LayerOp::Conv3x3 => {
                let h_out = (self.h + 2 - 3) / self.stride + 1;
                RbeJob::conv3x3(
                    h_out, h_out, self.cin, self.cout, self.stride,
                    self.w_bits, self.i_bits, self.o_bits,
                )
            }
            LayerOp::Conv1x1 => {
                let h_out = (self.h - 1) / self.stride + 1;
                RbeJob::conv1x1(
                    h_out, h_out, self.cin, self.cout, self.stride,
                    self.w_bits, self.i_bits, self.o_bits,
                )
            }
            LayerOp::Linear | LayerOp::LinearSigned => RbeJob::conv1x1(
                1, 1, self.cin, self.cout, 1, self.w_bits, self.i_bits,
                self.o_bits,
            ),
            _ => bail!(
                "{}: {} layers have no RBE job geometry",
                self.name,
                self.op.as_str()
            ),
        }
    }
}

/// Parsed `manifest.tsv`.
#[derive(Debug, Clone)]
pub struct Manifest {
    entries: HashMap<String, ManifestEntry>,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let t = TsvTable::load(&artifacts_dir.join("manifest.tsv"))?;
        let mut entries = HashMap::new();
        for r in 0..t.len() {
            let name = t.get(r, "name")?.to_string();
            let op = LayerOp::parse(t.get(r, "op")?)
                .ok_or_else(|| anyhow::anyhow!("bad op row {r}"))?;
            let e = ManifestEntry {
                name: name.clone(),
                op,
                h: t.get_usize(r, "h")?,
                cin: t.get_usize(r, "cin")?,
                cout: t.get_usize(r, "cout")?,
                stride: t.get_usize(r, "stride")?,
                w_bits: t.get_usize(r, "w_bits")?,
                i_bits: t.get_usize(r, "i_bits")?,
                o_bits: t.get_usize(r, "o_bits")?,
                shift: t.get_usize(r, "shift")? as u32,
            };
            entries.insert(name, e);
        }
        Ok(Self { entries })
    }

    /// Build a manifest from layer descriptors (no disk involved): one
    /// entry per unique artifact name, exactly like `aot.gather_specs`.
    pub fn from_layers<'a>(layers: impl IntoIterator<Item = &'a Layer>) -> Self {
        let mut entries = HashMap::new();
        for l in layers {
            let name = l.artifact();
            entries
                .entry(name.clone())
                .or_insert_with(|| entry_from_layer(name, l));
        }
        Self { entries }
    }

    /// The built-in artifact zoo: every layer of every registered
    /// network ([`crate::dnn::registry::NETWORKS`]) under both precision
    /// configurations, plus the standalone quickstart conv. This is what
    /// the native backend executes when `make artifacts` has never been
    /// run — the full servable surface of the deployment API.
    pub fn builtin() -> Self {
        let mut layers = Vec::new();
        for net in NETWORKS {
            for cfg in [PrecisionConfig::Uniform8, PrecisionConfig::Mixed] {
                layers.extend(net.layers(cfg));
            }
        }
        layers.push(quickstart_layer());
        Self::from_layers(layers.iter())
    }

    /// The subset of the zoo that `python/compile/aot.py` lowers to PJRT
    /// artifacts: both ResNet-20 configurations plus the quickstart conv.
    /// An on-disk `manifest.tsv` is required to agree with *this* set
    /// (the python/rust contract); the other registry networks are
    /// Rust-builtin only.
    pub fn aot_zoo() -> Self {
        let mut layers = resnet20_layers(PrecisionConfig::Uniform8);
        layers.extend(resnet20_layers(PrecisionConfig::Mixed));
        layers.push(quickstart_layer());
        Self::from_layers(layers.iter())
    }

    /// The built-in zoo, extended/overridden by `manifest.tsv` when the
    /// artifacts directory has one. Errors only on a *corrupt* manifest;
    /// a missing file silently falls back to the built-in zoo.
    pub fn load_or_builtin(artifacts_dir: &Path) -> Result<Self> {
        let mut m = Self::builtin();
        if artifacts_dir.join("manifest.tsv").exists() {
            let disk = Self::load(artifacts_dir)?;
            m.entries.extend(disk.entries);
        }
        Ok(m)
    }

    /// All artifact names, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.entries.keys().cloned().collect();
        v.sort();
        v
    }

    /// Iterate over all entries (arbitrary order).
    pub fn entries(&self) -> impl Iterator<Item = &ManifestEntry> {
        self.entries.values()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.get(name)
    }

    /// Check that every layer of a schedule has a manifest entry with a
    /// matching signature — the deploy-time validation of the deployment
    /// API (and, for the AOT subset, the python/rust zoo agreement).
    /// Also rejects schedules that would stream signed activations into
    /// the unsigned bit-plane packers
    /// ([`super::layer::validate_signed_dataflow`]).
    pub fn validate_layers(&self, layers: &[Layer]) -> Result<()> {
        super::layer::validate_signed_dataflow(layers)?;
        for l in layers {
            let name = l.artifact();
            let Some(e) = self.entries.get(&name) else {
                bail!("layer {} has no artifact {name}", l.name);
            };
            if !entry_matches(e, l) {
                bail!(
                    "artifact {name} signature mismatch: manifest {e:?} vs \
                     layer {l:?}"
                );
            }
        }
        Ok(())
    }

    /// [`Self::validate_layers`] over the ResNet-20 schedule (historical
    /// entry point; the deployment API validates arbitrary schedules).
    pub fn validate_network(&self, config: PrecisionConfig) -> Result<()> {
        self.validate_layers(&resnet20_layers(config))
    }
}

fn entry_from_layer(name: String, l: &Layer) -> ManifestEntry {
    ManifestEntry {
        name,
        op: l.op,
        h: l.h,
        cin: l.cin,
        cout: l.cout,
        stride: l.stride,
        w_bits: l.w_bits,
        i_bits: l.i_bits,
        o_bits: l.o_bits,
        shift: l.shift,
    }
}

fn entry_matches(e: &ManifestEntry, l: &Layer) -> bool {
    e.op == l.op
        && e.h == l.h
        && e.cin == l.cin
        && e.cout == l.cout
        && e.stride == l.stride
        && (e.w_bits, e.i_bits, e.o_bits) == (l.w_bits, l.i_bits, l.o_bits)
        && e.shift == l.shift
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn builtin_manifest_covers_every_registry_network() {
        let m = Manifest::builtin();
        assert!(m.len() >= 20, "{} artifacts", m.len());
        for net in crate::dnn::registry::NETWORKS {
            for cfg in [PrecisionConfig::Uniform8, PrecisionConfig::Mixed] {
                m.validate_layers(&net.layers(cfg))
                    .unwrap_or_else(|e| panic!("{}/{}: {e}", net.id, cfg.as_str()));
            }
        }
        // quickstart spec keeps its hand-picked shift (not shift_for)
        let qs = m.get("conv3x3_h16_ci32_co32_s1_w4i4o4").unwrap();
        assert_eq!(qs.shift, 10);
        // the signed KWS head is part of the servable zoo
        assert!(m.get("linears_ci16_co12_w8i8o8").is_some());
        // and the aot subset stays exactly the python-lowered set
        let aot = Manifest::aot_zoo();
        assert!(aot.len() < m.len());
        assert!(aot.get("linears_ci16_co12_w8i8o8").is_none());
        for name in aot.names() {
            assert_eq!(m.get(&name), aot.get(&name), "{name}");
        }
    }

    #[test]
    fn geometry_helpers_cover_every_rbe_entry() {
        let m = Manifest::builtin();
        for e in m.entries() {
            match e.op {
                LayerOp::Conv3x3
                | LayerOp::Conv1x1
                | LayerOp::Linear
                | LayerOp::LinearSigned => {
                    let job = e.rbe_job().unwrap();
                    assert_eq!(job.k_in, e.cin, "{}", e.name);
                    assert_eq!(job.k_out, e.cout, "{}", e.name);
                    // the strided extent always fits the full plane
                    assert!(job.h_in() <= e.full_side(), "{}", e.name);
                }
                _ => assert!(e.rbe_job().is_err(), "{}", e.name),
            }
        }
        // linear layers receive a single pixel (h = 0 by convention)
        let fc = m.get("linear_ci64_co10_w8i8o8").unwrap();
        assert_eq!(fc.full_side(), 1);
        assert_eq!(fc.rbe_job().unwrap().h_in(), 1);
    }

    /// Deploy-time structural guard: a schedule whose signed-output
    /// layer is not the head is rejected before any kernel runs (the
    /// signed-activation-into-unsigned-packing trap).
    #[test]
    fn validate_layers_rejects_mid_network_signed_schedule() {
        let m = Manifest::builtin();
        let mut layers = crate::dnn::kws_layers(PrecisionConfig::Mixed);
        m.validate_layers(&layers).unwrap();
        assert!(layers.last().unwrap().op.signed_output());
        // rotate the signed head off the end: now mid-network
        layers.rotate_right(1);
        let err = m.validate_layers(&layers).unwrap_err().to_string();
        assert!(err.contains("signed"), "{err}");
    }

    #[test]
    fn load_or_builtin_without_disk_equals_builtin() {
        let dir = std::path::Path::new("/nonexistent-artifacts-dir");
        let m = Manifest::load_or_builtin(dir).unwrap();
        assert_eq!(m.names(), Manifest::builtin().names());
    }

    #[test]
    fn manifest_loads_and_covers_both_configs() {
        let dir = artifacts_dir();
        if !dir.join("manifest.tsv").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.len() >= 20, "{} artifacts", m.len());
        m.validate_network(PrecisionConfig::Uniform8).unwrap();
        m.validate_network(PrecisionConfig::Mixed).unwrap();
    }
}
