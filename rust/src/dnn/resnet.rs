//! Network definitions: ResNet-20/CIFAR-10 (Figs. 17–18) and
//! ResNet-18/ImageNet (Table II timing rows).

use super::layer::{shift_for, Layer, LayerOp, PrecisionConfig};

struct StageBits {
    stem: (usize, usize, usize),
    stage1: (usize, usize, usize),
    stage2: (usize, usize, usize),
    stage3: (usize, usize, usize),
    down: (usize, usize, usize),
    fc: (usize, usize, usize),
}

fn bits_of(config: PrecisionConfig) -> StageBits {
    match config {
        PrecisionConfig::Uniform8 => StageBits {
            stem: (8, 8, 8),
            stage1: (8, 8, 8),
            stage2: (8, 8, 8),
            stage3: (8, 8, 8),
            down: (8, 8, 8),
            fc: (8, 8, 8),
        },
        // Representative HAWQ assignment (mirrors model.PRECISIONS).
        PrecisionConfig::Mixed => StageBits {
            stem: (8, 8, 4),
            stage1: (6, 4, 4),
            stage2: (3, 4, 4),
            stage3: (2, 4, 4),
            down: (8, 4, 4),
            fc: (8, 4, 8),
        },
    }
}

fn conv(
    op: LayerOp,
    name: &str,
    h: usize,
    cin: usize,
    cout: usize,
    stride: usize,
    bits: (usize, usize, usize),
) -> Layer {
    let taps = if op == LayerOp::Conv3x3 { 9 } else { 1 };
    Layer {
        op,
        name: name.to_string(),
        h,
        cin,
        cout,
        stride,
        w_bits: bits.0,
        i_bits: bits.1,
        o_bits: bits.2,
        shift: shift_for(cin, bits.0, bits.1, bits.2, taps),
        residual_of: None,
    }
}

/// The ResNet-20 layer schedule — must mirror
/// `python/compile/model.py::resnet20_layers` exactly.
pub fn resnet20_layers(config: PrecisionConfig) -> Vec<Layer> {
    let p = bits_of(config);
    let mut layers = Vec::new();
    layers.push(conv(LayerOp::Conv3x3, "stem", 32, 3, 16, 1, p.stem));

    let specs: [(&str, usize, usize, usize, (usize, usize, usize)); 3] = [
        ("stage1", 32, 16, 16, p.stage1),
        ("stage2", 16, 16, 32, p.stage2),
        ("stage3", 8, 32, 64, p.stage3),
    ];
    for (stage, h_out, cin_stage, ch, bits) in specs {
        for blk in 0..3 {
            let first = blk == 0 && stage != "stage1";
            let h_in = if first { h_out * 2 } else { h_out };
            let cin = if blk == 0 { cin_stage } else { ch };
            let stride = if first { 2 } else { 1 };
            layers.push(conv(
                LayerOp::Conv3x3,
                &format!("{stage}.b{blk}.conv0"),
                h_in,
                cin,
                ch,
                stride,
                bits,
            ));
            layers.push(conv(
                LayerOp::Conv3x3,
                &format!("{stage}.b{blk}.conv1"),
                h_out,
                ch,
                ch,
                1,
                bits,
            ));
            let shortcut = if first {
                layers.push(conv(
                    LayerOp::Conv1x1,
                    &format!("{stage}.b{blk}.down"),
                    h_in,
                    cin,
                    ch,
                    2,
                    p.down,
                ));
                format!("{stage}.b{blk}.down")
            } else {
                "input".to_string()
            };
            layers.push(Layer {
                op: LayerOp::Add,
                name: format!("{stage}.b{blk}.add"),
                h: h_out,
                cin: ch,
                cout: ch,
                stride: 1,
                w_bits: 8,
                i_bits: 8,
                o_bits: bits.2,
                shift: 1,
                residual_of: Some(shortcut),
            });
        }
    }
    layers.push(Layer {
        op: LayerOp::AvgPool,
        name: "avgpool".into(),
        h: 8,
        cin: 64,
        cout: 64,
        stride: 1,
        w_bits: 8,
        i_bits: 8,
        o_bits: 8,
        shift: 6,
        residual_of: None,
    });
    let (w, i, o) = p.fc;
    layers.push(Layer {
        op: LayerOp::Linear,
        name: "fc".into(),
        h: 0,
        cin: 64,
        cout: 10,
        stride: 1,
        w_bits: w,
        i_bits: i,
        o_bits: o,
        shift: shift_for(64, w, i, o, 1),
        residual_of: None,
    });
    layers
}

/// The standalone quickstart conv artifact (mirrors
/// `python/compile/aot.py::quickstart_spec`, including its hand-picked
/// normquant shift of 10 — *not* the `shift_for` value).
pub fn quickstart_layer() -> Layer {
    Layer {
        op: LayerOp::Conv3x3,
        name: "quickstart".into(),
        h: 16,
        cin: 32,
        cout: 32,
        stride: 1,
        w_bits: 4,
        i_bits: 4,
        o_bits: 4,
        shift: 10,
        residual_of: None,
    }
}

/// ResNet-18/ImageNet layer shapes, used for the Table II timing rows
/// (HAWQ 4×4-bit per the paper). The 7×7/s2 stem is scheduled as an
/// MAC-equivalent 3×3 job over a folded input (DORY-style im2row of the
/// 49-tap kernel into 3×3 over 3·(49/9) ≈ 17 channels, rounded to the
/// RBE's 32-channel group). Equivalent to
/// [`resnet18_layers_cfg`]`(PrecisionConfig::Mixed)`.
pub fn resnet18_layers() -> Vec<Layer> {
    resnet18_layers_cfg(PrecisionConfig::Mixed)
}

/// ResNet-18/ImageNet under a precision configuration:
/// [`PrecisionConfig::Mixed`] is the paper's HAWQ 4×4-bit assignment,
/// [`PrecisionConfig::Uniform8`] the all-8-bit variant. Servable
/// end-to-end through the deployment API — every layer is part of the
/// built-in zoo ([`crate::dnn::Manifest::builtin`]).
pub fn resnet18_layers_cfg(config: PrecisionConfig) -> Vec<Layer> {
    let b4 = match config {
        PrecisionConfig::Uniform8 => (8usize, 8usize, 8usize),
        PrecisionConfig::Mixed => (4usize, 4usize, 4usize),
    };
    let mut layers = Vec::new();
    // stem: 7x7 s2, 3->64, 224->112 (folded; see doc comment)
    layers.push(conv(LayerOp::Conv3x3, "stem7x7", 224, 17, 64, 2, b4));
    // 4 stages x 2 basic blocks
    let specs: [(&str, usize, usize, usize); 4] = [
        ("stage1", 56, 64, 64),
        ("stage2", 28, 64, 128),
        ("stage3", 14, 128, 256),
        ("stage4", 7, 256, 512),
    ];
    for (stage, h_out, cin_stage, ch) in specs {
        for blk in 0..2 {
            let first = blk == 0 && stage != "stage1";
            let h_in = if first { h_out * 2 } else { h_out };
            let cin = if blk == 0 { cin_stage } else { ch };
            let stride = if first { 2 } else { 1 };
            layers.push(conv(
                LayerOp::Conv3x3,
                &format!("{stage}.b{blk}.conv0"),
                h_in,
                cin,
                ch,
                stride,
                b4,
            ));
            layers.push(conv(
                LayerOp::Conv3x3,
                &format!("{stage}.b{blk}.conv1"),
                h_out,
                ch,
                ch,
                1,
                b4,
            ));
            if first {
                layers.push(conv(
                    LayerOp::Conv1x1,
                    &format!("{stage}.b{blk}.down"),
                    h_in,
                    cin,
                    ch,
                    2,
                    b4,
                ));
            }
            layers.push(Layer {
                op: LayerOp::Add,
                name: format!("{stage}.b{blk}.add"),
                h: h_out,
                cin: ch,
                cout: ch,
                stride: 1,
                w_bits: 8,
                i_bits: 8,
                o_bits: b4.2,
                shift: 1,
                residual_of: Some(if first {
                    format!("{stage}.b{blk}.down")
                } else {
                    "input".into()
                }),
            });
        }
    }
    layers.push(Layer {
        op: LayerOp::AvgPool,
        name: "avgpool".into(),
        h: 7,
        cin: 512,
        cout: 512,
        stride: 1,
        w_bits: 8,
        i_bits: 8,
        o_bits: 8,
        shift: 6,
        residual_of: None,
    });
    layers.push(Layer {
        op: LayerOp::Linear,
        name: "fc".into(),
        h: 0,
        cin: 512,
        cout: 1000,
        stride: 1,
        w_bits: b4.0,
        i_bits: b4.1,
        o_bits: 8,
        shift: shift_for(512, b4.0, b4.1, 8, 1),
        residual_of: None,
    });
    layers
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet20_structure() {
        for cfg in [PrecisionConfig::Uniform8, PrecisionConfig::Mixed] {
            let ls = resnet20_layers(cfg);
            assert_eq!(
                ls.iter().filter(|l| l.op == LayerOp::Conv3x3).count(),
                19
            );
            assert_eq!(
                ls.iter().filter(|l| l.op == LayerOp::Conv1x1).count(),
                2
            );
            assert_eq!(ls.iter().filter(|l| l.op == LayerOp::Add).count(), 9);
            assert_eq!(ls.last().unwrap().op, LayerOp::Linear);
        }
    }

    #[test]
    fn resnet20_macs_about_41m() {
        let ls = resnet20_layers(PrecisionConfig::Uniform8);
        let macs: u64 = ls.iter().map(|l| l.macs()).sum();
        // CIFAR ResNet-20 is ~40.8 MMAC
        assert!((39_000_000..43_000_000).contains(&macs), "{macs}");
    }

    #[test]
    fn resnet18_macs_about_1_8g() {
        let ls = resnet18_layers();
        let macs: u64 = ls.iter().map(|l| l.macs()).sum();
        assert!((1_600_000_000..2_100_000_000).contains(&macs), "{macs}");
    }

    #[test]
    fn resnet18_precision_variants() {
        // the historical no-arg constructor is the HAWQ 4x4 assignment
        assert_eq!(resnet18_layers(), resnet18_layers_cfg(PrecisionConfig::Mixed));
        let u = resnet18_layers_cfg(PrecisionConfig::Uniform8);
        let m = resnet18_layers_cfg(PrecisionConfig::Mixed);
        assert_eq!(u.len(), m.len());
        for (lu, lm) in u.iter().zip(&m) {
            assert_eq!(lu.name, lm.name);
            assert_eq!((lu.h, lu.cin, lu.cout, lu.stride),
                       (lm.h, lm.cin, lm.cout, lm.stride));
            if lu.op.on_rbe() {
                assert_eq!((lu.w_bits, lu.i_bits), (8, 8), "{}", lu.name);
                assert_eq!((lm.w_bits, lm.i_bits), (4, 4), "{}", lm.name);
            }
        }
    }

    #[test]
    fn shapes_chain() {
        let ls = resnet20_layers(PrecisionConfig::Mixed);
        let (mut h, mut c) = (32usize, 3usize);
        for l in &ls {
            match l.op {
                LayerOp::Conv3x3 => {
                    if !l.name.ends_with(".down") {
                        assert_eq!(l.cin, c, "{}", l.name);
                        h = l.h_out();
                        c = l.cout;
                    }
                }
                LayerOp::Add => assert_eq!((l.h, l.cin), (h, c), "{}", l.name),
                _ => {}
            }
        }
        assert_eq!((h, c), (8, 64));
    }

    #[test]
    fn mixed_uses_hawq_bit_palette() {
        let ls = resnet20_layers(PrecisionConfig::Mixed);
        for l in ls.iter().filter(|l| l.op.on_rbe()) {
            assert!([2, 3, 6, 8].contains(&l.w_bits), "{}", l.name);
            assert!([4, 8].contains(&l.i_bits), "{}", l.name);
        }
    }
}
