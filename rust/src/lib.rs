//! # marsellus-sim
//!
//! A full-stack reproduction of the MARSELLUS AI-IoT SoC (Conti et al.,
//! JSSC 2023): a heterogeneous RISC-V cluster with XpulpNN ISA extensions,
//! the 2-to-8-bit Reconfigurable Binary Engine (RBE), and adaptive body
//! biasing (ABB) — rebuilt as a three-layer Rust + JAX + Pallas system.
//!
//! Since the paper's artifact is silicon, the substrate here is a
//! cycle-approximate simulator calibrated against the paper's measurements:
//!
//! * [`isa`] / [`core`] — RV32IMFC + Xpulp + XpulpNN instruction-set
//!   simulator with the MAC&LOAD / NN-RF mechanism (paper §II-A).
//! * [`cluster`] — 16-core cluster: TCDM banks, logarithmic interconnect,
//!   shared FPUs, event unit, DMA (paper §II).
//! * [`rbe`] — functional (bit-serial, Eqs. 1–2) + cycle model of the
//!   Reconfigurable Binary Engine (paper §II-B).
//! * [`power`] / [`abb`] — voltage/frequency/power model fitted to Fig. 9
//!   and the OCM + ABB generator control loop (paper §II-C, Figs. 10–12).
//! * [`dnn`] / [`mapping`] — DORY-style tiler and HAWQ mixed-precision
//!   network descriptions (paper §IV).
//! * [`runtime`] — pluggable execution backend for the DNN layer
//!   numerics: a pure-Rust **native** backend (default feature, dispatches
//!   to the in-tree RBE functional models) and an opt-in **PJRT** backend
//!   (`pjrt` feature) loading the AOT-compiled JAX/Pallas artifacts.
//! * [`coordinator`] — top-level scheduler tying cores, RBE, DMA and ABB
//!   together; the entry point for examples and the figure harness, with
//!   multi-threaded batch serving (`Coordinator::infer_batch`).
//! * [`gateway`] — multi-tenant serving front-end over the deployment
//!   API: bounded admission, per-tenant quotas, deadline/priority-aware
//!   dispatch onto the process-wide runtime, with its own telemetry.

// Simulator idiom: hardware-signature functions carry many scalar
// parameters and loop nests use explicit index math; clippy's preferred
// rewrites obscure the datapath correspondence the code documents.
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::type_complexity)]
// Second line of defense behind ci/lint_invariants.py: every unsafe
// block must carry a `// SAFETY:` argument.
#![warn(clippy::undocumented_unsafe_blocks)]

pub mod abb;
pub mod analysis;
pub mod cluster;
pub mod coordinator;
pub mod core;
pub mod dnn;
pub mod figures;
pub mod gateway;
pub mod isa;
pub mod kernels;
pub mod mapping;
pub mod metrics;
pub mod power;
pub mod rbe;
pub mod runtime;
pub mod soc;
pub mod util;
