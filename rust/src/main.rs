//! `marsellus` CLI — leader entrypoint for the Marsellus SoC reproduction.
//!
//! ```text
//! marsellus smoke    [--artifacts DIR]        check the execution runtime
//! marsellus figure   <id>|all [--fast]        regenerate a paper figure
//! marsellus infer    [--network ID] [--config uniform8|mixed]
//!                    [--vdd V] [--seed N] [--check LAYER]
//!                    [--threads T] [--profile]
//!                    [--exec owned|global]
//!                    [--artifacts DIR]        end-to-end inference
//!                                             (T > 1: latency mode —
//!                                             packing bands + conv
//!                                             tiles over T lanes of
//!                                             the process-wide
//!                                             runtime; --profile
//!                                             prints the per-layer
//!                                             setup/pack/compute split
//!                                             + worker telemetry)
//! marsellus batch    [--network ID] [--n N] [--threads T] [--config C]
//!                    [--seed S] [--exec owned|global]
//!                    [--schedule auto|batch|latency|hybrid]
//!                                             scheduled batch inference
//! marsellus tune     [--network ID] [--config C] [--seed S]
//!                    [--threads T] [--trials N] [--tune-dir DIR]
//!                    [--json PATH]            deploy-time autotuning:
//!                                             micro-benchmark kernel
//!                                             variants per conv layer,
//!                                             persist + report the
//!                                             winning config
//! marsellus serve    [--trace TSV] [--requests N] [--queue-depth D]
//!                    [--inflight I] [--threads T] [--deadline-us U]
//!                    [--starve-bound K] [--vdd V]
//!                    [--serve-expired] [--reap-us U]
//!                    [--brownout W] [--brownout-lanes L]
//!                    [--chaos SEED]
//!                    [--artifacts DIR]        multi-tenant serving
//!                                             through the admission
//!                                             gateway: replay a
//!                                             traffic trace (or a
//!                                             synthetic 2-tenant mix)
//!                                             and report admission /
//!                                             lifecycle / per-tenant
//!                                             latency telemetry + the
//!                                             plan-cache residency
//!                                             split. --serve-expired
//!                                             serves past-deadline
//!                                             requests instead of
//!                                             shedding; --brownout W
//!                                             sets the overload
//!                                             high-watermark; --chaos
//!                                             arms seeded fault
//!                                             injection (needs
//!                                             --features chaos)
//! marsellus networks [--plans]                list deployable networks
//!                                             (--plans: deploy each and
//!                                             print the per-deployment
//!                                             plan-cache byte split)
//! marsellus list                              list figure ids
//! ```
//!
//! `--network` names a `dnn` registry entry (default `resnet20`); the
//! CLI deploys `Coordinator::deploy(NetworkSpec)` and streams through
//! the returned handle. `--schedule` picks the hybrid batch x tile
//! scheduler's shape (default `auto`: image shards for the bulk of the
//! batch, the remainder tiled within-image over the same worker pool).
//! `infer` and `batch` accept `--tune` to serve from an autotuned plan
//! (tuning once, persisting beside the plan cache); `MARSELLUS_TUNE=1`
//! opts every deploy in (with `MARSELLUS_TUNE_TRIALS`,
//! `MARSELLUS_TUNE_THREADS`, `MARSELLUS_TUNE_DIR`).
//! Parallel serving runs on the process-wide work-stealing runtime by
//! default (workers spawned once, sized to cores;
//! `MARSELLUS_POOL_THREADS` overrides); `--exec owned` (or
//! `MARSELLUS_EXEC=owned`) opts a call back into the PR-5 scoped
//! per-call pool — bitwise-identical logits, kept for A/B measurement.
//! Backend selection: `MARSELLUS_BACKEND=native|pjrt` (default native).
//! Plan-cache bound: `MARSELLUS_PLAN_CACHE_BYTES` (default 256 MiB).

use anyhow::{bail, ensure, Context, Result};
use marsellus::coordinator::{Coordinator, Schedule, ScheduleMode};
use marsellus::dnn::{NetworkSpec, PrecisionConfig};
use marsellus::power::OperatingPoint;
use marsellus::runtime::{
    ExecRuntime, TuneOptions, TunedConfig, DEFAULT_TUNE_TRIALS,
};
use marsellus::util::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("smoke") => smoke(&args),
        Some("figure") => figure(&args),
        Some("infer") => infer(&args),
        Some("batch") => batch(&args),
        Some("tune") => tune(&args),
        Some("serve") => serve(&args),
        Some("networks") => {
            for def in marsellus::dnn::registry::NETWORKS {
                println!("{:<10} {}", def.id, def.description);
            }
            if args.flag("plans") {
                networks_plans(&args)?;
            }
            Ok(())
        }
        Some("list") => {
            for id in marsellus::figures::ALL {
                println!("{id}");
            }
            Ok(())
        }
        other => {
            eprintln!(
                "usage: marsellus \
                 <smoke|figure|infer|batch|tune|serve|networks|list> \
                 [options]"
            );
            bail!("unknown command {other:?}")
        }
    }
}

fn artifacts_dir(args: &Args) -> std::path::PathBuf {
    marsellus::runtime::Runtime::resolve_artifacts_dir(args.get("artifacts"))
}

fn smoke(args: &Args) -> Result<()> {
    let rt = marsellus::runtime::Runtime::cpu(artifacts_dir(args))?;
    println!("backend   = {}", rt.kind().as_str());
    println!("platform  = {}", rt.platform());
    let names = rt.list_artifacts();
    println!("artifacts = {}", names.len());
    // compile + run one artifact end to end as the smoke signal
    if let Some(name) = names.iter().find(|n| n.starts_with("avgpool")) {
        let exe = rt.load(name)?;
        let x = vec![1i32; 8 * 8 * 64];
        let out = exe.execute_i32(&[marsellus::runtime::TensorArg::new(
            x,
            vec![8, 8, 64],
        )])?;
        println!("{name} -> {} outputs, first = {}", out[0].len(), out[0][0]);
    }
    println!("smoke OK");
    Ok(())
}

fn figure(args: &Args) -> Result<()> {
    let fast = args.flag("fast");
    let Some(id) = args.positional.get(1) else {
        bail!("figure id required; try `marsellus list`");
    };
    if id == "all" {
        for id in marsellus::figures::ALL {
            println!("{}\n", marsellus::figures::generate(id, fast)?);
        }
        return Ok(());
    }
    println!("{}", marsellus::figures::generate(id, fast)?);
    Ok(())
}

fn parse_config(args: &Args) -> Result<PrecisionConfig> {
    match args.get_or("config", "mixed") {
        "uniform8" => Ok(PrecisionConfig::Uniform8),
        "mixed" => Ok(PrecisionConfig::Mixed),
        other => bail!("unknown config {other}"),
    }
}

fn parse_spec(args: &Args) -> Result<NetworkSpec> {
    let network = args.get_or("network", "resnet20");
    let seed = args.get_usize("seed", 42)? as u64;
    Ok(NetworkSpec::new(network, parse_config(args)?, seed))
}

/// `--exec owned|global`, falling back to the `MARSELLUS_EXEC` process
/// default (global).
fn parse_exec(args: &Args) -> Result<ExecRuntime> {
    match args.get("exec") {
        Some(v) => v.parse().map_err(anyhow::Error::msg),
        None => Ok(ExecRuntime::from_env()),
    }
}

/// Tuning options shared by `marsellus tune` and the `--tune` flags:
/// `--threads` (default: the machine's cores) x `--trials` (default 3),
/// persisting under `--tune-dir` (default `<artifacts>/tuned`).
fn tune_options(args: &Args, threads: usize) -> Result<TuneOptions> {
    let cores = std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(4);
    let threads = if threads > 1 { threads } else { cores };
    let trials =
        args.get_usize("trials", DEFAULT_TUNE_TRIALS as usize)? as u32;
    let dir = match args.get("tune-dir") {
        Some(d) => std::path::PathBuf::from(d),
        None => artifacts_dir(args).join("tuned"),
    };
    Ok(TuneOptions { threads, trials, persist_dir: Some(dir) })
}

fn infer(args: &Args) -> Result<()> {
    let coord = Coordinator::new(artifacts_dir(args))?;
    let spec = parse_spec(args)?;
    let vdd = args.get_f64("vdd", 0.8)?;
    let op = OperatingPoint::at_vdd(vdd);

    let threads = args.get_usize("threads", 1)?;
    let exec = parse_exec(args)?;
    let deployment = if args.flag("tune") {
        coord.deploy_tuned(&spec, &tune_options(args, threads)?)?
    } else {
        coord.deploy(&spec)?
    };
    let (h, c) = deployment.input_dims();
    let mut rng = marsellus::util::Rng::new(spec.seed);
    let image = deployment.random_input(&mut rng);
    println!(
        "deployed {spec}: {} layers, input {h}x{h}x{c} @ {} bits",
        deployment.layers().len(),
        deployment.input_bits()
    );
    if let Some(cfg) = deployment.tuned() {
        println!(
            "tuned: {} layer pick(s), predicted {:.2}x vs heuristic, \
             hybrid cutover {}",
            cfg.layers.len(),
            cfg.predicted_speedup(),
            cfg.hybrid_cutover()
        );
    }
    let res = match args.get("check") {
        // cross-checking forces the per-call path; pick a small layer
        Some(layer) => {
            if threads > 1 {
                println!(
                    "note: --check forces the sequential per-call path; \
                     --threads {threads} is ignored"
                );
            }
            deployment.infer_cross_checked(&op, &image, &[layer])?
        }
        // latency mode: tile one image's conv layers across workers
        None if threads > 1 => {
            println!(
                "latency mode: conv tiles across {threads} lanes \
                 ({exec:?} runtime)"
            );
            deployment.infer_latency_on(&op, &image, threads, exec)?
        }
        None => deployment.infer(&op, &image)?,
    };
    if args.flag("profile") {
        let (split, pool) =
            deployment.profile_scheduled_on(&image, threads, exec)?;
        print!("{}", marsellus::metrics::render_setup_compute(&split));
        let conv_layers = deployment
            .layers()
            .iter()
            .filter(|l| l.op.on_rbe())
            .count();
        println!(
            "exec: {} worker(s), {} spawned by this call, {} job(s) \
             streamed (per-layer respawning would cost ~{} spawns per \
             image)",
            pool.width,
            pool.spawned_threads,
            pool.jobs,
            pool.width.saturating_sub(1) * conv_layers,
        );
    }
    println!("logits        = {:?}", res.logits);
    if res.cross_checked > 0 {
        println!(
            "cross-checked = {} layer(s) vs rust bit-serial model",
            res.cross_checked
        );
    }
    println!(
        "latency       = {:.0} µs   energy = {:.1} µJ   ({:.2} Top/s/W)",
        res.report.total_latency_us(),
        res.report.total_energy_uj(),
        res.report.tops_per_w()
    );
    Ok(())
}

fn batch(args: &Args) -> Result<()> {
    let coord = Coordinator::new(artifacts_dir(args))?;
    let spec = parse_spec(args)?;
    let n = args.get_usize("n", 8)?;
    let threads = args.get_usize("threads", 4)?;
    let vdd = args.get_f64("vdd", 0.8)?;
    let mode: ScheduleMode = args.get_or("schedule", "auto").parse()?;
    let exec = parse_exec(args)?;
    let sched = Schedule { threads, mode };

    let deployment = if args.flag("tune") {
        coord.deploy_tuned(&spec, &tune_options(args, threads)?)?
    } else {
        coord.deploy(&spec)?
    };
    if let Some(cfg) = deployment.tuned() {
        println!(
            "tuned: {} layer pick(s), predicted {:.2}x vs heuristic, \
             hybrid cutover {}",
            cfg.layers.len(),
            cfg.predicted_speedup(),
            cfg.hybrid_cutover()
        );
    }
    let mut rng = marsellus::util::Rng::new(spec.seed ^ 0xBA7C4);
    let images: Vec<Vec<i32>> =
        (0..n).map(|_| deployment.random_input(&mut rng)).collect();

    println!(
        "schedule: {:?} over {threads} lane(s) ({n} image(s), {:?} \
         runtime)",
        mode, exec
    );
    let t0 = std::time::Instant::now();
    let results = deployment.infer_scheduled_on(
        &OperatingPoint::at_vdd(vdd),
        &images,
        sched,
        exec,
    )?;
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    for (i, r) in results.iter().enumerate() {
        let top = r
            .logits
            .iter()
            .enumerate()
            .max_by_key(|&(_, v)| *v)
            .map(|(c, _)| c)
            .unwrap_or(0);
        println!("input {i}: class {top}  logits[..10] {:?}",
                 &r.logits[..r.logits.len().min(10)]);
    }
    let sim_us: f64 =
        results.iter().map(|r| r.report.total_latency_us()).sum();
    println!(
        "batch of {n} x {spec} on {threads} thread(s) [{} backend]: \
         host {wall_ms:.0} ms, simulated SoC time {sim_us:.0} µs total",
        coord.runtime.kind().as_str(),
    );
    println!(
        "runtime cache: {} executables, {} hits / {} compiles",
        coord.runtime.cached_executables(),
        coord.runtime.cache_hits(),
        coord.runtime.cache_misses(),
    );
    println!(
        "plan cache: {} deployment(s), {} KiB resident / {} KiB budget, \
         {} eviction(s)",
        coord.runtime.cached_plans(),
        coord.runtime.plan_bytes() / 1024,
        coord.runtime.plan_cache_budget() / 1024,
        coord.runtime.plan_evictions(),
    );
    if exec == ExecRuntime::Global && threads > 1 {
        let g = marsellus::runtime::global().telemetry();
        println!(
            "global runtime: {} worker(s) ({} spawned once per \
             process), {} job(s) streamed, {} steal(s)",
            g.width, g.spawned_threads, g.jobs, g.steals,
        );
    }
    Ok(())
}

fn tune(args: &Args) -> Result<()> {
    let coord = Coordinator::new(artifacts_dir(args))?;
    let spec = parse_spec(args)?;
    let threads = args.get_usize("threads", 0)?;
    let opts = tune_options(args, threads)?;
    println!(
        "tuning {spec}: {} trial(s) per candidate over {} worker(s)",
        opts.trials, opts.threads
    );
    let t0 = std::time::Instant::now();
    let deployment = coord.deploy_tuned(&spec, &opts)?;
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let cfg = deployment
        .tuned()
        .context("tuned deployment carries no config")?;
    println!(
        "{:<16} {:>6} {:>5} {:>5} {:>13} {:>9} {:>8}",
        "layer", "width", "tile", "band", "heuristic_us", "tuned_us",
        "speedup"
    );
    for l in &cfg.layers {
        let width = l
            .width
            .map(|w| w.lanes().to_string())
            .unwrap_or_else(|| "-".into());
        println!(
            "{:<16} {:>6} {:>5} {:>5} {:>13.1} {:>9.1} {:>7.2}x",
            l.layer,
            width,
            l.factors.tile,
            l.factors.band,
            l.heuristic_us,
            l.tuned_us,
            l.speedup()
        );
    }
    println!(
        "predicted speedup {:.2}x over the heuristic config \
         (measured layers, sum of best trials)",
        cfg.predicted_speedup()
    );
    println!(
        "tile speedup {:.2} -> hybrid cutover {} (fixed cap {})",
        cfg.tile_speedup,
        cfg.hybrid_cutover(),
        marsellus::runtime::HYBRID_TILE_SPEEDUP_CAP,
    );
    println!(
        "tuned {} in {wall_ms:.0} ms on {}",
        cfg.spec, cfg.fingerprint
    );
    if cfg.trials > 0 {
        // the persisted sidecar must reproduce this config byte for
        // byte — the CI tuner-smoke step relies on this check
        let dir = opts.persist_dir.as_ref().expect("cli always persists");
        let reloaded = TunedConfig::load(dir, &cfg.spec, &cfg.fingerprint)?
            .context("persisted tuned config did not reload")?;
        ensure!(
            reloaded.to_tsv() == cfg.to_tsv(),
            "persisted tuned config does not round-trip"
        );
        println!(
            "config persisted + round-tripped: {}",
            TunedConfig::path_in(dir, &cfg.spec, &cfg.fingerprint)
                .display()
        );
    } else {
        println!("trial budget 0: heuristic control config, not persisted");
    }
    if let Some(path) = args.get("json") {
        write_tune_json(path, cfg)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn write_tune_json(path: &str, cfg: &TunedConfig) -> Result<()> {
    let mut layers = String::new();
    for (i, l) in cfg.layers.iter().enumerate() {
        if i > 0 {
            layers.push_str(",\n");
        }
        layers.push_str(&format!(
            "    {{\"layer\": \"{}\", \"width\": {}, \"tile_factor\": {}, \
             \"band_factor\": {}, \"tuned_us\": {:.1}, \
             \"heuristic_us\": {:.1}}}",
            l.layer,
            l.width.map(|w| w.lanes()).unwrap_or(0),
            l.factors.tile,
            l.factors.band,
            l.tuned_us,
            l.heuristic_us,
        ));
    }
    let json = format!(
        "{{\n  \"spec\": \"{}\",\n  \"fingerprint\": \"{}\",\n  \
         \"threads\": {},\n  \"trials\": {},\n  \
         \"tile_speedup\": {:.4},\n  \"hybrid_cutover\": {},\n  \
         \"predicted_speedup\": {:.4},\n  \"layers\": [\n{}\n  ]\n}}\n",
        cfg.spec,
        cfg.fingerprint,
        cfg.threads,
        cfg.trials,
        cfg.tile_speedup,
        cfg.hybrid_cutover(),
        cfg.predicted_speedup(),
        layers,
    );
    std::fs::write(path, json)
        .with_context(|| format!("writing {path}"))?;
    Ok(())
}

/// One request of a serving trace: who asks for what, how big, how
/// urgent.
struct TraceReq {
    tenant: String,
    spec: NetworkSpec,
    images: usize,
    priority: marsellus::gateway::Priority,
    deadline: Option<std::time::Duration>,
    /// Replay-side cancellation: submit this request, then cancel its
    /// ticket before waiting (exercises `Ticket::cancel` from a trace).
    cancel: bool,
}

/// Parse a whitespace-separated trace file: one request per line,
/// `tenant network config seed images priority deadline_us [cancel]`
/// (`deadline_us` 0 = none; the optional 8th column is `cancel`/`1` to
/// cancel the ticket after submit, `-`/`0` or absent to wait normally
/// — 7-column traces stay valid); `#` starts a comment.
fn parse_trace(path: &str) -> Result<Vec<TraceReq>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {path}"))?;
    let mut reqs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split_whitespace().collect();
        ensure!(
            fields.len() == 7 || fields.len() == 8,
            "{path}:{}: expected 7 or 8 fields (tenant network config \
             seed images priority deadline_us [cancel]), got {}",
            lineno + 1,
            fields.len()
        );
        let config = match fields[2] {
            "uniform8" => PrecisionConfig::Uniform8,
            "mixed" => PrecisionConfig::Mixed,
            other => bail!("{path}:{}: unknown config {other}", lineno + 1),
        };
        let seed: u64 = fields[3]
            .parse()
            .with_context(|| format!("{path}:{}: seed", lineno + 1))?;
        let images: usize = fields[4]
            .parse()
            .with_context(|| format!("{path}:{}: images", lineno + 1))?;
        let deadline_us: u64 = fields[6].parse().with_context(|| {
            format!("{path}:{}: deadline_us", lineno + 1)
        })?;
        let cancel = match fields.get(7).copied() {
            None | Some("-") | Some("0") => false,
            Some("cancel") | Some("1") => true,
            Some(other) => bail!(
                "{path}:{}: unknown cancel flag {other:?} (use \
                 cancel/1 to cancel, -/0 to wait)",
                lineno + 1
            ),
        };
        reqs.push(TraceReq {
            tenant: fields[0].to_string(),
            spec: NetworkSpec::new(fields[1], config, seed),
            images: images.max(1),
            priority: fields[5].parse()?,
            deadline: (deadline_us > 0)
                .then(|| std::time::Duration::from_micros(deadline_us)),
            cancel,
        });
    }
    ensure!(!reqs.is_empty(), "{path}: trace holds no requests");
    Ok(reqs)
}

/// The built-in 2-tenant traffic mix when no `--trace` is given:
/// `interactive` submits high-priority single-image ResNet-20 requests
/// with a deadline, `bulk` submits normal-priority 4-image KWS batches.
fn synthetic_trace(requests: usize) -> Vec<TraceReq> {
    (0..requests.max(1))
        .map(|i| {
            if i % 2 == 0 {
                TraceReq {
                    tenant: "interactive".into(),
                    spec: NetworkSpec::new(
                        "resnet20",
                        PrecisionConfig::Mixed,
                        42,
                    ),
                    images: 1,
                    priority: marsellus::gateway::Priority::High,
                    deadline: Some(std::time::Duration::from_secs(30)),
                    cancel: false,
                }
            } else {
                TraceReq {
                    tenant: "bulk".into(),
                    spec: NetworkSpec::new(
                        "kws",
                        PrecisionConfig::Mixed,
                        7,
                    ),
                    images: 4,
                    priority: marsellus::gateway::Priority::Normal,
                    deadline: None,
                    cancel: false,
                }
            }
        })
        .collect()
}

/// `--chaos <seed>`: arm the deterministic fault-injection harness for
/// the whole serve run. Only available when the binary was built with
/// `--features chaos` (the harness is compiled out of plain release
/// builds); without the feature the flag fails loudly rather than
/// silently serving fault-free.
fn arm_chaos(args: &Args) -> Result<bool> {
    let Some(raw) = args.get("chaos") else {
        return Ok(false);
    };
    let seed: u64 = raw
        .parse()
        .with_context(|| format!("--chaos seed {raw:?}"))?;
    #[cfg(feature = "chaos")]
    {
        marsellus::analysis::failpoint::arm_seed(seed);
        println!("chaos: failpoints armed from seed {seed}");
        Ok(true)
    }
    #[cfg(not(feature = "chaos"))]
    {
        let _ = seed;
        bail!(
            "--chaos needs the fault-injection harness: rebuild with \
             `cargo build --features chaos`"
        );
    }
}

fn serve(args: &Args) -> Result<()> {
    use marsellus::gateway::{Gateway, GatewayConfig, ServeError};

    let chaos = arm_chaos(args)?;
    let coord =
        std::sync::Arc::new(Coordinator::new(artifacts_dir(args))?);
    let cfg = GatewayConfig {
        queue_depth: args.get_usize("queue-depth", 32)?,
        per_tenant_inflight: args.get_usize("inflight", 16)?,
        default_deadline: {
            let us = args.get_usize("deadline-us", 0)? as u64;
            (us > 0).then(|| std::time::Duration::from_micros(us))
        },
        threads: args.get_usize("threads", 0)?,
        starvation_bound: args.get_usize("starve-bound", 4)?,
        shed_expired: !args.flag("serve-expired"),
        reap_interval: std::time::Duration::from_micros(
            args.get_usize("reap-us", 2000)? as u64,
        ),
        brownout_watermark: args.get_usize("brownout", 0)?,
        brownout_lanes: args.get_usize("brownout-lanes", 0)?,
    };
    let op = OperatingPoint::at_vdd(args.get_f64("vdd", 0.8)?);
    let reqs = match args.get("trace") {
        Some(path) => {
            let reqs = parse_trace(path)?;
            println!("replaying {} request(s) from {path}", reqs.len());
            reqs
        }
        None => {
            let n = args.get_usize("requests", 12)?;
            println!(
                "synthetic 2-tenant trace: {n} request(s) \
                 (interactive resnet20 x1 / bulk kws x4)"
            );
            synthetic_trace(n)
        }
    };

    // deploy each spec up front (warms the plan cache so the replay
    // measures serving, not first-touch compiles) and pre-generate
    // every request's images
    let mut rng = marsellus::util::Rng::new(0x5E44E);
    let mut images: Vec<Vec<Vec<i32>>> = Vec::with_capacity(reqs.len());
    for r in &reqs {
        let d = coord.deploy(&r.spec)?;
        images.push(
            (0..r.images).map(|_| d.random_input(&mut rng)).collect(),
        );
    }
    println!(
        "gateway: queue_depth {}, per-tenant inflight {}, {} lane(s), \
         starvation bound {}, {}{}",
        cfg.queue_depth,
        cfg.per_tenant_inflight,
        if cfg.threads > 0 {
            cfg.threads
        } else {
            marsellus::runtime::global().width()
        },
        cfg.starvation_bound,
        if cfg.shed_expired {
            "shed expired deadlines"
        } else {
            "serve expired deadlines"
        },
        if cfg.brownout_watermark > 0 {
            format!(", brownout watermark {}", cfg.brownout_watermark)
        } else {
            String::new()
        },
    );

    let gateway = Gateway::new(coord.clone(), cfg)?;
    let t0 = std::time::Instant::now();
    let mut tickets = Vec::new();
    let mut rejected = 0usize;
    for (r, imgs) in reqs.iter().zip(images) {
        match gateway.submit(
            &r.tenant,
            &r.spec,
            &op,
            imgs,
            r.priority,
            r.deadline,
        ) {
            Ok(t) => tickets.push((r.cancel, t)),
            Err(e) => {
                rejected += 1;
                println!("rejected ({}, {}): {e}", r.tenant, r.spec);
            }
        }
    }
    // replay-side cancellations first (while the backlog is still
    // queued), then wait every ticket to its typed outcome
    for (want_cancel, t) in &tickets {
        if *want_cancel {
            println!("cancel request {}: {:?}", t.id(), t.cancel());
        }
    }
    let mut served_images = 0usize;
    let mut cancelled = 0usize;
    let mut shed = 0usize;
    let mut panicked = 0usize;
    for (_, t) in tickets {
        match t.wait() {
            Ok(done) => served_images += done.results.len(),
            Err(err) => match err.downcast_ref::<ServeError>() {
                Some(ServeError::Cancelled { .. }) => cancelled += 1,
                Some(ServeError::DeadlineExceeded { id, late_us }) => {
                    println!("shed request {id}: {late_us}us late");
                    shed += 1;
                }
                Some(ServeError::Panicked { id, .. }) => {
                    println!("panicked request {id} (caught, typed)");
                    panicked += 1;
                }
                // anything untyped (deploy/quota failure) aborts the
                // replay loudly
                None => return Err(err),
            },
        }
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    let snap = gateway.telemetry().snapshot();
    println!(
        "served {served_images} image(s) in {wall_ms:.0} ms \
         ({rejected} rejected at admission, {cancelled} cancelled, \
         {shed} shed, {panicked} panicked)"
    );
    println!(
        "gateway: {} submitted / {} admitted / {} rejected (full {}, \
         tenant {}, shutdown {}, brownout {}), {} completed, {} \
         failed, {} cancelled, {} shed, {} panicked, {} \
         deadline-missed, {} degraded dispatch(es)",
        snap.submitted,
        snap.admitted,
        snap.rejected(),
        snap.rejected_full,
        snap.rejected_tenant,
        snap.rejected_shutdown,
        snap.rejected_brownout,
        snap.completed,
        snap.failed,
        snap.cancelled,
        snap.shed,
        snap.panicked,
        snap.deadline_missed,
        snap.degraded,
    );
    println!(
        "{:<14} {:>8} {:>9} {:>8} {:>9} {:>6} {:>7} {:>9} {:>9}",
        "tenant", "admitted", "completed", "rejected", "cancelled",
        "shed", "missed", "p50_us", "p99_us"
    );
    for t in &snap.tenants {
        println!(
            "{:<14} {:>8} {:>9} {:>8} {:>9} {:>6} {:>7} {:>9} {:>9}",
            t.tenant,
            t.admitted,
            t.completed,
            t.rejected,
            t.cancelled,
            t.shed,
            t.deadline_missed,
            t.p50_us,
            t.p99_us,
        );
    }
    // the lifecycle ledger must balance after a full drain — under
    // --chaos this is the assertion the CI smoke leans on
    ensure!(
        snap.reconciles(),
        "gateway lifecycle counters do not reconcile after drain: \
         {snap:?}"
    );
    if chaos {
        println!("chaos: lifecycle counters reconcile after drain");
    }
    print_plan_residency(&coord);
    let g = marsellus::runtime::global().telemetry();
    println!(
        "global runtime: {} worker(s) ({} spawned once per process), \
         {} job(s) streamed",
        g.width, g.spawned_threads, g.jobs,
    );
    Ok(())
}

/// The per-deployment plan-cache byte split (`marsellus networks
/// --plans` and the tail of `marsellus serve`).
fn print_plan_residency(coord: &Coordinator) {
    let rt = &coord.runtime;
    println!(
        "plan cache: {} deployment(s), {} KiB resident / {} KiB \
         budget, {} KiB pinned, {} eviction(s)",
        rt.cached_plans(),
        rt.plan_bytes() / 1024,
        rt.plan_cache_budget() / 1024,
        rt.pinned_plan_bytes() / 1024,
        rt.plan_evictions(),
    );
    for row in rt.plan_residency() {
        println!(
            "  {:<28} {:>8} KiB{}",
            row.spec.to_string(),
            row.bytes / 1024,
            if row.pinned { "  [pinned]" } else { "" },
        );
    }
}

/// `marsellus networks --plans`: deploy every registry network once
/// (mixed precision, seed 42) and print the per-deployment byte split
/// of the plan cache — the per-tenant half of the `plan_bytes`
/// telemetry.
fn networks_plans(args: &Args) -> Result<()> {
    let coord = Coordinator::new(artifacts_dir(args))?;
    for def in marsellus::dnn::registry::NETWORKS {
        let spec =
            NetworkSpec::new(def.id, PrecisionConfig::Mixed, 42);
        coord.deploy(&spec)?;
    }
    print_plan_residency(&coord);
    Ok(())
}
