//! `marsellus` CLI — leader entrypoint for the Marsellus SoC reproduction.
//!
//! ```text
//! marsellus smoke   [--artifacts DIR]        check the PJRT runtime
//! marsellus figure  <id>|all [--fast]        regenerate a paper figure
//! marsellus infer   [--artifacts DIR] [--config uniform8|mixed]
//!                   [--vdd V] [--seed N]     end-to-end ResNet-20
//! marsellus list                             list figure ids
//! ```

use anyhow::{bail, Result};
use marsellus::coordinator::{random_image, Coordinator};
use marsellus::dnn::PrecisionConfig;
use marsellus::power::OperatingPoint;
use marsellus::util::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("smoke") => smoke(&args),
        Some("figure") => figure(&args),
        Some("infer") => infer(&args),
        Some("list") => {
            for id in marsellus::figures::ALL {
                println!("{id}");
            }
            Ok(())
        }
        other => {
            eprintln!(
                "usage: marsellus <smoke|figure|infer|list> [options]"
            );
            bail!("unknown command {other:?}")
        }
    }
}

fn smoke(args: &Args) -> Result<()> {
    let rt =
        marsellus::runtime::Runtime::cpu(args.get_or("artifacts", "artifacts"))?;
    println!("platform  = {}", rt.platform());
    let names = rt.list_artifacts();
    println!("artifacts = {}", names.len());
    // compile + run one artifact end to end as the smoke signal
    if let Some(name) = names.iter().find(|n| n.starts_with("avgpool")) {
        let exe = rt.load(name)?;
        let x = vec![1i32; 8 * 8 * 64];
        let out = exe.execute_i32(&[marsellus::runtime::TensorArg::new(
            x,
            vec![8, 8, 64],
        )])?;
        println!("{name} -> {} outputs, first = {}", out[0].len(), out[0][0]);
    }
    println!("smoke OK");
    Ok(())
}

fn figure(args: &Args) -> Result<()> {
    let fast = args.flag("fast");
    let Some(id) = args.positional.get(1) else {
        bail!("figure id required; try `marsellus list`");
    };
    if id == "all" {
        for id in marsellus::figures::ALL {
            println!("{}\n", marsellus::figures::generate(id, fast)?);
        }
        return Ok(());
    }
    println!("{}", marsellus::figures::generate(id, fast)?);
    Ok(())
}

fn infer(args: &Args) -> Result<()> {
    let coord = Coordinator::new(args.get_or("artifacts", "artifacts"))?;
    let config = match args.get_or("config", "mixed") {
        "uniform8" => PrecisionConfig::Uniform8,
        "mixed" => PrecisionConfig::Mixed,
        other => bail!("unknown config {other}"),
    };
    let vdd = args.get_f64("vdd", 0.8)?;
    let seed = args.get_usize("seed", 42)? as u64;
    let mut rng = marsellus::util::Rng::new(seed);
    let i_bits = if config == PrecisionConfig::Uniform8 { 8 } else { 8 };
    let image = random_image(i_bits, &mut rng);
    let res = coord.infer_resnet20(
        config,
        &OperatingPoint::at_vdd(vdd),
        &image,
        seed,
        &["stage3.b2.conv1"],
    )?;
    println!("logits        = {:?}", res.logits);
    println!("cross-checked = {} layer(s) vs rust bit-serial model",
             res.cross_checked);
    println!(
        "latency       = {:.0} µs   energy = {:.1} µJ   ({:.2} Top/s/W)",
        res.report.total_latency_us(),
        res.report.total_energy_uj(),
        res.report.tops_per_w()
    );
    Ok(())
}
