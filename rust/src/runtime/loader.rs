//! The [`Runtime`]: a shared execution backend plus the per-artifact
//! compile cache and the bounded, LRU-evicting deployment plan cache.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::dnn::NetworkSpec;

use super::backend::{BackendKind, ExecBackend};
use super::executable::Executable;
use super::plan::NetworkPlan;

/// Default plan-cache byte budget when `MARSELLUS_PLAN_CACHE_BYTES` is
/// unset: roomy enough for a ResNet-18 deployment plus a handful of
/// small-network tenants, small enough to bound many-tenant serving.
pub const DEFAULT_PLAN_CACHE_BYTES: usize = 256 * 1024 * 1024;

/// One resident deployment plan plus its eviction metadata.
struct PlanSlot {
    plan: Arc<NetworkPlan>,
    bytes: usize,
    /// Logical LRU timestamp: bumped from `plan_clock` on every hit.
    last_used: u64,
    /// Pinned plans are exempt from LRU eviction ([`Runtime::pin_plan`])
    /// but still counted against the byte budget.
    pinned: bool,
}

/// One row of [`Runtime::plan_residency`]: the per-deployment byte
/// split of the plan cache (today `plan_bytes` alone is the global
/// total).
#[derive(Debug, Clone)]
pub struct PlanResidency {
    /// The deployment this row accounts.
    pub spec: NetworkSpec,
    /// Resident bytes of its compiled plan.
    pub bytes: usize,
    /// Whether the plan is pinned against LRU eviction.
    pub pinned: bool,
    /// Logical LRU timestamp of the last hit (higher = more recent).
    pub last_used: u64,
}

/// An execution backend plus a cache of compiled executables keyed by
/// artifact name, and a cache of precompiled [`NetworkPlan`]s keyed by
/// [`NetworkSpec`] (network id + precision config + weight seed).
///
/// Compilation is performed once per artifact (and plan compilation
/// once per deployment); subsequent lookups are O(1) and share the
/// compiled object via `Arc`. The plan cache is **bounded**: resident
/// plans are byte-accounted (`NetworkPlan::bytes`) and the
/// least-recently-used deployment is evicted once the total exceeds the
/// budget (`MARSELLUS_PLAN_CACHE_BYTES`, default 256 MiB), so
/// many-tenant serving has a memory ceiling instead of monotonic
/// growth — `plan_evictions`/`plan_bytes` report the telemetry. The
/// runtime is `Send + Sync` (backend is `Sync`, caches are behind
/// `Mutex`es), so the coordinator can share one instance across worker
/// threads — see `Deployment::infer_batch`.
pub struct Runtime {
    backend: Arc<dyn ExecBackend>,
    artifacts_dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    plans: Mutex<HashMap<NetworkSpec, PlanSlot>>,
    plan_hits: AtomicU64,
    plan_builds: AtomicU64,
    plan_evictions: AtomicU64,
    plan_bytes: AtomicUsize,
    plan_budget: AtomicUsize,
    plan_clock: AtomicU64,
}

/// Parse a `MARSELLUS_PLAN_CACHE_BYTES`-style value; `None`/empty/bad
/// values fall back to the default budget.
fn parse_plan_budget(v: Option<String>) -> usize {
    v.and_then(|s| s.trim().parse::<usize>().ok())
        .unwrap_or(DEFAULT_PLAN_CACHE_BYTES)
}

impl Runtime {
    /// Wrap an explicit backend. `artifacts_dir` is kept for diagnostics
    /// and for locating on-disk artifact files.
    pub fn with_backend(backend: Arc<dyn ExecBackend>, artifacts_dir: impl AsRef<Path>) -> Self {
        let budget =
            parse_plan_budget(std::env::var("MARSELLUS_PLAN_CACHE_BYTES").ok());
        Self {
            backend,
            artifacts_dir: artifacts_dir.as_ref().to_path_buf(),
            cache: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            plans: Mutex::new(HashMap::new()),
            plan_hits: AtomicU64::new(0),
            plan_builds: AtomicU64::new(0),
            plan_evictions: AtomicU64::new(0),
            plan_bytes: AtomicUsize::new(0),
            plan_budget: AtomicUsize::new(budget),
            plan_clock: AtomicU64::new(0),
        }
    }

    /// Pure-Rust native backend: the built-in layer zoo, extended by
    /// `manifest.tsv` if `artifacts_dir` has one.
    #[cfg(feature = "native")]
    pub fn native(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref();
        let manifest = crate::dnn::Manifest::load_or_builtin(dir)?;
        let backend = super::native::NativeBackend::from_manifest(&manifest);
        Ok(Self::with_backend(Arc::new(backend), dir))
    }

    /// PJRT CPU backend over on-disk HLO-text artifacts.
    #[cfg(feature = "pjrt")]
    pub fn pjrt(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref();
        let backend = super::pjrt::PjrtBackend::cpu(dir)?;
        Ok(Self::with_backend(Arc::new(backend), dir))
    }

    /// Backend selected by `MARSELLUS_BACKEND` (`native` | `pjrt`),
    /// defaulting to native when unset.
    pub fn from_env(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let choice = std::env::var("MARSELLUS_BACKEND").unwrap_or_default();
        if choice == "pjrt" {
            #[cfg(feature = "pjrt")]
            return Self::pjrt(artifacts_dir);
            #[cfg(not(feature = "pjrt"))]
            anyhow::bail!(
                "MARSELLUS_BACKEND=pjrt but the `pjrt` feature is not \
                 compiled in (rebuild with --features pjrt)"
            );
        }
        if choice == "native" {
            #[cfg(feature = "native")]
            return Self::native(artifacts_dir);
            #[cfg(not(feature = "native"))]
            anyhow::bail!(
                "MARSELLUS_BACKEND=native but the `native` feature is not \
                 compiled in (rebuild with --features native)"
            );
        }
        if !choice.is_empty() {
            anyhow::bail!("unknown MARSELLUS_BACKEND {choice:?} (expected native|pjrt)");
        }
        // no explicit choice: prefer native, fall back to whatever is built
        #[cfg(feature = "native")]
        return Self::native(artifacts_dir);
        #[cfg(all(not(feature = "native"), feature = "pjrt"))]
        return Self::pjrt(artifacts_dir);
        #[cfg(all(not(feature = "native"), not(feature = "pjrt")))]
        let _ = &artifacts_dir;
        #[cfg(all(not(feature = "native"), not(feature = "pjrt")))]
        anyhow::bail!(
            "no execution backend compiled in; build with \
             `--features native` (default) or `--features pjrt`"
        );
    }

    /// Historical constructor name (pre-backend-trait); now an alias for
    /// [`Runtime::from_env`].
    pub fn cpu(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        Self::from_env(artifacts_dir)
    }

    /// Resolve the artifacts directory for CLI/example entry points:
    /// an explicit `--artifacts` value wins; otherwise the first of
    /// `./artifacts` and `./rust/artifacts` that holds a `manifest.tsv`
    /// (so `make artifacts` output is found from the repo root); else
    /// `./artifacts` (the native backend needs no files anyway).
    pub fn resolve_artifacts_dir(explicit: Option<&str>) -> PathBuf {
        if let Some(d) = explicit {
            return PathBuf::from(d);
        }
        for cand in ["artifacts", "rust/artifacts"] {
            if Path::new(cand).join("manifest.tsv").exists() {
                return PathBuf::from(cand);
            }
        }
        PathBuf::from("artifacts")
    }

    pub fn backend(&self) -> &Arc<dyn ExecBackend> {
        &self.backend
    }

    pub fn kind(&self) -> BackendKind {
        self.backend.kind()
    }

    /// Platform name reported by the backend (e.g. "native", "cpu").
    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Directory this runtime resolves on-disk artifacts against.
    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// Load (or fetch from cache) the executable for artifact `name`.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(exe.clone());
        }
        // Compile outside the lock: backend compilation can be slow
        // (PJRT) and must not serialize unrelated worker threads. A racy
        // double-compile of the same name is benign — first insert wins.
        let compiled = self.backend.compile(name)?;
        let exe = Arc::new(Executable::new(name.to_string(), compiled));
        let mut cache = self.cache.lock().unwrap();
        let entry = cache.entry(name.to_string()).or_insert(exe);
        self.misses.fetch_add(1, Ordering::Relaxed);
        Ok(entry.clone())
    }

    /// True if the backend can execute the artifact `name` (used by tests
    /// to skip gracefully when `make artifacts` has not run and the
    /// backend needs files on disk).
    pub fn has_artifact(&self, name: &str) -> bool {
        self.backend.has_artifact(name)
    }

    /// True if the AOT artifact *file* exists on disk (independent of the
    /// active backend).
    pub fn artifact_file_exists(&self, name: &str) -> bool {
        self.artifacts_dir.join(format!("{name}.hlo.txt")).exists()
    }

    /// Names of all artifacts the backend can execute.
    pub fn list_artifacts(&self) -> Vec<String> {
        self.backend.list_artifacts()
    }

    /// Fetch (or compile, once) the precompiled layer-plan pipeline for
    /// the deployment identified by `spec`. This is the load-time half
    /// of the plan-driven serving path: after the first call for a spec,
    /// every subsequent `infer`/batch over the same deployment streams
    /// through the shared immutable plan. Two threads racing an uncached
    /// spec may both run `build`; the first insert wins, the duplicate
    /// is discarded and counted as a hit, so `plan_builds` always equals
    /// the number of distinct plans that entered the cache.
    ///
    /// Every hit bumps the deployment's LRU stamp; every insert runs the
    /// eviction sweep, so the cache never holds more than the byte
    /// budget across *multiple* residents (a single over-budget plan is
    /// kept — a bound must not refuse to serve the one active tenant).
    pub fn network_plan(
        &self,
        spec: &NetworkSpec,
        build: impl FnOnce() -> Result<NetworkPlan>,
    ) -> Result<Arc<NetworkPlan>> {
        self.network_plan_replacing(spec, |_| true, build)
    }

    /// Resident plan for `spec` if one is cached **and** `accept`s —
    /// the read-only half of [`Self::network_plan_replacing`], letting
    /// callers probe for (say) a suitably-tuned plan without committing
    /// to a build. An accepted hit bumps the LRU stamp and counts as a
    /// cache hit; a rejected resident is left untouched.
    pub fn cached_network_plan(
        &self,
        spec: &NetworkSpec,
        accept: impl Fn(&NetworkPlan) -> bool,
    ) -> Option<Arc<NetworkPlan>> {
        let mut plans = self.plans.lock().unwrap();
        let slot = plans.get_mut(spec)?;
        if !accept(&slot.plan) {
            return None;
        }
        slot.last_used = self.plan_clock.fetch_add(1, Ordering::Relaxed);
        self.plan_hits.fetch_add(1, Ordering::Relaxed);
        Some(slot.plan.clone())
    }

    /// [`Self::network_plan`] with an acceptance predicate: a resident
    /// plan failing `accept` (e.g. an untuned plan when the caller
    /// requires a tuned one, or vice versa) is **replaced** — the
    /// rejected resident is removed and un-accounted (this counts as a
    /// build of the successor, not an eviction, which telemetry
    /// reserves for budget pressure) and `build`'s result takes its
    /// slot. The race rules match [`Self::network_plan`], with `accept`
    /// arbitrating: losing a race to an acceptable plan serves it as a
    /// hit and discards the duplicate build.
    pub fn network_plan_replacing(
        &self,
        spec: &NetworkSpec,
        accept: impl Fn(&NetworkPlan) -> bool,
        build: impl FnOnce() -> Result<NetworkPlan>,
    ) -> Result<Arc<NetworkPlan>> {
        if let Some(plan) = self.cached_network_plan(spec, &accept) {
            return Ok(plan);
        }
        // Build outside the lock: plan compilation packs every weight
        // tensor of the network and must not serialize unrelated worker
        // threads.
        let built = Arc::new(build()?);
        let mut plans = self.plans.lock().unwrap();
        let mut pinned = false;
        if let Some(slot) = plans.get_mut(spec) {
            if accept(&slot.plan) {
                // lost the race to an acceptable plan: serve the
                // winner's, count a hit
                slot.last_used =
                    self.plan_clock.fetch_add(1, Ordering::Relaxed);
                self.plan_hits.fetch_add(1, Ordering::Relaxed);
                return Ok(slot.plan.clone());
            }
            let old = plans.remove(spec).expect("resident slot");
            self.plan_bytes.fetch_sub(old.bytes, Ordering::Relaxed);
            // a replaced resident keeps its pin: the residency
            // guarantee follows the spec, not one compiled artifact
            pinned = old.pinned;
        }
        let bytes = built.bytes();
        self.plan_builds.fetch_add(1, Ordering::Relaxed);
        self.plan_bytes.fetch_add(bytes, Ordering::Relaxed);
        plans.insert(
            spec.clone(),
            PlanSlot {
                plan: built.clone(),
                bytes,
                last_used: self.plan_clock.fetch_add(1, Ordering::Relaxed),
                pinned,
            },
        );
        self.evict_lru_over_budget(&mut plans);
        Ok(built)
    }

    /// Drop least-recently-used deployments until the resident total is
    /// back under budget (or no evictable plan remains). Caller holds
    /// the cache lock. Pinned plans and a sole resident are never
    /// victims: the bound sheds *other* tenants, it never evicts a plan
    /// a request may be streaming through ([`Self::pin_plan`]) or
    /// refuses the one active deployment.
    fn evict_lru_over_budget(&self, plans: &mut HashMap<NetworkSpec, PlanSlot>) {
        let budget = self.plan_budget.load(Ordering::Relaxed);
        while plans.len() > 1
            && self.plan_bytes.load(Ordering::Relaxed) > budget
        {
            let Some(victim) = plans
                .iter()
                .filter(|(_, slot)| !slot.pinned)
                .min_by_key(|(_, slot)| slot.last_used)
                .map(|(spec, _)| spec.clone())
            else {
                // every resident is pinned: nothing evictable, stay
                // over budget rather than break a residency guarantee
                break;
            };
            let slot = plans.remove(&victim).expect("victim is resident");
            self.plan_bytes.fetch_sub(slot.bytes, Ordering::Relaxed);
            self.plan_evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Pin `spec`'s resident plan: LRU eviction may no longer touch it,
    /// so a latency-tier tenant's plan can never be evicted mid-request
    /// by other tenants' churn. Pinned bytes stay counted against the
    /// budget; pinning fails loudly when the pinned set alone would
    /// exceed it (a quota nobody can serve under must be an error, not
    /// a silent over-commit). Errors also when `spec` is not resident —
    /// deploy first, then pin.
    pub fn pin_plan(&self, spec: &NetworkSpec) -> Result<()> {
        let mut plans = self.plans.lock().unwrap();
        let pinned_total: usize = plans
            .values()
            .filter(|slot| slot.pinned)
            .map(|slot| slot.bytes)
            .sum();
        let budget = self.plan_budget.load(Ordering::Relaxed);
        let Some(slot) = plans.get_mut(spec) else {
            anyhow::bail!(
                "cannot pin {spec}: no resident plan (deploy it first)"
            );
        };
        if slot.pinned {
            return Ok(());
        }
        anyhow::ensure!(
            pinned_total + slot.bytes <= budget,
            "cannot pin {spec}: pinned plans would hold {} bytes, \
             exceeding the {budget}-byte plan-cache budget — unpin \
             another plan or raise MARSELLUS_PLAN_CACHE_BYTES",
            pinned_total + slot.bytes,
        );
        slot.pinned = true;
        Ok(())
    }

    /// Make `spec`'s plan evictable again. Returns `true` when a
    /// resident pin was actually cleared.
    pub fn unpin_plan(&self, spec: &NetworkSpec) -> bool {
        let mut plans = self.plans.lock().unwrap();
        match plans.get_mut(spec) {
            Some(slot) if slot.pinned => {
                slot.pinned = false;
                true
            }
            _ => false,
        }
    }

    /// Total bytes held by pinned plans (counted inside
    /// [`Self::plan_bytes`], never evictable).
    pub fn pinned_plan_bytes(&self) -> usize {
        self.plans
            .lock()
            .unwrap()
            .values()
            .filter(|slot| slot.pinned)
            .map(|slot| slot.bytes)
            .sum()
    }

    /// Specs of the currently pinned plans (arbitrary order).
    pub fn pinned_plan_specs(&self) -> Vec<NetworkSpec> {
        self.plans
            .lock()
            .unwrap()
            .iter()
            .filter(|(_, slot)| slot.pinned)
            .map(|(spec, _)| spec.clone())
            .collect()
    }

    /// Resident plan bytes of one deployment, `None` when not cached —
    /// the per-tenant half of the `plan_bytes` telemetry (gateway
    /// quotas sum this over a tenant's specs).
    pub fn plan_bytes_of(&self, spec: &NetworkSpec) -> Option<usize> {
        self.plans.lock().unwrap().get(spec).map(|slot| slot.bytes)
    }

    /// Per-deployment residency rows (bytes, pin state, recency),
    /// sorted by spec for stable display — the split `marsellus
    /// networks --plans` prints. Row bytes always sum to
    /// [`Self::plan_bytes`].
    pub fn plan_residency(&self) -> Vec<PlanResidency> {
        let plans = self.plans.lock().unwrap();
        let mut rows: Vec<PlanResidency> = plans
            .iter()
            .map(|(spec, slot)| PlanResidency {
                spec: spec.clone(),
                bytes: slot.bytes,
                pinned: slot.pinned,
                last_used: slot.last_used,
            })
            .collect();
        rows.sort_by_key(|r| r.spec.to_string());
        rows
    }

    /// Number of plan-cache hits served so far (including builds
    /// discarded after losing an insert race).
    pub fn plan_hits(&self) -> u64 {
        self.plan_hits.load(Ordering::Relaxed)
    }

    /// Number of distinct network plans compiled into the cache so far
    /// (equals [`Self::cached_plans`] while nothing is evicted).
    pub fn plan_builds(&self) -> u64 {
        self.plan_builds.load(Ordering::Relaxed)
    }

    /// Number of deployments evicted from the plan cache so far.
    pub fn plan_evictions(&self) -> u64 {
        self.plan_evictions.load(Ordering::Relaxed)
    }

    /// Resident bytes currently held by the plan cache.
    pub fn plan_bytes(&self) -> usize {
        self.plan_bytes.load(Ordering::Relaxed)
    }

    /// The plan-cache byte budget currently in force.
    pub fn plan_cache_budget(&self) -> usize {
        self.plan_budget.load(Ordering::Relaxed)
    }

    /// Override the plan-cache byte budget (tests, admission control).
    /// Takes effect on the next insert; resident plans are not swept
    /// retroactively.
    pub fn set_plan_cache_budget(&self, bytes: usize) {
        self.plan_budget.store(bytes, Ordering::Relaxed);
    }

    /// Number of distinct network plans currently cached.
    pub fn cached_plans(&self) -> usize {
        self.plans.lock().unwrap().len()
    }

    /// Specs of the deployments currently resident in the plan cache
    /// (arbitrary order) — lets tests pin down LRU victims exactly.
    pub fn cached_plan_specs(&self) -> Vec<NetworkSpec> {
        self.plans.lock().unwrap().keys().cloned().collect()
    }

    /// Number of cache hits served so far (telemetry for tests/benches).
    pub fn cache_hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Number of compilations performed so far.
    pub fn cache_misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Number of distinct executables currently cached.
    pub fn cached_executables(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_budget_parsing() {
        assert_eq!(parse_plan_budget(None), DEFAULT_PLAN_CACHE_BYTES);
        assert_eq!(
            parse_plan_budget(Some(String::new())),
            DEFAULT_PLAN_CACHE_BYTES
        );
        assert_eq!(
            parse_plan_budget(Some("not-a-number".into())),
            DEFAULT_PLAN_CACHE_BYTES
        );
        assert_eq!(parse_plan_budget(Some(" 4096 ".into())), 4096);
        assert_eq!(parse_plan_budget(Some("0".into())), 0);
    }
}
