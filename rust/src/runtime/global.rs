//! Process-wide work-stealing execution runtime shared by all
//! deployments.
//!
//! The paper's cluster is one set of cores that every workload shares;
//! the PR-5 [`ExecPool`] still provisioned that fan-out *per serving
//! call*, so concurrent callers each spawned their own worker set and
//! oversubscribed the machine. [`GlobalRuntime`] promotes the pool to a
//! process singleton: workers are spawned once, lazily, on the first
//! parallel serving call ([`global`]), sized to the machine's cores
//! (`MARSELLUS_POOL_THREADS` overrides, clamped to 2x cores like the
//! scoped pool), and every deployment's jobs land on the same threads
//! for the life of the process — `spawned_threads` telemetry stays flat
//! from the second call on.
//!
//! Scheduling is two-level: an *injector* queue receives jobs submitted
//! from outside the runtime (serving entry points), and each worker
//! owns a *deque* that receives jobs submitted from inside a task it is
//! running (an image-shard task scattering its layer's tile/band
//! items). Workers drain their own deque newest-first (depth-first into
//! the image they are already walking), then the injector oldest-first,
//! then *steal* the oldest items of other workers' deques — so an idle
//! image-shard worker steals tile/band items from a concurrently
//! walking image instead of idling at the layer-walk barrier (the `B`
//! slightly-under-`T` regime the scoped pool rounded away).
//!
//! Nesting is bounded by construction: a thread blocked in
//! [`GlobalRuntime::scatter`] executes items of *its own* job only
//! (identified by `Arc` pointer), so an image-shard task never recurses
//! into a second image mid-tile; idle workers take anything. Task
//! payloads are `Arc<dyn Fn(usize) + Send + Sync>`: `'static` with
//! `Arc`-shared operands ([`GlobalRuntime::scatter`]) or borrowing the
//! submitter's stack ([`GlobalRuntime::scatter_scoped`] — sound because
//! the barrier reclaims the task object before returning, the
//! `std::thread::scope` argument).
//!
//! [`ExecCtx`] is the handle threaded through the serving stack:
//! `Seq | Owned(&ExecPool) | Global(threads)`. The scoped pool survives
//! as the `Owned` A/B path (benches and parity tests compare the two);
//! [`ExecRuntime`] picks the default per process via `MARSELLUS_EXEC`.

use std::any::Any;
use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::{Arc, OnceLock};

use crate::analysis::sync::{AtomicUsize, Condvar, Mutex};

use super::pool::ExecPool;

/// One indexed task set: the runtime calls `task(i)` for every
/// `i in 0..n`, each index exactly once. `'static` — operands are
/// `Arc`-shared into the closure, never borrowed from the caller.
pub type GlobalTask = Arc<dyn Fn(usize) + Send + Sync + 'static>;

/// One submitted job: the task, its item count, and the completion /
/// panic state the submitting thread blocks on.
struct JobCore {
    /// The task, reclaimed (taken and dropped) by the submitter once
    /// the barrier resolves: workers may hold `Arc<JobCore>` clones a
    /// moment longer, but no reference to the task object itself
    /// survives [`GlobalRuntime::scatter`] — the guarantee that makes
    /// the scoped (`'env`-borrowing) submission path sound.
    task: Mutex<Option<GlobalTask>>,
    n: usize,
    /// Items completed (stores happen under the state mutex so a
    /// submitter checking it there cannot miss the final wakeup).
    done: AtomicUsize,
    /// First task panic, re-raised on the submitting thread after the
    /// barrier — a panicking tile must not kill a detached worker.
    panic: Mutex<Option<Box<dyn Any + Send + 'static>>>,
}

/// One schedulable unit: a single index of a job.
struct Chunk {
    job: Arc<JobCore>,
    index: usize,
}

/// Queues + counters, all under one mutex: contention is per item-grab,
/// and items are conv tiles / packing bands / whole image walks — far
/// coarser than the lock.
struct Queues {
    /// Jobs submitted from outside the runtime, oldest first.
    injector: VecDeque<Chunk>,
    /// Per-worker deques for nested submissions (back = newest).
    decks: Vec<VecDeque<Chunk>>,
    jobs: usize,
    steals: usize,
}

struct Inner {
    width: usize,
    state: Mutex<Queues>,
    /// Workers and blocked submitters wait here; notified on every
    /// submission and every item completion.
    work: Condvar,
}

/// Runtime counters surfaced by `Deployment::profile_scheduled` and the
/// CLI. `spawned_threads` is the whole point: it is `width - 1` after
/// the first parallel call and **never grows again** for the life of
/// the process (asserted in the serving tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GlobalTelemetry {
    /// Worker count including a submitting thread.
    pub width: usize,
    /// OS threads ever spawned by the runtime (once, `width - 1`).
    pub spawned_threads: usize,
    /// Jobs streamed through the queues since process start.
    pub jobs: usize,
    /// Items executed by a worker other than the one whose deque held
    /// them — cross-image tile/band stealing at the barrier.
    pub steals: usize,
}

thread_local! {
    /// `(runtime identity, worker index)` of the runtime worker this
    /// thread belongs to, if any — routes nested submissions to the
    /// submitting worker's own deque. The identity guards against unit
    /// tests that run private runtimes side by side.
    static WORKER: Cell<Option<(usize, usize)>> = const { Cell::new(None) };
}

/// The process-wide runtime; see the module docs. Private instances
/// exist only in unit tests — serving goes through [`global`].
pub struct GlobalRuntime {
    inner: Arc<Inner>,
}

static GLOBAL: OnceLock<GlobalRuntime> = OnceLock::new();

/// The process-wide runtime, provisioned on first use: worker count
/// from `MARSELLUS_POOL_THREADS` when set (clamped to `1..=2x cores`),
/// else the machine's cores.
pub fn global() -> &'static GlobalRuntime {
    GLOBAL.get_or_init(|| {
        let cores = std::thread::available_parallelism()
            .map(|v| v.get())
            .unwrap_or(1);
        let var = std::env::var("MARSELLUS_POOL_THREADS").ok();
        GlobalRuntime::new(width_from_env(var.as_deref(), cores))
    })
}

/// Resolve the runtime width: an explicit positive
/// `MARSELLUS_POOL_THREADS` clamped to `1..=2x cores` (the [`ExecPool`]
/// clamp — more workers than that only adds handoff overhead), anything
/// unset/unparsable/zero means "size to the machine".
fn width_from_env(var: Option<&str>, cores: usize) -> usize {
    let cores = cores.max(1);
    match var.and_then(|v| v.trim().parse::<usize>().ok()) {
        Some(w) if w > 0 => w.min(cores.saturating_mul(2)),
        _ => cores,
    }
}

impl GlobalRuntime {
    /// A runtime of `width` workers (the submitting thread counts;
    /// `width - 1` detached OS threads are spawned).
    fn new(width: usize) -> Self {
        let width = width.max(1);
        let inner = Arc::new(Inner {
            width,
            state: Mutex::new(Queues {
                injector: VecDeque::new(),
                decks: (0..width.saturating_sub(1))
                    .map(|_| VecDeque::new())
                    .collect(),
                jobs: 0,
                steals: 0,
            }),
            work: Condvar::new(),
        });
        for id in 0..width.saturating_sub(1) {
            let inner = inner.clone();
            std::thread::Builder::new()
                .name(format!("marsellus-global-{id}"))
                .spawn(move || worker_loop(&inner, id))
                .expect("spawn global runtime worker");
        }
        Self { inner }
    }

    /// Worker count, including a submitting thread — what per-layer
    /// splits (`tile_split`, packing bands) should size against.
    pub fn width(&self) -> usize {
        self.inner.width
    }

    /// Snapshot of the runtime counters.
    pub fn telemetry(&self) -> GlobalTelemetry {
        let q = self.inner.state.lock().unwrap();
        GlobalTelemetry {
            width: self.inner.width,
            spawned_threads: self.inner.width - 1,
            jobs: q.jobs,
            steals: q.steals,
        }
    }

    /// Run `task(i)` for every `i in 0..n` across the runtime and block
    /// until all items completed (the inter-layer / batch barrier). The
    /// calling thread participates; a 1-wide runtime (or `n == 1`)
    /// degrades to an inline loop with no synchronization. Each index
    /// runs exactly once; completion order is unspecified, so tasks
    /// must write disjoint outputs (slot-per-index).
    ///
    /// Unlike [`ExecPool::scatter`] this IS reentrant: a task may
    /// scatter a nested job (image shard -> layer tiles). While blocked
    /// on the nested barrier the thread executes items of that job
    /// only; idle workers steal anything, from any job.
    pub fn scatter(&self, n: usize, task: GlobalTask) {
        if n == 0 {
            return;
        }
        // Chaos site (delay-only — a panic here would kill a fleet
        // worker): stretches the submit-to-barrier window so gateway
        // lifecycle races overlap real execution.
        crate::failpoint!("runtime::scatter");
        let me = WORKER.with(|w| w.get());
        let ident = Arc::as_ptr(&self.inner) as usize;
        if self.inner.width == 1 || n == 1 {
            self.inner.state.lock().unwrap().jobs += 1;
            for i in 0..n {
                task(i);
            }
            return;
        }
        let job = Arc::new(JobCore {
            task: Mutex::new(Some(task)),
            n,
            done: AtomicUsize::new(0),
            panic: Mutex::new(None),
        });
        {
            let mut q = self.inner.state.lock().unwrap();
            q.jobs += 1;
            let chunks =
                (0..n).map(|index| Chunk { job: job.clone(), index });
            match me {
                // nested submission: onto the submitting worker's own
                // deque (drained depth-first by it, stolen oldest-first
                // by idle peers)
                Some((id, w)) if id == ident => q.decks[w].extend(chunks),
                _ => q.injector.extend(chunks),
            }
            self.inner.work.notify_all();
        }
        // Participate, but only in THIS job: nested barriers bottom out
        // instead of recursing into unrelated work mid-task.
        loop {
            let chunk = {
                let mut q = self.inner.state.lock().unwrap();
                loop {
                    if job.done.load(Ordering::Acquire) >= n {
                        break None;
                    }
                    if let Some(c) = take_of_job(&mut q, &job) {
                        break Some(c);
                    }
                    q = self.inner.work.wait(q).unwrap();
                }
            };
            match chunk {
                Some(c) => self.run_chunk(c),
                None => break,
            }
        }
        // Reclaim the task before returning (normally or by unwind):
        // every per-item clone was dropped before its `done` increment,
        // and `done == n` was observed under the state mutex, so this
        // take drops the last reference to the task object.
        debug_assert!(
            job.done.load(Ordering::Acquire) == job.n,
            "invariant: reclaim only after the barrier (done == n)"
        );
        let reclaimed = job.task.lock().unwrap().take();
        debug_assert!(
            reclaimed.is_some(),
            "invariant: the task slot holds the task until this \
             (single) reclaim — nothing else takes it"
        );
        if let Some(t) = reclaimed.as_ref() {
            // Workers may still hold `Arc<JobCore>` clones, but every
            // per-item *task* clone was dropped before its `done`
            // increment — so with `done == n` observed, this handle is
            // provably the last one. This count is what makes
            // `scatter_scoped`'s lifetime erasure sound.
            debug_assert_eq!(
                Arc::strong_count(t),
                1,
                "invariant: no task clone survives the barrier"
            );
        }
        drop(reclaimed);
        if let Some(p) = job.panic.lock().unwrap().take() {
            resume_unwind(p);
        }
    }

    /// [`Self::scatter`] for tasks that borrow from the caller's stack
    /// (`'env` rather than `'static`) — what lets batch sharding lend
    /// `&Deployment` / `&[Vec<i32>]` to the long-lived workers. Sound
    /// for the same reason `std::thread::scope` is: `scatter` is a
    /// strict barrier that both finishes every invocation of the task
    /// *and* drops every reference to the task object before it
    /// returns, so nothing the task borrows is reachable afterwards.
    pub fn scatter_scoped<'env>(
        &self,
        n: usize,
        task: Arc<dyn Fn(usize) + Send + Sync + 'env>,
    ) {
        // SAFETY: this transmute erases ONLY the closure's `'env`
        // lifetime bound — `Arc<dyn Fn(usize) + Send + Sync + 'env>`
        // to `... + 'static`. Both are `Arc<dyn Trait>` fat pointers
        // with identical layout (same data pointer, same vtable);
        // nothing about the value's representation changes, so the
        // only obligation is proving no use of the closure escapes
        // `'env`. The reclaim protocol bounds every such use inside
        // this very call:
        //
        // 1. The task object lives in `JobCore::task` and is reachable
        //    only through per-item `Chunk`s queued by `scatter`.
        // 2. A worker running an item clones the task `Arc` out, calls
        //    it, and drops the clone BEFORE counting the item `done` —
        //    and that count happens under the state mutex
        //    (`run_chunk`), so it happens-before any observation of
        //    `done == n` made under the same mutex.
        // 3. `scatter` returns only after observing `done == n` and
        //    then taking + dropping the task from its slot; at that
        //    point step 2 guarantees the slot held the LAST strong
        //    reference (debug-asserted on the reclaim path), so the
        //    closure — and every `'env` borrow inside it — is dead
        //    before `scatter_scoped` returns.
        // 4. Panics don't break the chain: a panicking item still
        //    drops its clone (the clone is consumed by the
        //    `catch_unwind` scope) and still counts `done`; the
        //    submitter re-raises only after reclaiming.
        //
        // This is the `std::thread::scope` argument: a strict barrier
        // that both finishes every invocation and destroys every
        // handle before the borrowed scope ends.
        let task: GlobalTask = unsafe { std::mem::transmute(task) };
        self.scatter(n, task);
    }

    /// Execute one item; count it done under the state mutex (so
    /// waiters cannot miss the last wakeup) and stash — not propagate —
    /// any panic.
    fn run_chunk(&self, c: Chunk) {
        // Clone the task handle out for the call and drop the clone
        // BEFORE counting the item done: once `done == n`, the
        // submitter's reference is provably the last one (see
        // `scatter_scoped`).
        let task = c
            .job
            .task
            .lock()
            .unwrap()
            .clone()
            .expect("task reclaimed before barrier");
        let res = catch_unwind(AssertUnwindSafe(|| task(c.index)));
        drop(task);
        if let Err(p) = res {
            let mut slot = c.job.panic.lock().unwrap();
            if slot.is_none() {
                *slot = Some(p);
            }
        }
        let _q = self.inner.state.lock().unwrap();
        let prev = c.job.done.fetch_add(1, Ordering::Release);
        debug_assert!(
            prev < c.job.n,
            "invariant: done <= n — each queued index counts once"
        );
        self.inner.work.notify_all();
    }
}

/// Pull an item of `job` (and only `job`) from any queue.
fn take_of_job(q: &mut Queues, job: &Arc<JobCore>) -> Option<Chunk> {
    if let Some(i) =
        q.injector.iter().position(|c| Arc::ptr_eq(&c.job, job))
    {
        return q.injector.remove(i);
    }
    for d in q.decks.iter_mut() {
        if let Some(i) = d.iter().position(|c| Arc::ptr_eq(&c.job, job)) {
            return d.remove(i);
        }
    }
    None
}

/// Pull the next item for idle worker `id`: own deque newest-first,
/// then the injector oldest-first, then steal the oldest item of a
/// peer's deque.
fn take_any(q: &mut Queues, id: usize) -> Option<Chunk> {
    if let Some(c) = q.decks[id].pop_back() {
        return Some(c);
    }
    if let Some(c) = q.injector.pop_front() {
        return Some(c);
    }
    let peers = q.decks.len();
    for w in 0..peers {
        if w == id {
            continue;
        }
        if let Some(c) = q.decks[w].pop_front() {
            q.steals += 1;
            return Some(c);
        }
    }
    None
}

/// Detached worker body: take anything, run it, forever. Lives for the
/// whole process — there is deliberately no shutdown path.
fn worker_loop(inner: &Arc<Inner>, id: usize) {
    WORKER.with(|w| w.set(Some((Arc::as_ptr(inner) as usize, id))));
    let rt = GlobalRuntime { inner: inner.clone() };
    loop {
        let chunk = {
            let mut q = rt.inner.state.lock().unwrap();
            loop {
                if let Some(c) = take_any(&mut q, id) {
                    break c;
                }
                q = rt.inner.work.wait(q).unwrap();
            }
        };
        rt.run_chunk(chunk);
    }
}

/// Which worker set a parallel serving call runs on — the Owned-vs-
/// Global A/B switch. `Owned` provisions a scoped [`ExecPool`] per call
/// (the PR-5 behavior, kept for measurement); `Global` streams onto the
/// process-wide runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecRuntime {
    /// Scoped per-call pool (`ExecPool::with` around the call).
    Owned,
    /// Process-wide work-stealing runtime ([`global`]).
    #[default]
    Global,
}

impl ExecRuntime {
    /// Process default: `MARSELLUS_EXEC=owned` opts back into per-call
    /// pools; anything else (including unset) is `Global`.
    pub fn from_env() -> Self {
        match std::env::var("MARSELLUS_EXEC") {
            Ok(v) => v.parse().unwrap_or(ExecRuntime::Global),
            Err(_) => ExecRuntime::Global,
        }
    }
}

impl std::str::FromStr for ExecRuntime {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim().to_ascii_lowercase().as_str() {
            "owned" | "pool" | "scoped" => Ok(ExecRuntime::Owned),
            "global" | "shared" => Ok(ExecRuntime::Global),
            other => Err(format!(
                "unknown exec runtime '{other}' (expected owned|global)"
            )),
        }
    }
}

/// The pool handle threaded through every parallel entry point — plan
/// kernels, the network walk, batch sharding, the tuner — so one code
/// path serves the sequential, scoped-pool and global-runtime cases.
#[derive(Clone, Copy)]
pub enum ExecCtx<'env> {
    /// Inline on the calling thread.
    Seq,
    /// A caller-owned scoped pool (the PR-5 A/B path).
    Owned(&'env ExecPool<'env>),
    /// The process-wide runtime, with the caller's requested lane
    /// count: splits size against `min(requested, runtime width)`, so a
    /// `--threads 4` call shards like a 4-wide owned pool even on a
    /// 16-wide runtime.
    Global(usize),
}

impl<'env> ExecCtx<'env> {
    /// The context a serving call with `threads` lanes should use under
    /// runtime choice `rt` when no scoped pool is in hand ([`Seq`] for
    /// one lane; `Owned` callers build their pool first and wrap it
    /// themselves).
    ///
    /// [`Seq`]: ExecCtx::Seq
    pub fn for_threads(threads: usize, rt: ExecRuntime) -> ExecCtx<'static> {
        match rt {
            _ if threads <= 1 => ExecCtx::Seq,
            ExecRuntime::Global => ExecCtx::Global(threads),
            // Owned contexts need a live scoped pool; callers that want
            // one wrap it explicitly. Requesting Owned without a pool
            // degrades to the global runtime rather than silently
            // sequential.
            ExecRuntime::Owned => ExecCtx::Global(threads),
        }
    }

    /// Effective worker count — what `tile_split`, packing bands and
    /// image shards size against.
    pub fn width(&self) -> usize {
        match self {
            ExecCtx::Seq => 1,
            ExecCtx::Owned(p) => p.width(),
            ExecCtx::Global(t) => (*t).min(global().width()).max(1),
        }
    }

    /// Run `task(i)` for every `i in 0..n` on this context and block
    /// until all items completed. Tasks may borrow from the caller's
    /// scope (`'env`): every arm is a strict barrier — inline for
    /// [`Seq`](ExecCtx::Seq), the scoped pool's join for `Owned`, and
    /// [`GlobalRuntime::scatter_scoped`]'s task reclamation for
    /// `Global`.
    pub fn scatter(
        &self,
        n: usize,
        task: Arc<dyn Fn(usize) + Send + Sync + 'env>,
    ) {
        match self {
            ExecCtx::Seq => {
                for i in 0..n {
                    task(i);
                }
            }
            ExecCtx::Owned(p) => p.scatter(n, task),
            ExecCtx::Global(_) => global().scatter_scoped(n, task),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every index of every job runs exactly once, across many jobs on
    /// one runtime, at every width — including width 1 (inline).
    #[test]
    fn scatter_runs_each_index_once_across_jobs() {
        for width in [1usize, 2, 3, 8] {
            let rt = GlobalRuntime::new(width);
            for n in [0usize, 1, 5, 64] {
                let hits: Arc<Vec<AtomicUsize>> =
                    Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect());
                let task = {
                    let hits = hits.clone();
                    Arc::new(move |i: usize| {
                        hits[i].fetch_add(1, Ordering::Relaxed);
                    })
                };
                rt.scatter(n, task);
                for (i, h) in hits.iter().enumerate() {
                    assert_eq!(
                        h.load(Ordering::Relaxed),
                        1,
                        "width {width}, n {n}, index {i}"
                    );
                }
            }
        }
    }

    /// The barrier holds: after `scatter` returns, every item's side
    /// effect is visible to the submitter.
    #[test]
    fn scatter_is_a_barrier() {
        let rt = GlobalRuntime::new(4);
        for round in 0..50usize {
            let n = 16;
            let slots: Arc<Vec<Mutex<Option<usize>>>> =
                Arc::new((0..n).map(|_| Mutex::new(None)).collect());
            let task = {
                let slots = slots.clone();
                Arc::new(move |i: usize| {
                    *slots[i].lock().unwrap() = Some(i * i);
                })
            };
            rt.scatter(n, task);
            for (i, s) in slots.iter().enumerate() {
                assert_eq!(
                    s.lock().unwrap().take(),
                    Some(i * i),
                    "round {round}"
                );
            }
        }
    }

    /// Nested scatter — a task submitting a sub-job and blocking on it,
    /// the image-shard -> layer-tiles shape — completes, runs every
    /// inner index exactly once, and never deadlocks, even when every
    /// outer item nests.
    #[test]
    fn nested_scatter_completes() {
        let rt = Arc::new(GlobalRuntime::new(4));
        let outer = 6usize;
        let inner_n = 12usize;
        let hits: Arc<Vec<AtomicUsize>> = Arc::new(
            (0..outer * inner_n).map(|_| AtomicUsize::new(0)).collect(),
        );
        let task = {
            let (rt, hits) = (rt.clone(), hits.clone());
            Arc::new(move |o: usize| {
                let hits = hits.clone();
                rt.scatter(
                    inner_n,
                    Arc::new(move |i: usize| {
                        hits[o * inner_n + i]
                            .fetch_add(1, Ordering::Relaxed);
                    }),
                );
            })
        };
        rt.scatter(outer, task);
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    /// Two external threads scattering concurrently onto one runtime
    /// both complete with exactly-once execution — the multi-tenant
    /// serving shape.
    #[test]
    fn concurrent_submitters_share_the_runtime() {
        let rt = Arc::new(GlobalRuntime::new(4));
        let n = 64usize;
        let counts: Vec<Arc<Vec<AtomicUsize>>> = (0..2)
            .map(|_| {
                Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect())
            })
            .collect();
        std::thread::scope(|s| {
            for hits in &counts {
                let (rt, hits) = (rt.clone(), hits.clone());
                s.spawn(move || {
                    for _ in 0..10 {
                        let hits = hits.clone();
                        rt.scatter(
                            n,
                            Arc::new(move |i: usize| {
                                hits[i].fetch_add(1, Ordering::Relaxed);
                            }),
                        );
                    }
                });
            }
        });
        for hits in &counts {
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 10, "index {i}");
            }
        }
    }

    /// A panicking task reaches the submitter as a panic (after the
    /// barrier) and the runtime keeps serving afterwards — detached
    /// workers must survive task panics.
    #[test]
    fn task_panic_propagates_to_submitter_and_runtime_survives() {
        let rt = GlobalRuntime::new(3);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            rt.scatter(
                8,
                Arc::new(|i: usize| {
                    if i == 5 {
                        panic!("tile 5 exploded");
                    }
                }),
            );
        }));
        assert!(caught.is_err(), "panic must cross the barrier");
        // still serving
        let ran = Arc::new(AtomicUsize::new(0));
        let r = ran.clone();
        rt.scatter(
            16,
            Arc::new(move |_| {
                r.fetch_add(1, Ordering::Relaxed);
            }),
        );
        assert_eq!(ran.load(Ordering::Relaxed), 16);
    }

    /// Telemetry: spawns happen once at construction and never grow;
    /// jobs count scatters (degenerate `n == 0` excluded).
    #[test]
    fn telemetry_spawns_once_and_counts_jobs() {
        let rt = GlobalRuntime::new(3);
        let t0 = rt.telemetry();
        assert_eq!(t0.width, 3);
        assert_eq!(t0.spawned_threads, 2);
        assert_eq!(t0.jobs, 0);
        for _ in 0..5 {
            rt.scatter(4, Arc::new(|_: usize| {}));
        }
        rt.scatter(0, Arc::new(|_: usize| {})); // no-op, not a job
        let t = rt.telemetry();
        assert_eq!(t.jobs, 5);
        assert_eq!(
            t.spawned_threads, t0.spawned_threads,
            "serving calls must not spawn"
        );
    }

    /// Width resolution: unset/garbage/zero -> cores; explicit values
    /// clamp to 2x cores and floor at 1.
    #[test]
    fn width_from_env_resolves_and_clamps() {
        assert_eq!(width_from_env(None, 8), 8);
        assert_eq!(width_from_env(Some(""), 8), 8);
        assert_eq!(width_from_env(Some("nope"), 8), 8);
        assert_eq!(width_from_env(Some("0"), 8), 8);
        assert_eq!(width_from_env(Some("4"), 8), 4);
        assert_eq!(width_from_env(Some(" 12 "), 8), 12);
        assert_eq!(width_from_env(Some("9999"), 8), 16);
        assert_eq!(width_from_env(Some("3"), 1), 2);
    }

    /// `ExecCtx` width semantics: `Seq` is 1, `Owned` is the pool's
    /// width, `Global(t)` caps the request at the runtime width; and
    /// `scatter` runs inline for `Seq`.
    #[test]
    fn exec_ctx_width_and_seq_scatter() {
        assert_eq!(ExecCtx::Seq.width(), 1);
        ExecPool::with(3, |pool| {
            assert_eq!(ExecCtx::Owned(pool).width(), pool.width());
        });
        let rt_width = global().width();
        assert_eq!(ExecCtx::Global(1).width(), 1);
        assert_eq!(
            ExecCtx::Global(usize::MAX).width(),
            rt_width,
            "requests cap at the runtime width"
        );
        let ran = Arc::new(AtomicUsize::new(0));
        let r = ran.clone();
        ExecCtx::Seq.scatter(
            5,
            Arc::new(move |_| {
                r.fetch_add(1, Ordering::Relaxed);
            }),
        );
        assert_eq!(ran.load(Ordering::Relaxed), 5);
    }

    /// `ExecRuntime` parsing: explicit owned/global spellings, errors
    /// on junk, `for_threads` collapses single-lane calls to `Seq`.
    #[test]
    fn exec_runtime_parses_and_routes() {
        assert_eq!("owned".parse::<ExecRuntime>().unwrap(), ExecRuntime::Owned);
        assert_eq!("pool".parse::<ExecRuntime>().unwrap(), ExecRuntime::Owned);
        assert_eq!(
            " Global ".parse::<ExecRuntime>().unwrap(),
            ExecRuntime::Global
        );
        assert!("turbo".parse::<ExecRuntime>().is_err());
        assert_eq!(ExecRuntime::default(), ExecRuntime::Global);
        assert!(matches!(
            ExecCtx::for_threads(1, ExecRuntime::Global),
            ExecCtx::Seq
        ));
        assert!(matches!(
            ExecCtx::for_threads(4, ExecRuntime::Global),
            ExecCtx::Global(4)
        ));
    }

    /// Steal accounting: a nested job lands on the submitting worker's
    /// deque; with idle peers around, at least some of its items are
    /// stolen (eventually — assert only the counter is consistent with
    /// completed work, not a racy exact count).
    #[test]
    fn steals_are_counted_consistently() {
        let rt = Arc::new(GlobalRuntime::new(4));
        let before = rt.telemetry().steals;
        // many nested jobs with slow-ish outer items give peers time to
        // go idle and steal from the busy worker's deque
        let task = {
            let rt = rt.clone();
            Arc::new(move |_: usize| {
                let spin = AtomicUsize::new(0);
                rt.scatter(
                    8,
                    Arc::new(move |_| {
                        for _ in 0..1000 {
                            spin.fetch_add(1, Ordering::Relaxed);
                        }
                    }),
                );
            })
        };
        for _ in 0..8 {
            rt.scatter(4, task.clone());
        }
        let t = rt.telemetry();
        assert!(t.steals >= before, "steal counter must not regress");
        assert_eq!(t.jobs, 8 + 8 * 4, "outer jobs + one nested job each");
    }

    /// The transmute path under direct test (and the prime Miri
    /// target): a `'env` task borrowing the submitter's stack, pushed
    /// through `scatter_scoped`'s lifetime erasure. Reading the
    /// borrowed data after the barrier is exactly what the reclaim
    /// protocol must make sound.
    #[test]
    fn scatter_scoped_borrows_stack_data() {
        let rt = GlobalRuntime::new(4);
        let inputs: Vec<usize> = (0..32).collect();
        let outputs: Vec<AtomicUsize> =
            (0..32).map(|_| AtomicUsize::new(0)).collect();
        {
            let (inputs, outputs) = (&inputs, &outputs);
            rt.scatter_scoped(
                32,
                Arc::new(move |i: usize| {
                    outputs[i].store(inputs[i] * 3, Ordering::Relaxed);
                }),
            );
        }
        for (i, o) in outputs.iter().enumerate() {
            assert_eq!(o.load(Ordering::Relaxed), i * 3);
        }
        // a second scoped job over fresh borrows — the erased closure
        // from round one must be fully dead (Miri would flag any
        // dangling use)
        let flags: Vec<AtomicUsize> =
            (0..8).map(|_| AtomicUsize::new(0)).collect();
        {
            let flags = &flags;
            rt.scatter_scoped(
                8,
                Arc::new(move |i: usize| {
                    flags[i].fetch_add(1, Ordering::Relaxed);
                }),
            );
        }
        assert!(flags
            .iter()
            .all(|f| f.load(Ordering::Relaxed) == 1));
    }
}
