//! Precompiled layer plans: the "compile once, stream activations"
//! stage of the native execution path.
//!
//! The per-call path ([`super::native`]) re-derives job geometry,
//! re-validates weights and re-reads normquant parameters on every
//! `execute_i32`, so serving throughput is bounded by setup rather than
//! compute. A [`LayerPlan`] hoists all of that to network-load time:
//! weights are validated once and pre-packed into channel-parallel
//! bit-plane words ([`PackedWeights`]) at a plan-chosen lane width
//! ([`PlaneWidth::for_job`]: the literal §II-B3 32-lane layout for
//! narrow layers, 64-lane words past one group), the [`RbeJob`]
//! geometry and requant constants are resolved, and per-call work
//! collapses to activation checking + streaming through the `*_planned`
//! entry points of [`crate::rbe::functional`]. Plans are immutable and
//! their hot operands (`PackedWeights`, requant constants) are
//! `Arc`-staged, so batch workers share one `Arc<NetworkPlan>`
//! read-only across threads — see `Deployment::infer_batch` — and
//! every parallel entry point takes an [`ExecCtx`] handle: inline,
//! a caller-scoped [`super::pool::ExecPool`], or the process-wide
//! work-stealing runtime ([`super::global`]). The single-image latency
//! mode splits one layer's `(output-row, k_out)` range across the same
//! workers ([`ConvPlan::run_scheduled`]).
//!
//! Bitwise identity with the per-call path is by construction: every
//! kernel choice evaluates the same Eq. 1–2 integer arithmetic
//! (property-tested equivalent in `rbe::functional`), only the operand
//! staging differs.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::dnn::{Layer, LayerOp, ManifestEntry};
use crate::rbe::functional::{
    assemble_activation_bands, band_split, check_activation_plane,
    check_weights, conv_bitserial_packed_tile, conv_reference_planned,
    conv_reference_tile, pack_activation_band, pack_activations,
    pack_weights_with, trim_input, ActivationBand, ConvTile, NormQuant,
    PackedActivations, PackedWeights, PlaneWidth,
};
use crate::rbe::RbeJob;

use super::global::ExecCtx;
use super::tune::{LayerTune, SplitFactors, TunedConfig};

/// Jobs at or below this MAC count run bit-serial under
/// [`NativeNumerics::Auto`] on the per-call path, and packed bit-serial
/// on the plan path.
pub const AUTO_BITSERIAL_MACS: u64 = 1 << 16;

/// Which functional implementation conv/linear layers run on. All
/// choices produce bit-identical outputs (`rbe::functional` property
/// tests); they differ only in speed and in how literally they model the
/// hardware datapath.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NativeNumerics {
    /// Bit-serial Eq. 1 datapath for small jobs, integer oracle for large
    /// ones (default: exactness is identical, this only bounds runtime).
    Auto,
    /// Always the bit-serial datapath model.
    BitSerial,
    /// Always the plain integer oracle.
    Reference,
}

impl NativeNumerics {
    /// Per-call datapath choice for the interpreted native path.
    pub fn bit_serial_for(&self, job: &RbeJob) -> bool {
        match self {
            NativeNumerics::BitSerial => true,
            NativeNumerics::Reference => false,
            NativeNumerics::Auto => job.macs() <= AUTO_BITSERIAL_MACS,
        }
    }

    /// Plan-compile kernel choice: the packed bit-serial datapath when
    /// it is the literal hardware model (small jobs / `BitSerial`) or
    /// when its inner loop is cheaper than the oracle's — per tap the
    /// packed path does `w_bits · i_bits · ceil(k_in/lanes)`
    /// AND+popcount word ops against the oracle's `k_in` multiplies,
    /// with `lanes` the word width [`PlaneWidth::for_job`] would pick.
    pub fn packed_for(&self, job: &RbeJob) -> bool {
        match self {
            NativeNumerics::BitSerial => true,
            NativeNumerics::Reference => false,
            NativeNumerics::Auto => {
                let lanes = PlaneWidth::for_job(job).lanes();
                job.macs() <= AUTO_BITSERIAL_MACS
                    || job.w_bits * job.i_bits * job.k_in.div_ceil(lanes)
                        < job.k_in
            }
        }
    }
}

/// Conv jobs below this MAC count run sequentially even in latency mode:
/// tiny layers (e.g. the classifier head) finish faster than the worker
/// handoff costs.
pub const LATENCY_TILE_MIN_MACS: u64 = 1 << 14;

/// Split a job's output into about `threads` `(output-row, k_out)`
/// tiles: rows first (they stitch contiguously), output channels only
/// when there are fewer rows than workers (e.g. linear layers). Tiles
/// partition the output exactly; each is non-empty.
fn tile_split(job: &RbeJob, threads: usize) -> Vec<ConvTile> {
    if threads <= 1 {
        return vec![ConvTile::full(job)];
    }
    let row_chunks = threads.min(job.h_out);
    let k_chunks = (threads / row_chunks).min(job.k_out).max(1);
    let mut tiles = Vec::with_capacity(row_chunks * k_chunks);
    for r in 0..row_chunks {
        let (row0, row1) = (
            r * job.h_out / row_chunks,
            (r + 1) * job.h_out / row_chunks,
        );
        for k in 0..k_chunks {
            tiles.push(ConvTile {
                row0,
                row1,
                ko0: k * job.k_out / k_chunks,
                ko1: (k + 1) * job.k_out / k_chunks,
            });
        }
    }
    tiles
}

/// How a planned conv/linear layer streams activations.
enum PlanKernel {
    /// Bit-plane-packed Eq. 1 datapath (popcount over 32-channel words).
    Packed(PackedWeights),
    /// Plain integer oracle over the raw (validated-once) weights.
    Reference(Vec<i32>),
}

/// Result of one scheduled conv-layer run: the output plane plus the
/// wall time of its activation-packing phase — the pack half of the
/// per-layer pack-vs-compute split `Deployment::profile` reports
/// (0 for the reference staging, which packs nothing).
pub struct ConvRun {
    /// The layer's output plane, identical to [`ConvPlan::run`].
    pub out: Vec<i32>,
    /// Wall microseconds spent packing the activation plane (banded
    /// across the pool when one was given).
    pub pack_us: f64,
}

/// One conv3x3 / conv1x1 / linear layer, compiled: resolved geometry,
/// bound weights, requant constants. Immutable after compilation.
pub struct ConvPlan {
    /// Resolved RBE job geometry (output extent, stride, precisions).
    pub job: RbeJob,
    /// Side of the activation plane the layer receives (padded for 3×3,
    /// 1 for linear).
    pub full: usize,
    /// `Arc`-staged so `'static` runtime tasks can own a handle without
    /// borrowing the plan's stack frame.
    nq: Arc<NormQuant>,
    kernel: Arc<PlanKernel>,
    /// Split-shape multipliers applied on every pooled run — `UNIT`
    /// unless the plan was compiled from a tuned configuration.
    factors: SplitFactors,
}

impl ConvPlan {
    /// Length-check the incoming plane and trim it to the job's strided
    /// extent — the shared prologue of [`Self::run`] and
    /// [`Self::run_tiled`].
    fn checked_trim<'a>(
        &self,
        x: &'a [i32],
    ) -> Result<std::borrow::Cow<'a, [i32]>> {
        let want = self.full * self.full * self.job.k_in;
        if x.len() != want {
            bail!(
                "planned layer expects a ({f}, {f}, {k}) activation plane \
                 ({want} values), got {}",
                x.len(),
                f = self.full,
                k = self.job.k_in,
            );
        }
        Ok(trim_input(x, self.full, self.job.h_in(), self.job.k_in))
    }

    /// Stream one activation plane through the plan. Per-call work is
    /// exactly: length check, strided trim, kernel evaluation.
    pub fn run(&self, x: &[i32]) -> Result<Vec<i32>> {
        self.run_scheduled(x, ExecCtx::Seq).map(|r| r.out)
    }

    /// Stream one activation plane through the plan, fanning the
    /// layer's work over the given execution context when it is wider
    /// than one lane: the activation plane is packed in row bands
    /// across the workers (lifting the serial packing fraction of wide
    /// layers), then the `(output-row, k_out)` range is split into
    /// tiles pulled by the same workers. On [`ExecCtx::Seq`] — or for
    /// jobs under [`LATENCY_TILE_MIN_MACS`], which degrade gracefully —
    /// the layer runs inline on the calling thread.
    ///
    /// Bitwise identical to [`Self::run`] in every configuration:
    /// banded packing stitches to the exact whole-plane words, and
    /// disjoint tiles compute disjoint output elements with the same
    /// arithmetic.
    pub fn run_scheduled(
        &self,
        x: &[i32],
        ctx: ExecCtx<'_>,
    ) -> Result<ConvRun> {
        self.run_scheduled_factored(x, ctx, self.factors)
    }

    /// [`Self::run_scheduled`] with explicit split-shape multipliers
    /// overriding the plan's compiled-in factors for this one call —
    /// the autotuner's measurement hook: candidate variants are timed
    /// through the exact serving code path without mutating (or
    /// recompiling) the shared plan. Factors only re-partition the same
    /// output and packing ranges, so every value is bitwise identical
    /// to [`Self::run`].
    pub fn run_scheduled_factored(
        &self,
        x: &[i32],
        ctx: ExecCtx<'_>,
        f: SplitFactors,
    ) -> Result<ConvRun> {
        let x = self.checked_trim(x)?;
        let width = ctx.width();
        if width > 1 && self.job.macs() >= LATENCY_TILE_MIN_MACS {
            let tiles = tile_split(
                &self.job,
                width.saturating_mul(f.tile.max(1)),
            );
            if tiles.len() > 1 {
                let bands = width.saturating_mul(f.band.max(1));
                return self.run_pooled_trimmed(x, ctx, tiles, bands);
            }
        }
        self.run_seq_trimmed(&x)
    }

    /// Sequential staging over an already-trimmed plane, with the
    /// activation-packing phase timed for the pack-vs-compute split.
    fn run_seq_trimmed(&self, x: &[i32]) -> Result<ConvRun> {
        match &*self.kernel {
            PlanKernel::Packed(pw) => {
                let t0 = Instant::now();
                let xp = pack_activations(&self.job, x, pw.width())?;
                let pack_us = t0.elapsed().as_secs_f64() * 1e6;
                let out = conv_bitserial_packed_tile(
                    &self.job,
                    &xp,
                    pw,
                    &self.nq,
                    ConvTile::full(&self.job),
                )?;
                Ok(ConvRun { out, pack_us })
            }
            PlanKernel::Reference(w) => Ok(ConvRun {
                out: conv_reference_planned(&self.job, x, w, &self.nq)?,
                pack_us: 0.0,
            }),
        }
    }

    /// Worker fan-out over an already-trimmed plane: band-parallel
    /// pack, then tile-parallel conv, both as jobs on the context's
    /// workers. Tasks are `'static`: the job geometry is copied and the
    /// kernel/requant operands are `Arc`-shared into the closures (the
    /// safe lifetime story that lets the process-wide runtime outlive
    /// this call); the one plane copy this costs is small against the
    /// conv itself.
    fn run_pooled_trimmed(
        &self,
        x: std::borrow::Cow<'_, [i32]>,
        ctx: ExecCtx<'_>,
        tiles: Vec<ConvTile>,
        bands: usize,
    ) -> Result<ConvRun> {
        let plane: Arc<Vec<i32>> = Arc::new(x.into_owned());
        let (staged, pack_us) = match &*self.kernel {
            PlanKernel::Packed(pw) => {
                let t0 = Instant::now();
                let xp =
                    self.pack_banded(&plane, pw.width(), ctx, bands)?;
                (Some(Arc::new(xp)), t0.elapsed().as_secs_f64() * 1e6)
            }
            PlanKernel::Reference(_) => {
                // validate the shared plane ONCE; the tile kernel only
                // debug_asserts it
                check_activation_plane(&self.job, &plane)?;
                (None, 0.0)
            }
        };
        let tiles = Arc::new(tiles);
        let slots: Arc<Vec<Mutex<Option<Result<Vec<i32>>>>>> =
            Arc::new(tiles.iter().map(|_| Mutex::new(None)).collect());
        {
            let (tiles, slots, plane, staged) =
                (tiles.clone(), slots.clone(), plane.clone(), staged);
            let (job, kernel, nq) =
                (self.job, self.kernel.clone(), self.nq.clone());
            ctx.scatter(
                tiles.len(),
                Arc::new(move |t| {
                    let res = match (&*kernel, staged.as_deref()) {
                        (PlanKernel::Packed(pw), Some(xp)) => {
                            conv_bitserial_packed_tile(
                                &job, xp, pw, &nq, tiles[t],
                            )
                        }
                        (PlanKernel::Reference(w), _) => {
                            conv_reference_tile(
                                &job, &plane, w, &nq, tiles[t],
                            )
                        }
                        (PlanKernel::Packed(_), None) => {
                            unreachable!("packed kernel stages activations")
                        }
                    };
                    *slots[t].lock().unwrap() = Some(res);
                }),
            );
        }
        let mut out =
            vec![0i32; self.job.h_out * self.job.w_out * self.job.k_out];
        for (tile, slot) in tiles.iter().zip(slots.iter()) {
            let part = slot
                .lock()
                .unwrap()
                .take()
                .expect("every tile index was pulled by a worker")?;
            self.stitch_tile(&mut out, tile, &part);
        }
        Ok(ConvRun { out, pack_us })
    }

    /// Pack the activation plane in contiguous row bands across the
    /// context's workers and stitch the bands — bitwise identical to a
    /// whole-plane [`pack_activations`] (band-parity property tests in
    /// `rbe::functional`).
    fn pack_banded(
        &self,
        plane: &Arc<Vec<i32>>,
        width: PlaneWidth,
        ctx: ExecCtx<'_>,
        bands: usize,
    ) -> Result<PackedActivations> {
        let rows = band_split(self.job.h_in(), bands);
        if rows.len() <= 1 {
            return pack_activations(&self.job, plane, width);
        }
        let w_in = self.job.w_in();
        let bands: Arc<Vec<(usize, usize)>> = Arc::new(
            rows.into_iter()
                .map(|(r0, r1)| (r0 * w_in, r1 * w_in))
                .collect(),
        );
        let slots: Arc<Vec<Mutex<Option<Result<ActivationBand>>>>> =
            Arc::new(bands.iter().map(|_| Mutex::new(None)).collect());
        {
            let (bands, slots, plane) =
                (bands.clone(), slots.clone(), plane.clone());
            let job = self.job;
            ctx.scatter(
                bands.len(),
                Arc::new(move |b| {
                    let (p0, p1) = bands[b];
                    *slots[b].lock().unwrap() = Some(pack_activation_band(
                        &job, &plane, width, p0, p1,
                    ));
                }),
            );
        }
        let mut parts = Vec::with_capacity(bands.len());
        for slot in slots.iter() {
            parts.push(
                slot.lock()
                    .unwrap()
                    .take()
                    .expect("every band index was pulled by a worker")?,
            );
        }
        assemble_activation_bands(&self.job, width, parts)
    }

    /// Copy one `(rows, w_out, ko-range)` row-major tile into its place
    /// in the interleaved full output.
    fn stitch_tile(&self, out: &mut [i32], tile: &ConvTile, part: &[i32]) {
        let kos = tile.ko1 - tile.ko0;
        for r in 0..tile.row1 - tile.row0 {
            for ox in 0..self.job.w_out {
                let src = (r * self.job.w_out + ox) * kos;
                let dst = ((tile.row0 + r) * self.job.w_out + ox)
                    * self.job.k_out
                    + tile.ko0;
                out[dst..dst + kos].copy_from_slice(&part[src..src + kos]);
            }
        }
    }

    /// Stream one activation plane through the plan with the layer's
    /// `(output-row, k_out)` range split into tiles pulled by `threads`
    /// scoped workers — the **legacy** (pre-pool) latency path, which
    /// spawns and joins a fresh thread set per call. Kept so benches
    /// and tests can measure the recovered spawn overhead against
    /// [`Self::run_scheduled`] over persistent workers; serving goes
    /// through [`ExecCtx`]. For the packed kernel the activation
    /// plane is packed ONCE (serially) and shared read-only by every
    /// tile worker. Bitwise identical to [`Self::run`]: disjoint tiles
    /// compute disjoint output elements with the same arithmetic, so
    /// the stitched result is the sequential result.
    pub fn run_tiled(&self, x: &[i32], threads: usize) -> Result<Vec<i32>> {
        // Clamp the fan-out to the machine: more workers than cores only
        // adds spawn/join overhead, and an absurd operator value
        // (`--threads 9999`) must degrade, not abort on thread
        // exhaustion. 2x cores leaves headroom for uneven tile costs.
        let cores = std::thread::available_parallelism()
            .map(|v| v.get())
            .unwrap_or(1);
        let threads = threads.min(cores.saturating_mul(2));
        let tiles = tile_split(&self.job, threads);
        if tiles.len() <= 1 || self.job.macs() < LATENCY_TILE_MIN_MACS {
            return self.run(x);
        }
        let x = self.checked_trim(x)?;
        // Stage the shared operand once, outside the pool — including
        // the per-call activation validation (signed-activation guard),
        // paid once per layer instead of once per tile: packed
        // activations for the popcount kernel, the validated trimmed
        // plane itself for the oracle.
        let staged: Option<PackedActivations> = match &*self.kernel {
            PlanKernel::Packed(pw) => {
                Some(pack_activations(&self.job, &x, pw.width())?)
            }
            PlanKernel::Reference(_) => {
                check_activation_plane(&self.job, &x)?;
                None
            }
        };
        let slots: Vec<Mutex<Option<Result<Vec<i32>>>>> =
            tiles.iter().map(|_| Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..threads.min(tiles.len()) {
                let (slots, next, tiles, staged, x) =
                    (&slots, &next, &tiles, &staged, &x);
                s.spawn(move || loop {
                    let t = next.fetch_add(1, Ordering::Relaxed);
                    if t >= tiles.len() {
                        break;
                    }
                    let res = match (&*self.kernel, staged) {
                        (PlanKernel::Packed(pw), Some(xp)) => {
                            conv_bitserial_packed_tile(
                                &self.job, xp, pw, &self.nq, tiles[t],
                            )
                        }
                        (PlanKernel::Reference(w), _) => {
                            conv_reference_tile(
                                &self.job, x, w, &self.nq, tiles[t],
                            )
                        }
                        (PlanKernel::Packed(_), None) => {
                            unreachable!("packed kernel stages activations")
                        }
                    };
                    *slots[t].lock().unwrap() = Some(res);
                });
            }
        });
        // Stitch: each tile is (rows, w_out, ko-range) row-major; the
        // full output interleaves k_out per pixel.
        let mut out =
            vec![0i32; self.job.h_out * self.job.w_out * self.job.k_out];
        for (tile, slot) in tiles.iter().zip(slots) {
            let part = slot
                .into_inner()
                .unwrap()
                .expect("every tile index was pulled by a worker")?;
            self.stitch_tile(&mut out, tile, &part);
        }
        Ok(out)
    }

    /// True when this plan streams through the packed bit-serial path.
    pub fn is_packed(&self) -> bool {
        matches!(&*self.kernel, PlanKernel::Packed(_))
    }

    /// Lane width of the packed bit-plane words (`None` on the
    /// reference-oracle staging).
    pub fn plane_width(&self) -> Option<PlaneWidth> {
        match &*self.kernel {
            PlanKernel::Packed(pw) => Some(pw.width()),
            PlanKernel::Reference(_) => None,
        }
    }

    /// Resident bytes of the staged operands: the packed bit-plane words
    /// (or raw reference weights) plus the requant constants — what the
    /// plan-cache eviction policy accounts per deployment.
    pub fn bytes(&self) -> usize {
        let kernel = match &*self.kernel {
            PlanKernel::Packed(pw) => pw.bytes(),
            PlanKernel::Reference(w) => w.len() * 4,
        };
        kernel + (self.nq.scale.len() + self.nq.bias.len()) * 4
    }
}

/// One layer of a deployed network, compiled into an immutable execution
/// plan.
pub enum LayerPlan {
    /// conv3x3 / conv1x1 / linear — weights bound and pre-staged.
    Conv(ConvPlan),
    /// Residual add + requant (stateless; shape + constants resolved).
    Add { h: usize, k: usize, shift: u32, o_bits: usize },
    /// Global average pool.
    AvgPool { h: usize, k: usize, shift: u32 },
}

impl LayerPlan {
    /// Compile one manifest entry into a plan. Conv/linear entries bind
    /// (and validate, once) the layer's weights and normquant
    /// parameters; elementwise entries ignore them.
    pub fn compile(
        e: &ManifestEntry,
        w: &[i32],
        scale: &[i32],
        bias: &[i32],
        numerics: NativeNumerics,
    ) -> Result<Self> {
        Self::compile_with(e, w, scale, bias, numerics, None)
    }

    /// [`Self::compile`] with an optional per-layer tuned pick: plane
    /// word width and split-shape multipliers come from the autotuner's
    /// measurement instead of the fixed heuristics. The kernel *choice*
    /// (packed vs reference) stays with `numerics` — a tuned width only
    /// reshapes the packed staging, it never moves a layer onto a
    /// different arithmetic path, so tuned plans remain bitwise
    /// identical by the same construction as heuristic ones.
    pub fn compile_with(
        e: &ManifestEntry,
        w: &[i32],
        scale: &[i32],
        bias: &[i32],
        numerics: NativeNumerics,
        tune: Option<&LayerTune>,
    ) -> Result<Self> {
        match e.op {
            LayerOp::Conv3x3
            | LayerOp::Conv1x1
            | LayerOp::Linear
            | LayerOp::LinearSigned => {
                let job = e.rbe_job()?;
                if scale.len() != e.cout || bias.len() != e.cout {
                    bail!(
                        "{}: normquant params must be per-output-channel \
                         ({} scales / {} biases vs cout = {})",
                        e.name,
                        scale.len(),
                        bias.len(),
                        e.cout
                    );
                }
                let nq = NormQuant {
                    scale: scale.to_vec(),
                    bias: bias.to_vec(),
                    shift: e.shift,
                    signed: e.op.signed_output(),
                };
                let kernel = if numerics.packed_for(&job) {
                    // word width is a plan-time parameter: the tuned
                    // pick when one was measured, otherwise wide words
                    // past one 32-channel group and the literal §II-B3
                    // layout below
                    let width = tune
                        .and_then(|t| t.width)
                        .unwrap_or_else(|| PlaneWidth::for_job(&job));
                    PlanKernel::Packed(pack_weights_with(&job, w, width)?)
                } else {
                    check_weights(&job, w)?;
                    PlanKernel::Reference(w.to_vec())
                };
                Ok(LayerPlan::Conv(ConvPlan {
                    job,
                    full: e.full_side(),
                    nq: Arc::new(nq),
                    kernel: Arc::new(kernel),
                    factors: tune
                        .map(|t| t.factors)
                        .unwrap_or(SplitFactors::UNIT),
                }))
            }
            LayerOp::Add => Ok(LayerPlan::Add {
                h: e.h,
                k: e.cin,
                shift: e.shift,
                o_bits: e.o_bits,
            }),
            LayerOp::AvgPool => Ok(LayerPlan::AvgPool {
                h: e.h,
                k: e.cin,
                shift: e.shift,
            }),
        }
    }

    /// Resident bytes of this layer's staged operands (elementwise plans
    /// stage only a few scalars and account as 0).
    pub fn bytes(&self) -> usize {
        match self {
            LayerPlan::Conv(c) => c.bytes(),
            LayerPlan::Add { .. } | LayerPlan::AvgPool { .. } => 0,
        }
    }
}

/// One step of a compiled network: the schedulable layer plus its plan
/// and the wall-clock cost of compiling it (the "setup" half of the
/// setup-vs-compute bench split).
pub struct PlanStep {
    pub layer: Layer,
    pub plan: LayerPlan,
    pub setup_us: f64,
}

/// A whole deployed network, compiled layer by layer. Shared read-only
/// (`Arc`) across batch worker threads.
pub struct NetworkPlan {
    steps: Vec<PlanStep>,
    bytes: usize,
    tuned: Option<TunedConfig>,
}

impl NetworkPlan {
    pub fn new(steps: Vec<PlanStep>) -> Self {
        let bytes = steps.iter().map(|s| s.plan.bytes()).sum();
        Self { steps, bytes, tuned: None }
    }

    /// Attach the tuned configuration this plan was compiled from. The
    /// config's serialized size joins [`Self::bytes`] so the plan-cache
    /// LRU accounts the tuning sidecar alongside the staged operands.
    pub fn set_tuned(&mut self, cfg: TunedConfig) {
        self.bytes += cfg.bytes();
        self.tuned = Some(cfg);
    }

    /// The tuned configuration this plan was compiled from, if any.
    pub fn tuned(&self) -> Option<&TunedConfig> {
        self.tuned.as_ref()
    }

    pub fn steps(&self) -> &[PlanStep] {
        &self.steps
    }

    /// Total resident bytes of the staged operands across all layers —
    /// the quantity the `Runtime` plan cache bounds with LRU eviction.
    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

#[cfg(test)]
mod tests {
    use super::super::pool::ExecPool;
    use super::*;
    use crate::dnn::Manifest;
    use crate::rbe::functional::{conv_bitserial, conv_reference};
    use crate::util::Rng;

    fn quickstart_entry() -> ManifestEntry {
        Manifest::builtin()
            .get("conv3x3_h16_ci32_co32_s1_w4i4o4")
            .unwrap()
            .clone()
    }

    fn random_conv_inputs(
        e: &ManifestEntry,
        seed: u64,
    ) -> (Vec<i32>, Vec<i32>, Vec<i32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let full = e.full_side();
        let half = 1 << (e.w_bits - 1);
        let x = (0..full * full * e.cin)
            .map(|_| rng.range_i32(0, 1 << e.i_bits))
            .collect();
        let w = (0..e.cout * e.cin * 9)
            .map(|_| rng.range_i32(-half, half))
            .collect();
        let scale = (0..e.cout).map(|_| rng.range_i32(1, 16)).collect();
        let bias = (0..e.cout).map(|_| rng.range_i32(-500, 500)).collect();
        (x, w, scale, bias)
    }

    /// The plan path and both functional models agree on the quickstart
    /// layer, for every numerics policy.
    #[test]
    fn plan_matches_functional_models() {
        let e = quickstart_entry();
        let (x, w, scale, bias) = random_conv_inputs(&e, 99);
        let job = e.rbe_job().unwrap();
        let nq = NormQuant::new(scale.clone(), bias.clone(), e.shift);
        let xt = trim_input(&x, e.full_side(), job.h_in(), e.cin);
        let want = conv_reference(&job, &xt, &w, &nq).unwrap();
        assert_eq!(want, conv_bitserial(&job, &xt, &w, &nq).unwrap());
        for numerics in [
            NativeNumerics::Auto,
            NativeNumerics::BitSerial,
            NativeNumerics::Reference,
        ] {
            let plan =
                LayerPlan::compile(&e, &w, &scale, &bias, numerics).unwrap();
            let LayerPlan::Conv(c) = &plan else {
                panic!("conv entry compiled to a non-conv plan")
            };
            // the policy resolves to the expected kernel staging
            assert_eq!(
                c.is_packed(),
                numerics != NativeNumerics::Reference,
                "{numerics:?}"
            );
            assert_eq!(c.run(&x).unwrap(), want, "{numerics:?}");
        }
    }

    #[test]
    fn plan_rejects_bad_activation_plane() {
        let e = quickstart_entry();
        let (_, w, scale, bias) = random_conv_inputs(&e, 3);
        let plan =
            LayerPlan::compile(&e, &w, &scale, &bias, NativeNumerics::Auto)
                .unwrap();
        let LayerPlan::Conv(c) = &plan else { panic!() };
        let err = c.run(&[0i32; 7]).unwrap_err().to_string();
        assert!(err.contains("activation plane"), "{err}");
    }

    #[test]
    fn compile_validates_weights_once() {
        let e = quickstart_entry();
        let (_, mut w, scale, bias) = random_conv_inputs(&e, 4);
        w[0] = 1 << 10; // far outside signed 4-bit range
        for numerics in [NativeNumerics::BitSerial, NativeNumerics::Reference]
        {
            assert!(
                LayerPlan::compile(&e, &w, &scale, &bias, numerics).is_err(),
                "{numerics:?} accepted out-of-range weights"
            );
        }
    }

    /// Plan bytes equal the staged-operand footprint exactly: packed
    /// bit-plane words (or raw reference weights) + requant constants.
    #[test]
    fn plan_bytes_account_staged_operands() {
        let e = quickstart_entry();
        let (_, w, scale, bias) = random_conv_inputs(&e, 8);
        let nq_bytes = 2 * e.cout * 4;
        let packed =
            LayerPlan::compile(&e, &w, &scale, &bias, NativeNumerics::BitSerial)
                .unwrap();
        // Kout * ceil(Kin/32) * w_bits * 9 taps * 4 bytes/word
        assert_eq!(packed.bytes(), 32 * 1 * 4 * 9 * 4 + nq_bytes);
        let reference =
            LayerPlan::compile(&e, &w, &scale, &bias, NativeNumerics::Reference)
                .unwrap();
        assert_eq!(reference.bytes(), w.len() * 4 + nq_bytes);
        // elementwise plans account as 0
        let add = Manifest::builtin().get("add_h8_k64_o4_sh1").unwrap().clone();
        let plan =
            LayerPlan::compile(&add, &[], &[], &[], NativeNumerics::Auto)
                .unwrap();
        assert_eq!(plan.bytes(), 0);
        // and the network roll-up is the sum over steps
        let np = NetworkPlan::new(vec![
            PlanStep { layer: quickstart_test_layer(), plan: packed, setup_us: 0.0 },
            PlanStep { layer: quickstart_test_layer(), plan, setup_us: 0.0 },
        ]);
        assert_eq!(np.bytes(), 32 * 4 * 9 * 4 + nq_bytes);
    }

    fn quickstart_test_layer() -> crate::dnn::Layer {
        crate::dnn::quickstart_layer()
    }

    /// A conv entry wide enough (cin > 32) that plan compilation picks
    /// 64-lane words and tiling has real work to split.
    fn wide_entry() -> ManifestEntry {
        ManifestEntry {
            name: "conv3x3_h8_ci64_co64_s1_w4i4o4".into(),
            op: LayerOp::Conv3x3,
            h: 8,
            cin: 64,
            cout: 64,
            stride: 1,
            w_bits: 4,
            i_bits: 4,
            o_bits: 4,
            shift: 10,
        }
    }

    /// Wide layers compile to 64-lane plans whose reported bytes match
    /// the actual word allocation exactly (ISSUE 4 satellite: the
    /// plan-cache LRU must account real `Vec` word sizes, not assume
    /// 4-byte words).
    #[test]
    fn wide_plan_bytes_track_word_size() {
        let e = wide_entry();
        let (_, w, scale, bias) = random_conv_inputs(&e, 21);
        let plan =
            LayerPlan::compile(&e, &w, &scale, &bias, NativeNumerics::BitSerial)
                .unwrap();
        let LayerPlan::Conv(c) = &plan else { panic!() };
        assert_eq!(c.plane_width(), Some(PlaneWidth::W64));
        // Kout * ceil(Kin/64) * w_bits * 9 taps * 8 bytes/word + requant
        assert_eq!(plan.bytes(), 64 * 1 * 4 * 9 * 8 + 2 * 64 * 4);
        // the narrow quickstart layer stays on the literal 32-lane
        // §II-B3 layout
        let q = quickstart_entry();
        let (_, w, scale, bias) = random_conv_inputs(&q, 22);
        let plan =
            LayerPlan::compile(&q, &w, &scale, &bias, NativeNumerics::BitSerial)
                .unwrap();
        let LayerPlan::Conv(c) = &plan else { panic!() };
        assert_eq!(c.plane_width(), Some(PlaneWidth::W32));
    }

    /// `run_tiled` is bitwise identical to the sequential `run` at every
    /// thread count, for both kernel stagings.
    #[test]
    fn tiled_run_matches_sequential_run() {
        let e = wide_entry();
        let (x, w, scale, bias) = random_conv_inputs(&e, 23);
        for numerics in [NativeNumerics::BitSerial, NativeNumerics::Reference]
        {
            let plan =
                LayerPlan::compile(&e, &w, &scale, &bias, numerics).unwrap();
            let LayerPlan::Conv(c) = &plan else { panic!() };
            let want = c.run(&x).unwrap();
            for threads in [1usize, 2, 3, 5, 8, 64] {
                assert_eq!(
                    c.run_tiled(&x, threads).unwrap(),
                    want,
                    "{numerics:?} with {threads} workers"
                );
            }
            // bad planes fail the same way as the sequential path
            assert!(c.run_tiled(&[0i32; 3], 4).is_err());
        }
    }

    /// `run_scheduled` over a persistent pool — banded pack + tile
    /// fan-out — is bitwise identical to the sequential `run` at every
    /// pool width, for both kernel stagings, across several layers
    /// reusing ONE pool (the provision-once/stream-jobs shape).
    #[test]
    fn pooled_run_matches_sequential_run() {
        let e = wide_entry();
        let (x, w, scale, bias) = random_conv_inputs(&e, 29);
        for numerics in [NativeNumerics::BitSerial, NativeNumerics::Reference]
        {
            let plan =
                LayerPlan::compile(&e, &w, &scale, &bias, numerics).unwrap();
            let LayerPlan::Conv(c) = &plan else { panic!() };
            let want = c.run(&x).unwrap();
            for threads in [1usize, 2, 3, 5, 8] {
                ExecPool::with(threads, |pool| {
                    // several jobs through one pool: reuse is the point
                    for round in 0..3 {
                        let got = c
                            .run_scheduled(&x, ExecCtx::Owned(pool))
                            .unwrap();
                        assert_eq!(
                            got.out, want,
                            "{numerics:?}, {threads} workers, round {round}"
                        );
                    }
                    // bad planes fail identically through the pool
                    assert!(c
                        .run_scheduled(&[0i32; 3], ExecCtx::Owned(pool))
                        .is_err());
                });
                // ...and the process-wide runtime produces the same
                // words at every requested lane count
                let got =
                    c.run_scheduled(&x, ExecCtx::Global(threads)).unwrap();
                assert_eq!(
                    got.out, want,
                    "{numerics:?}, {threads} global lanes"
                );
            }
            assert!(c
                .run_scheduled(&[0i32; 3], ExecCtx::Global(4))
                .is_err());
        }
    }

    /// `run_scheduled_factored` — the autotuner's measurement hook —
    /// is bitwise identical to the sequential `run` for every
    /// (width × tile factor × band factor) candidate the tuner may
    /// try, through one shared pool per width.
    #[test]
    fn factored_run_matches_sequential_for_all_candidates() {
        use super::super::tune::{
            BAND_FACTOR_CANDIDATES, TILE_FACTOR_CANDIDATES,
        };
        let e = wide_entry();
        let (x, w, scale, bias) = random_conv_inputs(&e, 33);
        let mut want: Option<Vec<i32>> = None;
        for width in PlaneWidth::ALL {
            let t = LayerTune {
                layer: e.name.clone(),
                width: Some(width),
                factors: SplitFactors { tile: 2, band: 2 },
                tuned_us: 0.0,
                heuristic_us: 0.0,
            };
            let plan = LayerPlan::compile_with(
                &e,
                &w,
                &scale,
                &bias,
                NativeNumerics::BitSerial,
                Some(&t),
            )
            .unwrap();
            let LayerPlan::Conv(c) = &plan else { panic!() };
            assert_eq!(c.plane_width(), Some(width), "tuned width applied");
            let out = c.run(&x).unwrap();
            let want = want.get_or_insert(out.clone());
            assert_eq!(&out, want, "{width} sequential");
            ExecPool::with(4, |pool| {
                // the compiled-in (2, 2) factors drive run_scheduled...
                let got =
                    c.run_scheduled(&x, ExecCtx::Owned(pool)).unwrap();
                assert_eq!(&got.out, want, "{width} compiled factors");
                // ...and every candidate override stays identical
                for tf in TILE_FACTOR_CANDIDATES {
                    for bf in BAND_FACTOR_CANDIDATES {
                        let f = SplitFactors { tile: tf, band: bf };
                        let got = c
                            .run_scheduled_factored(
                                &x,
                                ExecCtx::Owned(pool),
                                f,
                            )
                            .unwrap();
                        assert_eq!(
                            &got.out, want,
                            "{width} tile x{tf} band x{bf}"
                        );
                        // the global runtime re-partitions to the same
                        // words for the same candidate
                        let got = c
                            .run_scheduled_factored(
                                &x,
                                ExecCtx::Global(4),
                                f,
                            )
                            .unwrap();
                        assert_eq!(
                            &got.out, want,
                            "{width} tile x{tf} band x{bf} (global)"
                        );
                    }
                }
            });
        }
    }

    /// A conv entry past two 32-channel groups compiles to 128-lane
    /// plans whose bytes track the 16-byte word size.
    #[test]
    fn widest_plan_picks_u128_words() {
        let e = ManifestEntry {
            name: "conv3x3_h8_ci96_co8_s1_w4i4o4".into(),
            op: LayerOp::Conv3x3,
            h: 8,
            cin: 96,
            cout: 8,
            stride: 1,
            w_bits: 4,
            i_bits: 4,
            o_bits: 4,
            shift: 10,
        };
        let (x, w, scale, bias) = random_conv_inputs(&e, 31);
        let plan =
            LayerPlan::compile(&e, &w, &scale, &bias, NativeNumerics::BitSerial)
                .unwrap();
        let LayerPlan::Conv(c) = &plan else { panic!() };
        assert_eq!(c.plane_width(), Some(PlaneWidth::W128));
        // Kout * ceil(96/128) * w_bits * 9 taps * 16 bytes/word + requant
        assert_eq!(plan.bytes(), 8 * 1 * 4 * 9 * 16 + 2 * 8 * 4);
        // and the kernel agrees with the oracle bitwise
        let r =
            LayerPlan::compile(&e, &w, &scale, &bias, NativeNumerics::Reference)
                .unwrap();
        let LayerPlan::Conv(oracle) = &r else { panic!() };
        let want = oracle.run(&x).unwrap();
        assert_eq!(c.run(&x).unwrap(), want);
        ExecPool::with(4, |pool| {
            assert_eq!(
                c.run_scheduled(&x, ExecCtx::Owned(pool)).unwrap().out,
                want
            );
        });
        assert_eq!(
            c.run_scheduled(&x, ExecCtx::Global(4)).unwrap().out,
            want
        );
    }

    /// Below the latency-tile MAC floor a pooled `run_scheduled`
    /// degrades gracefully to the inline path — no worker handoff, no
    /// pack job — and stays bitwise identical.
    #[test]
    fn tiny_jobs_degrade_inside_the_pool() {
        let m = Manifest::builtin();
        let e = m.get("linear_ci64_co10_w8i8o8").unwrap();
        assert!(e.rbe_job().unwrap().macs() < LATENCY_TILE_MIN_MACS);
        let (_, w, scale, bias) = random_conv_inputs_linear(e, 26);
        let mut rng = Rng::new(27);
        let x: Vec<i32> = (0..64).map(|_| rng.range_i32(0, 256)).collect();
        let plan =
            LayerPlan::compile(e, &w, &scale, &bias, NativeNumerics::Auto)
                .unwrap();
        let LayerPlan::Conv(c) = &plan else { panic!() };
        ExecPool::with(8, |pool| {
            let jobs_before = pool.telemetry().jobs;
            let got = c.run_scheduled(&x, ExecCtx::Owned(pool)).unwrap();
            assert_eq!(got.out, c.run(&x).unwrap());
            assert_eq!(
                pool.telemetry().jobs,
                jobs_before,
                "a tiny layer must not stream pool jobs"
            );
        });
    }

    fn random_conv_inputs_linear(
        e: &ManifestEntry,
        seed: u64,
    ) -> (Vec<i32>, Vec<i32>, Vec<i32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let half = 1 << (e.w_bits - 1);
        let x = (0..e.cin).map(|_| rng.range_i32(0, 1 << e.i_bits)).collect();
        let w = (0..e.cout * e.cin)
            .map(|_| rng.range_i32(-half, half))
            .collect();
        let scale = (0..e.cout).map(|_| rng.range_i32(1, 16)).collect();
        let bias = (0..e.cout).map(|_| rng.range_i32(-500, 500)).collect();
        (x, w, scale, bias)
    }

    /// Below the latency-tile MAC floor `run_tiled` degrades to the
    /// sequential path (no worker handoff for tiny layers) and stays
    /// bitwise identical.
    #[test]
    fn tiny_jobs_skip_the_tile_pool() {
        let m = Manifest::builtin();
        let e = m.get("linear_ci64_co10_w8i8o8").unwrap();
        assert!(e.rbe_job().unwrap().macs() < LATENCY_TILE_MIN_MACS);
        let mut rng = Rng::new(24);
        let w: Vec<i32> =
            (0..10 * 64).map(|_| rng.range_i32(-128, 128)).collect();
        let x: Vec<i32> = (0..64).map(|_| rng.range_i32(0, 256)).collect();
        let scale: Vec<i32> = (0..10).map(|_| rng.range_i32(1, 16)).collect();
        let bias: Vec<i32> =
            (0..10).map(|_| rng.range_i32(-500, 500)).collect();
        let plan =
            LayerPlan::compile(e, &w, &scale, &bias, NativeNumerics::Auto)
                .unwrap();
        let LayerPlan::Conv(c) = &plan else { panic!() };
        assert_eq!(c.run_tiled(&x, 8).unwrap(), c.run(&x).unwrap());
    }

    /// `tile_split` partitions the output exactly: every (row, k_out)
    /// cell is covered by exactly one tile at every worker count,
    /// including spatial-less (h_out = 1) linear-shaped jobs.
    #[test]
    fn tile_split_partitions_output_exactly() {
        for (h_out, k_out) in [(8usize, 64usize), (1, 12), (3, 2), (6, 1)] {
            let job =
                RbeJob::conv1x1(h_out, h_out, 4, k_out, 1, 4, 4, 4).unwrap();
            for threads in 1..=20usize {
                let tiles = tile_split(&job, threads);
                let mut cover = vec![0u32; h_out * k_out];
                for t in &tiles {
                    assert!(t.row0 < t.row1 && t.ko0 < t.ko1, "{t:?}");
                    for r in t.row0..t.row1 {
                        for k in t.ko0..t.ko1 {
                            cover[r * k_out + k] += 1;
                        }
                    }
                }
                assert!(
                    cover.iter().all(|&c| c == 1),
                    "h_out {h_out} k_out {k_out} threads {threads}: \
                     non-exact cover {cover:?}"
                );
                assert!(tiles.len() <= threads.max(1) * 2);
            }
        }
    }

    /// A `linears` manifest entry compiles to a signed-clip plan: zero
    /// activations with a negative bias stay negative instead of
    /// ReLU-clipping to 0.
    #[test]
    fn signed_head_plan_keeps_negative_logits() {
        let m = Manifest::builtin();
        let e = m.get("linears_ci16_co12_w8i8o8").unwrap();
        let w = vec![0i32; 12 * 16];
        let scale = vec![1i32; 12];
        let bias = vec![-(1 << 20); 12];
        let plan =
            LayerPlan::compile(e, &w, &scale, &bias, NativeNumerics::Auto)
                .unwrap();
        let LayerPlan::Conv(c) = &plan else { panic!() };
        let out = c.run(&vec![0i32; 16]).unwrap();
        let want = ((-(1i64 << 20)) >> e.shift).clamp(-128, 127) as i32;
        assert!(want < 0);
        assert_eq!(out, vec![want; 12]);
    }

    #[test]
    fn auto_prefers_packed_when_cheaper() {
        // 2b x 4b over 64 channels: 8 word-ops/tap vs 64 multiplies
        let cheap = RbeJob::conv3x3(30, 30, 64, 64, 1, 2, 4, 4).unwrap();
        assert!(cheap.macs() > AUTO_BITSERIAL_MACS);
        assert!(NativeNumerics::Auto.packed_for(&cheap));
        // 8b x 8b over 16 channels: 64 word-ops/tap vs 16 multiplies
        let dear = RbeJob::conv3x3(30, 30, 16, 16, 1, 8, 8, 8).unwrap();
        assert!(dear.macs() > AUTO_BITSERIAL_MACS);
        assert!(!NativeNumerics::Auto.packed_for(&dear));
    }
}
