//! The pluggable execution-backend contract.

use anyhow::Result;

use super::plan::NativeNumerics;
use super::tensor::TensorArg;

/// Which engine a backend (or runtime) executes on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// Pure-Rust in-process execution via `rbe::functional`.
    Native,
    /// PJRT execution of AOT-compiled HLO-text artifacts.
    Pjrt,
}

impl BackendKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// One compiled artifact, ready to execute. Implementations must be
/// immutable after compilation (`execute_i32` takes `&self`) so a single
/// instance can be shared across worker threads.
pub trait LayerExec: Send + Sync {
    /// Artifact name this executable was compiled from.
    fn name(&self) -> &str;

    /// Execute with s32 tensor arguments; returns the flattened s32
    /// outputs of the result tuple (artifacts are lowered with
    /// `return_tuple=True`, so even single-output layers come back as a
    /// one-element vec).
    fn execute_i32(&self, args: &[TensorArg]) -> Result<Vec<Vec<i32>>>;
}

/// An execution engine that can compile artifact names into executables.
///
/// Backends are `Send + Sync`; the [`super::Runtime`] wraps one in an
/// `Arc` and adds the per-artifact compile cache, so `compile` is only
/// called once per artifact name per runtime.
pub trait ExecBackend: Send + Sync {
    fn kind(&self) -> BackendKind;

    /// Platform string for diagnostics (e.g. "native", "cpu").
    fn platform(&self) -> String;

    /// True if `compile(name)` can succeed — artifact file present (PJRT)
    /// or layer signature known to the built-in zoo (native). Tests use
    /// this to skip artifact-dependent cases cleanly.
    fn has_artifact(&self, name: &str) -> bool;

    /// Names of all artifacts this backend can execute, sorted.
    fn list_artifacts(&self) -> Vec<String>;

    /// Compile the named artifact into an executable layer.
    fn compile(&self, name: &str) -> Result<Box<dyn LayerExec>>;

    /// Numerics policy that precompiled layer plans (`super::plan`)
    /// should follow for this backend. The native backend forwards its
    /// configured policy; others keep the default.
    fn plan_numerics(&self) -> NativeNumerics {
        NativeNumerics::Auto
    }
}
