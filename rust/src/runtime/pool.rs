//! Persistent execution pool: provision workers once, stream jobs.
//!
//! Marsellus' cluster amortizes its 16-core fan-out across a whole
//! workload — cores are provisioned once and fed jobs, they are not
//! re-spawned per layer (paper §IV). The pre-pool serving path did the
//! opposite: `ConvPlan::run_tiled` spawned and joined a fresh
//! scoped-thread set for *every* conv layer (~20 spawn/join cycles per
//! ResNet-20 image). [`ExecPool`] recovers that overhead: workers are
//! spawned once per serving call ([`ExecPool::with`]), block on a job
//! queue, and every layer's fan-out ([`ExecPool::scatter`]) is one
//! condvar wake + an atomic index race instead of a thread spawn.
//!
//! A *job* is an indexed task set (`n` items, workers pull the next
//! index from an atomic counter); `scatter` submits one job, has the
//! calling thread participate, and returns once every item completed —
//! the inter-layer barrier of the layer walk. One job runs at a time
//! (`scatter` is not reentrant from inside a task): the serving layer
//! walk is sequential between layers by construction, which is exactly
//! the barrier this models.
//!
//! Task payloads are `Arc<dyn Fn(usize) + Send + Sync + 'env>`: per-job
//! operands are `Arc`-shared into the closure (no lifetime erasure, no
//! `unsafe`), while long-lived operands (the compiled plan, the
//! coordinator) are borrowed at the pool's `'env` lifetime.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use crate::analysis::sync::{AtomicUsize, Condvar, Mutex};

/// One indexed task set: workers call `task(i)` for every `i in 0..n`,
/// each index exactly once.
type Task<'env> = Arc<dyn Fn(usize) + Send + Sync + 'env>;

struct Job<'env> {
    task: Task<'env>,
    n: usize,
    /// Next item index to pull (shared lock-free with the workers).
    next: Arc<AtomicUsize>,
    /// Items not yet completed (guarded by the state mutex so the
    /// submitter's completion wait cannot miss a wakeup).
    pending: usize,
    /// Submission generation, so a worker never re-enters a job it
    /// already drained.
    gen: u64,
}

struct State<'env> {
    job: Option<Job<'env>>,
    gen: u64,
    shutdown: bool,
}

/// A pool of workers provisioned once and fed per-layer jobs — see the
/// module docs. Created via [`ExecPool::with`]; `width` counts the
/// submitting thread, so `with(1, ..)` spawns nothing and `scatter`
/// degrades to an inline loop.
pub struct ExecPool<'env> {
    state: Mutex<State<'env>>,
    /// Workers wait here for a new job generation (or shutdown).
    work_ready: Condvar,
    /// The submitter waits here for the last straggler of its job.
    job_done: Condvar,
    width: usize,
    jobs: AtomicUsize,
}

/// Pool counters surfaced by `Deployment::profile_scheduled` and the
/// CLI: how many OS threads served how many per-layer jobs. The
/// recovered overhead is visible by contrast — the pre-pool path spawned
/// `width - 1` fresh threads per tiled conv layer instead of
/// `spawned_threads` once.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolTelemetry {
    /// Worker count including the submitting thread.
    pub width: usize,
    /// OS threads actually spawned (once, `width - 1`).
    pub spawned_threads: usize,
    /// Jobs streamed through the queue (tile fan-outs + packing bands
    /// + image shards).
    pub jobs: usize,
}

impl PoolTelemetry {
    /// The telemetry of running without a pool (sequential walk).
    pub fn sequential() -> Self {
        Self { width: 1, spawned_threads: 0, jobs: 0 }
    }
}

/// Decrements the pending count when dropped — even if the task
/// panicked, so the submitting thread never deadlocks waiting for an
/// item that will not complete (the panic then propagates at scope
/// join).
struct DoneGuard<'p, 'env> {
    pool: &'p ExecPool<'env>,
}

impl Drop for DoneGuard<'_, '_> {
    fn drop(&mut self) {
        let mut st = self.pool.state.lock().unwrap();
        if let Some(job) = st.job.as_mut() {
            job.pending -= 1;
            if job.pending == 0 {
                self.pool.job_done.notify_all();
            }
        }
    }
}

impl<'env> ExecPool<'env> {
    /// Provision a pool of `threads` workers (the calling thread
    /// counts; `threads - 1` OS threads are spawned), run `f` with it,
    /// then shut the workers down. The fan-out is clamped to 2x the
    /// machine's cores: more workers than cores only adds handoff
    /// overhead, and an absurd operator value (`--threads 9999`) must
    /// degrade, not abort on thread exhaustion.
    pub fn with<R>(
        threads: usize,
        f: impl FnOnce(&ExecPool<'env>) -> R,
    ) -> R {
        let cores = std::thread::available_parallelism()
            .map(|v| v.get())
            .unwrap_or(1);
        let width = threads.clamp(1, cores.saturating_mul(2));
        let pool = ExecPool {
            state: Mutex::new(State { job: None, gen: 0, shutdown: false }),
            work_ready: Condvar::new(),
            job_done: Condvar::new(),
            width,
            jobs: AtomicUsize::new(0),
        };
        if width == 1 {
            return f(&pool);
        }
        std::thread::scope(|s| {
            for _ in 0..width - 1 {
                s.spawn(|| pool.worker_loop());
            }
            let out = f(&pool);
            pool.shutdown();
            out
        })
    }

    /// Worker count, including the submitting thread — what per-layer
    /// splits (`tile_split`, packing bands) should size against.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Snapshot of the pool counters.
    pub fn telemetry(&self) -> PoolTelemetry {
        PoolTelemetry {
            width: self.width,
            spawned_threads: self.width - 1,
            jobs: self.jobs.load(Ordering::Relaxed),
        }
    }

    /// Run `task(i)` for every `i in 0..n` across the pool and block
    /// until all items completed (the inter-layer barrier). The calling
    /// thread participates, so a 1-wide pool (or `n == 1`) degrades to
    /// an inline loop with no synchronization. Each index is pulled by
    /// exactly one worker; completion order is unspecified, so tasks
    /// must write disjoint outputs (slot-per-index).
    ///
    /// Must not be called from inside a task of the same pool: one job
    /// streams at a time.
    pub fn scatter(&self, n: usize, task: Task<'env>) {
        if n == 0 {
            return;
        }
        self.jobs.fetch_add(1, Ordering::Relaxed);
        if self.width == 1 || n == 1 {
            for i in 0..n {
                task(i);
            }
            return;
        }
        let next = Arc::new(AtomicUsize::new(0));
        {
            let mut st = self.state.lock().unwrap();
            assert!(
                st.job.is_none(),
                "ExecPool::scatter is not reentrant: a job is already \
                 streaming"
            );
            st.gen += 1;
            st.job = Some(Job {
                task: task.clone(),
                n,
                next: next.clone(),
                pending: n,
                gen: st.gen,
            });
            self.work_ready.notify_all();
        }
        // Participate: the submitter is a full member of the pool.
        self.pull(&task, n, &next);
        // Barrier: wait for the stragglers, then retire the job.
        let mut st = self.state.lock().unwrap();
        while st.job.as_ref().is_some_and(|j| j.pending > 0) {
            st = self.job_done.wait(st).unwrap();
        }
        st.job = None;
    }

    /// Pull item indices until the job is drained.
    fn pull(&self, task: &Task<'env>, n: usize, next: &AtomicUsize) {
        loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                return;
            }
            let guard = DoneGuard { pool: self };
            task(i);
            drop(guard);
        }
    }

    fn worker_loop(&self) {
        let mut seen = 0u64;
        loop {
            let mut st = self.state.lock().unwrap();
            let (task, n, next) = loop {
                if st.shutdown {
                    return;
                }
                let fresh =
                    st.job.as_ref().is_some_and(|j| j.gen != seen);
                if fresh {
                    let j = st.job.as_ref().expect("checked fresh");
                    seen = j.gen;
                    break (j.task.clone(), j.n, j.next.clone());
                }
                st = self.work_ready.wait(st).unwrap();
            };
            drop(st);
            self.pull(&task, n, &next);
            // drop the task Arc before sleeping so per-job operands are
            // released as soon as the job retires
            drop(task);
        }
    }

    fn shutdown(&self) {
        let mut st = self.state.lock().unwrap();
        st.shutdown = true;
        self.work_ready.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every index of every job is executed exactly once, across many
    /// sequential jobs on one pool (the reuse the spawn-per-layer path
    /// never had), at every width.
    #[test]
    fn scatter_runs_each_index_once_across_jobs() {
        for threads in [1usize, 2, 3, 8] {
            ExecPool::with(threads, |pool| {
                for n in [0usize, 1, 5, 64] {
                    let hits: Arc<Vec<AtomicUsize>> = Arc::new(
                        (0..n).map(|_| AtomicUsize::new(0)).collect(),
                    );
                    let task = {
                        let hits = hits.clone();
                        Arc::new(move |i: usize| {
                            hits[i].fetch_add(1, Ordering::Relaxed);
                        })
                    };
                    pool.scatter(n, task);
                    for (i, h) in hits.iter().enumerate() {
                        assert_eq!(
                            h.load(Ordering::Relaxed),
                            1,
                            "threads {threads}, n {n}, index {i}"
                        );
                    }
                }
            });
        }
    }

    /// The barrier holds: after `scatter` returns, every item's side
    /// effect is visible to the submitter.
    #[test]
    fn scatter_is_a_barrier() {
        ExecPool::with(4, |pool| {
            for round in 0..50usize {
                let n = 16;
                let slots: Arc<Vec<Mutex<Option<usize>>>> =
                    Arc::new((0..n).map(|_| Mutex::new(None)).collect());
                let task = {
                    let slots = slots.clone();
                    Arc::new(move |i: usize| {
                        *slots[i].lock().unwrap() = Some(i * i);
                    })
                };
                pool.scatter(n, task);
                for (i, s) in slots.iter().enumerate() {
                    assert_eq!(
                        s.lock().unwrap().take(),
                        Some(i * i),
                        "round {round}"
                    );
                }
            }
        });
    }

    /// Telemetry: width counts the submitter, spawns happen once, jobs
    /// count scatters (including degenerate ones).
    #[test]
    fn telemetry_counts_spawns_and_jobs() {
        ExecPool::with(3, |pool| {
            assert_eq!(pool.telemetry().jobs, 0);
            for _ in 0..5 {
                pool.scatter(4, Arc::new(|_: usize| {}));
            }
            pool.scatter(0, Arc::new(|_: usize| {})); // no-op, not a job
            let t = pool.telemetry();
            assert_eq!(t.width, pool.width());
            assert_eq!(t.spawned_threads, pool.width() - 1);
            assert_eq!(t.jobs, 5);
        });
        assert_eq!(PoolTelemetry::sequential().spawned_threads, 0);
    }

    /// An absurd worker request degrades to the 2x-cores clamp instead
    /// of exhausting the machine; 0 degrades to 1.
    #[test]
    fn width_is_clamped_to_the_machine() {
        let cores = std::thread::available_parallelism()
            .map(|v| v.get())
            .unwrap_or(1);
        ExecPool::with(usize::MAX, |pool| {
            assert!(pool.width() <= cores * 2);
        });
        ExecPool::with(0, |pool| {
            assert_eq!(pool.width(), 1);
            // and a 1-wide pool still runs jobs (inline)
            let ran = Arc::new(AtomicUsize::new(0));
            let r = ran.clone();
            pool.scatter(
                3,
                Arc::new(move |_: usize| {
                    r.fetch_add(1, Ordering::Relaxed);
                }),
            );
            assert_eq!(ran.load(Ordering::Relaxed), 3);
        });
    }

    /// Tasks may borrow data at the pool's `'env` lifetime (the
    /// compiled-plan pattern): a stack value declared outside `with` is
    /// readable from every worker.
    #[test]
    fn tasks_borrow_env_data() {
        let table: Vec<usize> = (0..32).map(|i| i * 7).collect();
        let out: Vec<AtomicUsize> =
            (0..32).map(|_| AtomicUsize::new(0)).collect();
        ExecPool::with(4, |pool| {
            let table = &table;
            let out = &out;
            pool.scatter(
                32,
                Arc::new(move |i| {
                    out[i].store(table[i], Ordering::Relaxed);
                }),
            );
        });
        for (i, o) in out.iter().enumerate() {
            assert_eq!(o.load(Ordering::Relaxed), i * 7);
        }
    }
}
