//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! This is the only place the `xla` crate is touched. Artifacts are produced
//! once at build time by `python/compile/aot.py` (HLO *text*, not serialized
//! protos — see /opt/xla-example/README.md); the rust hot path never calls
//! into Python.

mod client;
mod executable;

pub use client::Runtime;
pub use executable::{Executable, TensorArg};
