//! Pluggable execution runtime for the AOT-compiled DNN layer artifacts.
//!
//! The functional numerics of every layer are defined once, in
//! `python/compile/kernels` (lowered to HLO-text artifacts at build time)
//! and mirrored bit-exactly by `rbe::functional`. This module turns that
//! contract into a swappable [`ExecBackend`]:
//!
//! * [`NativeBackend`] (cargo feature `native`, **default**) — pure Rust:
//!   dispatches each artifact name to the in-tree RBE functional models
//!   (`conv_bitserial` / `conv_reference` + the add/avgpool normquant
//!   kernels). Needs no artifacts on disk: the built-in layer zoo
//!   ([`crate::dnn::Manifest::builtin`]) mirrors exactly what `aot.py`
//!   lowers, so results are bit-exact with the artifacts by construction.
//! * `PjrtBackend` (cargo feature `pjrt`, opt-in) — loads `<name>.hlo.txt`
//!   artifacts through the `xla` PJRT bindings. The workspace vendors a
//!   compile-time stub of `xla`; patch in the real crate to execute.
//!
//! [`Runtime`] owns a backend plus two caches: the per-artifact compile
//! cache (compile once, `Arc`-share thereafter) and the per-deployment
//! [`NetworkPlan`] cache — precompiled layer plans ([`plan`]) that hoist
//! weight packing, job-geometry resolution and requant staging out of
//! the per-inference hot path. Serving fan-out goes through the
//! process-wide work-stealing runtime ([`global`]): workers are
//! provisioned once per *process* and fed per-layer jobs (packing
//! bands, conv tiles, image shards) from every deployment; the scoped
//! per-call [`ExecPool`] survives as the `Owned` A/B path behind the
//! same [`ExecCtx`] handle. The plan cache is keyed by
//! `dnn::NetworkSpec`, byte-accounted and bounded with LRU eviction
//! (`MARSELLUS_PLAN_CACHE_BYTES`), so many-tenant serving cannot grow
//! without bound. Both caches are `Send + Sync`, so the coordinator can
//! fan inference batches out across threads over one shared instance.
//! Deploy-time autotuning ([`TunedConfig`]) replaces the fixed
//! width/split heuristics with per-layer measurements on the live
//! machine; tuned configs persist beside the plan cache and ride inside
//! the cached [`NetworkPlan`].
//!
//! Backend selection: [`Runtime::from_env`] honours
//! `MARSELLUS_BACKEND=native|pjrt`, defaulting to native.

mod backend;
mod executable;
mod global;
mod loader;
#[cfg(feature = "native")]
mod native;
mod plan;
#[cfg(feature = "pjrt")]
mod pjrt;
mod pool;
mod tensor;
mod tune;

pub use backend::{BackendKind, ExecBackend, LayerExec};
pub use executable::Executable;
pub use global::{
    global, ExecCtx, ExecRuntime, GlobalRuntime, GlobalTask,
    GlobalTelemetry,
};
pub use loader::{PlanResidency, Runtime, DEFAULT_PLAN_CACHE_BYTES};
#[cfg(feature = "native")]
pub use native::NativeBackend;
pub use plan::{
    ConvPlan, ConvRun, LayerPlan, NativeNumerics, NetworkPlan, PlanStep,
    AUTO_BITSERIAL_MACS, LATENCY_TILE_MIN_MACS,
};
pub use pool::{ExecPool, PoolTelemetry};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;
pub use tensor::TensorArg;
pub use tune::{
    machine_fingerprint, LayerTune, SplitFactors, TuneOptions, TunedConfig,
    BAND_FACTOR_CANDIDATES, DEFAULT_TUNE_TRIALS, HYBRID_TILE_SPEEDUP_CAP,
    MAX_HYBRID_CUTOVER, TILE_FACTOR_CANDIDATES,
};
