//! Host tensor arguments for executable invocation.

/// A host tensor argument: flat i32 data + dims.
///
/// All Marsellus artifacts use s32 tensors (quantized integer activations,
/// weights, normquant parameters), so a single concrete type keeps the
/// backend interface small. Row-major (C) layout, matching jax defaults.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorArg {
    pub data: Vec<i32>,
    pub dims: Vec<usize>,
}

impl TensorArg {
    pub fn new(data: Vec<i32>, dims: Vec<usize>) -> Self {
        debug_assert_eq!(data.len(), dims.iter().product::<usize>());
        Self { data, dims }
    }

    pub fn scalar_vec(data: Vec<i32>) -> Self {
        let dims = vec![data.len()];
        Self { data, dims }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}
