//! PJRT execution backend (cargo feature `pjrt`, opt-in).
//!
//! The only place the `xla` crate is touched. Artifacts are produced once
//! at build time by `python/compile/aot.py` (HLO *text*, not serialized
//! protos — the text parser reassigns instruction ids, which is what
//! makes jax ≥ 0.5 output loadable); the Rust hot path never calls into
//! Python.
//!
//! Note: the workspace's `vendor/xla` package is a compile-time stub —
//! [`PjrtBackend::cpu`] fails with an explanatory error until the real
//! `xla` crate is patched in (see README "PJRT backend").

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::backend::{BackendKind, ExecBackend, LayerExec};
use super::tensor::TensorArg;

/// A PJRT client rooted at an artifacts directory.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
}

impl PjrtBackend {
    /// Create a CPU PJRT client rooted at `artifacts_dir`.
    pub fn cpu(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu client: {e}"))?;
        Ok(Self { client, artifacts_dir: artifacts_dir.as_ref().to_path_buf() })
    }

    fn artifact_path(&self, name: &str) -> PathBuf {
        self.artifacts_dir.join(format!("{name}.hlo.txt"))
    }
}

impl ExecBackend for PjrtBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Pjrt
    }

    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn has_artifact(&self, name: &str) -> bool {
        self.artifact_path(name).exists()
    }

    fn list_artifacts(&self) -> Vec<String> {
        let mut names = Vec::new();
        if let Ok(rd) = std::fs::read_dir(&self.artifacts_dir) {
            for e in rd.flatten() {
                let fname = e.file_name().to_string_lossy().into_owned();
                if let Some(stem) = fname.strip_suffix(".hlo.txt") {
                    names.push(stem.to_string());
                }
            }
        }
        names.sort();
        names
    }

    fn compile(&self, name: &str) -> Result<Box<dyn LayerExec>> {
        let exe = PjrtExec::from_hlo_text(&self.client, &self.artifact_path(name))
            .with_context(|| format!("loading artifact {name}"))?;
        Ok(Box::new(exe))
    }
}

/// One compiled PJRT executable wrapping an HLO-text artifact.
struct PjrtExec {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

// SAFETY: the xla crate wraps C++ objects behind raw pointers without
// Send/Sync markers; PJRT CPU client objects are documented thread-safe
// for execute(), and PjrtExec exposes nothing else.
unsafe impl Send for PjrtExec {}
// SAFETY: same argument as Send — shared-reference use is limited to
// execute(), which PJRT documents as thread-safe.
unsafe impl Sync for PjrtExec {}

impl PjrtExec {
    /// Parse HLO text, re-assign instruction ids (done by the text parser
    /// — this is why text, not proto, is the interchange format), and
    /// compile for the given client.
    fn from_hlo_text(client: &xla::PjRtClient, path: &Path) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| anyhow::anyhow!("parse hlo text {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e}", path.display()))?;
        Ok(Self {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

impl LayerExec for PjrtExec {
    fn name(&self) -> &str {
        &self.name
    }

    fn execute_i32(&self, args: &[TensorArg]) -> Result<Vec<Vec<i32>>> {
        let mut literals = Vec::with_capacity(args.len());
        for a in args {
            let dims: Vec<i64> = a.dims.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&a.data)
                .reshape(&dims)
                .map_err(|e| anyhow::anyhow!("reshape arg to {dims:?}: {e}"))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {}: {e}", self.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e}"))?;
        let tuple = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("decompose result tuple: {e}"))?;
        let mut outs = Vec::with_capacity(tuple.len());
        for lit in tuple {
            outs.push(
                lit.to_vec::<i32>()
                    .map_err(|e| anyhow::anyhow!("result to_vec<i32>: {e}"))?,
            );
        }
        Ok(outs)
    }
}
