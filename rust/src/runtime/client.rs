//! PJRT CPU client wrapper with an artifact cache.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use super::executable::Executable;

/// A PJRT client plus a cache of compiled executables keyed by artifact name.
///
/// Compilation is performed once per artifact; subsequent lookups are O(1).
/// The runtime is `Send + Sync` via internal locking so the coordinator can
/// share one instance across worker tasks.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    cache: std::sync::Mutex<HashMap<String, Arc<Executable>>>,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at `artifacts_dir`.
    pub fn cpu(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu client: {e}"))?;
        Ok(Self {
            client,
            artifacts_dir: artifacts_dir.as_ref().to_path_buf(),
            cache: std::sync::Mutex::new(HashMap::new()),
        })
    }

    /// Platform name reported by PJRT (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (or fetch from cache) the artifact `<name>.hlo.txt`.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
        let exe = Arc::new(
            Executable::from_hlo_text(&self.client, &path)
                .with_context(|| format!("loading artifact {name}"))?,
        );
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// True if the artifact file exists on disk (used by tests to skip
    /// gracefully when `make artifacts` has not run).
    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifacts_dir.join(format!("{name}.hlo.txt")).exists()
    }

    /// Names of all artifacts present on disk.
    pub fn list_artifacts(&self) -> Vec<String> {
        let mut names = Vec::new();
        if let Ok(rd) = std::fs::read_dir(&self.artifacts_dir) {
            for e in rd.flatten() {
                let fname = e.file_name().to_string_lossy().into_owned();
                if let Some(stem) = fname.strip_suffix(".hlo.txt") {
                    names.push(stem.to_string());
                }
            }
        }
        names.sort();
        names
    }
}
