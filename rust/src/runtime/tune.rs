//! Deploy-time kernel autotuning configuration.
//!
//! Marsellus hits its peak throughput by reconfiguring the RBE per
//! layer; the software analog has the same per-layer degrees of
//! freedom — plane word width ([`PlaneWidth`]), tile multiplier, band
//! multiplier and the hybrid batch/tile cutover — but picks them with
//! fixed heuristics. This module holds the *configuration* side of the
//! measured alternative: a [`TunedConfig`] records, per conv layer, the
//! `(width, tile factor, band factor)` variant that micro-benchmarked
//! fastest on the live machine (the measurement loop itself lives in
//! `coordinator::infer`, which owns plan building), plus a whole-net
//! tile-vs-sequential speedup that replaces the fixed
//! [`HYBRID_TILE_SPEEDUP_CAP`] in the hybrid scheduler.
//!
//! Every candidate the tuner may pick comes from the set already proven
//! bitwise identical (`rbe::functional` width/band/tile parity property
//! tests), and the measurement loop re-asserts identity on every
//! candidate's first trial — tuning changes speed, never logits.
//!
//! Configs persist as `#`-metadata-prefixed TSV next to the plan cache
//! (`TuneOptions::persist_dir`), keyed by `NetworkSpec` **and**
//! [`machine_fingerprint`] so a config tuned on one machine is never
//! served on another, and are byte-accounted into `NetworkPlan::bytes`
//! so the plan-cache LRU sees their footprint.

use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

use crate::rbe::functional::PlaneWidth;
use crate::util::TsvTable;

/// Effective-tile-speedup estimate bounding the hybrid scheduler's
/// tiled remainder when no measured value is available: remainders of
/// `min(threads, CAP)` images or more stay image-parallel, strictly
/// smaller ones are tiled. Rationale: tiling one image across `T`
/// workers yields at most ~`min(T, 8)` effective speedup on the zoo
/// networks (activation packing and elementwise layers bound it), so a
/// remainder of `k` images finishes faster as concurrent whole-image
/// shards (wall = 1 image) once `k >= min(T, 8)`; below that, tiling
/// each in turn wins. A tuned deployment replaces this constant with
/// [`TunedConfig::hybrid_cutover`], derived from the speedup actually
/// observed on the serving machine.
pub const HYBRID_TILE_SPEEDUP_CAP: usize = 8;

/// Largest measured hybrid cutover honoured: beyond this the tiled
/// remainder could cover the whole batch and the hybrid schedule would
/// collapse into pure latency mode.
pub const MAX_HYBRID_CUTOVER: usize = 64;

/// Trial count per candidate when `MARSELLUS_TUNE_TRIALS` is unset.
/// Minimum-of-3 is enough to reject scheduler-noise outliers while
/// keeping deploy-time tuning under a second on the zoo networks.
pub const DEFAULT_TUNE_TRIALS: u32 = 3;

/// Tile-split multipliers the tuner tries on the winning width: the
/// conv tile count becomes `pool_width * factor`, trading scatter
/// overhead against tail imbalance (more, smaller tiles drain evenly).
pub const TILE_FACTOR_CANDIDATES: [usize; 3] = [1, 2, 4];

/// Band-split multipliers for the activation-packing phase, same
/// trade-off as [`TILE_FACTOR_CANDIDATES`] on the pack half.
pub const BAND_FACTOR_CANDIDATES: [usize; 2] = [1, 2];

/// On-disk format version; bumped whenever the TSV schema changes.
const TUNE_FORMAT_VERSION: u32 = 1;

/// Per-layer split-shape multipliers applied when a conv plan fans out
/// over a pool: the tile count is `pool_width * tile` and the packing
/// band count `pool_width * band`. `UNIT` is the pre-tuner heuristic
/// (one tile and one band per worker). Factors only re-partition the
/// same output range, so every value is bitwise identical.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SplitFactors {
    /// Conv tiles per pool worker.
    pub tile: usize,
    /// Activation-packing bands per pool worker.
    pub band: usize,
}

impl SplitFactors {
    /// The heuristic split: one tile and one band per worker.
    pub const UNIT: SplitFactors = SplitFactors { tile: 1, band: 1 };
}

impl Default for SplitFactors {
    fn default() -> Self {
        SplitFactors::UNIT
    }
}

/// How a tuning run is conducted: pool width to measure under, trials
/// per candidate (minimum-of-N), and where winning configs persist.
#[derive(Debug, Clone)]
pub struct TuneOptions {
    /// Pool width the variants are measured under (and that serving is
    /// assumed to use). 0 degrades to 1.
    pub threads: usize,
    /// Trials per candidate; the minimum is kept. **0 skips measurement
    /// entirely** and yields the exact heuristic configuration.
    pub trials: u32,
    /// Directory for persisted configs (`None`: tune in-memory only).
    pub persist_dir: Option<PathBuf>,
}

impl TuneOptions {
    /// Options measuring under `threads` workers with the default trial
    /// budget, without persistence.
    pub fn new(threads: usize, trials: u32) -> Self {
        Self { threads, trials, persist_dir: None }
    }

    /// Read the opt-in tuning environment: `Some` when `MARSELLUS_TUNE`
    /// is truthy (`1`/`true`/`on`/`yes`), with `MARSELLUS_TUNE_TRIALS`,
    /// `MARSELLUS_TUNE_THREADS` (default: the machine's cores) and
    /// `MARSELLUS_TUNE_DIR` filling the fields.
    pub fn from_env() -> Option<Self> {
        let enabled = std::env::var("MARSELLUS_TUNE")
            .map(|v| env_truthy(&v))
            .unwrap_or(false);
        if !enabled {
            return None;
        }
        let cores = std::thread::available_parallelism()
            .map(|v| v.get())
            .unwrap_or(4);
        let threads = std::env::var("MARSELLUS_TUNE_THREADS")
            .ok()
            .and_then(|v| v.trim().parse().ok())
            .unwrap_or(cores);
        let trials = std::env::var("MARSELLUS_TUNE_TRIALS")
            .ok()
            .and_then(|v| v.trim().parse().ok())
            .unwrap_or(DEFAULT_TUNE_TRIALS);
        let persist_dir = std::env::var("MARSELLUS_TUNE_DIR")
            .ok()
            .filter(|v| !v.trim().is_empty())
            .map(PathBuf::from);
        Some(Self { threads, trials, persist_dir })
    }
}

/// `MARSELLUS_TUNE`-style opt-in values.
fn env_truthy(v: &str) -> bool {
    matches!(
        v.trim().to_ascii_lowercase().as_str(),
        "1" | "true" | "on" | "yes"
    )
}

/// The tuned pick for one conv layer: the winning kernel variant plus
/// the measurements that chose it.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerTune {
    /// Layer name (`NetworkPlan` step identity).
    pub layer: String,
    /// Winning plane word width (`None`: the layer runs the reference
    /// staging, which packs no bit-plane words).
    pub width: Option<PlaneWidth>,
    /// Winning split-shape multipliers.
    pub factors: SplitFactors,
    /// Best trial of the winning variant, wall microseconds (0 when the
    /// layer was not measured — below the tile floor or trials = 0).
    pub tuned_us: f64,
    /// Best trial of the heuristic variant under the same pool.
    pub heuristic_us: f64,
}

impl LayerTune {
    /// The unmeasured heuristic pick for a layer (what the plan
    /// compiler would choose on its own).
    pub fn heuristic(layer: &str, width: Option<PlaneWidth>) -> Self {
        Self {
            layer: layer.to_string(),
            width,
            factors: SplitFactors::UNIT,
            tuned_us: 0.0,
            heuristic_us: 0.0,
        }
    }

    /// Measured speedup of the tuned variant over the heuristic one
    /// (1.0 for unmeasured layers).
    pub fn speedup(&self) -> f64 {
        if self.tuned_us > 0.0 && self.heuristic_us > 0.0 {
            self.heuristic_us / self.tuned_us
        } else {
            1.0
        }
    }
}

/// The winning configuration of one tuning run: per-layer variants plus
/// the whole-net tile-vs-sequential speedup, keyed by deployment spec
/// and serving machine.
#[derive(Debug, Clone, PartialEq)]
pub struct TunedConfig {
    /// `NetworkSpec` display form (`network/config/seedN`).
    pub spec: String,
    /// [`machine_fingerprint`] of the machine that measured this.
    pub fingerprint: String,
    /// Pool width the measurements ran under.
    pub threads: usize,
    /// Trials per candidate (0: the unmeasured heuristic config).
    pub trials: u32,
    /// Measured whole-net speedup of the pooled (tile-parallel) walk
    /// over the sequential walk on the tuned plan; 0.0 when unmeasured.
    pub tile_speedup: f64,
    /// Per-conv-layer winners, in plan step order.
    pub layers: Vec<LayerTune>,
}

impl TunedConfig {
    /// The tuned pick for `layer`, if one was recorded.
    pub fn layer(&self, name: &str) -> Option<&LayerTune> {
        self.layers.iter().find(|t| t.layer == name)
    }

    /// Measured hybrid batch/tile cutover: remainders strictly smaller
    /// than this are tiled, larger ones stay image-parallel. The
    /// measured tile-vs-sequential speedup *is* the break-even point
    /// (`k` remainder images finish in `k / tile_speedup` image-walls
    /// tiled vs 1 image-wall sharded), rounded and clamped to
    /// `[1, MAX_HYBRID_CUTOVER]`; an unmeasured config (trials = 0)
    /// falls back to the fixed [`HYBRID_TILE_SPEEDUP_CAP`].
    pub fn hybrid_cutover(&self) -> usize {
        if self.tile_speedup <= 0.0 {
            return HYBRID_TILE_SPEEDUP_CAP;
        }
        (self.tile_speedup.round() as usize).clamp(1, MAX_HYBRID_CUTOVER)
    }

    /// Sum-of-layers predicted speedup of the tuned configuration over
    /// the heuristic one (1.0 when nothing was measured).
    pub fn predicted_speedup(&self) -> f64 {
        let (tuned, heur) = self.layers.iter().fold((0.0, 0.0), |(t, h), l| {
            (t + l.tuned_us, h + l.heuristic_us)
        });
        if tuned > 0.0 && heur > 0.0 {
            heur / tuned
        } else {
            1.0
        }
    }

    /// Resident bytes this config adds to its plan — what
    /// `NetworkPlan::bytes` (and so the plan-cache LRU) accounts for a
    /// tuned deployment. The serialized form *is* the footprint model:
    /// it is within a few words of the in-memory size and keeps the
    /// accounting trivially consistent with what persists.
    pub fn bytes(&self) -> usize {
        self.to_tsv().len()
    }

    /// Serialize to the on-disk form: `#key\tvalue` metadata lines
    /// (version, spec, fingerprint, threads, trials, tile_speedup)
    /// followed by a plain TSV table of per-layer picks.
    pub fn to_tsv(&self) -> String {
        let mut out = String::new();
        out.push_str("# marsellus tuned config\n");
        out.push_str(&format!("#version\t{TUNE_FORMAT_VERSION}\n"));
        out.push_str(&format!("#spec\t{}\n", self.spec));
        out.push_str(&format!("#fingerprint\t{}\n", self.fingerprint));
        out.push_str(&format!("#threads\t{}\n", self.threads));
        out.push_str(&format!("#trials\t{}\n", self.trials));
        out.push_str(&format!("#tile_speedup\t{:.4}\n", self.tile_speedup));
        out.push_str(
            "layer\twidth\ttile_factor\tband_factor\ttuned_us\theuristic_us\n",
        );
        for t in &self.layers {
            let width = match t.width {
                Some(w) => w.lanes().to_string(),
                None => "-".to_string(),
            };
            out.push_str(&format!(
                "{}\t{}\t{}\t{}\t{:.1}\t{:.1}\n",
                t.layer,
                width,
                t.factors.tile,
                t.factors.band,
                t.tuned_us,
                t.heuristic_us,
            ));
        }
        out
    }

    /// Parse the [`Self::to_tsv`] form. Formatting is idempotent:
    /// `from_tsv(to_tsv(c)).to_tsv() == c.to_tsv()`, which is what the
    /// round-trip assertions compare.
    pub fn from_tsv(text: &str) -> Result<Self> {
        let mut meta = std::collections::HashMap::new();
        let mut body = String::new();
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix('#') {
                if let Some((k, v)) = rest.split_once('\t') {
                    meta.insert(k.trim().to_string(), v.trim().to_string());
                }
            } else {
                body.push_str(line);
                body.push('\n');
            }
        }
        let get = |k: &str| {
            meta.get(k)
                .with_context(|| format!("tuned config missing #{k} line"))
        };
        let version: u32 = get("version")?.parse()?;
        ensure!(
            version == TUNE_FORMAT_VERSION,
            "tuned config version {version} (this build reads \
             {TUNE_FORMAT_VERSION})"
        );
        let tile_speedup: f64 = get("tile_speedup")?
            .parse()
            .context("tuned config #tile_speedup is not a number")?;
        let table =
            TsvTable::parse(&body).context("tuned config layer table")?;
        let mut layers = Vec::with_capacity(table.len());
        for row in 0..table.len() {
            let width = match table.get(row, "width")? {
                "-" => None,
                lanes => Some(PlaneWidth::from_lanes(
                    lanes.parse().with_context(|| {
                        format!("tuned config row {row}: bad width {lanes:?}")
                    })?,
                )?),
            };
            let parse_us = |col: &str| -> Result<f64> {
                table.get(row, col)?.parse().with_context(|| {
                    format!("tuned config row {row}: bad {col}")
                })
            };
            layers.push(LayerTune {
                layer: table.get(row, "layer")?.to_string(),
                width,
                factors: SplitFactors {
                    tile: table.get_usize(row, "tile_factor")?.max(1),
                    band: table.get_usize(row, "band_factor")?.max(1),
                },
                tuned_us: parse_us("tuned_us")?,
                heuristic_us: parse_us("heuristic_us")?,
            });
        }
        Ok(Self {
            spec: get("spec")?.clone(),
            fingerprint: get("fingerprint")?.clone(),
            threads: get("threads")?.parse()?,
            trials: get("trials")?.parse()?,
            tile_speedup,
            layers,
        })
    }

    /// On-disk path of the config for `(spec, fingerprint)` under
    /// `dir`: both keys are slugged into the file name so one shared
    /// directory can hold configs for many deployments and machines.
    pub fn path_in(dir: &Path, spec: &str, fingerprint: &str) -> PathBuf {
        dir.join(format!("TUNE_{}__{}.tsv", slug(spec), slug(fingerprint)))
    }

    /// Persist beside the plan cache. Unmeasured (trials = 0) configs
    /// are never written: a persisted heuristic would satisfy later
    /// lookups and block real tuning forever.
    pub fn save(&self, dir: &Path) -> Result<PathBuf> {
        ensure!(
            self.trials > 0,
            "refusing to persist an unmeasured (trials = 0) tuned config"
        );
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let path = Self::path_in(dir, &self.spec, &self.fingerprint);
        std::fs::write(&path, self.to_tsv())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(path)
    }

    /// Load the persisted config for `(spec, fingerprint)` from `dir`.
    /// Returns `Ok(None)` when no *valid* config is available: the file
    /// is absent, or its content keys disagree with the request (a
    /// stale machine fingerprint — e.g. the core count changed — or a
    /// renamed file), or it records no measurements. Malformed content
    /// is an error, not a silent re-tune.
    pub fn load(
        dir: &Path,
        spec: &str,
        fingerprint: &str,
    ) -> Result<Option<Self>> {
        let path = Self::path_in(dir, spec, fingerprint);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(None)
            }
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("reading {}", path.display()))
            }
        };
        let cfg = Self::from_tsv(&text)
            .with_context(|| format!("parsing {}", path.display()))?;
        if cfg.spec != spec
            || cfg.fingerprint != fingerprint
            || cfg.trials == 0
        {
            return Ok(None);
        }
        Ok(Some(cfg))
    }
}

/// Identity of the serving machine for tuned-config keying: OS, ISA and
/// core count (plus the format version, so a schema bump reads as a
/// fresh machine instead of a parse error). Coarse on purpose — it must
/// change when the measured trade-offs plausibly change (different
/// machine, different core count) and stay stable across reboots.
pub fn machine_fingerprint() -> String {
    let cores = std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(1);
    format!(
        "v{TUNE_FORMAT_VERSION}-{}-{}-{cores}c",
        std::env::consts::OS,
        std::env::consts::ARCH,
    )
}

/// File-name-safe slug: alphanumerics kept, every other run of
/// characters collapsed to one `-`.
fn slug(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut dash = false;
    for c in s.chars() {
        if c.is_ascii_alphanumeric() {
            out.push(c);
            dash = false;
        } else if !dash {
            out.push('-');
            dash = true;
        }
    }
    out.trim_matches('-').to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TunedConfig {
        TunedConfig {
            spec: "resnet20/mixed/seed42".into(),
            fingerprint: machine_fingerprint(),
            threads: 4,
            trials: 3,
            tile_speedup: 3.4567,
            layers: vec![
                LayerTune {
                    layer: "b1.c0.conv0".into(),
                    width: Some(PlaneWidth::W64),
                    factors: SplitFactors { tile: 2, band: 1 },
                    tuned_us: 123.4,
                    heuristic_us: 150.0,
                },
                LayerTune::heuristic("head.fc", None),
            ],
        }
    }

    #[test]
    fn tsv_round_trips_exactly() {
        let cfg = sample();
        let text = cfg.to_tsv();
        let back = TunedConfig::from_tsv(&text).unwrap();
        assert_eq!(back, cfg);
        // string-level idempotence is what the CLI round-trip asserts
        assert_eq!(back.to_tsv(), text);
    }

    #[test]
    fn parse_rejects_malformed_content() {
        assert!(TunedConfig::from_tsv("").is_err());
        // wrong version is a loud error (the fingerprint also embeds
        // the version, so this only occurs on hand-edited files)
        let doctored = sample().to_tsv().replace(
            &format!("#version\t{TUNE_FORMAT_VERSION}"),
            "#version\t999",
        );
        let err = TunedConfig::from_tsv(&doctored).unwrap_err().to_string();
        assert!(err.contains("version 999"), "{err}");
        // a bad width is an error, not a fallback pick
        let doctored = sample().to_tsv().replace("\t64\t", "\t48\t");
        assert!(TunedConfig::from_tsv(&doctored).is_err());
    }

    #[test]
    fn cutover_is_the_rounded_clamped_speedup() {
        let mut cfg = sample();
        for (speedup, want) in [
            (0.0, HYBRID_TILE_SPEEDUP_CAP), // unmeasured sentinel
            (0.4, 1),                       // never below 1
            (3.4, 3),
            (3.6, 4),
            (1e9, MAX_HYBRID_CUTOVER),
        ] {
            cfg.tile_speedup = speedup;
            assert_eq!(cfg.hybrid_cutover(), want, "speedup {speedup}");
        }
    }

    #[test]
    fn layer_speedup_and_prediction() {
        let cfg = sample();
        let t = cfg.layer("b1.c0.conv0").unwrap();
        assert!((t.speedup() - 150.0 / 123.4).abs() < 1e-9);
        // unmeasured layers contribute neutrally
        assert_eq!(cfg.layer("head.fc").unwrap().speedup(), 1.0);
        assert!((cfg.predicted_speedup() - 150.0 / 123.4).abs() < 1e-9);
        assert!(cfg.layer("nope").is_none());
    }

    #[test]
    fn bytes_track_serialized_size() {
        let cfg = sample();
        assert_eq!(cfg.bytes(), cfg.to_tsv().len());
        assert!(cfg.bytes() > 100);
    }

    #[test]
    fn slugged_paths_are_filename_safe() {
        let p = TunedConfig::path_in(
            Path::new("/tmp/x"),
            "resnet20/mixed/seed42",
            "v1-linux-x86_64-8c",
        );
        let name = p.file_name().unwrap().to_str().unwrap();
        assert_eq!(
            name,
            "TUNE_resnet20-mixed-seed42__v1-linux-x86-64-8c.tsv"
        );
        assert!(name.chars().all(|c| c.is_ascii_alphanumeric()
            || matches!(c, '-' | '_' | '.')));
    }

    #[test]
    fn save_and_load_honour_the_keys() {
        let dir = std::env::temp_dir().join(format!(
            "marsellus-tune-unit-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = sample();
        cfg.save(&dir).unwrap();
        let fp = cfg.fingerprint.clone();
        // exact keys round-trip
        let got = TunedConfig::load(&dir, &cfg.spec, &fp).unwrap().unwrap();
        assert_eq!(got, cfg);
        // other spec / other machine: absent, not someone else's config
        assert!(TunedConfig::load(&dir, "kws/mixed/seed7", &fp)
            .unwrap()
            .is_none());
        assert!(TunedConfig::load(&dir, &cfg.spec, "v1-other-arch-2c")
            .unwrap()
            .is_none());
        // stale fingerprint *content* (file kept, machine changed — e.g.
        // a renamed file or copied cache dir) invalidates the config
        let path = TunedConfig::path_in(&dir, &cfg.spec, &fp);
        let doctored = std::fs::read_to_string(&path)
            .unwrap()
            .replace(&fp, "v1-elsewhere-riscv64-3c");
        std::fs::write(&path, doctored).unwrap();
        assert!(TunedConfig::load(&dir, &cfg.spec, &fp)
            .unwrap()
            .is_none());
        // unmeasured configs refuse to persist
        let mut heuristic = sample();
        heuristic.trials = 0;
        assert!(heuristic.save(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn env_truthiness() {
        for v in ["1", "true", "ON", " yes "] {
            assert!(env_truthy(v), "{v:?}");
        }
        for v in ["", "0", "false", "off", "no", "2", "enable"] {
            assert!(!env_truthy(v), "{v:?}");
        }
    }

    #[test]
    fn fingerprint_shape() {
        let fp = machine_fingerprint();
        assert!(fp.starts_with(&format!("v{TUNE_FORMAT_VERSION}-")));
        assert!(fp.ends_with('c'));
    }
}
