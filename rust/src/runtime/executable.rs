//! A compiled artifact handle, backend-agnostic.

use anyhow::Result;

use super::backend::LayerExec;
use super::tensor::TensorArg;

/// One compiled artifact. Thread-safe: the inner [`LayerExec`] is
/// immutable after compilation and `execute_i32` takes `&self`, so the
/// runtime shares executables across threads via `Arc<Executable>`.
pub struct Executable {
    name: String,
    inner: Box<dyn LayerExec>,
}

impl Executable {
    pub(crate) fn new(name: String, inner: Box<dyn LayerExec>) -> Self {
        Self { name, inner }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with s32 tensor arguments; returns the flattened s32
    /// outputs of the result tuple.
    pub fn execute_i32(&self, args: &[TensorArg]) -> Result<Vec<Vec<i32>>> {
        self.inner.execute_i32(args)
    }
}
