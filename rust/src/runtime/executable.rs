//! A compiled PJRT executable wrapping one HLO-text artifact.

use std::path::Path;

use anyhow::Result;

/// A host tensor argument for executable invocation: flat i32 data + dims.
///
/// All Marsellus artifacts use s32 tensors (quantized integer activations,
/// weights, normquant parameters), so a single concrete type keeps the FFI
/// surface small. Row-major (C) layout, matching jax defaults.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorArg {
    pub data: Vec<i32>,
    pub dims: Vec<usize>,
}

impl TensorArg {
    pub fn new(data: Vec<i32>, dims: Vec<usize>) -> Self {
        debug_assert_eq!(data.len(), dims.iter().product::<usize>());
        Self { data, dims }
    }

    pub fn scalar_vec(data: Vec<i32>) -> Self {
        let dims = vec![data.len()];
        Self { data, dims }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// One compiled artifact. Thread-safe: PJRT executables are immutable after
/// compilation and `execute` takes `&self`.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

// The xla crate wraps C++ objects behind raw pointers without Send/Sync
// markers; PJRT CPU client objects are documented thread-safe for execute().
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Parse HLO text, re-assign instruction ids (done by the text parser —
    /// this is why text, not proto, is the interchange format), and compile
    /// for the given client.
    pub fn from_hlo_text(client: &xla::PjRtClient, path: &Path) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| anyhow::anyhow!("parse hlo text {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e}", path.display()))?;
        Ok(Self {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with s32 tensor arguments; returns the flattened s32 outputs
    /// of the result tuple (artifacts are lowered with `return_tuple=True`).
    pub fn execute_i32(&self, args: &[TensorArg]) -> Result<Vec<Vec<i32>>> {
        let mut literals = Vec::with_capacity(args.len());
        for a in args {
            let dims: Vec<i64> = a.dims.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&a.data)
                .reshape(&dims)
                .map_err(|e| anyhow::anyhow!("reshape arg to {dims:?}: {e}"))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {}: {e}", self.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e}"))?;
        let tuple = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("decompose result tuple: {e}"))?;
        let mut outs = Vec::with_capacity(tuple.len());
        for lit in tuple {
            outs.push(
                lit.to_vec::<i32>()
                    .map_err(|e| anyhow::anyhow!("result to_vec<i32>: {e}"))?,
            );
        }
        Ok(outs)
    }
}
