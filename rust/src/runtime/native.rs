//! Native pure-Rust execution backend (cargo feature `native`, default).
//!
//! Executes every artifact of the built-in layer zoo
//! ([`Manifest::builtin`], exactly the set `python/compile/aot.py`
//! lowers) without touching disk or FFI: conv/linear layers dispatch to
//! the bit-exact RBE functional models in [`crate::rbe::functional`], and
//! the elementwise add/avgpool kernels mirror
//! `python/compile/kernels/ref.py` line for line. Because both sides
//! implement the same Eq. 1–2 integer arithmetic (property-tested
//! equivalent, and cross-checked against the PJRT artifacts in
//! integration tests), native results are bit-identical to artifact
//! results by construction.
//!
//! Unlike XLA, the native path *validates* its inputs: wrong arg counts,
//! wrong shapes, or out-of-range quantized values are loud errors rather
//! than silent wraparound. In particular, a *negative* activation value
//! (a signed mid-network activation that escaped the deploy-time
//! `dnn::validate_signed_dataflow` guard) is rejected by name at the
//! kernel boundary — the unsigned bit-plane packers must never see
//! two's-complement bits.

use std::collections::HashMap;

use anyhow::{bail, ensure, Result};

use crate::dnn::{LayerOp, Manifest, ManifestEntry};
use crate::rbe::functional::{
    add_requant, avgpool, conv_bitserial, conv_reference, trim_input,
    NormQuant,
};
use crate::rbe::RbeJob;

use super::backend::{BackendKind, ExecBackend, LayerExec};
use super::plan::NativeNumerics;
use super::tensor::TensorArg;

/// The native execution engine: an artifact-name → layer-signature zoo.
pub struct NativeBackend {
    zoo: HashMap<String, ManifestEntry>,
    numerics: NativeNumerics,
}

impl NativeBackend {
    /// Backend over the built-in layer zoo with [`NativeNumerics::Auto`].
    pub fn new() -> Self {
        Self::from_manifest(&Manifest::builtin())
    }

    /// Backend over an explicit manifest (e.g. the built-in zoo extended
    /// by an on-disk `manifest.tsv`).
    pub fn from_manifest(manifest: &Manifest) -> Self {
        let zoo = manifest
            .entries()
            .map(|e| (e.name.clone(), e.clone()))
            .collect();
        Self { zoo, numerics: NativeNumerics::Auto }
    }

    /// Override the conv/linear numerics implementation.
    pub fn with_numerics(mut self, numerics: NativeNumerics) -> Self {
        self.numerics = numerics;
        self
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl ExecBackend for NativeBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Native
    }

    fn platform(&self) -> String {
        "native".to_string()
    }

    fn has_artifact(&self, name: &str) -> bool {
        self.zoo.contains_key(name)
    }

    fn list_artifacts(&self) -> Vec<String> {
        let mut names: Vec<String> = self.zoo.keys().cloned().collect();
        names.sort();
        names
    }

    fn compile(&self, name: &str) -> Result<Box<dyn LayerExec>> {
        let Some(e) = self.zoo.get(name) else {
            bail!(
                "unknown artifact {name:?}: not in the native layer zoo \
                 (built-in networks + manifest.tsv)"
            );
        };
        Ok(Box::new(NativeExec { e: e.clone(), numerics: self.numerics }))
    }

    fn plan_numerics(&self) -> NativeNumerics {
        self.numerics
    }
}

/// One "compiled" layer: for the native backend, compilation is just
/// binding the layer signature; execution interprets it.
struct NativeExec {
    e: ManifestEntry,
    numerics: NativeNumerics,
}

fn expect_dims(arg: &TensorArg, want: &[usize], what: &str, name: &str) -> Result<()> {
    ensure!(
        arg.dims == want,
        "{name}: {what} has dims {:?}, artifact expects {:?}",
        arg.dims,
        want
    );
    ensure!(
        arg.data.len() == want.iter().product::<usize>(),
        "{name}: {what} data length {} does not match dims {:?}",
        arg.data.len(),
        want
    );
    Ok(())
}

impl NativeExec {
    fn run_conv(&self, job: &RbeJob, x: &[i32], w: &[i32], nq: &NormQuant) -> Result<Vec<i32>> {
        if self.numerics.bit_serial_for(job) {
            conv_bitserial(job, x, w, nq)
        } else {
            conv_reference(job, x, w, nq)
        }
    }

    /// conv3x3 / conv1x1: args = [x, w, scale, bias], mirroring the
    /// artifact calling convention (`model.layer_fn` arg shapes).
    fn conv(&self, args: &[TensorArg]) -> Result<Vec<i32>> {
        let e = &self.e;
        ensure!(args.len() == 4, "{}: conv takes 4 args, got {}", e.name, args.len());
        // conv3x3 artifacts take the zero-padded plane (pad = 1/side).
        let full = e.full_side();
        expect_dims(&args[0], &[full, full, e.cin], "activation", &e.name)?;
        let w_dims: Vec<usize> = if e.op == LayerOp::Conv3x3 {
            vec![e.cout, e.cin, 3, 3]
        } else {
            vec![e.cout, e.cin]
        };
        expect_dims(&args[1], &w_dims, "weights", &e.name)?;
        expect_dims(&args[2], &[e.cout], "scale", &e.name)?;
        expect_dims(&args[3], &[e.cout], "bias", &e.name)?;

        // Output extent matches the artifact exactly: valid conv over the
        // padded plane (3x3), strided gather of the full plane (1x1).
        let job = e.rbe_job()?;
        // The datapath model wants exactly the strided extent.
        let x = trim_input(&args[0].data, full, job.h_in(), e.cin);
        let nq = NormQuant::new(
            args[2].data.clone(),
            args[3].data.clone(),
            e.shift,
        );
        self.run_conv(&job, &x, &args[1].data, &nq)
    }

    /// linear / linears: args = [x (Kin,), w (Kout, Kin), scale, bias].
    /// Identical arithmetic to a 1×1 conv over a single pixel; the
    /// signed-head variant swaps the ReLU clip for the two's-complement
    /// one.
    fn linear(&self, args: &[TensorArg]) -> Result<Vec<i32>> {
        let e = &self.e;
        ensure!(args.len() == 4, "{}: linear takes 4 args, got {}", e.name, args.len());
        expect_dims(&args[0], &[e.cin], "activation", &e.name)?;
        expect_dims(&args[1], &[e.cout, e.cin], "weights", &e.name)?;
        expect_dims(&args[2], &[e.cout], "scale", &e.name)?;
        expect_dims(&args[3], &[e.cout], "bias", &e.name)?;
        let job = e.rbe_job()?;
        let nq = NormQuant {
            scale: args[2].data.clone(),
            bias: args[3].data.clone(),
            shift: e.shift,
            signed: e.op.signed_output(),
        };
        self.run_conv(&job, &args[0].data, &args[1].data, &nq)
    }

    /// add: args = [a, b], both (H, W, K); mirrors `ref.add_requant_ref`
    /// with scale_a = scale_b = 1.
    fn add(&self, args: &[TensorArg]) -> Result<Vec<i32>> {
        let e = &self.e;
        ensure!(args.len() == 2, "{}: add takes 2 args, got {}", e.name, args.len());
        let dims = [e.h, e.h, e.cin];
        expect_dims(&args[0], &dims, "lhs", &e.name)?;
        expect_dims(&args[1], &dims, "rhs", &e.name)?;
        add_requant(&args[0].data, &args[1].data, e.shift, e.o_bits)
    }

    /// avgpool: args = [x (H, W, K)]; per-channel sum over the spatial
    /// plane, then arithmetic right shift — mirrors `ref.avgpool_ref`.
    fn avgpool(&self, args: &[TensorArg]) -> Result<Vec<i32>> {
        let e = &self.e;
        ensure!(args.len() == 1, "{}: avgpool takes 1 arg, got {}", e.name, args.len());
        expect_dims(&args[0], &[e.h, e.h, e.cin], "activation", &e.name)?;
        avgpool(&args[0].data, e.h * e.h, e.cin, e.shift)
    }
}

impl LayerExec for NativeExec {
    fn name(&self) -> &str {
        &self.e.name
    }

    fn execute_i32(&self, args: &[TensorArg]) -> Result<Vec<Vec<i32>>> {
        let out = match self.e.op {
            LayerOp::Conv3x3 | LayerOp::Conv1x1 => self.conv(args)?,
            LayerOp::Linear | LayerOp::LinearSigned => self.linear(args)?,
            LayerOp::Add => self.add(args)?,
            LayerOp::AvgPool => self.avgpool(args)?,
        };
        Ok(vec![out])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn backend() -> NativeBackend {
        NativeBackend::new()
    }

    #[test]
    fn zoo_covers_every_registry_network() {
        let b = backend();
        assert!(b.list_artifacts().len() >= 20);
        assert!(b.has_artifact("avgpool_h8_k64"));
        assert!(b.has_artifact("linear_ci64_co10_w8i8o8"));
        // ResNet-18 (folded stem) and the signed KWS head are servable
        assert!(b.has_artifact("conv3x3_h224_ci17_co64_s2_w4i4o4"));
        assert!(b.has_artifact("linear_ci512_co1000_w4i4o8"));
        assert!(b.has_artifact("linears_ci16_co12_w8i8o8"));
        assert!(b.has_artifact("avgpool_h8_k16"));
        assert!(!b.has_artifact("no_such_artifact"));
    }

    /// The signed-head artifact keeps negative logits: zero input +
    /// negative bias must floor-shift and clamp on the signed range, not
    /// ReLU to 0.
    #[test]
    fn signed_head_dispatch_keeps_negative_logits() {
        let name = "linears_ci16_co12_w8i8o8";
        let exe = backend().compile(name).unwrap();
        let shift = Manifest::builtin().get(name).unwrap().shift;
        let args = vec![
            TensorArg::new(vec![0i32; 16], vec![16]),
            TensorArg::new(vec![0i32; 12 * 16], vec![12, 16]),
            TensorArg::scalar_vec(vec![1i32; 12]),
            TensorArg::scalar_vec(vec![-(1 << 20); 12]),
        ];
        let out = exe.execute_i32(&args).unwrap();
        let want = ((-(1i64 << 20)) >> shift).clamp(-128, 127) as i32;
        assert!(want < 0, "test premise: shift {shift} too large");
        assert_eq!(out[0], vec![want; 12]);
    }

    /// Regression (ISSUE 4 satellite): a negative activation value
    /// surfaces the named signed-activation error through backend
    /// dispatch — defense in depth under the deploy-time dataflow guard.
    #[test]
    fn negative_activations_error_loudly_through_dispatch() {
        let exe = backend().compile("linear_ci64_co10_w8i8o8").unwrap();
        let mut x = vec![0i32; 64];
        x[3] = -5;
        let args = vec![
            TensorArg::new(x, vec![64]),
            TensorArg::new(vec![0i32; 10 * 64], vec![10, 64]),
            TensorArg::scalar_vec(vec![1i32; 10]),
            TensorArg::scalar_vec(vec![0i32; 10]),
        ];
        let err = exe.execute_i32(&args).unwrap_err().to_string();
        assert!(
            err.contains("negative") && err.contains("signed"),
            "unhelpful error: {err:?}"
        );
    }

    #[test]
    fn avgpool_matches_ref_semantics() {
        let exe = backend().compile("avgpool_h8_k64").unwrap();
        // all-ones plane: per-channel sum = 64, >> 6 = 1
        let out = exe
            .execute_i32(&[TensorArg::new(vec![1; 8 * 8 * 64], vec![8, 8, 64])])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0], vec![1i32; 64]);
    }

    #[test]
    fn add_clamps_to_output_range() {
        // mixed config: add_h8_k64_o4_sh1 -> (a + b) >> 1, clipped to 4b
        let exe = backend().compile("add_h8_k64_o4_sh1").unwrap();
        let n = 8 * 8 * 64;
        let a = TensorArg::new(vec![15; n], vec![8, 8, 64]);
        let b = TensorArg::new(vec![15; n], vec![8, 8, 64]);
        let out = exe.execute_i32(&[a, b]).unwrap();
        assert!(out[0].iter().all(|&v| v == 15)); // (15+15)>>1 = 15 = omax
    }

    #[test]
    fn wrong_dims_rejected() {
        let exe = backend().compile("avgpool_h8_k64").unwrap();
        let bad = exe.execute_i32(&[TensorArg::new(vec![0; 10], vec![10])]);
        assert!(bad.is_err());
    }

    #[test]
    fn numerics_choices_agree_on_quickstart() {
        let name = "conv3x3_h16_ci32_co32_s1_w4i4o4";
        let bs = backend()
            .with_numerics(NativeNumerics::BitSerial)
            .compile(name)
            .unwrap();
        let rf = backend()
            .with_numerics(NativeNumerics::Reference)
            .compile(name)
            .unwrap();
        let mut rng = Rng::new(11);
        let hp = 18;
        let args = vec![
            TensorArg::new(
                (0..hp * hp * 32).map(|_| rng.range_i32(0, 16)).collect(),
                vec![hp, hp, 32],
            ),
            TensorArg::new(
                (0..32 * 32 * 9).map(|_| rng.range_i32(-8, 8)).collect(),
                vec![32, 32, 3, 3],
            ),
            TensorArg::scalar_vec((0..32).map(|_| rng.range_i32(1, 16)).collect()),
            TensorArg::scalar_vec((0..32).map(|_| rng.range_i32(-500, 500)).collect()),
        ];
        assert_eq!(
            bs.execute_i32(&args).unwrap(),
            rf.execute_i32(&args).unwrap()
        );
    }
}
