//! SOC domain (paper Fig. 1): the advanced microcontroller hosting the
//! RV32IMCFXpulp controller core, the L2 memory and the I/O DMA towards
//! external (L3) memory.
//!
//! In the simulator the SOC contributes three things:
//! * the single-core Xpulp baseline that Fig. 14 speedups are measured
//!   against (`crate::cluster::ClusterConfig::soc_controller`);
//! * L2 storage (lives in [`crate::cluster::Tcdm`], shared address space);
//! * the analytical L3 (HyperRAM) transfer model
//!   ([`crate::cluster::IoDma`]) used by the DORY tiler for the
//!   off-chip rows of Figs. 17–18.

mod clocks;

pub use clocks::{ClockDomains, ClockTree};
