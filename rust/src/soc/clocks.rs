//! FLL clock domains (paper §II: three FLLs — SOC core/memories, SOC
//! peripherals, CLUSTER). Used to convert between domain cycle counts and
//! wall-clock time when rolling up end-to-end latency.

/// The three generated clocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ClockDomains {
    SocCore,
    SocPeriph,
    Cluster,
}

/// Frequencies of the three FLL outputs, MHz.
#[derive(Debug, Clone)]
pub struct ClockTree {
    pub soc_core_mhz: f64,
    pub soc_periph_mhz: f64,
    pub cluster_mhz: f64,
}

impl ClockTree {
    /// Both domains at the cluster's operating frequency (the common
    /// measurement configuration in the paper).
    pub fn uniform(mhz: f64) -> Self {
        Self { soc_core_mhz: mhz, soc_periph_mhz: mhz, cluster_mhz: mhz }
    }

    pub fn freq_mhz(&self, d: ClockDomains) -> f64 {
        match d {
            ClockDomains::SocCore => self.soc_core_mhz,
            ClockDomains::SocPeriph => self.soc_periph_mhz,
            ClockDomains::Cluster => self.cluster_mhz,
        }
    }

    /// Convert a cycle count in a domain to microseconds.
    pub fn cycles_to_us(&self, d: ClockDomains, cycles: u64) -> f64 {
        cycles as f64 / self.freq_mhz(d)
    }

    /// Convert microseconds to (rounded-up) cycles of a domain.
    pub fn us_to_cycles(&self, d: ClockDomains, us: f64) -> u64 {
        (us * self.freq_mhz(d)).ceil() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycle_time_roundtrip() {
        let t = ClockTree::uniform(420.0);
        let us = t.cycles_to_us(ClockDomains::Cluster, 420_000);
        assert!((us - 1000.0).abs() < 1e-9);
        assert_eq!(t.us_to_cycles(ClockDomains::Cluster, 1000.0), 420_000);
    }

    #[test]
    fn dual_clock_conversion() {
        let t = ClockTree {
            soc_core_mhz: 200.0,
            soc_periph_mhz: 100.0,
            cluster_mhz: 400.0,
        };
        // same wall-clock, different cycle counts
        let us = 10.0;
        assert_eq!(t.us_to_cycles(ClockDomains::SocCore, us), 2000);
        assert_eq!(t.us_to_cycles(ClockDomains::Cluster, us), 4000);
    }
}
