//! TCDM placement helpers: a bump allocator over the 128 KiB L1 plus
//! pack/unpack between host `i32` tensors and the packed SIMD words the
//! kernels consume.

use anyhow::{bail, Result};

use crate::cluster::{Tcdm, TCDM_BASE, TCDM_SIZE};
use crate::isa::{simd, Prec};

/// Word-granular bump allocator over TCDM addresses.
#[derive(Debug, Clone)]
pub struct TcdmAlloc {
    next_word: usize,
}

impl Default for TcdmAlloc {
    fn default() -> Self {
        Self::new()
    }
}

impl TcdmAlloc {
    pub fn new() -> Self {
        Self { next_word: 0 }
    }

    /// Allocate `words` words; returns the byte address.
    pub fn alloc(&mut self, words: usize) -> Result<u32> {
        let addr = TCDM_BASE + (self.next_word * 4) as u32;
        self.next_word += words;
        if self.next_word * 4 > TCDM_SIZE as usize {
            bail!(
                "TCDM overflow: {} KiB requested",
                self.next_word * 4 / 1024
            );
        }
        Ok(addr)
    }

    /// Bytes currently allocated.
    pub fn used_bytes(&self) -> usize {
        self.next_word * 4
    }
}

/// Word offset of a TCDM byte address.
pub fn word_of(addr: u32) -> usize {
    ((addr - TCDM_BASE) / 4) as usize
}

/// Pack signed lane values at `prec` into TCDM at `addr`.
pub fn write_packed(mem: &mut Tcdm, addr: u32, values: &[i32], prec: Prec) {
    let words = simd::pack(values, prec);
    mem.write_l1(word_of(addr), &words);
}

/// Write raw i32 words (e.g. accumulators / fp bits).
pub fn write_words(mem: &mut Tcdm, addr: u32, values: &[u32]) {
    mem.write_l1(word_of(addr), values);
}

/// Read `n` i32 values starting at `addr`.
pub fn read_i32(mem: &Tcdm, addr: u32, n: usize) -> Vec<i32> {
    mem.read_l1(word_of(addr), n).iter().map(|&w| w as i32).collect()
}

/// Read `n` f32 values starting at `addr`.
pub fn read_f32(mem: &Tcdm, addr: u32, n: usize) -> Vec<f32> {
    mem.read_l1(word_of(addr), n)
        .iter()
        .map(|&w| f32::from_bits(w))
        .collect()
}

/// Write f32 values.
pub fn write_f32(mem: &mut Tcdm, addr: u32, values: &[f32]) {
    let words: Vec<u32> = values.iter().map(|v| v.to_bits()).collect();
    mem.write_l1(word_of(addr), &words);
}

/// Words needed for `n` lanes at `prec`.
pub fn packed_words(n: usize, prec: Prec) -> usize {
    n.div_ceil(prec.lanes() as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_is_word_aligned_and_bounded() {
        let mut a = TcdmAlloc::new();
        let p1 = a.alloc(10).unwrap();
        let p2 = a.alloc(1).unwrap();
        assert_eq!(p1, TCDM_BASE);
        assert_eq!(p2, TCDM_BASE + 40);
        assert!(a.alloc(40_000).is_err()); // > 128 KiB total
    }

    #[test]
    fn pack_roundtrip_via_mem() {
        let mut mem = Tcdm::new();
        let vals = vec![1, -2, 3, -4, 5, -6, 7, -8];
        write_packed(&mut mem, TCDM_BASE, &vals, Prec::B4);
        let w = mem.read_l1(0, 1)[0];
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(simd::lane_s(w, Prec::B4, i as u32), v);
        }
    }

    #[test]
    fn f32_roundtrip() {
        let mut mem = Tcdm::new();
        write_f32(&mut mem, TCDM_BASE + 8, &[1.5, -2.25]);
        assert_eq!(read_f32(&mem, TCDM_BASE + 8, 2), vec![1.5, -2.25]);
    }
}
