//! RBE offload driver kernel: the instruction sequence a RISC-V core
//! executes to configure, commit and synchronize with an RBE job through
//! the memory-mapped peripheral (paper §II-B4 / Fig. 4 timeline).

use anyhow::Result;

use crate::cluster::periph::{regs, RBE_PERIPH_BASE};
use crate::isa::{AluOp, Cond, Instr, IsaLevel, Program, ProgramBuilder};
use crate::rbe::{RbeJob, RbeMode};

/// Build a driver program: core 0 programs the job registers, commits
/// `jobs` back-to-back jobs (waiting for a free context when needed) and
/// spins on STATUS_BUSY until all complete. Other cores go straight to
/// halt (they would be running their own work on the chip).
pub fn rbe_offload_program(job: &RbeJob, jobs: u32) -> Result<Program> {
    job.validate()?;
    let mut b = ProgramBuilder::new("rbe_offload", IsaLevel::Xpulp);
    let done = b.label();
    // only core 0 drives the peripheral
    b.emit(Instr::CoreId { rd: 5 });
    b.branch(Cond::Ne, 5, 0, done);

    let base = RBE_PERIPH_BASE as i32;
    let fields: [(u32, u32); 9] = [
        (regs::MODE, matches!(job.mode, RbeMode::Conv1x1) as u32),
        (regs::H_OUT, job.h_out as u32),
        (regs::W_OUT, job.w_out as u32),
        (regs::K_IN, job.k_in as u32),
        (regs::K_OUT, job.k_out as u32),
        (regs::STRIDE, job.stride as u32),
        (regs::W_BITS, job.w_bits as u32),
        (regs::I_BITS, job.i_bits as u32),
        (regs::O_BITS, job.o_bits as u32),
    ];
    b.emit(Instr::Li { rd: 6, imm: base });
    for (off, val) in fields {
        b.emit(Instr::Li { rd: 7, imm: val as i32 });
        b.emit(Instr::Sw { rs: 7, base: 6, offset: off as i32 * 4, post_inc: 0 });
    }
    // commit loop: wait for a free context, then commit
    b.emit(Instr::Li { rd: 8, imm: jobs as i32 });
    let commit_top = b.label();
    let ctx_poll = b.label();
    b.bind(commit_top);
    b.bind(ctx_poll);
    b.emit(Instr::Lw {
        rd: 9,
        base: 6,
        offset: regs::COMMIT as i32 * 4,
        post_inc: 0,
    });
    b.branch(Cond::Eq, 9, 0, ctx_poll); // no free context yet
    b.emit(Instr::Li { rd: 7, imm: 1 });
    b.emit(Instr::Sw {
        rs: 7,
        base: 6,
        offset: regs::COMMIT as i32 * 4,
        post_inc: 0,
    });
    b.emit(Instr::AluImm { op: AluOp::Add, rd: 8, rs1: 8, imm: -1 });
    b.branch(Cond::Ne, 8, 0, commit_top);
    // wait for completion: spin on EVT_COUNT == jobs
    let wait = b.label();
    b.bind(wait);
    b.emit(Instr::Lw {
        rd: 9,
        base: 6,
        offset: regs::EVT_COUNT as i32 * 4,
        post_inc: 0,
    });
    b.emit(Instr::Li { rd: 10, imm: jobs as i32 });
    b.branch(Cond::Ltu, 9, 10, wait);
    b.bind(done);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ClusterConfig};
    use crate::rbe::RbeTiming;

    fn job() -> RbeJob {
        RbeJob::conv3x3(6, 6, 32, 32, 1, 4, 4, 4).unwrap()
    }

    /// The driven offload takes (RBE job latency + driver overhead), and
    /// the event counter reports completion.
    #[test]
    fn core_driven_offload_completes() {
        let j = job();
        let prog = rbe_offload_program(&j, 1).unwrap();
        let mut cl = Cluster::new(ClusterConfig::soc_controller());
        cl.load_spmd(prog);
        let stats = cl.run().unwrap();
        assert_eq!(cl.rbe.completed, 1);
        let engine = RbeTiming::cycles(&j);
        assert!(
            stats.cycles >= engine,
            "{} < engine {engine}",
            stats.cycles
        );
        assert!(
            stats.cycles < engine + 500,
            "driver overhead too large: {} vs {engine}",
            stats.cycles
        );
    }

    /// Two jobs use both register-file contexts; the second commit does
    /// not wait for the first job to finish (dual-context pipelining).
    #[test]
    fn dual_context_pipelines_two_jobs() {
        let j = job();
        let prog = rbe_offload_program(&j, 2).unwrap();
        let mut cl = Cluster::new(ClusterConfig::soc_controller());
        cl.load_spmd(prog);
        let stats = cl.run().unwrap();
        assert_eq!(cl.rbe.completed, 2);
        let engine = 2 * RbeTiming::cycles(&j);
        assert!(stats.cycles >= engine);
        assert!(stats.cycles < engine + 600);
    }

    /// While the RBE streams, the LIC loses bank slots: a memory-bound
    /// 16-core kernel slows down during accelerator activity.
    #[test]
    fn rbe_activity_steals_tcdm_bandwidth() {
        use crate::cluster::TCDM_BASE;
        // kernel: each core hammers loads; core 0 first offloads a job
        let j = RbeJob::conv3x3(9, 9, 64, 64, 1, 8, 8, 8).unwrap();
        let build = |with_rbe: bool| {
            let mut b =
                ProgramBuilder::new("bw_probe", IsaLevel::Xpulp);
            let skip = b.label();
            b.emit(Instr::CoreId { rd: 5 });
            b.branch(Cond::Ne, 5, 0, skip);
            if with_rbe {
                let base = RBE_PERIPH_BASE as i32;
                b.emit(Instr::Li { rd: 6, imm: base });
                for (off, val) in [
                    (regs::MODE, 0u32),
                    (regs::H_OUT, 9),
                    (regs::W_OUT, 9),
                    (regs::K_IN, 64),
                    (regs::K_OUT, 64),
                    (regs::STRIDE, 1),
                    (regs::W_BITS, 8),
                    (regs::I_BITS, 8),
                    (regs::O_BITS, 8),
                ] {
                    b.emit(Instr::Li { rd: 7, imm: val as i32 });
                    b.emit(Instr::Sw {
                        rs: 7,
                        base: 6,
                        offset: off as i32 * 4,
                        post_inc: 0,
                    });
                }
                b.emit(Instr::Li { rd: 7, imm: 1 });
                b.emit(Instr::Sw {
                    rs: 7,
                    base: 6,
                    offset: regs::COMMIT as i32 * 4,
                    post_inc: 0,
                });
            }
            b.bind(skip);
            // all cores: load loop over private words
            b.emit(Instr::CoreId { rd: 5 });
            b.emit(Instr::AluImm { op: AluOp::Sll, rd: 5, rs1: 5, imm: 2 });
            b.emit(Instr::AluImm {
                op: AluOp::Add,
                rd: 5,
                rs1: 5,
                imm: TCDM_BASE as i32,
            });
            b.emit(Instr::Li { rd: 8, imm: 2000 });
            let (ls, le) = (b.label(), b.label());
            b.hw_loop(0, 8, ls, le);
            b.bind(ls);
            b.emit(Instr::Lw { rd: 9, base: 5, offset: 0, post_inc: 0 });
            b.bind(le);
            b.build().unwrap()
        };
        let run = |with_rbe: bool| {
            let mut cl = Cluster::new(ClusterConfig::default());
            cl.load_spmd(build(with_rbe));
            cl.run().unwrap()
        };
        let quiet = run(false);
        let busy = run(true);
        assert!(
            busy.total.stall_conflict > quiet.total.stall_conflict + 1000,
            "RBE streaming must cost the cores bank slots: {} vs {}",
            busy.total.stall_conflict,
            quiet.total.stall_conflict
        );
        let _ = j;
    }
}
