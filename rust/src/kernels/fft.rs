//! Parallel radix-2 complex FP32 FFT on the cluster (paper §III-C1 /
//! Fig. 14: 2048-point window, 16 cores sharing 8 FPUs, peak 4.69
//! FLOp/cycle on silicon).
//!
//! Decimation-in-time with an explicit bit-reversal permutation pass
//! (reversal table precomputed by the host, as deployed DSP code does),
//! then log2(N) butterfly stages. The host launches one SPMD program per
//! stage; the inter-stage barrier is the program boundary (equivalent to
//! the event-unit barrier on chip). Butterflies of each stage are
//! block-partitioned across cores.

use std::f32::consts::PI;

use anyhow::{ensure, Result};

use crate::cluster::{Cluster, ClusterConfig, RunStats};
use crate::core::CoreStats;
use crate::isa::{AluOp, Cond, FOp, Instr, IsaLevel, Program, ProgramBuilder};
use crate::kernels::layout::{read_f32, write_f32, write_words, TcdmAlloc};

/// FFT problem: `n` complex points (power of two).
#[derive(Debug, Clone, Copy)]
pub struct FftProblem {
    pub n: usize,
    pub cores: usize,
}

impl FftProblem {
    pub fn stages(&self) -> usize {
        self.n.trailing_zeros() as usize
    }

    /// Real FLOPs of the whole transform (10 per butterfly).
    pub fn flops(&self) -> u64 {
        (self.n / 2 * self.stages() * 10) as u64
    }

    /// Up-front shape validation: every constraint is checked before any
    /// program emission, and each failure names the offending dimension
    /// and the divisor the kernel requires.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.cores > 0, "cores must be > 0");
        ensure!(
            self.n.is_power_of_two() && self.n >= 8,
            "N={} is unsupported: the radix-2 FFT needs a power of two \
             >= 8",
            self.n
        );
        ensure!(
            (self.n / 2) % self.cores == 0,
            "N/2 = {} butterflies must be a multiple of cores={} (each \
             stage block-partitions butterflies across the cluster)",
            self.n / 2,
            self.cores
        );
        ensure!(
            self.n % self.cores == 0,
            "N={} must be a multiple of cores={} (the bit-reversal pass \
             slices N indices across the cluster)",
            self.n,
            self.cores
        );
        Ok(())
    }

    /// Bit-reversal permutation program: each core swaps its slice of
    /// indices with their reversals (table-driven).
    fn bitrev_program(&self, x_addr: u32, rev_addr: u32) -> Result<Program> {
        let per_core = (self.n / self.cores) as i32;
        let mut b = ProgramBuilder::new("fft_bitrev", IsaLevel::Xpulp);
        // x5 = i (runs over my slice), x6 = end
        b.emit(Instr::CoreId { rd: 29 });
        b.emit(Instr::Li { rd: 30, imm: per_core });
        b.emit(Instr::Alu { op: AluOp::Mul, rd: 5, rs1: 29, rs2: 30 });
        b.emit(Instr::AluImm { op: AluOp::Add, rd: 6, rs1: 5, imm: per_core });
        let loop_top = b.label();
        let skip = b.label();
        b.bind(loop_top);
        // j = rev[i] (byte offset table: rev[i] = bitrev(i) * 8)
        b.emit(Instr::AluImm { op: AluOp::Sll, rd: 7, rs1: 5, imm: 2 });
        b.emit(Instr::Li { rd: 8, imm: rev_addr as i32 });
        b.emit(Instr::Alu { op: AluOp::Add, rd: 8, rs1: 8, rs2: 7 });
        b.emit(Instr::Lw { rd: 9, base: 8, offset: 0, post_inc: 0 }); // j*8
        // swap only when i*8 < j*8
        b.emit(Instr::AluImm { op: AluOp::Sll, rd: 10, rs1: 5, imm: 3 });
        b.branch(Cond::Geu, 10, 9, skip);
        b.emit(Instr::Li { rd: 11, imm: x_addr as i32 });
        b.emit(Instr::Alu { op: AluOp::Add, rd: 12, rs1: 11, rs2: 10 }); // &x[i]
        b.emit(Instr::Alu { op: AluOp::Add, rd: 13, rs1: 11, rs2: 9 }); // &x[j]
        for off in [0, 4] {
            b.emit(Instr::Lw { rd: 14, base: 12, offset: off, post_inc: 0 });
            b.emit(Instr::Lw { rd: 15, base: 13, offset: off, post_inc: 0 });
            b.emit(Instr::Sw { rs: 15, base: 12, offset: off, post_inc: 0 });
            b.emit(Instr::Sw { rs: 14, base: 13, offset: off, post_inc: 0 });
        }
        b.bind(skip);
        b.emit(Instr::AluImm { op: AluOp::Add, rd: 5, rs1: 5, imm: 1 });
        b.branch(Cond::Ltu, 5, 6, loop_top);
        b.build()
    }

    /// One butterfly stage. `s` = stage index (half = 2^s).
    fn stage_program(
        &self,
        s: usize,
        x_addr: u32,
        tw_addr: u32,
    ) -> Result<Program> {
        let half = 1i32 << s;
        let log2n = self.stages() as i32;
        let per_core = (self.n / 2 / self.cores) as i32;
        let mut b = ProgramBuilder::new("fft_stage", IsaLevel::Xpulp);
        // x5 = butterfly index j, distributed CYCLICALLY (j = id, id+P,
        // id+2P, ...) so concurrent cores touch different TCDM banks —
        // block distribution would start every core on bank 0.
        b.emit(Instr::CoreId { rd: 5 });
        b.emit(Instr::Li { rd: 26, imm: per_core });
        let (ls, le) = (b.label(), b.label());
        b.hw_loop(0, 26, ls, le);
        b.bind(ls);
        // group = j >> s; pos = j & (half-1)
        b.emit(Instr::AluImm { op: AluOp::Srl, rd: 6, rs1: 5, imm: s as i32 });
        b.emit(Instr::AluImm { op: AluOp::And, rd: 7, rs1: 5, imm: half - 1 });
        // i1 = (group << (s+1)) + pos ; addr1 = x + i1*8
        b.emit(Instr::AluImm {
            op: AluOp::Sll,
            rd: 8,
            rs1: 6,
            imm: s as i32 + 1,
        });
        b.emit(Instr::Alu { op: AluOp::Add, rd: 8, rs1: 8, rs2: 7 });
        b.emit(Instr::AluImm { op: AluOp::Sll, rd: 8, rs1: 8, imm: 3 });
        b.emit(Instr::AluImm {
            op: AluOp::Add,
            rd: 8,
            rs1: 8,
            imm: x_addr as i32,
        });
        // addr2 = addr1 + half*8
        b.emit(Instr::AluImm {
            op: AluOp::Add,
            rd: 9,
            rs1: 8,
            imm: half * 8,
        });
        // twiddle addr = tw + (pos << (log2n-1-s)) * 8
        b.emit(Instr::AluImm {
            op: AluOp::Sll,
            rd: 10,
            rs1: 7,
            imm: log2n - 1 - s as i32 + 3,
        });
        b.emit(Instr::AluImm {
            op: AluOp::Add,
            rd: 10,
            rs1: 10,
            imm: tw_addr as i32,
        });
        // loads
        b.emit(Instr::Flw { fd: 1, base: 8, offset: 0, post_inc: 0 }); // x1r
        b.emit(Instr::Flw { fd: 2, base: 8, offset: 4, post_inc: 0 }); // x1i
        b.emit(Instr::Flw { fd: 3, base: 9, offset: 0, post_inc: 0 }); // x2r
        b.emit(Instr::Flw { fd: 4, base: 9, offset: 4, post_inc: 0 }); // x2i
        b.emit(Instr::Flw { fd: 5, base: 10, offset: 0, post_inc: 0 }); // wr
        b.emit(Instr::Flw { fd: 6, base: 10, offset: 4, post_inc: 0 }); // wi
        // tr = x2r*wr - x2i*wi ; ti = x2r*wi + x2i*wr
        b.emit(Instr::FAlu { op: FOp::Mul, lanes: 1, fd: 7, fs1: 3, fs2: 5, fs3: 0 });
        b.emit(Instr::FAlu { op: FOp::Nmsub, lanes: 1, fd: 7, fs1: 4, fs2: 6, fs3: 7 });
        b.emit(Instr::FAlu { op: FOp::Mul, lanes: 1, fd: 8, fs1: 3, fs2: 6, fs3: 0 });
        b.emit(Instr::FAlu { op: FOp::Madd, lanes: 1, fd: 8, fs1: 4, fs2: 5, fs3: 8 });
        // x2 = x1 - t ; x1 = x1 + t
        b.emit(Instr::FAlu { op: FOp::Sub, lanes: 1, fd: 9, fs1: 1, fs2: 7, fs3: 0 });
        b.emit(Instr::FAlu { op: FOp::Sub, lanes: 1, fd: 10, fs1: 2, fs2: 8, fs3: 0 });
        b.emit(Instr::FAlu { op: FOp::Add, lanes: 1, fd: 1, fs1: 1, fs2: 7, fs3: 0 });
        b.emit(Instr::FAlu { op: FOp::Add, lanes: 1, fd: 2, fs1: 2, fs2: 8, fs3: 0 });
        b.emit(Instr::Fsw { fs: 1, base: 8, offset: 0, post_inc: 0 });
        b.emit(Instr::Fsw { fs: 2, base: 8, offset: 4, post_inc: 0 });
        b.emit(Instr::Fsw { fs: 9, base: 9, offset: 0, post_inc: 0 });
        b.emit(Instr::Fsw { fs: 10, base: 9, offset: 4, post_inc: 0 });
        b.emit(Instr::AluImm {
            op: AluOp::Add,
            rd: 5,
            rs1: 5,
            imm: self.cores as i32,
        });
        b.bind(le);
        b.build()
    }

    /// Run the full FFT on a fresh cluster; input is `n` (re, im) pairs.
    /// Returns the transformed data and accumulated run statistics.
    pub fn run_with(
        &self,
        cfg: ClusterConfig,
        input: &[(f32, f32)],
    ) -> Result<(Vec<(f32, f32)>, RunStats)> {
        self.validate()?;
        ensure!(
            input.len() == self.n,
            "input has {} complex points, expected N = {}",
            input.len(),
            self.n
        );
        ensure!(
            cfg.cores == self.cores,
            "cluster config has {} cores but the problem was built for {}",
            cfg.cores,
            self.cores
        );
        let mut alloc = TcdmAlloc::new();
        let x_addr = alloc.alloc(self.n * 2)?;
        let tw_addr = alloc.alloc(self.n)?; // n/2 complex
        let rev_addr = alloc.alloc(self.n)?;

        let mut cl = Cluster::new(cfg);
        let flat: Vec<f32> =
            input.iter().flat_map(|&(r, i)| [r, i]).collect();
        write_f32(&mut cl.mem, x_addr, &flat);
        let tw: Vec<f32> = (0..self.n / 2)
            .flat_map(|k| {
                let ang = -2.0 * PI * k as f32 / self.n as f32;
                [ang.cos(), ang.sin()]
            })
            .collect();
        write_f32(&mut cl.mem, tw_addr, &tw);
        let bits = self.stages();
        let rev: Vec<u32> = (0..self.n as u32)
            .map(|i| i.reverse_bits() >> (32 - bits) << 3) // byte offsets
            .collect();
        write_words(&mut cl.mem, rev_addr, &rev);

        // bit-reverse pass + one program per stage
        let mut total = RunStats::default();
        total.traffic_seed = cl.cfg.traffic_seed;
        let mut programs =
            vec![self.bitrev_program(x_addr, rev_addr)?];
        for s in 0..self.stages() {
            programs.push(self.stage_program(s, x_addr, tw_addr)?);
        }
        for prog in programs {
            cl.load_spmd(prog);
            let st = cl.run()?;
            total.cycles += st.cycles;
            let mut t = CoreStats::default();
            t.merge(&total.total);
            t.merge(&st.total);
            total.total = t;
            total.per_core = st.per_core;
        }
        let out_flat = read_f32(&cl.mem, x_addr, self.n * 2);
        let out = out_flat
            .chunks(2)
            .map(|c| (c[0], c[1]))
            .collect();
        Ok((out, total))
    }
}

/// Naive host DFT oracle (O(n²), f64 accumulation).
pub fn dft_reference(input: &[(f32, f32)]) -> Vec<(f32, f32)> {
    let n = input.len();
    (0..n)
        .map(|k| {
            let mut re = 0.0f64;
            let mut im = 0.0f64;
            for (j, &(r, i)) in input.iter().enumerate() {
                let ang = -2.0 * std::f64::consts::PI * (k * j) as f64
                    / n as f64;
                let (c, s) = (ang.cos(), ang.sin());
                re += r as f64 * c - i as f64 * s;
                im += r as f64 * s + i as f64 * c;
            }
            (re as f32, im as f32)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_signal(n: usize, seed: u64) -> Vec<(f32, f32)> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                (rng.f64() as f32 * 2.0 - 1.0, rng.f64() as f32 * 2.0 - 1.0)
            })
            .collect()
    }

    fn assert_close(a: &[(f32, f32)], b: &[(f32, f32)], tol: f32) {
        let scale = (a.len() as f32).sqrt();
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x.0 - y.0).abs() < tol * scale
                    && (x.1 - y.1).abs() < tol * scale,
                "bin {i}: {x:?} vs {y:?}"
            );
        }
    }

    /// Unsupported sizes fail up front, naming dimension and divisor.
    #[test]
    fn validate_names_offending_dimension() {
        let err = FftProblem { n: 96, cores: 16 }
            .validate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("N=96") && err.contains("power of two"), "{err}");
        let err = FftProblem { n: 16, cores: 16 }
            .validate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("N/2 = 8") && err.contains("cores=16"), "{err}");
        let sig = rand_signal(16, 1);
        assert!(FftProblem { n: 16, cores: 16 }
            .run_with(ClusterConfig::default(), &sig)
            .is_err());
        FftProblem { n: 256, cores: 16 }.validate().unwrap();
    }

    #[test]
    fn fft_matches_dft_256() {
        let sig = rand_signal(256, 3);
        let p = FftProblem { n: 256, cores: 16 };
        let (out, _) =
            p.run_with(ClusterConfig::default(), &sig).unwrap();
        assert_close(&out, &dft_reference(&sig), 2e-4);
    }

    #[test]
    fn fft_single_core_matches() {
        let sig = rand_signal(64, 4);
        let p = FftProblem { n: 64, cores: 1 };
        let (out, _) =
            p.run_with(ClusterConfig::soc_controller(), &sig).unwrap();
        assert_close(&out, &dft_reference(&sig), 1e-4);
    }

    /// Paper §III-C1: 2048-point FFT reaches ~4.69 FLOp/cycle on 16
    /// cores. Assert the measured throughput is in the right band.
    #[test]
    fn fft2048_throughput_band() {
        let sig = rand_signal(2048, 5);
        let p = FftProblem { n: 2048, cores: 16 };
        let (_, stats) =
            p.run_with(ClusterConfig::default(), &sig).unwrap();
        let fpc = p.flops() as f64 / stats.cycles as f64;
        assert!(
            (3.5..7.0).contains(&fpc),
            "FFT {fpc:.2} FLOp/cycle (paper: 4.69)"
        );
    }

    #[test]
    fn impulse_transforms_to_flat_spectrum() {
        let n = 128;
        let mut sig = vec![(0.0f32, 0.0f32); n];
        sig[0] = (1.0, 0.0);
        let p = FftProblem { n, cores: 16 };
        let (out, _) = p.run_with(ClusterConfig::default(), &sig).unwrap();
        for (r, i) in out {
            assert!((r - 1.0).abs() < 1e-5 && i.abs() < 1e-5);
        }
    }
}
