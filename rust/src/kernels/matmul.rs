//! Parallel integer matrix multiplication kernels (paper §II-A, Fig. 2c).
//!
//! `C[M,N] += A[M,K] · Bᵀ[N,K]` with A, B packed at 8/4/2-bit precision
//! and 32-bit accumulators, SPMD across the cluster cores (rows of C are
//! block-partitioned by core id). Four variants:
//!
//! * [`MatmulKernel::Xpulp8`] — the Fig. 15 "MMUL" baseline: 4×2 register
//!   blocking, explicit post-increment loads, `pv.sdotp.b`.
//! * [`MatmulKernel::Nn`] — XpulpNN nibble/crumb SIMD without MAC&LOAD:
//!   same 4×2 structure at B4/B2 (the "native sub-byte support" point).
//! * [`MatmulKernel::MacLoad`] — the Fig. 2c MAC&LOAD kernel: 4×4
//!   blocking, operands staged in the NN-RF, inner loop of **16
//!   `pv.mlsdotp` + 1 explicit load** (the paper's "16 accumulators at
//!   the cost of a single explicit load", ~94% DOTP utilization).
//! * [`MatmulKernel::UnpackBaseline`] — plain-Xpulp execution of 4/2-bit
//!   data by unpacking nibbles/crumbs to bytes in registers before
//!   `pv.sdotp.b` (the §III-C1 instruction-count comparison baseline).

use anyhow::{bail, ensure, Result};

use crate::cluster::{Cluster, ClusterConfig, RunStats};
use crate::isa::{
    AluOp, Cond, Instr, IsaLevel, Prec, Program, ProgramBuilder, Sign, VAluOp,
};
use crate::kernels::layout::{
    packed_words, read_i32, write_packed, TcdmAlloc,
};

/// Kernel variant selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MatmulKernel {
    Xpulp8,
    Nn { prec: Prec },
    MacLoad { prec: Prec },
    UnpackBaseline { prec: Prec },
}

impl MatmulKernel {
    pub fn prec(&self) -> Prec {
        match *self {
            MatmulKernel::Xpulp8 => Prec::B8,
            MatmulKernel::Nn { prec }
            | MatmulKernel::MacLoad { prec }
            | MatmulKernel::UnpackBaseline { prec } => prec,
        }
    }

    pub fn isa(&self) -> IsaLevel {
        match self {
            MatmulKernel::Xpulp8 | MatmulKernel::UnpackBaseline { .. } => {
                IsaLevel::Xpulp
            }
            _ => IsaLevel::XpulpNN,
        }
    }

    pub fn name(&self) -> String {
        match *self {
            MatmulKernel::Xpulp8 => "mmul-xpulp-8b".into(),
            MatmulKernel::Nn { prec } => format!("mmul-nn-{}b", prec.bits()),
            MatmulKernel::MacLoad { prec } => {
                format!("mmul-macload-{}b", prec.bits())
            }
            MatmulKernel::UnpackBaseline { prec } => {
                format!("mmul-unpack-{}b", prec.bits())
            }
        }
    }
}

/// Problem description.
#[derive(Debug, Clone, Copy)]
pub struct MatmulProblem {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub kernel: MatmulKernel,
    pub cores: usize,
}

/// Addresses of the placed operands.
#[derive(Debug, Clone)]
pub struct BuiltMatmul {
    pub prog: Program,
    pub a_addr: u32,
    pub b_addr: u32,
    pub c_addr: u32,
    pub problem: MatmulProblem,
}

// Register map (see module docs / builder code):
const P_A: [u8; 4] = [1, 2, 3, 4];
const P_B: [u8; 4] = [5, 6, 7, 8];
const R_PC: u8 = 9; // C pointer
const R_ACC0: u8 = 10; // accumulators x10..x25
const R_ROW: u8 = 26;
const R_COL: u8 = 27;
const R_KCNT: u8 = 28;
const R_T0: u8 = 29;
const R_T1: u8 = 30;
const R_ABASE: u8 = 31;
// unpack-baseline scratch (overlaps upper accums, which it does not use):
const R_AV: [u8; 4] = [18, 19, 20, 21]; // loaded A words
const R_BV: [u8; 2] = [22, 23]; // loaded B words
const R_MASKV: u8 = 24; // per-lane shift vector for pv.sra.b
const R_U0: u8 = 25; // unpack scratch

impl MatmulProblem {
    /// MAC count of the whole problem.
    pub fn macs(&self) -> u64 {
        (self.m * self.n * self.k) as u64
    }

    pub fn ops(&self) -> u64 {
        self.macs() * 2
    }

    fn rows_per_core(&self) -> usize {
        self.m / self.cores
    }

    fn col_block(&self) -> usize {
        match self.kernel {
            MatmulKernel::MacLoad { .. } => 4,
            _ => 2,
        }
    }

    /// Up-front shape validation: every constraint is checked before any
    /// program emission or TCDM allocation, and each failure names the
    /// offending dimension and the divisor the kernel requires.
    pub fn validate(&self) -> Result<()> {
        let lanes = self.kernel.prec().lanes() as usize;
        ensure!(
            self.cores > 0 && self.m > 0 && self.n > 0 && self.k > 0,
            "degenerate matmul shape M={} N={} K={} cores={}: every \
             dimension must be > 0",
            self.m,
            self.n,
            self.k,
            self.cores
        );
        ensure!(
            self.m % (4 * self.cores) == 0,
            "M={} must be a multiple of 4*cores = {} ({} rows are \
             block-partitioned across {} cores in 4-row register blocks)",
            self.m,
            4 * self.cores,
            self.m,
            self.cores
        );
        ensure!(
            self.n % self.col_block() == 0,
            "N={} must be a multiple of {} (the {} kernel computes \
             {}-column accumulator blocks)",
            self.n,
            self.col_block(),
            self.kernel.name(),
            self.col_block()
        );
        ensure!(
            self.k % lanes == 0,
            "K={} must be a multiple of {} ({}-bit operands pack {} \
             lanes per 32-bit word)",
            self.k,
            lanes,
            self.kernel.prec().bits(),
            lanes
        );
        ensure!(
            self.k / lanes >= 2,
            "K={} gives only {} packed word(s) per row; the software \
             pipeline prefetches one word ahead and needs K >= {}",
            self.k,
            self.k / lanes,
            2 * lanes
        );
        if let MatmulKernel::UnpackBaseline { prec } = self.kernel {
            ensure!(
                matches!(prec, Prec::B4 | Prec::B2),
                "unpack baseline models 4/2-bit data on 8-bit hardware \
                 (got {}-bit)",
                prec.bits()
            );
        }
        Ok(())
    }

    /// Build the SPMD program and allocate operand storage.
    pub fn build(&self, alloc: &mut TcdmAlloc) -> Result<BuiltMatmul> {
        self.validate()?;
        let prec = self.kernel.prec();
        let row_words = packed_words(self.k, prec);
        // +8 pad words: the software pipeline prefetches one word past the
        // last row (MAC&LOAD refresh / post-increment loads).
        let a_addr = alloc.alloc(self.m * row_words + 8)?;
        let b_addr = alloc.alloc(self.n * row_words + 8)?;
        let c_addr = alloc.alloc(self.m * self.n)?;
        let prog = match self.kernel {
            MatmulKernel::MacLoad { prec } => {
                self.build_macload(a_addr, b_addr, c_addr, prec)?
            }
            MatmulKernel::Xpulp8 => {
                self.build_dotp(a_addr, b_addr, c_addr, Prec::B8, false)?
            }
            MatmulKernel::Nn { prec } => {
                self.build_dotp(a_addr, b_addr, c_addr, prec, false)?
            }
            MatmulKernel::UnpackBaseline { prec } => {
                self.build_dotp(a_addr, b_addr, c_addr, prec, true)?
            }
        };
        Ok(BuiltMatmul { prog, a_addr, b_addr, c_addr, problem: *self })
    }

    /// Common prologue: compute this core's A-base (x31) and C pointer
    /// (x9), initialize loop counters.
    fn prologue(
        &self,
        b: &mut ProgramBuilder,
        a_addr: u32,
        c_addr: u32,
        row_bytes: i32,
    ) {
        let rpc = self.rows_per_core() as i32;
        b.emit(Instr::CoreId { rd: R_T0 });
        b.emit(Instr::Li { rd: R_T1, imm: rpc * row_bytes });
        b.emit(Instr::Alu { op: AluOp::Mul, rd: R_T1, rs1: R_T0, rs2: R_T1 });
        b.emit(Instr::Li { rd: R_ABASE, imm: a_addr as i32 });
        b.emit(Instr::Alu {
            op: AluOp::Add,
            rd: R_ABASE,
            rs1: R_ABASE,
            rs2: R_T1,
        });
        b.emit(Instr::Li { rd: R_T1, imm: rpc * self.n as i32 * 4 });
        b.emit(Instr::Alu { op: AluOp::Mul, rd: R_T1, rs1: R_T0, rs2: R_T1 });
        b.emit(Instr::Li { rd: R_PC, imm: c_addr as i32 });
        b.emit(Instr::Alu { op: AluOp::Add, rd: R_PC, rs1: R_PC, rs2: R_T1 });
        b.emit(Instr::Li { rd: R_ROW, imm: (self.rows_per_core() / 4) as i32 });
    }

    /// The MAC&LOAD kernel (Fig. 2c right): 4×4 blocking, NN-RF operand
    /// staging, 17-instruction inner loop.
    fn build_macload(
        &self,
        a_addr: u32,
        b_addr: u32,
        c_addr: u32,
        prec: Prec,
    ) -> Result<Program> {
        let lanes = prec.lanes() as usize;
        let row_bytes = (self.k / lanes * 4) as i32;
        let kwords = (self.k / lanes) as i32;
        let n = self.n as i32;
        let mut b = ProgramBuilder::new("matmul_macload", IsaLevel::XpulpNN);
        // acc(r, c) register: x10 + 4c + r
        let acc = |r: u8, c: u8| R_ACC0 + 4 * c + r;

        self.prologue(&mut b, a_addr, c_addr, row_bytes);
        b.emit(Instr::Li { rd: R_KCNT, imm: kwords });

        let row_loop = b.label();
        b.bind(row_loop);
        // p_b[i] = B + i*row_bytes
        b.emit(Instr::Li { rd: R_T0, imm: b_addr as i32 });
        for (i, &pb) in P_B.iter().enumerate() {
            b.emit(Instr::AluImm {
                op: AluOp::Add,
                rd: pb,
                rs1: R_T0,
                imm: i as i32 * row_bytes,
            });
        }
        b.emit(Instr::Li { rd: R_COL, imm: n / 4 });

        let col_loop = b.label();
        b.bind(col_loop);
        // p_a[r] = p_a_base + r*row_bytes
        for (r, &pa) in P_A.iter().enumerate() {
            b.emit(Instr::AluImm {
                op: AluOp::Add,
                rd: pa,
                rs1: R_ABASE,
                imm: r as i32 * row_bytes,
            });
        }
        // NN-RF warm-up: word 0 of the four A rows and of B col c0
        for (r, &pa) in P_A.iter().enumerate() {
            b.emit(Instr::NnLoad { nn_rd: r as u8, ptr: pa, post_inc: 4 });
        }
        b.emit(Instr::NnLoad { nn_rd: 4, ptr: P_B[0], post_inc: 4 });
        // zero the 16 accumulators
        for c in 0..4u8 {
            for r in 0..4u8 {
                b.emit(Instr::Li { rd: acc(r, c), imm: 0 });
            }
        }
        // ---- the 17-instruction inner loop (16 mlsdotp + 1 load) ----
        let (ls, le) = (b.label(), b.label());
        b.hw_loop(0, R_KCNT, ls, le);
        b.bind(ls);
        let ml = |bq: u8, // nn register holding the current B word
                  c: u8,
                  r: u8,
                  refresh: Option<(u8, u8)>| {
            Instr::MlSdotp {
                prec,
                sign: Sign::SS,
                rd: acc(r, c),
                na: r, // nn0..nn3 = A rows
                nb: bq,
                refresh,
            }
        };
        // col 0 from nn4; first slot prefetches B[c1] into nn5
        b.emit(ml(4, 0, 0, Some((5, P_B[1]))));
        b.emit(ml(4, 0, 1, None));
        b.emit(ml(4, 0, 2, None));
        b.emit(ml(4, 0, 3, None));
        // col 1 from nn5; prefetch B[c2] into nn4
        b.emit(ml(5, 1, 0, Some((4, P_B[2]))));
        b.emit(ml(5, 1, 1, None));
        b.emit(ml(5, 1, 2, None));
        b.emit(ml(5, 1, 3, None));
        // col 2 from nn4; prefetch B[c3] into nn5
        b.emit(ml(4, 2, 0, Some((5, P_B[3]))));
        b.emit(ml(4, 2, 1, None));
        b.emit(ml(4, 2, 2, None));
        b.emit(ml(4, 2, 3, None));
        // col 3 from nn5; refresh the four A rows for the next k step
        b.emit(ml(5, 3, 0, Some((0, P_A[0]))));
        b.emit(ml(5, 3, 1, Some((1, P_A[1]))));
        b.emit(ml(5, 3, 2, Some((2, P_A[2]))));
        b.emit(ml(5, 3, 3, Some((3, P_A[3]))));
        // the single explicit load: B[c0] of the next k step
        b.emit(Instr::NnLoad { nn_rd: 4, ptr: P_B[0], post_inc: 4 });
        b.bind(le); // loop body ends at the NnLoad above
        // ---- end inner loop ----
        // store the 4x4 accumulator block
        for r in 0..4u8 {
            for c in 0..4u8 {
                b.emit(Instr::Sw {
                    rs: acc(r, c),
                    base: R_PC,
                    offset: (r as i32 * n + c as i32) * 4,
                    post_inc: 0,
                });
            }
        }
        // advance B pointers to the next 4-column block. p_b0 advanced
        // row_bytes + 4 (warm-up load + per-iteration prefetch), the rest
        // exactly row_bytes.
        b.emit(Instr::AluImm {
            op: AluOp::Add,
            rd: P_B[0],
            rs1: P_B[0],
            imm: 3 * row_bytes - 4,
        });
        for &pb in &P_B[1..] {
            b.emit(Instr::AluImm {
                op: AluOp::Add,
                rd: pb,
                rs1: pb,
                imm: 3 * row_bytes,
            });
        }
        b.emit(Instr::AluImm { op: AluOp::Add, rd: R_PC, rs1: R_PC, imm: 16 });
        b.emit(Instr::AluImm { op: AluOp::Add, rd: R_COL, rs1: R_COL, imm: -1 });
        b.branch(Cond::Ne, R_COL, 0, col_loop);
        // next 4-row block
        b.emit(Instr::AluImm {
            op: AluOp::Add,
            rd: R_ABASE,
            rs1: R_ABASE,
            imm: 4 * row_bytes,
        });
        b.emit(Instr::AluImm {
            op: AluOp::Add,
            rd: R_PC,
            rs1: R_PC,
            imm: 3 * n * 4,
        });
        b.emit(Instr::AluImm { op: AluOp::Add, rd: R_ROW, rs1: R_ROW, imm: -1 });
        b.branch(Cond::Ne, R_ROW, 0, row_loop);
        b.build()
    }

    /// Shared builder for the explicit-load dotp kernels (Xpulp8, Nn,
    /// UnpackBaseline): 4×2 blocking, 8 accumulators.
    fn build_dotp(
        &self,
        a_addr: u32,
        b_addr: u32,
        c_addr: u32,
        prec: Prec,
        unpack: bool,
    ) -> Result<Program> {
        let lanes = prec.lanes() as usize;
        let row_bytes = (self.k / lanes * 4) as i32;
        let kwords = (self.k / lanes) as i32;
        let n = self.n as i32;
        let isa = if unpack { IsaLevel::Xpulp } else { self.kernel.isa() };
        let name = self.kernel.name();
        let mut b = ProgramBuilder::new(&name, isa);
        let acc = |r: u8, c: u8| R_ACC0 + 2 * r + c; // x10..x17

        self.prologue(&mut b, a_addr, c_addr, row_bytes);
        b.emit(Instr::Li { rd: R_KCNT, imm: kwords });
        if unpack {
            // per-lane shift counts for pv.sra.b: 4 for nibbles, 6 crumbs
            let s = if prec == Prec::B4 { 4 } else { 6 };
            b.emit(Instr::Li {
                rd: R_MASKV,
                imm: i32::from_ne_bytes([s, s, s, s]),
            });
        }

        let row_loop = b.label();
        b.bind(row_loop);
        b.emit(Instr::Li { rd: R_T0, imm: b_addr as i32 });
        for (i, &pb) in P_B[..2].iter().enumerate() {
            b.emit(Instr::AluImm {
                op: AluOp::Add,
                rd: pb,
                rs1: R_T0,
                imm: i as i32 * row_bytes,
            });
        }
        b.emit(Instr::Li { rd: R_COL, imm: n / 2 });

        let col_loop = b.label();
        b.bind(col_loop);
        for (r, &pa) in P_A.iter().enumerate() {
            b.emit(Instr::AluImm {
                op: AluOp::Add,
                rd: pa,
                rs1: R_ABASE,
                imm: r as i32 * row_bytes,
            });
        }
        for c in 0..2u8 {
            for r in 0..4u8 {
                b.emit(Instr::Li { rd: acc(r, c), imm: 0 });
            }
        }
        let (ls, le) = (b.label(), b.label());
        b.hw_loop(0, R_KCNT, ls, le);
        b.bind(ls);
        // loads (post-increment walks the rows)
        for (r, &pa) in P_A.iter().enumerate() {
            b.emit(Instr::Lw {
                rd: R_AV[r],
                base: pa,
                offset: 0,
                post_inc: 4,
            });
        }
        b.emit(Instr::Lw { rd: R_BV[0], base: P_B[0], offset: 0, post_inc: 4 });
        // last load placed just before first use would stall; keep order
        b.emit(Instr::Lw { rd: R_BV[1], base: P_B[1], offset: 0, post_inc: 4 });
        if !unpack {
            for r in 0..4u8 {
                for c in 0..2u8 {
                    b.emit(Instr::Sdotp {
                        prec,
                        sign: Sign::SS,
                        rd: acc(r, c),
                        rs1: R_AV[r as usize],
                        rs2: R_BV[c as usize],
                    });
                }
            }
        } else {
            self.emit_unpacked_dotps(&mut b, prec, &acc);
        }
        b.bind(le); // hw-loop body ends at the previous instruction
        for r in 0..4u8 {
            for c in 0..2u8 {
                b.emit(Instr::Sw {
                    rs: acc(r, c),
                    base: R_PC,
                    offset: (r as i32 * n + c as i32) * 4,
                    post_inc: 0,
                });
            }
        }
        for &pb in &P_B[..2] {
            b.emit(Instr::AluImm {
                op: AluOp::Add,
                rd: pb,
                rs1: pb,
                imm: row_bytes,
            });
        }
        b.emit(Instr::AluImm { op: AluOp::Add, rd: R_PC, rs1: R_PC, imm: 8 });
        b.emit(Instr::AluImm { op: AluOp::Add, rd: R_COL, rs1: R_COL, imm: -1 });
        b.branch(Cond::Ne, R_COL, 0, col_loop);
        b.emit(Instr::AluImm {
            op: AluOp::Add,
            rd: R_ABASE,
            rs1: R_ABASE,
            imm: 4 * row_bytes,
        });
        b.emit(Instr::AluImm {
            op: AluOp::Add,
            rd: R_PC,
            rs1: R_PC,
            imm: 3 * n * 4,
        });
        b.emit(Instr::AluImm { op: AluOp::Add, rd: R_ROW, rs1: R_ROW, imm: -1 });
        b.branch(Cond::Ne, R_ROW, 0, row_loop);
        b.build()
    }

    /// Unpack-then-dotp sequence for the plain-Xpulp sub-byte baseline.
    ///
    /// Nibbles: word → (evens, odds) B8 words via `sll` + per-lane
    /// arithmetic shifts; crumbs: word → 4 B8 words. Both A words are
    /// unpacked in place (scratch R_U0), then 8-bit sdotps accumulate.
    /// All plane orders match between A and B, so dot products are
    /// preserved.
    fn emit_unpacked_dotps(
        &self,
        b: &mut ProgramBuilder,
        prec: Prec,
        acc: &dyn Fn(u8, u8) -> u8,
    ) {
        let planes: &[u32] = match prec {
            Prec::B4 => &[4, 0],  // sll amounts producing evens/odds
            Prec::B2 => &[6, 4, 2, 0],
            _ => unreachable!(),
        };
        // For every (A row, B col) pair and every plane: unpack the plane
        // of both words and sdotp.b. Unpacked planes of B are recomputed
        // per row (register pressure: only R_U0/R_T0/R_T1 scratch), which
        // is exactly the data-manipulation overhead the paper describes.
        for r in 0..4u8 {
            for c in 0..2u8 {
                for &sh in planes {
                    // plane of A row
                    let ua = R_U0;
                    if sh != 0 {
                        b.emit(Instr::AluImm {
                            op: AluOp::Sll,
                            rd: ua,
                            rs1: R_AV[r as usize],
                            imm: sh as i32,
                        });
                        b.emit(Instr::VAlu {
                            op: VAluOp::Sra,
                            prec: Prec::B8,
                            rd: ua,
                            rs1: ua,
                            rs2: R_MASKV,
                        });
                    } else {
                        b.emit(Instr::VAlu {
                            op: VAluOp::Sra,
                            prec: Prec::B8,
                            rd: ua,
                            rs1: R_AV[r as usize],
                            rs2: R_MASKV,
                        });
                    }
                    // plane of B col
                    let ub = R_T0;
                    if sh != 0 {
                        b.emit(Instr::AluImm {
                            op: AluOp::Sll,
                            rd: ub,
                            rs1: R_BV[c as usize],
                            imm: sh as i32,
                        });
                        b.emit(Instr::VAlu {
                            op: VAluOp::Sra,
                            prec: Prec::B8,
                            rd: ub,
                            rs1: ub,
                            rs2: R_MASKV,
                        });
                    } else {
                        b.emit(Instr::VAlu {
                            op: VAluOp::Sra,
                            prec: Prec::B8,
                            rd: ub,
                            rs1: R_BV[c as usize],
                            rs2: R_MASKV,
                        });
                    }
                    b.emit(Instr::Sdotp {
                        prec: Prec::B8,
                        sign: Sign::SS,
                        rd: acc(r, c),
                        rs1: ua,
                        rs2: ub,
                    });
                }
            }
        }
    }

    /// Place operands, run on a cluster, return (C, stats). `a` is (M, K)
    /// row-major, `b` is (N, K) row-major (i.e. Bᵀ); values must fit the
    /// kernel precision.
    pub fn run_with(
        &self,
        cfg: ClusterConfig,
        a: &[i32],
        b: &[i32],
    ) -> Result<(Vec<i32>, RunStats)> {
        self.validate()?;
        ensure!(
            a.len() == self.m * self.k,
            "A has {} values, expected M*K = {}x{} = {}",
            a.len(),
            self.m,
            self.k,
            self.m * self.k
        );
        ensure!(
            b.len() == self.n * self.k,
            "B has {} values, expected N*K = {}x{} = {} (B is stored \
             transposed, (N, K) row-major)",
            b.len(),
            self.n,
            self.k,
            self.n * self.k
        );
        let half = 1i32 << (self.kernel.prec().bits() - 1);
        if a.iter().chain(b).any(|&v| v < -half || v >= half) {
            bail!("operand out of {}-bit range", self.kernel.prec().bits());
        }
        ensure!(
            cfg.cores == self.cores,
            "cluster config has {} cores but the problem was built for {}",
            cfg.cores,
            self.cores
        );
        let mut alloc = TcdmAlloc::new();
        let built = self.build(&mut alloc)?;
        let mut cl = Cluster::new(cfg);
        let prec = self.kernel.prec();
        write_packed(&mut cl.mem, built.a_addr, a, prec);
        write_packed(&mut cl.mem, built.b_addr, b, prec);
        cl.load_spmd(built.prog);
        let stats = cl.run()?;
        let c = read_i32(&cl.mem, built.c_addr, self.m * self.n);
        Ok((c, stats))
    }
}

/// Host oracle: C[M,N] = A[M,K] · Bᵀ[N,K] in i32.
pub fn matmul_reference(
    m: usize,
    n: usize,
    k: usize,
    a: &[i32],
    b: &[i32],
) -> Vec<i32> {
    let mut c = vec![0i32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut s = 0i64;
            for kk in 0..k {
                s += a[i * k + kk] as i64 * b[j * k + kk] as i64;
            }
            c[i * n + j] = s as i32;
        }
    }
    c
}

/// Random operands within the precision range.
pub fn random_operands(
    m: usize,
    n: usize,
    k: usize,
    prec: Prec,
    seed: u64,
) -> (Vec<i32>, Vec<i32>) {
    let mut rng = crate::util::Rng::new(seed);
    let half = 1i32 << (prec.bits() - 1);
    let a = (0..m * k).map(|_| rng.range_i32(-half, half)).collect();
    let b = (0..n * k).map(|_| rng.range_i32(-half, half)).collect();
    (a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check(kernel: MatmulKernel, m: usize, n: usize, k: usize, cores: usize) {
        let p = MatmulProblem { m, n, k, kernel, cores };
        let (a, b) = random_operands(m, n, k, kernel.prec(), 42);
        let mut cfg = ClusterConfig::default();
        cfg.cores = cores;
        let (c, stats) = p.run_with(cfg, &a, &b).unwrap();
        assert_eq!(c, matmul_reference(m, n, k, &a, &b), "{kernel:?}");
        assert_eq!(stats.total.macs, p.macs(), "{kernel:?} MAC count");
    }

    #[test]
    fn xpulp8_correct_single_core() {
        check(MatmulKernel::Xpulp8, 4, 4, 16, 1);
    }

    /// Unsupported shapes are rejected up front with messages naming the
    /// offending dimension and the required divisor — before any program
    /// emission or TCDM placement.
    #[test]
    fn validate_names_offending_dimension() {
        let p = |m, n, k, cores| MatmulProblem {
            m,
            n,
            k,
            kernel: MatmulKernel::Xpulp8,
            cores,
        };
        let err = p(6, 4, 16, 2).validate().unwrap_err().to_string();
        assert!(err.contains("M=6") && err.contains("4*cores = 8"), "{err}");
        let err = p(8, 3, 16, 2).validate().unwrap_err().to_string();
        assert!(err.contains("N=3") && err.contains("multiple of 2"), "{err}");
        let err = p(8, 4, 10, 2).validate().unwrap_err().to_string();
        assert!(err.contains("K=10") && err.contains("multiple of 4"), "{err}");
        let err = p(8, 4, 4, 2).validate().unwrap_err().to_string();
        assert!(err.contains("prefetches one word ahead"), "{err}");
        assert!(p(0, 4, 16, 2).validate().is_err());
        // the runner rejects before touching the cluster
        let (a, b) = random_operands(6, 4, 16, Prec::B8, 1);
        let mut cfg = ClusterConfig::default();
        cfg.cores = 2;
        assert!(p(6, 4, 16, 2).run_with(cfg, &a, &b).is_err());
        // wrong operand lengths name the expected extent
        let good = p(8, 4, 16, 2);
        let (a, b) = random_operands(8, 4, 16, Prec::B8, 2);
        let mut cfg = ClusterConfig::default();
        cfg.cores = 2;
        let err = good
            .run_with(cfg, &a[..a.len() - 1], &b)
            .unwrap_err()
            .to_string();
        assert!(err.contains("expected M*K"), "{err}");
    }

    #[test]
    fn xpulp8_correct_16_cores() {
        check(MatmulKernel::Xpulp8, 64, 16, 32, 16);
    }

    #[test]
    fn nn_nibble_and_crumb_correct() {
        check(MatmulKernel::Nn { prec: Prec::B4 }, 16, 8, 32, 4);
        check(MatmulKernel::Nn { prec: Prec::B2 }, 16, 8, 64, 4);
    }

    #[test]
    fn macload_correct_all_precisions() {
        check(MatmulKernel::MacLoad { prec: Prec::B8 }, 16, 8, 32, 4);
        check(MatmulKernel::MacLoad { prec: Prec::B4 }, 16, 8, 32, 4);
        check(MatmulKernel::MacLoad { prec: Prec::B2 }, 16, 8, 32, 4);
        check(MatmulKernel::MacLoad { prec: Prec::B8 }, 64, 32, 64, 16);
    }

    #[test]
    fn unpack_baseline_correct() {
        check(MatmulKernel::UnpackBaseline { prec: Prec::B4 }, 8, 4, 32, 2);
        check(MatmulKernel::UnpackBaseline { prec: Prec::B2 }, 8, 4, 32, 2);
    }

    /// Paper §III-C1: the MAC&LOAD inner loop keeps the DOTP unit ~94%
    /// utilized (16 of every 17 issue slots). Measured over a K large
    /// enough to amortize block overheads.
    #[test]
    fn macload_dotp_utilization() {
        let p = MatmulProblem {
            m: 16,
            n: 8,
            k: 512,
            kernel: MatmulKernel::MacLoad { prec: Prec::B8 },
            cores: 1,
        };
        let (a, b) = random_operands(16, 8, 512, Prec::B8, 1);
        let (_, stats) = p.run_with(ClusterConfig::soc_controller(), &a, &b)
            .unwrap();
        let util = stats.dotp_utilization();
        assert!(util > 0.88, "DOTP utilization {util:.3} (paper: 0.94)");
    }

    /// Paper §III-C1: MAC&LOAD boosts matmul throughput by up to ~67%
    /// over the explicit-load kernel.
    #[test]
    fn macload_speedup_over_baseline() {
        let run = |kernel| {
            let p = MatmulProblem { m: 64, n: 32, k: 64, kernel, cores: 16 };
            let (a, b) = random_operands(64, 32, 64, Prec::B8, 3);
            let (_, stats) =
                p.run_with(ClusterConfig::default(), &a, &b).unwrap();
            p.ops() as f64 / stats.cycles as f64
        };
        let base = run(MatmulKernel::Xpulp8);
        let ml = run(MatmulKernel::MacLoad { prec: Prec::B8 });
        let speedup = ml / base;
        assert!(
            (1.4..2.0).contains(&speedup),
            "M&L speedup {speedup:.2} (paper: ~1.67)"
        );
    }

    /// Paper §III-C1: 4-bit and 2-bit matmuls need ~6x/9x fewer
    /// instructions than the Xpulp unpack baseline. Our optimized unpack
    /// baseline lands lower (see EXPERIMENTS.md); assert the ordering and
    /// magnitude band.
    #[test]
    fn instruction_reduction_vs_unpack_baseline() {
        let count = |kernel: MatmulKernel| {
            let p = MatmulProblem { m: 8, n: 4, k: 64, kernel, cores: 1 };
            let (a, b) = random_operands(8, 4, 64, kernel.prec(), 5);
            let (_, stats) =
                p.run_with(ClusterConfig::soc_controller(), &a, &b).unwrap();
            stats.total.instrs as f64
        };
        let r4 = count(MatmulKernel::UnpackBaseline { prec: Prec::B4 })
            / count(MatmulKernel::Nn { prec: Prec::B4 });
        let r2 = count(MatmulKernel::UnpackBaseline { prec: Prec::B2 })
            / count(MatmulKernel::Nn { prec: Prec::B2 });
        assert!(r4 > 2.0, "4-bit instruction ratio {r4:.1}");
        assert!(r2 > 3.5, "2-bit instruction ratio {r2:.1}");
        assert!(r2 > r4, "2-bit saves more than 4-bit");
    }

    /// 2-bit MAC&LOAD on 16 cores approaches the paper's 180 Gop/s at
    /// 470 MHz => ~383 ops/cycle.
    #[test]
    fn crumb_macload_throughput() {
        let p = MatmulProblem {
            m: 64,
            n: 32,
            k: 128,
            kernel: MatmulKernel::MacLoad { prec: Prec::B2 },
            cores: 16,
        };
        let (a, b) = random_operands(64, 32, 128, Prec::B2, 7);
        let (_, stats) = p.run_with(ClusterConfig::default(), &a, &b).unwrap();
        let opc = p.ops() as f64 / stats.cycles as f64;
        assert!(
            (300.0..440.0).contains(&opc),
            "2-bit M&L {opc:.0} ops/cycle (paper ~383 at 470 MHz)"
        );
    }
}
