//! Elementwise tensor kernels: 8-bit SIMD tensor addition and word-copy
//! data marshaling (Fig. 14's "Add" task and Fig. 11's middle phase).

use anyhow::{ensure, Result};

use crate::cluster::{Cluster, ClusterConfig, RunStats};
use crate::isa::{AluOp, Instr, IsaLevel, Prec, Program, ProgramBuilder, VAluOp};
use crate::kernels::layout::{read_i32, write_packed, TcdmAlloc};

/// Build the SPMD 8-bit tensor-add kernel: `out = a + b` over `elems`
/// int8 values (wrap-around lanes, as `pv.add.b`). `elems` must split
/// into word-aligned equal per-core chunks.
pub fn tensor_add_program(
    a_addr: u32,
    b_addr: u32,
    out_addr: u32,
    elems: usize,
    cores: usize,
) -> Result<Program> {
    ensure!(elems % (4 * cores) == 0, "elems {elems} vs {cores} cores");
    let words_per_core = (elems / 4 / cores) as i32;
    let mut b = ProgramBuilder::new("tensor_add", IsaLevel::Xpulp);
    // x1 = pa, x2 = pb, x3 = pout, x5 = count, x6/x7 data, x29/x30 tmp
    b.emit(Instr::CoreId { rd: 29 });
    b.emit(Instr::AluImm { op: AluOp::Sll, rd: 29, rs1: 29, imm: 2 }); // id*4
    b.emit(Instr::Li { rd: 30, imm: words_per_core });
    b.emit(Instr::Alu { op: AluOp::Mul, rd: 29, rs1: 29, rs2: 30 }); // byte off
    for (reg, addr) in [(1u8, a_addr), (2, b_addr), (3, out_addr)] {
        b.emit(Instr::Li { rd: reg, imm: addr as i32 });
        b.emit(Instr::Alu { op: AluOp::Add, rd: reg, rs1: reg, rs2: 29 });
    }
    b.emit(Instr::Li { rd: 5, imm: words_per_core });
    let (ls, le) = (b.label(), b.label());
    b.hw_loop(0, 5, ls, le);
    b.bind(ls);
    b.emit(Instr::Lw { rd: 6, base: 1, offset: 0, post_inc: 4 });
    b.emit(Instr::Lw { rd: 7, base: 2, offset: 0, post_inc: 4 });
    b.emit(Instr::VAlu { op: VAluOp::Add, prec: Prec::B8, rd: 6, rs1: 6, rs2: 7 });
    b.emit(Instr::Sw { rs: 6, base: 3, offset: 0, post_inc: 4 });
    b.bind(le);
    b.build()
}

/// Build a word-copy marshaling kernel (`memcpy`-like, one word per
/// iteration per core).
pub fn marshal_copy_program(
    src_addr: u32,
    dst_addr: u32,
    words: usize,
    cores: usize,
) -> Result<Program> {
    ensure!(words % cores == 0);
    let per_core = (words / cores) as i32;
    let mut b = ProgramBuilder::new("marshal_copy", IsaLevel::Xpulp);
    b.emit(Instr::CoreId { rd: 29 });
    b.emit(Instr::Li { rd: 30, imm: per_core * 4 });
    b.emit(Instr::Alu { op: AluOp::Mul, rd: 29, rs1: 29, rs2: 30 });
    for (reg, addr) in [(1u8, src_addr), (2, dst_addr)] {
        b.emit(Instr::Li { rd: reg, imm: addr as i32 });
        b.emit(Instr::Alu { op: AluOp::Add, rd: reg, rs1: reg, rs2: 29 });
    }
    b.emit(Instr::Li { rd: 5, imm: per_core });
    let (ls, le) = (b.label(), b.label());
    b.hw_loop(0, 5, ls, le);
    b.bind(ls);
    b.emit(Instr::Lw { rd: 6, base: 1, offset: 0, post_inc: 4 });
    b.emit(Instr::Sw { rs: 6, base: 2, offset: 0, post_inc: 4 });
    b.bind(le);
    b.build()
}

/// Host driver: run tensor-add on `cores` cores and verify semantics.
pub fn run_tensor_add(
    cfg: ClusterConfig,
    a: &[i32],
    b: &[i32],
) -> Result<(Vec<i32>, RunStats)> {
    ensure!(a.len() == b.len());
    let elems = a.len();
    let mut alloc = TcdmAlloc::new();
    let words = elems / 4;
    let a_addr = alloc.alloc(words)?;
    let b_addr = alloc.alloc(words)?;
    let out_addr = alloc.alloc(words)?;
    let prog = tensor_add_program(a_addr, b_addr, out_addr, elems, cfg.cores)?;
    let mut cl = Cluster::new(cfg);
    write_packed(&mut cl.mem, a_addr, a, Prec::B8);
    write_packed(&mut cl.mem, b_addr, b, Prec::B8);
    cl.load_spmd(prog);
    let stats = cl.run()?;
    // read packed bytes back as lanes
    let out_words = cl.mem.read_l1(
        crate::kernels::layout::word_of(out_addr),
        words,
    );
    let mut out = Vec::with_capacity(elems);
    for &w in out_words {
        for i in 0..4 {
            out.push(crate::isa::simd::lane_s(w, Prec::B8, i));
        }
    }
    Ok((out, stats))
}

/// Host driver for the marshaling kernel.
pub fn run_marshal_copy(
    cfg: ClusterConfig,
    data: &[i32],
) -> Result<(Vec<i32>, RunStats)> {
    let words = data.len();
    let mut alloc = TcdmAlloc::new();
    let src = alloc.alloc(words)?;
    let dst = alloc.alloc(words)?;
    let prog = marshal_copy_program(src, dst, words, cfg.cores)?;
    let mut cl = Cluster::new(cfg);
    crate::kernels::layout::write_words(
        &mut cl.mem,
        src,
        &data.iter().map(|&v| v as u32).collect::<Vec<_>>(),
    );
    cl.load_spmd(prog);
    let stats = cl.run()?;
    Ok((read_i32(&cl.mem, dst, words), stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn tensor_add_correct() {
        let mut rng = Rng::new(1);
        let n = 9 * 9 * 64 - 9 * 9 * 64 % 64; // word+core aligned
        let a: Vec<i32> = (0..n).map(|_| rng.range_i32(-64, 64)).collect();
        let b: Vec<i32> = (0..n).map(|_| rng.range_i32(-63, 63)).collect();
        let (out, stats) =
            run_tensor_add(ClusterConfig::default(), &a, &b).unwrap();
        for i in 0..n {
            // wrap-around 8-bit add
            let want = ((a[i] + b[i]) as i8) as i32;
            assert_eq!(out[i], want, "elem {i}");
        }
        assert!(stats.cycles > 0);
    }

    #[test]
    fn add_scales_with_cores() {
        let mut rng = Rng::new(2);
        let n = 4096;
        let a: Vec<i32> = (0..n).map(|_| rng.range_i32(-8, 8)).collect();
        let b: Vec<i32> = (0..n).map(|_| rng.range_i32(-8, 8)).collect();
        let run = |cores| {
            let mut cfg = ClusterConfig::default();
            cfg.cores = cores;
            run_tensor_add(cfg, &a, &b).unwrap().1.cycles
        };
        let c1 = run(1);
        let c16 = run(16);
        let speedup = c1 as f64 / c16 as f64;
        assert!(speedup > 8.0, "16-core speedup {speedup:.1}");
    }

    #[test]
    fn marshal_copies_exactly() {
        let data: Vec<i32> = (0..2048).map(|i| i * 3 - 500).collect();
        let (out, _) =
            run_marshal_copy(ClusterConfig::default(), &data).unwrap();
        assert_eq!(out, data);
    }
}
