//! Direct 8-bit convolution (+ batch-norm) on the RISC-V cores — the
//! software path that Fig. 14 compares against RBE execution.
//!
//! Layouts (software-centric, paper §III-B "data marshaling" discussion):
//! * input `X (H+2, W+2, Kin)` HWC, int8 packed 4/word (padded border);
//! * weights `W (Kout, 9, Kin)` int8 packed (tap-major per output ch);
//! * output `(H, W, Kout)` int32 words (post-BN, shifted and clipped).
//!
//! Output channels are block-partitioned across cores (`Kout/cores`
//! each); inside, a 4-output-channel register block reuses every loaded
//! activation word for 4 `pv.sdotp.b` ops.

use anyhow::{ensure, Result};

use crate::cluster::{Cluster, ClusterConfig, RunStats};
use crate::isa::{AluOp, Cond, Instr, IsaLevel, Prec, Program, ProgramBuilder,
                 Sign};
use crate::kernels::layout::{read_i32, write_packed, write_words, TcdmAlloc};

/// Conv shape descriptor (square spatial, stride 1, pad 1 for 3×3).
#[derive(Debug, Clone, Copy)]
pub struct ConvProblem {
    pub h: usize,
    pub w: usize,
    pub k_in: usize,
    pub k_out: usize,
    /// 3 or 1.
    pub ksize: usize,
    pub cores: usize,
    /// batch-norm shift (scale/bias supplied at run time).
    pub bn_shift: u32,
}

impl ConvProblem {
    pub fn macs(&self) -> u64 {
        (self.h * self.w * self.k_in * self.k_out * self.ksize * self.ksize)
            as u64
    }

    pub fn ops(&self) -> u64 {
        self.macs() * 2
    }

    /// Up-front shape validation: every constraint is checked before any
    /// program emission, and each failure names the offending dimension
    /// and the divisor the kernel requires.
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.ksize == 1 || self.ksize == 3,
            "ksize={} is unsupported: the software conv kernel handles \
             1x1 and 3x3 filters only",
            self.ksize
        );
        ensure!(
            self.h > 0 && self.w > 0 && self.cores > 0,
            "degenerate conv shape H={} W={} cores={}: every dimension \
             must be > 0",
            self.h,
            self.w,
            self.cores
        );
        ensure!(
            self.k_in % 4 == 0,
            "Kin={} must be a multiple of 4 (int8 activations pack 4 \
             channels per 32-bit word)",
            self.k_in
        );
        ensure!(
            self.k_out % self.cores == 0,
            "Kout={} must be a multiple of cores={} (output channels are \
             block-partitioned across the cluster)",
            self.k_out,
            self.cores
        );
        ensure!(
            (self.k_out / self.cores) % 4 == 0,
            "Kout/core = {} must be a multiple of 4 (the kernel computes \
             4-output-channel register blocks); use Kout a multiple of {}",
            self.k_out / self.cores,
            4 * self.cores
        );
        Ok(())
    }

    fn hp(&self) -> usize {
        self.h + if self.ksize == 3 { 2 } else { 0 }
    }

    fn wp(&self) -> usize {
        self.w + if self.ksize == 3 { 2 } else { 0 }
    }

    /// Build the SPMD program.
    ///
    /// Register map: x1 pixbase(A), x2 scratch a-ptr, x3..x6 wptr, x7
    /// kin-count, x9 out-ptr, x10..13 accs, x14 a-word, x15/16 scale/bias
    /// ptrs, x17 shift, x20 y, x21 x, x22 kout-blk, x26..28 consts/tmp,
    /// x29/30/31 tmp.
    pub fn build(
        &self,
        x_addr: u32,
        w_addr: u32,
        scale_addr: u32,
        bias_addr: u32,
        out_addr: u32,
    ) -> Result<Program> {
        self.validate()?;
        let kin_w = self.k_in / 4; // activation words per tap
        let taps = self.ksize * self.ksize;
        let wrow_bytes = (taps * self.k_in) as i32; // weight bytes per kout
        let kouts_per_core = self.k_out / self.cores;
        let mut b = ProgramBuilder::new(
            if self.ksize == 3 { "conv3x3_sw" } else { "conv1x1_sw" },
            IsaLevel::Xpulp,
        );
        // my first kout = id * kouts_per_core
        b.emit(Instr::CoreId { rd: 29 });
        b.emit(Instr::Li { rd: 30, imm: kouts_per_core as i32 });
        b.emit(Instr::Alu { op: AluOp::Mul, rd: 28, rs1: 29, rs2: 30 }); // k0
        // weight base for k0: w_addr + k0*wrow_bytes
        b.emit(Instr::Li { rd: 30, imm: wrow_bytes });
        b.emit(Instr::Alu { op: AluOp::Mul, rd: 31, rs1: 28, rs2: 30 });
        b.emit(Instr::Li { rd: 27, imm: w_addr as i32 });
        b.emit(Instr::Alu { op: AluOp::Add, rd: 27, rs1: 27, rs2: 31 }); // wbase
        // scale/bias pointers for k0
        b.emit(Instr::AluImm { op: AluOp::Sll, rd: 31, rs1: 28, imm: 2 });
        b.emit(Instr::Li { rd: 15, imm: scale_addr as i32 });
        b.emit(Instr::Alu { op: AluOp::Add, rd: 15, rs1: 15, rs2: 31 });
        b.emit(Instr::Li { rd: 16, imm: bias_addr as i32 });
        b.emit(Instr::Alu { op: AluOp::Add, rd: 16, rs1: 16, rs2: 31 });
        // out base for pixel 0, channel k0: out + k0*4
        b.emit(Instr::Li { rd: 9, imm: out_addr as i32 });
        b.emit(Instr::Alu { op: AluOp::Add, rd: 9, rs1: 9, rs2: 31 });
        // kout-block loop: kouts_per_core/4 blocks
        b.emit(Instr::Li { rd: 22, imm: (kouts_per_core / 4) as i32 });
        let kout_loop = b.label();
        b.bind(kout_loop);
        // y/x pixel loops
        b.emit(Instr::Li { rd: 20, imm: self.h as i32 });
        let y_loop = b.label();
        b.bind(y_loop);
        b.emit(Instr::Li { rd: 21, imm: self.w as i32 });
        let x_loop = b.label();
        b.bind(x_loop);
        // pixbase = x_addr + ((y_idx*wp + x_idx) * kin) bytes, where
        // y_idx = h - x20, x_idx = w - x21 (counters count down).
        // Compute via tmp: iy = h - x20; ix = w - x21.
        b.emit(Instr::Li { rd: 29, imm: self.h as i32 });
        b.emit(Instr::Alu { op: AluOp::Sub, rd: 29, rs1: 29, rs2: 20 });
        b.emit(Instr::Li { rd: 30, imm: self.wp() as i32 });
        b.emit(Instr::Alu { op: AluOp::Mul, rd: 29, rs1: 29, rs2: 30 });
        b.emit(Instr::Li { rd: 30, imm: self.w as i32 });
        b.emit(Instr::Alu { op: AluOp::Sub, rd: 30, rs1: 30, rs2: 21 });
        b.emit(Instr::Alu { op: AluOp::Add, rd: 29, rs1: 29, rs2: 30 });
        b.emit(Instr::Li { rd: 30, imm: self.k_in as i32 });
        b.emit(Instr::Alu { op: AluOp::Mul, rd: 29, rs1: 29, rs2: 30 });
        b.emit(Instr::Li { rd: 1, imm: x_addr as i32 });
        b.emit(Instr::Alu { op: AluOp::Add, rd: 1, rs1: 1, rs2: 29 });
        // working weight pointers for the 4 kouts of this block
        for i in 0..4u8 {
            b.emit(Instr::AluImm {
                op: AluOp::Add,
                rd: 3 + i,
                rs1: 27,
                imm: i as i32 * wrow_bytes,
            });
        }
        // zero accumulators
        for i in 0..4u8 {
            b.emit(Instr::Li { rd: 10 + i, imm: 0 });
        }
        // taps
        for ty in 0..self.ksize {
            for tx in 0..self.ksize {
                // a-ptr = pixbase + (ty*wp + tx)*kin
                b.emit(Instr::AluImm {
                    op: AluOp::Add,
                    rd: 2,
                    rs1: 1,
                    imm: ((ty * self.wp() + tx) * self.k_in) as i32,
                });
                b.emit(Instr::Li { rd: 7, imm: kin_w as i32 });
                let (ls, le) = (b.label(), b.label());
                b.hw_loop(0, 7, ls, le);
                b.bind(ls);
                b.emit(Instr::Lw { rd: 14, base: 2, offset: 0, post_inc: 4 });
                for i in 0..4u8 {
                    b.emit(Instr::Lw {
                        rd: 30,
                        base: 3 + i,
                        offset: 0,
                        post_inc: 4,
                    });
                    b.emit(Instr::Sdotp {
                        prec: Prec::B8,
                        sign: Sign::SS,
                        rd: 10 + i,
                        rs1: 14,
                        rs2: 30,
                    });
                }
                b.bind(le);
            }
        }
        // batch-norm + store: out = clip((scale*acc + bias) >> shift)
        for i in 0..4u8 {
            b.emit(Instr::Lw {
                rd: 29,
                base: 15,
                offset: i as i32 * 4,
                post_inc: 0,
            });
            b.emit(Instr::Alu { op: AluOp::Mul, rd: 29, rs1: 29, rs2: 10 + i });
            b.emit(Instr::Lw {
                rd: 30,
                base: 16,
                offset: i as i32 * 4,
                post_inc: 0,
            });
            b.emit(Instr::Alu { op: AluOp::Add, rd: 29, rs1: 29, rs2: 30 });
            b.emit(Instr::AluImm {
                op: AluOp::Sra,
                rd: 29,
                rs1: 29,
                imm: self.bn_shift as i32,
            });
            b.emit(Instr::Li { rd: 30, imm: 127 });
            b.emit(Instr::Alu { op: AluOp::Min, rd: 29, rs1: 29, rs2: 30 });
            b.emit(Instr::Li { rd: 30, imm: -128 });
            b.emit(Instr::Alu { op: AluOp::Max, rd: 29, rs1: 29, rs2: 30 });
            b.emit(Instr::Sw {
                rs: 29,
                base: 9,
                offset: i as i32 * 4,
                post_inc: 0,
            });
        }
        // advance out by one pixel (Kout words)
        b.emit(Instr::AluImm {
            op: AluOp::Add,
            rd: 9,
            rs1: 9,
            imm: self.k_out as i32 * 4,
        });
        b.emit(Instr::AluImm { op: AluOp::Add, rd: 21, rs1: 21, imm: -1 });
        b.branch(Cond::Ne, 21, 0, x_loop);
        b.emit(Instr::AluImm { op: AluOp::Add, rd: 20, rs1: 20, imm: -1 });
        b.branch(Cond::Ne, 20, 0, y_loop);
        // next kout block: wbase += 4 rows, scale/bias += 16, out rewinds
        // to pixel 0 of the next 4 channels
        b.emit(Instr::AluImm {
            op: AluOp::Add,
            rd: 27,
            rs1: 27,
            imm: 4 * wrow_bytes,
        });
        b.emit(Instr::AluImm { op: AluOp::Add, rd: 15, rs1: 15, imm: 16 });
        b.emit(Instr::AluImm { op: AluOp::Add, rd: 16, rs1: 16, imm: 16 });
        b.emit(Instr::AluImm {
            op: AluOp::Add,
            rd: 9,
            rs1: 9,
            imm: -((self.h * self.w * self.k_out * 4) as i32) + 16,
        });
        b.emit(Instr::AluImm { op: AluOp::Add, rd: 22, rs1: 22, imm: -1 });
        b.branch(Cond::Ne, 22, 0, kout_loop);
        b.build()
    }

    /// Place data, run, return (output, stats). `x` is (H+2p, W+2p, Kin)
    /// int8 HWC; `w` is (Kout, taps, Kin) int8; `scale`/`bias` per-Kout.
    pub fn run_with(
        &self,
        cfg: ClusterConfig,
        x: &[i32],
        w: &[i32],
        scale: &[i32],
        bias: &[i32],
    ) -> Result<(Vec<i32>, RunStats)> {
        self.validate()?;
        let taps = self.ksize * self.ksize;
        ensure!(
            x.len() == self.hp() * self.wp() * self.k_in,
            "activation has {} values, expected ({}, {}, {}) = {} \
             (padded plane for 3x3)",
            x.len(),
            self.hp(),
            self.wp(),
            self.k_in,
            self.hp() * self.wp() * self.k_in
        );
        ensure!(
            w.len() == self.k_out * taps * self.k_in,
            "weights have {} values, expected Kout*taps*Kin = {}x{}x{} = {}",
            w.len(),
            self.k_out,
            taps,
            self.k_in,
            self.k_out * taps * self.k_in
        );
        ensure!(
            scale.len() == self.k_out && bias.len() == self.k_out,
            "scale/bias have {}/{} values, expected Kout = {} each",
            scale.len(),
            bias.len(),
            self.k_out
        );
        ensure!(
            cfg.cores == self.cores,
            "cluster config has {} cores but the problem was built for {}",
            cfg.cores,
            self.cores
        );
        let mut alloc = TcdmAlloc::new();
        let x_addr = alloc.alloc(x.len() / 4 + 2)?;
        let w_addr = alloc.alloc(w.len() / 4 + 2)?;
        let s_addr = alloc.alloc(self.k_out)?;
        let b_addr = alloc.alloc(self.k_out)?;
        let out_addr = alloc.alloc(self.h * self.w * self.k_out)?;
        let prog = self.build(x_addr, w_addr, s_addr, b_addr, out_addr)?;
        let mut cl = Cluster::new(cfg);
        write_packed(&mut cl.mem, x_addr, x, Prec::B8);
        write_packed(&mut cl.mem, w_addr, w, Prec::B8);
        write_words(&mut cl.mem, s_addr,
                    &scale.iter().map(|&v| v as u32).collect::<Vec<_>>());
        write_words(&mut cl.mem, b_addr,
                    &bias.iter().map(|&v| v as u32).collect::<Vec<_>>());
        cl.load_spmd(prog);
        let stats = cl.run()?;
        let out = read_i32(&cl.mem, out_addr, self.h * self.w * self.k_out);
        Ok((out, stats))
    }
}

/// Host oracle for the software conv + BN.
pub fn conv_sw_reference(
    p: &ConvProblem,
    x: &[i32],
    w: &[i32],
    scale: &[i32],
    bias: &[i32],
) -> Vec<i32> {
    let taps = p.ksize;
    let (wp, kin) = (p.wp(), p.k_in);
    let mut out = vec![0i32; p.h * p.w * p.k_out];
    for y in 0..p.h {
        for xq in 0..p.w {
            for ko in 0..p.k_out {
                let mut acc = 0i64;
                for ty in 0..taps {
                    for tx in 0..taps {
                        for ki in 0..kin {
                            let xv =
                                x[((y + ty) * wp + (xq + tx)) * kin + ki];
                            let wv = w[(ko * taps * taps + ty * taps + tx)
                                * kin
                                + ki];
                            acc += xv as i64 * wv as i64;
                        }
                    }
                }
                let v = ((scale[ko] as i64 * (acc as i32) as i64
                    + bias[ko] as i64)
                    >> p.bn_shift)
                    .clamp(-128, 127);
                out[(y * p.w + xq) * p.k_out + ko] = v as i32;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn inputs(p: &ConvProblem, seed: u64)
        -> (Vec<i32>, Vec<i32>, Vec<i32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let taps = p.ksize * p.ksize;
        let x = (0..p.hp() * p.wp() * p.k_in)
            .map(|_| rng.range_i32(-128, 128))
            .collect();
        let w = (0..p.k_out * taps * p.k_in)
            .map(|_| rng.range_i32(-128, 128))
            .collect();
        let scale = (0..p.k_out).map(|_| rng.range_i32(1, 8)).collect();
        let bias = (0..p.k_out).map(|_| rng.range_i32(-100, 100)).collect();
        (x, w, scale, bias)
    }

    /// Unsupported shapes fail up front, naming dimension and divisor.
    #[test]
    fn validate_names_offending_dimension() {
        let base = ConvProblem {
            h: 4, w: 4, k_in: 8, k_out: 8, ksize: 3, cores: 2, bn_shift: 6,
        };
        let err = ConvProblem { ksize: 5, ..base }
            .validate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("ksize=5"), "{err}");
        let err = ConvProblem { k_in: 6, ..base }
            .validate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("Kin=6") && err.contains("multiple of 4"), "{err}");
        let err = ConvProblem { k_out: 6, ..base }
            .validate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("Kout=6") && err.contains("cores=2"), "{err}");
        let err = ConvProblem { k_out: 4, ..base }
            .validate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("Kout/core = 2"), "{err}");
        base.validate().unwrap();
    }

    #[test]
    fn conv3x3_matches_reference() {
        let p = ConvProblem {
            h: 5, w: 5, k_in: 8, k_out: 8, ksize: 3, cores: 2, bn_shift: 8,
        };
        let (x, w, s, bi) = inputs(&p, 11);
        let mut cfg = ClusterConfig::default();
        cfg.cores = 2;
        let (out, stats) = p.run_with(cfg, &x, &w, &s, &bi).unwrap();
        assert_eq!(out, conv_sw_reference(&p, &x, &w, &s, &bi));
        assert_eq!(stats.total.macs, p.macs());
    }

    #[test]
    fn conv1x1_matches_reference() {
        let p = ConvProblem {
            h: 4, w: 4, k_in: 16, k_out: 16, ksize: 1, cores: 4, bn_shift: 6,
        };
        let (x, w, s, bi) = inputs(&p, 13);
        let mut cfg = ClusterConfig::default();
        cfg.cores = 4;
        let (out, _) = p.run_with(cfg, &x, &w, &s, &bi).unwrap();
        assert_eq!(out, conv_sw_reference(&p, &x, &w, &s, &bi));
    }

    /// Fig. 14 workload: 9×9×64 output, 64 input channels, 16 cores.
    #[test]
    fn fig14_conv3x3_runs_parallel() {
        let p = ConvProblem {
            h: 9, w: 9, k_in: 64, k_out: 64, ksize: 3, cores: 16, bn_shift: 10,
        };
        let (x, w, s, bi) = inputs(&p, 17);
        let (out16, stats16) =
            p.run_with(ClusterConfig::default(), &x, &w, &s, &bi).unwrap();
        assert_eq!(out16, conv_sw_reference(&p, &x, &w, &s, &bi));
        // single-core run for the speedup shape
        let p1 = ConvProblem { cores: 1, ..p };
        let (_, stats1) = p1
            .run_with(ClusterConfig::soc_controller(), &x, &w, &s, &bi)
            .unwrap();
        let speedup = stats1.cycles as f64 / stats16.cycles as f64;
        assert!(speedup > 10.0, "16-core speedup {speedup:.1}");
    }
}
