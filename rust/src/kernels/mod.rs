//! Software kernel library for the RISC-V cluster (paper §II-A3: the
//! optimized XpulpNN QNN/linear-algebra routines, here emitted through the
//! [`crate::isa::ProgramBuilder`] instead of GCC builtins).
//!
//! Each kernel builder returns a SPMD [`crate::isa::Program`] plus a host
//! descriptor that knows how to place inputs in TCDM and read results
//! back, so tests can verify the ISS output against a plain Rust oracle —
//! these kernels *execute*, they are not latency formulas.
//!
//! Inventory (paper §III-C1, Figs. 14–15):
//! * [`matmul`] — parallel INT matmul: Xpulp 8-bit baseline, XpulpNN
//!   nibble/crumb SIMD, MAC&LOAD variants (the Fig. 2c inner loop), and
//!   the pulp-nn-style unpack baseline used for the 6×/9× instruction
//!   comparisons.
//! * [`fft`] — radix-2 complex FP32 FFT on 16 cores + 8 shared FPUs.
//! * [`vecops`] — tensor add and data-marshaling kernels.
//! * [`conv`] — direct 3×3 / 1×1 8-bit convolution + batch-norm on the
//!   cores (the software path RBE is compared against in Fig. 14).

pub mod conv;
pub mod fft;
pub mod layout;
pub mod matmul;
pub mod offload;
pub mod vecops;

pub use layout::TcdmAlloc;
pub use matmul::{MatmulKernel, MatmulProblem};
