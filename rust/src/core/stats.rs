//! Per-core performance counters (modelled after the RI5CY PCCRs).

/// Counters accumulated while a core executes; the cluster aggregates them
/// into workload-level metrics (Gop/s, DOTP utilization, stall breakdown).
#[derive(Debug, Default, Clone)]
pub struct CoreStats {
    /// Instructions retired.
    pub instrs: u64,
    /// Cycles this core was active (not halted), including stalls.
    pub cycles: u64,
    /// MAC operations performed (SIMD lanes counted).
    pub macs: u64,
    /// FP operations performed (FMA = 2).
    pub flops: u64,
    /// Instructions that occupied the DOTP unit.
    pub dotp_instrs: u64,
    /// MAC&LOAD instructions among them.
    pub macload_instrs: u64,
    /// Data-memory accesses issued (TCDM + L2).
    pub mem_accesses: u64,
    /// Stall cycles: TCDM bank conflict.
    pub stall_conflict: u64,
    /// Stall cycles: shared-FPU contention.
    pub stall_fpu: u64,
    /// Stall cycles: load-use hazard.
    pub stall_loaduse: u64,
    /// Stall cycles: taken-branch bubble.
    pub stall_branch: u64,
    /// Stall cycles: L2 (AXI) access latency.
    pub stall_l2: u64,
    /// Cycles parked at an event-unit barrier.
    pub stall_barrier: u64,
}

impl CoreStats {
    pub fn total_stalls(&self) -> u64 {
        self.stall_conflict
            + self.stall_fpu
            + self.stall_loaduse
            + self.stall_branch
            + self.stall_l2
            + self.stall_barrier
    }

    /// Fraction of active cycles in which the DOTP unit was busy — the
    /// utilization figure the paper quotes as 94% for MAC&LOAD MatMul.
    pub fn dotp_utilization(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.dotp_instrs as f64 / self.cycles as f64
        }
    }

    pub fn merge(&mut self, o: &CoreStats) {
        self.instrs += o.instrs;
        self.cycles += o.cycles;
        self.macs += o.macs;
        self.flops += o.flops;
        self.dotp_instrs += o.dotp_instrs;
        self.macload_instrs += o.macload_instrs;
        self.mem_accesses += o.mem_accesses;
        self.stall_conflict += o.stall_conflict;
        self.stall_fpu += o.stall_fpu;
        self.stall_loaduse += o.stall_loaduse;
        self.stall_branch += o.stall_branch;
        self.stall_l2 += o.stall_l2;
        self.stall_barrier += o.stall_barrier;
    }
}
