//! RI5CY-style core model: architectural state + instruction semantics +
//! per-instruction timing rules (paper §II-A2, Fig. 2b).
//!
//! The core is executed cycle-by-cycle by [`crate::cluster::Cluster`]; this
//! module owns everything *inside* one core: the GP-RF, FP-RF, the XpulpNN
//! NN-RF, hardware-loop contexts, and the execute stage. Memory and FPU
//! arbitration live in the cluster (they are shared resources).

mod exec;
mod stats;

pub use exec::{ExecOutcome, MemOp, MemSpace};
pub use stats::CoreStats;

use std::sync::Arc;

use crate::isa::{Instr, Program, NN_RF_SIZE};

/// Hardware-loop context (Xpulp `lp.setup`).
#[derive(Debug, Clone, Copy)]
pub struct LoopCtx {
    pub body_start: usize,
    pub body_end: usize,
    pub remaining: u32,
}

/// One cluster (or SOC) core.
pub struct Core {
    pub id: usize,
    pub regs: [u32; 32],
    /// FP registers, stored as raw f32 bits.
    pub fregs: [u32; 32],
    /// The XpulpNN NN register file (6 × 32-bit SIMD vectors).
    pub nnrf: [u32; NN_RF_SIZE],
    pub pc: usize,
    pub halted: bool,
    /// Cycles the core must stall before issuing again.
    pub stall: u32,
    /// Set while parked at an event-unit barrier.
    pub at_barrier: bool,
    pub loops: [Option<LoopCtx>; 2],
    pub prog: Arc<Program>,
    pub stats: CoreStats,
    /// rd of an in-flight load, for the load-use hazard check.
    pub last_load_rd: Option<u8>,
}

impl Core {
    pub fn new(id: usize, prog: Arc<Program>) -> Self {
        Self {
            id,
            regs: [0; 32],
            fregs: [0; 32],
            nnrf: [0; NN_RF_SIZE],
            pc: 0,
            halted: false,
            stall: 0,
            at_barrier: false,
            loops: [None; 2],
            prog,
            stats: CoreStats::default(),
        last_load_rd: None,
        }
    }

    /// Current instruction, if any.
    pub fn fetch(&self) -> Option<Instr> {
        if self.halted {
            None
        } else {
            self.prog.instrs.get(self.pc).copied()
        }
    }

    pub fn reg(&self, r: u8) -> u32 {
        if r == 0 {
            0
        } else {
            self.regs[r as usize]
        }
    }

    pub fn set_reg(&mut self, r: u8, v: u32) {
        if r != 0 {
            self.regs[r as usize] = v;
        }
    }

    pub fn freg(&self, r: u8) -> f32 {
        f32::from_bits(self.fregs[r as usize])
    }

    pub fn set_freg(&mut self, r: u8, v: f32) {
        self.fregs[r as usize] = v.to_bits();
    }

    /// Advance pc after executing the instruction at `pc`, honouring
    /// hardware-loop back-edges (zero-overhead: the jump back is free).
    pub fn advance_pc(&mut self) {
        // Innermost loop whose body ends here takes priority. With two
        // contexts, the one with the *larger* body_start that matches is
        // the inner one.
        let mut matched: Option<usize> = None;
        for i in 0..2 {
            if let Some(ctx) = self.loops[i] {
                if ctx.body_end == self.pc && ctx.remaining > 0 {
                    matched = match matched {
                        Some(j)
                            if self.loops[j].unwrap().body_start
                                >= ctx.body_start =>
                        {
                            Some(j)
                        }
                        _ => Some(i),
                    };
                }
            }
        }
        if let Some(i) = matched {
            let ctx = self.loops[i].as_mut().unwrap();
            ctx.remaining -= 1;
            if ctx.remaining > 0 {
                self.pc = ctx.body_start;
                return;
            }
            self.loops[i] = None;
        }
        self.pc += 1;
    }
}
