//! Execute stage: instruction semantics against a word-addressed memory.

use anyhow::{bail, Result};

use super::{Core, LoopCtx};
use crate::isa::{dotp, simd_alu, AluOp, Cond, FOp, Instr};

/// The memory side-effect an instruction wants this cycle, computed
/// *before* execution so the cluster can arbitrate TCDM banks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemOp {
    /// Byte address (word aligned).
    pub addr: u32,
    pub is_store: bool,
}

/// What happened when an instruction executed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecOutcome {
    /// Normal completion; pc advanced.
    Done,
    /// Branch taken (pc redirected) — costs one bubble.
    BranchTaken,
    /// Core reached Halt.
    Halted,
    /// Core parked at barrier.
    Barrier,
}

impl Core {
    /// If the current instruction accesses data memory, return the request
    /// (pure; no state change).
    pub fn mem_request(&self) -> Option<MemOp> {
        let i = self.fetch()?;
        let (base, offset, post_inc, is_store) = match i {
            Instr::Lw { base, offset, post_inc, .. } => {
                (base, offset, post_inc, false)
            }
            Instr::Sw { base, offset, post_inc, .. } => {
                (base, offset, post_inc, true)
            }
            Instr::Flw { base, offset, post_inc, .. } => {
                (base, offset, post_inc, false)
            }
            Instr::Fsw { base, offset, post_inc, .. } => {
                (base, offset, post_inc, true)
            }
            Instr::NnLoad { ptr, post_inc, .. } => (ptr, 0, post_inc, false),
            Instr::MlSdotp { refresh: Some((_, ptr)), .. } => {
                (ptr, 0, 0, false)
            }
            _ => return None,
        };
        let eff = if post_inc != 0 {
            self.reg(base) // post-increment form: address is the old base
        } else {
            self.reg(base).wrapping_add(offset as u32)
        };
        Some(MemOp { addr: eff, is_store })
    }

    /// Execute the current instruction. `mem` is the whole address space
    /// (the cluster has already granted any needed bank this cycle).
    pub fn exec<M: MemSpace>(&mut self, mem: &mut M) -> Result<ExecOutcome> {
        let Some(i) = self.fetch() else {
            return Ok(ExecOutcome::Halted);
        };
        self.stats.instrs += 1;
        // op-class counters are bumped inside the match arms (hot loop:
        // one dispatch per instruction instead of five)
        let mut next_load_rd: Option<u8> = None;

        match i {
            Instr::Alu { op, rd, rs1, rs2 } => {
                let v = alu(op, self.reg(rs1), self.reg(rs2));
                self.set_reg(rd, v);
            }
            Instr::AluImm { op, rd, rs1, imm } => {
                let v = alu(op, self.reg(rs1), imm as u32);
                self.set_reg(rd, v);
            }
            Instr::Li { rd, imm } => self.set_reg(rd, imm as u32),
            Instr::Mac { rd, rs1, rs2 } => {
                self.stats.macs += 1;
                let v = (self.reg(rd) as i32).wrapping_add(
                    (self.reg(rs1) as i32).wrapping_mul(self.reg(rs2) as i32),
                );
                self.set_reg(rd, v as u32);
            }
            Instr::VAlu { op, prec, rd, rs1, rs2 } => {
                let v = simd_alu(op, self.reg(rs1), self.reg(rs2), prec);
                self.set_reg(rd, v);
            }
            Instr::Dotp { prec, sign, rd, rs1, rs2 } => {
                self.stats.macs += prec.macs_per_dotp();
                self.stats.dotp_instrs += 1;
                let v = dotp(self.reg(rs1), self.reg(rs2), prec, sign);
                self.set_reg(rd, v as u32);
            }
            Instr::Sdotp { prec, sign, rd, rs1, rs2 } => {
                self.stats.macs += prec.macs_per_dotp();
                self.stats.dotp_instrs += 1;
                let v = (self.reg(rd) as i32).wrapping_add(dotp(
                    self.reg(rs1),
                    self.reg(rs2),
                    prec,
                    sign,
                ));
                self.set_reg(rd, v as u32);
            }
            Instr::MlSdotp { prec, sign, rd, na, nb, refresh } => {
                self.stats.macs += prec.macs_per_dotp();
                self.stats.dotp_instrs += 1;
                self.stats.macload_instrs += 1;
                if refresh.is_some() {
                    self.stats.mem_accesses += 1;
                }
                // DOTP reads the *current* NN-RF contents; the refresh data
                // lands in WB, visible from the next cycle (paper Fig. 2b).
                let v = (self.reg(rd) as i32).wrapping_add(dotp(
                    self.nnrf[na as usize],
                    self.nnrf[nb as usize],
                    prec,
                    sign,
                ));
                self.set_reg(rd, v as u32);
                if let Some((nn, ptr)) = refresh {
                    let addr = self.reg(ptr);
                    self.nnrf[nn as usize] = mem.load(addr)?;
                    // pointer post-incremented by one word in EX
                    self.set_reg(ptr, addr.wrapping_add(4));
                }
            }
            Instr::NnLoad { nn_rd, ptr, post_inc } => {
                self.stats.mem_accesses += 1;
                let addr = self.reg(ptr);
                self.nnrf[nn_rd as usize] = mem.load(addr)?;
                if post_inc != 0 {
                    self.set_reg(ptr, addr.wrapping_add(post_inc as u32));
                }
            }
            Instr::Lw { rd, base, offset, post_inc } => {
                self.stats.mem_accesses += 1;
                let addr = if post_inc != 0 {
                    let a = self.reg(base);
                    self.set_reg(base, a.wrapping_add(post_inc as u32));
                    a
                } else {
                    self.reg(base).wrapping_add(offset as u32)
                };
                let v = mem.load(addr)?;
                self.set_reg(rd, v);
                next_load_rd = Some(rd);
            }
            Instr::Sw { rs, base, offset, post_inc } => {
                self.stats.mem_accesses += 1;
                let addr = if post_inc != 0 {
                    let a = self.reg(base);
                    self.set_reg(base, a.wrapping_add(post_inc as u32));
                    a
                } else {
                    self.reg(base).wrapping_add(offset as u32)
                };
                mem.store(addr, self.reg(rs))?;
            }
            Instr::Flw { fd, base, offset, post_inc } => {
                self.stats.mem_accesses += 1;
                let addr = if post_inc != 0 {
                    let a = self.reg(base);
                    self.set_reg(base, a.wrapping_add(post_inc as u32));
                    a
                } else {
                    self.reg(base).wrapping_add(offset as u32)
                };
                self.fregs[fd as usize] = mem.load(addr)?;
            }
            Instr::Fsw { fs, base, offset, post_inc } => {
                self.stats.mem_accesses += 1;
                let addr = if post_inc != 0 {
                    let a = self.reg(base);
                    self.set_reg(base, a.wrapping_add(post_inc as u32));
                    a
                } else {
                    self.reg(base).wrapping_add(offset as u32)
                };
                mem.store(addr, self.fregs[fs as usize])?;
            }
            Instr::FAlu { op, lanes, fd, fs1, fs2, fs3 } => {
                self.stats.flops += op.flops() * lanes as u64;
                let (a, b, c) = (self.freg(fs1), self.freg(fs2), self.freg(fs3));
                let v = match op {
                    FOp::Add => a + b,
                    FOp::Sub => a - b,
                    FOp::Mul => a * b,
                    FOp::Madd => a.mul_add(b, c),
                    FOp::Nmsub => (-a).mul_add(b, c),
                };
                self.set_freg(fd, v);
            }
            Instr::FMvToF { fd, rs } => {
                self.fregs[fd as usize] = self.reg(rs);
            }
            Instr::FMvToX { rd, fs } => {
                self.set_reg(rd, self.fregs[fs as usize]);
            }
            Instr::Branch { cond, rs1, rs2, target } => {
                let (a, b) = (self.reg(rs1), self.reg(rs2));
                let taken = match cond {
                    Cond::Eq => a == b,
                    Cond::Ne => a != b,
                    Cond::Lt => (a as i32) < (b as i32),
                    Cond::Ge => (a as i32) >= (b as i32),
                    Cond::Ltu => a < b,
                    Cond::Geu => a >= b,
                };
                if taken {
                    self.pc = target;
                    return Ok(ExecOutcome::BranchTaken);
                }
            }
            Instr::Jump { target } => {
                self.pc = target;
                return Ok(ExecOutcome::BranchTaken);
            }
            Instr::HwLoop { idx, count, body_start, body_end } => {
                let n = self.reg(count);
                if n == 0 {
                    bail!("hw loop {idx} setup with count 0 (pc {})", self.pc);
                }
                self.loops[idx as usize] = Some(LoopCtx {
                    body_start,
                    body_end,
                    remaining: n,
                });
            }
            Instr::Barrier => {
                self.at_barrier = true;
                self.advance_pc();
                return Ok(ExecOutcome::Barrier);
            }
            Instr::CoreId { rd } => self.set_reg(rd, self.id as u32),
            Instr::Nop => {}
            Instr::Halt => {
                self.halted = true;
                return Ok(ExecOutcome::Halted);
            }
        }

        self.advance_pc();
        // Load-use hazard: stall one cycle if the *next* instruction reads
        // the register a load just wrote (RI5CY forwards from WB with a
        // single bubble).
        if let Some(rd) = next_load_rd {
            if let Some(next) = self.fetch() {
                if reads_reg(&next, rd) {
                    self.stall += 1;
                    self.stats.stall_loaduse += 1;
                }
            }
        }
        self.last_load_rd = next_load_rd;
        Ok(ExecOutcome::Done)
    }
}

/// Word-addressed memory interface implemented by the cluster memory
/// system.
pub trait MemSpace {
    fn load(&mut self, addr: u32) -> Result<u32>;
    fn store(&mut self, addr: u32, value: u32) -> Result<()>;
}

fn alu(op: AluOp, a: u32, b: u32) -> u32 {
    match op {
        AluOp::Add => a.wrapping_add(b),
        AluOp::Sub => a.wrapping_sub(b),
        AluOp::Sll => a.wrapping_shl(b & 31),
        AluOp::Srl => a.wrapping_shr(b & 31),
        AluOp::Sra => ((a as i32).wrapping_shr(b & 31)) as u32,
        AluOp::And => a & b,
        AluOp::Or => a | b,
        AluOp::Xor => a ^ b,
        AluOp::Slt => ((a as i32) < (b as i32)) as u32,
        AluOp::Sltu => (a < b) as u32,
        AluOp::Mul => a.wrapping_mul(b),
        AluOp::Min => (a as i32).min(b as i32) as u32,
        AluOp::Max => (a as i32).max(b as i32) as u32,
    }
}

/// Does `i` read GPR `r`? (conservative, for the load-use hazard check)
fn reads_reg(i: &Instr, r: u8) -> bool {
    if r == 0 {
        return false;
    }
    match *i {
        Instr::Alu { rs1, rs2, .. } => rs1 == r || rs2 == r,
        Instr::AluImm { rs1, .. } => rs1 == r,
        Instr::Mac { rd, rs1, rs2 } => rd == r || rs1 == r || rs2 == r,
        Instr::VAlu { rs1, rs2, .. } => rs1 == r || rs2 == r,
        Instr::Dotp { rs1, rs2, .. } => rs1 == r || rs2 == r,
        Instr::Sdotp { rd, rs1, rs2, .. } => rd == r || rs1 == r || rs2 == r,
        Instr::MlSdotp { rd, refresh, .. } => {
            rd == r || matches!(refresh, Some((_, p)) if p == r)
        }
        Instr::NnLoad { ptr, .. } => ptr == r,
        Instr::Lw { base, .. } => base == r,
        Instr::Sw { rs, base, .. } => rs == r || base == r,
        Instr::Flw { base, .. } | Instr::Fsw { base, .. } => base == r,
        Instr::FMvToF { rs, .. } => rs == r,
        Instr::Branch { rs1, rs2, .. } => rs1 == r || rs2 == r,
        Instr::HwLoop { count, .. } => count == r,
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{IsaLevel, Prec, Program, ProgramBuilder, Sign};
    use std::sync::Arc;

    struct FlatMem(Vec<u32>);
    impl MemSpace for FlatMem {
        fn load(&mut self, addr: u32) -> Result<u32> {
            Ok(self.0[(addr >> 2) as usize])
        }
        fn store(&mut self, addr: u32, value: u32) -> Result<()> {
            self.0[(addr >> 2) as usize] = value;
            Ok(())
        }
    }

    fn run(prog: Program, mem: &mut FlatMem) -> Core {
        let mut c = Core::new(0, Arc::new(prog));
        for _ in 0..100_000 {
            if c.halted {
                break;
            }
            if c.stall > 0 {
                c.stall -= 1;
                continue;
            }
            c.exec(mem).unwrap();
        }
        assert!(c.halted, "program did not halt");
        c
    }

    #[test]
    fn hw_loop_executes_count_times() {
        let mut b = ProgramBuilder::new("loop", IsaLevel::Xpulp);
        let (s, e) = (b.label(), b.label());
        b.emit(Instr::Li { rd: 5, imm: 10 });
        b.emit(Instr::Li { rd: 6, imm: 0 });
        b.hw_loop(0, 5, s, e);
        b.bind(s);
        b.emit(Instr::AluImm { op: AluOp::Add, rd: 6, rs1: 6, imm: 1 });
        b.bind(e);
        b.emit(Instr::Nop);
        let mut mem = FlatMem(vec![0; 16]);
        let c = run(b.build().unwrap(), &mut mem);
        assert_eq!(c.reg(6), 10);
    }

    #[test]
    fn nested_hw_loops() {
        let mut b = ProgramBuilder::new("nest", IsaLevel::Xpulp);
        let (os, oe) = (b.label(), b.label());
        let (is_, ie) = (b.label(), b.label());
        b.emit(Instr::Li { rd: 5, imm: 3 }); // outer count
        b.emit(Instr::Li { rd: 7, imm: 0 }); // counter
        b.hw_loop(1, 5, os, oe);
        b.bind(os);
        b.emit(Instr::Li { rd: 6, imm: 4 }); // inner count
        b.hw_loop(0, 6, is_, ie);
        b.bind(is_);
        b.emit(Instr::AluImm { op: AluOp::Add, rd: 7, rs1: 7, imm: 1 });
        b.bind(ie);
        b.emit(Instr::Nop); // last instr of outer body
        b.bind(oe);
        b.emit(Instr::Nop);
        let mut mem = FlatMem(vec![0; 16]);
        let c = run(b.build().unwrap(), &mut mem);
        assert_eq!(c.reg(7), 12); // 3 * 4
    }

    #[test]
    fn post_increment_load_walks_array() {
        let mut b = ProgramBuilder::new("pi", IsaLevel::Xpulp);
        b.emit(Instr::Li { rd: 10, imm: 0 }); // ptr
        b.emit(Instr::Li { rd: 11, imm: 0 }); // sum
        for _ in 0..4 {
            b.emit(Instr::Lw { rd: 12, base: 10, offset: 0, post_inc: 4 });
            b.emit(Instr::Alu { op: AluOp::Add, rd: 11, rs1: 11, rs2: 12 });
        }
        let mut mem = FlatMem(vec![5, 6, 7, 8]);
        let c = run(b.build().unwrap(), &mut mem);
        assert_eq!(c.reg(11), 26);
        assert_eq!(c.reg(10), 16);
    }

    #[test]
    fn macload_uses_pre_refresh_operands() {
        // nn0 = 1s vector, refresh nn0 from memory; dotp must use the OLD
        // value in the same cycle.
        let mut b = ProgramBuilder::new("ml", IsaLevel::XpulpNN);
        b.emit(Instr::Li { rd: 10, imm: 0 }); // ptr to new data
        b.emit(Instr::Li { rd: 11, imm: 0 }); // acc
        b.emit(Instr::NnLoad { nn_rd: 0, ptr: 10, post_inc: 0 }); // nn0 = mem[0]
        b.emit(Instr::NnLoad { nn_rd: 1, ptr: 10, post_inc: 0 }); // nn1 = mem[0]
        b.emit(Instr::Li { rd: 10, imm: 4 }); // point at second word
        b.emit(Instr::MlSdotp {
            prec: Prec::B8,
            sign: Sign::SS,
            rd: 11,
            na: 0,
            nb: 1,
            refresh: Some((0, 10)),
        });
        // second mlsdotp sees the refreshed nn0
        b.emit(Instr::MlSdotp {
            prec: Prec::B8,
            sign: Sign::SS,
            rd: 11,
            na: 0,
            nb: 1,
            refresh: None,
        });
        // mem[0] = [1,1,1,1] bytes; mem[1] = [2,2,2,2] bytes
        let mut mem = FlatMem(vec![0x01010101, 0x02020202]);
        let c = run(b.build().unwrap(), &mut mem);
        // first: dot([1;4],[1;4]) = 4; second: dot([2;4],[1;4]) = 8
        assert_eq!(c.reg(11) as i32, 12);
        assert_eq!(c.reg(10), 8); // ptr post-incremented by 4
    }

    #[test]
    fn branch_loop_and_x0() {
        let mut b = ProgramBuilder::new("br", IsaLevel::Xpulp);
        let top = b.label();
        b.emit(Instr::Li { rd: 5, imm: 5 });
        b.emit(Instr::Li { rd: 6, imm: 0 });
        b.bind(top);
        b.emit(Instr::AluImm { op: AluOp::Add, rd: 6, rs1: 6, imm: 2 });
        b.emit(Instr::AluImm { op: AluOp::Add, rd: 5, rs1: 5, imm: -1 });
        b.branch(Cond::Ne, 5, 0, top);
        b.emit(Instr::Li { rd: 0, imm: 99 }); // write to x0 ignored
        let mut mem = FlatMem(vec![0; 4]);
        let c = run(b.build().unwrap(), &mut mem);
        assert_eq!(c.reg(6), 10);
        assert_eq!(c.reg(0), 0);
    }

    #[test]
    fn fp_madd() {
        let mut b = ProgramBuilder::new("fp", IsaLevel::Xpulp);
        b.emit(Instr::Li { rd: 5, imm: 2.5f32.to_bits() as i32 });
        b.emit(Instr::FMvToF { fd: 1, rs: 5 });
        b.emit(Instr::Li { rd: 5, imm: 4.0f32.to_bits() as i32 });
        b.emit(Instr::FMvToF { fd: 2, rs: 5 });
        b.emit(Instr::Li { rd: 5, imm: 1.0f32.to_bits() as i32 });
        b.emit(Instr::FMvToF { fd: 3, rs: 5 });
        b.emit(Instr::FAlu {
            op: FOp::Madd,
            lanes: 1,
            fd: 4,
            fs1: 1,
            fs2: 2,
            fs3: 3,
        });
        b.emit(Instr::FMvToX { rd: 6, fs: 4 });
        let mut mem = FlatMem(vec![0; 4]);
        let c = run(b.build().unwrap(), &mut mem);
        assert_eq!(f32::from_bits(c.reg(6)), 11.0);
    }
}
