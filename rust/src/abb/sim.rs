//! Coupled OCM + ABB-generator simulation over a phased workload —
//! produces the Fig. 11 (1 ms three-phase trace) and Fig. 12 (transition
//! detail) data.
//!
//! Physics of the phase transition (why ABB is errorless, Fig. 5 right):
//! when a compute phase begins, activity ramps through the pipeline over
//! a few microseconds and the *shallower* paths toggle before the deepest
//! ones ([`RAMP_US`], the `rel_cap` ramp). Shallow paths enter the OCM
//! guard band first and trip pre-errors; the generator completes a boost
//! transition (~310 cycles, Fig. 12) before the critical paths are
//! exercised, so no real error ever lands.

use crate::power::{fmax_mhz, OperatingPoint, PowerModel, Workload, FBB_MAX_V};
use crate::util::Rng;

use super::generator::{AbbGenerator, GeneratorConfig};
use super::ocm::OcmBank;

/// Activity/path-depth ramp-in time at a phase transition, microseconds.
pub const RAMP_US: f64 = 5.0;

/// One workload phase of the synthetic benchmark (paper Fig. 11: RBE-
/// centric, data marshaling, RISC-V compute).
#[derive(Debug, Clone)]
pub struct Phase {
    pub name: &'static str,
    pub duration_us: f64,
    /// Probability a monitored endpoint toggles in a control window.
    pub activity: f64,
    /// Deepest relative path depth this phase exercises (the marshaling
    /// phase never toggles the deep DOTP/RBE arithmetic paths).
    pub rel_cap: f64,
    /// Power-model workload class while this phase runs.
    pub workload: Workload,
}

impl Phase {
    /// The paper's three-phase synthetic benchmark, 1 ms total.
    pub fn fig11_benchmark() -> Vec<Phase> {
        vec![
            Phase {
                name: "RBE-accelerated",
                duration_us: 350.0,
                activity: 0.85,
                rel_cap: 1.0,
                workload: Workload::Rbe { duty_pct: 100 },
            },
            Phase {
                name: "data marshaling",
                duration_us: 300.0,
                activity: 0.06,
                rel_cap: 0.85,
                workload: Workload::Marshaling,
            },
            Phase {
                name: "RISC-V compute",
                duration_us: 350.0,
                activity: 0.95,
                rel_cap: 1.0,
                workload: Workload::MatmulMacLoad,
            },
        ]
    }
}

/// One sampled point of the trace.
#[derive(Debug, Clone)]
pub struct TracePoint {
    pub t_us: f64,
    pub fbb_v: f64,
    pub pre_errors: u32,
    pub real_errors: u32,
    pub phase: &'static str,
    pub power_mw: f64,
}

/// Simulation driver.
pub struct AbbSim {
    pub ocm: OcmBank,
    pub gen: AbbGenerator,
    pub vdd: f64,
    pub freq_mhz: f64,
    /// Control window length in cycles.
    pub window_cycles: u64,
    rng: Rng,
}

/// Result of a phased run.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub trace: Vec<TracePoint>,
    pub boost_events: u64,
    pub total_pre_errors: u64,
    pub total_real_errors: u64,
    pub avg_power_mw: f64,
}

impl AbbSim {
    pub fn new(vdd: f64, freq_mhz: f64, abb_enabled: bool) -> Self {
        let mut cfg = GeneratorConfig::default();
        let mut fbb0 = 0.0;
        if abb_enabled {
            // The measured operating points are *settled*: on silicon the
            // clock is raised after the ABB loop locks, so start from the
            // smallest bias that meets timing (max bias if none does).
            while fbb0 < FBB_MAX_V && fmax_mhz(vdd, fbb0) < freq_mhz {
                fbb0 += 0.01;
            }
        } else {
            // generator disabled: zero slew, bias frozen at 0
            cfg.boost_slew_v_per_cycle = 0.0;
            cfg.boost_step_v = 0.0;
        }
        let mut gen = AbbGenerator::new(cfg);
        gen.fbb_v = fbb0;
        Self {
            ocm: OcmBank::new(128, 0xA11CE),
            gen,
            vdd,
            freq_mhz,
            window_cycles: 64,
            rng: Rng::new(0xB0057),
        }
    }

    /// Run the phased benchmark; sample the trace roughly every
    /// `sample_every_us`.
    pub fn run(&mut self, phases: &[Phase], sample_every_us: f64) -> SimResult {
        let model = PowerModel;
        let mut trace = Vec::new();
        let mut t_us = 0.0;
        let window_us = self.window_cycles as f64 / self.freq_mhz;
        let mut since_sample = f64::INFINITY; // force first sample
        let mut total_pre = 0u64;
        let mut total_real = 0u64;
        let mut energy_mw_us = 0.0;
        let mut total_us = 0.0;

        for ph in phases {
            let windows = (ph.duration_us / window_us).ceil() as u64;
            let mut t_in_phase = 0.0;
            for _ in 0..windows {
                // path-depth ramp: shallower logic toggles first
                let progress = (t_in_phase / RAMP_US).min(1.0);
                let cap = ph.rel_cap.min(0.90 + 0.10 * progress);
                let activity = ph.activity * (0.2 + 0.8 * progress);
                let rep = self.ocm.sample(
                    self.vdd,
                    self.gen.fbb_v,
                    self.freq_mhz,
                    activity,
                    cap,
                    &mut self.rng,
                );
                self.gen.step(rep.pre_errors, self.window_cycles);
                total_pre += rep.pre_errors as u64;
                total_real += rep.real_errors as u64;
                let op = OperatingPoint {
                    vdd: self.vdd,
                    freq_mhz: self.freq_mhz,
                    fbb_v: self.gen.fbb_v,
                };
                let p = model.total_mw(ph.workload, &op);
                energy_mw_us += p * window_us;
                total_us += window_us;
                t_us += window_us;
                t_in_phase += window_us;
                since_sample += window_us;
                if since_sample >= sample_every_us {
                    since_sample = 0.0;
                    trace.push(TracePoint {
                        t_us,
                        fbb_v: self.gen.fbb_v,
                        pre_errors: rep.pre_errors,
                        real_errors: rep.real_errors,
                        phase: ph.name,
                        power_mw: p,
                    });
                }
            }
        }
        SimResult {
            trace,
            boost_events: self.gen.boost_events,
            total_pre_errors: total_pre,
            total_real_errors: total_real,
            avg_power_mw: energy_mw_us / total_us,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fig. 11 reproduction: 470 MHz overclock at 0.8 V. With ABB the run
    /// is errorless and the generator boosts during (only) the two
    /// high-activity phases, relaxing through the marshaling phase.
    #[test]
    fn fig11_two_boosts_no_real_errors() {
        let mut sim = AbbSim::new(0.8, 470.0, true);
        let res = sim.run(&Phase::fig11_benchmark(), 5.0);
        assert_eq!(res.total_real_errors, 0, "ABB must prevent errors");
        assert!(res.total_pre_errors > 0);
        assert_eq!(res.boost_events, 2, "one boost per compute phase");
        // bias relaxes during the marshaling phase
        let mid: Vec<_> = res
            .trace
            .iter()
            .filter(|p| p.phase == "data marshaling")
            .collect();
        assert!(
            mid.last().unwrap().fbb_v < mid.first().unwrap().fbb_v - 0.02,
            "no relaxation visible"
        );
    }

    /// Without ABB the same overclock produces real timing errors.
    #[test]
    fn overclock_fails_without_abb() {
        let mut sim = AbbSim::new(0.8, 470.0, false);
        let res = sim.run(&Phase::fig11_benchmark(), 50.0);
        assert!(res.total_real_errors > 0);
        assert_eq!(res.boost_events, 0);
    }

    /// At signoff 400 MHz / 0.8 V the system is clean with or without ABB.
    #[test]
    fn signoff_clean() {
        for abb in [false, true] {
            let mut sim = AbbSim::new(0.8, 400.0, abb);
            let res = sim.run(&Phase::fig11_benchmark(), 50.0);
            assert_eq!(res.total_real_errors, 0, "abb={abb}");
        }
    }

    /// Fig. 10 scenario: 400 MHz at 0.65 V only works with ABB, and burns
    /// less power than the 0.8 V nominal point.
    #[test]
    fn undervolt_needs_abb() {
        let mut with = AbbSim::new(0.65, 400.0, true);
        let r1 = with.run(&Phase::fig11_benchmark(), 50.0);
        assert_eq!(r1.total_real_errors, 0);
        let mut without = AbbSim::new(0.65, 400.0, false);
        let r2 = without.run(&Phase::fig11_benchmark(), 50.0);
        assert!(r2.total_real_errors > 0);
        let mut nom = AbbSim::new(0.8, 400.0, true);
        let p_nom = nom.run(&Phase::fig11_benchmark(), 50.0).avg_power_mw;
        assert!(r1.avg_power_mw < p_nom);
    }

    /// Fig. 12: the boost transition at the compute-phase onset completes
    /// in the ~310-cycle slew the paper measures (~0.66 us at 470 MHz).
    #[test]
    fn boost_transition_duration() {
        let mut sim = AbbSim::new(0.8, 470.0, true);
        let res = sim.run(&Phase::fig11_benchmark(), 0.2);
        // find the start of the RISC-V compute phase and measure how long
        // fbb takes to settle back to its peak
        let compute: Vec<_> = res
            .trace
            .iter()
            .filter(|p| p.phase == "RISC-V compute")
            .collect();
        let start = compute.first().unwrap().t_us;
        let peak = compute
            .iter()
            .map(|p| p.fbb_v)
            .fold(0.0f64, f64::max);
        let settled = compute
            .iter()
            .find(|p| p.fbb_v >= peak - 1e-6)
            .unwrap()
            .t_us;
        let us = settled - start;
        assert!(
            us < 8.0,
            "boost transition took {us:.2} us (ramp + ~0.66 us slew)"
        );
    }
}
