//! The ABB generator hardware control loop (paper §II-C, based on the
//! Moursy et al. regulator): slews the body-bias voltage toward forward
//! bias when pre-errors arrive, relaxes it when the system is quiet.

use crate::power::FBB_MAX_V;

/// Control-loop constants.
#[derive(Debug, Clone)]
pub struct GeneratorConfig {
    /// FBB volts gained per cycle while boosting. A full 0.3 V transition
    /// takes ~310 cycles (paper Fig. 12: ~0.66 µs at 470 MHz).
    pub boost_slew_v_per_cycle: f64,
    /// FBB volts dropped per cycle while relaxing (orders of magnitude
    /// slower — leakage optimization, not timing recovery).
    pub relax_slew_v_per_cycle: f64,
    /// Control windows without pre-errors before relaxation starts.
    pub quiet_windows: u32,
    /// FBB increment requested per pre-error window.
    pub boost_step_v: f64,
}

impl Default for GeneratorConfig {
    fn default() -> Self {
        Self {
            boost_slew_v_per_cycle: 0.3 / 310.0,
            relax_slew_v_per_cycle: 0.9 / 800_000.0,
            quiet_windows: 8,
            boost_step_v: 0.15,
        }
    }
}

/// Discrete-time model of the generator.
#[derive(Debug, Clone)]
pub struct AbbGenerator {
    pub cfg: GeneratorConfig,
    /// Present body-bias output.
    pub fbb_v: f64,
    /// Where the loop is slewing to.
    target_v: f64,
    quiet: u32,
    /// Rising boost transitions observed (Fig. 11 counts these).
    pub boost_events: u64,
    boosting: bool,
}

impl AbbGenerator {
    pub fn new(cfg: GeneratorConfig) -> Self {
        let quiet = cfg.quiet_windows; // start in the relaxed state
        Self {
            cfg,
            fbb_v: 0.0,
            target_v: 0.0,
            quiet,
            boost_events: 0,
            boosting: false,
        }
    }

    /// Advance one control window of `cycles` cycles, with `pre_errors`
    /// reported by the OCMs in that window.
    pub fn step(&mut self, pre_errors: u32, cycles: u64) {
        if pre_errors > 0 {
            // a boost *event* is a wake from a relaxed state (Fig. 11
            // counts two across the trace); corrections while pre-errors
            // keep arriving belong to the same episode
            let woke = self.quiet >= self.cfg.quiet_windows;
            self.quiet = 0;
            let nt = (self.fbb_v + self.cfg.boost_step_v).min(FBB_MAX_V);
            if nt > self.target_v {
                self.target_v = nt;
            }
            if woke && self.target_v > self.fbb_v + 1e-9 {
                self.boosting = true;
                self.boost_events += 1;
            }
        } else {
            self.quiet = self.quiet.saturating_add(1);
            if self.quiet >= self.cfg.quiet_windows {
                // relax: target follows the (slowly dropping) output
                self.target_v = 0.0;
            }
        }
        // Slew the output toward the target.
        let dt = cycles as f64;
        if self.target_v > self.fbb_v {
            self.fbb_v = (self.fbb_v
                + self.cfg.boost_slew_v_per_cycle * dt)
                .min(self.target_v);
            if (self.fbb_v - self.target_v).abs() < 1e-9 {
                self.boosting = false;
            }
        } else {
            self.boosting = false;
            self.fbb_v = (self.fbb_v - self.cfg.relax_slew_v_per_cycle * dt)
                .max(self.target_v.max(0.0));
        }
    }

    /// Cycles a full `delta_v` boost transition takes (Fig. 12).
    pub fn transition_cycles(&self, delta_v: f64) -> u64 {
        (delta_v / self.cfg.boost_slew_v_per_cycle).ceil() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boost_transition_is_about_310_cycles() {
        let g = AbbGenerator::new(GeneratorConfig::default());
        let t = g.transition_cycles(0.3);
        assert!((300..=320).contains(&t), "{t}");
    }

    #[test]
    fn pre_errors_drive_boost_then_quiet_relaxes() {
        let mut g = AbbGenerator::new(GeneratorConfig::default());
        // hammer pre-errors: output should climb towards max
        for _ in 0..100 {
            g.step(4, 64);
        }
        assert!(g.fbb_v > 0.5, "fbb {}", g.fbb_v);
        assert_eq!(g.boost_events, 1); // one continuous boost episode
        let peak = g.fbb_v;
        // long quiet period: relaxes, but much slower than the boost
        for _ in 0..200 {
            g.step(0, 64);
        }
        assert!(g.fbb_v < peak);
        assert!(g.fbb_v > 0.0, "relaxation should be gradual");
        // new pre-error burst: second boost event
        for _ in 0..50 {
            g.step(2, 64);
        }
        assert_eq!(g.boost_events, 2);
    }

    #[test]
    fn clamps_at_fbb_max() {
        let mut g = AbbGenerator::new(GeneratorConfig::default());
        for _ in 0..100_000 {
            g.step(8, 64);
        }
        assert!(g.fbb_v <= FBB_MAX_V + 1e-12);
    }

    #[test]
    fn boost_rate_much_faster_than_relax() {
        let c = GeneratorConfig::default();
        assert!(
            c.boost_slew_v_per_cycle / c.relax_slew_v_per_cycle > 100.0
        );
    }
}
