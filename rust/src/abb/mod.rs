//! On-Chip Monitors + Adaptive Body Biasing (paper §II-C, Figs. 5,
//! 10–12).
//!
//! The chip instruments the 1% most timing-critical register endpoints
//! with shadow-register monitors (OCMs) that raise a *pre-error* when an
//! endpoint's arrival time enters the guard band before the clock edge.
//! A hardware control loop in the ABB generator reacts by slewing the
//! N/P-well bias toward stronger forward body bias (lower V_th, faster
//! logic) and relaxes it when no pre-errors arrive, trading leakage for
//! timing margin on the fly.
//!
//! * [`ocm`] — statistical model of the monitored endpoint population
//!   (path-delay distribution scaled by the f_max(V, FBB) curve) and the
//!   per-cycle pre-error sampling given workload activity.
//! * [`generator`] — the discrete-time control loop (boost slew ≈ 310
//!   cycles per transition, Fig. 12; slow relaxation).
//! * [`sim`] — couples both over a phased workload and records the
//!   Fig. 11/12 traces.

pub mod generator;
pub mod ocm;
pub mod sim;

pub use generator::{AbbGenerator, GeneratorConfig};
pub use ocm::OcmBank;
pub use sim::{AbbSim, Phase, TracePoint};
