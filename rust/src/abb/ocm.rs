//! On-Chip Monitor bank: the monitored near-critical endpoint population.

use crate::power::fmax_mhz;
use crate::util::Rng;

/// Fraction of the clock period used as the OCM guard band (the delay of
/// the shadow-register path; paper Fig. 5 "pre-error delay margins").
/// 4% sits inside the silicon's 5% signoff margin (420 vs 400 MHz), so
/// the signoff point is pre-error-free while undervolt/overclock points
/// trip the monitors before real failures.
pub const GUARD_BAND_FRAC: f64 = 0.04;

/// A bank of OCM-instrumented endpoints. Endpoint `i` has a relative path
/// delay `r_i` (fraction of the critical path); the signoff selection
/// keeps only the worst 1%, so `r_i` concentrates near 1.0.
#[derive(Debug, Clone)]
pub struct OcmBank {
    /// Relative delays in (0.9, 1.0]; the critical path itself is 1.0.
    rel_delay: Vec<f64>,
}

/// What the monitors reported in one sampling window.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OcmReport {
    /// Endpoints that tripped the shadow-register comparison.
    pub pre_errors: u32,
    /// Endpoints that actually missed the clock edge (functional failure —
    /// with ABB active this must stay zero).
    pub real_errors: u32,
}

impl OcmBank {
    /// `n` monitored endpoints (the paper instruments the worst 1% of
    /// endpoints; the absolute number is not disclosed — 128 keeps the
    /// statistics smooth).
    pub fn new(n: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let rel_delay = (0..n)
            .map(|_| {
                // quadratic concentration towards the critical path:
                // u^2 maps U(0,1) mass towards 0 => delays towards 1.0
                let u = rng.f64();
                1.0 - 0.1 * u * u
            })
            .collect();
        Self { rel_delay }
    }

    pub fn len(&self) -> usize {
        self.rel_delay.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rel_delay.is_empty()
    }

    /// Sample one control window: each endpoint is exercised with
    /// probability `activity`; exercised endpoints compare their arrival
    /// time against the guard band.
    ///
    /// `freq_mhz` is the actual clock; path delays scale with
    /// 1/f_max(vdd, fbb) from the calibrated V/f model. `rel_cap` bounds
    /// the relative depth of paths the current workload exercises: light
    /// phases (data marshaling) never toggle the deepest DOTP/RBE paths,
    /// and at a phase transition activity ramps through shallower logic
    /// first — which is precisely why the OCMs catch a pre-error before
    /// any real failure (paper Fig. 5 right).
    pub fn sample(
        &self,
        vdd: f64,
        fbb_v: f64,
        freq_mhz: f64,
        activity: f64,
        rel_cap: f64,
        rng: &mut Rng,
    ) -> OcmReport {
        let period_ns = 1.0e3 / freq_mhz;
        let crit_ns = 1.0e3 / fmax_mhz(vdd, fbb_v);
        let guard = GUARD_BAND_FRAC * period_ns;
        let mut rep = OcmReport::default();
        for &r in &self.rel_delay {
            if r > rel_cap || rng.f64() >= activity {
                continue;
            }
            let d = r * crit_ns;
            if d > period_ns {
                rep.real_errors += 1;
            } else if d > period_ns - guard {
                rep.pre_errors += 1;
            }
        }
        rep
    }

    /// Deterministic worst-case check: would the critical path meet timing?
    pub fn worst_path_ok(&self, vdd: f64, fbb_v: f64, freq_mhz: f64) -> bool {
        1.0e3 / fmax_mhz(vdd, fbb_v) <= 1.0e3 / freq_mhz
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::power::FBB_MAX_V;

    #[test]
    fn delays_concentrate_near_critical() {
        let b = OcmBank::new(1000, 1);
        let near: usize =
            b.rel_delay.iter().filter(|&&r| r > 0.97).count();
        assert!(near > 500, "near-critical fraction too small: {near}");
        assert!(b.rel_delay.iter().all(|&r| (0.9..=1.0).contains(&r)));
    }

    /// At signoff (0.8 V, 400 MHz) there is margin: no errors at all.
    #[test]
    fn clean_at_signoff() {
        let b = OcmBank::new(128, 2);
        let mut rng = Rng::new(3);
        let rep = b.sample(0.8, 0.0, 400.0, 1.0, 1.0, &mut rng);
        assert_eq!(rep, OcmReport::default());
    }

    /// Undervolted to 0.70 V at 400 MHz: real errors without ABB (paper:
    /// SoC stops working below 0.74 V), none with full FBB.
    #[test]
    fn undervolt_errors_without_fbb() {
        let b = OcmBank::new(128, 4);
        let mut rng = Rng::new(5);
        let rep = b.sample(0.70, 0.0, 400.0, 1.0, 1.0, &mut rng);
        assert!(rep.real_errors > 0);
        let rep = b.sample(0.70, FBB_MAX_V, 400.0, 1.0, 1.0, &mut rng);
        assert_eq!(rep.real_errors, 0);
    }

    /// Overclocked to 470 MHz at 0.8 V: pre-errors persist even at full
    /// FBB (the operating point sits inside the guard band) but no real
    /// errors — exactly the Fig. 11 regime.
    #[test]
    fn overclock_sits_in_guard_band() {
        let b = OcmBank::new(128, 6);
        let mut rng = Rng::new(7);
        let rep = b.sample(0.8, FBB_MAX_V, 470.0, 1.0, 1.0, &mut rng);
        assert_eq!(rep.real_errors, 0, "{rep:?}");
        assert!(rep.pre_errors > 0);
    }

    /// Zero activity exercises nothing (the low-intensity phase of
    /// Fig. 11: monitors see no transitions, so no pre-errors).
    #[test]
    fn no_activity_no_errors() {
        let b = OcmBank::new(128, 8);
        let mut rng = Rng::new(9);
        let rep = b.sample(0.65, 0.0, 470.0, 0.0, 1.0, &mut rng);
        assert_eq!(rep, OcmReport::default());
    }
}
