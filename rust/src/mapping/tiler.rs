//! The tiler: split a layer into L1-resident chunks (paper Fig. 16).
//!
//! Strategy (as in DORY): keep weights for a K_out slice plus an input
//! row band and the corresponding output band resident; all three
//! buffers are double-buffered so the cluster DMA can prefetch tile i+1
//! while RBE computes tile i. Tiles shrink first along K_out (to the
//! RBE's 32-channel accumulator granularity), then along output rows (to
//! the 3-row spatial granularity).

use anyhow::{bail, Result};

use crate::cluster::TCDM_SIZE;
use crate::dnn::{Layer, LayerOp};
use crate::rbe::layout;

/// One tile of a layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tile {
    /// Output rows covered.
    pub rows: usize,
    /// Output channels covered.
    pub kout: usize,
    /// Bytes DMA'd in for this tile (input band + weights when fresh).
    pub in_bytes: u64,
    /// Bytes DMA'd out (output band).
    pub out_bytes: u64,
    /// True if this tile needs its weight slice loaded (first row band
    /// of each K_out slice).
    pub loads_weights: bool,
}

/// Tiling decision for one layer.
#[derive(Debug, Clone)]
pub struct LayerTiling {
    pub tiles: Vec<Tile>,
    /// Rows per (full) tile and K_out per tile chosen.
    pub rows_per_tile: usize,
    pub kout_per_tile: usize,
    /// Peak L1 bytes used (both double-buffer halves).
    pub l1_bytes: u64,
}

impl LayerTiling {
    pub fn total_in_bytes(&self) -> u64 {
        self.tiles.iter().map(|t| t.in_bytes).sum()
    }

    pub fn total_out_bytes(&self) -> u64 {
        self.tiles.iter().map(|t| t.out_bytes).sum()
    }
}

/// The tiler itself (holds the budget so tests can shrink it).
#[derive(Debug, Clone)]
pub struct Tiler {
    /// Usable L1 bytes (leave headroom for stacks & normquant params).
    pub l1_budget: u64,
}

impl Default for Tiler {
    fn default() -> Self {
        // 128 KiB minus 8 KiB of runtime reserve.
        Self { l1_budget: TCDM_SIZE as u64 - 8 * 1024 }
    }
}

impl Tiler {
    /// Bytes of one candidate tile set (input band + weights + output
    /// band), single-buffered.
    fn tile_bytes(l: &Layer, rows: usize, kout: usize) -> u64 {
        let h_out = l.h_out();
        let ksz = if l.op == LayerOp::Conv3x3 { 3 } else { 1 };
        let in_rows = (rows - 1) * l.stride + ksz;
        let x = layout::act_bytes(in_rows, l.h, l.cin, l.i_bits);
        let w = match l.op {
            LayerOp::Conv3x3 => layout::weight3x3_bytes(kout, l.cin, l.w_bits),
            _ => layout::weight1x1_bytes(kout, l.cin, l.w_bits),
        };
        let y = layout::act_bytes(rows.min(h_out), h_out, kout, l.o_bits);
        x + w + y + layout::normquant_bytes(kout)
    }

    /// Decide the tiling for an RBE-mapped conv layer.
    pub fn tile(&self, l: &Layer) -> Result<LayerTiling> {
        if !matches!(l.op, LayerOp::Conv3x3 | LayerOp::Conv1x1) {
            bail!("tiler handles conv layers; got {:?}", l.op);
        }
        let h_out = l.h_out();
        let mut kout = l.cout;
        let mut rows = h_out;
        // shrink kout first (32-channel steps), then rows (3-row steps),
        // then below the 32-accumulator granularity (partial K_out tiles
        // under-use the Accum banks but keep the weight slice small —
        // needed by wide layers like ResNet-18 stage4)
        while 2 * Self::tile_bytes(l, rows, kout) > self.l1_budget {
            if kout > 32 {
                kout = (kout / 2).max(32).div_ceil(32) * 32;
            } else if rows > 3 {
                rows = (rows / 2).max(3).div_ceil(3) * 3;
            } else if kout > 8 {
                kout /= 2;
            } else {
                bail!(
                    "layer {} cannot fit TCDM even at minimum tile",
                    l.name
                );
            }
        }
        let mut tiles = Vec::new();
        let mut ko = 0;
        while ko < l.cout {
            let k = kout.min(l.cout - ko);
            let mut r = 0;
            while r < h_out {
                let rr = rows.min(h_out - r);
                let ksz = if l.op == LayerOp::Conv3x3 { 3 } else { 1 };
                let in_rows = (rr - 1) * l.stride + ksz;
                let mut in_bytes =
                    layout::act_bytes(in_rows, l.h, l.cin, l.i_bits);
                let loads_weights = r == 0;
                if loads_weights {
                    in_bytes += match l.op {
                        LayerOp::Conv3x3 => {
                            layout::weight3x3_bytes(k, l.cin, l.w_bits)
                        }
                        _ => layout::weight1x1_bytes(k, l.cin, l.w_bits),
                    } + layout::normquant_bytes(k);
                }
                tiles.push(Tile {
                    rows: rr,
                    kout: k,
                    in_bytes,
                    out_bytes: layout::act_bytes(rr, h_out, k, l.o_bits),
                    loads_weights,
                });
                r += rr;
            }
            ko += k;
        }
        Ok(LayerTiling {
            l1_bytes: 2 * Self::tile_bytes(l, rows, kout),
            rows_per_tile: rows,
            kout_per_tile: kout,
            tiles,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::{resnet20_layers, PrecisionConfig};

    fn conv_layers() -> Vec<Layer> {
        resnet20_layers(PrecisionConfig::Uniform8)
            .into_iter()
            .filter(|l| {
                matches!(l.op, LayerOp::Conv3x3 | LayerOp::Conv1x1)
            })
            .collect()
    }

    #[test]
    fn every_resnet20_layer_fits() {
        let t = Tiler::default();
        for l in conv_layers() {
            let tiling = t.tile(&l).unwrap();
            assert!(
                tiling.l1_bytes <= t.l1_budget,
                "{}: {} B",
                l.name,
                tiling.l1_bytes
            );
            // coverage: rows x kout sums to the full layer
            let total: usize =
                tiling.tiles.iter().map(|t| t.rows * t.kout).sum();
            assert_eq!(total, l.h_out() * l.cout, "{}", l.name);
        }
    }

    #[test]
    fn small_budget_forces_more_tiles() {
        let l = &conv_layers()[1]; // stage1 conv 32x32x16
        let big = Tiler::default().tile(l).unwrap();
        let small = Tiler { l1_budget: 40 * 1024 }.tile(l).unwrap();
        assert!(small.tiles.len() > big.tiles.len());
        assert!(small.l1_bytes <= 40 * 1024);
    }

    #[test]
    fn weights_loaded_once_per_kout_slice() {
        // stage3 conv: 8x8x64 -> 64, big enough to force kout slicing
        let l = conv_layers()
            .into_iter()
            .find(|l| l.name == "stage3.b1.conv0")
            .unwrap();
        let tiling = Tiler { l1_budget: 36 * 1024 }.tile(&l).unwrap();
        let loads = tiling.tiles.iter().filter(|t| t.loads_weights).count();
        let kout_slices = l.cout.div_ceil(tiling.kout_per_tile);
        assert_eq!(loads, kout_slices);
        assert!(kout_slices >= 2, "want actual slicing, got {kout_slices}");
    }

    #[test]
    fn impossible_budget_errors() {
        let l = &conv_layers()[0];
        assert!(Tiler { l1_budget: 512 }.tile(l).is_err());
    }
}
