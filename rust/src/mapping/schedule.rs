//! Layer scheduling + latency/energy roll-up (paper Figs. 17–18).
//!
//! Every layer has three latency components, fully overlapped by double
//! buffering (Fig. 18: "the tallest bar in each group defines the latency
//! of a layer"):
//! * **off-chip** — L3 (HyperRAM) → L2 weight streaming, analytical model;
//! * **on-chip**  — L2 → L1 cluster-DMA traffic of the tile schedule;
//! * **execute**  — RBE (or RISC-V) compute including tiling overhead.

use anyhow::Result;

use crate::cluster::{DmaEngine, IoDma};
use crate::dnn::{Layer, LayerOp};
use crate::power::{OperatingPoint, PowerModel, Workload};
use crate::rbe::{layout, RbeJob, RbeTiming};

use super::tiler::Tiler;

/// Orchestration overhead per offloaded tile (job programming through the
/// peripheral interconnect + event handling), cluster cycles.
const TILE_OVERHEAD_CYCLES: u64 = 180;
/// HyperRAM I/O energy, picojoules per byte (DDR interface + PHY).
const IO_PJ_PER_BYTE: f64 = 120.0;

/// Per-layer report (one group of bars in Figs. 17–18).
#[derive(Debug, Clone)]
pub struct LayerReport {
    pub name: String,
    pub op: LayerOp,
    pub tiles: usize,
    pub off_us: f64,
    pub onchip_us: f64,
    pub exec_us: f64,
    pub latency_us: f64,
    pub energy_uj: f64,
    pub macs: u64,
}

impl LayerReport {
    /// Which component dominates (Fig. 18's red/blue/green labels).
    pub fn bound(&self) -> &'static str {
        if self.off_us >= self.onchip_us && self.off_us >= self.exec_us {
            "off-chip"
        } else if self.onchip_us >= self.exec_us {
            "on-chip"
        } else {
            "compute"
        }
    }
}

/// Whole-network roll-up.
#[derive(Debug, Clone)]
pub struct NetworkReport {
    pub layers: Vec<LayerReport>,
    pub op: OperatingPoint,
}

impl NetworkReport {
    pub fn total_latency_us(&self) -> f64 {
        self.layers.iter().map(|l| l.latency_us).sum()
    }

    pub fn total_energy_uj(&self) -> f64 {
        self.layers.iter().map(|l| l.energy_uj).sum()
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }

    /// Average Top/s/W over the inference.
    pub fn tops_per_w(&self) -> f64 {
        let ops = self.total_macs() as f64 * 2.0;
        let joules = self.total_energy_uj() * 1e-6;
        ops / joules / 1e12
    }

    /// Average Gop/s.
    pub fn gops(&self) -> f64 {
        let ops = self.total_macs() as f64 * 2.0;
        ops / (self.total_latency_us() * 1e-6) / 1e9
    }
}

/// The scheduler: maps layers through the tiler and the timing models.
pub struct Scheduler {
    pub tiler: Tiler,
    pub dma: DmaEngine,
    pub io: IoDma,
    pub power: PowerModel,
    /// 16 cores assisting marshaling / sw layers.
    pub cores: usize,
}

impl Default for Scheduler {
    fn default() -> Self {
        Self {
            tiler: Tiler::default(),
            dma: DmaEngine::default(),
            io: IoDma::default(),
            power: PowerModel,
            cores: 16,
        }
    }
}

impl Scheduler {
    fn conv_job(l: &Layer) -> Result<RbeJob> {
        let h = l.h_out();
        Ok(match l.op {
            LayerOp::Conv3x3 => RbeJob::conv3x3(
                h, h, l.cin, l.cout, l.stride, l.w_bits, l.i_bits, l.o_bits,
            )?,
            LayerOp::Conv1x1 => RbeJob::conv1x1(
                h, h, l.cin, l.cout, l.stride, l.w_bits, l.i_bits, l.o_bits,
            )?,
            LayerOp::Linear | LayerOp::LinearSigned => RbeJob::conv1x1(
                1, 1, l.cin, l.cout, 1, l.w_bits, l.i_bits, l.o_bits,
            )?,
            _ => anyhow::bail!("not an RBE layer"),
        })
    }

    /// Schedule one layer at an operating point.
    pub fn layer_report(
        &self,
        l: &Layer,
        op: &OperatingPoint,
    ) -> Result<LayerReport> {
        let f = op.freq_mhz; // cycles -> us: /f
        match l.op {
            LayerOp::Conv3x3 | LayerOp::Conv1x1 => {
                let tiling = self.tiler.tile(l)?;
                // exec: one RBE job per tile
                let mut exec_cycles = 0u64;
                for t in &tiling.tiles {
                    let job = RbeJob {
                        h_out: t.rows,
                        w_out: l.h_out(),
                        k_out: t.kout,
                        ..Self::conv_job(l)?
                    };
                    exec_cycles +=
                        RbeTiming::cycles(&job) + TILE_OVERHEAD_CYCLES;
                }
                let dma_cycles: u64 = tiling
                    .tiles
                    .iter()
                    .map(|t| {
                        self.dma.cycles_for_bytes(t.in_bytes)
                            + self.dma.cycles_for_bytes(t.out_bytes)
                    })
                    .sum();
                // off-chip: weights stream from L3 once per layer; when
                // the activation working set exceeds the L2 double-buffer
                // budget (ImageNet-scale stage-1 layers), activations
                // spill through L3 too (DORY's outermost tiling level)
                let w_bytes = match l.op {
                    LayerOp::Conv3x3 => {
                        layout::weight3x3_bytes(l.cout, l.cin, l.w_bits)
                    }
                    _ => layout::weight1x1_bytes(l.cout, l.cin, l.w_bits),
                };
                let act_bytes = layout::act_bytes(l.h, l.h, l.cin, l.i_bits)
                    + layout::act_bytes(
                        l.h_out(),
                        l.h_out(),
                        l.cout,
                        l.o_bits,
                    );
                let l3_bytes =
                    if act_bytes > crate::cluster::L2_SIZE as u64 / 2 {
                        w_bytes + act_bytes
                    } else {
                        w_bytes
                    };
                let off_us = self.io.us_for_bytes(l3_bytes);
                let exec_us = exec_cycles as f64 / f;
                let onchip_us = dma_cycles as f64 / f;
                let latency_us = off_us.max(onchip_us).max(exec_us);
                let job = Self::conv_job(l)?;
                let duty =
                    (RbeTiming::binconv_duty(&job) * 100.0).round() as u8;
                let p_exec = self.power.total_mw(
                    Workload::Rbe { duty_pct: duty },
                    op,
                );
                let p_idle = self.power.total_mw(Workload::Idle, op);
                let energy_uj = p_exec * 1e-3 * exec_us
                    + p_idle * 1e-3 * (latency_us - exec_us)
                    + w_bytes as f64 * IO_PJ_PER_BYTE * 1e-6;
                Ok(LayerReport {
                    name: l.name.clone(),
                    op: l.op,
                    tiles: tiling.tiles.len(),
                    off_us,
                    onchip_us,
                    exec_us,
                    latency_us,
                    energy_uj,
                    macs: l.macs(),
                })
            }
            LayerOp::Linear | LayerOp::LinearSigned => {
                let job = Self::conv_job(l)?;
                let exec_cycles =
                    RbeTiming::cycles(&job) + TILE_OVERHEAD_CYCLES;
                let w_bytes =
                    layout::weight1x1_bytes(l.cout, l.cin, l.w_bits);
                let off_us = self.io.us_for_bytes(w_bytes);
                let exec_us = exec_cycles as f64 / f;
                let onchip_us =
                    self.dma.cycles_for_bytes(w_bytes) as f64 / f;
                let latency_us = off_us.max(onchip_us).max(exec_us);
                let p = self.power.total_mw(
                    Workload::Rbe { duty_pct: 50 },
                    op,
                );
                Ok(LayerReport {
                    name: l.name.clone(),
                    op: l.op,
                    tiles: 1,
                    off_us,
                    onchip_us,
                    exec_us,
                    latency_us,
                    energy_uj: p * 1e-3 * latency_us
                        + w_bytes as f64 * IO_PJ_PER_BYTE * 1e-6,
                    macs: l.macs(),
                })
            }
            LayerOp::Add | LayerOp::AvgPool => {
                // runs on the cores: ~1 cycle/lane-word/core + marshaling
                // between the RBE bit-plane layout and the byte layout
                let elems = l.out_elems().max(l.h * l.h * l.cin);
                let words = elems.div_ceil(4) as u64;
                let exec_cycles =
                    words * 4 / self.cores as u64 + TILE_OVERHEAD_CYCLES;
                let exec_us = exec_cycles as f64 / f;
                // on-chip: operands move L2->L1 and the result back
                let n_in = if l.op == LayerOp::Add { 2 } else { 1 };
                let bytes =
                    ((n_in * elems * l.i_bits + l.out_elems() * l.o_bits)
                        / 8) as u64;
                let onchip_us =
                    self.dma.cycles_for_bytes(bytes) as f64 / f;
                // off-chip: the residual shortcut tensor was evicted to
                // L3 under L2 double-buffering pressure and streams back
                // (the DORY policy behind Fig. 18's off-chip-bound adds)
                let off_us = if l.op == LayerOp::Add {
                    self.io.us_for_bytes(
                        (l.h * l.h * l.cin * l.i_bits / 8) as u64,
                    )
                } else {
                    0.0
                };
                let latency_us = off_us.max(onchip_us).max(exec_us);
                let p = self.power.total_mw(Workload::Marshaling, op);
                Ok(LayerReport {
                    name: l.name.clone(),
                    op: l.op,
                    tiles: 1,
                    off_us,
                    onchip_us,
                    exec_us,
                    latency_us,
                    energy_uj: p * 1e-3 * latency_us,
                    macs: 0,
                })
            }
        }
    }

    /// Schedule a whole network.
    pub fn network_report(
        &self,
        layers: &[Layer],
        op: &OperatingPoint,
    ) -> Result<NetworkReport> {
        let mut reports = Vec::with_capacity(layers.len());
        for l in layers {
            reports.push(self.layer_report(l, op)?);
        }
        Ok(NetworkReport { layers: reports, op: *op })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::{resnet18_layers, resnet20_layers, PrecisionConfig};
    use crate::power::OperatingPoint;

    #[test]
    fn resnet20_schedules_at_all_operating_points() {
        let s = Scheduler::default();
        for cfg in [PrecisionConfig::Uniform8, PrecisionConfig::Mixed] {
            for vdd in [0.5, 0.65, 0.8] {
                let rep = s
                    .network_report(
                        &resnet20_layers(cfg),
                        &OperatingPoint::at_vdd(vdd),
                    )
                    .unwrap();
                assert!(rep.total_latency_us() > 0.0);
                assert!(rep.total_energy_uj() > 0.0);
            }
        }
    }

    /// Paper §IV: mixed precision saves ~68% of execution energy vs the
    /// 8-bit configuration at nominal voltage (we assert a deep cut).
    #[test]
    fn mixed_precision_energy_saving() {
        let s = Scheduler::default();
        let op = OperatingPoint::nominal();
        let e8 = s
            .network_report(
                &resnet20_layers(PrecisionConfig::Uniform8),
                &op,
            )
            .unwrap()
            .total_energy_uj();
        let em = s
            .network_report(&resnet20_layers(PrecisionConfig::Mixed), &op)
            .unwrap()
            .total_energy_uj();
        let saving = 1.0 - em / e8;
        assert!(
            (0.50..0.80).contains(&saving),
            "mixed saves {saving:.2} (paper: 0.68); e8={e8:.1} em={em:.1}"
        );
    }

    /// Paper §IV energy *shape*: voltage scaling from 0.8 V to 0.5 V cuts
    /// inference energy by ~2.3× (paper: 28 µJ → 12 µJ). Absolute values
    /// sit ~1.8× above the paper because our RBE model charges full
    /// 32-channel FSM granularity on the low-utilization stage-1 layers —
    /// see EXPERIMENTS.md.
    #[test]
    fn resnet20_energy_anchors() {
        let s = Scheduler::default();
        let layers = resnet20_layers(PrecisionConfig::Mixed);
        let e_nom = s
            .network_report(&layers, &OperatingPoint::nominal())
            .unwrap()
            .total_energy_uj();
        let e_low = s
            .network_report(&layers, &OperatingPoint::at_vdd(0.5))
            .unwrap()
            .total_energy_uj();
        let ratio = e_nom / e_low;
        assert!(
            (1.7..3.2).contains(&ratio),
            "0.8V/0.5V energy ratio {ratio:.2} (paper ~2.3): \
             {e_nom:.1} -> {e_low:.1} uJ"
        );
        // and the absolute magnitude is tens of microjoules, not hundreds
        assert!(
            (15.0..120.0).contains(&e_nom),
            "mixed @0.8V: {e_nom:.1} uJ (paper ~28)"
        );
    }

    /// Table II latency *shape* at the 0.5 V best-efficiency point:
    /// ResNet-18/ResNet-20 ratio ~45× (paper: 48 ms / 1.05 ms), with
    /// ResNet-18 inside the paper's magnitude band.
    #[test]
    fn table2_latency_anchors() {
        let s = Scheduler::default();
        let op = OperatingPoint::at_vdd(0.5);
        let r20 = s
            .network_report(&resnet20_layers(PrecisionConfig::Mixed), &op)
            .unwrap();
        let ms = r20.total_latency_us() / 1000.0;
        assert!((0.8..4.0).contains(&ms), "ResNet-20 {ms:.2} ms (paper 1.05)");
        let r18 = s.network_report(&resnet18_layers(), &op).unwrap();
        let ms18 = r18.total_latency_us() / 1000.0;
        assert!((25.0..75.0).contains(&ms18),
                "ResNet-18 {ms18:.1} ms (paper 48)");
        assert!(ms18 / ms > 10.0, "relative scale {}", ms18 / ms);
    }

    /// Every registry network (incl. the signed-head KWS net) schedules
    /// cleanly under both precision configurations.
    #[test]
    fn every_registry_network_schedules() {
        let s = Scheduler::default();
        let op = OperatingPoint::nominal();
        for net in crate::dnn::registry::NETWORKS {
            for cfg in [PrecisionConfig::Uniform8, PrecisionConfig::Mixed] {
                let rep = s.network_report(&net.layers(cfg), &op).unwrap();
                assert!(rep.total_latency_us() > 0.0, "{}", net.id);
                assert!(rep.total_energy_uj() > 0.0, "{}", net.id);
            }
        }
    }

    /// Fig. 18: the three bound classes all occur across the network.
    #[test]
    fn bound_classes_present() {
        let s = Scheduler::default();
        let rep = s
            .network_report(
                &resnet20_layers(PrecisionConfig::Mixed),
                &OperatingPoint::at_vdd(0.5),
            )
            .unwrap();
        let bounds: std::collections::HashSet<_> =
            rep.layers.iter().map(|l| l.bound()).collect();
        assert!(bounds.contains("compute"), "{bounds:?}");
        assert!(bounds.len() >= 2, "{bounds:?}");
    }
}
