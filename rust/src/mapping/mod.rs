//! DORY-style deployment mapping (paper §IV, Figs. 16–18): tile each
//! layer between the memory hierarchy levels, double-buffer DMA against
//! compute, and roll up per-layer latency/energy.

mod schedule;
mod tiler;

pub use schedule::{LayerReport, NetworkReport, Scheduler};
pub use tiler::{LayerTiling, Tile, Tiler};
