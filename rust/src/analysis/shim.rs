//! Instrumented drop-in replacements for `std::sync::{Mutex, Condvar}`
//! and `AtomicUsize`, active only under
//! `cfg(any(test, feature = "interleave"))` via the
//! [`crate::analysis::sync`] façade.
//!
//! Outside an exploration (no thread-local scheduler context) every
//! operation delegates straight to `std` — normal tests and production
//! code pay one thread-local read per lock op and behave identically.
//! Inside an exploration each operation becomes a *yield point*: the
//! shim first acquires/releases/waits **virtually** through the
//! [`explore`] scheduler, and only then touches the real primitive.
//!
//! The invariant that keeps this sound: a model thread takes the inner
//! `std` mutex only after its virtual acquisition succeeded, so the
//! real lock is always uncontended (std-held ⊆ virtually-held) and no
//! model thread ever blocks in the OS where the serialized scheduler
//! cannot see it.

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicUsize as StdAtomicUsize, Ordering};
use std::sync::{
    Condvar as StdCondvar, LockResult, Mutex as StdMutex,
    MutexGuard as StdMutexGuard, PoisonError,
};

use super::explore::{current, next_obj_id};

/// A `std::sync::Mutex` that reports its lock/unlock edges to the
/// interleaving explorer when one is active.
pub struct Mutex<T: ?Sized> {
    id: usize,
    inner: StdMutex<T>,
}

impl<T> Mutex<T> {
    /// Create a new instrumented mutex.
    pub fn new(value: T) -> Self {
        Self { id: next_obj_id(), inner: StdMutex::new(value) }
    }
}

impl<T: ?Sized> Mutex<T> {
    /// Acquire, virtually first when a model context is active. Poison
    /// is surfaced exactly like `std` (the guard rides inside the
    /// error).
    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        let virtual_held = if let Some((sched, me)) = current() {
            sched.acquire(me, self.id, "lock");
            true
        } else {
            false
        };
        // Under a model context the inner lock is uncontended by the
        // std-held ⊆ virtually-held invariant, so this never blocks the
        // OS thread outside the scheduler's sight.
        match self.inner.lock() {
            Ok(inner) => Ok(MutexGuard {
                lock: self,
                inner: Some(inner),
                virtual_held,
            }),
            Err(poison) => Err(PoisonError::new(MutexGuard {
                lock: self,
                inner: Some(poison.into_inner()),
                virtual_held,
            })),
        }
    }
}

impl<T: ?Sized + fmt::Debug> fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Mutex").field("inner", &self.inner).finish()
    }
}

/// Guard for [`Mutex`]; releases virtually (a scheduler yield point)
/// after dropping the real guard.
pub struct MutexGuard<'a, T: ?Sized> {
    lock: &'a Mutex<T>,
    inner: Option<StdMutexGuard<'a, T>>,
    virtual_held: bool,
}

impl<T: ?Sized> Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard holds until drop")
    }
}

impl<T: ?Sized> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard holds until drop")
    }
}

impl<T: ?Sized> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        // Real release strictly before the virtual one: once the
        // scheduler hands the lock to another model thread, the std
        // mutex must already be free.
        drop(self.inner.take());
        if self.virtual_held {
            if let Some((sched, me)) = current() {
                sched.release(me, self.lock.id);
            }
        }
    }
}

impl<T: ?Sized + fmt::Debug> fmt::Debug for MutexGuard<'_, T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&**self, f)
    }
}

/// A `std::sync::Condvar` that routes wait/notify through the explorer
/// when a model context is active (no spurious wakeups in model mode —
/// every caller in the tree loops on its condition anyway).
pub struct Condvar {
    id: usize,
    inner: StdCondvar,
}

impl Condvar {
    /// Create a new instrumented condvar.
    pub fn new() -> Self {
        Self { id: next_obj_id(), inner: StdCondvar::new() }
    }

    /// Wait on this condvar, releasing `guard`'s mutex for the
    /// duration; the returned guard holds the mutex again.
    pub fn wait<'a, T: ?Sized>(
        &self,
        mut guard: MutexGuard<'a, T>,
    ) -> LockResult<MutexGuard<'a, T>> {
        if let Some((sched, me)) = current() {
            let lock = guard.lock;
            // Hand the real+virtual lock back without the guard's Drop
            // scheduling a release yield point: cond_wait models the
            // release+sleep as one atomic step, like the real condvar.
            guard.virtual_held = false;
            drop(guard.inner.take());
            drop(guard);
            sched.cond_wait(me, self.id, lock.id);
            // Woken: contend for the lock again (a fresh decision).
            sched.acquire(me, lock.id, "relock after wait");
            return match lock.inner.lock() {
                Ok(inner) => Ok(MutexGuard {
                    lock,
                    inner: Some(inner),
                    virtual_held: true,
                }),
                Err(poison) => Err(PoisonError::new(MutexGuard {
                    lock,
                    inner: Some(poison.into_inner()),
                    virtual_held: true,
                })),
            };
        }
        let lock = guard.lock;
        let inner = guard.inner.take().expect("guard holds until drop");
        std::mem::forget(guard);
        match self.inner.wait(inner) {
            Ok(inner) => Ok(MutexGuard {
                lock,
                inner: Some(inner),
                virtual_held: false,
            }),
            Err(poison) => Err(PoisonError::new(MutexGuard {
                lock,
                inner: Some(poison.into_inner()),
                virtual_held: false,
            })),
        }
    }

    /// Wait with a timeout. Unlike `std`, the timed-out flag is not
    /// returned (`std::sync::WaitTimeoutResult` has no public
    /// constructor, so the shim could not fabricate one in model
    /// mode); every caller in the tree re-checks its condition under
    /// the lock anyway. In model mode the wait is modeled as an
    /// *immediate timeout* — release, one yield point, re-acquire —
    /// because virtual time does not advance inside an exploration and
    /// a modeled sleep would just be a lost-wakeup false positive.
    pub fn wait_timeout<'a, T: ?Sized>(
        &self,
        mut guard: MutexGuard<'a, T>,
        dur: std::time::Duration,
    ) -> LockResult<MutexGuard<'a, T>> {
        if let Some((sched, me)) = current() {
            let lock = guard.lock;
            guard.virtual_held = false;
            drop(guard.inner.take());
            drop(guard);
            sched.release(me, lock.id);
            sched.yield_point(me, "timed wait (modeled as immediate timeout)");
            sched.acquire(me, lock.id, "relock after timed wait");
            return match lock.inner.lock() {
                Ok(inner) => Ok(MutexGuard {
                    lock,
                    inner: Some(inner),
                    virtual_held: true,
                }),
                Err(poison) => Err(PoisonError::new(MutexGuard {
                    lock,
                    inner: Some(poison.into_inner()),
                    virtual_held: true,
                })),
            };
        }
        let lock = guard.lock;
        let inner = guard.inner.take().expect("guard holds until drop");
        std::mem::forget(guard);
        match self.inner.wait_timeout(inner, dur) {
            Ok((inner, _timed_out)) => Ok(MutexGuard {
                lock,
                inner: Some(inner),
                virtual_held: false,
            }),
            Err(poison) => {
                let (inner, _timed_out) = poison.into_inner();
                Err(PoisonError::new(MutexGuard {
                    lock,
                    inner: Some(inner),
                    virtual_held: false,
                }))
            }
        }
    }

    /// Wake all waiters.
    pub fn notify_all(&self) {
        if let Some((sched, me)) = current() {
            sched.notify_all(me, self.id);
        }
        self.inner.notify_all();
    }

    /// Wake one waiter (which one is a scheduling decision in model
    /// mode).
    pub fn notify_one(&self) {
        if let Some((sched, me)) = current() {
            sched.notify_one(me, self.id);
        }
        self.inner.notify_one();
    }
}

impl Default for Condvar {
    fn default() -> Self {
        Self::new()
    }
}

impl fmt::Debug for Condvar {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Condvar").finish_non_exhaustive()
    }
}

/// An `AtomicUsize` whose every operation is a yield point in model
/// mode, so races on lock-free counters (like the reclaim barrier's
/// `done`) are explorable. Sequentially consistent under the model —
/// serialized execution cannot express weak orderings; Miri/TSan cover
/// that axis.
#[derive(Debug)]
pub struct AtomicUsize {
    inner: StdAtomicUsize,
}

impl AtomicUsize {
    /// Create a new instrumented atomic.
    pub const fn new(v: usize) -> Self {
        Self { inner: StdAtomicUsize::new(v) }
    }

    fn hook(&self, what: &str) {
        if let Some((sched, me)) = current() {
            sched.yield_point(me, what);
        }
    }

    /// Load (yield point in model mode).
    pub fn load(&self, order: Ordering) -> usize {
        self.hook("atomic load");
        self.inner.load(order)
    }

    /// Store (yield point in model mode).
    pub fn store(&self, v: usize, order: Ordering) {
        self.hook("atomic store");
        self.inner.store(v, order)
    }

    /// Atomic add returning the previous value (yield point in model
    /// mode; the read-modify-write itself stays indivisible).
    pub fn fetch_add(&self, v: usize, order: Ordering) -> usize {
        self.hook("atomic fetch_add");
        self.inner.fetch_add(v, order)
    }
}
