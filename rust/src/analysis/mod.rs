//! Concurrency-correctness harness: the sync façade and the
//! deterministic interleaving explorer.
//!
//! The serving stack rests on hand-rolled concurrency — the
//! work-stealing global runtime's task-reclaim barrier (a protocol
//! that soundly erases a `'env` lifetime with one `unsafe transmute`)
//! and the gateway's ticket/queue coordination. This module gives
//! those protocols a mechanized checking layer, the software analogue
//! of the on-chip monitoring blocks the Marsellus SoC bakes into
//! silicon: a system pushed to its operating limits needs continuous
//! self-checking, not spot audits.
//!
//! Three layers, each catching what the others cannot:
//!
//! * **`sync`** — the façade the runtime and gateway lock through.
//!   `std::sync` in normal builds; instrumented shims under
//!   `cfg(any(test, feature = "interleave"))`. Also home of the
//!   poison-recovery helpers (`lock_recover`, `wait_recover`).
//! * **`explore`** (same cfg) — a bounded, seeded schedule explorer
//!   (mini-loom) that runs 2–4 model threads through every reachable
//!   interleaving of their lock/condvar/atomic operations, with DFS
//!   replay, a preemption bound, and deadlock/live-lock detection.
//!   `rust/tests/interleave.rs` drives the reclaim, ticket, shutdown
//!   and pop-order protocols through it.
//! * **`failpoint`** (under `cfg(any(test, feature = "chaos"))`) —
//!   deterministic fault injection: named sites in the gateway and
//!   runtime that tests and `marsellus serve --chaos` arm to panic,
//!   delay, or force a shed exactly where a real fault would land.
//!   The explorer proves protocols correct under every schedule; the
//!   failpoints prove the *recovery* paths (panicked request, shed
//!   deadline, cancel race) are actually reachable and leave the
//!   telemetry reconciled.
//! * **CI lanes outside this module** — `cargo miri test` (UB on the
//!   transmute-bearing paths) and ThreadSanitizer (real weak-memory
//!   races the serialized explorer cannot express), plus
//!   `ci/lint_invariants.py` (SAFETY comments, thread containment,
//!   gateway unwrap ban, façade bypass, failpoint release gating).

pub mod sync;

#[cfg(any(test, feature = "interleave"))]
pub mod explore;

#[cfg(any(test, feature = "interleave"))]
mod shim;

#[cfg(any(test, feature = "chaos"))]
pub mod failpoint;

/// Probe a named failpoint site: panic or delay there when a test or
/// `--chaos` run armed it. Expands to nothing in builds without the
/// harness, so production binaries carry no site lookups at all.
#[macro_export]
macro_rules! failpoint {
    ($site:expr) => {{
        #[cfg(any(test, feature = "chaos"))]
        $crate::analysis::failpoint::fire($site);
        #[cfg(not(any(test, feature = "chaos")))]
        let _ = $site;
    }};
}

/// Probe a named failpoint site for a forced-shed decision; evaluates
/// to `false` (no shed) in builds without the harness.
#[macro_export]
macro_rules! failpoint_shed {
    ($site:expr) => {{
        #[cfg(any(test, feature = "chaos"))]
        {
            $crate::analysis::failpoint::should_shed($site)
        }
        #[cfg(not(any(test, feature = "chaos")))]
        {
            let _ = $site;
            false
        }
    }};
}
