//! Concurrency-correctness harness: the sync façade and the
//! deterministic interleaving explorer.
//!
//! The serving stack rests on hand-rolled concurrency — the
//! work-stealing global runtime's task-reclaim barrier (a protocol
//! that soundly erases a `'env` lifetime with one `unsafe transmute`)
//! and the gateway's ticket/queue coordination. This module gives
//! those protocols a mechanized checking layer, the software analogue
//! of the on-chip monitoring blocks the Marsellus SoC bakes into
//! silicon: a system pushed to its operating limits needs continuous
//! self-checking, not spot audits.
//!
//! Three layers, each catching what the others cannot:
//!
//! * **`sync`** — the façade the runtime and gateway lock through.
//!   `std::sync` in normal builds; instrumented shims under
//!   `cfg(any(test, feature = "interleave"))`. Also home of the
//!   poison-recovery helpers (`lock_recover`, `wait_recover`).
//! * **`explore`** (same cfg) — a bounded, seeded schedule explorer
//!   (mini-loom) that runs 2–4 model threads through every reachable
//!   interleaving of their lock/condvar/atomic operations, with DFS
//!   replay, a preemption bound, and deadlock/live-lock detection.
//!   `rust/tests/interleave.rs` drives the reclaim, ticket, shutdown
//!   and pop-order protocols through it.
//! * **CI lanes outside this module** — `cargo miri test` (UB on the
//!   transmute-bearing paths) and ThreadSanitizer (real weak-memory
//!   races the serialized explorer cannot express), plus
//!   `ci/lint_invariants.py` (SAFETY comments, thread containment,
//!   gateway unwrap ban, façade bypass).

pub mod sync;

#[cfg(any(test, feature = "interleave"))]
pub mod explore;

#[cfg(any(test, feature = "interleave"))]
mod shim;
