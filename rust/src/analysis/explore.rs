//! Deterministic interleaving explorer — a bounded, seeded mini-loom
//! built in-repo (vendored deps are only `anyhow` + the xla stub, so no
//! external model checker).
//!
//! A *model* is a closure that spawns 2–4 model threads ([`spawn`]) and
//! coordinates them through the instrumented sync shims
//! (`analysis::sync` under `cfg(any(test, feature = "interleave"))`).
//! [`explore`] runs the model under every schedule a bounded DFS can
//! reach: model threads are real OS threads, but exactly one runs at a
//! time, and at every *yield point* (lock acquire/release, condvar
//! wait/notify, atomic op, [`yield_now`]) the scheduler picks which
//! thread continues. Each run records its decision sequence; the next
//! run replays a prefix and takes the first unexplored branch —
//! loom-style stateless DFS with replay.
//!
//! Bounds that keep the search tractable:
//!
//! * **Preemption bound** ([`ExploreOpts::preemption_bound`]): at most
//!   N involuntary switches away from a runnable thread per schedule.
//!   Most concurrency bugs need 1–2 preemptions (the classic result
//!   behind CHESS-style bounded search), so a small bound finds them
//!   while cutting the schedule space from exponential to polynomial.
//! * **Schedule budget** ([`ExploreOpts::max_schedules`]): DFS stops
//!   after this many runs even with branches left ([`ExploreReport`]
//!   says whether the space was exhausted).
//! * **Step limit** ([`ExploreOpts::max_steps`]): a schedule that keeps
//!   yielding without finishing (live-lock, unfair spin) fails loudly
//!   instead of hanging the suite.
//! * **Seeded mode** ([`ExploreOpts::seed`]): instead of DFS, run
//!   `max_schedules` independent schedules driven by a seeded xoshiro
//!   PRNG — a cheap way to smoke much larger models where DFS cannot
//!   finish any interesting prefix.
//!
//! Failures — a panicked model thread, a deadlock (no runnable thread
//! while some are blocked), or a step-limit hit — abort the exploration
//! and report the full decision trace of the failing schedule, so a
//! finding is a *reproducible* schedule, not a flaky observation.
//!
//! What is modeled: mutexes (without reentrancy), condvars (without
//! spurious wakeups — every user in the tree loops on its condition
//! anyway, and the explorer's job is finding *ordering* bugs), atomics
//! (sequentially consistent — serialized execution cannot model weak
//! memory; TSan and Miri cover that axis in CI), and thread join.
//! Model threads must not block through any other channel.

use std::any::Any;
use std::cell::RefCell;
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar as StdCondvar, Mutex as StdMutex};

use crate::util::Rng;

/// Sentinel for "no thread scheduled" (run over / failure).
const DONE: usize = usize::MAX;

/// Bounds for one [`explore`] call. The defaults exhaust small models
/// (2–3 threads, a handful of yield points each) and stay under a
/// second even for branchy ones.
#[derive(Debug, Clone)]
pub struct ExploreOpts {
    /// Maximum schedules to run; DFS stops here even with branches
    /// left.
    pub max_schedules: usize,
    /// Maximum involuntary context switches per schedule.
    pub preemption_bound: usize,
    /// Per-schedule yield-point limit (live-lock guard).
    pub max_steps: usize,
    /// `Some(seed)`: seeded random walk instead of exhaustive DFS.
    pub seed: Option<u64>,
}

impl Default for ExploreOpts {
    fn default() -> Self {
        Self {
            max_schedules: 4096,
            preemption_bound: 2,
            max_steps: 20_000,
            seed: None,
        }
    }
}

/// What an exploration covered (returned on success).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExploreReport {
    /// Schedules actually run.
    pub schedules: usize,
    /// Whether the bounded schedule space was fully explored (always
    /// `false` in seeded mode).
    pub exhausted: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum TState {
    Runnable,
    /// Blocked acquiring the model lock with this id.
    Lock(usize),
    /// Waiting on the model condvar with this id.
    Cond(usize),
    /// Waiting for this thread id to finish.
    Join(usize),
    Finished,
}

/// One recorded scheduling (or notify-victim) decision.
#[derive(Debug, Clone, Copy)]
struct Decision {
    chosen: usize,
    options: usize,
    /// Forced decisions (single option, preemption bound hit, seeded
    /// mode) are not DFS branch points.
    forced: bool,
}

struct Core {
    states: Vec<TState>,
    running: usize,
    /// Model lock id -> holding thread id (absent = free).
    holders: HashMap<usize, usize>,
    trace: Vec<String>,
    decisions: Vec<Decision>,
    preemptions: usize,
    steps: usize,
    failure: Option<String>,
    rng: Option<Rng>,
}

/// Panic payload used to unwind model threads once a failure is
/// recorded; never surfaces to the user.
struct ExplorerAbort;

pub(crate) struct Scheduler {
    core: StdMutex<Core>,
    cv: StdCondvar,
    prefix: Vec<usize>,
    preemption_bound: usize,
    max_steps: usize,
    handles: StdMutex<Vec<std::thread::JoinHandle<()>>>,
}

thread_local! {
    /// The active exploration this thread is a model thread of, if any.
    static CTX: RefCell<Option<(Arc<Scheduler>, usize)>> =
        const { RefCell::new(None) };
}

/// The current thread's model context (`None` outside an exploration —
/// the sync shims then delegate straight to `std`).
pub(crate) fn current() -> Option<(Arc<Scheduler>, usize)> {
    CTX.with(|c| c.borrow().clone())
}

fn set_ctx(ctx: Option<(Arc<Scheduler>, usize)>) {
    CTX.with(|c| *c.borrow_mut() = ctx);
}

/// Fresh unique id for a shim object (mutex/condvar); uniqueness is all
/// that matters, ids are only resource keys inside one schedule.
pub(crate) fn next_obj_id() -> usize {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static NEXT: AtomicUsize = AtomicUsize::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

fn payload_str(p: &(dyn Any + Send)) -> String {
    p.downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| p.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".into())
}

impl Scheduler {
    fn new(opts: &ExploreOpts, prefix: Vec<usize>, iter: usize) -> Self {
        Self {
            core: StdMutex::new(Core {
                states: vec![TState::Runnable],
                running: 0,
                holders: HashMap::new(),
                trace: Vec::new(),
                decisions: Vec::new(),
                preemptions: 0,
                steps: 0,
                failure: None,
                rng: opts.seed.map(|s| {
                    Rng::new(s ^ (iter as u64).wrapping_mul(0x9E3779B97F4A7C15))
                }),
            }),
            cv: StdCondvar::new(),
            prefix,
            preemption_bound: opts.preemption_bound,
            max_steps: opts.max_steps,
            handles: StdMutex::new(Vec::new()),
        }
    }

    /// Panic out of a model thread once the exploration has failed —
    /// unless already unwinding (drop paths must not double-panic).
    fn abort(&self) {
        if !std::thread::panicking() {
            std::panic::panic_any(ExplorerAbort);
        }
    }

    fn fail_locked(&self, core: &mut Core, msg: String) {
        if core.failure.is_none() {
            let tail: Vec<&str> = core
                .trace
                .iter()
                .rev()
                .take(120)
                .map(String::as_str)
                .collect();
            let tail: Vec<&str> = tail.into_iter().rev().collect();
            core.failure = Some(format!(
                "{msg}\nlast {} schedule step(s):\n{}",
                tail.len(),
                tail.join("\n")
            ));
        }
        core.running = DONE;
        self.cv.notify_all();
    }

    /// Record a decision among `options` choices and return the chosen
    /// index: replayed from the prefix, drawn from the seeded RNG, or
    /// defaulting to 0 (DFS explores the rest by prefix increment).
    fn decide(&self, core: &mut Core, options: usize, can_branch: bool) -> usize {
        let k = core.decisions.len();
        let idx = if options == 1 {
            0
        } else if k < self.prefix.len() {
            self.prefix[k]
        } else if let Some(rng) = core.rng.as_mut() {
            (rng.next_u64() % options as u64) as usize
        } else {
            0
        };
        if idx >= options {
            self.fail_locked(
                core,
                format!(
                    "schedule replay diverged at decision {k}: prefix \
                     chose {idx} of {options} options — the model is \
                     nondeterministic (wall clock, hash order, real \
                     threads?)"
                ),
            );
            return 0;
        }
        let forced = options == 1
            || core.rng.is_some()
            || (!can_branch && k >= self.prefix.len());
        core.decisions.push(Decision { chosen: idx, options, forced });
        idx
    }

    /// Hand the CPU to the next thread: the scheduling decision at the
    /// heart of the explorer. `from` is the thread giving up control
    /// (it may itself still be runnable — staying with it is the
    /// default, switching away is a preemption).
    fn pick(&self, core: &mut Core, from: usize) {
        core.steps += 1;
        if core.steps > self.max_steps {
            self.fail_locked(
                core,
                format!(
                    "step limit {} exceeded — model live-locks or spins \
                     without a condvar",
                    self.max_steps
                ),
            );
            return;
        }
        if core.failure.is_some() {
            core.running = DONE;
            self.cv.notify_all();
            return;
        }
        let mut options: Vec<usize> = (0..core.states.len())
            .filter(|&t| core.states[t] == TState::Runnable)
            .collect();
        if options.is_empty() {
            if core.states.iter().all(|s| *s == TState::Finished) {
                core.running = DONE;
                self.cv.notify_all();
                return;
            }
            let states = core.states.clone();
            self.fail_locked(
                core,
                format!("deadlock: no runnable thread; states: {states:?}"),
            );
            return;
        }
        // "Continue with the yielding thread" is option 0 when legal, so
        // the DFS base schedule is switch-free and every alternative is
        // an explicit preemption.
        let from_runnable =
            from != DONE && core.states.get(from) == Some(&TState::Runnable);
        if from_runnable {
            let p = options
                .iter()
                .position(|&t| t == from)
                .expect("runnable `from` is an option");
            options.remove(p);
            options.insert(0, from);
        }
        let can_branch =
            !(from_runnable && core.preemptions >= self.preemption_bound);
        let idx = self.decide(core, options.len(), can_branch);
        if core.failure.is_some() {
            return;
        }
        let chosen = options[idx];
        if from_runnable && chosen != from {
            core.preemptions += 1;
        }
        core.running = chosen;
        self.cv.notify_all();
    }

    /// Park the calling thread until it is scheduled (or the
    /// exploration fails, in which case it unwinds).
    fn wait_turn<'a>(
        &'a self,
        mut core: std::sync::MutexGuard<'a, Core>,
        me: usize,
    ) {
        loop {
            if core.failure.is_some() {
                drop(core);
                self.abort();
                return;
            }
            if core.running == me && core.states[me] == TState::Runnable {
                return;
            }
            core = self.cv.wait(core).expect("scheduler core never poisons");
        }
    }

    fn lock_core(&self) -> std::sync::MutexGuard<'_, Core> {
        self.core.lock().expect("scheduler core never poisons")
    }

    /// A plain yield point: a scheduling decision with no state change.
    pub(crate) fn yield_point(&self, me: usize, label: &str) {
        let mut core = self.lock_core();
        if core.failure.is_some() {
            drop(core);
            self.abort();
            return;
        }
        core.trace.push(format!("t{me}: {label}"));
        self.pick(&mut core, me);
        self.wait_turn(core, me);
    }

    /// Acquire model lock `id` (blocking virtually while held).
    pub(crate) fn acquire(&self, me: usize, id: usize, what: &str) {
        loop {
            self.yield_point(me, &format!("{what} L{id}"));
            let mut core = self.lock_core();
            if core.failure.is_some() {
                drop(core);
                self.abort();
                return;
            }
            match core.holders.get(&id) {
                None => {
                    core.holders.insert(id, me);
                    return;
                }
                Some(&holder) => {
                    debug_assert_ne!(
                        holder, me,
                        "model mutex L{id} is not reentrant"
                    );
                    core.states[me] = TState::Lock(id);
                    core.trace.push(format!("t{me}: blocked on L{id}"));
                    self.pick(&mut core, me);
                    self.wait_turn(core, me);
                    // woken by a release — retry the acquire
                }
            }
        }
    }

    /// Release model lock `id`; wakes blocked acquirers and yields (so
    /// a freshly woken waiter can win the lock over the releaser).
    pub(crate) fn release(&self, me: usize, id: usize) {
        let mut core = self.lock_core();
        core.holders.remove(&id);
        for s in core.states.iter_mut() {
            if *s == TState::Lock(id) {
                *s = TState::Runnable;
            }
        }
        core.trace.push(format!("t{me}: release L{id}"));
        if core.failure.is_some() || std::thread::panicking() {
            // Unwinding guard drops must neither schedule nor panic.
            self.cv.notify_all();
            return;
        }
        self.pick(&mut core, me);
        self.wait_turn(core, me);
    }

    /// Atomically release lock `lock` and wait on condvar `cv` (the
    /// atomicity is free: execution is serialized, and no yield happens
    /// between the release and the wait registration). The caller
    /// reacquires the lock afterwards.
    pub(crate) fn cond_wait(&self, me: usize, cv: usize, lock: usize) {
        let mut core = self.lock_core();
        if core.failure.is_some() {
            drop(core);
            self.abort();
            return;
        }
        core.trace
            .push(format!("t{me}: wait C{cv} (releases L{lock})"));
        core.holders.remove(&lock);
        for s in core.states.iter_mut() {
            if *s == TState::Lock(lock) {
                *s = TState::Runnable;
            }
        }
        core.states[me] = TState::Cond(cv);
        self.pick(&mut core, me);
        self.wait_turn(core, me);
    }

    /// Wake every waiter of condvar `cv` (they still contend on the
    /// lock), then yield.
    pub(crate) fn notify_all(&self, me: usize, cv: usize) {
        let mut core = self.lock_core();
        let mut woken = 0;
        for s in core.states.iter_mut() {
            if *s == TState::Cond(cv) {
                *s = TState::Runnable;
                woken += 1;
            }
        }
        core.trace
            .push(format!("t{me}: notify_all C{cv} (woke {woken})"));
        if core.failure.is_some() || std::thread::panicking() {
            self.cv.notify_all();
            return;
        }
        self.pick(&mut core, me);
        self.wait_turn(core, me);
    }

    /// Wake one waiter of condvar `cv` — *which* one is a scheduling
    /// decision the DFS branches over, then yield.
    pub(crate) fn notify_one(&self, me: usize, cv: usize) {
        let mut core = self.lock_core();
        let waiters: Vec<usize> = (0..core.states.len())
            .filter(|&t| core.states[t] == TState::Cond(cv))
            .collect();
        if !waiters.is_empty() {
            let idx = self.decide(&mut core, waiters.len(), true);
            if core.failure.is_some() {
                drop(core);
                self.abort();
                return;
            }
            let victim = waiters[idx];
            core.states[victim] = TState::Runnable;
            core.trace
                .push(format!("t{me}: notify_one C{cv} -> t{victim}"));
        } else {
            core.trace
                .push(format!("t{me}: notify_one C{cv} (no waiter)"));
        }
        if core.failure.is_some() || std::thread::panicking() {
            self.cv.notify_all();
            return;
        }
        self.pick(&mut core, me);
        self.wait_turn(core, me);
    }

    /// Block until thread `target` finishes.
    fn join_wait(&self, me: usize, target: usize) {
        loop {
            let mut core = self.lock_core();
            if core.failure.is_some() {
                drop(core);
                self.abort();
                return;
            }
            if core.states[target] == TState::Finished {
                return;
            }
            core.states[me] = TState::Join(target);
            core.trace.push(format!("t{me}: join t{target}"));
            self.pick(&mut core, me);
            self.wait_turn(core, me);
        }
    }

    /// Mark `me` finished, wake its joiners and hand off the CPU.
    fn finish(&self, me: usize) {
        let mut core = self.lock_core();
        core.states[me] = TState::Finished;
        for s in core.states.iter_mut() {
            if *s == TState::Join(me) {
                *s = TState::Runnable;
            }
        }
        core.trace.push(format!("t{me}: finished"));
        if core.failure.is_some() {
            self.cv.notify_all();
            return;
        }
        self.pick(&mut core, me);
    }

    /// Finish without scheduling — the failure path, where the run is
    /// already being torn down.
    fn finish_quiet(&self, me: usize) {
        let mut core = self.lock_core();
        core.states[me] = TState::Finished;
        self.cv.notify_all();
    }

    fn fail(&self, msg: String) {
        let mut core = self.lock_core();
        self.fail_locked(&mut core, msg);
    }
}

/// Handle to a model thread spawned with [`spawn`]; [`join`] blocks
/// (virtually) until it finishes and returns its result.
///
/// [`join`]: ModelHandle::join
pub struct ModelHandle<T> {
    tid: usize,
    result: Arc<StdMutex<Option<T>>>,
}

impl<T> ModelHandle<T> {
    /// Wait for the thread to finish and take its result. A panicked
    /// model thread fails the whole exploration instead of returning.
    pub fn join(self) -> T {
        let (sched, me) =
            current().expect("ModelHandle::join outside an exploration");
        sched.join_wait(me, self.tid);
        self.result
            .lock()
            .expect("model result slot never poisons")
            .take()
            .expect("joined model thread left a result")
    }
}

/// Spawn a model thread inside an active exploration. The closure runs
/// on a real OS thread, but only when the scheduler picks it; it must
/// synchronize exclusively through the instrumented shims (and
/// [`yield_now`]) so every blocking edge is visible to the explorer.
pub fn spawn<T, F>(f: F) -> ModelHandle<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let (sched, me) = current().expect("explore::spawn outside an exploration");
    let tid = {
        let mut core = sched.lock_core();
        core.states.push(TState::Runnable);
        core.trace.push(format!(
            "t{me}: spawn t{}",
            core.states.len() - 1
        ));
        core.states.len() - 1
    };
    let result: Arc<StdMutex<Option<T>>> = Arc::new(StdMutex::new(None));
    let thread_result = result.clone();
    let thread_sched = sched.clone();
    let handle = std::thread::Builder::new()
        .name(format!("model-t{tid}"))
        .spawn(move || {
            set_ctx(Some((thread_sched.clone(), tid)));
            {
                // Park until first scheduled.
                let core = thread_sched.lock_core();
                thread_sched.wait_turn(core, tid);
            }
            match catch_unwind(AssertUnwindSafe(f)) {
                Ok(v) => {
                    *thread_result
                        .lock()
                        .expect("model result slot never poisons") = Some(v);
                    thread_sched.finish(tid);
                }
                Err(p) if p.downcast_ref::<ExplorerAbort>().is_some() => {
                    thread_sched.finish_quiet(tid);
                }
                Err(p) => {
                    thread_sched.fail(format!(
                        "model thread t{tid} panicked: {}",
                        payload_str(p.as_ref())
                    ));
                    thread_sched.finish_quiet(tid);
                }
            }
            set_ctx(None);
        })
        .expect("spawn model OS thread");
    sched
        .handles
        .lock()
        .expect("handle list never poisons")
        .push(handle);
    // Yield so schedules where the child runs before the spawner
    // continues are reachable.
    sched.yield_point(me, "post-spawn");
    ModelHandle { tid, result }
}

/// An explicit yield point — a no-op outside an exploration.
pub fn yield_now() {
    if let Some((sched, me)) = current() {
        sched.yield_point(me, "yield_now");
    }
}

/// Run `body` under every schedule the bounded DFS reaches, returning
/// the failing schedule's report instead of panicking. `Ok` carries how
/// much was explored; `Err` carries the failure plus its full decision
/// trace.
pub fn explore_collect<F: Fn()>(
    opts: ExploreOpts,
    body: F,
) -> Result<ExploreReport, String> {
    assert!(
        current().is_none(),
        "explore() does not nest inside an exploration"
    );
    let mut prefix: Vec<usize> = Vec::new();
    let mut schedules = 0usize;
    loop {
        if schedules >= opts.max_schedules {
            return Ok(ExploreReport { schedules, exhausted: false });
        }
        let sched = Arc::new(Scheduler::new(&opts, prefix.clone(), schedules));
        set_ctx(Some((sched.clone(), 0)));
        let outcome = catch_unwind(AssertUnwindSafe(&body));
        match outcome {
            Ok(()) => sched.finish(0),
            Err(p) if p.downcast_ref::<ExplorerAbort>().is_some() => {
                sched.finish_quiet(0);
            }
            Err(p) => {
                sched.fail(format!(
                    "model main thread panicked: {}",
                    payload_str(p.as_ref())
                ));
                sched.finish_quiet(0);
            }
        }
        let handles = std::mem::take(
            &mut *sched.handles.lock().expect("handle list never poisons"),
        );
        for h in handles {
            let _ = h.join();
        }
        set_ctx(None);
        let core = sched.lock_core();
        if let Some(failure) = core.failure.as_ref() {
            return Err(format!(
                "schedule {} failed:\n{failure}",
                schedules + 1
            ));
        }
        schedules += 1;
        if opts.seed.is_some() {
            // Seeded mode: independent runs, no DFS bookkeeping.
            continue;
        }
        match next_prefix(&core.decisions) {
            Some(p) => {
                drop(core);
                prefix = p;
            }
            None => return Ok(ExploreReport { schedules, exhausted: true }),
        }
    }
}

/// [`explore_collect`], panicking with the schedule trace on failure —
/// the assertion form used directly in tests.
pub fn explore<F: Fn()>(opts: ExploreOpts, body: F) -> ExploreReport {
    match explore_collect(opts, body) {
        Ok(report) => report,
        Err(failure) => panic!(
            "interleaving explorer found a failing schedule:\n{failure}"
        ),
    }
}

/// The deepest non-forced decision with an untaken alternative becomes
/// the next DFS prefix; `None` when the bounded space is exhausted.
fn next_prefix(decisions: &[Decision]) -> Option<Vec<usize>> {
    for k in (0..decisions.len()).rev() {
        let d = decisions[k];
        if !d.forced && d.chosen + 1 < d.options {
            let mut p: Vec<usize> =
                decisions[..k].iter().map(|d| d.chosen).collect();
            p.push(d.chosen + 1);
            return Some(p);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::sync::{AtomicUsize, Condvar, Mutex};
    use std::sync::atomic::Ordering;

    fn opts(max: usize) -> ExploreOpts {
        ExploreOpts { max_schedules: max, ..ExploreOpts::default() }
    }

    /// The classic lost update: two threads doing a non-atomic
    /// read-modify-write through shim atomics. The explorer must find
    /// the interleaving where both read the same value.
    #[test]
    fn finds_lost_update_race() {
        let err = explore_collect(opts(2000), || {
            let a = Arc::new(AtomicUsize::new(0));
            let a2 = a.clone();
            let h = spawn(move || {
                let v = a2.load(Ordering::SeqCst);
                a2.store(v + 1, Ordering::SeqCst);
            });
            let v = a.load(Ordering::SeqCst);
            a.store(v + 1, Ordering::SeqCst);
            h.join();
            assert_eq!(a.load(Ordering::SeqCst), 2, "lost update");
        })
        .expect_err("explorer must find the lost update");
        assert!(err.contains("lost update"), "{err}");
    }

    /// The mutex-protected version of the same counter passes every
    /// explored schedule — and the space is small enough to exhaust.
    #[test]
    fn mutex_protected_counter_passes() {
        let report = explore(opts(4000), || {
            let a = Arc::new(Mutex::new(0usize));
            let a2 = a.clone();
            let h = spawn(move || {
                *a2.lock().unwrap() += 1;
            });
            *a.lock().unwrap() += 1;
            h.join();
            assert_eq!(*a.lock().unwrap(), 2);
        });
        assert!(report.exhausted, "small model should exhaust: {report:?}");
        assert!(report.schedules > 1, "must explore > 1 schedule");
    }

    /// AB/BA lock ordering: the explorer reports the deadlock cycle
    /// rather than hanging.
    #[test]
    fn detects_lock_order_deadlock() {
        let err = explore_collect(opts(2000), || {
            let a = Arc::new(Mutex::new(()));
            let b = Arc::new(Mutex::new(()));
            let (a2, b2) = (a.clone(), b.clone());
            let h = spawn(move || {
                let _gb = b2.lock().unwrap();
                let _ga = a2.lock().unwrap();
            });
            let _ga = a.lock().unwrap();
            let _gb = b.lock().unwrap();
            drop(_gb);
            drop(_ga);
            h.join();
        })
        .expect_err("explorer must find the AB/BA deadlock");
        assert!(err.contains("deadlock"), "{err}");
    }

    /// A signaller that sets the flag but never notifies: the waiter
    /// sleeps forever and the explorer flags the lost wakeup as a
    /// deadlock.
    #[test]
    fn detects_lost_wakeup() {
        let err = explore_collect(opts(2000), || {
            let flag = Arc::new((Mutex::new(false), Condvar::new()));
            let f2 = flag.clone();
            let h = spawn(move || {
                let mut g = f2.0.lock().unwrap();
                while !*g {
                    g = f2.1.wait(g).unwrap();
                }
            });
            *flag.0.lock().unwrap() = true; // bug: no notify
            h.join();
        })
        .expect_err("explorer must find the missed wakeup");
        assert!(err.contains("deadlock"), "{err}");
    }

    /// The correctly-notified version passes and exhausts.
    #[test]
    fn condvar_handshake_passes() {
        let report = explore(opts(4000), || {
            let flag = Arc::new((Mutex::new(false), Condvar::new()));
            let f2 = flag.clone();
            let h = spawn(move || {
                let mut g = f2.0.lock().unwrap();
                while !*g {
                    g = f2.1.wait(g).unwrap();
                }
            });
            {
                let mut g = flag.0.lock().unwrap();
                *g = true;
                flag.1.notify_all();
            }
            h.join();
        });
        assert!(report.exhausted, "{report:?}");
    }

    /// notify_one picks its victim nondeterministically: with two
    /// waiters and one notify, some schedule leaves the "wrong" waiter
    /// asleep — the explorer must reach it.
    #[test]
    fn notify_one_victim_is_explored() {
        let err = explore_collect(opts(4000), || {
            let pair = Arc::new((Mutex::new(0usize), Condvar::new()));
            let mut handles = Vec::new();
            for _ in 0..2 {
                let p = pair.clone();
                handles.push(spawn(move || {
                    let mut g = p.0.lock().unwrap();
                    while *g == 0 {
                        g = p.1.wait(g).unwrap();
                    }
                    *g -= 1;
                }));
            }
            {
                let mut g = pair.0.lock().unwrap();
                *g = 2;
                pair.1.notify_one(); // bug: two consumers, one notify
            }
            for h in handles {
                h.join();
            }
        })
        .expect_err("one notify for two waiters must strand one");
        assert!(err.contains("deadlock"), "{err}");
    }

    /// Trivial bodies explore exactly one schedule and report
    /// exhaustion; seeded mode runs the full budget instead.
    #[test]
    fn report_counts_schedules() {
        let report = explore(opts(100), || {});
        assert_eq!(
            report,
            ExploreReport { schedules: 1, exhausted: true }
        );
        let seeded = explore(
            ExploreOpts { seed: Some(7), ..opts(5) },
            || {
                let a = Arc::new(AtomicUsize::new(0));
                let a2 = a.clone();
                let h = spawn(move || {
                    a2.fetch_add(1, Ordering::SeqCst);
                });
                a.fetch_add(1, Ordering::SeqCst);
                h.join();
            },
        );
        assert_eq!(seeded.schedules, 5);
        assert!(!seeded.exhausted);
    }

    /// A panicking model thread fails the exploration with its message
    /// and the schedule trace, and every OS thread is reaped (the next
    /// exploration starts clean).
    #[test]
    fn model_panic_is_reported_with_trace() {
        let err = explore_collect(opts(100), || {
            let h = spawn(|| panic!("tile 5 exploded"));
            h.join();
        })
        .expect_err("panic must fail the exploration");
        assert!(err.contains("tile 5 exploded"), "{err}");
        assert!(err.contains("schedule step"), "{err}");
        // and the harness still works afterwards
        explore(opts(10), || {});
    }

    /// The step limit catches unfair spin loops instead of hanging.
    #[test]
    fn step_limit_catches_spin() {
        let err = explore_collect(
            ExploreOpts { max_steps: 200, ..opts(10) },
            || {
                let a = Arc::new(AtomicUsize::new(0));
                let a2 = a.clone();
                let _h = spawn(move || {
                    a2.store(1, Ordering::SeqCst);
                });
                // spin-wait with no condvar: the continue-first default
                // schedule never runs the writer
                while a.load(Ordering::SeqCst) == 0 {
                    yield_now();
                }
            },
        )
        .expect_err("spin loop must hit the step limit");
        assert!(err.contains("step limit"), "{err}");
    }
}
