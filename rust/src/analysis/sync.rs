//! The sync façade: the lock/condvar/atomic surface the runtime and
//! gateway synchronize through.
//!
//! In a normal build this module *is* `std::sync` — pure re-exports,
//! zero cost. Under `cfg(any(test, feature = "interleave"))` the same
//! names resolve to the instrumented shims in
//! [`shim`](super::shim), which delegate to `std` until a
//! deterministic exploration ([`super::explore`]) is active on the
//! current thread — so unit tests and production behavior are
//! unchanged, while interleaving tests can drive the *real*
//! synchronization protocols through every bounded schedule.
//!
//! Code that must use this façade instead of importing
//! `std::sync::{Mutex, Condvar}` directly: `runtime/global.rs`,
//! `runtime/pool.rs`, and everything under `gateway/`. The
//! `ci/lint_invariants.py` gate enforces this.

#[cfg(not(any(test, feature = "interleave")))]
pub use std::sync::{Condvar, Mutex, MutexGuard};

#[cfg(not(any(test, feature = "interleave")))]
pub use std::sync::atomic::AtomicUsize;

#[cfg(any(test, feature = "interleave"))]
pub use super::shim::{AtomicUsize, Condvar, Mutex, MutexGuard};

/// Lock `m`, recovering from poison: a panic on another thread while
/// it held the lock must not cascade — the protected state is either
/// repaired by the caller's own invariant checks or simple enough
/// (counters, queues of owned values) that observing it mid-update is
/// safe. This is the gateway's "a panicking dispatcher must not strand
/// blocked `Ticket::wait` callers" policy in one place.
pub fn lock_recover<'a, T: ?Sized>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// [`Condvar::wait`] with the same poison-recovery policy as
/// [`lock_recover`].
pub fn wait_recover<'a, T: ?Sized>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
) -> MutexGuard<'a, T> {
    cv.wait(guard)
        .unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// `Condvar::wait_timeout` with the same poison-recovery policy as
/// [`lock_recover`]. The timed-out flag is deliberately not returned:
/// every caller in the tree (the dispatcher's reap tick) re-checks its
/// condition under the lock, and the shim cannot fabricate a
/// `std::sync::WaitTimeoutResult` in model mode anyway.
#[cfg(not(any(test, feature = "interleave")))]
pub fn wait_timeout_recover<'a, T: ?Sized>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: std::time::Duration,
) -> MutexGuard<'a, T> {
    match cv.wait_timeout(guard, dur) {
        Ok((guard, _timed_out)) => guard,
        Err(poison) => poison.into_inner().0,
    }
}

/// `Condvar::wait_timeout` with the same poison-recovery policy as
/// [`lock_recover`] (shim flavor: the instrumented condvar already
/// drops the timed-out flag).
#[cfg(any(test, feature = "interleave"))]
pub fn wait_timeout_recover<'a, T: ?Sized>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: std::time::Duration,
) -> MutexGuard<'a, T> {
    cv.wait_timeout(guard, dur)
        .unwrap_or_else(std::sync::PoisonError::into_inner)
}
