//! Deterministic, seeded fault injection: named sites in the gateway
//! and runtime that tests (and `marsellus serve --chaos`) can arm to
//! panic, delay, or force a shed exactly where a real fault would
//! land.
//!
//! The module is the software analogue of scan-chain fault insertion:
//! instead of waiting for an overload, a cancellation race or a
//! panicking kernel to happen by accident, a test *provokes* it at a
//! named site and asserts the lifecycle invariants hold (every ticket
//! resolves, counters reconcile, inflight slots release).
//!
//! Compiled only under `cfg(any(test, feature = "chaos"))` — release
//! builds without the `chaos` feature contain no registry, no site
//! lookups, nothing (the [`crate::failpoint!`] macro expands to a
//! no-op; `ci/lint_invariants.py` rule R5 enforces that no call
//! bypasses the gate). Everything here is process-global and
//! deterministic: armed actions fire in arming order, and seeded mode
//! decides each hit from a pure hash of `(seed, site, hit index)` so
//! a chaos run replays exactly from its seed.
//!
//! This module deliberately uses `std::sync` directly rather than the
//! [`super::sync`] façade: the registry is test scaffolding, not a
//! protocol under exploration, and routing its locks through the shims
//! would add yield points to every failpoint probe inside interleave
//! models.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

/// What an armed failpoint does when its site is hit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailAction {
    /// Panic at the site (exercises catch_unwind / poison paths).
    Panic,
    /// Sleep this many microseconds at the site (widens race windows).
    DelayUs(u64),
    /// Report "shed this request" to sites that poll
    /// [`should_shed`] (forced deadline-reap).
    Shed,
}

struct Armed {
    action: FailAction,
    /// `None` = fire on every hit; `Some(n)` = fire `n` more times.
    remaining: Option<u64>,
}

#[derive(Default)]
struct Registry {
    armed: HashMap<String, Armed>,
    /// Seeded chaos mode: when set, *unarmed* sites also fire
    /// pseudo-randomly from a pure hash of (seed, site, hit index).
    seed: Option<u64>,
    /// Per-site hit counters (every probe counts, fired or not).
    hits: HashMap<String, u64>,
}

fn registry() -> &'static Mutex<Registry> {
    static REGISTRY: OnceLock<Mutex<Registry>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Registry::default()))
}

fn lock() -> std::sync::MutexGuard<'static, Registry> {
    // A panic injected by `fire` unwinds while this lock is *not*
    // held (we drop before panicking), but a panicking test body can
    // still poison it; recover like the gateway does.
    registry().lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Arm `site` with `action` for every subsequent hit (until
/// [`disarm_all`] or a re-arm).
pub fn arm(site: &str, action: FailAction) {
    lock().armed.insert(site.to_string(), Armed { action, remaining: None });
}

/// Arm `site` with `action` for exactly one hit; after it fires the
/// site is disarmed (so a test can inject one panic, then prove the
/// system recovered by driving the same path again).
pub fn arm_once(site: &str, action: FailAction) {
    lock()
        .armed
        .insert(site.to_string(), Armed { action, remaining: Some(1) });
}

/// Enter seeded chaos mode: every site decides per-hit from a pure
/// hash of `(seed, site, hit index)` whether to fire, and which
/// action. Deterministic — the same seed over the same request
/// sequence replays the same faults.
pub fn arm_seed(seed: u64) {
    lock().seed = Some(seed);
}

/// Disarm every site, leave seeded mode, and reset hit counters.
pub fn disarm_all() {
    let mut reg = lock();
    reg.armed.clear();
    reg.seed = None;
    reg.hits.clear();
}

/// How many times `site` has been probed (armed or not).
pub fn hits(site: &str) -> u64 {
    lock().hits.get(site).copied().unwrap_or(0)
}

/// SplitMix64 — a tiny, high-quality pure mix so seeded decisions are
/// a function of nothing but their inputs.
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

fn seeded_hash(seed: u64, site: &str, hit: u64) -> u64 {
    let mut h = mix(seed);
    for b in site.as_bytes() {
        h = mix(h ^ u64::from(*b));
    }
    mix(h ^ hit)
}

/// Seeded decision for non-shed sites: mostly do nothing, sometimes
/// delay, rarely panic — panics only at sites that declare themselves
/// panic-safe (inside a `catch_unwind`).
fn seeded_action(seed: u64, site: &str, hit: u64) -> Option<FailAction> {
    let h = seeded_hash(seed, site, hit);
    // ~1 in 4 hits fire at all; of those, panic-safe sites panic on a
    // further 1-in-4, everything else delays 50..850us.
    if h % 4 != 0 {
        return None;
    }
    let panic_safe = site == "dispatch::serve";
    if panic_safe && (h >> 8) % 4 == 0 {
        Some(FailAction::Panic)
    } else {
        Some(FailAction::DelayUs(50 + (h >> 16) % 800))
    }
}

/// Count a hit at `site` and return the action to perform, if any.
/// Decrements one-shot arms. Drops the registry lock before returning
/// so the caller can panic/sleep without holding it.
fn decide(site: &str) -> Option<FailAction> {
    let mut reg = lock();
    let hit = reg.hits.entry(site.to_string()).or_insert(0);
    let this_hit = *hit;
    *hit += 1;
    if let Some(armed) = reg.armed.get_mut(site) {
        let action = armed.action;
        match &mut armed.remaining {
            None => return Some(action),
            Some(n) => {
                *n -= 1;
                if *n == 0 {
                    reg.armed.remove(site);
                }
                return Some(action);
            }
        }
    }
    let seed = reg.seed?;
    seeded_action(seed, site, this_hit)
}

/// Probe `site`: panic or delay if armed (or if seeded chaos decides
/// to). `Shed` arms are ignored here — they only answer
/// [`should_shed`]. Call through the [`crate::failpoint!`] macro, not
/// directly, so release builds compile the probe out.
pub fn fire(site: &str) {
    match decide(site) {
        Some(FailAction::Panic) => {
            panic!("failpoint {site:?}: injected panic")
        }
        Some(FailAction::DelayUs(us)) => {
            std::thread::sleep(Duration::from_micros(us))
        }
        Some(FailAction::Shed) | None => {}
    }
}

/// Probe `site` as a shed decision: `true` when a `Shed` action is
/// armed there (or seeded chaos picks one). Call through the
/// [`crate::failpoint_shed!`] macro.
pub fn should_shed(site: &str) -> bool {
    let mut reg = lock();
    let hit = reg.hits.entry(site.to_string()).or_insert(0);
    let this_hit = *hit;
    *hit += 1;
    if let Some(armed) = reg.armed.get_mut(site) {
        if armed.action == FailAction::Shed {
            match &mut armed.remaining {
                None => return true,
                Some(n) => {
                    *n -= 1;
                    if *n == 0 {
                        reg.armed.remove(site);
                    }
                    return true;
                }
            }
        }
        return false;
    }
    match reg.seed {
        // Forced sheds are rarer than delays: ~1 in 8 probes.
        Some(seed) => seeded_hash(seed, site, this_hit) % 8 == 0,
        None => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::MutexGuard;

    /// The registry is process-global; serialize tests that touch it.
    fn serial() -> MutexGuard<'static, ()> {
        static GATE: Mutex<()> = Mutex::new(());
        GATE.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    #[test]
    fn unarmed_site_is_silent_and_counted() {
        let _g = serial();
        disarm_all();
        fire("test::silent");
        fire("test::silent");
        assert_eq!(hits("test::silent"), 2);
        assert!(!should_shed("test::silent"));
        disarm_all();
    }

    #[test]
    fn arm_once_fires_exactly_once() {
        let _g = serial();
        disarm_all();
        arm_once("test::once", FailAction::Panic);
        let err = std::panic::catch_unwind(|| fire("test::once"))
            .expect_err("armed panic must fire");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("test::once"), "panic names the site: {msg}");
        // Disarmed after one shot: the second probe is silent.
        fire("test::once");
        assert_eq!(hits("test::once"), 2);
        disarm_all();
    }

    #[test]
    fn shed_arms_only_answer_should_shed() {
        let _g = serial();
        disarm_all();
        arm("test::shed", FailAction::Shed);
        // `fire` ignores Shed actions entirely.
        fire("test::shed");
        assert!(should_shed("test::shed"));
        assert!(should_shed("test::shed"), "persistent arm keeps firing");
        disarm_all();
        assert!(!should_shed("test::shed"));
        disarm_all();
    }

    #[test]
    fn seeded_decisions_replay_from_the_seed() {
        let _g = serial();
        disarm_all();
        arm_seed(42);
        let run_a: Vec<bool> =
            (0..64).map(|_| should_shed("test::seeded")).collect();
        disarm_all();
        arm_seed(42);
        let run_b: Vec<bool> =
            (0..64).map(|_| should_shed("test::seeded")).collect();
        disarm_all();
        assert_eq!(run_a, run_b, "same seed, same trace");
        assert!(run_a.iter().any(|&b| b), "seed 42 sheds at least once in 64");
        assert!(!run_a.iter().all(|&b| b), "…but not every time");
    }

    #[test]
    fn seeded_panics_are_confined_to_panic_safe_sites() {
        // Pure-function check, no registry: seeded_action must never
        // pick Panic outside the catch_unwind-protected serve site.
        for seed in [1u64, 7, 42, 0xdead] {
            for hit in 0..256 {
                if let Some(FailAction::Panic) =
                    seeded_action(seed, "gateway::submit", hit)
                {
                    panic!("submit site must never draw a seeded panic");
                }
            }
            assert!(
                (0..4096).any(|hit| matches!(
                    seeded_action(seed, "dispatch::serve", hit),
                    Some(FailAction::Panic)
                )),
                "serve site draws a seeded panic eventually (seed {seed})"
            );
        }
    }
}
