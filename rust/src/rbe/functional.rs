//! Bit-exact functional model of the RBE datapath.
//!
//! Three implementations of the same arithmetic:
//! * [`conv_bitserial`] computes exactly as the hardware (and the L1
//!   Pallas kernel) does: decompose into bit planes, AND, scale by
//!   ±2^(i+j) (weight MSB negative — two's complement), accumulate in
//!   32-bit, then normquant (Eq. 1 + Eq. 2);
//! * [`conv_bitserial_packed`] is the same Eq. 1 datapath driven by a
//!   pre-packed weight operand ([`PackedWeights`], the §II-B3 bit-plane
//!   layout): the per-channel bit loop collapses into one AND + popcount
//!   per plane word, which is what makes the precompiled-plan serving
//!   path fast. The word width is a pack-time parameter
//!   ([`PlaneWidth`]): 32-lane words are the literal §II-B3 TCDM layout
//!   (and the parity reference), 64-lane words halve the popcount word
//!   count for layers wider than one group. Every width is bitwise
//!   identical to [`conv_bitserial`] by construction — each (i, j)
//!   contribution is the same popcount total;
//! * [`conv_reference`] is a plain signed-integer convolution + normquant
//!   (the specification, mirroring python `ref.py`).
//!
//! The packed kernel is additionally *tileable*: activations are packed
//! once per plane ([`pack_activations`]) and any `(output-row, k_out)`
//! rectangle of the output can be computed independently
//! ([`conv_bitserial_packed_tile`]), which is what the single-image
//! latency mode splits across the worker pool (`ConvPlan::run_tiled`).
//!
//! Property tests assert they agree for every precision/shape; integration
//! tests additionally compare against the PJRT artifact outputs, closing
//! the three-way equivalence the DESIGN.md §Functional-vs-timing split
//! requires.
//!
//! The `*_planned` entry points serve precompiled layer plans
//! (`runtime::plan`): weights were validated once at plan-compile time,
//! so per-call work is only activation checking + streaming.
//!
//! Tensor layout: activations `(H, W, K)` row-major `i32`, unsigned values
//! in `[0, 2^I)`; weights `(Kout, Kin, fy, fx)` signed in
//! `[-2^(W-1), 2^(W-1))`.

use std::borrow::Cow;

use anyhow::{bail, ensure, Result};

use super::config::{RbeJob, RbeMode};

/// Per-output-channel normalization parameters (Eq. 2).
///
/// `signed` selects the output clip the conv/linear kernels apply:
/// `false` (the zoo default) is the ReLU `[0, 2^O - 1]` clip
/// ([`Self::apply`]), `true` the two's-complement
/// `[-2^(O-1), 2^(O-1) - 1]` clip ([`Self::apply_signed`]) used by
/// signed-head layers (`LayerOp::LinearSigned`).
#[derive(Debug, Clone)]
pub struct NormQuant {
    pub scale: Vec<i32>,
    pub bias: Vec<i32>,
    pub shift: u32,
    pub signed: bool,
}

impl NormQuant {
    /// Unsigned (ReLU-clipped) normquant — the zoo default.
    pub fn new(scale: Vec<i32>, bias: Vec<i32>, shift: u32) -> Self {
        Self { scale, bias, shift, signed: false }
    }

    /// Signed (no-ReLU) normquant for `LinearSigned` heads.
    pub fn new_signed(scale: Vec<i32>, bias: Vec<i32>, shift: u32) -> Self {
        Self { scale, bias, shift, signed: true }
    }

    /// Identity-ish normquant: scale 1, bias 0, shift 0.
    pub fn unit(k_out: usize) -> Self {
        Self::new(vec![1; k_out], vec![0; k_out], 0)
    }

    /// Apply Eq. 2 with whichever clip this instance selects.
    #[inline]
    pub fn quantize(&self, k: usize, acc: i64, o_bits: usize) -> i32 {
        if self.signed {
            self.apply_signed(k, acc, o_bits)
        } else {
            self.apply(k, acc, o_bits)
        }
    }

    /// Apply Eq. 2 + ReLU clip to `o_bits`.
    ///
    /// Audit note (requant clamp bounds): every layer of the built-in
    /// zoo applies ReLU before quantization, so the unconditional
    /// `[0, 2^O - 1]` clip here matches both the bit-serial reference
    /// and python `ref.py` (`np.clip(v, 0, (1 << o_bits) - 1)`)
    /// bit-exactly — no divergence. The bound is only correct *because*
    /// of the ReLU; signed-output layers must use [`Self::apply_signed`]
    /// instead, which the edge-case property tests below pin down.
    #[inline]
    pub fn apply(&self, k: usize, acc: i64, o_bits: usize) -> i32 {
        let v = (self.scale[k] as i64 * acc + self.bias[k] as i64)
            >> self.shift;
        v.clamp(0, (1i64 << o_bits) - 1) as i32
    }

    /// Apply Eq. 2 with a *signed* (no-ReLU) clip to `o_bits`:
    /// `clamp(v, -2^(O-1), 2^(O-1) - 1)`, the two's-complement output
    /// range. The shift stays arithmetic (floor division), matching
    /// numpy's `>>` on negative int64.
    #[inline]
    pub fn apply_signed(&self, k: usize, acc: i64, o_bits: usize) -> i32 {
        let v = (self.scale[k] as i64 * acc + self.bias[k] as i64)
            >> self.shift;
        let half = 1i64 << (o_bits - 1);
        v.clamp(-half, half - 1) as i32
    }
}

/// Trim a `(full, full, c)` activation plane to its strided extent
/// `(need, need, c)`. Artifacts take the layer's full input plane; the
/// datapath model wants exactly `(h_out - 1) * stride + k` rows/cols
/// ([`RbeJob::h_in`]). Borrows when no trim is needed.
pub fn trim_input(x: &[i32], full: usize, need: usize, c: usize) -> Cow<'_, [i32]> {
    debug_assert!(need <= full);
    if need == full {
        return Cow::Borrowed(x);
    }
    let mut v = Vec::with_capacity(need * need * c);
    for r in 0..need {
        v.extend_from_slice(&x[r * full * c..(r * full + need) * c]);
    }
    Cow::Owned(v)
}

fn tap_range(job: &RbeJob) -> usize {
    match job.mode {
        RbeMode::Conv3x3 => 3,
        RbeMode::Conv1x1 => 1,
    }
}

fn check_activations(job: &RbeJob, x: &[i32]) -> Result<()> {
    let want_x = job.h_in() * job.w_in() * job.k_in;
    if x.len() != want_x {
        bail!("activation len {} != {}", x.len(), want_x);
    }
    check_activation_values(job, x)
}

/// Value-range half of the activation check (no length check): every
/// value must be unsigned and fit `i_bits`. Band packing validates the
/// band's own slice with this, so the whole plane is still scanned
/// exactly once across all bands.
fn check_activation_values(job: &RbeJob, x: &[i32]) -> Result<()> {
    let imax = 1 << job.i_bits;
    if let Some(&v) = x.iter().find(|&&v| v < 0 || v >= imax) {
        if v < 0 {
            // A negative value here means a *signed* (mid-network)
            // activation reached an unsigned kernel: the bit-plane
            // packer reads raw two's-complement bits, so packing it
            // would be silent corruption, not a wrong clamp. The plan
            // compiler refuses such schedules up front
            // (`dnn::validate_signed_dataflow`); this is the
            // defense-in-depth value check.
            bail!(
                "activation {v} is negative: the RBE kernels pack \
                 activations as unsigned {}-bit bit-planes and cannot \
                 represent signed (mid-network) activations",
                job.i_bits
            );
        }
        bail!(
            "activation {v} out of unsigned {}-bit range",
            job.i_bits
        );
    }
    Ok(())
}

/// Validate a raw weight tensor against the job signature (length +
/// signed range). Public so plan compilation can validate *once* and
/// then stream through the unchecked `*_planned` entry points.
pub fn check_weights(job: &RbeJob, w: &[i32]) -> Result<()> {
    let taps = tap_range(job);
    let want_w = job.k_out * job.k_in * taps * taps;
    if w.len() != want_w {
        bail!("weight len {} != {}", w.len(), want_w);
    }
    let whalf = 1 << (job.w_bits - 1);
    if w.iter().any(|&v| v < -whalf || v >= whalf) {
        bail!("weight out of signed {}-bit range", job.w_bits);
    }
    Ok(())
}

fn check_normquant(job: &RbeJob, nq: &NormQuant) -> Result<()> {
    if nq.scale.len() != job.k_out || nq.bias.len() != job.k_out {
        bail!("normquant params must be per-output-channel");
    }
    Ok(())
}

fn check_shapes(
    job: &RbeJob,
    x: &[i32],
    w: &[i32],
    nq: &NormQuant,
) -> Result<()> {
    check_activations(job, x)?;
    check_weights(job, w)?;
    check_normquant(job, nq)
}

/// A rectangular tile of a conv job's output: output rows
/// `[row0, row1)` × output channels `[ko0, ko1)`, always spanning the
/// full `w_out` extent. The unit of intra-image parallelism: disjoint
/// tiles cover disjoint output elements and can be computed on
/// different workers, then stitched (`ConvPlan::run_tiled`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvTile {
    pub row0: usize,
    pub row1: usize,
    pub ko0: usize,
    pub ko1: usize,
}

impl ConvTile {
    /// The whole output as one tile.
    pub fn full(job: &RbeJob) -> Self {
        Self { row0: 0, row1: job.h_out, ko0: 0, ko1: job.k_out }
    }

    /// Number of output values this tile produces.
    pub fn out_len(&self, job: &RbeJob) -> usize {
        (self.row1 - self.row0) * job.w_out * (self.ko1 - self.ko0)
    }

    fn validate(&self, job: &RbeJob) -> Result<()> {
        ensure!(
            self.row0 < self.row1
                && self.row1 <= job.h_out
                && self.ko0 < self.ko1
                && self.ko1 <= job.k_out,
            "tile {self:?} out of bounds for {} x {} output",
            job.h_out,
            job.k_out
        );
        Ok(())
    }
}

/// Plain integer convolution + normquant: the oracle.
pub fn conv_reference(
    job: &RbeJob,
    x: &[i32],
    w: &[i32],
    nq: &NormQuant,
) -> Result<Vec<i32>> {
    check_shapes(job, x, w, nq)?;
    Ok(conv_reference_core(job, x, w, nq, ConvTile::full(job)))
}

/// Plan-driven oracle entry point: weights (and normquant shapes) were
/// validated once at plan-compile time, so per-call checking is the
/// activation stream only. Bitwise identical to [`conv_reference`].
pub fn conv_reference_planned(
    job: &RbeJob,
    x: &[i32],
    w: &[i32],
    nq: &NormQuant,
) -> Result<Vec<i32>> {
    check_activations(job, x)?;
    debug_assert!(check_weights(job, w).is_ok());
    debug_assert!(check_normquant(job, nq).is_ok());
    Ok(conv_reference_core(job, x, w, nq, ConvTile::full(job)))
}

/// One output tile of the integer oracle — the reference-kernel half of
/// the tiled latency path. Tile layout: `(rows, w_out, ko-range)`
/// row-major. Bitwise identical to the matching slice of
/// [`conv_reference`].
///
/// The activation plane is only `debug_assert`ed here: the tile fan-out
/// shares one plane across many tiles, so the caller validates it once
/// via [`check_activation_plane`] instead of paying a full range scan
/// per tile.
pub fn conv_reference_tile(
    job: &RbeJob,
    x: &[i32],
    w: &[i32],
    nq: &NormQuant,
    tile: ConvTile,
) -> Result<Vec<i32>> {
    tile.validate(job)?;
    // the length check stays hard (O(1), and the core indexes by it);
    // only the O(n) value scan is delegated to the caller
    let want = job.h_in() * job.w_in() * job.k_in;
    if x.len() != want {
        bail!("activation len {} != {want}", x.len());
    }
    debug_assert!(check_activations(job, x).is_ok());
    debug_assert!(check_weights(job, w).is_ok());
    debug_assert!(check_normquant(job, nq).is_ok());
    Ok(conv_reference_core(job, x, w, nq, tile))
}

fn conv_reference_core(
    job: &RbeJob,
    x: &[i32],
    w: &[i32],
    nq: &NormQuant,
    tile: ConvTile,
) -> Vec<i32> {
    let taps = tap_range(job);
    let (hi, wi) = (job.h_in(), job.w_in());
    let kos = tile.ko1 - tile.ko0;
    let mut out = vec![0i32; tile.out_len(job)];
    for oy in tile.row0..tile.row1 {
        for ox in 0..job.w_out {
            for ko in tile.ko0..tile.ko1 {
                let mut acc: i64 = 0;
                for fy in 0..taps {
                    for fx in 0..taps {
                        let iy = oy * job.stride + fy;
                        let ix = ox * job.stride + fx;
                        debug_assert!(iy < hi && ix < wi);
                        for ki in 0..job.k_in {
                            let xv =
                                x[(iy * wi + ix) * job.k_in + ki] as i64;
                            let wv = w[((ko * job.k_in + ki) * taps + fy)
                                * taps
                                + fx] as i64;
                            acc += xv * wv;
                        }
                    }
                }
                out[((oy - tile.row0) * job.w_out + ox) * kos
                    + (ko - tile.ko0)] =
                    nq.quantize(ko, acc, job.o_bits);
            }
        }
    }
    out
}

/// Bit-serial convolution: Eq. 1 exactly as the datapath evaluates it.
///
/// For every (weight bit i, input bit j) pair the contribution is
/// `coef(i,j) * popcount(w_bit & x_bit)` accumulated over channels and
/// taps, where `coef = -2^(i+j)` for the weight MSB plane (two's
/// complement) and `+2^(i+j)` otherwise. Accumulation is wrapping 32-bit,
/// like the hardware Accums.
pub fn conv_bitserial(
    job: &RbeJob,
    x: &[i32],
    w: &[i32],
    nq: &NormQuant,
) -> Result<Vec<i32>> {
    check_shapes(job, x, w, nq)?;
    let taps = tap_range(job);
    let wi = job.w_in();
    let mut out = vec![0i32; job.h_out * job.w_out * job.k_out];
    for oy in 0..job.h_out {
        for ox in 0..job.w_out {
            for ko in 0..job.k_out {
                let mut acc: i32 = 0; // the 32-bit Accum register
                for i in 0..job.w_bits {
                    let neg = i == job.w_bits - 1 && job.w_bits > 1;
                    for j in 0..job.i_bits {
                        // binary dot product over taps x channels — what
                        // the BinConv AND arrays + popcount adders produce
                        let mut ones: i32 = 0;
                        for fy in 0..taps {
                            for fx in 0..taps {
                                let iy = oy * job.stride + fy;
                                let ix = ox * job.stride + fx;
                                for ki in 0..job.k_in {
                                    let xv = x
                                        [(iy * wi + ix) * job.k_in + ki]
                                        as u32;
                                    let wv = (w[((ko * job.k_in + ki)
                                        * taps
                                        + fy)
                                        * taps
                                        + fx]
                                        as u32)
                                        & ((1u32 << job.w_bits) - 1);
                                    ones += (((wv >> i) & 1)
                                        & ((xv >> j) & 1))
                                        as i32;
                                }
                            }
                        }
                        // Dynamic shifter, scale by ±2^(i+j). Headroom
                        // audit: `ones <= taps² · k_in` (one set bit per
                        // channel per tap at most — 64-lane packed words
                        // raise the per-word popcount ceiling to 64 but
                        // NOT this total), and the largest shift is
                        // (w_bits - 1) + (i_bits - 1) <= 14. The i32
                        // shift is therefore exact — no bits lost —
                        // whenever
                        //     taps² · k_in < 2^(31 - (w_bits + i_bits - 2)),
                        // i.e. k_in <= 14563 for a 3×3 conv at the full
                        // 8b×8b precision (any deeper layer would also
                        // wrap the hardware's 32-bit Accum). Past that
                        // bound `wrapping_shl` + the wrapping add/sub
                        // below wrap *identically* in the scalar,
                        // 32-lane and 64-lane packed paths: every path
                        // accumulates the same per-(i, j) `ones`
                        // totals, and wrapping i32 addition is
                        // associative and commutative — see
                        // `wrapping_parity_at_extreme_bit_widths`.
                        let contrib = ones.wrapping_shl((i + j) as u32);
                        acc = if neg {
                            acc.wrapping_sub(contrib)
                        } else {
                            acc.wrapping_add(contrib)
                        };
                    }
                }
                out[(oy * job.w_out + ox) * job.k_out + ko] =
                    nq.quantize(ko, acc as i64, job.o_bits);
            }
        }
    }
    Ok(out)
}

/// Lane count of the packed bit-plane words — the plan-time word-width
/// parameter of the packed bit-serial kernel.
///
/// [`PlaneWidth::W32`] is the literal §II-B3 TCDM layout (32 channels
/// per word, the parity reference); [`PlaneWidth::W64`] packs 64
/// channels per word, halving the AND+popcount word count for layers
/// wider than one 32-channel group; [`PlaneWidth::W128`] packs 128
/// channels per word for layers wider than two groups (on a 64-bit
/// host a `u128` AND+popcount lowers to two machine words, so it
/// halves the indexing/loop overhead rather than the popcount count).
/// Outputs are bitwise identical for every width.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PlaneWidth {
    /// 32 channels per `u32` word (§II-B3 hardware layout).
    W32,
    /// 64 channels per `u64` word (wide-word software path).
    W64,
    /// 128 channels per `u128` word (widest software path).
    W128,
}

impl PlaneWidth {
    /// Channels packed per plane word.
    pub fn lanes(self) -> usize {
        match self {
            PlaneWidth::W32 => 32,
            PlaneWidth::W64 => 64,
            PlaneWidth::W128 => 128,
        }
    }

    /// Bytes per plane word (the unit of the plan-cache byte model).
    pub fn word_bytes(self) -> usize {
        self.lanes() / 8
    }

    /// Plan-compile width choice for a job: the widest word the layer
    /// can fill — 128-lane words past two 32-channel groups, 64-lane
    /// words past one (each step halves the word count of the inner
    /// AND+popcount loop); the literal 32-lane hardware layout
    /// otherwise (a lone group gains nothing from wider words).
    pub fn for_job(job: &RbeJob) -> Self {
        if job.k_in > 64 {
            PlaneWidth::W128
        } else if job.k_in > 32 {
            PlaneWidth::W64
        } else {
            PlaneWidth::W32
        }
    }

    /// Every lane width, narrowest first — the full `PlaneWord` axis
    /// the deploy-time autotuner enumerates per layer. All widths are
    /// bitwise identical ([`Self::for_job`] only estimates which is
    /// fastest), so a tuner may pick any of them on measurement alone.
    pub const ALL: [PlaneWidth; 3] =
        [PlaneWidth::W32, PlaneWidth::W64, PlaneWidth::W128];

    /// The width packing `lanes` channels per word — the inverse of
    /// [`Self::lanes`], for deserializing persisted tuned configs.
    pub fn from_lanes(lanes: usize) -> Result<Self> {
        PlaneWidth::ALL
            .into_iter()
            .find(|w| w.lanes() == lanes)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no plane width has {lanes} lanes (expected 32, 64 \
                     or 128)"
                )
            })
    }
}

impl std::fmt::Display for PlaneWidth {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}-lane", self.lanes())
    }
}

/// One packed bit-plane word: `LANES` channels per word, one bit each.
/// The two implementations (`u32`, `u64`) differ only in lane count;
/// the kernel is generic over this trait and monomorphized per width.
trait PlaneWord: Copy {
    const LANES: usize;
    const ZERO: Self;
    fn with_bit(self, lane: usize) -> Self;
    fn and_popcount(self, other: Self) -> u32;
}

impl PlaneWord for u32 {
    const LANES: usize = 32;
    const ZERO: Self = 0;
    #[inline(always)]
    fn with_bit(self, lane: usize) -> Self {
        self | (1u32 << lane)
    }
    #[inline(always)]
    fn and_popcount(self, other: Self) -> u32 {
        (self & other).count_ones()
    }
}

impl PlaneWord for u64 {
    const LANES: usize = 64;
    const ZERO: Self = 0;
    #[inline(always)]
    fn with_bit(self, lane: usize) -> Self {
        self | (1u64 << lane)
    }
    #[inline(always)]
    fn and_popcount(self, other: Self) -> u32 {
        (self & other).count_ones()
    }
}

impl PlaneWord for u128 {
    const LANES: usize = 128;
    const ZERO: Self = 0;
    #[inline(always)]
    fn with_bit(self, lane: usize) -> Self {
        self | (1u128 << lane)
    }
    #[inline(always)]
    fn and_popcount(self, other: Self) -> u32 {
        (self & other).count_ones()
    }
}

/// Width-tagged storage for packed bit-plane words.
#[derive(Debug, Clone)]
enum PlaneVec {
    W32(Vec<u32>),
    W64(Vec<u64>),
    W128(Vec<u128>),
}

impl PlaneVec {
    fn width(&self) -> PlaneWidth {
        match self {
            PlaneVec::W32(_) => PlaneWidth::W32,
            PlaneVec::W64(_) => PlaneWidth::W64,
            PlaneVec::W128(_) => PlaneWidth::W128,
        }
    }

    fn len(&self) -> usize {
        match self {
            PlaneVec::W32(v) => v.len(),
            PlaneVec::W64(v) => v.len(),
            PlaneVec::W128(v) => v.len(),
        }
    }

    /// An empty storage at `width`, pre-sized for `capacity` words —
    /// the accumulator band assembly appends into.
    fn empty(width: PlaneWidth, capacity: usize) -> Self {
        match width {
            PlaneWidth::W32 => PlaneVec::W32(Vec::with_capacity(capacity)),
            PlaneWidth::W64 => PlaneVec::W64(Vec::with_capacity(capacity)),
            PlaneWidth::W128 => {
                PlaneVec::W128(Vec::with_capacity(capacity))
            }
        }
    }

    /// Append another segment packed at the same width (pure
    /// concatenation; a width mismatch is a loud error).
    fn append(&mut self, other: PlaneVec) -> Result<()> {
        match (self, other) {
            (PlaneVec::W32(a), PlaneVec::W32(b)) => a.extend(b),
            (PlaneVec::W64(a), PlaneVec::W64(b)) => a.extend(b),
            (PlaneVec::W128(a), PlaneVec::W128(b)) => a.extend(b),
            (a, b) => bail!(
                "activation band packed at {} cannot join a {} plane",
                b.width(),
                a.width()
            ),
        }
        Ok(())
    }
}

/// Weights pre-packed into channel-parallel bit-plane words — the
/// §II-B3 TCDM layout the streamer feeds the BinConvs from (at 32
/// lanes), and the weight half of a precompiled layer plan. The lane
/// count is a pack-time parameter ([`PlaneWidth`]).
///
/// Lane `c` of `planes[((ko * groups + g) * w_bits + i) * taps² + t]`
/// is bit `i` of the two's-complement weight for output channel `ko`,
/// input channel `g * lanes + c`, filter tap `t` (`t = fy * taps + fx`).
/// Ragged channel tails are zero-padded, contributing nothing to any
/// popcount.
#[derive(Debug, Clone)]
pub struct PackedWeights {
    words: PlaneVec,
    groups: usize,
    k_in: usize,
    taps: usize,
    k_out: usize,
    w_bits: usize,
}

impl PackedWeights {
    /// The lane width these planes were packed at.
    pub fn width(&self) -> PlaneWidth {
        self.words.width()
    }

    /// Packed bytes held (what the TCDM would store) — the number the
    /// plan-cache eviction policy accounts. Tracks the actual `Vec`
    /// element size: a 64-lane plan holds half as many words of twice
    /// the size.
    pub fn bytes(&self) -> usize {
        self.words.len() * self.width().word_bytes()
    }
}

fn pack_weight_words<W: PlaneWord>(job: &RbeJob, w: &[i32]) -> Vec<W> {
    let taps = tap_range(job);
    let taps2 = taps * taps;
    let groups = job.k_in.div_ceil(W::LANES);
    let wmask = (1u32 << job.w_bits) - 1;
    let mut planes = vec![W::ZERO; job.k_out * groups * job.w_bits * taps2];
    for ko in 0..job.k_out {
        for ki in 0..job.k_in {
            let (g, c) = (ki / W::LANES, ki % W::LANES);
            for t in 0..taps2 {
                let wv = (w[(ko * job.k_in + ki) * taps2 + t] as u32) & wmask;
                for i in 0..job.w_bits {
                    if (wv >> i) & 1 == 1 {
                        let idx = ((ko * groups + g) * job.w_bits + i)
                            * taps2
                            + t;
                        planes[idx] = planes[idx].with_bit(c);
                    }
                }
            }
        }
    }
    planes
}

/// Validate + pack a raw `(Kout, Kin, fy, fx)` weight tensor into the
/// 32-lane bit-plane layout (the §II-B3 hardware reference), once per
/// plan compilation. See [`pack_weights_with`] for an explicit width.
pub fn pack_weights(job: &RbeJob, w: &[i32]) -> Result<PackedWeights> {
    pack_weights_with(job, w, PlaneWidth::W32)
}

/// Validate + pack a raw weight tensor into the bit-plane layout at an
/// explicit lane width. Plan compilation picks the width via
/// [`PlaneWidth::for_job`]; parity tests pin both widths against the
/// scalar model.
pub fn pack_weights_with(
    job: &RbeJob,
    w: &[i32],
    width: PlaneWidth,
) -> Result<PackedWeights> {
    check_weights(job, w)?;
    let words = match width {
        PlaneWidth::W32 => PlaneVec::W32(pack_weight_words::<u32>(job, w)),
        PlaneWidth::W64 => PlaneVec::W64(pack_weight_words::<u64>(job, w)),
        PlaneWidth::W128 => {
            PlaneVec::W128(pack_weight_words::<u128>(job, w))
        }
    };
    Ok(PackedWeights {
        words,
        groups: job.k_in.div_ceil(width.lanes()),
        k_in: job.k_in,
        taps: tap_range(job),
        k_out: job.k_out,
        w_bits: job.w_bits,
    })
}

/// An activation plane packed into the same channel-parallel bit-plane
/// words as [`PackedWeights`]: one word per (pixel, group, input bit).
/// Packing is amortized — once per layer invocation, shared by every
/// `k_out` channel and, in the tiled latency path, by every tile
/// worker.
#[derive(Debug, Clone)]
pub struct PackedActivations {
    words: PlaneVec,
    groups: usize,
    k_in: usize,
    i_bits: usize,
    pixels: usize,
}

impl PackedActivations {
    /// The lane width these planes were packed at.
    pub fn width(&self) -> PlaneWidth {
        self.words.width()
    }
}

/// Pack the pixel range `[px0, px1)` of an activation plane. The plane
/// layout is per-pixel contiguous (`(p * groups + g) * i_bits + j`), so
/// a pixel range packs into an independent contiguous word segment —
/// the property the band-parallel pack relies on.
fn pack_activation_words_range<W: PlaneWord>(
    job: &RbeJob,
    x: &[i32],
    px0: usize,
    px1: usize,
) -> Vec<W> {
    let groups = job.k_in.div_ceil(W::LANES);
    let mut xp = vec![W::ZERO; (px1 - px0) * groups * job.i_bits];
    for p in px0..px1 {
        for ki in 0..job.k_in {
            // non-negative by check_activation_values: the raw bits ARE
            // the unsigned magnitude
            let v = x[p * job.k_in + ki] as u32;
            let (g, c) = (ki / W::LANES, ki % W::LANES);
            for j in 0..job.i_bits {
                if (v >> j) & 1 == 1 {
                    let idx = ((p - px0) * groups + g) * job.i_bits + j;
                    xp[idx] = xp[idx].with_bit(c);
                }
            }
        }
    }
    xp
}

fn pack_plane_vec_range(
    job: &RbeJob,
    x: &[i32],
    width: PlaneWidth,
    px0: usize,
    px1: usize,
) -> PlaneVec {
    match width {
        PlaneWidth::W32 => {
            PlaneVec::W32(pack_activation_words_range::<u32>(job, x, px0, px1))
        }
        PlaneWidth::W64 => {
            PlaneVec::W64(pack_activation_words_range::<u64>(job, x, px0, px1))
        }
        PlaneWidth::W128 => PlaneVec::W128(
            pack_activation_words_range::<u128>(job, x, px0, px1),
        ),
    }
}

/// Validate + pack one activation plane into bit-plane words at `width`.
/// Rejects signed (negative) activations loudly — the packer reads raw
/// unsigned bits and would otherwise corrupt silently.
pub fn pack_activations(
    job: &RbeJob,
    x: &[i32],
    width: PlaneWidth,
) -> Result<PackedActivations> {
    check_activations(job, x)?;
    let pixels = job.h_in() * job.w_in();
    Ok(PackedActivations {
        words: pack_plane_vec_range(job, x, width, 0, pixels),
        groups: job.k_in.div_ceil(width.lanes()),
        k_in: job.k_in,
        i_bits: job.i_bits,
        pixels,
    })
}

/// One contiguous pixel band of a packed activation plane — the unit of
/// band-parallel packing. Bands are produced independently (one per
/// pool worker) and stitched back with
/// [`assemble_activation_bands`]; because the packed layout is
/// per-pixel contiguous, stitching is pure concatenation and the result
/// is bitwise identical to [`pack_activations`] over the whole plane.
#[derive(Debug, Clone)]
pub struct ActivationBand {
    words: PlaneVec,
    px0: usize,
    px1: usize,
}

/// Split `n` units (pixel rows, pixels, ...) into at most `parts`
/// non-empty contiguous ranges of near-equal size that exactly cover
/// `[0, n)`.
pub fn band_split(n: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.clamp(1, n.max(1));
    (0..parts)
        .map(|b| (b * n / parts, (b + 1) * n / parts))
        .filter(|(a, b)| a < b)
        .collect()
}

/// Validate + pack the pixel range `[px0, px1)` of an activation plane.
/// The band checks its own slice's value range, so packing every band
/// of a [`band_split`] scans the whole plane exactly once — including
/// the loud signed-activation rejection of [`pack_activations`].
pub fn pack_activation_band(
    job: &RbeJob,
    x: &[i32],
    width: PlaneWidth,
    px0: usize,
    px1: usize,
) -> Result<ActivationBand> {
    let pixels = job.h_in() * job.w_in();
    ensure!(
        px0 < px1 && px1 <= pixels,
        "activation band [{px0}, {px1}) out of range for {pixels} pixels"
    );
    if x.len() != pixels * job.k_in {
        bail!("activation len {} != {}", x.len(), pixels * job.k_in);
    }
    check_activation_values(job, &x[px0 * job.k_in..px1 * job.k_in])?;
    Ok(ActivationBand {
        words: pack_plane_vec_range(job, x, width, px0, px1),
        px0,
        px1,
    })
}

/// Stitch independently packed pixel bands back into one
/// [`PackedActivations`] plane. The bands must exactly tile
/// `[0, pixels)` in order and share `width`; the assembled plane is
/// bitwise identical to a whole-plane [`pack_activations`] call.
pub fn assemble_activation_bands(
    job: &RbeJob,
    width: PlaneWidth,
    bands: Vec<ActivationBand>,
) -> Result<PackedActivations> {
    let pixels = job.h_in() * job.w_in();
    let groups = job.k_in.div_ceil(width.lanes());
    let mut expect = 0usize;
    let mut words = PlaneVec::empty(width, pixels * groups * job.i_bits);
    for band in bands {
        ensure!(
            band.px0 == expect,
            "activation bands must tile the plane in order: band starts \
             at pixel {} but {expect} pixels are assembled",
            band.px0
        );
        expect = band.px1;
        words.append(band.words)?;
    }
    ensure!(
        expect == pixels,
        "activation bands cover {expect} of {pixels} pixels"
    );
    Ok(PackedActivations {
        words,
        groups,
        k_in: job.k_in,
        i_bits: job.i_bits,
        pixels,
    })
}

/// Validate one activation plane (length + unsigned range) against a
/// job — the per-call activation check of the planned entry points,
/// exposed so the tiled latency path can validate ONCE per layer
/// instead of once per tile ([`conv_reference_tile`] only
/// `debug_assert`s it).
pub fn check_activation_plane(job: &RbeJob, x: &[i32]) -> Result<()> {
    check_activations(job, x)
}

fn check_packed_signature(job: &RbeJob, pw: &PackedWeights) -> Result<()> {
    let taps = tap_range(job);
    // Every field that determines the plane layout must match, or the
    // indexing below reads wrong planes / out of bounds. k_in is
    // checked directly, not only via the group count: two ragged
    // channel counts can share a group (e.g. 33 and 40 at 64 lanes)
    // and the zero-padded tail would silently popcount as nothing.
    if pw.taps != taps
        || pw.k_in != job.k_in
        || pw.k_out != job.k_out
        || pw.w_bits != job.w_bits
    {
        bail!(
            "packed weights were built for a different job signature \
             (taps {} / k_in {} / k_out {} / w_bits {} vs \
             {taps} / {} / {} / {})",
            pw.taps,
            pw.k_in,
            pw.k_out,
            pw.w_bits,
            job.k_in,
            job.k_out,
            job.w_bits
        );
    }
    Ok(())
}

fn conv_tile_core<W: PlaneWord>(
    job: &RbeJob,
    xw: &[W],
    ww: &[W],
    groups: usize,
    taps: usize,
    nq: &NormQuant,
    tile: ConvTile,
) -> Vec<i32> {
    let taps2 = taps * taps;
    let wi = job.w_in();
    let kos = tile.ko1 - tile.ko0;
    let mut out = vec![0i32; tile.out_len(job)];
    for oy in tile.row0..tile.row1 {
        for ox in 0..job.w_out {
            for ko in tile.ko0..tile.ko1 {
                let wbase = ko * groups;
                let mut acc: i32 = 0; // the 32-bit Accum register
                for i in 0..job.w_bits {
                    let neg = i == job.w_bits - 1 && job.w_bits > 1;
                    for j in 0..job.i_bits {
                        let mut ones: i32 = 0;
                        for fy in 0..taps {
                            let iy = oy * job.stride + fy;
                            for fx in 0..taps {
                                let ix = ox * job.stride + fx;
                                let px = (iy * wi + ix) * groups;
                                for g in 0..groups {
                                    ones += xw[(px + g) * job.i_bits + j]
                                        .and_popcount(
                                            ww[((wbase + g) * job.w_bits
                                                + i)
                                                * taps2
                                                + fy * taps
                                                + fx],
                                        )
                                        as i32;
                                }
                            }
                        }
                        // Same ±2^(i+j) dynamic shifter as the scalar
                        // model; `ones` is the identical per-(i, j)
                        // total regardless of lane width, so wrapping
                        // behaviour matches bit for bit — see the
                        // headroom audit comment in `conv_bitserial`.
                        let contrib = ones.wrapping_shl((i + j) as u32);
                        acc = if neg {
                            acc.wrapping_sub(contrib)
                        } else {
                            acc.wrapping_add(contrib)
                        };
                    }
                }
                out[((oy - tile.row0) * job.w_out + ox) * kos
                    + (ko - tile.ko0)] =
                    nq.quantize(ko, acc as i64, job.o_bits);
            }
        }
    }
    out
}

/// Bit-serial convolution over pre-packed weights: the plan-driven fast
/// path. Activations are packed into matching bit-plane words on entry
/// (amortized over all `k_out` channels), then every (i, j)
/// contribution is one AND + popcount per word instead of a per-channel
/// bit walk. The (i, j) popcount totals are the same integers
/// [`conv_bitserial`] accumulates — at any [`PlaneWidth`] — and
/// wrapping 32-bit addition is associative, so outputs are bitwise
/// identical.
pub fn conv_bitserial_packed(
    job: &RbeJob,
    x: &[i32],
    pw: &PackedWeights,
    nq: &NormQuant,
) -> Result<Vec<i32>> {
    // O(1) shape checks first: a mismatched call must fail before the
    // O(n) activation pack, not after
    check_normquant(job, nq)?;
    check_packed_signature(job, pw)?;
    let xp = pack_activations(job, x, pw.width())?;
    conv_bitserial_packed_tile(job, &xp, pw, nq, ConvTile::full(job))
}

/// One output tile of the packed bit-serial kernel over a pre-packed
/// activation plane — the unit the single-image latency mode fans out
/// across workers. Tile layout: `(rows, w_out, ko-range)` row-major.
/// The full tile reproduces [`conv_bitserial_packed`] exactly; disjoint
/// tiles stitch to the same output bitwise.
pub fn conv_bitserial_packed_tile(
    job: &RbeJob,
    xp: &PackedActivations,
    pw: &PackedWeights,
    nq: &NormQuant,
    tile: ConvTile,
) -> Result<Vec<i32>> {
    check_normquant(job, nq)?;
    check_packed_signature(job, pw)?;
    tile.validate(job)?;
    if xp.k_in != job.k_in
        || xp.i_bits != job.i_bits
        || xp.groups != pw.groups
        || xp.pixels != job.h_in() * job.w_in()
    {
        bail!(
            "packed activations were built for a different job signature \
             (k_in {} / i_bits {} / groups {} / pixels {} vs \
             {} / {} / {} / {})",
            xp.k_in,
            xp.i_bits,
            xp.groups,
            xp.pixels,
            job.k_in,
            job.i_bits,
            pw.groups,
            job.h_in() * job.w_in()
        );
    }
    match (&xp.words, &pw.words) {
        (PlaneVec::W32(x), PlaneVec::W32(w)) => Ok(conv_tile_core(
            job,
            x.as_slice(),
            w.as_slice(),
            pw.groups,
            pw.taps,
            nq,
            tile,
        )),
        (PlaneVec::W64(x), PlaneVec::W64(w)) => Ok(conv_tile_core(
            job,
            x.as_slice(),
            w.as_slice(),
            pw.groups,
            pw.taps,
            nq,
            tile,
        )),
        (PlaneVec::W128(x), PlaneVec::W128(w)) => Ok(conv_tile_core(
            job,
            x.as_slice(),
            w.as_slice(),
            pw.groups,
            pw.taps,
            nq,
            tile,
        )),
        (x, w) => bail!(
            "packed activations are {} but packed weights are {}",
            x.width(),
            w.width()
        ),
    }
}

/// Residual add + requant (`ref.add_requant_ref` with unit scales):
/// `clip((a + b) >> shift, 0, 2^O - 1)` elementwise.
pub fn add_requant(
    a: &[i32],
    b: &[i32],
    shift: u32,
    o_bits: usize,
) -> Result<Vec<i32>> {
    if a.len() != b.len() {
        bail!("add operands differ in length: {} vs {}", a.len(), b.len());
    }
    let omax = (1i64 << o_bits) - 1;
    Ok(a.iter()
        .zip(b)
        .map(|(&a, &b)| (((a as i64 + b as i64) >> shift).clamp(0, omax)) as i32)
        .collect())
}

/// Global average pool (`ref.avgpool_ref`): per-channel sum over
/// `pixels` spatial positions, then arithmetic right shift.
pub fn avgpool(x: &[i32], pixels: usize, k: usize, shift: u32) -> Result<Vec<i32>> {
    if x.len() != pixels * k {
        bail!("avgpool input len {} != {pixels} pixels x {k} channels", x.len());
    }
    let mut sums = vec![0i64; k];
    for px in x.chunks_exact(k) {
        for (s, &v) in sums.iter_mut().zip(px) {
            *s += v as i64;
        }
    }
    Ok(sums.iter().map(|&s| (s >> shift) as i32).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_job_inputs(
        rng: &mut Rng,
        job: &RbeJob,
    ) -> (Vec<i32>, Vec<i32>, NormQuant) {
        let taps = tap_range(job);
        let x: Vec<i32> = (0..job.h_in() * job.w_in() * job.k_in)
            .map(|_| rng.range_i32(0, 1 << job.i_bits))
            .collect();
        let whalf = 1 << (job.w_bits - 1);
        let w: Vec<i32> = (0..job.k_out * job.k_in * taps * taps)
            .map(|_| rng.range_i32(-whalf, whalf))
            .collect();
        let nq = NormQuant {
            scale: (0..job.k_out).map(|_| rng.range_i32(1, 16)).collect(),
            bias: (0..job.k_out).map(|_| rng.range_i32(-500, 500)).collect(),
            shift: rng.range_i32(0, 12) as u32,
            // cover the signed (no-ReLU) clip in every kernel sweep
            signed: rng.f64() < 0.3,
        };
        (x, w, nq)
    }

    /// Property: bit-serial == plain integer conv for every precision and
    /// mode (the core Eq. 1 equivalence).
    #[test]
    fn bitserial_equals_reference_sweep() {
        let mut rng = Rng::new(2024);
        for _ in 0..60 {
            let mode = if rng.f64() < 0.5 {
                RbeMode::Conv3x3
            } else {
                RbeMode::Conv1x1
            };
            let job = RbeJob {
                mode,
                h_out: 1 + rng.index(3),
                w_out: 1 + rng.index(3),
                k_in: *rng.pick(&[1, 3, 8, 32]),
                k_out: *rng.pick(&[1, 4, 16]),
                stride: 1 + rng.index(2),
                w_bits: 2 + rng.index(7),
                i_bits: 2 + rng.index(7),
                o_bits: 2 + rng.index(7),
            };
            let (x, w, nq) = random_job_inputs(&mut rng, &job);
            let a = conv_bitserial(&job, &x, &w, &nq).unwrap();
            let b = conv_reference(&job, &x, &w, &nq).unwrap();
            assert_eq!(a, b, "job {job:?}");
        }
    }

    /// All three kernels honour the signed (no-ReLU) clip: a negative
    /// accumulation survives as a negative output instead of pinning 0.
    #[test]
    fn signed_normquant_keeps_negative_logits_in_every_kernel() {
        let job = RbeJob::conv1x1(1, 1, 4, 1, 1, 3, 2, 4).unwrap();
        let x = vec![3, 3, 3, 3];
        let w = vec![-4, -4, -4, -4];
        let nq = NormQuant::new_signed(vec![1], vec![0], 0);
        // acc = -48; the signed 4-bit clip pins -8 (ReLU would give 0)
        assert_eq!(conv_bitserial(&job, &x, &w, &nq).unwrap(), vec![-8]);
        assert_eq!(conv_reference(&job, &x, &w, &nq).unwrap(), vec![-8]);
        for width in [PlaneWidth::W32, PlaneWidth::W64, PlaneWidth::W128] {
            let pw = pack_weights_with(&job, &w, width).unwrap();
            assert_eq!(
                conv_bitserial_packed(&job, &x, &pw, &nq).unwrap(),
                vec![-8],
                "{width}"
            );
        }
    }

    #[test]
    fn relu_clips_negative_accumulations() {
        let job = RbeJob::conv1x1(1, 1, 4, 1, 1, 3, 2, 4).unwrap();
        let x = vec![3, 3, 3, 3];
        let w = vec![-4, -4, -4, -4];
        let nq = NormQuant::unit(1);
        let out = conv_bitserial(&job, &x, &w, &nq).unwrap();
        assert_eq!(out, vec![0]);
    }

    #[test]
    fn saturates_at_output_max() {
        let job = RbeJob::conv1x1(1, 1, 8, 1, 1, 8, 8, 3).unwrap();
        let x = vec![255; 8];
        let w = vec![127; 8];
        let nq = NormQuant::unit(1);
        let out = conv_reference(&job, &x, &w, &nq).unwrap();
        assert_eq!(out, vec![7]); // 2^3 - 1
    }

    #[test]
    fn rejects_out_of_range_inputs() {
        let job = RbeJob::conv1x1(1, 1, 4, 1, 1, 2, 2, 2).unwrap();
        let nq = NormQuant::unit(1);
        // activation 4 does not fit 2 bits
        assert!(conv_bitserial(&job, &[4, 0, 0, 0], &[1, 1, 1, 1], &nq)
            .is_err());
        // weight 2 does not fit signed 2 bits
        assert!(conv_bitserial(&job, &[1, 0, 0, 0], &[2, 0, 0, 0], &nq)
            .is_err());
    }

    /// Regression (signed-activation packing trap): a negative
    /// mid-network activation must be a loud, named error in every
    /// kernel that packs unsigned bit-planes — never silently packed
    /// garbage high bits.
    #[test]
    fn signed_activations_rejected_loudly_not_packed() {
        let job = RbeJob::conv1x1(1, 1, 4, 1, 1, 4, 4, 4).unwrap();
        let w = vec![1, 1, 1, 1];
        let x = vec![3, -2, 3, 3]; // one signed (negative) activation
        let nq = NormQuant::unit(1);
        for width in [PlaneWidth::W32, PlaneWidth::W64, PlaneWidth::W128] {
            let pw = pack_weights_with(&job, &w, width).unwrap();
            let err = conv_bitserial_packed(&job, &x, &pw, &nq)
                .unwrap_err()
                .to_string();
            assert!(
                err.contains("negative") && err.contains("signed"),
                "{width}: unhelpful error {err:?}"
            );
            let err =
                pack_activations(&job, &x, width).unwrap_err().to_string();
            assert!(err.contains("negative"), "{width}: {err:?}");
        }
        // the scalar kernels reject the same plane with the same message
        let err = conv_bitserial(&job, &x, &w, &nq).unwrap_err().to_string();
        assert!(err.contains("negative"), "{err:?}");
    }

    #[test]
    fn strided_conv_matches() {
        let mut rng = Rng::new(7);
        let job = RbeJob::conv3x3(2, 2, 8, 4, 2, 4, 4, 8).unwrap();
        let (x, w, nq) = random_job_inputs(&mut rng, &job);
        assert_eq!(
            conv_bitserial(&job, &x, &w, &nq).unwrap(),
            conv_reference(&job, &x, &w, &nq).unwrap()
        );
    }

    /// Property: the packed plan-driven datapath is bitwise identical to
    /// the scalar bit-serial model for every precision, mode, stride,
    /// lane width and ragged channel count (incl. k_in not a multiple of
    /// 32 or 64, and k_in < 32).
    #[test]
    fn packed_equals_scalar_bitserial_sweep() {
        let mut rng = Rng::new(4242);
        for _ in 0..40 {
            let mode = if rng.f64() < 0.5 {
                RbeMode::Conv3x3
            } else {
                RbeMode::Conv1x1
            };
            let job = RbeJob {
                mode,
                h_out: 1 + rng.index(3),
                w_out: 1 + rng.index(3),
                k_in: *rng.pick(&[1, 3, 31, 32, 33, 40, 63, 64, 65, 96, 129]),
                k_out: *rng.pick(&[1, 4, 16]),
                stride: 1 + rng.index(2),
                w_bits: 2 + rng.index(7),
                i_bits: 2 + rng.index(7),
                o_bits: 2 + rng.index(7),
            };
            let (x, w, nq) = random_job_inputs(&mut rng, &job);
            let scalar = conv_bitserial(&job, &x, &w, &nq).unwrap();
            for width in [PlaneWidth::W32, PlaneWidth::W64, PlaneWidth::W128] {
                let pw = pack_weights_with(&job, &w, width).unwrap();
                assert_eq!(
                    conv_bitserial_packed(&job, &x, &pw, &nq).unwrap(),
                    scalar,
                    "{width}, job {job:?}"
                );
            }
            assert_eq!(
                conv_reference_planned(&job, &x, &w, &nq).unwrap(),
                conv_reference(&job, &x, &w, &nq).unwrap(),
                "planned oracle, job {job:?}"
            );
        }
    }

    /// Property: any tiling of the output — random row and k_out cut
    /// points, both lane widths, packed and reference kernels — stitches
    /// to exactly the full-kernel output.
    #[test]
    fn tiles_stitch_to_full_kernel_output() {
        let mut rng = Rng::new(7331);
        for _ in 0..25 {
            let mode = if rng.f64() < 0.5 {
                RbeMode::Conv3x3
            } else {
                RbeMode::Conv1x1
            };
            let job = RbeJob {
                mode,
                h_out: 2 + rng.index(4),
                w_out: 2 + rng.index(4),
                k_in: *rng.pick(&[3, 33, 64]),
                k_out: *rng.pick(&[2, 5, 16]),
                stride: 1 + rng.index(2),
                w_bits: 2 + rng.index(7),
                i_bits: 2 + rng.index(7),
                o_bits: 2 + rng.index(7),
            };
            let (x, w, nq) = random_job_inputs(&mut rng, &job);
            let full = conv_bitserial(&job, &x, &w, &nq).unwrap();
            // random 2x2 tiling: one interior cut per axis
            let rcut = 1 + rng.index(job.h_out - 1);
            let kcut = 1 + rng.index(job.k_out - 1);
            let tiles = [
                ConvTile { row0: 0, row1: rcut, ko0: 0, ko1: kcut },
                ConvTile { row0: 0, row1: rcut, ko0: kcut, ko1: job.k_out },
                ConvTile { row0: rcut, row1: job.h_out, ko0: 0, ko1: kcut },
                ConvTile {
                    row0: rcut,
                    row1: job.h_out,
                    ko0: kcut,
                    ko1: job.k_out,
                },
            ];
            let stitch = |parts: &[Vec<i32>]| {
                let mut out = vec![0i32; full.len()];
                for (t, part) in tiles.iter().zip(parts) {
                    let kos = t.ko1 - t.ko0;
                    for r in 0..t.row1 - t.row0 {
                        for ox in 0..job.w_out {
                            for k in 0..kos {
                                out[(((t.row0 + r) * job.w_out + ox)
                                    * job.k_out)
                                    + t.ko0
                                    + k] = part
                                    [(r * job.w_out + ox) * kos + k];
                            }
                        }
                    }
                }
                out
            };
            for width in [PlaneWidth::W32, PlaneWidth::W64, PlaneWidth::W128] {
                let pw = pack_weights_with(&job, &w, width).unwrap();
                let xp = pack_activations(&job, &x, width).unwrap();
                let parts: Vec<Vec<i32>> = tiles
                    .iter()
                    .map(|t| {
                        conv_bitserial_packed_tile(&job, &xp, &pw, &nq, *t)
                            .unwrap()
                    })
                    .collect();
                assert_eq!(stitch(&parts), full, "{width}, job {job:?}");
            }
            let parts: Vec<Vec<i32>> = tiles
                .iter()
                .map(|t| {
                    conv_reference_tile(&job, &x, &w, &nq, *t).unwrap()
                })
                .collect();
            assert_eq!(stitch(&parts), full, "reference tiles, job {job:?}");
        }
    }

    /// The documented dynamic-shifter headroom bound: past
    /// `taps² · k_in = 2^(31 - (w_bits + i_bits - 2))` the ±2^(i+j)
    /// contribution wraps the 32-bit Accum — and the scalar, 32-lane and
    /// 64-lane paths wrap bit-identically (a 64-lane word carries up to
    /// 2× the ones of a 32-lane word, but the per-(i, j) total is the
    /// same integer in every path).
    #[test]
    fn wrapping_parity_at_extreme_bit_widths() {
        // all-ones worst case: every AND matches, ones = 9 * k_in =
        // 147456 > 2^17, so contrib = ones << 14 wraps i32
        let job = RbeJob::conv3x3(1, 1, 16384, 1, 1, 8, 8, 8).unwrap();
        let x = vec![255i32; job.h_in() * job.w_in() * job.k_in];
        let w = vec![-1i32; job.k_out * job.k_in * 9];
        let ones_max = 9i64 * job.k_in as i64;
        let top_shift = (job.w_bits + job.i_bits - 2) as i64;
        assert!(
            ones_max << top_shift > i32::MAX as i64,
            "test premise: the top contribution must overflow i32"
        );
        let nq = NormQuant::new_signed(vec![1], vec![0], 0);
        let scalar = conv_bitserial(&job, &x, &w, &nq).unwrap();
        for width in [PlaneWidth::W32, PlaneWidth::W64, PlaneWidth::W128] {
            let pw = pack_weights_with(&job, &w, width).unwrap();
            assert_eq!(
                conv_bitserial_packed(&job, &x, &pw, &nq).unwrap(),
                scalar,
                "{width} diverged from scalar under Accum wrapping"
            );
        }
        // and a random job just past the documented exactness bound
        let mut rng = Rng::new(99);
        let job = RbeJob::conv3x3(1, 1, 14848, 1, 1, 8, 8, 8).unwrap();
        let x: Vec<i32> = (0..job.h_in() * job.w_in() * job.k_in)
            .map(|_| rng.range_i32(128, 256))
            .collect();
        let w: Vec<i32> = (0..job.k_out * job.k_in * 9)
            .map(|_| rng.range_i32(-128, 128))
            .collect();
        let scalar = conv_bitserial(&job, &x, &w, &nq).unwrap();
        for width in [PlaneWidth::W32, PlaneWidth::W64, PlaneWidth::W128] {
            let pw = pack_weights_with(&job, &w, width).unwrap();
            assert_eq!(
                conv_bitserial_packed(&job, &x, &pw, &nq).unwrap(),
                scalar,
                "{width} diverged on the random extreme-width job"
            );
        }
    }

    /// The plan-compile width policy: one 32-channel group stays on the
    /// literal hardware layout, anything wider takes 64-lane words.
    #[test]
    fn width_policy_and_byte_accounting() {
        let narrow = RbeJob::conv3x3(2, 2, 32, 4, 1, 4, 4, 4).unwrap();
        assert_eq!(PlaneWidth::for_job(&narrow), PlaneWidth::W32);
        let wide = RbeJob::conv3x3(2, 2, 33, 4, 1, 4, 4, 4).unwrap();
        assert_eq!(PlaneWidth::for_job(&wide), PlaneWidth::W64);
        // one u64 group exactly stays 64-lane; past it, 128-lane words
        let two = RbeJob::conv3x3(2, 2, 64, 4, 1, 4, 4, 4).unwrap();
        assert_eq!(PlaneWidth::for_job(&two), PlaneWidth::W64);
        let wider = RbeJob::conv3x3(2, 2, 65, 4, 1, 4, 4, 4).unwrap();
        assert_eq!(PlaneWidth::for_job(&wider), PlaneWidth::W128);

        // bytes track the actual Vec element size at each width:
        // k_in = 64 is 2 u32 groups or 1 u64 group — same byte count,
        // half the words
        let job = RbeJob::conv3x3(2, 2, 64, 4, 1, 4, 4, 4).unwrap();
        let w = vec![0i32; job.k_out * job.k_in * 9];
        let pw32 = pack_weights_with(&job, &w, PlaneWidth::W32).unwrap();
        let pw64 = pack_weights_with(&job, &w, PlaneWidth::W64).unwrap();
        assert_eq!(pw32.bytes(), 4 * 2 * 4 * 9 * 4);
        assert_eq!(pw64.bytes(), 4 * 1 * 4 * 9 * 8);
        assert_eq!(pw32.bytes(), pw64.bytes());
        // ragged tail: 33 channels cost a full second u32 group but
        // only one u64 group
        let jr = RbeJob::conv1x1(1, 1, 33, 2, 1, 2, 2, 2).unwrap();
        let wr = vec![0i32; 2 * 33];
        assert_eq!(
            pack_weights_with(&jr, &wr, PlaneWidth::W32).unwrap().bytes(),
            2 * 2 * 2 * 4
        );
        assert_eq!(
            pack_weights_with(&jr, &wr, PlaneWidth::W64).unwrap().bytes(),
            2 * 1 * 2 * 8
        );
        // a u128 plan holds a quarter of the u32 words at 4x the size:
        // k_in = 128 is 4 u32 groups or 1 u128 group — same byte count
        let j128 = RbeJob::conv1x1(1, 1, 128, 2, 1, 2, 2, 2).unwrap();
        let w128 = vec![0i32; 2 * 128];
        assert_eq!(
            pack_weights_with(&j128, &w128, PlaneWidth::W32)
                .unwrap()
                .bytes(),
            2 * 4 * 2 * 4
        );
        assert_eq!(
            pack_weights_with(&j128, &w128, PlaneWidth::W128)
                .unwrap()
                .bytes(),
            2 * 1 * 2 * 16
        );
    }

    /// The tuner's enumeration axis round-trips: every width in `ALL`
    /// survives lanes -> `from_lanes`, and unknown lane counts fail
    /// loudly instead of mapping to a nearby width.
    #[test]
    fn width_enumeration_round_trips() {
        assert_eq!(PlaneWidth::ALL.len(), 3);
        for w in PlaneWidth::ALL {
            assert_eq!(PlaneWidth::from_lanes(w.lanes()).unwrap(), w);
        }
        for lanes in [0usize, 1, 16, 33, 96, 256] {
            let err =
                PlaneWidth::from_lanes(lanes).unwrap_err().to_string();
            assert!(err.contains("lanes"), "{err}");
        }
    }

    #[test]
    fn packed_rejects_mismatched_geometry() {
        let j3 = RbeJob::conv3x3(2, 2, 8, 4, 1, 4, 4, 4).unwrap();
        let mut rng = Rng::new(5);
        let (_, w, nq) = random_job_inputs(&mut rng, &j3);
        let pw = pack_weights(&j3, &w).unwrap();
        // every layout-determining field is checked: mode (taps), k_out
        // and w_bits mismatches must all fail loudly, not index garbage
        let j1 = RbeJob::conv1x1(2, 2, 8, 4, 1, 4, 4, 4).unwrap();
        let x1 = vec![0i32; j1.h_in() * j1.w_in() * j1.k_in];
        assert!(conv_bitserial_packed(&j1, &x1, &pw, &nq).is_err());
        let jw = RbeJob::conv3x3(2, 2, 8, 4, 1, 6, 4, 4).unwrap();
        let xw = vec![0i32; jw.h_in() * jw.w_in() * jw.k_in];
        assert!(conv_bitserial_packed(&jw, &xw, &pw, &nq).is_err());
        let jk = RbeJob::conv3x3(2, 2, 8, 2, 1, 4, 4, 4).unwrap();
        let xk = vec![0i32; jk.h_in() * jk.w_in() * jk.k_in];
        let nq2 = NormQuant::unit(2);
        assert!(conv_bitserial_packed(&jk, &xk, &pw, &nq2).is_err());
        // lane-width mismatch between activations and weights is loud
        let zeros = vec![0i32; j3.h_in() * j3.w_in() * 8];
        let xp64 = pack_activations(&j3, &zeros, PlaneWidth::W64).unwrap();
        assert!(conv_bitserial_packed_tile(
            &j3,
            &xp64,
            &pw,
            &nq,
            ConvTile::full(&j3)
        )
        .is_err());
        // a ragged-channel plane whose GROUP count happens to match is
        // still a signature mismatch (k_in is checked directly, not
        // only via groups): 33 and 40 channels are both one 64-lane
        // group, but channels 33..39 would silently popcount as zero
        let ja = RbeJob::conv1x1(2, 2, 33, 4, 1, 4, 4, 4).unwrap();
        let jb = RbeJob::conv1x1(2, 2, 40, 4, 1, 4, 4, 4).unwrap();
        let xa = vec![0i32; ja.h_in() * ja.w_in() * 33];
        let xpa = pack_activations(&ja, &xa, PlaneWidth::W64).unwrap();
        let wb = vec![0i32; 4 * 40];
        let pwb = pack_weights_with(&jb, &wb, PlaneWidth::W64).unwrap();
        let nq4 = NormQuant::unit(4);
        let err = conv_bitserial_packed_tile(
            &jb,
            &xpa,
            &pwb,
            &nq4,
            ConvTile::full(&jb),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("k_in 33"), "{err}");
        // the weights side is checked symmetrically (and fails before
        // the O(n) activation pack): 40-channel packed weights must not
        // serve a 33-channel job sharing the group count
        let err = conv_bitserial_packed(&ja, &xa, &pwb, &nq4)
            .unwrap_err()
            .to_string();
        assert!(err.contains("k_in 40"), "{err}");
        // and out-of-bounds tiles are rejected
        let xp = pack_activations(&j3, &zeros, PlaneWidth::W32).unwrap();
        for bad in [
            ConvTile { row0: 0, row1: 3, ko0: 0, ko1: 4 },
            ConvTile { row0: 1, row1: 1, ko0: 0, ko1: 4 },
            ConvTile { row0: 0, row1: 2, ko0: 4, ko1: 5 },
        ] {
            assert!(
                conv_bitserial_packed_tile(&j3, &xp, &pw, &nq, bad)
                    .is_err(),
                "{bad:?}"
            );
        }
    }

    #[test]
    fn pack_rejects_out_of_range_weights() {
        let job = RbeJob::conv1x1(1, 1, 4, 1, 1, 2, 2, 2).unwrap();
        assert!(pack_weights(&job, &[2, 0, 0, 0]).is_err());
        assert!(pack_weights(&job, &[0, 0, 0]).is_err()); // wrong length
        assert!(
            pack_weights_with(&job, &[2, 0, 0, 0], PlaneWidth::W64).is_err()
        );
    }

    /// Requant clamp edge cases across every output precision: extreme
    /// positive/negative accumulators must pin to the exact unsigned /
    /// signed bounds, and the shift must floor (arithmetic) on negatives.
    #[test]
    fn requant_clamp_bounds_all_obits() {
        let nq = NormQuant::new(vec![3], vec![-7], 2);
        let spec = |acc: i64| (3 * acc - 7) >> 2;
        for o_bits in 2..=8usize {
            let omax = (1i64 << o_bits) - 1;
            let half = 1i64 << (o_bits - 1);
            // saturating high: both clips hit their max
            assert_eq!(nq.apply(0, i32::MAX as i64, o_bits) as i64, omax);
            assert_eq!(
                nq.apply_signed(0, i32::MAX as i64, o_bits) as i64,
                half - 1
            );
            // saturating low: ReLU pins 0, signed pins -2^(O-1)
            assert_eq!(nq.apply(0, i32::MIN as i64, o_bits), 0);
            assert_eq!(
                nq.apply_signed(0, i32::MIN as i64, o_bits) as i64,
                -half
            );
            // in-range values pass through both untouched
            for acc in [0i64, 1, half / 2, -1] {
                let v = spec(acc);
                if (0..=omax).contains(&v) {
                    assert_eq!(nq.apply(0, acc, o_bits) as i64, v);
                }
                if (-half..half).contains(&v) {
                    assert_eq!(nq.apply_signed(0, acc, o_bits) as i64, v);
                }
            }
        }
        // arithmetic shift floors: (1*(-3) + 0) >> 1 = -2, not -1
        let unit = NormQuant::new(vec![1], vec![0], 1);
        assert_eq!(unit.apply_signed(0, -3, 8), -2);
        assert_eq!(unit.apply(0, -3, 8), 0); // ReLU clips it away
    }

    /// Property: packing any `band_split` of the pixel range and
    /// stitching the bands is bitwise identical to the whole-plane pack
    /// — the packed words agree through the kernel at every width, band
    /// count and ragged channel count.
    #[test]
    fn banded_pack_assembles_bitwise_identical() {
        let mut rng = Rng::new(9119);
        for _ in 0..20 {
            let mode = if rng.f64() < 0.5 {
                RbeMode::Conv3x3
            } else {
                RbeMode::Conv1x1
            };
            let job = RbeJob {
                mode,
                h_out: 1 + rng.index(4),
                w_out: 1 + rng.index(4),
                k_in: *rng.pick(&[1, 3, 33, 64, 65, 129]),
                k_out: *rng.pick(&[1, 4]),
                stride: 1 + rng.index(2),
                w_bits: 2 + rng.index(7),
                i_bits: 2 + rng.index(7),
                o_bits: 2 + rng.index(7),
            };
            let (x, w, nq) = random_job_inputs(&mut rng, &job);
            let pixels = job.h_in() * job.w_in();
            let parts = 1 + rng.index(pixels.min(7));
            for width in
                [PlaneWidth::W32, PlaneWidth::W64, PlaneWidth::W128]
            {
                let whole = pack_activations(&job, &x, width).unwrap();
                let bands: Vec<ActivationBand> = band_split(pixels, parts)
                    .into_iter()
                    .map(|(p0, p1)| {
                        pack_activation_band(&job, &x, width, p0, p1)
                            .unwrap()
                    })
                    .collect();
                let stitched =
                    assemble_activation_bands(&job, width, bands).unwrap();
                // words agree through the kernel on the full tile
                let pw = pack_weights_with(&job, &w, width).unwrap();
                let full = ConvTile::full(&job);
                assert_eq!(
                    conv_bitserial_packed_tile(&job, &stitched, &pw, &nq, full)
                        .unwrap(),
                    conv_bitserial_packed_tile(&job, &whole, &pw, &nq, full)
                        .unwrap(),
                    "{width}, {parts} bands, job {job:?}"
                );
            }
        }
    }

    /// `band_split` exactly tiles `[0, n)` with non-empty in-order
    /// ranges for every part count, including parts > n.
    #[test]
    fn band_split_covers_exactly() {
        for n in [1usize, 2, 5, 16, 97] {
            for parts in 1..=20usize {
                let bands = band_split(n, parts);
                assert!(bands.len() <= parts.min(n));
                let mut expect = 0;
                for (a, b) in &bands {
                    assert_eq!(*a, expect, "n {n} parts {parts}");
                    assert!(a < b);
                    expect = *b;
                }
                assert_eq!(expect, n, "n {n} parts {parts}");
            }
        }
    }

    /// Band packing keeps every loud failure of the whole-plane pack:
    /// signed activations in the band's own slice, out-of-range bands,
    /// and malformed (out-of-order / gappy / mixed-width) assemblies.
    #[test]
    fn band_pack_rejects_bad_input() {
        let job = RbeJob::conv1x1(2, 2, 4, 1, 1, 4, 4, 4).unwrap();
        let pixels = job.h_in() * job.w_in();
        let mut x = vec![3i32; pixels * 4];
        x[2 * 4] = -1; // pixel 2 holds a signed activation
        // the band containing pixel 2 rejects loudly...
        let err = pack_activation_band(&job, &x, PlaneWidth::W32, 2, 3)
            .unwrap_err()
            .to_string();
        assert!(err.contains("negative"), "{err}");
        // ...a band that excludes it packs fine
        assert!(pack_activation_band(&job, &x, PlaneWidth::W32, 0, 2).is_ok());
        // out-of-range / empty bands are rejected
        assert!(
            pack_activation_band(&job, &x, PlaneWidth::W32, 0, pixels + 1)
                .is_err()
        );
        assert!(pack_activation_band(&job, &x, PlaneWidth::W32, 1, 1).is_err());
        // assemblies must tile in order, completely, at one width
        let ok = vec![0i32; pixels * 4];
        let band = |p0, p1, w| {
            pack_activation_band(&job, &ok, w, p0, p1).unwrap()
        };
        let w32 = PlaneWidth::W32;
        assert!(assemble_activation_bands(
            &job,
            w32,
            vec![band(2, pixels, w32), band(0, 2, w32)]
        )
        .is_err());
        assert!(
            assemble_activation_bands(&job, w32, vec![band(0, 2, w32)])
                .is_err()
        );
        let err = assemble_activation_bands(
            &job,
            w32,
            vec![band(0, 2, w32), band(2, pixels, PlaneWidth::W64)],
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("64-lane"), "{err}");
    }

    #[test]
    fn add_and_avgpool_match_ref_semantics() {
        // (15 + 15) >> 1 = 15 = omax at 4 bits
        assert_eq!(add_requant(&[15, 0], &[15, 1], 1, 4).unwrap(), vec![15, 0]);
        assert!(add_requant(&[1], &[1, 2], 0, 4).is_err());
        // 4 pixels x 2 channels, sum = 4 per channel, >> 2 = 1
        let x = vec![1i32; 8];
        assert_eq!(avgpool(&x, 4, 2, 2).unwrap(), vec![1, 1]);
        assert!(avgpool(&x, 3, 2, 2).is_err());
    }
}
