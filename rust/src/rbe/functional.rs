//! Bit-exact functional model of the RBE datapath.
//!
//! Two implementations of the same arithmetic:
//! * [`conv_bitserial`] computes exactly as the hardware (and the L1
//!   Pallas kernel) does: decompose into bit planes, AND, scale by
//!   ±2^(i+j) (weight MSB negative — two's complement), accumulate in
//!   32-bit, then normquant (Eq. 1 + Eq. 2);
//! * [`conv_reference`] is a plain signed-integer convolution + normquant
//!   (the specification, mirroring python `ref.py`).
//!
//! Property tests assert they agree for every precision/shape; integration
//! tests additionally compare against the PJRT artifact outputs, closing
//! the three-way equivalence the DESIGN.md §Functional-vs-timing split
//! requires.
//!
//! Tensor layout: activations `(H, W, K)` row-major `i32`, unsigned values
//! in `[0, 2^I)`; weights `(Kout, Kin, fy, fx)` signed in
//! `[-2^(W-1), 2^(W-1))`.

use std::borrow::Cow;

use anyhow::{bail, Result};

use super::config::{RbeJob, RbeMode};

/// Per-output-channel normalization parameters (Eq. 2).
#[derive(Debug, Clone)]
pub struct NormQuant {
    pub scale: Vec<i32>,
    pub bias: Vec<i32>,
    pub shift: u32,
}

impl NormQuant {
    /// Identity-ish normquant: scale 1, bias 0, shift 0.
    pub fn unit(k_out: usize) -> Self {
        Self { scale: vec![1; k_out], bias: vec![0; k_out], shift: 0 }
    }

    /// Apply Eq. 2 + ReLU clip to `o_bits`.
    #[inline]
    pub fn apply(&self, k: usize, acc: i64, o_bits: usize) -> i32 {
        let v = (self.scale[k] as i64 * acc + self.bias[k] as i64)
            >> self.shift;
        v.clamp(0, (1i64 << o_bits) - 1) as i32
    }
}

/// Trim a `(full, full, c)` activation plane to its strided extent
/// `(need, need, c)`. Artifacts take the layer's full input plane; the
/// datapath model wants exactly `(h_out - 1) * stride + k` rows/cols
/// ([`RbeJob::h_in`]). Borrows when no trim is needed.
pub fn trim_input(x: &[i32], full: usize, need: usize, c: usize) -> Cow<'_, [i32]> {
    debug_assert!(need <= full);
    if need == full {
        return Cow::Borrowed(x);
    }
    let mut v = Vec::with_capacity(need * need * c);
    for r in 0..need {
        v.extend_from_slice(&x[r * full * c..(r * full + need) * c]);
    }
    Cow::Owned(v)
}

fn tap_range(job: &RbeJob) -> usize {
    match job.mode {
        RbeMode::Conv3x3 => 3,
        RbeMode::Conv1x1 => 1,
    }
}

fn check_shapes(
    job: &RbeJob,
    x: &[i32],
    w: &[i32],
    nq: &NormQuant,
) -> Result<()> {
    let taps = tap_range(job);
    let want_x = job.h_in() * job.w_in() * job.k_in;
    let want_w = job.k_out * job.k_in * taps * taps;
    if x.len() != want_x {
        bail!("activation len {} != {}", x.len(), want_x);
    }
    if w.len() != want_w {
        bail!("weight len {} != {}", w.len(), want_w);
    }
    if nq.scale.len() != job.k_out || nq.bias.len() != job.k_out {
        bail!("normquant params must be per-output-channel");
    }
    let imax = 1 << job.i_bits;
    if x.iter().any(|&v| v < 0 || v >= imax) {
        bail!("activation out of unsigned {}-bit range", job.i_bits);
    }
    let whalf = 1 << (job.w_bits - 1);
    if w.iter().any(|&v| v < -whalf || v >= whalf) {
        bail!("weight out of signed {}-bit range", job.w_bits);
    }
    Ok(())
}

/// Plain integer convolution + normquant: the oracle.
pub fn conv_reference(
    job: &RbeJob,
    x: &[i32],
    w: &[i32],
    nq: &NormQuant,
) -> Result<Vec<i32>> {
    check_shapes(job, x, w, nq)?;
    let taps = tap_range(job);
    let (hi, wi) = (job.h_in(), job.w_in());
    let mut out = vec![0i32; job.h_out * job.w_out * job.k_out];
    for oy in 0..job.h_out {
        for ox in 0..job.w_out {
            for ko in 0..job.k_out {
                let mut acc: i64 = 0;
                for fy in 0..taps {
                    for fx in 0..taps {
                        let iy = oy * job.stride + fy;
                        let ix = ox * job.stride + fx;
                        debug_assert!(iy < hi && ix < wi);
                        for ki in 0..job.k_in {
                            let xv =
                                x[(iy * wi + ix) * job.k_in + ki] as i64;
                            let wv = w[((ko * job.k_in + ki) * taps + fy)
                                * taps
                                + fx] as i64;
                            acc += xv * wv;
                        }
                    }
                }
                out[(oy * job.w_out + ox) * job.k_out + ko] =
                    nq.apply(ko, acc, job.o_bits);
            }
        }
    }
    Ok(out)
}

/// Bit-serial convolution: Eq. 1 exactly as the datapath evaluates it.
///
/// For every (weight bit i, input bit j) pair the contribution is
/// `coef(i,j) * popcount(w_bit & x_bit)` accumulated over channels and
/// taps, where `coef = -2^(i+j)` for the weight MSB plane (two's
/// complement) and `+2^(i+j)` otherwise. Accumulation is wrapping 32-bit,
/// like the hardware Accums.
pub fn conv_bitserial(
    job: &RbeJob,
    x: &[i32],
    w: &[i32],
    nq: &NormQuant,
) -> Result<Vec<i32>> {
    check_shapes(job, x, w, nq)?;
    let taps = tap_range(job);
    let wi = job.w_in();
    let mut out = vec![0i32; job.h_out * job.w_out * job.k_out];
    for oy in 0..job.h_out {
        for ox in 0..job.w_out {
            for ko in 0..job.k_out {
                let mut acc: i32 = 0; // the 32-bit Accum register
                for i in 0..job.w_bits {
                    let neg = i == job.w_bits - 1 && job.w_bits > 1;
                    for j in 0..job.i_bits {
                        // binary dot product over taps x channels — what
                        // the BinConv AND arrays + popcount adders produce
                        let mut ones: i32 = 0;
                        for fy in 0..taps {
                            for fx in 0..taps {
                                let iy = oy * job.stride + fy;
                                let ix = ox * job.stride + fx;
                                for ki in 0..job.k_in {
                                    let xv = x
                                        [(iy * wi + ix) * job.k_in + ki]
                                        as u32;
                                    let wv = (w[((ko * job.k_in + ki)
                                        * taps
                                        + fy)
                                        * taps
                                        + fx]
                                        as u32)
                                        & ((1u32 << job.w_bits) - 1);
                                    ones += (((wv >> i) & 1)
                                        & ((xv >> j) & 1))
                                        as i32;
                                }
                            }
                        }
                        // dynamic shifter: scale by +/- 2^(i+j)
                        let contrib = ones.wrapping_shl((i + j) as u32);
                        acc = if neg {
                            acc.wrapping_sub(contrib)
                        } else {
                            acc.wrapping_add(contrib)
                        };
                    }
                }
                out[(oy * job.w_out + ox) * job.k_out + ko] =
                    nq.apply(ko, acc as i64, job.o_bits);
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_job_inputs(
        rng: &mut Rng,
        job: &RbeJob,
    ) -> (Vec<i32>, Vec<i32>, NormQuant) {
        let taps = tap_range(job);
        let x: Vec<i32> = (0..job.h_in() * job.w_in() * job.k_in)
            .map(|_| rng.range_i32(0, 1 << job.i_bits))
            .collect();
        let whalf = 1 << (job.w_bits - 1);
        let w: Vec<i32> = (0..job.k_out * job.k_in * taps * taps)
            .map(|_| rng.range_i32(-whalf, whalf))
            .collect();
        let nq = NormQuant {
            scale: (0..job.k_out).map(|_| rng.range_i32(1, 16)).collect(),
            bias: (0..job.k_out).map(|_| rng.range_i32(-500, 500)).collect(),
            shift: rng.range_i32(0, 12) as u32,
        };
        (x, w, nq)
    }

    /// Property: bit-serial == plain integer conv for every precision and
    /// mode (the core Eq. 1 equivalence).
    #[test]
    fn bitserial_equals_reference_sweep() {
        let mut rng = Rng::new(2024);
        for _ in 0..60 {
            let mode = if rng.f64() < 0.5 {
                RbeMode::Conv3x3
            } else {
                RbeMode::Conv1x1
            };
            let job = RbeJob {
                mode,
                h_out: 1 + rng.index(3),
                w_out: 1 + rng.index(3),
                k_in: *rng.pick(&[1, 3, 8, 32]),
                k_out: *rng.pick(&[1, 4, 16]),
                stride: 1 + rng.index(2),
                w_bits: 2 + rng.index(7),
                i_bits: 2 + rng.index(7),
                o_bits: 2 + rng.index(7),
            };
            let (x, w, nq) = random_job_inputs(&mut rng, &job);
            let a = conv_bitserial(&job, &x, &w, &nq).unwrap();
            let b = conv_reference(&job, &x, &w, &nq).unwrap();
            assert_eq!(a, b, "job {job:?}");
        }
    }

    #[test]
    fn relu_clips_negative_accumulations() {
        let job = RbeJob::conv1x1(1, 1, 4, 1, 1, 3, 2, 4).unwrap();
        let x = vec![3, 3, 3, 3];
        let w = vec![-4, -4, -4, -4];
        let nq = NormQuant::unit(1);
        let out = conv_bitserial(&job, &x, &w, &nq).unwrap();
        assert_eq!(out, vec![0]);
    }

    #[test]
    fn saturates_at_output_max() {
        let job = RbeJob::conv1x1(1, 1, 8, 1, 1, 8, 8, 3).unwrap();
        let x = vec![255; 8];
        let w = vec![127; 8];
        let nq = NormQuant::unit(1);
        let out = conv_reference(&job, &x, &w, &nq).unwrap();
        assert_eq!(out, vec![7]); // 2^3 - 1
    }

    #[test]
    fn rejects_out_of_range_inputs() {
        let job = RbeJob::conv1x1(1, 1, 4, 1, 1, 2, 2, 2).unwrap();
        let nq = NormQuant::unit(1);
        // activation 4 does not fit 2 bits
        assert!(conv_bitserial(&job, &[4, 0, 0, 0], &[1, 1, 1, 1], &nq)
            .is_err());
        // weight 2 does not fit signed 2 bits
        assert!(conv_bitserial(&job, &[1, 0, 0, 0], &[2, 0, 0, 0], &nq)
            .is_err());
    }

    #[test]
    fn strided_conv_matches() {
        let mut rng = Rng::new(7);
        let job = RbeJob::conv3x3(2, 2, 8, 4, 2, 4, 4, 8).unwrap();
        let (x, w, nq) = random_job_inputs(&mut rng, &job);
        assert_eq!(
            conv_bitserial(&job, &x, &w, &nq).unwrap(),
            conv_reference(&job, &x, &w, &nq).unwrap()
        );
    }
}
