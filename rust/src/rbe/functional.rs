//! Bit-exact functional model of the RBE datapath.
//!
//! Three implementations of the same arithmetic:
//! * [`conv_bitserial`] computes exactly as the hardware (and the L1
//!   Pallas kernel) does: decompose into bit planes, AND, scale by
//!   ±2^(i+j) (weight MSB negative — two's complement), accumulate in
//!   32-bit, then normquant (Eq. 1 + Eq. 2);
//! * [`conv_bitserial_packed`] is the same Eq. 1 datapath driven by a
//!   pre-packed weight operand ([`PackedWeights`], the §II-B3 bit-plane
//!   layout): the per-channel bit loop collapses into one AND + popcount
//!   per 32-channel word, which is what makes the precompiled-plan
//!   serving path fast. Bitwise identical to [`conv_bitserial`] by
//!   construction — each (i, j) contribution is the same popcount;
//! * [`conv_reference`] is a plain signed-integer convolution + normquant
//!   (the specification, mirroring python `ref.py`).
//!
//! Property tests assert they agree for every precision/shape; integration
//! tests additionally compare against the PJRT artifact outputs, closing
//! the three-way equivalence the DESIGN.md §Functional-vs-timing split
//! requires.
//!
//! The `*_planned` entry points serve precompiled layer plans
//! (`runtime::plan`): weights were validated once at plan-compile time,
//! so per-call work is only activation checking + streaming.
//!
//! Tensor layout: activations `(H, W, K)` row-major `i32`, unsigned values
//! in `[0, 2^I)`; weights `(Kout, Kin, fy, fx)` signed in
//! `[-2^(W-1), 2^(W-1))`.

use std::borrow::Cow;

use anyhow::{bail, Result};

use super::config::{RbeJob, RbeMode};

/// Per-output-channel normalization parameters (Eq. 2).
///
/// `signed` selects the output clip the conv/linear kernels apply:
/// `false` (the zoo default) is the ReLU `[0, 2^O - 1]` clip
/// ([`Self::apply`]), `true` the two's-complement
/// `[-2^(O-1), 2^(O-1) - 1]` clip ([`Self::apply_signed`]) used by
/// signed-head layers (`LayerOp::LinearSigned`).
#[derive(Debug, Clone)]
pub struct NormQuant {
    pub scale: Vec<i32>,
    pub bias: Vec<i32>,
    pub shift: u32,
    pub signed: bool,
}

impl NormQuant {
    /// Unsigned (ReLU-clipped) normquant — the zoo default.
    pub fn new(scale: Vec<i32>, bias: Vec<i32>, shift: u32) -> Self {
        Self { scale, bias, shift, signed: false }
    }

    /// Signed (no-ReLU) normquant for `LinearSigned` heads.
    pub fn new_signed(scale: Vec<i32>, bias: Vec<i32>, shift: u32) -> Self {
        Self { scale, bias, shift, signed: true }
    }

    /// Identity-ish normquant: scale 1, bias 0, shift 0.
    pub fn unit(k_out: usize) -> Self {
        Self::new(vec![1; k_out], vec![0; k_out], 0)
    }

    /// Apply Eq. 2 with whichever clip this instance selects.
    #[inline]
    pub fn quantize(&self, k: usize, acc: i64, o_bits: usize) -> i32 {
        if self.signed {
            self.apply_signed(k, acc, o_bits)
        } else {
            self.apply(k, acc, o_bits)
        }
    }

    /// Apply Eq. 2 + ReLU clip to `o_bits`.
    ///
    /// Audit note (requant clamp bounds): every layer of the built-in
    /// zoo applies ReLU before quantization, so the unconditional
    /// `[0, 2^O - 1]` clip here matches both the bit-serial reference
    /// and python `ref.py` (`np.clip(v, 0, (1 << o_bits) - 1)`)
    /// bit-exactly — no divergence. The bound is only correct *because*
    /// of the ReLU; signed-output layers must use [`Self::apply_signed`]
    /// instead, which the edge-case property tests below pin down.
    #[inline]
    pub fn apply(&self, k: usize, acc: i64, o_bits: usize) -> i32 {
        let v = (self.scale[k] as i64 * acc + self.bias[k] as i64)
            >> self.shift;
        v.clamp(0, (1i64 << o_bits) - 1) as i32
    }

    /// Apply Eq. 2 with a *signed* (no-ReLU) clip to `o_bits`:
    /// `clamp(v, -2^(O-1), 2^(O-1) - 1)`, the two's-complement output
    /// range. The shift stays arithmetic (floor division), matching
    /// numpy's `>>` on negative int64.
    #[inline]
    pub fn apply_signed(&self, k: usize, acc: i64, o_bits: usize) -> i32 {
        let v = (self.scale[k] as i64 * acc + self.bias[k] as i64)
            >> self.shift;
        let half = 1i64 << (o_bits - 1);
        v.clamp(-half, half - 1) as i32
    }
}

/// Trim a `(full, full, c)` activation plane to its strided extent
/// `(need, need, c)`. Artifacts take the layer's full input plane; the
/// datapath model wants exactly `(h_out - 1) * stride + k` rows/cols
/// ([`RbeJob::h_in`]). Borrows when no trim is needed.
pub fn trim_input(x: &[i32], full: usize, need: usize, c: usize) -> Cow<'_, [i32]> {
    debug_assert!(need <= full);
    if need == full {
        return Cow::Borrowed(x);
    }
    let mut v = Vec::with_capacity(need * need * c);
    for r in 0..need {
        v.extend_from_slice(&x[r * full * c..(r * full + need) * c]);
    }
    Cow::Owned(v)
}

fn tap_range(job: &RbeJob) -> usize {
    match job.mode {
        RbeMode::Conv3x3 => 3,
        RbeMode::Conv1x1 => 1,
    }
}

fn check_activations(job: &RbeJob, x: &[i32]) -> Result<()> {
    let want_x = job.h_in() * job.w_in() * job.k_in;
    if x.len() != want_x {
        bail!("activation len {} != {}", x.len(), want_x);
    }
    let imax = 1 << job.i_bits;
    if x.iter().any(|&v| v < 0 || v >= imax) {
        bail!("activation out of unsigned {}-bit range", job.i_bits);
    }
    Ok(())
}

/// Validate a raw weight tensor against the job signature (length +
/// signed range). Public so plan compilation can validate *once* and
/// then stream through the unchecked `*_planned` entry points.
pub fn check_weights(job: &RbeJob, w: &[i32]) -> Result<()> {
    let taps = tap_range(job);
    let want_w = job.k_out * job.k_in * taps * taps;
    if w.len() != want_w {
        bail!("weight len {} != {}", w.len(), want_w);
    }
    let whalf = 1 << (job.w_bits - 1);
    if w.iter().any(|&v| v < -whalf || v >= whalf) {
        bail!("weight out of signed {}-bit range", job.w_bits);
    }
    Ok(())
}

fn check_normquant(job: &RbeJob, nq: &NormQuant) -> Result<()> {
    if nq.scale.len() != job.k_out || nq.bias.len() != job.k_out {
        bail!("normquant params must be per-output-channel");
    }
    Ok(())
}

fn check_shapes(
    job: &RbeJob,
    x: &[i32],
    w: &[i32],
    nq: &NormQuant,
) -> Result<()> {
    check_activations(job, x)?;
    check_weights(job, w)?;
    check_normquant(job, nq)
}

/// Plain integer convolution + normquant: the oracle.
pub fn conv_reference(
    job: &RbeJob,
    x: &[i32],
    w: &[i32],
    nq: &NormQuant,
) -> Result<Vec<i32>> {
    check_shapes(job, x, w, nq)?;
    Ok(conv_reference_core(job, x, w, nq))
}

/// Plan-driven oracle entry point: weights (and normquant shapes) were
/// validated once at plan-compile time, so per-call checking is the
/// activation stream only. Bitwise identical to [`conv_reference`].
pub fn conv_reference_planned(
    job: &RbeJob,
    x: &[i32],
    w: &[i32],
    nq: &NormQuant,
) -> Result<Vec<i32>> {
    check_activations(job, x)?;
    debug_assert!(check_weights(job, w).is_ok());
    debug_assert!(check_normquant(job, nq).is_ok());
    Ok(conv_reference_core(job, x, w, nq))
}

fn conv_reference_core(
    job: &RbeJob,
    x: &[i32],
    w: &[i32],
    nq: &NormQuant,
) -> Vec<i32> {
    let taps = tap_range(job);
    let (hi, wi) = (job.h_in(), job.w_in());
    let mut out = vec![0i32; job.h_out * job.w_out * job.k_out];
    for oy in 0..job.h_out {
        for ox in 0..job.w_out {
            for ko in 0..job.k_out {
                let mut acc: i64 = 0;
                for fy in 0..taps {
                    for fx in 0..taps {
                        let iy = oy * job.stride + fy;
                        let ix = ox * job.stride + fx;
                        debug_assert!(iy < hi && ix < wi);
                        for ki in 0..job.k_in {
                            let xv =
                                x[(iy * wi + ix) * job.k_in + ki] as i64;
                            let wv = w[((ko * job.k_in + ki) * taps + fy)
                                * taps
                                + fx] as i64;
                            acc += xv * wv;
                        }
                    }
                }
                out[(oy * job.w_out + ox) * job.k_out + ko] =
                    nq.quantize(ko, acc, job.o_bits);
            }
        }
    }
    out
}

/// Bit-serial convolution: Eq. 1 exactly as the datapath evaluates it.
///
/// For every (weight bit i, input bit j) pair the contribution is
/// `coef(i,j) * popcount(w_bit & x_bit)` accumulated over channels and
/// taps, where `coef = -2^(i+j)` for the weight MSB plane (two's
/// complement) and `+2^(i+j)` otherwise. Accumulation is wrapping 32-bit,
/// like the hardware Accums.
pub fn conv_bitserial(
    job: &RbeJob,
    x: &[i32],
    w: &[i32],
    nq: &NormQuant,
) -> Result<Vec<i32>> {
    check_shapes(job, x, w, nq)?;
    let taps = tap_range(job);
    let wi = job.w_in();
    let mut out = vec![0i32; job.h_out * job.w_out * job.k_out];
    for oy in 0..job.h_out {
        for ox in 0..job.w_out {
            for ko in 0..job.k_out {
                let mut acc: i32 = 0; // the 32-bit Accum register
                for i in 0..job.w_bits {
                    let neg = i == job.w_bits - 1 && job.w_bits > 1;
                    for j in 0..job.i_bits {
                        // binary dot product over taps x channels — what
                        // the BinConv AND arrays + popcount adders produce
                        let mut ones: i32 = 0;
                        for fy in 0..taps {
                            for fx in 0..taps {
                                let iy = oy * job.stride + fy;
                                let ix = ox * job.stride + fx;
                                for ki in 0..job.k_in {
                                    let xv = x
                                        [(iy * wi + ix) * job.k_in + ki]
                                        as u32;
                                    let wv = (w[((ko * job.k_in + ki)
                                        * taps
                                        + fy)
                                        * taps
                                        + fx]
                                        as u32)
                                        & ((1u32 << job.w_bits) - 1);
                                    ones += (((wv >> i) & 1)
                                        & ((xv >> j) & 1))
                                        as i32;
                                }
                            }
                        }
                        // dynamic shifter: scale by +/- 2^(i+j)
                        let contrib = ones.wrapping_shl((i + j) as u32);
                        acc = if neg {
                            acc.wrapping_sub(contrib)
                        } else {
                            acc.wrapping_add(contrib)
                        };
                    }
                }
                out[(oy * job.w_out + ox) * job.k_out + ko] =
                    nq.quantize(ko, acc as i64, job.o_bits);
            }
        }
    }
    Ok(out)
}

/// Weights pre-packed into 32-channel bit-plane words — the §II-B3 TCDM
/// layout the streamer feeds the BinConvs from, and the weight half of a
/// precompiled layer plan.
///
/// Bit `c` of `planes[((ko * groups + g) * w_bits + i) * taps² + t]` is
/// bit `i` of the two's-complement weight for output channel `ko`, input
/// channel `g * 32 + c`, filter tap `t` (`t = fy * taps + fx`). Ragged
/// channel tails are zero-padded, contributing nothing to any popcount.
#[derive(Debug, Clone)]
pub struct PackedWeights {
    planes: Vec<u32>,
    groups: usize,
    taps: usize,
    k_out: usize,
    w_bits: usize,
}

impl PackedWeights {
    /// Packed bytes held (what the TCDM would store) — the number a
    /// plan-cache eviction policy would account.
    pub fn bytes(&self) -> usize {
        self.planes.len() * 4
    }
}

/// Validate + pack a raw `(Kout, Kin, fy, fx)` weight tensor into the
/// bit-plane layout, once per plan compilation.
pub fn pack_weights(job: &RbeJob, w: &[i32]) -> Result<PackedWeights> {
    check_weights(job, w)?;
    let taps = tap_range(job);
    let taps2 = taps * taps;
    let groups = job.k_in.div_ceil(32);
    let wmask = (1u32 << job.w_bits) - 1;
    let mut planes = vec![0u32; job.k_out * groups * job.w_bits * taps2];
    for ko in 0..job.k_out {
        for ki in 0..job.k_in {
            let (g, c) = (ki / 32, ki % 32);
            for t in 0..taps2 {
                let wv = (w[(ko * job.k_in + ki) * taps2 + t] as u32) & wmask;
                for i in 0..job.w_bits {
                    if (wv >> i) & 1 == 1 {
                        planes[((ko * groups + g) * job.w_bits + i) * taps2
                            + t] |= 1 << c;
                    }
                }
            }
        }
    }
    Ok(PackedWeights {
        planes,
        groups,
        taps,
        k_out: job.k_out,
        w_bits: job.w_bits,
    })
}

/// Bit-serial convolution over pre-packed weights: the plan-driven fast
/// path. Activations are packed into the same 32-channel bit-plane words
/// on entry (amortized over all `k_out` channels), then every (i, j)
/// contribution is one AND + popcount per word instead of a per-channel
/// bit walk. The (i, j) popcount totals are the same integers
/// [`conv_bitserial`] accumulates, and wrapping 32-bit addition is
/// associative, so outputs are bitwise identical.
pub fn conv_bitserial_packed(
    job: &RbeJob,
    x: &[i32],
    pw: &PackedWeights,
    nq: &NormQuant,
) -> Result<Vec<i32>> {
    check_activations(job, x)?;
    check_normquant(job, nq)?;
    let taps = tap_range(job);
    let taps2 = taps * taps;
    let groups = job.k_in.div_ceil(32);
    // Every field that determines the plane layout must match, or the
    // indexing below reads wrong planes / out of bounds.
    if pw.taps != taps
        || pw.groups != groups
        || pw.k_out != job.k_out
        || pw.w_bits != job.w_bits
    {
        bail!(
            "packed weights were built for a different job signature \
             (taps {} / groups {} / k_out {} / w_bits {} vs \
             {taps} / {groups} / {} / {})",
            pw.taps,
            pw.groups,
            pw.k_out,
            pw.w_bits,
            job.k_out,
            job.w_bits
        );
    }
    let (hi, wi) = (job.h_in(), job.w_in());

    // Pack the activation plane: one u32 per (pixel, group, input bit).
    let mut xp = vec![0u32; hi * wi * groups * job.i_bits];
    for p in 0..hi * wi {
        for ki in 0..job.k_in {
            let v = x[p * job.k_in + ki] as u32;
            let (g, c) = (ki / 32, ki % 32);
            for j in 0..job.i_bits {
                if (v >> j) & 1 == 1 {
                    xp[(p * groups + g) * job.i_bits + j] |= 1 << c;
                }
            }
        }
    }

    let mut out = vec![0i32; job.h_out * job.w_out * job.k_out];
    for oy in 0..job.h_out {
        for ox in 0..job.w_out {
            for ko in 0..job.k_out {
                let wbase = ko * groups;
                let mut acc: i32 = 0; // the 32-bit Accum register
                for i in 0..job.w_bits {
                    let neg = i == job.w_bits - 1 && job.w_bits > 1;
                    for j in 0..job.i_bits {
                        let mut ones: i32 = 0;
                        for fy in 0..taps {
                            let iy = oy * job.stride + fy;
                            for fx in 0..taps {
                                let ix = ox * job.stride + fx;
                                let px = (iy * wi + ix) * groups;
                                for g in 0..groups {
                                    let xw = xp[(px + g) * job.i_bits + j];
                                    let ww = pw.planes[((wbase + g)
                                        * job.w_bits
                                        + i)
                                        * taps2
                                        + fy * taps
                                        + fx];
                                    ones += (xw & ww).count_ones() as i32;
                                }
                            }
                        }
                        let contrib = ones.wrapping_shl((i + j) as u32);
                        acc = if neg {
                            acc.wrapping_sub(contrib)
                        } else {
                            acc.wrapping_add(contrib)
                        };
                    }
                }
                out[(oy * job.w_out + ox) * job.k_out + ko] =
                    nq.quantize(ko, acc as i64, job.o_bits);
            }
        }
    }
    Ok(out)
}

/// Residual add + requant (`ref.add_requant_ref` with unit scales):
/// `clip((a + b) >> shift, 0, 2^O - 1)` elementwise.
pub fn add_requant(
    a: &[i32],
    b: &[i32],
    shift: u32,
    o_bits: usize,
) -> Result<Vec<i32>> {
    if a.len() != b.len() {
        bail!("add operands differ in length: {} vs {}", a.len(), b.len());
    }
    let omax = (1i64 << o_bits) - 1;
    Ok(a.iter()
        .zip(b)
        .map(|(&a, &b)| (((a as i64 + b as i64) >> shift).clamp(0, omax)) as i32)
        .collect())
}

/// Global average pool (`ref.avgpool_ref`): per-channel sum over
/// `pixels` spatial positions, then arithmetic right shift.
pub fn avgpool(x: &[i32], pixels: usize, k: usize, shift: u32) -> Result<Vec<i32>> {
    if x.len() != pixels * k {
        bail!("avgpool input len {} != {pixels} pixels x {k} channels", x.len());
    }
    let mut sums = vec![0i64; k];
    for px in x.chunks_exact(k) {
        for (s, &v) in sums.iter_mut().zip(px) {
            *s += v as i64;
        }
    }
    Ok(sums.iter().map(|&s| (s >> shift) as i32).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_job_inputs(
        rng: &mut Rng,
        job: &RbeJob,
    ) -> (Vec<i32>, Vec<i32>, NormQuant) {
        let taps = tap_range(job);
        let x: Vec<i32> = (0..job.h_in() * job.w_in() * job.k_in)
            .map(|_| rng.range_i32(0, 1 << job.i_bits))
            .collect();
        let whalf = 1 << (job.w_bits - 1);
        let w: Vec<i32> = (0..job.k_out * job.k_in * taps * taps)
            .map(|_| rng.range_i32(-whalf, whalf))
            .collect();
        let nq = NormQuant {
            scale: (0..job.k_out).map(|_| rng.range_i32(1, 16)).collect(),
            bias: (0..job.k_out).map(|_| rng.range_i32(-500, 500)).collect(),
            shift: rng.range_i32(0, 12) as u32,
            // cover the signed (no-ReLU) clip in every kernel sweep
            signed: rng.f64() < 0.3,
        };
        (x, w, nq)
    }

    /// Property: bit-serial == plain integer conv for every precision and
    /// mode (the core Eq. 1 equivalence).
    #[test]
    fn bitserial_equals_reference_sweep() {
        let mut rng = Rng::new(2024);
        for _ in 0..60 {
            let mode = if rng.f64() < 0.5 {
                RbeMode::Conv3x3
            } else {
                RbeMode::Conv1x1
            };
            let job = RbeJob {
                mode,
                h_out: 1 + rng.index(3),
                w_out: 1 + rng.index(3),
                k_in: *rng.pick(&[1, 3, 8, 32]),
                k_out: *rng.pick(&[1, 4, 16]),
                stride: 1 + rng.index(2),
                w_bits: 2 + rng.index(7),
                i_bits: 2 + rng.index(7),
                o_bits: 2 + rng.index(7),
            };
            let (x, w, nq) = random_job_inputs(&mut rng, &job);
            let a = conv_bitserial(&job, &x, &w, &nq).unwrap();
            let b = conv_reference(&job, &x, &w, &nq).unwrap();
            assert_eq!(a, b, "job {job:?}");
        }
    }

    /// All three kernels honour the signed (no-ReLU) clip: a negative
    /// accumulation survives as a negative output instead of pinning 0.
    #[test]
    fn signed_normquant_keeps_negative_logits_in_every_kernel() {
        let job = RbeJob::conv1x1(1, 1, 4, 1, 1, 3, 2, 4).unwrap();
        let x = vec![3, 3, 3, 3];
        let w = vec![-4, -4, -4, -4];
        let nq = NormQuant::new_signed(vec![1], vec![0], 0);
        // acc = -48; the signed 4-bit clip pins -8 (ReLU would give 0)
        assert_eq!(conv_bitserial(&job, &x, &w, &nq).unwrap(), vec![-8]);
        assert_eq!(conv_reference(&job, &x, &w, &nq).unwrap(), vec![-8]);
        let pw = pack_weights(&job, &w).unwrap();
        assert_eq!(
            conv_bitserial_packed(&job, &x, &pw, &nq).unwrap(),
            vec![-8]
        );
    }

    #[test]
    fn relu_clips_negative_accumulations() {
        let job = RbeJob::conv1x1(1, 1, 4, 1, 1, 3, 2, 4).unwrap();
        let x = vec![3, 3, 3, 3];
        let w = vec![-4, -4, -4, -4];
        let nq = NormQuant::unit(1);
        let out = conv_bitserial(&job, &x, &w, &nq).unwrap();
        assert_eq!(out, vec![0]);
    }

    #[test]
    fn saturates_at_output_max() {
        let job = RbeJob::conv1x1(1, 1, 8, 1, 1, 8, 8, 3).unwrap();
        let x = vec![255; 8];
        let w = vec![127; 8];
        let nq = NormQuant::unit(1);
        let out = conv_reference(&job, &x, &w, &nq).unwrap();
        assert_eq!(out, vec![7]); // 2^3 - 1
    }

    #[test]
    fn rejects_out_of_range_inputs() {
        let job = RbeJob::conv1x1(1, 1, 4, 1, 1, 2, 2, 2).unwrap();
        let nq = NormQuant::unit(1);
        // activation 4 does not fit 2 bits
        assert!(conv_bitserial(&job, &[4, 0, 0, 0], &[1, 1, 1, 1], &nq)
            .is_err());
        // weight 2 does not fit signed 2 bits
        assert!(conv_bitserial(&job, &[1, 0, 0, 0], &[2, 0, 0, 0], &nq)
            .is_err());
    }

    #[test]
    fn strided_conv_matches() {
        let mut rng = Rng::new(7);
        let job = RbeJob::conv3x3(2, 2, 8, 4, 2, 4, 4, 8).unwrap();
        let (x, w, nq) = random_job_inputs(&mut rng, &job);
        assert_eq!(
            conv_bitserial(&job, &x, &w, &nq).unwrap(),
            conv_reference(&job, &x, &w, &nq).unwrap()
        );
    }

    /// Property: the packed plan-driven datapath is bitwise identical to
    /// the scalar bit-serial model for every precision, mode, stride and
    /// ragged channel count (incl. k_in not a multiple of 32).
    #[test]
    fn packed_equals_scalar_bitserial_sweep() {
        let mut rng = Rng::new(4242);
        for _ in 0..40 {
            let mode = if rng.f64() < 0.5 {
                RbeMode::Conv3x3
            } else {
                RbeMode::Conv1x1
            };
            let job = RbeJob {
                mode,
                h_out: 1 + rng.index(3),
                w_out: 1 + rng.index(3),
                k_in: *rng.pick(&[1, 3, 31, 32, 33, 40, 64]),
                k_out: *rng.pick(&[1, 4, 16]),
                stride: 1 + rng.index(2),
                w_bits: 2 + rng.index(7),
                i_bits: 2 + rng.index(7),
                o_bits: 2 + rng.index(7),
            };
            let (x, w, nq) = random_job_inputs(&mut rng, &job);
            let pw = pack_weights(&job, &w).unwrap();
            assert_eq!(
                conv_bitserial_packed(&job, &x, &pw, &nq).unwrap(),
                conv_bitserial(&job, &x, &w, &nq).unwrap(),
                "job {job:?}"
            );
            assert_eq!(
                conv_reference_planned(&job, &x, &w, &nq).unwrap(),
                conv_reference(&job, &x, &w, &nq).unwrap(),
                "planned oracle, job {job:?}"
            );
        }
    }

    #[test]
    fn packed_rejects_mismatched_geometry() {
        let j3 = RbeJob::conv3x3(2, 2, 8, 4, 1, 4, 4, 4).unwrap();
        let mut rng = Rng::new(5);
        let (_, w, nq) = random_job_inputs(&mut rng, &j3);
        let pw = pack_weights(&j3, &w).unwrap();
        // every layout-determining field is checked: mode (taps), k_out
        // and w_bits mismatches must all fail loudly, not index garbage
        let j1 = RbeJob::conv1x1(2, 2, 8, 4, 1, 4, 4, 4).unwrap();
        let x1 = vec![0i32; j1.h_in() * j1.w_in() * j1.k_in];
        assert!(conv_bitserial_packed(&j1, &x1, &pw, &nq).is_err());
        let jw = RbeJob::conv3x3(2, 2, 8, 4, 1, 6, 4, 4).unwrap();
        let xw = vec![0i32; jw.h_in() * jw.w_in() * jw.k_in];
        assert!(conv_bitserial_packed(&jw, &xw, &pw, &nq).is_err());
        let jk = RbeJob::conv3x3(2, 2, 8, 2, 1, 4, 4, 4).unwrap();
        let xk = vec![0i32; jk.h_in() * jk.w_in() * jk.k_in];
        let nq2 = NormQuant::unit(2);
        assert!(conv_bitserial_packed(&jk, &xk, &pw, &nq2).is_err());
    }

    #[test]
    fn pack_rejects_out_of_range_weights() {
        let job = RbeJob::conv1x1(1, 1, 4, 1, 1, 2, 2, 2).unwrap();
        assert!(pack_weights(&job, &[2, 0, 0, 0]).is_err());
        assert!(pack_weights(&job, &[0, 0, 0]).is_err()); // wrong length
    }

    /// Requant clamp edge cases across every output precision: extreme
    /// positive/negative accumulators must pin to the exact unsigned /
    /// signed bounds, and the shift must floor (arithmetic) on negatives.
    #[test]
    fn requant_clamp_bounds_all_obits() {
        let nq = NormQuant::new(vec![3], vec![-7], 2);
        let spec = |acc: i64| (3 * acc - 7) >> 2;
        for o_bits in 2..=8usize {
            let omax = (1i64 << o_bits) - 1;
            let half = 1i64 << (o_bits - 1);
            // saturating high: both clips hit their max
            assert_eq!(nq.apply(0, i32::MAX as i64, o_bits) as i64, omax);
            assert_eq!(
                nq.apply_signed(0, i32::MAX as i64, o_bits) as i64,
                half - 1
            );
            // saturating low: ReLU pins 0, signed pins -2^(O-1)
            assert_eq!(nq.apply(0, i32::MIN as i64, o_bits), 0);
            assert_eq!(
                nq.apply_signed(0, i32::MIN as i64, o_bits) as i64,
                -half
            );
            // in-range values pass through both untouched
            for acc in [0i64, 1, half / 2, -1] {
                let v = spec(acc);
                if (0..=omax).contains(&v) {
                    assert_eq!(nq.apply(0, acc, o_bits) as i64, v);
                }
                if (-half..half).contains(&v) {
                    assert_eq!(nq.apply_signed(0, acc, o_bits) as i64, v);
                }
            }
        }
        // arithmetic shift floors: (1*(-3) + 0) >> 1 = -2, not -1
        let unit = NormQuant::new(vec![1], vec![0], 1);
        assert_eq!(unit.apply_signed(0, -3, 8), -2);
        assert_eq!(unit.apply(0, -3, 8), 0); // ReLU clips it away
    }

    #[test]
    fn add_and_avgpool_match_ref_semantics() {
        // (15 + 15) >> 1 = 15 = omax at 4 bits
        assert_eq!(add_requant(&[15, 0], &[15, 1], 1, 4).unwrap(), vec![15, 0]);
        assert!(add_requant(&[1], &[1, 2], 0, 4).is_err());
        // 4 pixels x 2 channels, sum = 4 per channel, >> 2 = 1
        let x = vec![1i32; 8];
        assert_eq!(avgpool(&x, 4, 2, 2).unwrap(), vec![1, 1]);
        assert!(avgpool(&x, 3, 2, 2).is_err());
    }
}
