//! Fixed RBE datapath geometry (paper §II-B2).

/// Cores in the engine; each works on the receptive field of one output
/// pixel over 32 channels (3×3 output pixels per spatial iteration).
pub const CORES: usize = 9;
/// Blocks per core: the 9 filter taps in 3×3 mode, or up to 8 weight bits
/// bit-parallel in 1×1 mode (the 9th block is clock-gated).
pub const BLOCKS: usize = 9;
/// BinConv units per block: 4 input-activation bit planes in parallel.
pub const BINCONV_PER_BLOCK: usize = 4;
/// Width of one BinConv 1-bit dot product (channels per group).
pub const BINCONV_WIDTH: usize = 32;
/// 32-bit accumulator banks per core (one per output channel of a tile).
pub const ACCUMS_PER_CORE: usize = 32;
/// Streamer width: 288-bit TCDM load/store unit (§II-B2).
pub const STREAM_BITS: usize = 288;

/// Total single-bit multipliers: the paper's "10368 AND gates".
pub const AND_GATES: usize = CORES * BLOCKS * BINCONV_PER_BLOCK * BINCONV_WIDTH;

/// Channel tile handled per iteration (BinConv width).
pub const KIN_TILE: usize = BINCONV_WIDTH;
/// Output-channel tile (accumulator banks per core).
pub const KOUT_TILE: usize = ACCUMS_PER_CORE;
/// Output spatial tile side (9 cores = 3×3 output pixels).
pub const SPATIAL_TILE: usize = 3;
/// Input-activation bits consumed in parallel (BinConvs per block).
pub const IBITS_PARALLEL: usize = BINCONV_PER_BLOCK;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_constants() {
        assert_eq!(AND_GATES, 10368);
        assert_eq!(STREAM_BITS, 288);
        // 288 bits/cycle exactly feeds one weight-bit plane of a 3x3 tap
        // group: 9 taps x 32 channels x 1 bit.
        assert_eq!(BLOCKS * BINCONV_WIDTH, STREAM_BITS);
    }
}
