//! Cycle model of the RBE execution flow (paper Fig. 4).
//!
//! The controller FSM walks the tiled loop nest
//!
//! ```text
//! for spatial_tile (3x3 output pixels on the 9 Cores):
//!   for kout_tile (32 output channels on the per-core Accums):
//!     for kin_tile (32 channels on the BinConv width):
//!       for ibit_group (4 activation bits on the 4 BinConvs):
//!         LOAD    input patch bits into the input buffer
//!         COMPUTE kout_tile x w_bits (3x3: weight bits serialized)
//!                 kout_tile x 1      (1x1: weight bits block-parallel)
//!     NORMQUANT + STREAMOUT of the 32 finished accumulators
//! ```
//!
//! Derivations from the paper's geometry:
//! * one COMPUTE cycle consumes one 288-bit weight beat (9 taps x 32
//!   channels x 1 bit), exactly the streamer width, so weight streaming
//!   never stalls 3x3 COMPUTE;
//! * LOAD moves `patch^2 x 32ch x min(I,4)bits` through the 288-bit
//!   streamer;
//! * `COMPUTE_FIXED` models the per-tile pipeline drain / accumulator
//!   turnaround; it is the single calibrated constant, set so the
//!   COMPUTE-state throughput peak and the Fig. 13 end-to-end numbers
//!   match (see DESIGN.md §Calibration and tests below).

use super::config::{RbeJob, RbeMode};
use super::geometry::*;

/// Calibrated per-COMPUTE-segment overhead (accumulator bank turnaround,
/// pipeline fill/drain) — cycles. The single fitted constant of the model:
/// 48 cycles reproduces the paper's 1610 ops/cycle COMPUTE-state peak
/// (-8%), the 571 Gop/s W2/I4 end-to-end point (-2%) and the ~7100 G
/// 1b-ops/s W8/I4 binary peak (-1%) simultaneously.
pub const COMPUTE_FIXED: u64 = 48;
/// NORMQUANT cycles per (spatial, kout) tile: the per-core Quantizer walks
/// its 32 accumulators.
pub const NORMQUANT_CYCLES: u64 = 32;
/// Job-launch overhead (register-file context switch + FSM start).
pub const JOB_SETUP_CYCLES: u64 = 24;

/// Cycle breakdown of one job.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CyclePhases {
    pub setup: u64,
    pub load: u64,
    pub compute: u64,
    pub normquant: u64,
    pub streamout: u64,
}

impl CyclePhases {
    pub fn total(&self) -> u64 {
        self.setup + self.load + self.compute + self.normquant + self.streamout
    }

    /// The paper's "main LOAD-COMPUTE loop" cycles (Fig. 13 denominator).
    pub fn load_compute(&self) -> u64 {
        self.load + self.compute
    }
}

/// The RBE timing model.
#[derive(Debug, Clone, Default)]
pub struct RbeTiming;

impl RbeTiming {
    /// Number of loop tiles in each dimension.
    pub fn tiles(job: &RbeJob) -> (u64, u64, u64, u64) {
        let sp = (job.h_out.div_ceil(SPATIAL_TILE)
            * job.w_out.div_ceil(SPATIAL_TILE)) as u64;
        let kout = job.k_out.div_ceil(KOUT_TILE) as u64;
        let kin = job.k_in.div_ceil(KIN_TILE) as u64;
        let ibg = job.i_bits.div_ceil(IBITS_PARALLEL) as u64;
        (sp, kout, kin, ibg)
    }

    /// LOAD cycles for one input patch (one kin tile, one ibit group).
    pub fn load_cycles(job: &RbeJob) -> u64 {
        let patch = match job.mode {
            // 3x3 output pixels need (3-1)*stride+3 input pixels per dim
            RbeMode::Conv3x3 => (SPATIAL_TILE - 1) * job.stride + 3,
            // 1x1 mode also fills the (fixed-size) 5x5 input buffer
            // (paper §II-B4: "the streamers load a smaller patch of up to
            // 4-bits of 32 channels of 5x5 pixels").
            RbeMode::Conv1x1 => 5,
        };
        let bits = patch * patch * KIN_TILE * job.i_bits.min(IBITS_PARALLEL);
        (bits as u64).div_ceil(STREAM_BITS as u64)
    }

    /// COMPUTE cycles for one (kout tile, kin tile, ibit group) segment.
    /// Partial K_out tiles (< 32 channels) only iterate their real
    /// channels — the uloop bounds are programmed per job.
    pub fn compute_cycles(job: &RbeJob) -> u64 {
        let kout = job.k_out.min(KOUT_TILE) as u64;
        match job.mode {
            // weight bits serialized in time
            RbeMode::Conv3x3 => kout * job.w_bits as u64 + COMPUTE_FIXED,
            // weight bits parallel across Blocks; kout serialized
            RbeMode::Conv1x1 => kout + COMPUTE_FIXED / 4,
        }
    }

    /// STREAMOUT cycles per (spatial, kout) tile: 9 pixels x 32 channels x
    /// O bits through the 288-bit streamer.
    pub fn streamout_cycles(job: &RbeJob) -> u64 {
        let bits = CORES * KOUT_TILE * job.o_bits;
        (bits as u64).div_ceil(STREAM_BITS as u64)
    }

    /// Full phase breakdown for a job.
    pub fn phases(job: &RbeJob) -> CyclePhases {
        let (sp, kout, kin, ibg) = Self::tiles(job);
        let inner = kin * ibg;
        CyclePhases {
            setup: JOB_SETUP_CYCLES,
            load: sp * kout * inner * Self::load_cycles(job),
            compute: sp * kout * inner * Self::compute_cycles(job),
            normquant: sp * kout * NORMQUANT_CYCLES,
            streamout: sp * kout * Self::streamout_cycles(job),
        }
    }

    /// Total job latency in RBE cycles.
    pub fn cycles(job: &RbeJob) -> u64 {
        Self::phases(job).total()
    }

    /// W×I-bit ops per cycle over the LOAD+COMPUTE loop (Fig. 13 blue).
    pub fn ops_per_cycle_load_compute(job: &RbeJob) -> f64 {
        job.ops() as f64 / Self::phases(job).load_compute() as f64
    }

    /// 1×1-bit ops per cycle over the LOAD+COMPUTE loop (Fig. 13 red).
    pub fn binary_ops_per_cycle(job: &RbeJob) -> f64 {
        job.binary_ops() as f64 / Self::phases(job).load_compute() as f64
    }

    /// W×I-bit ops per cycle over the *whole* job (end-to-end throughput).
    pub fn ops_per_cycle_total(job: &RbeJob) -> f64 {
        job.ops() as f64 / Self::cycles(job) as f64
    }

    /// Average active BinConv fraction during COMPUTE (for the power
    /// model): 3x3 uses I/4 of the BinConvs in each block; 1x1 uses W of
    /// the 9 blocks and I/4 of their BinConvs.
    pub fn binconv_duty(job: &RbeJob) -> f64 {
        let ib = job.i_bits.min(IBITS_PARALLEL) as f64 / IBITS_PARALLEL as f64;
        match job.mode {
            RbeMode::Conv3x3 => ib,
            RbeMode::Conv1x1 => ib * (job.w_bits as f64 / BLOCKS as f64),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fig. 13's workload: K_in = 64, K_out = 64, 3x3 output.
    fn fig13_job(mode: RbeMode, w: usize, i: usize, o: usize) -> RbeJob {
        RbeJob {
            mode,
            h_out: 3,
            w_out: 3,
            k_in: 64,
            k_out: 64,
            stride: 1,
            w_bits: w,
            i_bits: i,
            o_bits: o,
        }
    }

    /// Paper: peak COMPUTE-state throughput 1610 ops/cycle at 3x3, W=2,
    /// I=2 or 4 (we assert the compute-only number within 10%).
    #[test]
    fn compute_state_peak_calibration() {
        for i in [2, 4] {
            let job = fig13_job(RbeMode::Conv3x3, 2, i, 4);
            let (sp, kout, kin, ibg) = RbeTiming::tiles(&job);
            let compute =
                sp * kout * kin * ibg * RbeTiming::compute_cycles(&job);
            let ops_c = job.ops() as f64 / compute as f64;
            assert!(
                (ops_c - 1610.0).abs() / 1610.0 < 0.10,
                "W=2 I={i}: compute-state {ops_c:.0} ops/c vs paper 1610"
            );
        }
    }

    /// Paper: highest throughput 571 Gop/s at 420 MHz => 1360 ops/cycle,
    /// in the W=2, I=4 3x3 configuration (within 10%).
    #[test]
    fn w2i4_end_to_end_calibration() {
        let job = fig13_job(RbeMode::Conv3x3, 2, 4, 4);
        let ops_c = RbeTiming::ops_per_cycle_load_compute(&job);
        let paper = 571.0e9 / 420.0e6;
        assert!(
            (ops_c - paper).abs() / paper < 0.10,
            "W2/I4 {ops_c:.0} ops/c vs paper {paper:.0}"
        );
    }

    /// Paper: ~7100 G 1b-ops/s at W=8, I=4 => ~16900 binary ops/cycle.
    #[test]
    fn w8i4_binary_throughput_calibration() {
        let job = fig13_job(RbeMode::Conv3x3, 8, 4, 8);
        let bops_c = RbeTiming::binary_ops_per_cycle(&job);
        let paper = 7100.0e9 / 420.0e6;
        assert!(
            (bops_c - paper).abs() / paper < 0.10,
            "W8/I4 binary {bops_c:.0} ops/c vs paper {paper:.0}"
        );
    }

    /// Paper: I=8 configurations lose ~50% actual throughput (two ibit
    /// groups iterate sequentially).
    #[test]
    fn i8_halves_throughput() {
        let j4 = fig13_job(RbeMode::Conv3x3, 4, 4, 4);
        let j8 = fig13_job(RbeMode::Conv3x3, 4, 8, 4);
        let r = RbeTiming::ops_per_cycle_load_compute(&j8)
            / RbeTiming::ops_per_cycle_load_compute(&j4);
        assert!((r - 0.5).abs() < 0.1, "I8/I4 ratio {r}");
    }

    /// Paper: W does not change 1x1 throughput (bit-parallel across
    /// blocks) but lowers 3x3 latency when reduced.
    #[test]
    fn w_sensitivity_by_mode() {
        let t1 = |w| {
            RbeTiming::ops_per_cycle_load_compute(&fig13_job(
                RbeMode::Conv1x1,
                w,
                4,
                4,
            ))
        };
        assert_eq!(t1(2), t1(8));
        let t3 = |w| {
            RbeTiming::ops_per_cycle_load_compute(&fig13_job(
                RbeMode::Conv3x3,
                w,
                4,
                4,
            ))
        };
        assert!(t3(2) > t3(4) && t3(4) > t3(8));
    }

    /// Paper: 1x1 is hit harder by LOAD (COMPUTE is short and comparable
    /// to LOAD), 3x3 suffers little overhead.
    #[test]
    fn load_fraction_by_mode() {
        let j3 = fig13_job(RbeMode::Conv3x3, 8, 4, 4);
        let j1 = fig13_job(RbeMode::Conv1x1, 8, 4, 4);
        let f = |j: &RbeJob| {
            let p = RbeTiming::phases(j);
            p.load as f64 / p.load_compute() as f64
        };
        assert!(f(&j3) < 0.1, "3x3 load fraction {}", f(&j3));
        assert!(f(&j1) > 0.2, "1x1 load fraction {}", f(&j1));
    }

    /// Binary utilization is higher with I>=4 (all BinConvs busy).
    #[test]
    fn binary_throughput_higher_at_i4() {
        let b2 = RbeTiming::binary_ops_per_cycle(&fig13_job(
            RbeMode::Conv3x3,
            4,
            2,
            4,
        ));
        let b4 = RbeTiming::binary_ops_per_cycle(&fig13_job(
            RbeMode::Conv3x3,
            4,
            4,
            4,
        ));
        assert!(b4 > 1.8 * b2, "I4 {b4} vs I2 {b2}");
    }

    /// Tiling covers ragged shapes (partial tiles round up).
    #[test]
    fn ragged_tiles_round_up() {
        let job = RbeJob {
            mode: RbeMode::Conv3x3,
            h_out: 4,
            w_out: 7,
            k_in: 40,
            k_out: 33,
            stride: 1,
            w_bits: 4,
            i_bits: 4,
            o_bits: 4,
        };
        let (sp, kout, kin, ibg) = RbeTiming::tiles(&job);
        assert_eq!((sp, kout, kin, ibg), (2 * 3, 2, 2, 1));
    }
}
