//! RBE job offload interface (paper §II-B4): a dual-context register file
//! lets the RISC-V cores enqueue up to two jobs; the engine runs the
//! oldest, then emits an event to the cluster event unit.

use std::collections::VecDeque;

use anyhow::{bail, Result};

use super::config::RbeJob;
use super::functional::{conv_bitserial, NormQuant};
use super::timing::RbeTiming;

/// Completion record for one job.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub job: RbeJob,
    /// Output activations (H_out, W_out, K_out), unpacked i32.
    pub output: Vec<i32>,
    /// Cycle in RBE time at which the job finished.
    pub finish_cycle: u64,
    /// Latency of this job alone.
    pub cycles: u64,
}

struct Pending {
    job: RbeJob,
    x: Vec<i32>,
    w: Vec<i32>,
    nq: NormQuant,
}

/// The engine-side queue: dual-context register file semantics (capacity
/// 2), FIFO order, per-job event on completion.
pub struct JobQueue {
    queue: VecDeque<Pending>,
    /// RBE-domain cycle counter (advances as jobs retire).
    now: u64,
    completed: Vec<JobResult>,
}

impl Default for JobQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl JobQueue {
    pub fn new() -> Self {
        Self { queue: VecDeque::new(), now: 0, completed: Vec::new() }
    }

    /// Number of job contexts currently occupied.
    pub fn depth(&self) -> usize {
        self.queue.len()
    }

    /// Enqueue a job; fails when both register-file contexts are busy
    /// (cores must wait for the free-context event, as on chip).
    pub fn offload(
        &mut self,
        job: RbeJob,
        x: Vec<i32>,
        w: Vec<i32>,
        nq: NormQuant,
    ) -> Result<()> {
        if self.queue.len() >= 2 {
            bail!("both RBE job contexts busy (offload would block)");
        }
        job.validate()?;
        self.queue.push_back(Pending { job, x, w, nq });
        Ok(())
    }

    /// Run the oldest pending job to completion; returns its result.
    pub fn run_next(&mut self) -> Result<Option<JobResult>> {
        let Some(p) = self.queue.pop_front() else {
            return Ok(None);
        };
        let output = conv_bitserial(&p.job, &p.x, &p.w, &p.nq)?;
        let cycles = RbeTiming::cycles(&p.job);
        self.now += cycles;
        let res = JobResult {
            job: p.job,
            output,
            finish_cycle: self.now,
            cycles,
        };
        self.completed.push(res.clone());
        Ok(Some(res))
    }

    /// Drain the queue, returning all results in completion order.
    pub fn run_all(&mut self) -> Result<Vec<JobResult>> {
        let mut out = Vec::new();
        while let Some(r) = self.run_next()? {
            out.push(r);
        }
        Ok(out)
    }

    pub fn now(&self) -> u64 {
        self.now
    }

    pub fn completed(&self) -> &[JobResult] {
        &self.completed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rbe::functional::conv_reference;
    use crate::util::Rng;

    fn mk_inputs(job: &RbeJob, seed: u64) -> (Vec<i32>, Vec<i32>, NormQuant) {
        let mut rng = Rng::new(seed);
        let x = (0..job.h_in() * job.w_in() * job.k_in)
            .map(|_| rng.range_i32(0, 1 << job.i_bits))
            .collect();
        let wh = 1 << (job.w_bits - 1);
        let taps = match job.mode {
            super::super::RbeMode::Conv3x3 => 9,
            super::super::RbeMode::Conv1x1 => 1,
        };
        let w = (0..job.k_out * job.k_in * taps)
            .map(|_| rng.range_i32(-wh, wh))
            .collect();
        (x, w, NormQuant::unit(job.k_out))
    }

    #[test]
    fn fifo_order_and_events() {
        let j1 = RbeJob::conv3x3(3, 3, 32, 32, 1, 2, 2, 2).unwrap();
        let j2 = RbeJob::conv1x1(3, 3, 32, 32, 1, 8, 8, 8).unwrap();
        let (x1, w1, n1) = mk_inputs(&j1, 1);
        let (x2, w2, n2) = mk_inputs(&j2, 2);
        let mut q = JobQueue::new();
        q.offload(j1, x1, w1, n1).unwrap();
        q.offload(j2, x2, w2, n2).unwrap();
        let rs = q.run_all().unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].job.mode, super::super::RbeMode::Conv3x3);
        assert_eq!(rs[1].finish_cycle, rs[0].cycles + rs[1].cycles);
    }

    #[test]
    fn third_offload_blocks() {
        let j = RbeJob::conv1x1(1, 1, 32, 32, 1, 2, 2, 2).unwrap();
        let mut q = JobQueue::new();
        for _ in 0..2 {
            let (x, w, n) = mk_inputs(&j, 3);
            q.offload(j, x, w, n).unwrap();
        }
        let (x, w, n) = mk_inputs(&j, 4);
        assert!(q.offload(j, x, w, n).is_err());
        q.run_next().unwrap();
        let (x, w, n) = mk_inputs(&j, 5);
        q.offload(j, x, w, n).unwrap(); // context freed
    }

    #[test]
    fn output_matches_reference() {
        let j = RbeJob::conv3x3(3, 3, 16, 8, 1, 3, 5, 6).unwrap();
        let (x, w, n) = mk_inputs(&j, 9);
        let mut q = JobQueue::new();
        q.offload(j, x.clone(), w.clone(), n.clone()).unwrap();
        let r = q.run_next().unwrap().unwrap();
        assert_eq!(r.output, conv_reference(&j, &x, &w, &n).unwrap());
    }
}
