//! Reconfigurable Binary Engine (paper §II-B, Figs. 3–4).
//!
//! RBE accelerates 3×3 and 1×1 convolutions with a runtime-reconfigurable
//! datapath supporting 2–8-bit activation/weight precision by decomposing
//! every W×I-bit product into single-bit AND contributions (Eq. 1) and
//! recombining them with power-of-two shifts into 32-bit accumulators,
//! then normalizing/quantizing (Eq. 2).
//!
//! Split into:
//! * [`geometry`] — the fixed datapath shape (9 Cores × 9 Blocks ×
//!   4 BinConvs × 32-wide = 10368 AND gates).
//! * [`config`] — job descriptors (mode, shape, precisions).
//! * [`functional`] — bit-exact functional model (bit-serial, mirroring
//!   the L1 Pallas kernel, plus a plain integer oracle).
//! * [`timing`] — LOAD/COMPUTE/NORMQUANT/STREAMOUT cycle model of the
//!   Fig. 4 loop nest, calibrated against Fig. 13.
//! * [`layout`] — the specialised TCDM bit-plane data layouts (§II-B3)
//!   and their packed byte sizes (used by the DORY tiler for DMA costs).
//! * [`job`] — the dual-context job queue and offload interface
//!   (§II-B4: up to 2 jobs enqueued, events at completion).

pub mod config;
pub mod functional;
pub mod geometry;
pub mod job;
pub mod layout;
pub mod timing;
pub mod uloop;

pub use config::{RbeJob, RbeMode};
pub use job::{JobQueue, JobResult};
pub use timing::{CyclePhases, RbeTiming};
