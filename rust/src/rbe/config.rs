//! RBE job descriptors.

use anyhow::{bail, Result};

/// Operating mode of the unified datapath (paper §II-B1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RbeMode {
    Conv3x3,
    Conv1x1,
}

/// One offloaded convolution job: a complete layer (or tile of a layer)
/// executed by the controller FSM + uloop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RbeJob {
    pub mode: RbeMode,
    /// Output spatial size.
    pub h_out: usize,
    pub w_out: usize,
    pub k_in: usize,
    pub k_out: usize,
    pub stride: usize,
    /// Weight precision, 2–8 bits (asymmetric precision supported,
    /// including non-power-of-two).
    pub w_bits: usize,
    /// Input-activation precision, 2–8 bits.
    pub i_bits: usize,
    /// Output precision, 2–8 bits.
    pub o_bits: usize,
}

impl RbeJob {
    pub fn conv3x3(
        h_out: usize,
        w_out: usize,
        k_in: usize,
        k_out: usize,
        stride: usize,
        w_bits: usize,
        i_bits: usize,
        o_bits: usize,
    ) -> Result<Self> {
        let j = Self {
            mode: RbeMode::Conv3x3,
            h_out,
            w_out,
            k_in,
            k_out,
            stride,
            w_bits,
            i_bits,
            o_bits,
        };
        j.validate()?;
        Ok(j)
    }

    pub fn conv1x1(
        h_out: usize,
        w_out: usize,
        k_in: usize,
        k_out: usize,
        stride: usize,
        w_bits: usize,
        i_bits: usize,
        o_bits: usize,
    ) -> Result<Self> {
        let j = Self {
            mode: RbeMode::Conv1x1,
            h_out,
            w_out,
            k_in,
            k_out,
            stride,
            w_bits,
            i_bits,
            o_bits,
        };
        j.validate()?;
        Ok(j)
    }

    pub fn validate(&self) -> Result<()> {
        for (name, b) in [
            ("w_bits", self.w_bits),
            ("i_bits", self.i_bits),
            ("o_bits", self.o_bits),
        ] {
            if !(2..=8).contains(&b) {
                bail!("RBE supports 2-8 bit {name}, got {b}");
            }
        }
        if self.h_out == 0 || self.w_out == 0 || self.k_in == 0 || self.k_out == 0
        {
            bail!("degenerate job shape {self:?}");
        }
        if !(1..=2).contains(&self.stride) {
            bail!("RBE stride must be 1 or 2, got {}", self.stride);
        }
        Ok(())
    }

    /// MAC operations in the layer.
    pub fn macs(&self) -> u64 {
        let taps = match self.mode {
            RbeMode::Conv3x3 => 9,
            RbeMode::Conv1x1 => 1,
        };
        (self.h_out * self.w_out * self.k_out * self.k_in * taps) as u64
    }

    /// W×I-bit operations (2 per MAC — the paper's throughput metric).
    pub fn ops(&self) -> u64 {
        self.macs() * 2
    }

    /// Equivalent 1×1-bit binary operations (the paper's "raw" metric,
    /// Fig. 13 red axis): every W×I MAC decomposes into W·I binary MACs.
    pub fn binary_ops(&self) -> u64 {
        self.ops() * (self.w_bits * self.i_bits) as u64
    }

    /// Input spatial size.
    pub fn h_in(&self) -> usize {
        match self.mode {
            RbeMode::Conv3x3 => (self.h_out - 1) * self.stride + 3,
            RbeMode::Conv1x1 => (self.h_out - 1) * self.stride + 1,
        }
    }

    pub fn w_in(&self) -> usize {
        match self.mode {
            RbeMode::Conv3x3 => (self.w_out - 1) * self.stride + 3,
            RbeMode::Conv1x1 => (self.w_out - 1) * self.stride + 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_bounds_enforced() {
        assert!(RbeJob::conv3x3(3, 3, 64, 64, 1, 1, 4, 4).is_err());
        assert!(RbeJob::conv3x3(3, 3, 64, 64, 1, 9, 4, 4).is_err());
        assert!(RbeJob::conv3x3(3, 3, 64, 64, 3, 8, 4, 4).is_err());
        assert!(RbeJob::conv3x3(3, 3, 64, 64, 1, 3, 5, 7).is_ok()); // non-pow2 ok
    }

    #[test]
    fn op_counts() {
        let j = RbeJob::conv3x3(3, 3, 64, 64, 1, 2, 4, 4).unwrap();
        assert_eq!(j.macs(), 9 * 64 * 64 * 9);
        assert_eq!(j.binary_ops(), j.ops() * 8);
        assert_eq!(j.h_in(), 5);
        let j2 = RbeJob::conv1x1(3, 3, 64, 64, 2, 8, 8, 8).unwrap();
        assert_eq!(j2.macs(), 9 * 64 * 64);
        assert_eq!(j2.h_in(), 5);
    }
}
