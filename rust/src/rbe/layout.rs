//! RBE TCDM data layouts (paper §II-B3).
//!
//! The chip stores activation/weight *bit planes* so the streamer can feed
//! the BinConvs without marshaling:
//! * activations: `(H, W, K/32, I, 32)` — channel-major 32-bit groups,
//!   one word per (pixel, channel-group, bit);
//! * 3×3 weights: `(Kout, Kin/32, W, 9, 32)`;
//! * 1×1 weights: `(Kout, Kin/32, W, 32)`.
//!
//! The simulator keeps tensors *unpacked* (one i32 per element) for
//! functional work, but all DMA/TCDM sizing uses these packed byte sizes —
//! they are what determines tiling and transfer time on the chip.

/// Packed bytes of an activation tensor (H, W, K) at `i_bits` precision.
pub fn act_bytes(h: usize, w: usize, k: usize, i_bits: usize) -> u64 {
    // (H, W, K/32, I, 32): one 32-bit word per (pixel, group, bit)
    (h * w * k.div_ceil(32) * i_bits * 4) as u64
}

/// Packed bytes of a 3×3 weight tensor (Kout, Kin, 3, 3) at `w_bits`.
pub fn weight3x3_bytes(k_out: usize, k_in: usize, w_bits: usize) -> u64 {
    // (Kout, Kin/32, W, 9, 32): 9 words of 32 bits per (kout, group, bit)
    (k_out * k_in.div_ceil(32) * w_bits * 9 * 4) as u64
}

/// Packed bytes of a 1×1 weight tensor (Kout, Kin) at `w_bits`.
pub fn weight1x1_bytes(k_out: usize, k_in: usize, w_bits: usize) -> u64 {
    (k_out * k_in.div_ceil(32) * w_bits * 4) as u64
}

/// Packed bytes of per-channel normquant parameters (scale + bias, 32-bit
/// each).
pub fn normquant_bytes(k_out: usize) -> u64 {
    (k_out * 2 * 4) as u64
}

/// Bytes of a software-layout (byte-per-element, HWC) activation tensor —
/// what the RISC-V kernels consume. The difference against [`act_bytes`]
/// is the marshaling cost paid when mixing RBE and software operators
/// (paper §III-B, Fig. 11 discussion).
pub fn act_bytes_sw(h: usize, w: usize, k: usize, bits: usize) -> u64 {
    // software packs sub-byte data 8/bits per byte
    ((h * w * k * bits).div_ceil(8)) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bitplane_sizes() {
        // 32x32x16 @ 4 bits: 32*32*1group*4bits words = 16 KiB
        assert_eq!(act_bytes(32, 32, 16, 4), 32 * 32 * 4 * 4);
        // 64x64 3x3 @ 2 bits: 64 * 2groups * 2bits * 9 * 4B = 18.4 KiB
        assert_eq!(weight3x3_bytes(64, 64, 2), 64 * 2 * 2 * 9 * 4);
        assert_eq!(weight1x1_bytes(32, 64, 8), 32 * 2 * 8 * 4);
        assert_eq!(normquant_bytes(64), 512);
    }

    #[test]
    fn ragged_channel_groups_round_up() {
        // 3 input channels still occupy one full 32-channel group
        assert_eq!(act_bytes(4, 4, 3, 8), act_bytes(4, 4, 32, 8));
    }

    #[test]
    fn sw_layout_smaller_for_subbyte() {
        // the RBE layout pads to 32-channel words; software packs tighter
        assert!(act_bytes_sw(8, 8, 16, 4) < act_bytes(8, 8, 16, 4));
    }
}
