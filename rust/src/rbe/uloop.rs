//! The RBE controller's *uloop*: a tiny software-configurable microcoded
//! loop processor (paper §II-B2, based on the XNOR-Neural-Engine IP [27])
//! that sequences the tiled loop nest of Fig. 4 with minimal overhead.
//!
//! The uloop executes a static *microcode image*: an ordered set of loop
//! levels (outer → inner), each with a trip count and a list of
//! address-register increments applied when that level steps. Walking the
//! nest yields, for every innermost iteration, the current tile indices
//! and the streamer base addresses for input/weight/output accesses.
//!
//! [`rbe_microcode`] builds the Fig. 4 nest for a job; tests cross-check
//! it against the closed-form [`RbeTiming`](super::RbeTiming) tile counts
//! and the §II-B3 data-layout offsets — the two independent descriptions
//! of the engine must agree.

use anyhow::{bail, Result};

use super::config::{RbeJob, RbeMode};
use super::geometry::*;

/// One address register of the uloop datapath.
pub type AddrReg = usize;

/// Increment applied to an address register when a loop level steps.
#[derive(Debug, Clone, Copy)]
pub struct Update {
    pub reg: AddrReg,
    pub delta: i64,
}

/// One loop level (outer levels first in the microcode image).
#[derive(Debug, Clone)]
pub struct LoopLevel {
    pub name: &'static str,
    pub count: u64,
    /// Applied when this level advances by one iteration.
    pub step: Vec<Update>,
    /// Applied when this level wraps back to zero (carries to the outer
    /// level) — typically rewinding what the steps accumulated.
    pub wrap: Vec<Update>,
}

/// A configured microcode image plus the address register file.
#[derive(Debug, Clone)]
pub struct Microcode {
    pub levels: Vec<LoopLevel>,
    pub regs: Vec<i64>,
}

/// Address register roles for the RBE image.
pub const R_INPUT: AddrReg = 0;
pub const R_WEIGHT: AddrReg = 1;
pub const R_OUTPUT: AddrReg = 2;

/// Snapshot of one innermost iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Iteration {
    /// Loop indices, outer → inner.
    pub idx: [u64; 4],
    pub input_addr: i64,
    pub weight_addr: i64,
    pub output_addr: i64,
}

impl Microcode {
    /// Total innermost iterations (product of trip counts).
    pub fn iterations(&self) -> u64 {
        self.levels.iter().map(|l| l.count).product()
    }

    /// Walk the nest, invoking `f` for every innermost iteration with the
    /// current indices and addresses. Address updates mirror the hardware:
    /// the *innermost* level's `step` fires after each iteration; a level
    /// that wraps applies `wrap` and propagates one `step` of its parent.
    pub fn walk(&mut self, mut f: impl FnMut(&Iteration)) -> Result<()> {
        let n = self.levels.len();
        if n == 0 || n > 4 {
            bail!("uloop supports 1-4 levels, got {n}");
        }
        if self.levels.iter().any(|l| l.count == 0) {
            bail!("zero trip count");
        }
        let mut idx = [0u64; 4];
        loop {
            f(&Iteration {
                idx,
                input_addr: self.regs[R_INPUT],
                weight_addr: self.regs[R_WEIGHT],
                output_addr: self.regs[R_OUTPUT],
            });
            // advance from the innermost level
            let mut level = n;
            loop {
                if level == 0 {
                    return Ok(()); // outermost wrapped: done
                }
                level -= 1;
                idx[level] += 1;
                if idx[level] < self.levels[level].count {
                    for u in &self.levels[level].step {
                        self.regs[u.reg] += u.delta;
                    }
                    break;
                }
                idx[level] = 0;
                for u in &self.levels[level].wrap {
                    self.regs[u.reg] += u.delta;
                }
            }
        }
    }
}

/// Build the Fig. 4 microcode image for a job, with the §II-B3 packed
/// layouts as address strides (byte units):
///
/// ```text
/// for spatial_tile:            input += patch stride, output += tile
///   for kout_tile:             weight += kout-slice bytes
///     for kin_tile:            input += kin-group plane, weight += group
///       for ibit_group:        input += bit-plane bytes
///         <LOAD + COMPUTE segment>
/// ```
pub fn rbe_microcode(job: &RbeJob) -> Result<Microcode> {
    job.validate()?;
    let (sp, kout, kin, ibg) = super::timing::RbeTiming::tiles(job);
    // byte strides from the packed layouts
    let in_bitplane = (job.h_in() * job.w_in() * 4) as i64; // one (group, bit) plane
    let in_group = in_bitplane * job.i_bits as i64;
    let w_group = match job.mode {
        RbeMode::Conv3x3 => (job.w_bits * 9 * 4) as i64,
        RbeMode::Conv1x1 => (job.w_bits * 4) as i64,
    };
    let w_kout_slice = w_group * kin as i64 * KOUT_TILE as i64;
    let out_tile = (SPATIAL_TILE * SPATIAL_TILE * job.o_bits * 4) as i64;

    // A level's `step` fires count-1 times per sweep; `wrap` rewinds
    // exactly what the steps accumulated (delta * (count-1)).
    let rewind = |delta: i64, count: u64| -> i64 {
        -delta * (count as i64 - 1)
    };
    let kin_in = in_group;
    let kin_w = w_group * KOUT_TILE as i64;
    let ibit_in = in_bitplane * IBITS_PARALLEL as i64;
    let levels = vec![
        LoopLevel {
            name: "spatial",
            count: sp,
            step: vec![Update { reg: R_OUTPUT, delta: out_tile }],
            wrap: vec![],
        },
        LoopLevel {
            name: "kout",
            count: kout,
            step: vec![Update { reg: R_WEIGHT, delta: w_kout_slice }],
            wrap: vec![Update {
                reg: R_WEIGHT,
                delta: rewind(w_kout_slice, kout),
            }],
        },
        LoopLevel {
            name: "kin",
            count: kin,
            step: vec![
                Update { reg: R_INPUT, delta: kin_in },
                Update { reg: R_WEIGHT, delta: kin_w },
            ],
            wrap: vec![
                Update { reg: R_INPUT, delta: rewind(kin_in, kin) },
                Update { reg: R_WEIGHT, delta: rewind(kin_w, kin) },
            ],
        },
        LoopLevel {
            name: "ibit",
            count: ibg,
            step: vec![Update { reg: R_INPUT, delta: ibit_in }],
            wrap: vec![Update { reg: R_INPUT, delta: rewind(ibit_in, ibg) }],
        },
    ];
    Ok(Microcode { levels, regs: vec![0; 3] })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rbe::RbeTiming;

    fn job(mode: RbeMode, k_in: usize, k_out: usize, w: usize, i: usize)
        -> RbeJob {
        RbeJob {
            mode,
            h_out: 6,
            w_out: 6,
            k_in,
            k_out,
            stride: 1,
            w_bits: w,
            i_bits: i,
            o_bits: 4,
        }
    }

    /// The microcode nest must visit exactly the tile product the
    /// closed-form timing model uses — the two independent descriptions
    /// of the Fig. 4 loop nest agree.
    #[test]
    fn iteration_count_matches_timing_tiles() {
        for (mode, ki, ko, w, i) in [
            (RbeMode::Conv3x3, 64, 64, 2, 4),
            (RbeMode::Conv3x3, 16, 32, 8, 8),
            (RbeMode::Conv1x1, 96, 40, 5, 2),
        ] {
            let j = job(mode, ki, ko, w, i);
            let mut mc = rbe_microcode(&j).unwrap();
            let (sp, kout, kin, ibg) = RbeTiming::tiles(&j);
            assert_eq!(mc.iterations(), sp * kout * kin * ibg);
            let mut n = 0;
            mc.walk(|_| n += 1).unwrap();
            assert_eq!(n, sp * kout * kin * ibg);
        }
    }

    /// Weight addresses walk the (Kout, Kin/32, W, ...) layout: within a
    /// spatial tile, consecutive (kout, kin) iterations advance by whole
    /// packed groups, and every spatial tile replays the same weight
    /// sequence (weights are reused across output pixels).
    #[test]
    fn weight_addresses_replay_per_spatial_tile() {
        let j = job(RbeMode::Conv3x3, 64, 64, 2, 4);
        let mut mc = rbe_microcode(&j).unwrap();
        let mut per_tile: Vec<Vec<i64>> = Vec::new();
        mc.walk(|it| {
            let sp = it.idx[0] as usize;
            if per_tile.len() <= sp {
                per_tile.push(Vec::new());
            }
            per_tile[sp].push(it.weight_addr);
        })
        .unwrap();
        for t in 1..per_tile.len() {
            assert_eq!(per_tile[t], per_tile[0], "tile {t}");
        }
        // first tile covers each kout slice once per kin group
        let expected: Vec<i64> = {
            let w_group = (j.w_bits * 9 * 4) as i64;
            let slice = w_group * 2 /*kin tiles*/ * 32;
            let mut v = Vec::new();
            for ko in 0..2i64 {
                for ki in 0..2i64 {
                    v.push(ko * slice + ki * w_group * 32);
                }
            }
            v
        };
        assert_eq!(per_tile[0], expected);
    }

    /// Input bit-plane address stride matches the (H, W, K/32, I, 32)
    /// packed layout: one word per pixel per plane.
    #[test]
    fn input_addresses_follow_bitplane_layout() {
        let j = job(RbeMode::Conv3x3, 32, 32, 4, 8); // ibg = 2
        let mut mc = rbe_microcode(&j).unwrap();
        let mut first_tile = Vec::new();
        mc.walk(|it| {
            if it.idx[0] == 0 {
                first_tile.push(it.input_addr);
            }
        })
        .unwrap();
        // kin = 1, ibg = 2: two iterations, second offset by 4 bit planes
        let plane = (j.h_in() * j.w_in() * 4) as i64;
        assert_eq!(first_tile, vec![0, 4 * plane]);
    }

    /// Output advances monotonically by one packed tile per spatial step.
    #[test]
    fn output_monotone_per_spatial_tile() {
        let j = job(RbeMode::Conv1x1, 32, 32, 3, 4);
        let mut mc = rbe_microcode(&j).unwrap();
        let mut outs = Vec::new();
        mc.walk(|it| outs.push(it.output_addr)).unwrap();
        let tile = (3 * 3 * j.o_bits * 4) as i64;
        let (sp, ..) = RbeTiming::tiles(&j);
        for s in 0..sp as usize {
            assert!(outs.contains(&(s as i64 * tile)));
        }
        let mut sorted = outs.clone();
        sorted.sort_unstable();
        assert_eq!(outs, sorted, "output address must be monotone");
    }

    #[test]
    fn degenerate_microcode_rejected() {
        let mut mc = Microcode {
            levels: vec![LoopLevel {
                name: "z",
                count: 0,
                step: vec![],
                wrap: vec![],
            }],
            regs: vec![0; 3],
        };
        assert!(mc.walk(|_| {}).is_err());
    }
}
