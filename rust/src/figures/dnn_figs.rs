//! DNN deployment figures (paper §IV): Fig. 17 (layer-wise latency &
//! energy, four configurations) and Fig. 18 (latency-component detail).

use anyhow::Result;

use crate::dnn::{resnet20_layers, PrecisionConfig};
use crate::mapping::Scheduler;
use crate::metrics::render_table;
use crate::power::{OperatingPoint, FBB_MAX_V};

/// Fig. 17: layer-wise latency and energy for ResNet-20/CIFAR-10 in four
/// operating-point × precision configurations, plus the 0.65 V + ABB
/// point the paper discusses (no performance penalty, ~21 µJ).
pub fn fig17() -> Result<String> {
    let s = Scheduler::default();
    let configs = [
        ("8-bit @0.8V", PrecisionConfig::Uniform8,
         OperatingPoint::at_vdd(0.8)),
        ("mixed @0.8V", PrecisionConfig::Mixed, OperatingPoint::at_vdd(0.8)),
        ("mixed @0.65V+ABB", PrecisionConfig::Mixed,
         OperatingPoint { vdd: 0.65, freq_mhz: 400.0, fbb_v: FBB_MAX_V }),
        ("mixed @0.5V", PrecisionConfig::Mixed, OperatingPoint::at_vdd(0.5)),
    ];
    let mut out = String::from(
        "Fig. 17 — ResNet-20/CIFAR-10 layer-wise latency & energy\n\
         (paper: mixed saves 68% vs 8-bit → ~28 µJ @0.8 V; ~21 µJ \
         @0.65 V+ABB; ~12 µJ @0.5 V)\n\n",
    );
    for (name, cfg, op) in configs {
        let rep = s.network_report(&resnet20_layers(cfg), &op)?;
        let rows: Vec<Vec<String>> = rep
            .layers
            .iter()
            .map(|l| {
                vec![
                    l.name.clone(),
                    format!("{:.1}", l.latency_us),
                    format!("{:.3}", l.energy_uj),
                ]
            })
            .collect();
        out.push_str(&format!(
            "== {name}: total {:.0} µs, {:.1} µJ ({:.2} Top/s/W) ==\n{}\n",
            rep.total_latency_us(),
            rep.total_energy_uj(),
            rep.tops_per_w(),
            render_table(&["layer", "latency µs", "energy µJ"], &rows)
        ));
    }
    Ok(out)
}

/// Fig. 18: per-layer off-chip / on-chip / compute latency components at
/// the 0.5 V mixed-precision point; the tallest bar bounds the layer.
pub fn fig18() -> Result<String> {
    let s = Scheduler::default();
    let rep = s.network_report(
        &resnet20_layers(PrecisionConfig::Mixed),
        &OperatingPoint::at_vdd(0.5),
    )?;
    let rows: Vec<Vec<String>> = rep
        .layers
        .iter()
        .map(|l| {
            vec![
                l.name.clone(),
                format!("{:.1}", l.off_us),
                format!("{:.1}", l.onchip_us),
                format!("{:.1}", l.exec_us),
                l.bound().to_string(),
            ]
        })
        .collect();
    let counts = |b: &str| rep.layers.iter().filter(|l| l.bound() == b).count();
    Ok(format!(
        "Fig. 18 — latency components, ResNet-20 mixed @0.5 V (latencies \
         fully overlapped; tallest defines the layer)\n{}\nbound classes: \
         compute {}, on-chip {}, off-chip {}",
        render_table(
            &["layer", "off-chip µs", "on-chip µs", "compute µs", "bound"],
            &rows
        ),
        counts("compute"),
        counts("on-chip"),
        counts("off-chip"),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig17_four_configs() {
        let t = fig17().unwrap();
        assert_eq!(t.matches("== ").count(), 4);
        assert!(t.contains("stage3.b2.add"));
    }

    #[test]
    fn fig18_bound_classes() {
        let t = fig18().unwrap();
        assert!(t.contains("off-chip"));
        assert!(t.contains("bound classes"));
    }
}
