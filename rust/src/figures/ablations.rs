//! Ablation studies over the design choices DESIGN.md calls out: what
//! each Marsellus mechanism is worth in isolation, measured on the same
//! models that regenerate the paper figures.
//!
//! * `ablate-ml`   — MAC&LOAD / NN-RF: inner-loop structure vs throughput.
//! * `ablate-dbuf` — DORY double buffering: overlapped vs serialized
//!   DMA/compute on ResNet-20.
//! * `ablate-abb`  — ABB generator quiet-window and slew-rate sensitivity.
//! * `ablate-banks`— TCDM banking factor vs 16-core matmul throughput.

use anyhow::Result;

use crate::abb::{AbbSim, Phase};
use crate::cluster::ClusterConfig;
use crate::dnn::{resnet20_layers, PrecisionConfig};
use crate::isa::Prec;
use crate::kernels::matmul::{random_operands, MatmulKernel, MatmulProblem};
use crate::mapping::Scheduler;
use crate::metrics::render_table;
use crate::power::OperatingPoint;

/// MAC&LOAD ablation: same matmul, four kernel structures.
pub fn ablate_macload(fast: bool) -> Result<String> {
    let (m, n, k) = if fast { (64, 16, 64) } else { (64, 32, 128) };
    let mut rows = Vec::new();
    for (name, kernel) in [
        ("Xpulp 8b (explicit loads, 4x2)", MatmulKernel::Xpulp8),
        ("XpulpNN 4b SIMD (no M&L)", MatmulKernel::Nn { prec: Prec::B4 }),
        ("M&L 8b (NN-RF, 4x4)", MatmulKernel::MacLoad { prec: Prec::B8 }),
        ("M&L 4b", MatmulKernel::MacLoad { prec: Prec::B4 }),
        ("M&L 2b", MatmulKernel::MacLoad { prec: Prec::B2 }),
    ] {
        let p = MatmulProblem { m, n, k, kernel, cores: 16 };
        let (a, b) = random_operands(m, n, k, kernel.prec(), 21);
        let (_, st) = p.run_with(ClusterConfig::default(), &a, &b)?;
        rows.push(vec![
            name.to_string(),
            format!("{:.1}", p.ops() as f64 / st.cycles as f64),
            format!("{:.0}%", st.dotp_utilization() * 100.0),
            format!("{}", st.total.mem_accesses),
        ]);
    }
    Ok(format!(
        "Ablation — MAC&LOAD / NN-RF value (16-core matmul {m}x{n}x{k})\n{}",
        render_table(
            &["kernel", "ops/cycle", "DOTP util", "memory accesses"],
            &rows
        )
    ))
}

/// Double-buffering ablation: per-layer latency = max(components)
/// (overlapped) vs sum(components) (serialized), over ResNet-20 mixed.
pub fn ablate_double_buffering() -> Result<String> {
    let s = Scheduler::default();
    let mut rows = Vec::new();
    for vdd in [0.5, 0.8] {
        let rep = s.network_report(
            &resnet20_layers(PrecisionConfig::Mixed),
            &OperatingPoint::at_vdd(vdd),
        )?;
        let overlapped = rep.total_latency_us();
        let serialized: f64 = rep
            .layers
            .iter()
            .map(|l| l.off_us + l.onchip_us + l.exec_us)
            .sum();
        rows.push(vec![
            format!("{vdd:.2} V"),
            format!("{overlapped:.0}"),
            format!("{serialized:.0}"),
            format!("{:.2}x", serialized / overlapped),
        ]);
    }
    Ok(format!(
        "Ablation — DORY double buffering (ResNet-20 mixed): overlapped \
         (tallest bar) vs serialized transfers\n{}",
        render_table(
            &["op point", "overlapped µs", "serialized µs", "saving"],
            &rows
        )
    ))
}

/// ABB control-loop sensitivity: quiet window and boost slew vs energy
/// and error behaviour on the Fig. 11 benchmark.
pub fn ablate_abb() -> Result<String> {
    let mut rows = Vec::new();
    for (qw, slew_cycles) in
        [(2u32, 310.0f64), (8, 310.0), (32, 310.0), (8, 1240.0), (8, 78.0)]
    {
        let mut sim = AbbSim::new(0.8, 470.0, true);
        sim.gen.cfg.quiet_windows = qw;
        sim.gen.cfg.boost_slew_v_per_cycle = 0.3 / slew_cycles;
        let res = sim.run(&Phase::fig11_benchmark(), 100.0);
        rows.push(vec![
            format!("{qw}"),
            format!("{slew_cycles:.0}"),
            format!("{}", res.boost_events),
            format!("{}", res.total_real_errors),
            format!("{:.1}", res.avg_power_mw),
        ]);
    }
    Ok(format!(
        "Ablation — ABB loop parameters (470 MHz @ 0.8 V, Fig. 11 \
         benchmark; paper values: quiet window ≈ 8, slew 0.3 V/310 cy)\n{}",
        render_table(
            &["quiet wnd", "slew cyc/0.3V", "boosts", "real errs",
              "avg mW"],
            &rows
        )
    ))
}

/// TCDM banking ablation: 16-core M&L matmul under different bank counts
/// is not directly configurable (the interleave is architectural), so we
/// sweep *cores* against the fixed 32 banks — the same conflict-pressure
/// axis the paper's 0.22 banking factor (32/16) addresses.
pub fn ablate_banking(fast: bool) -> Result<String> {
    let k = if fast { 64 } else { 128 };
    let mut rows = Vec::new();
    for cores in [1usize, 2, 4, 8, 16] {
        let p = MatmulProblem {
            m: 16 * cores.max(4), // keep ≥4 row blocks per core
            n: 16,
            k,
            kernel: MatmulKernel::MacLoad { prec: Prec::B8 },
            cores,
        };
        let (a, b) = random_operands(p.m, p.n, p.k, Prec::B8, 31);
        let mut cfg = ClusterConfig::default();
        cfg.cores = cores;
        let (_, st) = p.run_with(cfg, &a, &b)?;
        let conflict_pct = 100.0 * st.total.stall_conflict as f64
            / st.total.cycles.max(1) as f64;
        rows.push(vec![
            format!("{cores}"),
            format!("{:.2}", 32.0 / cores as f64),
            format!("{:.1}", p.ops() as f64 / st.cycles as f64),
            format!("{conflict_pct:.1}%"),
        ]);
    }
    Ok(format!(
        "Ablation — conflict pressure on the 32-bank TCDM (M&L 8b \
         matmul)\n{}",
        render_table(
            &["cores", "banks/core", "ops/cycle", "conflict stalls"],
            &rows
        )
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablations_render() {
        assert!(ablate_macload(true).unwrap().contains("M&L 2b"));
        let d = ablate_double_buffering().unwrap();
        assert!(d.contains("saving"));
        let a = ablate_abb().unwrap();
        assert!(a.contains("boosts"));
        assert!(ablate_banking(true).unwrap().contains("banks/core"));
    }

    /// Double buffering must actually save time (serialized > overlapped).
    #[test]
    fn double_buffering_saves() {
        let t = ablate_double_buffering().unwrap();
        for line in t.lines().filter(|l| l.ends_with('x')) {
            let x: f64 = line
                .rsplit_once(' ')
                .unwrap()
                .1
                .trim_end_matches('x')
                .parse()
                .unwrap();
            assert!(x > 1.05, "{line}");
        }
    }
}
