//! Performance figures driven by the ISS and the RBE timing model:
//! Fig. 13 (RBE throughput sweep), Fig. 14 (task speedups), Fig. 19
//! (energy/op summary) and the §III-C1 ISA comparison table.

use anyhow::Result;

use crate::cluster::ClusterConfig;
use crate::isa::Prec;
use crate::kernels::conv::ConvProblem;
use crate::kernels::fft::FftProblem;
use crate::kernels::matmul::{random_operands, MatmulKernel, MatmulProblem};
use crate::kernels::vecops::run_tensor_add;
use crate::metrics::{fj_per_op, render_table};
use crate::power::{fmax_mhz, OperatingPoint, PowerModel, Workload, FBB_MAX_V};
use crate::rbe::{RbeJob, RbeMode, RbeTiming};
use crate::util::Rng;

/// Measured software throughputs used by several figures/tables.
pub struct SwPerf {
    pub mmul8_ops_per_cycle: f64,
    pub mmul_ml8_ops_per_cycle: f64,
    pub mmul_ml4_ops_per_cycle: f64,
    pub mmul_ml2_ops_per_cycle: f64,
    pub fft_flops_per_cycle: f64,
    pub fp16_flops_per_cycle: f64,
    pub macload_utilization: f64,
}

/// Packed-FP16 dot-product microkernel on the ISS: the streaming
/// `vfmac.h2` loop (two operand loads per FMA, post-increment walking)
/// behind the paper's "Best SW (FP16)" row — a DSP dot-product, not a
/// register-blocked GEMM, so it is load-slot-bound rather than FPU-bound.
fn fp16_dotp_flops_per_cycle(iters: i32) -> Result<f64> {
    use crate::cluster::{Cluster, ClusterConfig, TCDM_BASE, TCDM_SIZE};
    use crate::isa::{AluOp, FOp, Instr, IsaLevel, ProgramBuilder};

    let mut b = ProgramBuilder::new("fp16_dotp_inner", IsaLevel::Xpulp);
    // per-core streams, staggered so cores touch different banks
    b.emit(Instr::CoreId { rd: 5 });
    b.emit(Instr::AluImm { op: AluOp::Sll, rd: 5, rs1: 5, imm: 2 });
    b.emit(Instr::AluImm {
        op: AluOp::Add,
        rd: 6,
        rs1: 5,
        imm: TCDM_BASE as i32,
    });
    b.emit(Instr::AluImm {
        op: AluOp::Add,
        rd: 7,
        rs1: 5,
        imm: (TCDM_BASE + TCDM_SIZE / 2) as i32,
    });
    b.emit(Instr::Li { rd: 8, imm: iters });
    let (ls, le) = (b.label(), b.label());
    b.hw_loop(0, 8, ls, le);
    b.bind(ls);
    b.emit(Instr::Flw { fd: 1, base: 6, offset: 0, post_inc: 4 });
    b.emit(Instr::Flw { fd: 2, base: 7, offset: 0, post_inc: 4 });
    b.emit(Instr::FAlu {
        op: FOp::Madd,
        lanes: 2,
        fd: 3,
        fs1: 1,
        fs2: 2,
        fs3: 3,
    });
    b.bind(le);
    let mut cl = Cluster::new(ClusterConfig::default());
    cl.load_spmd(b.build()?);
    let stats = cl.run()?;
    Ok(stats.total.flops as f64 / stats.cycles as f64)
}

fn mm_run(kernel: MatmulKernel, m: usize, n: usize, k: usize) -> Result<f64> {
    let p = MatmulProblem { m, n, k, kernel, cores: 16 };
    let (a, b) = random_operands(m, n, k, kernel.prec(), 99);
    let (_, stats) = p.run_with(ClusterConfig::default(), &a, &b)?;
    Ok(p.ops() as f64 / stats.cycles as f64)
}

/// Run the software benchmark suite on the ISS (16-core cluster).
pub fn measured_sw_perf(fast: bool) -> Result<SwPerf> {
    let (m, n, k) = if fast { (64, 16, 64) } else { (64, 32, 128) };
    let mmul8 = mm_run(MatmulKernel::Xpulp8, m, n, k)?;
    let ml8 = mm_run(MatmulKernel::MacLoad { prec: Prec::B8 }, m, n, k)?;
    let ml4 = mm_run(MatmulKernel::MacLoad { prec: Prec::B4 }, m, n, k)?;
    let ml2 = mm_run(MatmulKernel::MacLoad { prec: Prec::B2 }, m, n, k)?;
    let fft_n = if fast { 256 } else { 2048 };
    let fft = FftProblem { n: fft_n, cores: 16 };
    let mut rng = Rng::new(12);
    let sig: Vec<(f32, f32)> = (0..fft_n)
        .map(|_| (rng.f64() as f32 - 0.5, rng.f64() as f32 - 0.5))
        .collect();
    let (_, fstats) = fft.run_with(ClusterConfig::default(), &sig)?;
    // utilization measured single-core, long K
    let pu = MatmulProblem {
        m: 16,
        n: 8,
        k: if fast { 128 } else { 512 },
        kernel: MatmulKernel::MacLoad { prec: Prec::B8 },
        cores: 1,
    };
    let (a, b) = random_operands(pu.m, pu.n, pu.k, Prec::B8, 5);
    let (_, ustats) = pu.run_with(ClusterConfig::soc_controller(), &a, &b)?;
    Ok(SwPerf {
        mmul8_ops_per_cycle: mmul8,
        mmul_ml8_ops_per_cycle: ml8,
        mmul_ml4_ops_per_cycle: ml4,
        mmul_ml2_ops_per_cycle: ml2,
        fft_flops_per_cycle: fstats.total.flops as f64
            / fstats.cycles as f64,
        fp16_flops_per_cycle: fp16_dotp_flops_per_cycle(if fast {
            256
        } else {
            2048
        })?,
        macload_utilization: ustats.dotp_utilization(),
    })
}

/// One RBE operating point for tables (throughput + efficiency).
pub struct RbePoint {
    pub gops: f64,
    pub tops_per_w: f64,
}

pub fn rbe_point(w: usize, i: usize, vdd: f64, _abb: bool) -> RbePoint {
    let job = RbeJob {
        mode: RbeMode::Conv3x3,
        h_out: 3,
        w_out: 3,
        k_in: 64,
        k_out: 64,
        stride: 1,
        w_bits: w,
        i_bits: i,
        o_bits: i.min(8),
    };
    let op = OperatingPoint::at_vdd(vdd);
    let opc = RbeTiming::ops_per_cycle_total(&job);
    let gops = opc * op.freq_mhz / 1.0e3;
    let duty = (RbeTiming::binconv_duty(&job) * 100.0).round() as u8;
    let p = PowerModel.total_mw(Workload::Rbe { duty_pct: duty }, &op);
    RbePoint { gops, tops_per_w: gops / p }
}

/// Fig. 13: RBE LOAD-COMPUTE throughput sweep (K_in = K_out = 64, 3×3
/// output), in W×I-bit ops/cycle and 1×1-bit Gops/s at 420 MHz.
pub fn fig13() -> String {
    let mut rows = Vec::new();
    for mode in [RbeMode::Conv3x3, RbeMode::Conv1x1] {
        for w in [2, 4, 8] {
            for i in [2, 4, 8] {
                let job = RbeJob {
                    mode,
                    h_out: 3,
                    w_out: 3,
                    k_in: 64,
                    k_out: 64,
                    stride: 1,
                    w_bits: w,
                    i_bits: i,
                    o_bits: 4,
                };
                let opc = RbeTiming::ops_per_cycle_load_compute(&job);
                let bopc = RbeTiming::binary_ops_per_cycle(&job);
                rows.push(vec![
                    format!("{mode:?}"),
                    format!("{w}x{i}"),
                    format!("{opc:.0}"),
                    format!("{:.0}", opc * 420.0 / 1.0e3),
                    format!("{:.1}", bopc * 420.0 / 1.0e6),
                ]);
            }
        }
    }
    format!(
        "Fig. 13 — RBE main LOAD-COMPUTE loop throughput @0.8 V/420 MHz\n\
         (paper anchors: peak 1610 ops/cycle at 3x3 W2; 571 Gop/s at W2/I4;\n \
         ~7.1 T 1b-ops/s at W8/I4; I=8 halves throughput; 1x1 LOAD-bound)\n{}",
        render_table(
            &["mode", "WxI", "ops/cycle", "Gop/s", "T 1b-ops/s"],
            &rows
        )
    )
}

/// Fig. 14: task speedups vs the single SOC controller core.
pub fn fig14(fast: bool) -> Result<String> {
    let mut rows = Vec::new();

    // ---- FFT ----
    let n = if fast { 256 } else { 2048 };
    let mut rng = Rng::new(3);
    let sig: Vec<(f32, f32)> = (0..n)
        .map(|_| (rng.f64() as f32 - 0.5, rng.f64() as f32 - 0.5))
        .collect();
    let run_fft = |cores: usize| -> Result<u64> {
        let p = FftProblem { n, cores };
        let mut cfg = ClusterConfig::default();
        cfg.cores = cores;
        if cores == 1 {
            cfg = ClusterConfig::soc_controller();
        }
        Ok(p.run_with(cfg, &sig)?.1.cycles)
    };
    let fft_soc = run_fft(1)?;
    let fft_1 = fft_soc; // cluster core == SOC core for pure FP32 DSP
    let fft_16 = run_fft(16)?;
    rows.push(vec![
        format!("FFT-{n} (FP32)"),
        "1.0".into(),
        format!("{:.1}", fft_soc as f64 / fft_1 as f64),
        format!("{:.1}", fft_soc as f64 / fft_16 as f64),
        "-".into(),
        "-".into(),
    ]);

    // ---- Conv 3x3 and 1x1 (+BN), 9x9x64 output, 64 input channels ----
    let mut conv1x1_soc = 0u64;
    for ksize in [3usize, 1] {
        let (h, w_sp) = (9usize, 9usize);
        let base = ConvProblem {
            h,
            w: w_sp,
            k_in: 64,
            k_out: 64,
            ksize,
            cores: 1,
            bn_shift: 10,
        };
        let mut rng = Rng::new(7);
        let taps = ksize * ksize;
        let hp = h + if ksize == 3 { 2 } else { 0 };
        let x: Vec<i32> = (0..hp * hp * 64)
            .map(|_| rng.range_i32(-128, 128))
            .collect();
        let wt: Vec<i32> = (0..64 * taps * 64)
            .map(|_| rng.range_i32(-128, 128))
            .collect();
        let sc: Vec<i32> = (0..64).map(|_| rng.range_i32(1, 8)).collect();
        let bi: Vec<i32> = (0..64).map(|_| rng.range_i32(-50, 50)).collect();
        let run_conv = |cores: usize| -> Result<u64> {
            let p = ConvProblem { cores, ..base };
            let cfg = if cores == 1 {
                ClusterConfig::soc_controller()
            } else {
                ClusterConfig::default()
            };
            Ok(p.run_with(cfg, &x, &wt, &sc, &bi)?.1.cycles)
        };
        let soc = run_conv(1)?;
        if ksize == 1 {
            conv1x1_soc = soc;
        }
        let c16 = run_conv(16)?;
        // RBE timing at 8-bit and 4-bit
        let rbe_cycles = |wb: usize, ib: usize| {
            let job = RbeJob {
                mode: if ksize == 3 {
                    RbeMode::Conv3x3
                } else {
                    RbeMode::Conv1x1
                },
                h_out: h,
                w_out: w_sp,
                k_in: 64,
                k_out: 64,
                stride: 1,
                w_bits: wb,
                i_bits: ib,
                o_bits: 8,
            };
            RbeTiming::cycles(&job)
        };
        rows.push(vec![
            format!("Conv{ksize}x{ksize}+BN 9x9x64"),
            "1.0".into(),
            "1.0".into(),
            format!("{:.1}", soc as f64 / c16 as f64),
            format!("{:.0}", soc as f64 / rbe_cycles(8, 8) as f64),
            format!("{:.0}", soc as f64 / rbe_cycles(4, 4) as f64),
        ]);
    }

    // ---- tensor add 9x9x64 ----
    let elems = 9 * 9 * 64 / 16 * 16; // align
    let mut rng = Rng::new(11);
    let a: Vec<i32> = (0..elems).map(|_| rng.range_i32(-64, 64)).collect();
    let b: Vec<i32> = (0..elems).map(|_| rng.range_i32(-64, 64)).collect();
    let add_soc = run_tensor_add(ClusterConfig::soc_controller(), &a, &b)?
        .1
        .cycles;
    let add_16 = run_tensor_add(ClusterConfig::default(), &a, &b)?.1.cycles;
    rows.push(vec![
        "Add 9x9x64 (8b)".into(),
        "1.0".into(),
        "1.0".into(),
        format!("{:.1}", add_soc as f64 / add_16 as f64),
        "-".into(),
        "-".into(),
    ]);

    Ok(format!(
        "Fig. 14 — speedup vs execution on the MARSELLUS SOC core\n{}\n\n{}",
        render_table(
            &["task", "SOC", "1 cluster core", "16 cores", "RBE 8b",
              "RBE 4b"],
            &rows
        ),
        fig14_contention_variance(fast, fft_soc, conv1x1_soc, add_soc)?
    ))
}

/// Mean and half-spread ((max − min) / 2) of a sample set.
fn mean_spread(samples: &[f64]) -> (f64, f64) {
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    (mean, (max - min) / 2.0)
}

/// Contention variance companion to Fig. 14: the 16-core speedups are
/// re-measured under RBE background bank traffic, sampling several
/// `ClusterConfig::traffic_seed` values and reporting mean ± spread —
/// one replayed conflict sequence under-reports the variance the
/// arbitration actually has (ROADMAP "contention variance sweeps").
///
/// The single-core SOC baselines are passed in from [`fig14`] (which
/// already simulated them over the identical seed-3/7/11 inputs this
/// companion regenerates), so only the contended 16-core runs are
/// simulated here.
fn fig14_contention_variance(
    fast: bool,
    fft_soc: u64,
    conv_soc: u64,
    add_soc: u64,
) -> Result<String> {
    const OCCUPANCY: f64 = 0.25;
    let seeds: &[u64] = if fast { &[1, 2, 3] } else { &[1, 2, 3, 4, 5] };
    let contended = |seed: u64| {
        let mut cfg = ClusterConfig::default();
        cfg.background_traffic = OCCUPANCY;
        cfg.traffic_seed = seed;
        cfg
    };

    // FFT: FP32 DSP, conflict-sensitive through the TCDM banks
    let n = if fast { 256 } else { 2048 };
    let mut rng = Rng::new(3);
    let sig: Vec<(f32, f32)> = (0..n)
        .map(|_| (rng.f64() as f32 - 0.5, rng.f64() as f32 - 0.5))
        .collect();
    let fft = FftProblem { n, cores: 16 };
    let fft_samples: Vec<f64> = seeds
        .iter()
        .map(|&s| {
            Ok(fft_soc as f64
                / fft.run_with(contended(s), &sig)?.1.cycles as f64)
        })
        .collect::<Result<_>>()?;

    // conv1x1+BN 9x9x64: the RBE-adjacent marshaling workload
    let base = ConvProblem {
        h: 9,
        w: 9,
        k_in: 64,
        k_out: 64,
        ksize: 1,
        cores: 16,
        bn_shift: 10,
    };
    let mut rng = Rng::new(7);
    let x: Vec<i32> =
        (0..9 * 9 * 64).map(|_| rng.range_i32(-128, 128)).collect();
    let wt: Vec<i32> =
        (0..64 * 64).map(|_| rng.range_i32(-128, 128)).collect();
    let sc: Vec<i32> = (0..64).map(|_| rng.range_i32(1, 8)).collect();
    let bi: Vec<i32> = (0..64).map(|_| rng.range_i32(-50, 50)).collect();
    let conv_samples: Vec<f64> = seeds
        .iter()
        .map(|&s| {
            Ok(conv_soc as f64
                / base.run_with(contended(s), &x, &wt, &sc, &bi)?.1.cycles
                    as f64)
        })
        .collect::<Result<_>>()?;

    // tensor add: pure load/store, the most bank-bound task
    let elems = 9 * 9 * 64 / 16 * 16;
    let mut rng = Rng::new(11);
    let a: Vec<i32> = (0..elems).map(|_| rng.range_i32(-64, 64)).collect();
    let b: Vec<i32> = (0..elems).map(|_| rng.range_i32(-64, 64)).collect();
    let add_samples: Vec<f64> = seeds
        .iter()
        .map(|&s| {
            Ok(add_soc as f64
                / run_tensor_add(contended(s), &a, &b)?.1.cycles as f64)
        })
        .collect::<Result<_>>()?;

    let mut rows_out = Vec::new();
    for (task, samples) in [
        (format!("FFT-{n} (FP32)"), fft_samples),
        ("Conv1x1+BN 9x9x64".to_string(), conv_samples),
        ("Add 9x9x64 (8b)".to_string(), add_samples),
    ] {
        let (mean, spread) = mean_spread(&samples);
        rows_out.push(vec![
            task,
            format!("{mean:.2}"),
            format!("± {spread:.2}"),
        ]);
    }
    Ok(format!(
        "contention variance — 16-core speedup under RBE bank traffic \
         (occupancy {:.0}%, {} traffic seeds, mean ± half-spread)\n{}",
        OCCUPANCY * 100.0,
        seeds.len(),
        render_table(&["task", "speedup", "spread"], &rows_out)
    ))
}

/// Fig. 19: energy-per-operation summary across all techniques.
pub fn fig19(fast: bool) -> Result<String> {
    let sw = measured_sw_perf(fast)?;
    let m = PowerModel;
    let mut rows = Vec::new();
    let points: [(&str, f64, Workload, f64); 8] = [
        ("SW MMUL 8b (Xpulp)", sw.mmul8_ops_per_cycle,
         Workload::MatmulXpulp8, 1.0),
        ("SW M&L 8b", sw.mmul_ml8_ops_per_cycle,
         Workload::MatmulMacLoad, 1.0),
        ("SW M&L 4b", sw.mmul_ml4_ops_per_cycle,
         Workload::MatmulMacLoad, 1.0),
        ("SW M&L 2b", sw.mmul_ml2_ops_per_cycle,
         Workload::MatmulMacLoad, 1.0),
        ("RBE 8x8b", RbeTiming::ops_per_cycle_total(&fig13_job(8, 8)),
         Workload::Rbe { duty_pct: 100 }, 1.0),
        ("RBE 4x4b", RbeTiming::ops_per_cycle_total(&fig13_job(4, 4)),
         Workload::Rbe { duty_pct: 100 }, 1.0),
        ("RBE 2x4b", RbeTiming::ops_per_cycle_total(&fig13_job(2, 4)),
         Workload::Rbe { duty_pct: 100 }, 1.0),
        ("RBE 2x2b", RbeTiming::ops_per_cycle_total(&fig13_job(2, 2)),
         Workload::Rbe { duty_pct: 50 }, 1.0),
    ];
    for (name, opc, w, _) in points {
        let mut cells = vec![name.to_string()];
        for (vdd, fbb) in [(0.8, 0.0), (0.65, FBB_MAX_V), (0.5, 0.0)] {
            let freq = if fbb > 0.0 { 400.0 } else { fmax_mhz(vdd, 0.0) };
            let op = OperatingPoint { vdd, freq_mhz: freq, fbb_v: fbb };
            let gops = opc * op.freq_mhz / 1.0e3;
            let p = m.total_mw(w, &op);
            cells.push(format!("{:.0}", fj_per_op(p, gops)));
        }
        rows.push(cells);
    }
    Ok(format!(
        "Fig. 19 — energy per operation (fJ/op) across techniques and \
         operating points\n{}",
        render_table(
            &["technique", "0.8V/fmax", "0.65V+ABB@400MHz", "0.5V/fmax"],
            &rows
        )
    ))
}

fn fig13_job(w: usize, i: usize) -> RbeJob {
    RbeJob {
        mode: RbeMode::Conv3x3,
        h_out: 3,
        w_out: 3,
        k_in: 64,
        k_out: 64,
        stride: 1,
        w_bits: w,
        i_bits: i,
        o_bits: 4,
    }
}

/// §III-C1 table: instruction reductions, MAC&LOAD gain, utilization, FFT.
pub fn isa_table(fast: bool) -> Result<String> {
    let sw = measured_sw_perf(fast)?;
    let count = |kernel: MatmulKernel| -> Result<f64> {
        let (m, n, k) = (8, 4, 64);
        let p = MatmulProblem { m, n, k, kernel, cores: 1 };
        let (a, b) = random_operands(m, n, k, kernel.prec(), 5);
        let (_, stats) = p.run_with(ClusterConfig::soc_controller(), &a, &b)?;
        Ok(stats.total.instrs as f64)
    };
    let r4 = count(MatmulKernel::UnpackBaseline { prec: Prec::B4 })?
        / count(MatmulKernel::Nn { prec: Prec::B4 })?;
    let r2 = count(MatmulKernel::UnpackBaseline { prec: Prec::B2 })?
        / count(MatmulKernel::Nn { prec: Prec::B2 })?;
    let rows = vec![
        vec!["4-bit instruction reduction vs Xpulp".into(),
             "6x".into(), format!("{r4:.1}x")],
        vec!["2-bit instruction reduction vs Xpulp".into(),
             "9x".into(), format!("{r2:.1}x")],
        vec!["MAC&LOAD speedup over baseline MMUL".into(), "+67%".into(),
             format!("+{:.0}%",
                     (sw.mmul_ml8_ops_per_cycle / sw.mmul8_ops_per_cycle
                      - 1.0) * 100.0)],
        vec!["DOTP unit utilization (M&L)".into(), "94%".into(),
             format!("{:.0}%", sw.macload_utilization * 100.0)],
        vec!["FFT-2048 throughput".into(), "4.69 FLOp/cycle".into(),
             format!("{:.2} FLOp/cycle", sw.fft_flops_per_cycle)],
        vec!["FFT peak perf @0.8V/420MHz".into(), "1.97 GFLOPS".into(),
             format!("{:.2} GFLOPS",
                     sw.fft_flops_per_cycle * 420.0 / 1.0e3)],
    ];
    Ok(format!(
        "§III-C1 — ISA extension results (measured on the ISS)\n{}",
        render_table(&["metric", "paper", "measured"], &rows)
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig13_renders_with_anchor_shape() {
        let t = fig13();
        assert!(t.contains("Conv3x3"));
        assert!(t.contains("Conv1x1"));
        // 18 rows: 2 modes x 3 W x 3 I
        assert_eq!(t.lines().count(), 5 + 18);
    }

    #[test]
    fn fig14_fast_shows_speedups() {
        let t = fig14(true).unwrap();
        assert!(t.contains("FFT"));
        assert!(t.contains("Conv3x3"));
        assert!(t.contains("Add"));
        // contention companion: several traffic seeds, mean ± spread
        assert!(t.contains("traffic seeds"), "{t}");
        assert!(t.contains("±"), "{t}");
    }

    /// The contention sweep really varies with the traffic seed: the
    /// spread over seeds is strictly positive for at least one task
    /// (otherwise the sweep is replaying one sequence).
    #[test]
    fn contention_sweep_has_spread() {
        // synthetic baselines keep the test off the 1-core simulations;
        // they are large so a one-cycle difference between seeds still
        // survives the 2-decimal rendering the assertion parses
        let b = 10_000_000;
        let t = fig14_contention_variance(true, b, b, b).unwrap();
        let spreads: Vec<f64> = t
            .lines()
            .filter_map(|l| l.rsplit_once("± "))
            .map(|(_, v)| v.trim().parse().unwrap())
            .collect();
        assert_eq!(spreads.len(), 3, "{t}");
        assert!(spreads.iter().any(|&s| s > 0.0), "{t}");
    }

    #[test]
    fn mean_spread_math() {
        let (m, s) = mean_spread(&[2.0, 4.0, 3.0]);
        assert!((m - 3.0).abs() < 1e-12);
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fig19_fast() {
        let t = fig19(true).unwrap();
        assert!(t.contains("RBE 2x2b"));
    }

    #[test]
    fn isa_table_fast() {
        let t = isa_table(true).unwrap();
        assert!(t.contains("DOTP"));
    }
}
