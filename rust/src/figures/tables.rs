//! Static/semi-static tables: Fig. 7 & 8 area breakdowns, Table I (ABB
//! SoA) and Table II (SoC SoA comparison).

use anyhow::Result;

use crate::abb::{AbbSim, Phase};
use crate::metrics::{gops_per_mm2, render_table};
use crate::power::{
    cluster_area_breakdown, fmax_mhz, rbe_area_breakdown, OperatingPoint,
    PowerModel, Workload, CLUSTER_AREA_MM2, DIE_AREA_MM2, FBB_MAX_V, RBE_KGE,
};

use super::perf_figs::{measured_sw_perf, rbe_point};

pub fn fig7() -> String {
    let rows: Vec<Vec<String>> = cluster_area_breakdown()
        .iter()
        .map(|i| {
            vec![
                i.name.to_string(),
                format!("{:.1}%", i.pct),
                format!("{:.3} mm2", CLUSTER_AREA_MM2 * i.pct / 100.0),
            ]
        })
        .collect();
    format!(
        "Fig. 7 — CLUSTER area distribution (total {CLUSTER_AREA_MM2} mm2 \
         of {DIE_AREA_MM2} mm2 die)\n{}",
        render_table(&["block", "share", "area"], &rows)
    )
}

pub fn fig8() -> String {
    let rows: Vec<Vec<String>> = rbe_area_breakdown()
        .iter()
        .map(|i| {
            vec![
                i.name.to_string(),
                format!("{:.1}%", i.pct),
                format!("{:.0} kGE", RBE_KGE * i.pct / 100.0),
            ]
        })
        .collect();
    format!(
        "Fig. 8 — RBE post-synthesis area ({RBE_KGE} kGE total)\n{}",
        render_table(&["part", "share", "complexity"], &rows)
    )
}

pub fn tab1() -> String {
    // Measure our Marsellus row: fixed 400 MHz, 0.8 V vs 0.65 V + ABB.
    let m = PowerModel;
    let w = Workload::MatmulMacLoad;
    let p_nom = m.total_mw(
        w,
        &OperatingPoint { vdd: 0.8, freq_mhz: 400.0, fbb_v: 0.0 },
    );
    let p_abb = m.total_mw(
        w,
        &OperatingPoint { vdd: 0.65, freq_mhz: 400.0, fbb_v: FBB_MAX_V },
    );
    let gain = (1.0 - p_abb / p_nom) * 100.0;
    // errorless check through the coupled OCM+generator simulation
    let errorless = {
        let mut sim = AbbSim::new(0.65, 400.0, true);
        sim.run(&Phase::fig11_benchmark(), 100.0).total_real_errors == 0
    };
    let rows = vec![
        vec!["Moursy et al. [20]".into(), "Cortex-M4F".into(), "2 mm2".into(), "-19.9%".into(), "OCM + ABB-gen".into()],
        vec!["Rossi et al. [31]".into(), "4-core PULP".into(), "3 mm2".into(), "-43% (sleep)".into(), "none".into()],
        vec!["SleepRunner [32]".into(), "Cortex-M0 MCU".into(), "0.6 mm2".into(), "-".into(), "UFBR".into()],
        vec!["Akgul et al. [33]".into(), "VLIW DSP".into(), "-".into(), "-17%".into(), "offline sw".into()],
        vec!["Quelen et al. [34]".into(), "digital core".into(), "2 mm2".into(), "-32%".into(), "OCM + ABB-gen".into()],
        vec![
            "Marsellus (measured)".into(),
            "17 RISC-V + RBE".into(),
            format!("{CLUSTER_AREA_MM2} mm2"),
            format!("{gain:.0}% (errorless: {errorless})"),
            "OCM + ABB-gen".into(),
        ],
    ];
    format!(
        "Table I — ABB methods in the SoA (paper rows cited; Marsellus row \
         measured on the simulator; paper reports -30%)\n{}",
        render_table(
            &["work", "prototype", "area", "best power gain", "tuning"],
            &rows
        )
    )
}

pub fn tab2(fast: bool) -> Result<String> {
    let m = PowerModel;
    // --- software rows (measured on the ISS) ---
    let sw = measured_sw_perf(fast)?;
    let f_abb = fmax_mhz(0.8, FBB_MAX_V); // 0.8 V + ABB overclock
    let sw2_gops = sw.mmul_ml2_ops_per_cycle * f_abb / 1.0e3;
    let p_sw_05 = m.total_mw(
        Workload::MatmulMacLoad,
        &OperatingPoint::at_vdd(0.5),
    );
    let sw2_gops_05 = sw.mmul_ml2_ops_per_cycle * fmax_mhz(0.5, 0.0) / 1.0e3;
    let sw2_eff = sw2_gops_05 / (p_sw_05 * 1e-3) / 1000.0; // Top/s/W
    // FP16: dense vfmac.h2 microkernel measured on the ISS (FPU-bound,
    // 16 cores on 8 shared FPUs); efficiency at the 0.5 V point.
    let fp16_gflops = sw.fp16_flops_per_cycle * f_abb / 1.0e3;
    let p_fp16_05 =
        m.total_mw(Workload::FftFp32, &OperatingPoint::at_vdd(0.5));
    let fp16_eff = sw.fp16_flops_per_cycle * fmax_mhz(0.5, 0.0) / 1.0e3
        / (p_fp16_05 * 1e-3);
    // --- RBE rows (timing model) ---
    let rbe22 = rbe_point(2, 2, 0.8, true);
    let rbe22_eff = rbe_point(2, 2, 0.5, false);
    // --- network rows (scheduler) ---
    use crate::dnn::{resnet18_layers, resnet20_layers, PrecisionConfig};
    use crate::mapping::Scheduler;
    let s = Scheduler::default();
    let op05 = OperatingPoint::at_vdd(0.5);
    let r20 = s.network_report(
        &resnet20_layers(PrecisionConfig::Mixed),
        &op05,
    )?;
    let r18 = s.network_report(&resnet18_layers(), &op05)?;

    let rows = vec![
        vec!["Technology".into(), "22nm FDX".into(), "22nm FDX".into()],
        vec![
            "Die (CLUSTER) area".into(),
            "18.7 (2.42) mm2".into(),
            format!("{DIE_AREA_MM2} ({CLUSTER_AREA_MM2}) mm2 [model]"),
        ],
        vec![
            "Best SW INT perf (2x2b, 0.8V+ABB)".into(),
            "180 Gop/s".into(),
            format!("{sw2_gops:.0} Gop/s"),
        ],
        vec![
            "Best SW INT area eff".into(),
            "9.63 Gop/s/mm2".into(),
            format!("{:.2} Gop/s/mm2",
                    gops_per_mm2(sw2_gops, DIE_AREA_MM2)),
        ],
        vec![
            "Best SW INT energy eff (0.5V)".into(),
            "3.32 Top/s/W @ 19 Gop/s".into(),
            format!("{sw2_eff:.2} Top/s/W @ {sw2_gops_05:.0} Gop/s"),
        ],
        vec![
            "Best SW FP16 perf".into(),
            "6.9 Gflop/s".into(),
            format!("{fp16_gflops:.1} Gflop/s"),
        ],
        vec![
            "Best SW FP16 energy eff".into(),
            "207 Gflop/s/W".into(),
            format!("{fp16_eff:.0} Gflop/s/W"),
        ],
        vec![
            "Best HW-accel perf (2x2b, 0.8V+ABB)".into(),
            "637 Gop/s".into(),
            format!("{:.0} Gop/s", rbe22.gops / 420.0 * f_abb),
        ],
        vec![
            "Best HW-accel area eff".into(),
            "34.1 Gop/s/mm2".into(),
            format!("{:.1} Gop/s/mm2",
                    gops_per_mm2(rbe22.gops / 420.0 * f_abb,
                                 DIE_AREA_MM2)),
        ],
        vec![
            "Best HW-accel energy eff (2x2b, 0.5V)".into(),
            "12.4 Top/s/W @ 136 Gop/s".into(),
            format!("{:.1} Top/s/W @ {:.0} Gop/s",
                    rbe22_eff.tops_per_w, rbe22_eff.gops),
        ],
        vec![
            "ResNet-20/CIFAR eff / latency".into(),
            "6.38 Top/s/W / 1.05 ms".into(),
            format!("{:.2} Top/s/W / {:.2} ms",
                    r20.tops_per_w(), r20.total_latency_us() / 1e3),
        ],
        vec![
            "ResNet-18/ImageNet eff / latency".into(),
            "5.83 Top/s/W / 48 ms".into(),
            format!("{:.2} Top/s/W / {:.1} ms",
                    r18.tops_per_w(), r18.total_latency_us() / 1e3),
        ],
    ];
    Ok(format!(
        "Table II — Marsellus column: paper-measured vs this model \
         (competitor columns are cited constants, see paper)\n{}",
        render_table(&["metric", "paper", "measured (model)"], &rows)
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_tables_render() {
        assert!(fig7().contains("RBE"));
        assert!(fig8().contains("datapath"));
        let t1 = tab1();
        assert!(t1.contains("Marsellus (measured)"));
        // the measured ABB gain must be ~-30%
        assert!(t1.contains("-"), "{t1}");
    }

    #[test]
    fn tab2_renders_fast() {
        let t = tab2(true).unwrap();
        assert!(t.contains("ResNet-20"));
        assert!(t.contains("Gop/s"));
    }
}
