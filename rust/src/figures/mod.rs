//! The reproduction harness: one generator per table/figure of the
//! paper's evaluation (DESIGN.md experiment index). Each function returns
//! the rendered rows as a string; the CLI (`marsellus figure <id>`),
//! the examples and the bench harness all call through here.

mod ablations;
mod dnn_figs;
mod perf_figs;
mod power_figs;
mod tables;

pub use ablations::{ablate_abb, ablate_banking, ablate_double_buffering,
                    ablate_macload};
pub use dnn_figs::{fig17, fig18};
pub use perf_figs::{fig13, fig14, fig19, isa_table};
pub use power_figs::{fig10, fig11, fig12, fig15, fig9};
pub use tables::{fig7, fig8, tab1, tab2};

use anyhow::Result;

/// All known figure ids.
pub const ALL: &[&str] = &[
    "fig7", "fig8", "fig9", "fig10", "fig11", "fig12", "fig13", "fig14",
    "fig15", "fig17", "fig18", "fig19", "tab1", "tab2", "isa",
    "ablate-ml", "ablate-dbuf", "ablate-abb", "ablate-banks",
];

/// Dispatch by id. `fast` trims the ISS workload sizes (used by tests).
pub fn generate(id: &str, fast: bool) -> Result<String> {
    Ok(match id {
        "fig7" => fig7(),
        "fig8" => fig8(),
        "fig9" => fig9(),
        "fig10" => fig10(),
        "fig11" => fig11(),
        "fig12" => fig12(),
        "fig13" => fig13(),
        "fig14" => fig14(fast)?,
        "fig15" => fig15(fast)?,
        "fig17" => fig17()?,
        "fig18" => fig18()?,
        "fig19" => fig19(fast)?,
        "tab1" => tab1(),
        "tab2" => tab2(fast)?,
        "isa" => isa_table(fast)?,
        "ablate-ml" => ablate_macload(fast)?,
        "ablate-dbuf" => ablate_double_buffering()?,
        "ablate-abb" => ablate_abb()?,
        "ablate-banks" => ablate_banking(fast)?,
        other => anyhow::bail!(
            "unknown figure {other:?}; known: {}",
            ALL.join(", ")
        ),
    })
}
