//! Power/ABB figures: Fig. 9 (V_DD sweep), Fig. 10 (ABB undervolting),
//! Fig. 11 (ABB trace), Fig. 12 (transition detail), Fig. 15
//! (efficiency vs performance).

use anyhow::Result;

use crate::abb::{AbbSim, Phase};
use crate::metrics::render_table;
use crate::power::{OperatingPoint, PowerModel, Workload, FBB_MAX_V};
use crate::rbe::{RbeJob, RbeMode, RbeTiming};

use super::perf_figs::measured_sw_perf;

/// Fig. 9: frequency and power vs V_DD (no ABB), INT8 MAC&LOAD matmul.
pub fn fig9() -> String {
    let m = PowerModel;
    let mut rows = Vec::new();
    let mut v = 0.50;
    while v <= 0.801 {
        let op = OperatingPoint::at_vdd(v);
        let dynamic = m.dynamic_mw(Workload::MatmulMacLoad, &op);
        let leak = m.leakage_mw(&op);
        rows.push(vec![
            format!("{v:.2}"),
            format!("{:.0}", op.freq_mhz),
            format!("{dynamic:.1}"),
            format!("{leak:.2}"),
            format!("{:.1}", dynamic + leak),
        ]);
        v += 0.05;
    }
    format!(
        "Fig. 9 — f_max and power vs V_DD, no ABB (paper anchors: 420 MHz \
         & 123 mW at 0.8 V; 100 MHz at 0.5 V; dyn -10.7x, leak -3.5x)\n{}",
        render_table(
            &["V_DD", "f_max MHz", "P_dyn mW", "P_leak mW", "P_tot mW"],
            &rows
        )
    )
}

/// Fig. 10: power at a fixed 400 MHz vs V_DD, with and without ABB. Only
/// timing-clean points are listed (as the paper plots only working ones).
pub fn fig10() -> String {
    let m = PowerModel;
    let w = Workload::MatmulMacLoad;
    let mut rows = Vec::new();
    let mut v = 0.80;
    while v >= 0.599 {
        let no_abb = OperatingPoint { vdd: v, freq_mhz: 400.0, fbb_v: 0.0 };
        let with = OperatingPoint {
            vdd: v,
            freq_mhz: 400.0,
            fbb_v: FBB_MAX_V,
        };
        let p_no = if no_abb.is_timing_clean() {
            format!("{:.1}", m.total_mw(w, &no_abb))
        } else {
            "fails".into()
        };
        let p_with = if with.is_timing_clean() {
            format!("{:.1}", m.total_mw(w, &with))
        } else {
            "fails".into()
        };
        rows.push(vec![format!("{v:.2}"), p_no, p_with]);
        v -= 0.03;
    }
    let p_nom = m.total_mw(w, &OperatingPoint {
        vdd: 0.8, freq_mhz: 400.0, fbb_v: 0.0,
    });
    let p_abb = m.total_mw(w, &OperatingPoint {
        vdd: 0.65, freq_mhz: 400.0, fbb_v: FBB_MAX_V,
    });
    format!(
        "Fig. 10 — power at fixed 400 MHz (paper: min 0.74 V w/o ABB; \
         0.65 V w/ ABB at -30% vs nominal)\n{}\nmeasured saving at 0.65 V \
         + ABB vs 0.8 V nominal: {:.0}%",
        render_table(&["V_DD", "P no-ABB mW", "P ABB mW"], &rows),
        (1.0 - p_abb / p_nom) * 100.0
    )
}

/// Fig. 11: ABB operation over the 1 ms three-phase benchmark, 470 MHz
/// overclock at 0.8 V.
pub fn fig11() -> String {
    let mut sim = AbbSim::new(0.8, 470.0, true);
    let res = sim.run(&Phase::fig11_benchmark(), 25.0);
    let rows: Vec<Vec<String>> = res
        .trace
        .iter()
        .map(|p| {
            vec![
                format!("{:.0}", p.t_us),
                p.phase.into(),
                format!("{:.3}", p.fbb_v),
                format!("{}", p.pre_errors),
                format!("{}", p.real_errors),
                format!("{:.1}", p.power_mw),
            ]
        })
        .collect();
    format!(
        "Fig. 11 — ABB trace, 470 MHz @ 0.8 V (paper: 2 boosts during \
         high-intensity phases, errorless)\n{}\nboost events: {}  \
         pre-errors: {}  real errors: {}",
        render_table(
            &["t us", "phase", "V_FBB", "pre-err", "real-err", "P mW"],
            &rows
        ),
        res.boost_events,
        res.total_pre_errors,
        res.total_real_errors
    )
}

/// Fig. 12: detail of one ABB transition at the compute-phase onset.
pub fn fig12() -> String {
    let mut sim = AbbSim::new(0.8, 470.0, true);
    let res = sim.run(&Phase::fig11_benchmark(), 0.15);
    // zoom on the RISC-V compute phase onset
    let compute: Vec<_> = res
        .trace
        .iter()
        .filter(|p| p.phase == "RISC-V compute")
        .take(40)
        .collect();
    let start_fbb = compute.first().map(|p| p.fbb_v).unwrap_or(0.0);
    let peak = compute.iter().map(|p| p.fbb_v).fold(0.0f64, f64::max);
    let t0 = compute
        .iter()
        .find(|p| p.fbb_v > start_fbb + 1e-6)
        .map(|p| p.t_us)
        .unwrap_or(0.0);
    let t1 = compute
        .iter()
        .find(|p| p.fbb_v >= peak - 1e-9)
        .map(|p| p.t_us)
        .unwrap_or(t0);
    let cycles = (t1 - t0) * 470.0;
    let rows: Vec<Vec<String>> = compute
        .iter()
        .map(|p| {
            vec![
                format!("{:.2}", p.t_us),
                format!("{:.3}", p.fbb_v),
                format!("{}", p.pre_errors),
            ]
        })
        .collect();
    format!(
        "Fig. 12 — ABB transition detail (paper: ~0.66 us / ~310 cycles \
         at 470 MHz)\n{}\nmeasured transition: {:.2} us ≈ {:.0} cycles",
        render_table(&["t us", "V_FBB", "pre-err"], &rows),
        t1 - t0,
        cycles
    )
}

/// Fig. 15: energy efficiency vs performance across operating points for
/// MMUL, MMUL M&L and RBE 3×3 kernels.
pub fn fig15(fast: bool) -> Result<String> {
    let sw = measured_sw_perf(fast)?;
    let m = PowerModel;
    let mut rows = Vec::new();
    let vdds = [0.5, 0.575, 0.65, 0.74, 0.8];
    let mut push = |name: &str, opc: f64, w: Workload| {
        for &vdd in &vdds {
            let op = OperatingPoint::at_vdd(vdd);
            let gops = opc * op.freq_mhz / 1.0e3;
            let p = m.total_mw(w, &op);
            rows.push(vec![
                name.to_string(),
                format!("{vdd:.2}"),
                format!("{:.0}", op.freq_mhz),
                format!("{gops:.1}"),
                format!("{:.0}", gops / (p * 1e-3)),
            ]);
        }
    };
    push("MMUL 8b", sw.mmul8_ops_per_cycle, Workload::MatmulXpulp8);
    push("MMUL M&L 8b", sw.mmul_ml8_ops_per_cycle, Workload::MatmulMacLoad);
    push("MMUL M&L 4b", sw.mmul_ml4_ops_per_cycle, Workload::MatmulMacLoad);
    push("MMUL M&L 2b", sw.mmul_ml2_ops_per_cycle, Workload::MatmulMacLoad);
    for (w_bits, i_bits, duty) in [(8, 8, 100u8), (4, 4, 100), (2, 2, 50)] {
        let job = RbeJob {
            mode: RbeMode::Conv3x3,
            h_out: 3,
            w_out: 3,
            k_in: 64,
            k_out: 64,
            stride: 1,
            w_bits,
            i_bits,
            o_bits: i_bits,
        };
        let opc = RbeTiming::ops_per_cycle_total(&job);
        push(
            &format!("RBE 3x3 {w_bits}x{i_bits}b"),
            opc,
            Workload::Rbe { duty_pct: duty },
        );
    }
    Ok(format!(
        "Fig. 15 — efficiency vs performance (paper anchors: MMUL 25.45 \
         Gop/s @ 250 Gop/s/W nominal; M&L +67%/+51%; RBE 8x8 91 Gop/s @ \
         740 Gop/s/W; RBE 2x2 569 Gop/s @ 5.37 Top/s/W; 12.36 Top/s/W @ \
         0.5 V)\n{}",
        render_table(
            &["kernel", "V_DD", "MHz", "Gop/s", "Gop/s/W"],
            &rows
        )
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_shape() {
        let t = fig9();
        assert!(t.contains("0.50"));
        assert!(t.contains("0.80"));
        // frequency at the endpoints
        assert!(t.contains("100"));
        assert!(t.contains("420"));
    }

    #[test]
    fn fig10_has_failure_region() {
        let t = fig10();
        assert!(t.contains("fails"), "{t}");
        assert!(t.contains("measured saving"));
    }

    #[test]
    fn fig11_12_traces() {
        let t11 = fig11();
        assert!(t11.contains("boost events: 2"), "{t11}");
        assert!(t11.contains("real errors: 0"));
        let t12 = fig12();
        assert!(t12.contains("measured transition"));
    }

    #[test]
    fn fig15_fast() {
        let t = fig15(true).unwrap();
        assert!(t.contains("RBE 3x3 2x2b"));
        assert!(t.contains("MMUL M&L 2b"));
    }
}
