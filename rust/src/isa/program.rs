//! Program container + label-resolving builder.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::{Cond, Instr, Reg, Target};

/// ISA level a program requires; the cluster cores implement `XpulpNN`, the
/// SOC controller only `Xpulp` (paper Fig. 1). Programs declare the level
/// they need so scheduling a 2-bit kernel on the SOC core is an error, like
/// on the chip.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum IsaLevel {
    /// RV32IMFC + Xpulp (hw loops, post-increment, 16/8-bit dotp).
    Xpulp,
    /// Xpulp + nibble/crumb SIMD + MAC&LOAD.
    XpulpNN,
}

/// An executable program: resolved instructions plus metadata.
#[derive(Debug, Clone)]
pub struct Program {
    pub name: String,
    pub instrs: Vec<Instr>,
    pub isa: IsaLevel,
}

impl Program {
    /// Minimum ISA level actually used by the instruction stream (used to
    /// validate the declared level).
    pub fn required_isa(&self) -> IsaLevel {
        use super::Prec;
        for i in &self.instrs {
            match i {
                Instr::MlSdotp { .. } | Instr::NnLoad { .. } => {
                    return IsaLevel::XpulpNN
                }
                Instr::Dotp { prec, .. }
                | Instr::Sdotp { prec, .. }
                | Instr::VAlu { prec, .. }
                    if matches!(prec, Prec::B4 | Prec::B2) =>
                {
                    return IsaLevel::XpulpNN
                }
                _ => {}
            }
        }
        IsaLevel::Xpulp
    }

    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }
}

/// Label identifier handed out by [`ProgramBuilder::label`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Label(usize);

enum Pending {
    Branch { at: usize, label: Label },
    Jump { at: usize, label: Label },
    LoopEnd { at: usize, start: Label, end: Label },
}

/// Builds a [`Program`], resolving forward label references. Kernels in
/// `crate::kernels` are written against this builder — it plays the role of
/// the XpulpNN GCC builtins layer described in paper §II-A3.
pub struct ProgramBuilder {
    name: String,
    isa: IsaLevel,
    instrs: Vec<Instr>,
    labels: HashMap<Label, usize>,
    next_label: usize,
    pending: Vec<Pending>,
}

impl ProgramBuilder {
    pub fn new(name: &str, isa: IsaLevel) -> Self {
        Self {
            name: name.to_string(),
            isa,
            instrs: Vec::new(),
            labels: HashMap::new(),
            next_label: 0,
            pending: Vec::new(),
        }
    }

    /// Allocate a fresh (unbound) label.
    pub fn label(&mut self) -> Label {
        let l = Label(self.next_label);
        self.next_label += 1;
        l
    }

    /// Bind `label` to the next emitted instruction.
    pub fn bind(&mut self, label: Label) {
        self.labels.insert(label, self.instrs.len());
    }

    /// Emit one instruction; returns its index.
    pub fn emit(&mut self, i: Instr) -> usize {
        self.instrs.push(i);
        self.instrs.len() - 1
    }

    /// Emit a branch to `label` (resolved at build()).
    pub fn branch(&mut self, cond: Cond, rs1: Reg, rs2: Reg, label: Label) {
        let at = self.emit(Instr::Branch { cond, rs1, rs2, target: 0 });
        self.pending.push(Pending::Branch { at, label });
    }

    /// Emit a jump to `label`.
    pub fn jump(&mut self, label: Label) {
        let at = self.emit(Instr::Jump { target: 0 });
        self.pending.push(Pending::Jump { at, label });
    }

    /// Emit an Xpulp hardware-loop setup whose body spans from `start` to
    /// the instruction *before* `end`. `count` is a register holding the
    /// trip count (must be >= 1 when executed).
    pub fn hw_loop(&mut self, idx: u8, count: Reg, start: Label, end: Label) {
        let at = self.emit(Instr::HwLoop {
            idx,
            count,
            body_start: 0,
            body_end: 0,
        });
        self.pending.push(Pending::LoopEnd { at, start, end });
    }

    fn resolve(&self, l: Label) -> Result<Target> {
        self.labels
            .get(&l)
            .copied()
            .with_context(|| format!("unbound label {l:?}"))
    }

    /// Resolve all labels and produce the program.
    pub fn build(mut self) -> Result<Program> {
        for p in std::mem::take(&mut self.pending) {
            match p {
                Pending::Branch { at, label } => {
                    let t = self.resolve(label)?;
                    if let Instr::Branch { target, .. } = &mut self.instrs[at]
                    {
                        *target = t;
                    }
                }
                Pending::Jump { at, label } => {
                    let t = self.resolve(label)?;
                    if let Instr::Jump { target, .. } = &mut self.instrs[at] {
                        *target = t;
                    }
                }
                Pending::LoopEnd { at, start, end } => {
                    let s = self.resolve(start)?;
                    let e = self.resolve(end)?;
                    if e <= s {
                        bail!("hw loop body empty: start {s} end {e}");
                    }
                    if let Instr::HwLoop {
                        body_start,
                        body_end,
                        ..
                    } = &mut self.instrs[at]
                    {
                        *body_start = s;
                        *body_end = e - 1; // inclusive last instruction
                    }
                }
            }
        }
        self.emit(Instr::Halt);
        let prog = Program {
            name: self.name,
            instrs: self.instrs,
            isa: self.isa,
        };
        if prog.required_isa() > prog.isa {
            bail!(
                "program {:?} declared {:?} but uses XpulpNN instructions",
                prog.name,
                prog.isa
            );
        }
        Ok(prog)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{AluOp, Prec, Sign};

    #[test]
    fn forward_labels_resolve() {
        let mut b = ProgramBuilder::new("t", IsaLevel::Xpulp);
        let done = b.label();
        b.emit(Instr::Li { rd: 1, imm: 0 });
        b.branch(Cond::Eq, 1, 0, done);
        b.emit(Instr::AluImm { op: AluOp::Add, rd: 1, rs1: 1, imm: 1 });
        b.bind(done);
        b.emit(Instr::Nop);
        let p = b.build().unwrap();
        match p.instrs[1] {
            Instr::Branch { target, .. } => assert_eq!(target, 3),
            _ => panic!(),
        }
        assert!(matches!(p.instrs.last(), Some(Instr::Halt)));
    }

    #[test]
    fn hw_loop_bounds_inclusive() {
        let mut b = ProgramBuilder::new("t", IsaLevel::Xpulp);
        let (s, e) = (b.label(), b.label());
        b.emit(Instr::Li { rd: 5, imm: 4 });
        b.hw_loop(0, 5, s, e);
        b.bind(s);
        b.emit(Instr::Nop);
        b.emit(Instr::Nop);
        b.bind(e);
        b.emit(Instr::Halt);
        let p = b.build().unwrap();
        match p.instrs[1] {
            Instr::HwLoop { body_start, body_end, .. } => {
                assert_eq!((body_start, body_end), (2, 3));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn unbound_label_is_error() {
        let mut b = ProgramBuilder::new("t", IsaLevel::Xpulp);
        let l = b.label();
        b.jump(l);
        assert!(b.build().is_err());
    }

    #[test]
    fn isa_level_enforced() {
        let mut b = ProgramBuilder::new("t", IsaLevel::Xpulp);
        b.emit(Instr::Sdotp {
            prec: Prec::B2,
            sign: Sign::SS,
            rd: 1,
            rs1: 2,
            rs2: 3,
        });
        assert!(b.build().is_err());
    }

    #[test]
    fn required_isa_detects_macload() {
        let mut b = ProgramBuilder::new("t", IsaLevel::XpulpNN);
        b.emit(Instr::MlSdotp {
            prec: Prec::B8,
            sign: Sign::SS,
            rd: 1,
            na: 0,
            nb: 1,
            refresh: None,
        });
        let p = b.build().unwrap();
        assert_eq!(p.required_isa(), IsaLevel::XpulpNN);
    }
}
