//! Assembly-style Display for instructions (debugging, traces, tests).

use std::fmt;

use super::instr::{AluOp, Cond, FOp, Instr, Prec, Sign, VAluOp};

impl fmt::Display for Prec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Xpulp suffixes: .h half, .b byte, .n nibble, .c crumb
        let s = match self {
            Prec::B16 => "h",
            Prec::B8 => "b",
            Prec::B4 => "n",
            Prec::B2 => "c",
        };
        write!(f, "{s}")
    }
}

impl fmt::Display for Sign {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Sign::SS => "s",
            Sign::UU => "u",
            Sign::US => "us",
            Sign::SU => "su",
        };
        write!(f, "{s}")
    }
}

fn alu_name(op: AluOp) -> &'static str {
    match op {
        AluOp::Add => "add",
        AluOp::Sub => "sub",
        AluOp::Sll => "sll",
        AluOp::Srl => "srl",
        AluOp::Sra => "sra",
        AluOp::And => "and",
        AluOp::Or => "or",
        AluOp::Xor => "xor",
        AluOp::Slt => "slt",
        AluOp::Sltu => "sltu",
        AluOp::Mul => "mul",
        AluOp::Min => "p.min",
        AluOp::Max => "p.max",
    }
}

fn valu_name(op: VAluOp) -> &'static str {
    match op {
        VAluOp::Add => "add",
        VAluOp::Sub => "sub",
        VAluOp::Max => "max",
        VAluOp::Min => "min",
        VAluOp::Sra => "sra",
        VAluOp::Shuffle => "shuffle",
    }
}

impl fmt::Display for Instr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            Instr::Alu { op, rd, rs1, rs2 } => {
                write!(f, "{} x{rd}, x{rs1}, x{rs2}", alu_name(op))
            }
            Instr::AluImm { op, rd, rs1, imm } => {
                write!(f, "{}i x{rd}, x{rs1}, {imm}", alu_name(op))
            }
            Instr::Li { rd, imm } => write!(f, "li x{rd}, {imm}"),
            Instr::Mac { rd, rs1, rs2 } => {
                write!(f, "p.mac x{rd}, x{rs1}, x{rs2}")
            }
            Instr::VAlu { op, prec, rd, rs1, rs2 } => {
                write!(f, "pv.{}.{prec} x{rd}, x{rs1}, x{rs2}", valu_name(op))
            }
            Instr::Dotp { prec, sign, rd, rs1, rs2 } => {
                write!(f, "pv.dotp{sign}.{prec} x{rd}, x{rs1}, x{rs2}")
            }
            Instr::Sdotp { prec, sign, rd, rs1, rs2 } => {
                write!(f, "pv.sdotp{sign}.{prec} x{rd}, x{rs1}, x{rs2}")
            }
            Instr::MlSdotp { prec, sign, rd, na, nb, refresh } => {
                match refresh {
                    Some((nn, ptr)) => write!(
                        f,
                        "pv.mlsdotp{sign}.{prec} x{rd}, nn{na}, nn{nb} ; nn{nn}=[x{ptr}!]"
                    ),
                    None => write!(
                        f,
                        "pv.mlsdotp{sign}.{prec} x{rd}, nn{na}, nn{nb}"
                    ),
                }
            }
            Instr::NnLoad { nn_rd, ptr, post_inc } => {
                write!(f, "p.nnlw nn{nn_rd}, {post_inc}(x{ptr}!)")
            }
            Instr::Lw { rd, base, offset, post_inc } => {
                if post_inc != 0 {
                    write!(f, "p.lw x{rd}, {post_inc}(x{base}!)")
                } else {
                    write!(f, "lw x{rd}, {offset}(x{base})")
                }
            }
            Instr::Sw { rs, base, offset, post_inc } => {
                if post_inc != 0 {
                    write!(f, "p.sw x{rs}, {post_inc}(x{base}!)")
                } else {
                    write!(f, "sw x{rs}, {offset}(x{base})")
                }
            }
            Instr::Flw { fd, base, offset, post_inc } => {
                if post_inc != 0 {
                    write!(f, "p.flw f{fd}, {post_inc}(x{base}!)")
                } else {
                    write!(f, "flw f{fd}, {offset}(x{base})")
                }
            }
            Instr::Fsw { fs, base, offset, post_inc } => {
                if post_inc != 0 {
                    write!(f, "p.fsw f{fs}, {post_inc}(x{base}!)")
                } else {
                    write!(f, "fsw f{fs}, {offset}(x{base})")
                }
            }
            Instr::FAlu { op, lanes, fd, fs1, fs2, fs3 } => {
                let n = match op {
                    FOp::Add => "fadd",
                    FOp::Sub => "fsub",
                    FOp::Mul => "fmul",
                    FOp::Madd => "fmadd",
                    FOp::Nmsub => "fnmsub",
                };
                let sfx = if lanes == 2 { ".h2" } else { ".s" };
                if matches!(op, FOp::Madd | FOp::Nmsub) {
                    write!(f, "{n}{sfx} f{fd}, f{fs1}, f{fs2}, f{fs3}")
                } else {
                    write!(f, "{n}{sfx} f{fd}, f{fs1}, f{fs2}")
                }
            }
            Instr::FMvToF { fd, rs } => write!(f, "fmv.w.x f{fd}, x{rs}"),
            Instr::FMvToX { rd, fs } => write!(f, "fmv.x.w x{rd}, f{fs}"),
            Instr::Branch { cond, rs1, rs2, target } => {
                let c = match cond {
                    Cond::Eq => "beq",
                    Cond::Ne => "bne",
                    Cond::Lt => "blt",
                    Cond::Ge => "bge",
                    Cond::Ltu => "bltu",
                    Cond::Geu => "bgeu",
                };
                write!(f, "{c} x{rs1}, x{rs2}, @{target}")
            }
            Instr::Jump { target } => write!(f, "j @{target}"),
            Instr::HwLoop { idx, count, body_start, body_end } => write!(
                f,
                "lp.setup l{idx}, x{count}, @{body_start}..@{body_end}"
            ),
            Instr::Barrier => write!(f, "ev.barrier"),
            Instr::CoreId { rd } => write!(f, "csrr x{rd}, mhartid"),
            Instr::Nop => write!(f, "nop"),
            Instr::Halt => write!(f, "halt"),
        }
    }
}

/// Disassemble a whole program, one instruction per line with indices.
pub fn disassemble(instrs: &[Instr]) -> String {
    instrs
        .iter()
        .enumerate()
        .map(|(i, ins)| format!("{i:5}: {ins}"))
        .collect::<Vec<_>>()
        .join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macload_formats_with_refresh() {
        let i = Instr::MlSdotp {
            prec: Prec::B4,
            sign: Sign::US,
            rd: 10,
            na: 0,
            nb: 4,
            refresh: Some((2, 11)),
        };
        assert_eq!(
            i.to_string(),
            "pv.mlsdotpus.n x10, nn0, nn4 ; nn2=[x11!]"
        );
    }

    #[test]
    fn crumb_suffix() {
        let i = Instr::Sdotp {
            prec: Prec::B2,
            sign: Sign::SS,
            rd: 3,
            rs1: 4,
            rs2: 5,
        };
        assert_eq!(i.to_string(), "pv.sdotps.c x3, x4, x5");
    }
}
