//! Text assembler: parses the assembly syntax emitted by [`disasm`] back
//! into [`Instr`] streams, so kernels can be written/patched as text and
//! every program round-trips (disassemble → assemble → identical
//! instruction vector — property-tested against the real kernels).
//!
//! Branch/loop targets use the explicit `@index` form of the
//! disassembler. One instruction per line; `#`-comments and blank lines
//! are skipped (they do not shift instruction indices — targets refer to
//! instruction positions, as in the hardware's resolved form).

use anyhow::{bail, Context, Result};

use super::instr::{AluOp, Cond, FOp, Instr, Prec, Sign, VAluOp};
use super::program::{IsaLevel, Program};

/// Assemble a full program text.
pub fn assemble(name: &str, isa: IsaLevel, text: &str) -> Result<Program> {
    let mut instrs = Vec::new();
    for (ln, raw) in text.lines().enumerate() {
        // strip "  12: " index prefixes that disassemble() adds
        let line = raw
            .split_once(": ")
            .map(|(pfx, rest)| {
                if pfx.trim().parse::<usize>().is_ok() {
                    rest
                } else {
                    raw
                }
            })
            .unwrap_or(raw)
            .trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        instrs.push(
            parse_line(line)
                .with_context(|| format!("line {}: {line:?}", ln + 1))?,
        );
    }
    let prog = Program { name: name.to_string(), instrs, isa };
    if prog.required_isa() > prog.isa {
        bail!("program uses XpulpNN instructions but declares {isa:?}");
    }
    Ok(prog)
}

fn xreg(tok: &str) -> Result<u8> {
    let t = tok.trim_end_matches(',');
    let n: u8 = t
        .strip_prefix('x')
        .with_context(|| format!("expected xN, got {tok:?}"))?
        .parse()
        .with_context(|| format!("bad register {tok:?}"))?;
    if n > 31 {
        bail!("register {tok} out of range");
    }
    Ok(n)
}

fn freg(tok: &str) -> Result<u8> {
    let t = tok.trim_end_matches(',');
    t.strip_prefix('f')
        .with_context(|| format!("expected fN, got {tok:?}"))?
        .parse()
        .with_context(|| format!("bad fp register {tok:?}"))
}

fn nnreg(tok: &str) -> Result<u8> {
    let t = tok.trim_end_matches(',');
    let n: u8 = t
        .strip_prefix("nn")
        .with_context(|| format!("expected nnN, got {tok:?}"))?
        .parse()?;
    if n as usize >= super::NN_RF_SIZE {
        bail!("NN-RF register {tok} out of range");
    }
    Ok(n)
}

fn imm(tok: &str) -> Result<i32> {
    let t = tok.trim_end_matches(',');
    if let Some(hex) = t.strip_prefix("0x") {
        return Ok(u32::from_str_radix(hex, 16)? as i32);
    }
    t.parse().with_context(|| format!("bad immediate {tok:?}"))
}

fn target(tok: &str) -> Result<usize> {
    tok.trim_end_matches(',')
        .strip_prefix('@')
        .with_context(|| format!("expected @index, got {tok:?}"))?
        .parse()
        .context("bad target index")
}

/// `off(xN)` or `off(xN!)`; returns (base, offset, post_inc_flag).
fn memop(tok: &str) -> Result<(u8, i32, bool)> {
    let t = tok.trim_end_matches(',');
    let (off_s, rest) =
        t.split_once('(').with_context(|| format!("bad mem op {tok:?}"))?;
    let inner = rest.strip_suffix(')').context("missing )")?;
    let (reg_s, post) = match inner.strip_suffix('!') {
        Some(r) => (r, true),
        None => (inner, false),
    };
    Ok((xreg(reg_s)?, imm(off_s)?, post))
}

fn prec_of(sfx: &str) -> Result<Prec> {
    Ok(match sfx {
        "h" => Prec::B16,
        "b" => Prec::B8,
        "n" => Prec::B4,
        "c" => Prec::B2,
        _ => bail!("unknown precision suffix {sfx:?}"),
    })
}

fn sign_of(s: &str) -> Result<Sign> {
    Ok(match s {
        "s" => Sign::SS,
        "u" => Sign::UU,
        "us" => Sign::US,
        "su" => Sign::SU,
        _ => bail!("unknown sign suffix {s:?}"),
    })
}

fn alu_of(m: &str) -> Option<AluOp> {
    Some(match m {
        "add" => AluOp::Add,
        "sub" => AluOp::Sub,
        "sll" => AluOp::Sll,
        "srl" => AluOp::Srl,
        "sra" => AluOp::Sra,
        "and" => AluOp::And,
        "or" => AluOp::Or,
        "xor" => AluOp::Xor,
        "slt" => AluOp::Slt,
        "sltu" => AluOp::Sltu,
        "mul" => AluOp::Mul,
        "p.min" => AluOp::Min,
        "p.max" => AluOp::Max,
        _ => return None,
    })
}

fn parse_line(line: &str) -> Result<Instr> {
    // split the MAC&LOAD refresh annotation first
    let (main, refresh) = match line.split_once(';') {
        Some((m, r)) => (m.trim(), Some(r.trim())),
        None => (line, None),
    };
    let mut it = main.split_whitespace();
    let mnem = it.next().context("empty line")?;
    let args: Vec<&str> = it.collect();
    let arg = |i: usize| -> Result<&str> {
        args.get(i).copied().with_context(|| format!("missing operand {i}"))
    };

    // ---- fixed mnemonics ----
    match mnem {
        "nop" => return Ok(Instr::Nop),
        "halt" => return Ok(Instr::Halt),
        "ev.barrier" => return Ok(Instr::Barrier),
        "csrr" => {
            let rd = xreg(arg(0)?)?;
            if arg(1)? != "mhartid" {
                bail!("only mhartid is modelled");
            }
            return Ok(Instr::CoreId { rd });
        }
        "li" => {
            return Ok(Instr::Li { rd: xreg(arg(0)?)?, imm: imm(arg(1)?)? })
        }
        "j" => return Ok(Instr::Jump { target: target(arg(0)?)? }),
        "p.mac" => {
            return Ok(Instr::Mac {
                rd: xreg(arg(0)?)?,
                rs1: xreg(arg(1)?)?,
                rs2: xreg(arg(2)?)?,
            })
        }
        "lw" | "p.lw" => {
            let rd = xreg(arg(0)?)?;
            let (base, off, post) = memop(arg(1)?)?;
            return Ok(Instr::Lw {
                rd,
                base,
                offset: if post { 0 } else { off },
                post_inc: if post { off } else { 0 },
            });
        }
        "sw" | "p.sw" => {
            let rs = xreg(arg(0)?)?;
            let (base, off, post) = memop(arg(1)?)?;
            return Ok(Instr::Sw {
                rs,
                base,
                offset: if post { 0 } else { off },
                post_inc: if post { off } else { 0 },
            });
        }
        "flw" | "p.flw" => {
            let fd = freg(arg(0)?)?;
            let (base, off, post) = memop(arg(1)?)?;
            return Ok(Instr::Flw {
                fd,
                base,
                offset: if post { 0 } else { off },
                post_inc: if post { off } else { 0 },
            });
        }
        "fsw" | "p.fsw" => {
            let fs = freg(arg(0)?)?;
            let (base, off, post) = memop(arg(1)?)?;
            return Ok(Instr::Fsw {
                fs,
                base,
                offset: if post { 0 } else { off },
                post_inc: if post { off } else { 0 },
            });
        }
        "p.nnlw" => {
            let nn_rd = nnreg(arg(0)?)?;
            let (ptr, off, post) = memop(arg(1)?)?;
            if !post && off != 0 {
                bail!("p.nnlw supports only post-increment addressing");
            }
            return Ok(Instr::NnLoad {
                nn_rd,
                ptr,
                post_inc: if post { off } else { 0 },
            });
        }
        "fmv.w.x" => {
            return Ok(Instr::FMvToF {
                fd: freg(arg(0)?)?,
                rs: xreg(arg(1)?)?,
            })
        }
        "fmv.x.w" => {
            return Ok(Instr::FMvToX {
                rd: xreg(arg(0)?)?,
                fs: freg(arg(1)?)?,
            })
        }
        "lp.setup" => {
            // lp.setup l0, x7, @3..@19
            let idx: u8 = arg(0)?
                .trim_end_matches(',')
                .strip_prefix('l')
                .context("loop index")?
                .parse()?;
            let count = xreg(arg(1)?)?;
            let range = arg(2)?;
            let (s, e) =
                range.split_once("..").context("expected @a..@b")?;
            return Ok(Instr::HwLoop {
                idx,
                count,
                body_start: target(s)?,
                body_end: target(e)?,
            });
        }
        _ => {}
    }

    // ---- branches ----
    if let Some(cond) = match mnem {
        "beq" => Some(Cond::Eq),
        "bne" => Some(Cond::Ne),
        "blt" => Some(Cond::Lt),
        "bge" => Some(Cond::Ge),
        "bltu" => Some(Cond::Ltu),
        "bgeu" => Some(Cond::Geu),
        _ => None,
    } {
        return Ok(Instr::Branch {
            cond,
            rs1: xreg(arg(0)?)?,
            rs2: xreg(arg(1)?)?,
            target: target(arg(2)?)?,
        });
    }

    // ---- FP compute: fadd.s / fmadd.h2 / ... ----
    if let Some((op_s, sfx)) = mnem.split_once('.') {
        let fop = match op_s {
            "fadd" => Some(FOp::Add),
            "fsub" => Some(FOp::Sub),
            "fmul" => Some(FOp::Mul),
            "fmadd" => Some(FOp::Madd),
            "fnmsub" => Some(FOp::Nmsub),
            _ => None,
        };
        if let Some(op) = fop {
            let lanes = match sfx {
                "s" => 1,
                "h2" => 2,
                _ => bail!("unknown fp suffix {sfx:?}"),
            };
            let fd = freg(arg(0)?)?;
            let fs1 = freg(arg(1)?)?;
            let fs2 = freg(arg(2)?)?;
            let fs3 = if matches!(op, FOp::Madd | FOp::Nmsub) {
                freg(arg(3)?)?
            } else {
                0
            };
            return Ok(Instr::FAlu { op, lanes, fd, fs1, fs2, fs3 });
        }
    }

    // ---- packed SIMD: pv.<op>[sign].<prec> ----
    if let Some(rest) = mnem.strip_prefix("pv.") {
        let (body, sfx) =
            rest.rsplit_once('.').context("pv. needs precision suffix")?;
        let prec = prec_of(sfx)?;
        // dot products carry a sign suffix on the op name
        for (stem, accumulate) in [("sdotp", true), ("dotp", false)] {
            if let Some(sign_s) = body.strip_prefix(stem) {
                let sign = sign_of(sign_s)?;
                let rd = xreg(arg(0)?)?;
                let rs1 = xreg(arg(1)?)?;
                let rs2 = xreg(arg(2)?)?;
                return Ok(if accumulate {
                    Instr::Sdotp { prec, sign, rd, rs1, rs2 }
                } else {
                    Instr::Dotp { prec, sign, rd, rs1, rs2 }
                });
            }
        }
        if let Some(sign_s) = body.strip_prefix("mlsdotp") {
            let sign = sign_of(sign_s)?;
            let rd = xreg(arg(0)?)?;
            let na = nnreg(arg(1)?)?;
            let nb = nnreg(arg(2)?)?;
            let refresh = match refresh {
                None => None,
                Some(r) => {
                    // nn2=[x11!]
                    let (nn_s, ptr_s) =
                        r.split_once("=[").context("bad refresh")?;
                    let ptr_s = ptr_s
                        .strip_suffix("!]")
                        .context("refresh must post-increment")?;
                    Some((nnreg(nn_s)?, xreg(ptr_s)?))
                }
            };
            return Ok(Instr::MlSdotp { prec, sign, rd, na, nb, refresh });
        }
        let vop = match body {
            "add" => VAluOp::Add,
            "sub" => VAluOp::Sub,
            "max" => VAluOp::Max,
            "min" => VAluOp::Min,
            "sra" => VAluOp::Sra,
            "shuffle" => VAluOp::Shuffle,
            _ => bail!("unknown pv op {body:?}"),
        };
        return Ok(Instr::VAlu {
            op: vop,
            prec,
            rd: xreg(arg(0)?)?,
            rs1: xreg(arg(1)?)?,
            rs2: xreg(arg(2)?)?,
        });
    }

    // ---- scalar ALU (possibly immediate form with trailing 'i') ----
    if let Some(op) = alu_of(mnem) {
        return Ok(Instr::Alu {
            op,
            rd: xreg(arg(0)?)?,
            rs1: xreg(arg(1)?)?,
            rs2: xreg(arg(2)?)?,
        });
    }
    if let Some(stem) = mnem.strip_suffix('i') {
        if let Some(op) = alu_of(stem) {
            return Ok(Instr::AluImm {
                op,
                rd: xreg(arg(0)?)?,
                rs1: xreg(arg(1)?)?,
                imm: imm(arg(2)?)?,
            });
        }
    }
    bail!("unknown mnemonic {mnem:?}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::disasm::disassemble;
    use crate::isa::Prec;
    use crate::kernels::matmul::{MatmulKernel, MatmulProblem};
    use crate::kernels::TcdmAlloc;

    /// Round-trip property: disassembling any real kernel and assembling
    /// the text reproduces the identical instruction stream.
    #[test]
    fn roundtrip_real_kernels() {
        for kernel in [
            MatmulKernel::Xpulp8,
            MatmulKernel::Nn { prec: Prec::B2 },
            MatmulKernel::MacLoad { prec: Prec::B4 },
            MatmulKernel::UnpackBaseline { prec: Prec::B4 },
        ] {
            let p = MatmulProblem { m: 8, n: 4, k: 32, kernel, cores: 2 };
            let built = p.build(&mut TcdmAlloc::new()).unwrap();
            let text = disassemble(&built.prog.instrs);
            let re = assemble("rt", built.prog.isa, &text)
                .unwrap_or_else(|e| panic!("{kernel:?}: {e}"));
            assert_eq!(re.instrs, built.prog.instrs, "{kernel:?}");
        }
    }

    #[test]
    fn roundtrip_fft_stage() {
        use crate::kernels::fft::FftProblem;
        // reuse the public driver: build via run_with is heavy; assemble a
        // hand-written fp butterfly fragment instead
        let _ = FftProblem { n: 64, cores: 1 };
        let text = "\
flw f1, 0(x8)
fmul.s f7, f3, f5
fnmsub.s f7, f4, f6, f7
fmadd.h2 f8, f4, f5, f8
fsw f1, 4(x8)
csrr x5, mhartid
ev.barrier
halt";
        let p = assemble("frag", IsaLevel::Xpulp, text).unwrap();
        let re = assemble("frag", IsaLevel::Xpulp,
                          &disassemble(&p.instrs)).unwrap();
        assert_eq!(p.instrs, re.instrs);
        assert_eq!(p.instrs.len(), 8);
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let p = assemble(
            "c",
            IsaLevel::Xpulp,
            "# header\n\nli x1, 5\n# mid\naddi x1, x1, -1\n",
        )
        .unwrap();
        assert_eq!(p.instrs.len(), 2);
    }

    #[test]
    fn macload_with_refresh_parses() {
        let p = assemble(
            "ml",
            IsaLevel::XpulpNN,
            "pv.mlsdotps.c x10, nn0, nn4 ; nn2=[x11!]",
        )
        .unwrap();
        assert_eq!(
            p.instrs[0],
            Instr::MlSdotp {
                prec: Prec::B2,
                sign: Sign::SS,
                rd: 10,
                na: 0,
                nb: 4,
                refresh: Some((2, 11)),
            }
        );
    }

    #[test]
    fn errors_are_loud() {
        assert!(assemble("e", IsaLevel::Xpulp, "frobnicate x1").is_err());
        assert!(assemble("e", IsaLevel::Xpulp, "li x99, 1").is_err());
        assert!(assemble("e", IsaLevel::Xpulp, "lw x1, 4[x2]").is_err());
        // ISA level enforcement
        assert!(
            assemble("e", IsaLevel::Xpulp, "pv.sdotps.c x1, x2, x3")
                .is_err()
        );
    }

    #[test]
    fn assembled_program_executes() {
        use crate::cluster::{Cluster, ClusterConfig, TCDM_BASE};
        let text = format!(
            "li x1, {TCDM_BASE}\nli x2, 7\nsw x2, 0(x1)\nlw x3, 0(x1)\n\
             slli x3, x3, 1\nsw x3, 4(x1)\nhalt"
        );
        let prog = assemble("exec", IsaLevel::Xpulp, &text).unwrap();
        let mut cl = Cluster::new(ClusterConfig::soc_controller());
        cl.load_spmd(prog);
        cl.run().unwrap();
        assert_eq!(cl.mem.l1[1], 14);
    }
}
