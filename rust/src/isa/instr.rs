//! Instruction definitions.

use super::{FReg, NnReg, Reg, Target};

/// Packed-SIMD element precision. `B16`/`B8` come from Xpulp; the *nibble*
/// (`B4`) and *crumb* (`B2`) formats are the XpulpNN addition (paper
/// §II-A1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Prec {
    B16,
    B8,
    B4,
    B2,
}

impl Prec {
    /// SIMD lanes in a 32-bit register.
    pub const fn lanes(self) -> u32 {
        match self {
            Prec::B16 => 2,
            Prec::B8 => 4,
            Prec::B4 => 8,
            Prec::B2 => 16,
        }
    }

    /// Element width in bits.
    pub const fn bits(self) -> u32 {
        32 / self.lanes()
    }

    /// MAC operations performed by one `sdotp` of this precision.
    pub const fn macs_per_dotp(self) -> u64 {
        self.lanes() as u64
    }
}

/// Operand signedness of a dot-product (paper §II-A1: ss/uu/us/su forms).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sign {
    SS,
    UU,
    US,
    SU,
}

/// Scalar ALU operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AluOp {
    Add,
    Sub,
    Sll,
    Srl,
    Sra,
    And,
    Or,
    Xor,
    Slt,
    Sltu,
    Mul,
    Min,
    Max,
}

/// Packed-SIMD vector ALU operation (Xpulp `pv.*`, extended by XpulpNN to
/// nibble/crumb granularity).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VAluOp {
    Add,
    Sub,
    Max,
    Min,
    /// Per-lane arithmetic right shift by a lane of rs2.
    Sra,
    /// Lane shuffle: lane i of the result is lane (rs2.lane i) of rs1.
    Shuffle,
}

/// Branch condition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cond {
    Eq,
    Ne,
    Lt,
    Ge,
    Ltu,
    Geu,
}

/// Floating-point operation (shared-FPU; `lanes == 2` models the packed
/// FP16/BF16 SIMD formats of the cluster FPUs, counting 2 flops/lane-op).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FOp {
    Add,
    Sub,
    Mul,
    /// fd = fs1 * fs2 + fs3
    Madd,
    /// fd = -(fs1 * fs2) + fs3
    Nmsub,
}

impl FOp {
    /// Flops per lane (FMA counts 2).
    pub const fn flops(self) -> u64 {
        match self {
            FOp::Madd | FOp::Nmsub => 2,
            _ => 1,
        }
    }
}

/// One instruction at the semantic level. Branch/loop targets are resolved
/// instruction indices (the [`ProgramBuilder`](super::ProgramBuilder)
/// resolves labels at build time).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Instr {
    // ---- scalar integer ----
    Alu { op: AluOp, rd: Reg, rs1: Reg, rs2: Reg },
    AluImm { op: AluOp, rd: Reg, rs1: Reg, imm: i32 },
    /// Load immediate (lui+addi pair on hardware; one slot here, two are
    /// accounted by the cycle model when |imm| needs the upper bits).
    Li { rd: Reg, imm: i32 },
    /// 32-bit fused MAC: rd += rs1 * rs2 (Xpulp `p.mac`).
    Mac { rd: Reg, rs1: Reg, rs2: Reg },

    // ---- packed SIMD ----
    VAlu { op: VAluOp, prec: Prec, rd: Reg, rs1: Reg, rs2: Reg },
    /// rd = dot(rs1, rs2) (Xpulp/XpulpNN `pv.dotp`).
    Dotp { prec: Prec, sign: Sign, rd: Reg, rs1: Reg, rs2: Reg },
    /// rd += dot(rs1, rs2) (`pv.sdotp` — the MAC-equivalent form).
    Sdotp { prec: Prec, sign: Sign, rd: Reg, rs1: Reg, rs2: Reg },

    // ---- XpulpNN MAC&LOAD (paper §II-A2, Fig. 2) ----
    /// rd += dot(nn[na], nn[nb]); if `refresh = Some((nn, ptr))`, the LSU
    /// simultaneously loads mem[ptr] into NN-RF entry `nn` and the ALU
    /// post-increments `ptr` by 4 — a single-cycle fused operation because
    /// the DOTP datapath and the LSU do not conflict.
    MlSdotp {
        prec: Prec,
        sign: Sign,
        rd: Reg,
        na: NnReg,
        nb: NnReg,
        refresh: Option<(NnReg, Reg)>,
    },
    /// Load a word into the NN-RF (NN-RF initialization, outside the inner
    /// loop): nn[nn_rd] = mem[ptr]; ptr += post_inc.
    NnLoad { nn_rd: NnReg, ptr: Reg, post_inc: i32 },

    // ---- memory (Xpulp post-increment forms) ----
    /// rd = mem[rs1 + offset]; if post_inc != 0: rs1 += post_inc
    /// (offset must be 0 in the post-increment form, as on hardware).
    Lw { rd: Reg, base: Reg, offset: i32, post_inc: i32 },
    Sw { rs: Reg, base: Reg, offset: i32, post_inc: i32 },

    // ---- floating point (shared FPU pool) ----
    Flw { fd: FReg, base: Reg, offset: i32, post_inc: i32 },
    Fsw { fs: FReg, base: Reg, offset: i32, post_inc: i32 },
    FAlu { op: FOp, lanes: u8, fd: FReg, fs1: FReg, fs2: FReg, fs3: FReg },
    /// Move between int and fp register files.
    FMvToF { fd: FReg, rs: Reg },
    FMvToX { rd: Reg, fs: FReg },

    // ---- control ----
    Branch { cond: Cond, rs1: Reg, rs2: Reg, target: Target },
    Jump { target: Target },
    /// Xpulp hardware loop: execute [body_start, body_end] `count` times
    /// with zero per-iteration branch overhead. `count` is read from a
    /// register at setup time.
    HwLoop { idx: u8, count: Reg, body_start: Target, body_end: Target },

    // ---- cluster primitives ----
    /// Event-unit barrier across all cluster cores.
    Barrier,
    /// rd = hart id (cluster core index).
    CoreId { rd: Reg },
    Nop,
    /// Terminate this core's program.
    Halt,
}

impl Instr {
    /// True if this instruction issues a data-memory request (participates
    /// in TCDM arbitration).
    pub fn is_mem(&self) -> bool {
        matches!(
            self,
            Instr::Lw { .. }
                | Instr::Sw { .. }
                | Instr::Flw { .. }
                | Instr::Fsw { .. }
                | Instr::NnLoad { .. }
                | Instr::MlSdotp { refresh: Some(_), .. }
        )
    }

    /// True if this instruction occupies the DOTP unit.
    pub fn is_dotp(&self) -> bool {
        matches!(
            self,
            Instr::Dotp { .. } | Instr::Sdotp { .. } | Instr::MlSdotp { .. }
        )
    }

    /// True if this instruction needs a shared-FPU slot.
    pub fn is_fpu(&self) -> bool {
        matches!(self, Instr::FAlu { .. })
    }

    /// MAC operations this instruction performs (for Gop/s accounting).
    pub fn macs(&self) -> u64 {
        match self {
            Instr::Mac { .. } => 1,
            Instr::Dotp { prec, .. } | Instr::Sdotp { prec, .. } => {
                prec.macs_per_dotp()
            }
            Instr::MlSdotp { prec, .. } => prec.macs_per_dotp(),
            _ => 0,
        }
    }

    /// Floating-point operations this instruction performs.
    pub fn flops(&self) -> u64 {
        match self {
            Instr::FAlu { op, lanes, .. } => op.flops() * *lanes as u64,
            _ => 0,
        }
    }
}
