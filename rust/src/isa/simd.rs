//! Packed-SIMD semantics: dot products and vector ALU operations over
//! 32-bit registers holding 2×16b, 4×8b, 8×4b or 16×2b lanes.
//!
//! These functions are the *functional* model of the RI5CY DOTP unit with
//! the XpulpNN multiplier islands (paper §II-A2, Fig. 2b): the ISS uses
//! them for execution and the tests compare them against scalar
//! re-computation.

use super::{Prec, Sign, VAluOp};

/// Extract lane `i` of `word` as signed (two's complement of the lane width).
#[inline]
pub fn lane_s(word: u32, prec: Prec, i: u32) -> i32 {
    let bits = prec.bits();
    let raw = (word >> (i * bits)) & ((1u64 << bits) as u32).wrapping_sub(1);
    // sign-extend
    let shift = 32 - bits;
    ((raw << shift) as i32) >> shift
}

/// Extract lane `i` of `word` as unsigned.
#[inline]
pub fn lane_u(word: u32, prec: Prec, i: u32) -> i32 {
    let bits = prec.bits();
    ((word >> (i * bits)) & ((1u64 << bits) as u32).wrapping_sub(1)) as i32
}

/// Insert `val`'s low bits into lane `i` of `word`.
#[inline]
pub fn set_lane(word: u32, prec: Prec, i: u32, val: i32) -> u32 {
    let bits = prec.bits();
    let mask = ((1u64 << bits) as u32).wrapping_sub(1);
    let cleared = word & !(mask << (i * bits));
    cleared | (((val as u32) & mask) << (i * bits))
}

/// Dot product of two packed registers with the given signedness; returns
/// the 32-bit sum (the DOTP unit's reduction result, before accumulation).
pub fn dotp(a: u32, b: u32, prec: Prec, sign: Sign) -> i32 {
    let mut acc: i32 = 0;
    for i in 0..prec.lanes() {
        let (x, y) = match sign {
            Sign::SS => (lane_s(a, prec, i), lane_s(b, prec, i)),
            Sign::UU => (lane_u(a, prec, i), lane_u(b, prec, i)),
            Sign::US => (lane_u(a, prec, i), lane_s(b, prec, i)),
            Sign::SU => (lane_s(a, prec, i), lane_u(b, prec, i)),
        };
        acc = acc.wrapping_add(x.wrapping_mul(y));
    }
    acc
}

/// Packed-SIMD ALU op; lanes are treated as signed (matching `pv.*` defaults).
pub fn simd_alu(op: VAluOp, a: u32, b: u32, prec: Prec) -> u32 {
    let mut out = 0u32;
    for i in 0..prec.lanes() {
        let x = lane_s(a, prec, i);
        let y = lane_s(b, prec, i);
        let v = match op {
            VAluOp::Add => x.wrapping_add(y),
            VAluOp::Sub => x.wrapping_sub(y),
            VAluOp::Max => x.max(y),
            VAluOp::Min => x.min(y),
            VAluOp::Sra => x >> (y as u32 & (prec.bits() - 1)),
            VAluOp::Shuffle => {
                let src = (y as u32) % prec.lanes();
                lane_s(a, prec, src)
            }
        };
        out = set_lane(out, prec, i, v);
    }
    out
}

/// Pack a slice of lane values (low bits taken) into 32-bit words.
pub fn pack(values: &[i32], prec: Prec) -> Vec<u32> {
    let lanes = prec.lanes() as usize;
    values
        .chunks(lanes)
        .map(|chunk| {
            let mut w = 0u32;
            for (i, &v) in chunk.iter().enumerate() {
                w = set_lane(w, prec, i as u32, v);
            }
            w
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn lanes_roundtrip_signed() {
        let mut rng = Rng::new(5);
        for prec in [Prec::B16, Prec::B8, Prec::B4, Prec::B2] {
            let half = 1i32 << (prec.bits() - 1);
            for _ in 0..200 {
                let vals: Vec<i32> = (0..prec.lanes())
                    .map(|_| rng.range_i32(-half, half))
                    .collect();
                let mut w = 0u32;
                for (i, &v) in vals.iter().enumerate() {
                    w = set_lane(w, prec, i as u32, v);
                }
                for (i, &v) in vals.iter().enumerate() {
                    assert_eq!(lane_s(w, prec, i as u32), v, "{prec:?}");
                }
            }
        }
    }

    #[test]
    fn dotp_matches_scalar() {
        let mut rng = Rng::new(7);
        for prec in [Prec::B16, Prec::B8, Prec::B4, Prec::B2] {
            let half = 1i32 << (prec.bits() - 1);
            for _ in 0..500 {
                let xs: Vec<i32> = (0..prec.lanes())
                    .map(|_| rng.range_i32(-half, half))
                    .collect();
                let ys: Vec<i32> = (0..prec.lanes())
                    .map(|_| rng.range_i32(-half, half))
                    .collect();
                let a = pack(&xs, prec)[0];
                let b = pack(&ys, prec)[0];
                let want: i32 = xs.iter().zip(&ys).map(|(x, y)| x * y).sum();
                assert_eq!(dotp(a, b, prec, Sign::SS), want, "{prec:?}");
            }
        }
    }

    #[test]
    fn dotp_unsigned() {
        let mut rng = Rng::new(8);
        for prec in [Prec::B8, Prec::B4, Prec::B2] {
            let hi = 1i32 << prec.bits();
            for _ in 0..300 {
                let xs: Vec<i32> =
                    (0..prec.lanes()).map(|_| rng.range_i32(0, hi)).collect();
                let ys: Vec<i32> =
                    (0..prec.lanes()).map(|_| rng.range_i32(0, hi)).collect();
                let a = pack(&xs, prec)[0];
                let b = pack(&ys, prec)[0];
                let want: i32 = xs.iter().zip(&ys).map(|(x, y)| x * y).sum();
                assert_eq!(dotp(a, b, prec, Sign::UU), want);
            }
        }
    }

    #[test]
    fn mixed_sign_us() {
        // one unsigned activation vector times signed weights — the QNN case
        let xs = [3, 0, 2, 1]; // unsigned 8-bit
        let ws = [-128, 127, -1, 5]; // signed 8-bit
        let a = pack(&xs, Prec::B8)[0];
        let b = pack(&ws, Prec::B8)[0];
        let want: i32 = xs.iter().zip(&ws).map(|(x, y)| x * y).sum();
        assert_eq!(dotp(a, b, Prec::B8, Sign::US), want);
    }

    #[test]
    fn simd_add_wraps_per_lane() {
        let a = pack(&[127, -128, 1, -1], Prec::B8)[0];
        let b = pack(&[1, -1, 2, 3], Prec::B8)[0];
        let r = simd_alu(VAluOp::Add, a, b, Prec::B8);
        assert_eq!(lane_s(r, Prec::B8, 0), -128); // 127+1 wraps
        assert_eq!(lane_s(r, Prec::B8, 1), 127); // -128-1 wraps
        assert_eq!(lane_s(r, Prec::B8, 2), 3);
        assert_eq!(lane_s(r, Prec::B8, 3), 2);
    }

    #[test]
    fn max_min() {
        let a = pack(&[5, -3], Prec::B16)[0];
        let b = pack(&[-7, 9], Prec::B16)[0];
        let mx = simd_alu(VAluOp::Max, a, b, Prec::B16);
        let mn = simd_alu(VAluOp::Min, a, b, Prec::B16);
        assert_eq!(lane_s(mx, Prec::B16, 0), 5);
        assert_eq!(lane_s(mx, Prec::B16, 1), 9);
        assert_eq!(lane_s(mn, Prec::B16, 0), -7);
        assert_eq!(lane_s(mn, Prec::B16, 1), -3);
    }
}
