//! RV32IMFC + Xpulp + XpulpNN instruction set (paper §II-A).
//!
//! The simulator executes programs at the *semantic* level: instructions are
//! a typed enum, not binary encodings, but every instruction corresponds
//! one-to-one with an instruction the GCC XpulpNN backend emits, so
//! instruction counts, DOTP-unit utilization and the MAC&LOAD overlap
//! behaviour match the chip's.
//!
//! Extension inventory:
//! * **Xpulp** (both the SOC core and the cluster cores): hardware loops
//!   (`lp.setup`), post-increment load/store, 32-bit MAC, packed-SIMD
//!   dot-products for 16-bit and 8-bit data.
//! * **XpulpNN** (cluster cores only): packed-SIMD dot-products and vector
//!   ALU ops for *nibble* (4-bit) and *crumb* (2-bit) data, plus the fused
//!   MAC&LOAD ([`Instr::MlSdotp`]) drawing operands from the 6-entry NN
//!   register file and optionally refreshing one NN-RF entry through the
//!   LSU in the same cycle.

pub mod asm;
pub mod disasm;
mod instr;
mod program;
pub mod simd;

pub use asm::assemble;
pub use instr::{AluOp, Cond, FOp, Instr, Prec, Sign, VAluOp};
pub use program::{IsaLevel, Program, ProgramBuilder};
pub use simd::{dotp, simd_alu};

/// General-purpose register index (x0..x31; x0 hardwired to zero).
pub type Reg = u8;
/// Floating-point register index (f0..f31).
pub type FReg = u8;
/// NN-RF register index (nn0..nn5; paper §II-A2: 6 × 32-bit SIMD vectors).
pub type NnReg = u8;
/// Resolved branch/loop target: an index into the program's instruction vec.
pub type Target = usize;

/// Number of NN-RF entries.
pub const NN_RF_SIZE: usize = 6;
/// Number of hardware-loop contexts (Xpulp: two nested loops).
pub const HW_LOOPS: usize = 2;
