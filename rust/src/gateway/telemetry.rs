//! Gateway telemetry: admission counters and per-tenant latency
//! histograms, kept out of the coordinator (the orchestrator/telemetry
//! split — serving metrics are their own module, not state woven
//! through the compute path).
//!
//! Counters are lock-free atomics bumped on the submit/dispatch path;
//! per-tenant state (histograms, served specs) sits behind one mutex
//! touched once per admission and once per completion. Reading is
//! always through an immutable [`GatewaySnapshot`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::analysis::sync::{lock_recover, Mutex};
use crate::dnn::NetworkSpec;

/// Log2-bucketed latency histogram: bucket `i` holds samples in
/// `[2^i, 2^(i+1))` microseconds, 40 buckets (~18 minutes) — enough
/// range for queue + service latency without unbounded memory.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    buckets: [u64; 40],
    count: u64,
}

impl LatencyHistogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Self { buckets: [0; 40], count: 0 }
    }

    /// Record one latency sample in microseconds.
    pub fn record(&mut self, us: u64) {
        let idx = (63 - us.max(1).leading_zeros() as usize).min(39);
        self.buckets[idx] += 1;
        self.count += 1;
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Upper bound (µs) of the bucket holding the q-quantile sample
    /// (0 when empty). Log2 buckets: quantiles are order-of-magnitude
    /// reads, exact percentiles come from the caller's own samples.
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil()
            as u64)
            .max(1);
        let mut seen = 0;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                return (1u64 << (i + 1)) - 1;
            }
        }
        (1u64 << 40) - 1
    }

    /// Median bucket upper bound (µs).
    pub fn p50_us(&self) -> u64 {
        self.quantile_us(0.50)
    }

    /// 99th-percentile bucket upper bound (µs).
    pub fn p99_us(&self) -> u64 {
        self.quantile_us(0.99)
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Per-tenant mutable state behind the telemetry mutex.
#[derive(Debug, Default)]
struct TenantStats {
    admitted: u64,
    completed: u64,
    rejected: u64,
    deadline_missed: u64,
    cancelled: u64,
    shed: u64,
    /// Distinct specs this tenant has served through the gateway — the
    /// quota-accounting set (a plan-cache "tenant share" is the bytes
    /// of the specs it deploys).
    specs: Vec<NetworkSpec>,
    /// End-to-end latency (queue + service), microseconds.
    hist: LatencyHistogram,
}

/// Gateway-wide counters plus per-tenant stats.
pub struct GatewayTelemetry {
    submitted: AtomicU64,
    admitted: AtomicU64,
    rejected_full: AtomicU64,
    rejected_tenant: AtomicU64,
    rejected_shutdown: AtomicU64,
    rejected_brownout: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    cancelled: AtomicU64,
    shed: AtomicU64,
    panicked: AtomicU64,
    degraded: AtomicU64,
    deadline_missed: AtomicU64,
    finish_seq: AtomicU64,
    tenants: Mutex<HashMap<String, TenantStats>>,
}

impl GatewayTelemetry {
    /// Fresh telemetry, all zeros.
    pub fn new() -> Self {
        Self {
            submitted: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
            rejected_full: AtomicU64::new(0),
            rejected_tenant: AtomicU64::new(0),
            rejected_shutdown: AtomicU64::new(0),
            rejected_brownout: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            panicked: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
            deadline_missed: AtomicU64::new(0),
            finish_seq: AtomicU64::new(0),
            tenants: Mutex::new(HashMap::new()),
        }
    }

    pub(super) fn note_submitted(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub(super) fn note_rejected_full(&self, tenant: &str) {
        self.rejected_full.fetch_add(1, Ordering::Relaxed);
        self.tenant_mut(tenant, |t| t.rejected += 1);
    }

    pub(super) fn note_rejected_tenant(&self, tenant: &str) {
        self.rejected_tenant.fetch_add(1, Ordering::Relaxed);
        self.tenant_mut(tenant, |t| t.rejected += 1);
    }

    pub(super) fn note_rejected_shutdown(&self) {
        self.rejected_shutdown.fetch_add(1, Ordering::Relaxed);
    }

    pub(super) fn note_admitted(&self, tenant: &str, spec: &NetworkSpec) {
        self.admitted.fetch_add(1, Ordering::Relaxed);
        self.tenant_mut(tenant, |t| {
            t.admitted += 1;
            if !t.specs.contains(spec) {
                t.specs.push(spec.clone());
            }
        });
    }

    pub(super) fn note_completed(
        &self,
        tenant: &str,
        latency_us: u64,
        missed_deadline: bool,
    ) -> u64 {
        self.completed.fetch_add(1, Ordering::Relaxed);
        if missed_deadline {
            self.deadline_missed.fetch_add(1, Ordering::Relaxed);
        }
        self.tenant_mut(tenant, |t| {
            t.completed += 1;
            if missed_deadline {
                t.deadline_missed += 1;
            }
            t.hist.record(latency_us);
        });
        self.finish_seq.fetch_add(1, Ordering::Relaxed) + 1
    }

    pub(super) fn note_failed(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    pub(super) fn note_rejected_brownout(&self, tenant: &str) {
        self.rejected_brownout.fetch_add(1, Ordering::Relaxed);
        self.tenant_mut(tenant, |t| t.rejected += 1);
    }

    pub(super) fn note_cancelled(&self, tenant: &str) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
        self.tenant_mut(tenant, |t| t.cancelled += 1);
    }

    pub(super) fn note_shed(&self, tenant: &str) {
        self.shed.fetch_add(1, Ordering::Relaxed);
        self.tenant_mut(tenant, |t| t.shed += 1);
    }

    /// A panicked request still records its end-to-end latency and
    /// deadline outcome — a crash is an observation, not a telemetry
    /// hole.
    pub(super) fn note_panicked(
        &self,
        tenant: &str,
        latency_us: u64,
        missed_deadline: bool,
    ) {
        self.panicked.fetch_add(1, Ordering::Relaxed);
        if missed_deadline {
            self.deadline_missed.fetch_add(1, Ordering::Relaxed);
        }
        self.tenant_mut(tenant, |t| {
            if missed_deadline {
                t.deadline_missed += 1;
            }
            t.hist.record(latency_us);
        });
    }

    pub(super) fn note_degraded(&self) {
        self.degraded.fetch_add(1, Ordering::Relaxed);
    }

    /// Distinct specs `tenant` has served — the byte-quota accounting
    /// set ([`crate::gateway::Gateway::set_tenant_quota`]).
    pub fn tenant_specs(&self, tenant: &str) -> Vec<NetworkSpec> {
        lock_recover(&self.tenants)
            .get(tenant)
            .map(|t| t.specs.clone())
            .unwrap_or_default()
    }

    fn tenant_mut(&self, tenant: &str, f: impl FnOnce(&mut TenantStats)) {
        let mut tenants = lock_recover(&self.tenants);
        f(tenants.entry(tenant.to_string()).or_default());
    }

    /// An immutable point-in-time view of all counters and tenants.
    pub fn snapshot(&self) -> GatewaySnapshot {
        let tenants = lock_recover(&self.tenants);
        let mut rows: Vec<TenantSnapshot> = tenants
            .iter()
            .map(|(name, t)| TenantSnapshot {
                tenant: name.clone(),
                admitted: t.admitted,
                completed: t.completed,
                rejected: t.rejected,
                deadline_missed: t.deadline_missed,
                cancelled: t.cancelled,
                shed: t.shed,
                p50_us: t.hist.p50_us(),
                p99_us: t.hist.p99_us(),
            })
            .collect();
        rows.sort_by(|a, b| a.tenant.cmp(&b.tenant));
        GatewaySnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            admitted: self.admitted.load(Ordering::Relaxed),
            rejected_full: self.rejected_full.load(Ordering::Relaxed),
            rejected_tenant: self.rejected_tenant.load(Ordering::Relaxed),
            rejected_shutdown: self
                .rejected_shutdown
                .load(Ordering::Relaxed),
            rejected_brownout: self
                .rejected_brownout
                .load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            panicked: self.panicked.load(Ordering::Relaxed),
            degraded: self.degraded.load(Ordering::Relaxed),
            deadline_missed: self.deadline_missed.load(Ordering::Relaxed),
            tenants: rows,
        }
    }
}

impl Default for GatewayTelemetry {
    fn default() -> Self {
        Self::new()
    }
}

/// Point-in-time gateway counters (see [`GatewayTelemetry::snapshot`]).
#[derive(Debug, Clone)]
pub struct GatewaySnapshot {
    /// Submit attempts, admitted or not.
    pub submitted: u64,
    /// Requests accepted into the queue.
    pub admitted: u64,
    /// Rejections from a full admission queue.
    pub rejected_full: u64,
    /// Rejections from a saturated tenant.
    pub rejected_tenant: u64,
    /// Rejections during shutdown.
    pub rejected_shutdown: u64,
    /// Low-priority rejections while past the brownout watermark.
    pub rejected_brownout: u64,
    /// Requests completed successfully.
    pub completed: u64,
    /// Requests that failed during dispatch (deploy/quota/inference
    /// error).
    pub failed: u64,
    /// Queued requests removed by [`crate::gateway::Ticket::cancel`].
    pub cancelled: u64,
    /// Queued requests shed by the deadline reaper
    /// ([`crate::gateway::GatewayConfig::shed_expired`]).
    pub shed: u64,
    /// Requests whose inference panicked (caught, typed, delivered).
    pub panicked: u64,
    /// Requests dispatched on degraded (brownout) lane widths.
    pub degraded: u64,
    /// Completions (or panics) after their deadline, plus nothing from
    /// shed requests — those are counted in `shed`, not here.
    pub deadline_missed: u64,
    /// Per-tenant rows, sorted by tenant name.
    pub tenants: Vec<TenantSnapshot>,
}

impl GatewaySnapshot {
    /// Total rejections across all bounds.
    pub fn rejected(&self) -> u64 {
        self.rejected_full
            + self.rejected_tenant
            + self.rejected_shutdown
            + self.rejected_brownout
    }

    /// The lifecycle ledger balances: every submit was either rejected
    /// or admitted, and every admitted request reached exactly one
    /// terminal state. Checked after draining (a request still queued
    /// or running is admitted but not yet terminal).
    pub fn reconciles(&self) -> bool {
        self.submitted == self.admitted + self.rejected()
            && self.admitted
                == self.completed
                    + self.failed
                    + self.cancelled
                    + self.shed
                    + self.panicked
    }
}

/// One tenant's row in a [`GatewaySnapshot`].
#[derive(Debug, Clone)]
pub struct TenantSnapshot {
    /// Tenant name as submitted.
    pub tenant: String,
    /// Requests admitted for this tenant.
    pub admitted: u64,
    /// Requests completed for this tenant.
    pub completed: u64,
    /// Requests rejected for this tenant (queue, tenant, or brownout
    /// bound).
    pub rejected: u64,
    /// Completions past their deadline.
    pub deadline_missed: u64,
    /// Queued requests this tenant cancelled.
    pub cancelled: u64,
    /// Queued requests the reaper shed for this tenant.
    pub shed: u64,
    /// Median end-to-end latency (µs, log2-bucket upper bound).
    pub p50_us: u64,
    /// 99th-percentile end-to-end latency (µs, log2-bucket upper
    /// bound).
    pub p99_us: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::PrecisionConfig;

    #[test]
    fn histogram_buckets_are_log2_and_quantiles_monotone() {
        let mut h = LatencyHistogram::new();
        assert_eq!(h.p50_us(), 0);
        for us in [1u64, 2, 3, 900, 1000, 1100, 64_000] {
            h.record(us);
        }
        assert_eq!(h.count(), 7);
        // p50 lands in the ~1ms cluster, p99 at the 64ms outlier
        assert!(h.p50_us() >= 511 && h.p50_us() <= 2047, "{}", h.p50_us());
        assert!(h.p99_us() >= 64_000, "{}", h.p99_us());
        assert!(h.p50_us() <= h.p99_us());
        // zero records as the first bucket, not a panic
        h.record(0);
        assert_eq!(h.count(), 8);
    }

    #[test]
    fn snapshot_aggregates_per_tenant() {
        let t = GatewayTelemetry::new();
        let spec = NetworkSpec::new("kws", PrecisionConfig::Mixed, 1);
        t.note_submitted();
        t.note_admitted("b", &spec);
        t.note_submitted();
        t.note_admitted("a", &spec);
        t.note_submitted();
        t.note_rejected_full("a");
        assert_eq!(t.note_completed("a", 100, false), 1);
        assert_eq!(t.note_completed("b", 5000, true), 2);
        let snap = t.snapshot();
        assert_eq!(snap.submitted, 3);
        assert_eq!(snap.admitted, 2);
        assert_eq!(snap.rejected(), 1);
        assert_eq!(snap.completed, 2);
        assert_eq!(snap.deadline_missed, 1);
        // rows sorted by tenant name
        assert_eq!(snap.tenants.len(), 2);
        assert_eq!(snap.tenants[0].tenant, "a");
        assert_eq!(snap.tenants[0].rejected, 1);
        assert_eq!(snap.tenants[1].deadline_missed, 1);
        assert!(snap.tenants[1].p99_us >= 5000);
        assert_eq!(t.tenant_specs("a"), vec![spec]);
        assert!(t.tenant_specs("nobody").is_empty());
    }

    #[test]
    fn lifecycle_counters_reconcile_exactly() {
        let t = GatewayTelemetry::new();
        let spec = NetworkSpec::new("kws", PrecisionConfig::Mixed, 1);
        // 6 submits: 1 brownout rejection + 5 admitted, each admitted
        // reaching a distinct terminal state.
        t.note_submitted();
        t.note_rejected_brownout("bulk");
        for _ in 0..5 {
            t.note_submitted();
            t.note_admitted("acme", &spec);
        }
        t.note_completed("acme", 100, false);
        t.note_failed();
        t.note_cancelled("acme");
        t.note_shed("acme");
        t.note_panicked("acme", 700, true);
        t.note_degraded();
        let snap = t.snapshot();
        assert!(snap.reconciles(), "{snap:?}");
        assert_eq!(snap.rejected(), 1);
        assert_eq!(snap.rejected_brownout, 1);
        assert_eq!(snap.cancelled, 1);
        assert_eq!(snap.shed, 1);
        assert_eq!(snap.panicked, 1);
        assert_eq!(snap.degraded, 1);
        // the panic recorded latency + deadline miss
        assert_eq!(snap.deadline_missed, 1);
        let acme = snap
            .tenants
            .iter()
            .find(|r| r.tenant == "acme")
            .expect("acme row");
        assert_eq!(acme.cancelled, 1);
        assert_eq!(acme.shed, 1);
        assert_eq!(acme.deadline_missed, 1);
        assert!(acme.p99_us >= 700, "panic latency recorded");
        // an in-flight (undrained) ledger must not reconcile
        t.note_submitted();
        t.note_admitted("acme", &spec);
        assert!(!t.snapshot().reconciles());
    }
}
