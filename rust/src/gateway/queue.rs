//! The admission queue: request records, deadline/priority ordering,
//! and the blocking [`Ticket`] reply path.
//!
//! Synchronization goes through the `analysis::sync` façade, and every
//! lock/wait uses the poison-recovering helpers: a dispatcher that
//! panicked while holding a lock must never strand a blocked
//! [`Ticket::wait`] caller (the protected values — a result slot, a
//! queue of owned requests — are valid at every yield point).

use std::collections::HashMap;
use std::sync::{Arc, Weak};
use std::time::{Duration, Instant};

use crate::analysis::sync::{lock_recover, wait_recover, Condvar, Mutex};

use anyhow::Result;

use crate::coordinator::InferenceResult;
use crate::dnn::NetworkSpec;
use crate::power::OperatingPoint;

use super::Priority;

/// One admitted request waiting in (or popped from) the queue.
///
/// `pub` (inside a private module) rather than `pub(super)` so the
/// feature-gated [`crate::gateway::model`] re-export can hand the real
/// type to the interleaving tests.
pub struct Request {
    /// Arrival order: monotonically increasing admission id — the
    /// aging/tie-break key.
    pub id: u64,
    pub tenant: String,
    pub spec: NetworkSpec,
    pub op: OperatingPoint,
    pub images: Vec<Vec<i32>>,
    pub priority: Priority,
    pub submitted: Instant,
    /// Absolute completion deadline, if any. When
    /// [`super::GatewayConfig::shed_expired`] is on (the default) a
    /// request still queued past its deadline is shed with a typed
    /// error; with it off the miss is *counted* (and flagged on the
    /// result) but still served — partial results beat silent loss for
    /// end-node workloads that want them.
    pub deadline: Option<Instant>,
    pub reply: Arc<ReplySlot>,
}

/// The rendezvous between the dispatcher and a waiting caller.
///
/// Protocol invariant (checked under the interleaving explorer): the
/// waiter is only ever woken *after* the result was stored under the
/// same mutex — store-then-notify, with the waiter re-checking the slot
/// in a loop. Either order of fill vs. wait delivers exactly once.
pub struct ReplySlot {
    result: Mutex<Option<Result<Completed>>>,
    ready: Condvar,
}

impl ReplySlot {
    /// A fresh, empty slot.
    pub fn new() -> Arc<Self> {
        Arc::new(Self {
            result: Mutex::new(None),
            ready: Condvar::new(),
        })
    }

    /// Deliver the result and wake the waiter (dispatcher side).
    /// Poison-recovering: a dispatcher unwinding through other locks
    /// must still complete this delivery.
    pub fn fill(&self, result: Result<Completed>) {
        *lock_recover(&self.result) = Some(result);
        self.ready.notify_all();
    }

    fn take_blocking(&self) -> Result<Completed> {
        let mut guard = lock_recover(&self.result);
        loop {
            if let Some(result) = guard.take() {
                return result;
            }
            guard = wait_recover(&self.ready, guard);
        }
    }
}

/// Outcome of a [`Ticket::cancel`] call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CancelOutcome {
    /// The request was still queued and has been removed; its
    /// [`Ticket::wait`] resolves to [`super::ServeError::Cancelled`].
    Cancelled,
    /// The dispatcher already popped the request (or the gateway is
    /// gone): cancellation is acknowledged but the request runs to its
    /// natural outcome — no mid-inference abort, no torn state.
    AlreadyStarted,
}

/// Handle to one admitted request; [`Ticket::wait`] blocks until the
/// dispatcher delivers the result. No async runtime involved — a plain
/// condvar rendezvous, usable from any thread.
pub struct Ticket {
    pub(super) id: u64,
    pub(super) slot: Arc<ReplySlot>,
    /// Back-reference for [`Self::cancel`]; `Weak` so an outstanding
    /// ticket never keeps a dropped gateway's dispatcher state alive.
    pub(super) shared: Weak<super::dispatch::Shared>,
}

impl Ticket {
    /// The admission id of this request (arrival order).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until the request completes (or fails) and return the
    /// outcome. Consumes the ticket: one request, one result.
    pub fn wait(self) -> Result<Completed> {
        self.slot.take_blocking()
    }

    /// Cancel this request if it is still queued: the request is
    /// removed, its inflight slot released, and [`Self::wait`] resolves
    /// immediately with a typed [`super::ServeError::Cancelled`]. Once
    /// execution has started the cancel is acknowledged but ignored
    /// ([`CancelOutcome::AlreadyStarted`]) — the result still arrives.
    /// Borrowing (not consuming): cancel-then-wait is the intended
    /// call sequence.
    pub fn cancel(&self) -> CancelOutcome {
        match self.shared.upgrade() {
            Some(shared) => super::dispatch::cancel_request(&shared, self.id),
            None => CancelOutcome::AlreadyStarted,
        }
    }

    /// Build a ticket over an explicit slot — for the interleaving
    /// tests, which drive the real wait/fill rendezvous under the
    /// schedule explorer without a gateway around it. Its
    /// [`Self::cancel`] always reports [`CancelOutcome::AlreadyStarted`]
    /// (no gateway to cancel through).
    #[cfg(any(test, feature = "interleave"))]
    pub fn for_model(id: u64, slot: Arc<ReplySlot>) -> Self {
        Self { id, slot, shared: Weak::new() }
    }
}

/// A finished request: per-image results plus serving metadata.
pub struct Completed {
    /// Per-image inference results, in submit order — bitwise identical
    /// to a direct `Deployment::infer_scheduled` call on the same
    /// images.
    pub results: Vec<InferenceResult>,
    /// Time spent waiting in the admission queue.
    pub queued: Duration,
    /// Time spent executing on the runtime.
    pub service: Duration,
    /// Whether completion happened after the request's deadline.
    pub deadline_missed: bool,
    /// Global completion order (1-based): the Kth request the gateway
    /// finished — lets tests pin starvation bounds exactly.
    pub finish_seq: u64,
}

/// Mutable queue state behind the gateway's single mutex.
pub struct QueueState {
    pub queue: Vec<Request>,
    /// Admitted-but-not-completed request count per tenant.
    pub inflight: HashMap<String, usize>,
    /// While paused the dispatcher pops nothing (tests/maintenance);
    /// admission stays open.
    pub paused: bool,
    pub shutdown: bool,
    pub next_id: u64,
    /// Consecutive priority-ordered pops since the last aged pop — the
    /// starvation-bound counter.
    pub priority_pops: usize,
}

impl QueueState {
    /// Fresh, empty queue state.
    pub fn new() -> Self {
        Self {
            queue: Vec::new(),
            inflight: HashMap::new(),
            paused: false,
            shutdown: false,
            next_id: 0,
            priority_pops: 0,
        }
    }
}

impl Default for QueueState {
    fn default() -> Self {
        Self::new()
    }
}

/// Pop the next request: normally the (priority, deadline, arrival)
/// minimum; every `starvation_bound`th pop instead takes the globally
/// oldest request, so a steady high-priority stream cannot starve bulk
/// traffic forever. Returns `None` on an empty queue.
pub fn pop_next(
    state: &mut QueueState,
    starvation_bound: usize,
) -> Option<Request> {
    if state.queue.is_empty() {
        return None;
    }
    let aged = starvation_bound > 0
        && state.priority_pops + 1 >= starvation_bound;
    let idx = if aged {
        state.priority_pops = 0;
        // oldest admission id wins, priority ignored
        state
            .queue
            .iter()
            .enumerate()
            .min_by_key(|(_, r)| r.id)
            .map(|(i, _)| i)
            .expect("invariant: a non-empty queue has a minimum")
    } else {
        state.priority_pops += 1;
        state
            .queue
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                a.priority
                    .rank()
                    .cmp(&b.priority.rank())
                    .then_with(|| cmp_deadline(a.deadline, b.deadline))
                    .then_with(|| a.id.cmp(&b.id))
            })
            .map(|(i, _)| i)
            .expect("invariant: a non-empty queue has a minimum")
    };
    Some(state.queue.swap_remove(idx))
}

/// Release one unit of `tenant`'s inflight count — the bookkeeping
/// shared by every terminal transition (completion, panic, cancel,
/// shed). Must run under the queue lock, exactly once per admitted
/// request.
pub fn release_inflight(state: &mut QueueState, tenant: &str) {
    if let Some(n) = state.inflight.get_mut(tenant) {
        *n = n.saturating_sub(1);
        if *n == 0 {
            state.inflight.remove(tenant);
        }
    }
}

/// Remove the still-queued request with admission id `id`, releasing
/// its inflight slot. `None` when no such request is queued (already
/// popped, shed, or never admitted) — the caller-side half of
/// [`super::Ticket::cancel`]. The reply slot is *not* filled here:
/// the caller fills it outside the queue lock.
pub fn cancel_queued(state: &mut QueueState, id: u64) -> Option<Request> {
    let idx = state.queue.iter().position(|r| r.id == id)?;
    let req = state.queue.swap_remove(idx);
    release_inflight(state, &req.tenant);
    Some(req)
}

/// Remove every queued request whose deadline is strictly before
/// `now`, releasing each inflight slot — the queue-side half of the
/// deadline reaper. Reply slots are *not* filled here: the dispatcher
/// fills them outside the queue lock. `now` is a parameter (not read
/// inside) so interleave models stay control-flow deterministic.
pub fn shed_expired(state: &mut QueueState, now: Instant) -> Vec<Request> {
    let mut shed = Vec::new();
    let mut i = 0;
    while i < state.queue.len() {
        if state.queue[i].deadline.is_some_and(|d| now > d) {
            let req = state.queue.swap_remove(i);
            release_inflight(state, &req.tenant);
            shed.push(req);
        } else {
            i += 1;
        }
    }
    shed
}

/// Earlier deadlines first; requests without one sort after all
/// deadlined requests.
fn cmp_deadline(
    a: Option<Instant>,
    b: Option<Instant>,
) -> std::cmp::Ordering {
    match (a, b) {
        (Some(a), Some(b)) => a.cmp(&b),
        (Some(_), None) => std::cmp::Ordering::Less,
        (None, Some(_)) => std::cmp::Ordering::Greater,
        (None, None) => std::cmp::Ordering::Equal,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::PrecisionConfig;

    fn req(
        id: u64,
        priority: Priority,
        deadline_us: Option<u64>,
        base: Instant,
    ) -> Request {
        Request {
            id,
            tenant: "t".into(),
            spec: NetworkSpec::new("kws", PrecisionConfig::Mixed, 1),
            op: OperatingPoint::at_vdd(0.8),
            images: Vec::new(),
            priority,
            submitted: base,
            deadline: deadline_us
                .map(|us| base + Duration::from_micros(us)),
            reply: ReplySlot::new(),
        }
    }

    fn ids_in_pop_order(
        mut state: QueueState,
        starvation_bound: usize,
    ) -> Vec<u64> {
        let mut out = Vec::new();
        while let Some(r) = pop_next(&mut state, starvation_bound) {
            out.push(r.id);
        }
        out
    }

    #[test]
    fn pops_by_priority_then_deadline_then_arrival() {
        let base = Instant::now();
        let mut state = QueueState::new();
        state.queue.push(req(0, Priority::Low, None, base));
        state.queue.push(req(1, Priority::Normal, Some(500), base));
        state.queue.push(req(2, Priority::Normal, Some(100), base));
        state.queue.push(req(3, Priority::Normal, None, base));
        state.queue.push(req(4, Priority::High, None, base));
        // strict order: high first, then normal by deadline (None
        // last, ties by arrival), low last
        assert_eq!(ids_in_pop_order(state, 0), vec![4, 2, 1, 3, 0]);
    }

    #[test]
    fn aging_bounds_low_priority_wait() {
        let base = Instant::now();
        let mut state = QueueState::new();
        // oldest request is low priority; seven high follow
        state.queue.push(req(0, Priority::Low, None, base));
        for id in 1..8 {
            state.queue.push(req(id, Priority::High, None, base));
        }
        // every 4th pop takes the oldest: the low request lands 4th
        let order = ids_in_pop_order(state, 4);
        assert_eq!(order[3], 0, "aged pop must take the oldest: {order:?}");
        // without aging it would be dead last
        let base = Instant::now();
        let mut state = QueueState::new();
        state.queue.push(req(0, Priority::Low, None, base));
        for id in 1..8 {
            state.queue.push(req(id, Priority::High, None, base));
        }
        assert_eq!(*ids_in_pop_order(state, 0).last().unwrap(), 0);
    }

    #[test]
    fn empty_queue_pops_nothing() {
        let mut state = QueueState::new();
        assert!(pop_next(&mut state, 4).is_none());
        assert!(pop_next(&mut state, 0).is_none());
    }

    #[test]
    fn cancel_queued_removes_and_releases_inflight() {
        let base = Instant::now();
        let mut state = QueueState::new();
        state.queue.push(req(0, Priority::Normal, None, base));
        state.queue.push(req(1, Priority::Normal, None, base));
        state.inflight.insert("t".into(), 2);
        let cancelled = cancel_queued(&mut state, 0)
            .expect("id 0 is queued");
        assert_eq!(cancelled.id, 0);
        assert_eq!(state.queue.len(), 1);
        assert_eq!(state.inflight.get("t"), Some(&1));
        // unknown id: no-op
        assert!(cancel_queued(&mut state, 99).is_none());
        assert_eq!(state.queue.len(), 1);
        // last release removes the tenant entry entirely
        cancel_queued(&mut state, 1).expect("id 1 is queued");
        assert!(state.inflight.is_empty());
    }

    #[test]
    fn shed_expired_takes_only_past_deadlines() {
        let base = Instant::now();
        let mut state = QueueState::new();
        state.queue.push(req(0, Priority::Normal, Some(10), base));
        state.queue.push(req(1, Priority::Normal, None, base));
        state.queue.push(req(2, Priority::Low, Some(50), base));
        state.queue.push(req(3, Priority::High, Some(10_000_000), base));
        state.inflight.insert("t".into(), 4);
        let now = base + Duration::from_micros(100);
        let mut shed_ids: Vec<u64> =
            shed_expired(&mut state, now).iter().map(|r| r.id).collect();
        shed_ids.sort_unstable();
        assert_eq!(shed_ids, vec![0, 2], "only the expired two go");
        assert_eq!(state.queue.len(), 2);
        assert_eq!(state.inflight.get("t"), Some(&2));
        // nothing newly expired: a second sweep is a no-op
        assert!(shed_expired(&mut state, now).is_empty());
        // a deadline exactly at `now` is not yet expired (strictly
        // after only)
        let mut state = QueueState::new();
        state.queue.push(req(0, Priority::Normal, Some(100), base));
        assert!(shed_expired(&mut state, now).is_empty());
    }

    /// Regression (issue 9 satellite): a thread that panics while
    /// holding the reply-slot mutex poisons it — fill and wait must
    /// recover and still deliver, never strand the waiter or cascade
    /// the panic.
    #[test]
    fn poisoned_reply_slot_still_delivers() {
        let slot = ReplySlot::new();
        let poisoner = slot.clone();
        let panicked = std::thread::spawn(move || {
            let _guard = poisoner.result.lock();
            panic!("dispatcher died mid-delivery");
        })
        .join();
        assert!(panicked.is_err(), "the poisoner must have panicked");
        // dispatcher side: fill recovers the poisoned lock
        slot.fill(Err(anyhow::anyhow!("request failed")));
        // caller side: wait recovers too and gets the result
        match Ticket::for_model(7, slot).wait() {
            Err(e) => assert_eq!(e.to_string(), "request failed"),
            Ok(_) => panic!("expected the filled error to come through"),
        }
    }
}
