//! The [`Gateway`]: admission at the front, a single dispatcher thread
//! at the back, execution on the process-wide runtime.
//!
//! The dispatcher serializes *scheduling* (which request runs next, in
//! (priority, deadline, arrival) order with aging), not *compute*: each
//! dispatched request fans its schedule's jobs across the full global
//! worker fleet, so the machine stays saturated while the gateway
//! decides only the order. Workers stay owned by
//! [`crate::runtime::global`] — serving a request spawns zero threads.

use std::collections::HashMap;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::analysis::sync::{
    lock_recover, wait_recover, wait_timeout_recover, Condvar, Mutex,
};

use crate::coordinator::{Coordinator, InferenceResult};
use crate::dnn::NetworkSpec;
use crate::power::OperatingPoint;
use crate::runtime::{global, ExecRuntime};

use super::queue::{
    cancel_queued, pop_next, release_inflight, shed_expired,
    CancelOutcome, QueueState, ReplySlot, Request, Ticket,
};
use super::telemetry::GatewayTelemetry;
use super::{
    degraded_lanes, pick_schedule, GatewayConfig, Overload, Priority,
    ServeError,
};

/// State shared between submitters and the dispatcher thread.
///
/// Lock order (when more than one is held): `state` is always taken
/// first and released before `quotas` or the telemetry tenant map —
/// no path holds two of them at once. Reply slots are filled strictly
/// after `state` is released (cancel, shed, and completion all follow
/// store-then-notify outside the queue lock).
///
/// `pub(super)` (fields stay private) so [`Ticket`] can hold a
/// `Weak<Shared>` back-reference for [`Ticket::cancel`].
pub(super) struct Shared {
    coord: Arc<Coordinator>,
    cfg: GatewayConfig,
    state: Mutex<QueueState>,
    work: Condvar,
    telemetry: GatewayTelemetry,
    /// Per-tenant plan-cache byte quotas (absent tenant: unlimited).
    quotas: Mutex<HashMap<String, usize>>,
}

/// The serving gateway — see the [module docs](crate::gateway).
///
/// Construction spawns the one dispatcher thread the gateway ever
/// owns; requests execute on the global runtime. Dropping the gateway
/// shuts it down: admission closes, the queue drains, the dispatcher
/// joins.
pub struct Gateway {
    shared: Arc<Shared>,
    dispatcher: Option<JoinHandle<()>>,
}

impl Gateway {
    /// Spawn a gateway over `coord` with the given admission config.
    pub fn new(
        coord: Arc<Coordinator>,
        cfg: GatewayConfig,
    ) -> Result<Self> {
        let shared = Arc::new(Shared {
            coord,
            cfg,
            state: Mutex::new(QueueState::new()),
            work: Condvar::new(),
            telemetry: GatewayTelemetry::new(),
            quotas: Mutex::new(HashMap::new()),
        });
        let dispatcher = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("marsellus-gateway".into())
                .spawn(move || dispatch_loop(shared))?
        };
        Ok(Self { shared, dispatcher: Some(dispatcher) })
    }

    /// Submit one request: `images` through `spec` at `op`, scheduled
    /// by `priority` and the optional relative `deadline` (falling back
    /// to [`GatewayConfig::default_deadline`]). Returns a [`Ticket`]
    /// when admitted, a typed [`Overload`] when a bound rejects it —
    /// nothing ever queues past [`GatewayConfig::queue_depth`].
    pub fn submit(
        &self,
        tenant: &str,
        spec: &NetworkSpec,
        op: &OperatingPoint,
        images: Vec<Vec<i32>>,
        priority: Priority,
        deadline: Option<Duration>,
    ) -> Result<Ticket, Overload> {
        let telemetry = &self.shared.telemetry;
        telemetry.note_submitted();
        // Chaos site: delay here widens the submit-vs-pop and
        // submit-vs-shutdown windows (outside the lock, so an injected
        // delay stalls only this submitter).
        crate::failpoint!("gateway::submit");
        let mut state = lock_recover(&self.shared.state);
        if state.shutdown {
            drop(state);
            telemetry.note_rejected_shutdown();
            return Err(Overload::ShuttingDown);
        }
        if state.queue.len() >= self.shared.cfg.queue_depth {
            drop(state);
            telemetry.note_rejected_full(tenant);
            return Err(Overload::QueueFull {
                depth: self.shared.cfg.queue_depth,
            });
        }
        let watermark = self.shared.cfg.brownout_watermark;
        if watermark > 0
            && state.queue.len() >= watermark
            && priority == Priority::Low
        {
            let depth = state.queue.len();
            drop(state);
            telemetry.note_rejected_brownout(tenant);
            return Err(Overload::Brownout { depth, watermark });
        }
        let inflight = state.inflight.get(tenant).copied().unwrap_or(0);
        if inflight >= self.shared.cfg.per_tenant_inflight {
            drop(state);
            telemetry.note_rejected_tenant(tenant);
            return Err(Overload::TenantSaturated {
                tenant: tenant.to_string(),
                inflight,
            });
        }
        let id = state.next_id;
        state.next_id += 1;
        *state.inflight.entry(tenant.to_string()).or_insert(0) += 1;
        let now = Instant::now();
        let deadline = deadline
            .or(self.shared.cfg.default_deadline)
            .map(|d| now + d);
        let slot = ReplySlot::new();
        state.queue.push(Request {
            id,
            tenant: tenant.to_string(),
            spec: spec.clone(),
            op: *op,
            images,
            priority,
            submitted: now,
            deadline,
            reply: slot.clone(),
        });
        drop(state);
        telemetry.note_admitted(tenant, spec);
        self.shared.work.notify_all();
        Ok(Ticket {
            id,
            slot,
            shared: Arc::downgrade(&self.shared),
        })
    }

    /// Cap `tenant`'s resident plan-cache bytes: a dispatched request
    /// whose tenant's deployed specs hold more resident plan bytes than
    /// the quota fails loudly (through its ticket) instead of silently
    /// crowding other tenants out of the LRU.
    pub fn set_tenant_quota(&self, tenant: &str, bytes: usize) {
        lock_recover(&self.shared.quotas)
            .insert(tenant.to_string(), bytes);
    }

    /// Deploy `spec` (warming the plan cache) and pin its plan so LRU
    /// eviction may not touch it — the latency-tier residency
    /// guarantee. Fails loudly when pins alone would exceed the cache
    /// budget (`Runtime::pin_plan`).
    pub fn pin(&self, spec: &NetworkSpec) -> Result<()> {
        self.shared.coord.deploy(spec)?;
        self.shared.coord.runtime.pin_plan(spec)
    }

    /// Stop popping requests (admission stays open) — deterministic
    /// backlog for tests and maintenance windows.
    pub fn pause(&self) {
        lock_recover(&self.shared.state).paused = true;
    }

    /// Resume dispatching after [`Self::pause`].
    pub fn resume(&self) {
        lock_recover(&self.shared.state).paused = false;
        self.shared.work.notify_all();
    }

    /// Requests currently waiting in the admission queue.
    pub fn queued(&self) -> usize {
        lock_recover(&self.shared.state).queue.len()
    }

    /// Gateway telemetry: counters + per-tenant latency histograms.
    pub fn telemetry(&self) -> &GatewayTelemetry {
        &self.shared.telemetry
    }

    /// The coordinator this gateway serves over.
    pub fn coordinator(&self) -> &Arc<Coordinator> {
        &self.shared.coord
    }

    /// Close admission, drain the queue (paused or not), and join the
    /// dispatcher. Every admitted ticket still receives its result.
    /// Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        lock_recover(&self.shared.state).shutdown = true;
        self.shared.work.notify_all();
        if let Some(handle) = self.dispatcher.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for Gateway {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Caller-side cancellation (the gateway half of [`Ticket::cancel`]):
/// remove the request from the queue if it is still there, release its
/// inflight slot, count it, and resolve its ticket with a typed
/// [`ServeError::Cancelled`] — all without ever touching a request the
/// dispatcher already popped (that one runs to its natural outcome).
/// The reply slot is filled *after* the queue lock drops.
pub(super) fn cancel_request(
    shared: &Arc<Shared>,
    id: u64,
) -> CancelOutcome {
    let cancelled = {
        let mut state = lock_recover(&shared.state);
        cancel_queued(&mut state, id)
    };
    match cancelled {
        Some(req) => {
            shared.telemetry.note_cancelled(&req.tenant);
            req.reply.fill(Err(ServeError::Cancelled { id }.into()));
            CancelOutcome::Cancelled
        }
        None => CancelOutcome::AlreadyStarted,
    }
}

/// One decision of the dispatcher's inner wait loop, carried out of
/// the queue lock.
enum Work {
    /// Serve this request; `usize` is the queue depth observed at pop
    /// time (the brownout monitor input).
    Serve(Box<Request>, usize),
    /// Resolve these expired requests as shed (deadline reaper).
    Shed(Vec<Request>),
    /// Shutdown flagged and the queue is drained.
    Exit,
}

/// The dispatcher body: wait for work, reap expired deadlines, pop by
/// (priority, deadline, arrival) with aging, serve outside the lock,
/// repeat. Exits when shutdown is flagged and the queue is drained — a
/// paused gateway still drains on shutdown so no ticket waits forever.
///
/// The deadline reaper runs here on both edges: every loop iteration
/// sheds already-expired requests before popping, and while the
/// dispatcher is otherwise idle (paused, or nothing poppable) the wait
/// becomes a timed one ([`GatewayConfig::reap_interval`]) so queued
/// deadlines still expire on time — but only when a deadlined request
/// is actually waiting, so deadline-free workloads never pay a
/// periodic wakeup.
fn dispatch_loop(shared: Arc<Shared>) {
    loop {
        let work = {
            let mut state = lock_recover(&shared.state);
            loop {
                if shared.cfg.shed_expired {
                    let mut expired =
                        shed_expired(&mut state, Instant::now());
                    // Chaos site: force-shed the oldest queued request
                    // as if its deadline had passed.
                    if crate::failpoint_shed!("queue::reap") {
                        let oldest = state
                            .queue
                            .iter()
                            .enumerate()
                            .min_by_key(|(_, r)| r.id)
                            .map(|(i, _)| i);
                        if let Some(i) = oldest {
                            let req = state.queue.swap_remove(i);
                            release_inflight(&mut state, &req.tenant);
                            expired.push(req);
                        }
                    }
                    if !expired.is_empty() {
                        break Work::Shed(expired);
                    }
                }
                let can_pop = !state.queue.is_empty()
                    && (!state.paused || state.shutdown);
                if can_pop {
                    let depth = state.queue.len();
                    let req = pop_next(
                        &mut state,
                        shared.cfg.starvation_bound,
                    )
                    .expect(
                        "invariant: pop_next is Some on the queue just \
                         checked non-empty under this lock",
                    );
                    break Work::Serve(Box::new(req), depth);
                }
                if state.shutdown {
                    break Work::Exit;
                }
                let reap_pending = shared.cfg.shed_expired
                    && state.queue.iter().any(|r| r.deadline.is_some());
                state = if reap_pending {
                    wait_timeout_recover(
                        &shared.work,
                        state,
                        shared.cfg.reap_interval,
                    )
                } else {
                    wait_recover(&shared.work, state)
                };
            }
        };
        match work {
            Work::Exit => return,
            Work::Shed(expired) => {
                let now = Instant::now();
                for req in expired {
                    shared.telemetry.note_shed(&req.tenant);
                    let late_us = req
                        .deadline
                        .map(|d| {
                            now.saturating_duration_since(d).as_micros()
                                as u64
                        })
                        .unwrap_or(0);
                    req.reply.fill(Err(ServeError::DeadlineExceeded {
                        id: req.id,
                        late_us,
                    }
                    .into()));
                }
            }
            Work::Serve(req, depth) => {
                // Chaos site: a delay here (after the pop, before the
                // reply) widens the cancel-after-pop window the
                // interleave suite models.
                crate::failpoint!("dispatch::pop");
                let base = if shared.cfg.threads > 0 {
                    shared.cfg.threads
                } else {
                    global().width()
                };
                let watermark = shared.cfg.brownout_watermark;
                let width = if watermark > 0 && depth >= watermark {
                    shared.telemetry.note_degraded();
                    degraded_lanes(base, shared.cfg.brownout_lanes)
                } else {
                    base
                };
                serve(&shared, *req, width);
            }
        }
    }
}

/// Serve one popped request on `width` lanes and deliver its result
/// through the reply slot. Panics inside inference are caught and
/// delivered as typed [`ServeError::Panicked`] errors — a poisoned
/// request must never hang its waiter or kill the dispatcher, and it
/// still records its end-to-end latency and deadline telemetry and
/// releases its inflight slot like every other terminal transition.
fn serve(shared: &Shared, req: Request, width: usize) {
    let queued = req.submitted.elapsed();
    let t0 = Instant::now();
    let outcome = std::panic::catch_unwind(
        std::panic::AssertUnwindSafe(|| run_request(shared, &req, width)),
    );
    let service = t0.elapsed();
    {
        let mut state = lock_recover(&shared.state);
        release_inflight(&mut state, &req.tenant);
    }
    let deadline_missed =
        req.deadline.is_some_and(|d| Instant::now() > d);
    let latency_us = (queued + service).as_micros() as u64;
    let result = match outcome {
        Ok(Ok(results)) => {
            let finish_seq = shared.telemetry.note_completed(
                &req.tenant,
                latency_us,
                deadline_missed,
            );
            Ok(super::Completed {
                results,
                queued,
                service,
                deadline_missed,
                finish_seq,
            })
        }
        Ok(Err(e)) => {
            shared.telemetry.note_failed();
            Err(e)
        }
        Err(panic) => {
            shared.telemetry.note_panicked(
                &req.tenant,
                latency_us,
                deadline_missed,
            );
            let msg = panic
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| panic.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".into());
            Err(ServeError::Panicked {
                id: req.id,
                msg: format!(
                    "{msg} (serving {} for tenant {:?})",
                    req.spec, req.tenant
                ),
            }
            .into())
        }
    };
    req.reply.fill(result);
}

/// Deploy (plan-cache hit after the first request per spec), enforce
/// the tenant's byte quota, pick the schedule shape from the request
/// size, and run on the global runtime.
///
/// Deploying per request — rather than caching `Deployment` handles in
/// the dispatcher — is deliberate: a cached handle would hold the
/// plan's `Arc` alive past LRU eviction and quietly void the byte
/// bound that quotas and pins enforce. A cache hit costs one map
/// lookup.
fn run_request(
    shared: &Shared,
    req: &Request,
    width: usize,
) -> Result<Vec<InferenceResult>> {
    // Chaos site: the one place an injected panic is caught by the
    // dispatcher's catch_unwind, exercising the panicked-request
    // lifecycle end to end.
    crate::failpoint!("dispatch::serve");
    let deployment = shared.coord.deploy(&req.spec)?;
    if let Some(&quota) =
        lock_recover(&shared.quotas).get(&req.tenant)
    {
        let runtime = &shared.coord.runtime;
        let resident: usize = shared
            .telemetry
            .tenant_specs(&req.tenant)
            .iter()
            .filter_map(|s| runtime.plan_bytes_of(s))
            .sum();
        if resident > quota {
            bail!(
                "tenant {:?} over plan-cache quota: {resident} resident \
                 plan bytes > {quota} allowed (request {} for {}); \
                 raise the quota or retire deployments",
                req.tenant,
                req.id,
                req.spec
            );
        }
    }
    let sched = pick_schedule(req.images.len(), width);
    deployment.infer_scheduled_on(
        &req.op,
        &req.images,
        sched,
        ExecRuntime::Global,
    )
}
