//! Multi-tenant serving gateway: admission control, per-tenant quotas
//! and deadline/priority-aware scheduling over the deployment API.
//!
//! The compute half of the reproduction (plans, tuner, the process-wide
//! work-stealing runtime) serves a batch as a blocking method call per
//! caller; production traffic is many tenants submitting concurrent
//! requests of mixed size. The gateway is the request front-end over
//! that machinery — the orchestrator/telemetry split of heterogeneous
//! serving clusters, kept **out** of the coordinator:
//!
//! * **Admission** ([`Gateway::submit`]) — a bounded queue
//!   ([`GatewayConfig::queue_depth`]) with a per-tenant inflight cap
//!   ([`GatewayConfig::per_tenant_inflight`]). A full queue or a
//!   saturated tenant is rejected *at submit time* with a typed
//!   [`Overload`] error instead of queueing unboundedly — backpressure,
//!   not OOM. Admitted requests return a [`Ticket`] whose blocking
//!   [`Ticket::wait`] delivers the result (no async runtime needed).
//! * **Scheduling** — a single dispatcher thread pops the queue by
//!   ([`Priority`], deadline, arrival) and picks the [`Schedule`] shape
//!   per request ([`pick_schedule`]): small interactive requests run in
//!   latency mode (conv tiles within the image), bulk requests as image
//!   shards, the in-between as the hybrid — the same
//!   `Deployment::infer_scheduled` machinery direct callers use.
//!   Strict priority ordering is aged ([`GatewayConfig::starvation_bound`]):
//!   every Nth pop takes the globally oldest request regardless of
//!   priority, so low-priority starvation is bounded, not merely
//!   unlikely.
//! * **Execution** — requests run on the process-wide work-stealing
//!   runtime ([`crate::runtime::global`]). The gateway only *schedules*;
//!   it owns no workers and a served request spawns **zero** threads.
//! * **Quotas** ([`Gateway::set_tenant_quota`]) — per-tenant plan-cache
//!   byte budgets enforced at dispatch, plus plan pinning
//!   ([`Gateway::pin`] / `Runtime::pin_plan`) so a hot tenant's plan is
//!   never LRU-evicted mid-request.
//! * **Telemetry** ([`telemetry::GatewayTelemetry`]) —
//!   queued/admitted/rejected/deadline-missed counters and per-tenant
//!   latency histograms (p50/p99), its own module rather than state
//!   woven through the coordinator.
//! * **Lifecycle** — every admitted request moves `queued → {running,
//!   cancelled, shed}` and a running request ends `{completed,
//!   panicked}`; each terminal state is a distinct typed outcome
//!   through the ticket ([`Completed`], [`ServeError`]) and a distinct
//!   telemetry counter, so `submitted == admitted + rejected` and
//!   `admitted == completed + failed + cancelled + shed + panicked`
//!   reconcile exactly
//!   ([`telemetry::GatewaySnapshot::reconciles`]). Callers cancel
//!   queued work ([`Ticket::cancel`]); the dispatcher sheds requests
//!   whose deadline already passed ([`GatewayConfig::shed_expired`])
//!   instead of serving results nobody reads; past a queue-depth
//!   high-watermark the gateway browns out — low-priority submits get
//!   typed early rejections and admitted requests run on fewer lanes
//!   ([`GatewayConfig::brownout_watermark`]) — degrading gracefully
//!   the way the SoC's on-chip monitors adapt body bias under stress
//!   rather than failing at the operating limit.
//!
//! Direct `Deployment` calls remain fully supported — the gateway is a
//! front-end over the same bitwise-deterministic serving path, and its
//! outputs are asserted bitwise equal to direct `infer_scheduled` calls
//! in tests and benches.

mod dispatch;
mod queue;
pub mod telemetry;

use std::time::Duration;

use crate::coordinator::Schedule;

pub use dispatch::Gateway;
pub use queue::{CancelOutcome, Completed, Ticket};

/// Feature-gated re-exports of the queue internals so
/// `tests/interleave.rs` can drive the *real* admission/rendezvous
/// protocols (not copies of them) under the deterministic interleaving
/// explorer (`analysis::explore`).
#[cfg(any(test, feature = "interleave"))]
pub mod model {
    pub use super::queue::{
        cancel_queued, pop_next, release_inflight, shed_expired,
        QueueState, ReplySlot, Request,
    };
}

/// Admission/scheduling knobs for a [`Gateway`].
#[derive(Debug, Clone)]
pub struct GatewayConfig {
    /// Maximum requests waiting in the admission queue; a submit beyond
    /// this is rejected with [`Overload::QueueFull`].
    pub queue_depth: usize,
    /// Maximum admitted-but-not-completed requests per tenant; a submit
    /// beyond this is rejected with [`Overload::TenantSaturated`].
    pub per_tenant_inflight: usize,
    /// Deadline applied to requests submitted without one (`None`:
    /// no default — such requests sort after all deadlined ones).
    pub default_deadline: Option<Duration>,
    /// Worker lanes each dispatched request occupies on the global
    /// runtime; `0` means the full fleet width.
    pub threads: usize,
    /// Anti-starvation aging: every Nth pop takes the globally oldest
    /// request regardless of priority (`0`: strict priority order, no
    /// aging).
    pub starvation_bound: usize,
    /// Shed queued requests whose deadline already passed (typed
    /// [`ServeError::DeadlineExceeded`] through the ticket) instead of
    /// serving a result nobody reads. `false` restores the serve-anyway
    /// behavior: a missed deadline is counted and flagged on the
    /// [`Completed`], never dropped.
    pub shed_expired: bool,
    /// How often the dispatcher sweeps an *idle* queue for expired
    /// deadlines when [`Self::shed_expired`] is on (shedding at pop
    /// time happens regardless of this interval). Only paid while
    /// deadlined requests are actually waiting.
    pub reap_interval: Duration,
    /// Brownout high-watermark on queue depth: at or beyond this many
    /// queued requests, [`Priority::Low`] submits are rejected with
    /// [`Overload::Brownout`] and admitted requests run degraded
    /// ([`Self::brownout_lanes`]). `0` disables brownout.
    pub brownout_watermark: usize,
    /// Worker lanes a request dispatched during brownout occupies;
    /// `0` means half the configured width (minimum 1).
    pub brownout_lanes: usize,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        Self {
            queue_depth: 64,
            per_tenant_inflight: 16,
            default_deadline: None,
            threads: 0,
            starvation_bound: 4,
            shed_expired: true,
            reap_interval: Duration::from_millis(2),
            brownout_watermark: 0,
            brownout_lanes: 0,
        }
    }
}

/// Typed admission rejection: the caller chose backpressure over
/// unbounded queueing, and the variant says which bound fired.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Overload {
    /// The bounded admission queue is at [`GatewayConfig::queue_depth`].
    QueueFull {
        /// The configured depth the queue is at.
        depth: usize,
    },
    /// The tenant is at [`GatewayConfig::per_tenant_inflight`] admitted
    /// requests.
    TenantSaturated {
        /// The saturated tenant.
        tenant: String,
        /// Its admitted-but-not-completed request count.
        inflight: usize,
    },
    /// The gateway is shutting down and admits nothing new.
    ShuttingDown,
    /// The queue is at or past [`GatewayConfig::brownout_watermark`]
    /// and this submission is [`Priority::Low`]: under brownout, bulk
    /// traffic is rejected early so interactive traffic keeps its
    /// latency.
    Brownout {
        /// Queue depth at rejection time.
        depth: usize,
        /// The configured high-watermark that fired.
        watermark: usize,
    },
}

impl std::fmt::Display for Overload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Overload::QueueFull { depth } => write!(
                f,
                "admission queue full ({depth} queued); retry with \
                 backoff or raise queue_depth"
            ),
            Overload::TenantSaturated { tenant, inflight } => write!(
                f,
                "tenant {tenant:?} saturated ({inflight} inflight); \
                 wait for completions or raise per_tenant_inflight"
            ),
            Overload::ShuttingDown => {
                write!(f, "gateway is shutting down")
            }
            Overload::Brownout { depth, watermark } => write!(
                f,
                "gateway in brownout ({depth} queued >= watermark \
                 {watermark}): low-priority traffic rejected until the \
                 backlog drains"
            ),
        }
    }
}

impl std::error::Error for Overload {}

/// Typed terminal outcome of an admitted request that did *not*
/// complete: delivered through [`Ticket::wait`] as a downcastable
/// `anyhow` error, so callers can branch on the lifecycle state
/// (`err.downcast_ref::<ServeError>()`) instead of parsing messages.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The caller cancelled the request while it was still queued
    /// ([`Ticket::cancel`]).
    Cancelled {
        /// Admission id of the cancelled request.
        id: u64,
    },
    /// The queue-side reaper shed the request because its deadline
    /// passed before execution started
    /// ([`GatewayConfig::shed_expired`]).
    DeadlineExceeded {
        /// Admission id of the shed request.
        id: u64,
        /// How far past the deadline the request was when shed (µs).
        late_us: u64,
    },
    /// Inference panicked mid-request; the dispatcher caught the
    /// unwind, recorded latency + deadline telemetry, released the
    /// inflight slot, and delivered this instead of stranding the
    /// waiter.
    Panicked {
        /// Admission id of the panicked request.
        id: u64,
        /// The panic payload (or a placeholder for non-string
        /// payloads).
        msg: String,
    },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Cancelled { id } => {
                write!(f, "request {id} cancelled by caller while queued")
            }
            ServeError::DeadlineExceeded { id, late_us } => write!(
                f,
                "request {id} shed: deadline exceeded by {late_us}us \
                 before execution started (set shed_expired=false to \
                 serve expired requests anyway)"
            ),
            ServeError::Panicked { id, msg } => {
                write!(f, "request {id}: inference panicked: {msg}")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// Dispatch priority of a request. Lower rank pops first; ties break by
/// deadline (requests without one sort last), then arrival order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Priority {
    /// Interactive traffic: pops before everything else.
    High,
    /// The default.
    Normal,
    /// Bulk/background traffic: pops last (aging still bounds its wait
    /// — see [`GatewayConfig::starvation_bound`]).
    Low,
}

impl Priority {
    pub(crate) fn rank(self) -> u8 {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }
}

impl std::str::FromStr for Priority {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s {
            "high" => Ok(Priority::High),
            "normal" => Ok(Priority::Normal),
            "low" => Ok(Priority::Low),
            other => anyhow::bail!(
                "unknown priority {other:?} (known: high, normal, low)"
            ),
        }
    }
}

/// The per-request schedule pick: a single image is pure latency mode
/// (conv tiles within the image), a batch smaller than the lane width
/// runs the hybrid (shards + tiled remainder), and a full-width-or-more
/// batch runs as whole-image shards — mirroring where each mode wins in
/// the bench matrix.
pub fn pick_schedule(images: usize, width: usize) -> Schedule {
    let w = width.max(1);
    if images <= 1 {
        Schedule::latency(w)
    } else if images < w {
        Schedule::hybrid(w)
    } else {
        Schedule::batch(w)
    }
}

/// Lane width for a request dispatched during brownout: the configured
/// [`GatewayConfig::brownout_lanes`] when set, else half the base
/// width — never zero, never wider than the base. Schedules stay
/// bitwise-deterministic at any width, so degrading only trades
/// latency for fleet headroom.
pub(crate) fn degraded_lanes(base: usize, brownout_lanes: usize) -> usize {
    let base = base.max(1);
    if brownout_lanes > 0 {
        brownout_lanes.min(base)
    } else {
        (base / 2).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ScheduleMode;

    #[test]
    fn schedule_pick_matches_request_shape() {
        assert_eq!(pick_schedule(1, 8).mode, ScheduleMode::Latency);
        assert_eq!(pick_schedule(0, 8).mode, ScheduleMode::Latency);
        assert_eq!(pick_schedule(3, 8).mode, ScheduleMode::Hybrid);
        assert_eq!(pick_schedule(8, 8).mode, ScheduleMode::Batch);
        assert_eq!(pick_schedule(17, 8).mode, ScheduleMode::Batch);
        // degenerate width still produces a sane schedule
        assert_eq!(pick_schedule(4, 0).threads, 1);
    }

    #[test]
    fn priority_parses_and_ranks() {
        assert_eq!("high".parse::<Priority>().unwrap(), Priority::High);
        assert_eq!("low".parse::<Priority>().unwrap(), Priority::Low);
        assert!("urgent".parse::<Priority>().is_err());
        assert!(Priority::High.rank() < Priority::Normal.rank());
        assert!(Priority::Normal.rank() < Priority::Low.rank());
    }

    #[test]
    fn overload_displays_the_bound_that_fired() {
        let e = Overload::QueueFull { depth: 4 };
        assert!(e.to_string().contains("4 queued"));
        let e = Overload::TenantSaturated {
            tenant: "acme".into(),
            inflight: 2,
        };
        assert!(e.to_string().contains("acme"));
        assert!(e.to_string().contains("2 inflight"));
        let e = Overload::Brownout { depth: 9, watermark: 8 };
        assert!(e.to_string().contains("9 queued"));
        assert!(e.to_string().contains("watermark"));
    }

    #[test]
    fn serve_errors_name_the_request_and_state() {
        let e = ServeError::Cancelled { id: 3 };
        assert!(e.to_string().contains("request 3"));
        assert!(e.to_string().contains("cancelled"));
        let e = ServeError::DeadlineExceeded { id: 4, late_us: 120 };
        assert!(e.to_string().contains("120us"));
        assert!(e.to_string().contains("shed"));
        let e = ServeError::Panicked { id: 5, msg: "boom".into() };
        assert!(e.to_string().contains("boom"));
        // delivered as anyhow errors; the typed variant must survive
        // the round-trip so callers can branch on it
        let any: anyhow::Error = ServeError::Cancelled { id: 7 }.into();
        assert_eq!(
            any.downcast_ref::<ServeError>(),
            Some(&ServeError::Cancelled { id: 7 })
        );
    }

    #[test]
    fn degraded_lanes_halves_or_clamps() {
        assert_eq!(degraded_lanes(8, 0), 4);
        assert_eq!(degraded_lanes(1, 0), 1);
        assert_eq!(degraded_lanes(8, 2), 2);
        // explicit lanes never exceed the base width
        assert_eq!(degraded_lanes(2, 6), 2);
        assert_eq!(degraded_lanes(0, 0), 1);
    }
}
