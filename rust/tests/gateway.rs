//! Serving-gateway integration tests: bitwise parity with the direct
//! deployment path, zero threads spawned per served request, bounded
//! admission (queue depth + per-tenant inflight), bounded low-priority
//! starvation, deadline accounting, plan-cache quotas, drain-on-
//! shutdown semantics (ISSUE 8), and the request lifecycle —
//! cancellation, deadline shedding, brownout, counter reconciliation
//! (ISSUE 10).

#![cfg(feature = "native")]

use std::sync::Arc;
use std::time::Duration;

use marsellus::coordinator::Coordinator;
use marsellus::dnn::{NetworkSpec, PrecisionConfig};
use marsellus::gateway::{
    pick_schedule, CancelOutcome, Gateway, GatewayConfig, Overload,
    Priority, ServeError,
};
use marsellus::power::OperatingPoint;
use marsellus::runtime::{global, ExecRuntime, Runtime};
use marsellus::util::Rng;

fn coordinator() -> Arc<Coordinator> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts");
    let rt = Runtime::native(&dir).expect("native runtime");
    Arc::new(Coordinator::with_runtime(rt).expect("coordinator"))
}

fn kws(seed: u64) -> NetworkSpec {
    NetworkSpec::new("kws", PrecisionConfig::Mixed, seed)
}

fn op() -> OperatingPoint {
    OperatingPoint::at_vdd(0.8)
}

fn config(queue_depth: usize, inflight: usize) -> GatewayConfig {
    GatewayConfig {
        queue_depth,
        per_tenant_inflight: inflight,
        threads: 2,
        ..GatewayConfig::default()
    }
}

/// Mixed-size 2-tenant load through the gateway: logits bitwise equal
/// to direct `infer_scheduled_on` calls, and the process-wide fleet
/// spawns zero additional threads while serving.
#[test]
fn gateway_matches_direct_path_and_spawns_nothing() {
    let coord = coordinator();
    let spec = kws(1);
    let d = coord.deploy(&spec).unwrap();
    let mut rng = Rng::new(50);
    // request sizes exercising all three schedule picks
    let sizes = [1usize, 3, 4, 1, 2];
    let batches: Vec<Vec<Vec<i32>>> = sizes
        .iter()
        .map(|&n| (0..n).map(|_| d.random_input(&mut rng)).collect())
        .collect();

    // direct path (also warms the global fleet so the spawn counter
    // below measures serving, not first-touch provisioning)
    let width = global().width();
    let direct: Vec<Vec<Vec<i32>>> = batches
        .iter()
        .map(|imgs| {
            d.infer_scheduled_on(
                &op(),
                imgs,
                pick_schedule(imgs.len(), width),
                ExecRuntime::Global,
            )
            .unwrap()
            .into_iter()
            .map(|r| r.logits)
            .collect()
        })
        .collect();
    let spawned_before = global().telemetry().spawned_threads;

    let gateway = Gateway::new(coord.clone(), GatewayConfig {
        threads: 0,
        ..config(16, 16)
    })
    .unwrap();
    let tickets: Vec<_> = batches
        .iter()
        .enumerate()
        .map(|(i, imgs)| {
            let tenant = if i % 2 == 0 { "a" } else { "b" };
            gateway
                .submit(
                    tenant,
                    &spec,
                    &op(),
                    imgs.clone(),
                    Priority::Normal,
                    None,
                )
                .expect("admission")
        })
        .collect();
    let served: Vec<Vec<Vec<i32>>> = tickets
        .into_iter()
        .map(|t| {
            t.wait()
                .unwrap()
                .results
                .into_iter()
                .map(|r| r.logits)
                .collect()
        })
        .collect();

    assert_eq!(direct, served, "gateway diverged from the direct path");
    assert_eq!(
        global().telemetry().spawned_threads,
        spawned_before,
        "serving through the gateway must spawn zero worker threads"
    );
}

/// A full admission queue rejects with a typed `QueueFull` instead of
/// queueing unboundedly; the backlog still completes.
#[test]
fn full_queue_rejects_instead_of_queueing_unboundedly() {
    let coord = coordinator();
    let spec = kws(2);
    let d = coord.deploy(&spec).unwrap();
    let mut rng = Rng::new(51);
    let img = d.random_input(&mut rng);

    let gateway = Gateway::new(coord.clone(), config(2, 16)).unwrap();
    gateway.pause();
    let t1 = gateway
        .submit("a", &spec, &op(), vec![img.clone()], Priority::Normal, None)
        .expect("first fits");
    let t2 = gateway
        .submit("a", &spec, &op(), vec![img.clone()], Priority::Normal, None)
        .expect("second fits");
    let err = gateway
        .submit("a", &spec, &op(), vec![img.clone()], Priority::Normal, None)
        .expect_err("third must be rejected");
    assert_eq!(err, Overload::QueueFull { depth: 2 });
    assert_eq!(gateway.queued(), 2);

    gateway.resume();
    assert_eq!(t1.wait().unwrap().results.len(), 1);
    assert_eq!(t2.wait().unwrap().results.len(), 1);
    let snap = gateway.telemetry().snapshot();
    assert_eq!(snap.submitted, 3);
    assert_eq!(snap.admitted, 2);
    assert_eq!(snap.rejected_full, 1);
    assert_eq!(snap.completed, 2);
}

/// The per-tenant inflight cap rejects the saturating tenant only;
/// other tenants keep being admitted.
#[test]
fn saturated_tenant_is_rejected_others_admitted() {
    let coord = coordinator();
    let spec = kws(3);
    let d = coord.deploy(&spec).unwrap();
    let mut rng = Rng::new(52);
    let img = d.random_input(&mut rng);

    let gateway = Gateway::new(coord.clone(), config(16, 1)).unwrap();
    gateway.pause();
    let t1 = gateway
        .submit("hog", &spec, &op(), vec![img.clone()], Priority::Normal, None)
        .expect("first fits");
    let err = gateway
        .submit("hog", &spec, &op(), vec![img.clone()], Priority::Normal, None)
        .expect_err("tenant is saturated");
    assert_eq!(
        err,
        Overload::TenantSaturated { tenant: "hog".into(), inflight: 1 }
    );
    let t2 = gateway
        .submit("other", &spec, &op(), vec![img.clone()], Priority::Normal, None)
        .expect("other tenants unaffected");

    gateway.resume();
    t1.wait().unwrap();
    t2.wait().unwrap();
    let snap = gateway.telemetry().snapshot();
    assert_eq!(snap.rejected_tenant, 1);
    assert_eq!(snap.completed, 2);
    // inflight released on completion: the tenant admits again
    gateway
        .submit("hog", &spec, &op(), vec![img], Priority::Normal, None)
        .expect("capacity released after completion")
        .wait()
        .unwrap();
}

/// Sustained 2-tenant load: every admitted request completes, counters
/// and per-tenant telemetry add up, and the per-tenant split is
/// reported (p50 <= p99).
#[test]
fn two_tenant_sustained_load_completes_with_telemetry() {
    let coord = coordinator();
    let spec_a = kws(4);
    let spec_b = kws(5);
    let da = coord.deploy(&spec_a).unwrap();
    let db = coord.deploy(&spec_b).unwrap();
    let mut rng = Rng::new(53);

    let gateway = Gateway::new(coord.clone(), config(64, 32)).unwrap();
    let mut tickets = Vec::new();
    for round in 0..6 {
        let a_imgs: Vec<Vec<i32>> =
            (0..1).map(|_| da.random_input(&mut rng)).collect();
        let b_imgs: Vec<Vec<i32>> =
            (0..3).map(|_| db.random_input(&mut rng)).collect();
        tickets.push(
            gateway
                .submit(
                    "alpha",
                    &spec_a,
                    &op(),
                    a_imgs,
                    Priority::High,
                    Some(Duration::from_secs(60)),
                )
                .unwrap_or_else(|e| panic!("round {round}: {e}")),
        );
        tickets.push(
            gateway
                .submit("beta", &spec_b, &op(), b_imgs, Priority::Low, None)
                .unwrap_or_else(|e| panic!("round {round}: {e}")),
        );
    }
    let mut images = 0;
    for t in tickets {
        images += t.wait().expect("admitted requests complete").results.len();
    }
    assert_eq!(images, 6 * (1 + 3));

    let snap = gateway.telemetry().snapshot();
    assert_eq!(snap.submitted, 12);
    assert_eq!(snap.admitted, 12);
    assert_eq!(snap.completed, 12);
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.rejected(), 0);
    assert_eq!(snap.tenants.len(), 2);
    for t in &snap.tenants {
        assert_eq!(t.admitted, 6, "{}", t.tenant);
        assert_eq!(t.completed, 6, "{}", t.tenant);
        assert!(t.p50_us <= t.p99_us, "{}: p50 > p99", t.tenant);
        assert!(t.p99_us > 0, "{}: latency not recorded", t.tenant);
    }
}

/// Aging bounds low-priority starvation deterministically: with
/// starvation_bound 4 the oldest (low) request is the 4th completion
/// even under a high-priority backlog; with 0 (strict priority) it is
/// dead last.
#[test]
fn starvation_bound_caps_low_priority_wait() {
    for (bound, expected_seq) in [(4usize, 4u64), (0, 8)] {
        let coord = coordinator();
        let spec = kws(6);
        let d = coord.deploy(&spec).unwrap();
        let mut rng = Rng::new(54);
        let img = d.random_input(&mut rng);

        let gateway = Gateway::new(coord.clone(), GatewayConfig {
            starvation_bound: bound,
            ..config(16, 16)
        })
        .unwrap();
        gateway.pause();
        let low = gateway
            .submit("bulk", &spec, &op(), vec![img.clone()], Priority::Low, None)
            .expect("low admitted");
        let highs: Vec<_> = (0..7)
            .map(|_| {
                gateway
                    .submit(
                        "hot",
                        &spec,
                        &op(),
                        vec![img.clone()],
                        Priority::High,
                        None,
                    )
                    .expect("high admitted")
            })
            .collect();
        gateway.resume();
        let done = low.wait().unwrap();
        assert_eq!(
            done.finish_seq, expected_seq,
            "bound {bound}: low-priority request finished at the wrong \
             position"
        );
        for t in highs {
            t.wait().unwrap();
        }
    }
}

/// With `shed_expired: false` (the serve-anyway knob) a missed
/// deadline is counted and flagged on the result — never dropped.
#[test]
fn missed_deadlines_are_counted_not_dropped() {
    let coord = coordinator();
    let spec = kws(7);
    let d = coord.deploy(&spec).unwrap();
    let mut rng = Rng::new(55);
    let img = d.random_input(&mut rng);

    let gateway = Gateway::new(coord.clone(), GatewayConfig {
        shed_expired: false,
        ..config(16, 16)
    })
    .unwrap();
    let done = gateway
        .submit(
            "t",
            &spec,
            &op(),
            vec![img],
            Priority::High,
            Some(Duration::from_nanos(1)),
        )
        .expect("admitted")
        .wait()
        .expect("still served");
    assert!(done.deadline_missed, "1ns deadline cannot be met");
    assert_eq!(done.results.len(), 1, "missed != dropped");
    let snap = gateway.telemetry().snapshot();
    assert_eq!(snap.deadline_missed, 1);
    assert_eq!(snap.completed, 1);
}

/// A tenant over its plan-cache byte quota fails loudly through its
/// ticket — a typed error naming the quota, not a silent eviction
/// of other tenants.
#[test]
fn over_quota_tenant_fails_loudly() {
    let coord = coordinator();
    let spec = kws(8);
    let d = coord.deploy(&spec).unwrap();
    let mut rng = Rng::new(56);
    let img = d.random_input(&mut rng);

    let gateway = Gateway::new(coord.clone(), config(16, 16)).unwrap();
    gateway.set_tenant_quota("cheap", 1);
    let err = gateway
        .submit("cheap", &spec, &op(), vec![img.clone()], Priority::Normal, None)
        .expect("admission is not where quotas bite")
        .wait()
        .expect_err("1-byte quota cannot hold a plan");
    let msg = format!("{err:#}");
    assert!(msg.contains("over plan-cache quota"), "got: {msg}");
    assert_eq!(gateway.telemetry().snapshot().failed, 1);

    // an unquota'd tenant serving the same spec is unaffected
    gateway
        .submit("rich", &spec, &op(), vec![img], Priority::Normal, None)
        .expect("admitted")
        .wait()
        .expect("no quota, no failure");
}

/// Shutdown drains the backlog (every admitted ticket gets its result)
/// and then rejects new submissions with `ShuttingDown`.
#[test]
fn shutdown_drains_backlog_then_rejects() {
    let coord = coordinator();
    let spec = kws(9);
    let d = coord.deploy(&spec).unwrap();
    let mut rng = Rng::new(57);
    let img = d.random_input(&mut rng);

    let mut gateway = Gateway::new(coord.clone(), config(16, 16)).unwrap();
    gateway.pause();
    let tickets: Vec<_> = (0..3)
        .map(|_| {
            gateway
                .submit(
                    "t",
                    &spec,
                    &op(),
                    vec![img.clone()],
                    Priority::Normal,
                    None,
                )
                .expect("admitted")
        })
        .collect();
    // shutdown must drain even a paused gateway: no ticket waits forever
    gateway.shutdown();
    for t in tickets {
        assert_eq!(t.wait().expect("drained on shutdown").results.len(), 1);
    }
    let err = gateway
        .submit("t", &spec, &op(), vec![img], Priority::Normal, None)
        .expect_err("admission is closed");
    assert_eq!(err, Overload::ShuttingDown);
    let snap = gateway.telemetry().snapshot();
    assert_eq!(snap.completed, 3);
    assert_eq!(snap.rejected_shutdown, 1);
}

/// With `shed_expired: true` (the default) an expired request is shed
/// by the queue-side reaper with a typed `DeadlineExceeded` — even on
/// a paused gateway, proving the periodic idle sweep fires without a
/// pop driving it.
#[test]
fn expired_deadline_is_shed_with_typed_error() {
    let coord = coordinator();
    let spec = kws(10);
    let d = coord.deploy(&spec).unwrap();
    let mut rng = Rng::new(58);
    let img = d.random_input(&mut rng);

    let gateway = Gateway::new(coord.clone(), config(16, 16)).unwrap();
    gateway.pause();
    let ticket = gateway
        .submit(
            "t",
            &spec,
            &op(),
            vec![img],
            Priority::High,
            Some(Duration::from_nanos(1)),
        )
        .expect("admitted");
    // never resumed: only the idle sweep can resolve this ticket
    let err = ticket.wait().expect_err("expired before start must shed");
    match err.downcast_ref::<ServeError>() {
        Some(ServeError::DeadlineExceeded { id: _, late_us: _ }) => {}
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    let snap = gateway.telemetry().snapshot();
    assert_eq!(snap.shed, 1);
    assert_eq!(snap.completed, 0);
    assert_eq!(snap.admitted, 1);
    assert!(snap.reconciles(), "counters must reconcile: {snap:?}");
    assert_eq!(gateway.queued(), 0, "shed request left the queue");
}

/// `Ticket::cancel` on a still-queued request removes it: the caller
/// gets `CancelOutcome::Cancelled`, `wait` resolves to a typed
/// `ServeError::Cancelled`, the tenant's inflight slot is released
/// (a follow-up submit under a cap of 1 is admitted), and a second
/// cancel is acknowledged-but-ignored.
#[test]
fn cancel_removes_queued_request_and_releases_inflight() {
    let coord = coordinator();
    let spec = kws(11);
    let d = coord.deploy(&spec).unwrap();
    let mut rng = Rng::new(59);
    let img = d.random_input(&mut rng);

    let gateway = Gateway::new(coord.clone(), config(16, 1)).unwrap();
    gateway.pause();
    let victim = gateway
        .submit("t", &spec, &op(), vec![img.clone()], Priority::Normal, None)
        .expect("admitted");
    assert_eq!(victim.cancel(), CancelOutcome::Cancelled);
    assert_eq!(
        victim.cancel(),
        CancelOutcome::AlreadyStarted,
        "second cancel finds nothing queued and is ignored"
    );
    // inflight released while still paused: with per_tenant_inflight 1
    // the same tenant admits again only if the cancel freed its slot
    let survivor = gateway
        .submit("t", &spec, &op(), vec![img], Priority::Normal, None)
        .expect("cancel must release the tenant's inflight slot");
    gateway.resume();

    let err = victim.wait().expect_err("cancelled tickets resolve to Err");
    match err.downcast_ref::<ServeError>() {
        Some(ServeError::Cancelled { id: _ }) => {}
        other => panic!("expected Cancelled, got {other:?}"),
    }
    assert_eq!(survivor.wait().unwrap().results.len(), 1);

    let snap = gateway.telemetry().snapshot();
    assert_eq!(snap.cancelled, 1);
    assert_eq!(snap.completed, 1);
    assert!(snap.reconciles(), "counters must reconcile: {snap:?}");
    let row = snap.tenants.iter().find(|t| t.tenant == "t").unwrap();
    assert_eq!(row.cancelled, 1);
}

/// Cancelling after the dispatcher already popped the request is
/// acknowledged-but-ignored: the caller still gets the completed
/// result.
#[test]
fn cancel_after_start_is_ignored() {
    let coord = coordinator();
    let spec = kws(12);
    let d = coord.deploy(&spec).unwrap();
    let mut rng = Rng::new(60);
    let img = d.random_input(&mut rng);

    let gateway = Gateway::new(coord.clone(), config(16, 16)).unwrap();
    let ticket = gateway
        .submit("t", &spec, &op(), vec![img], Priority::Normal, None)
        .expect("admitted");
    // wait for the request to finish, then cancel: it is long gone from
    // the queue, so the cancel must be a no-op acknowledgement
    while gateway.telemetry().snapshot().completed == 0 {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(ticket.cancel(), CancelOutcome::AlreadyStarted);
    assert_eq!(ticket.wait().expect("result survives the cancel").results.len(), 1);
    let snap = gateway.telemetry().snapshot();
    assert_eq!(snap.cancelled, 0);
    assert_eq!(snap.completed, 1);
}

/// Brownout: past the queue-depth watermark, low-priority submissions
/// are rejected with a typed `Overload::Brownout` while high-priority
/// requests are admitted, served on a degraded (narrower) schedule,
/// and still produce logits bitwise equal to the direct path.
#[test]
fn brownout_rejects_low_and_degrades_admitted_bitwise_equal() {
    let coord = coordinator();
    let spec = kws(13);
    let d = coord.deploy(&spec).unwrap();
    let mut rng = Rng::new(61);
    let imgs: Vec<Vec<i32>> = (0..3).map(|_| d.random_input(&mut rng)).collect();

    // direct path at full width: degraded serving must not change bits
    let width = global().width();
    let direct: Vec<Vec<i32>> = d
        .infer_scheduled_on(
            &op(),
            &imgs,
            pick_schedule(imgs.len(), width),
            ExecRuntime::Global,
        )
        .unwrap()
        .into_iter()
        .map(|r| r.logits)
        .collect();

    let gateway = Gateway::new(coord.clone(), GatewayConfig {
        brownout_watermark: 1,
        brownout_lanes: 1,
        ..config(16, 16)
    })
    .unwrap();
    gateway.pause();
    let high = gateway
        .submit("hot", &spec, &op(), imgs.clone(), Priority::High, None)
        .expect("high admitted below watermark");
    // depth is now 1 >= watermark 1: low is browned out, high is not
    let err = gateway
        .submit("bulk", &spec, &op(), imgs.clone(), Priority::Low, None)
        .expect_err("low-priority must be browned out");
    assert_eq!(err, Overload::Brownout { depth: 1, watermark: 1 });
    let high2 = gateway
        .submit("hot", &spec, &op(), imgs.clone(), Priority::High, None)
        .expect("high admitted during brownout");
    gateway.resume();

    let served: Vec<Vec<i32>> = high
        .wait()
        .unwrap()
        .results
        .into_iter()
        .map(|r| r.logits)
        .collect();
    assert_eq!(direct, served, "degraded schedule changed the bits");
    high2.wait().unwrap();

    let snap = gateway.telemetry().snapshot();
    assert_eq!(snap.rejected_brownout, 1);
    assert!(
        snap.degraded >= 1,
        "popping above the watermark must count degraded serves: {snap:?}"
    );
    assert_eq!(snap.completed, 2);
    assert!(snap.reconciles(), "counters must reconcile: {snap:?}");
}

/// One trace mixing every lifecycle outcome — completed, cancelled,
/// shed, and brownout-rejected — reconciles exactly:
/// submitted == admitted + rejected() and
/// admitted == completed + failed + cancelled + shed + panicked.
#[test]
fn counters_reconcile_under_mixed_outcomes() {
    let coord = coordinator();
    let spec = kws(14);
    let d = coord.deploy(&spec).unwrap();
    let mut rng = Rng::new(62);
    let img = d.random_input(&mut rng);

    let gateway = Gateway::new(coord.clone(), GatewayConfig {
        brownout_watermark: 1,
        ..config(16, 16)
    })
    .unwrap();
    gateway.pause();
    // stays queued (no deadline, paused) until cancelled below
    let cancelled = gateway
        .submit("a", &spec, &op(), vec![img.clone()], Priority::High, None)
        .expect("admitted");
    // depth >= 1: low priority is browned out deterministically
    gateway
        .submit("b", &spec, &op(), vec![img.clone()], Priority::Low, None)
        .expect_err("browned out");
    // expired before it can start: shed by the idle sweep
    let shed = gateway
        .submit(
            "a",
            &spec,
            &op(),
            vec![img.clone()],
            Priority::High,
            Some(Duration::from_nanos(1)),
        )
        .expect("admitted");
    // no deadline: completes after resume
    let completed = gateway
        .submit("b", &spec, &op(), vec![img], Priority::High, None)
        .expect("admitted");
    assert_eq!(cancelled.cancel(), CancelOutcome::Cancelled);
    gateway.resume();

    assert!(matches!(
        cancelled.wait().unwrap_err().downcast_ref::<ServeError>(),
        Some(ServeError::Cancelled { .. })
    ));
    assert!(matches!(
        shed.wait().unwrap_err().downcast_ref::<ServeError>(),
        Some(ServeError::DeadlineExceeded { .. })
    ));
    assert_eq!(completed.wait().unwrap().results.len(), 1);

    let snap = gateway.telemetry().snapshot();
    assert_eq!(snap.submitted, 4);
    assert_eq!(snap.admitted, 3);
    assert_eq!(snap.rejected(), 1);
    assert_eq!(snap.rejected_brownout, 1);
    assert_eq!(snap.completed, 1);
    assert_eq!(snap.cancelled, 1);
    assert_eq!(snap.shed, 1);
    assert_eq!(snap.panicked, 0);
    assert!(snap.reconciles(), "lifecycle identity broken: {snap:?}");
}
